"""Kernel-vs-oracle correctness: the CORE numeric signal of the build path.

The Pallas fused block contraction (L1) must agree with the pure-jnp einsum
oracle (ref.py) across shapes, slab sizes, and value distributions, because
every distributed STTSV result in the Rust layer is a sum of these block
contractions.
"""

import numpy as np
import pytest
from proptest_compat import given, settings, st

import jax
import jax.numpy as jnp

from compile.kernels import ref, sttsv_block

jax.config.update("jax_enable_x64", False)

RTOL = 1e-5
ATOL = 1e-5


def _rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@pytest.mark.parametrize("b", [1, 2, 3, 4, 5, 7, 8, 12, 16, 24, 32])
def test_block_contract_matches_ref(b):
    rng = np.random.default_rng(b)
    A = _rand(rng, b, b, b)
    u, v, w = _rand(rng, b), _rand(rng, b), _rand(rng, b)
    ci, cj, ck = sttsv_block.block_contract(A, u, v, w)
    ri, rj, rk = ref.block_contract_ref(A, u, v, w)
    np.testing.assert_allclose(ci, ri, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(cj, rj, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(ck, rk, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("b,slab", [(8, 1), (8, 2), (8, 4), (8, 8), (12, 3), (16, 8)])
def test_block_contract_slab_invariance(b, slab):
    """Result must not depend on the VMEM slab tiling."""
    rng = np.random.default_rng(100 + b + slab)
    A = _rand(rng, b, b, b)
    u, v, w = _rand(rng, b), _rand(rng, b), _rand(rng, b)
    got = sttsv_block.block_contract(A, u, v, w, slab=slab)
    want = ref.block_contract_ref(A, u, v, w)
    for g, r in zip(got, want):
        np.testing.assert_allclose(g, r, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("nb,b", [(1, 4), (2, 4), (3, 8), (4, 8), (4, 16)])
def test_block_contract_batch_matches_ref(nb, b):
    rng = np.random.default_rng(7 * nb + b)
    As = _rand(rng, nb, b, b, b)
    us, vs, ws = _rand(rng, nb, b), _rand(rng, nb, b), _rand(rng, nb, b)
    got = sttsv_block.block_contract_batch(As, us, vs, ws)
    want = ref.block_contract_batch_ref(As, us, vs, ws)
    for g, r in zip(got, want):
        np.testing.assert_allclose(g, r, rtol=RTOL, atol=ATOL)


def test_batch_equals_loop_of_singles():
    rng = np.random.default_rng(42)
    nb, b = 4, 8
    As = _rand(rng, nb, b, b, b)
    us, vs, ws = _rand(rng, nb, b), _rand(rng, nb, b), _rand(rng, nb, b)
    cis, cjs, cks = sttsv_block.block_contract_batch(As, us, vs, ws)
    for i in range(nb):
        ci, cj, ck = sttsv_block.block_contract(As[i], us[i], vs[i], ws[i])
        np.testing.assert_allclose(cis[i], ci, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(cjs[i], cj, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(cks[i], ck, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# hypothesis sweeps: shapes and value distributions
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_block_contract_hypothesis(b, seed, scale):
    rng = np.random.default_rng(seed)
    A = _rand(rng, b, b, b) * scale
    u, v, w = _rand(rng, b), _rand(rng, b), _rand(rng, b)
    got = sttsv_block.block_contract(A, u, v, w)
    want = ref.block_contract_ref(A, u, v, w)
    for g, r in zip(got, want):
        np.testing.assert_allclose(g, r, rtol=1e-4, atol=1e-4 * scale)


@settings(max_examples=15, deadline=None)
@given(
    nb=st.integers(min_value=1, max_value=5),
    b=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_block_contract_batch_hypothesis(nb, b, seed):
    rng = np.random.default_rng(seed)
    As = _rand(rng, nb, b, b, b)
    us, vs, ws = _rand(rng, nb, b), _rand(rng, nb, b), _rand(rng, nb, b)
    got = sttsv_block.block_contract_batch(As, us, vs, ws)
    want = ref.block_contract_batch_ref(As, us, vs, ws)
    for g, r in zip(got, want):
        np.testing.assert_allclose(g, r, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# special structure: symmetric blocks behave like the paper's Algorithm 5 says
# ---------------------------------------------------------------------------

def test_symmetric_block_ci_cj_agree():
    """For a block symmetric in modes 1-2 (non-central diagonal block
    A[i][i][k]), contracting with u == v must give ci == cj."""
    rng = np.random.default_rng(3)
    b = 8
    A = _rand(rng, b, b, b)
    A = (A + A.transpose(1, 0, 2)) / 2  # symmetric in first two modes
    x = _rand(rng, b)
    w = _rand(rng, b)
    ci, cj, ck = sttsv_block.block_contract(A, x, x, w)
    np.testing.assert_allclose(ci, cj, rtol=RTOL, atol=ATOL)


def test_fully_symmetric_block_all_agree():
    """Central diagonal block: fully symmetric A with u == v == w gives
    ci == cj == ck."""
    rng = np.random.default_rng(4)
    b = 6
    A = ref.symmetrize(_rand(rng, b, b, b)).astype(np.float32)
    x = _rand(rng, b)
    ci, cj, ck = sttsv_block.block_contract(A, x, x, x)
    np.testing.assert_allclose(ci, cj, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(cj, ck, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# multi-RHS kernels: one sweep of A serving r columns
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,r", [(1, 1), (4, 1), (4, 2), (8, 4), (8, 8), (12, 3), (16, 16)])
def test_block_contract_multi_matches_ref(b, r):
    rng = np.random.default_rng(13 * b + r)
    A = _rand(rng, b, b, b)
    U, V, W = _rand(rng, b, r), _rand(rng, b, r), _rand(rng, b, r)
    got = sttsv_block.block_contract_multi(A, U, V, W)
    want = ref.block_contract_multi_ref(A, U, V, W)
    for g, rr in zip(got, want):
        assert g.shape == (b, r)
        np.testing.assert_allclose(g, rr, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,r,slab", [(8, 4, 1), (8, 4, 2), (8, 4, 8), (12, 5, 3)])
def test_block_contract_multi_slab_invariance(b, r, slab):
    """The r-column result must not depend on the VMEM slab tiling."""
    rng = np.random.default_rng(200 + b + r + slab)
    A = _rand(rng, b, b, b)
    U, V, W = _rand(rng, b, r), _rand(rng, b, r), _rand(rng, b, r)
    got = sttsv_block.block_contract_multi(A, U, V, W, slab=slab)
    want = ref.block_contract_multi_ref(A, U, V, W)
    for g, rr in zip(got, want):
        np.testing.assert_allclose(g, rr, rtol=1e-4, atol=1e-4)


def test_multi_equals_loop_of_single_rhs():
    """Column l of the multi-RHS kernel == the single-RHS kernel on column l
    (the contract the Rust engine's fallback path relies on)."""
    rng = np.random.default_rng(14)
    b, r = 8, 5
    A = _rand(rng, b, b, b)
    U, V, W = _rand(rng, b, r), _rand(rng, b, r), _rand(rng, b, r)
    cis, cjs, cks = sttsv_block.block_contract_multi(A, U, V, W)
    for l in range(r):
        ci, cj, ck = sttsv_block.block_contract(A, U[:, l], V[:, l], W[:, l])
        np.testing.assert_allclose(cis[:, l], ci, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(cjs[:, l], cj, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(cks[:, l], ck, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("nb,b,r", [(1, 4, 2), (2, 4, 4), (3, 8, 2), (4, 8, 8)])
def test_block_contract_multi_batch_matches_ref(nb, b, r):
    rng = np.random.default_rng(17 * nb + b + r)
    As = _rand(rng, nb, b, b, b)
    Us, Vs, Ws = (
        _rand(rng, nb, b, r),
        _rand(rng, nb, b, r),
        _rand(rng, nb, b, r),
    )
    got = sttsv_block.block_contract_multi_batch(As, Us, Vs, Ws)
    want = ref.block_contract_multi_batch_ref(As, Us, Vs, Ws)
    for g, rr in zip(got, want):
        assert g.shape == (nb, b, r)
        np.testing.assert_allclose(g, rr, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=10),
    r=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_block_contract_multi_hypothesis(b, r, seed):
    rng = np.random.default_rng(seed)
    A = _rand(rng, b, b, b)
    U, V, W = _rand(rng, b, r), _rand(rng, b, r), _rand(rng, b, r)
    got = sttsv_block.block_contract_multi(A, U, V, W)
    want = ref.block_contract_multi_ref(A, U, V, W)
    for g, rr in zip(got, want):
        np.testing.assert_allclose(g, rr, rtol=1e-4, atol=1e-4)
