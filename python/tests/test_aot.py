"""AOT path checks: every artifact lowers to parseable HLO text with the
expected entry signature, and the manifest is consistent.

These run the same lowering path as `make artifacts` but against a temp dir
with the reduced (--quick) plan, so tests stay fast.
"""

import os
import re

import pytest

from compile import aot


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    names = aot.emit(str(out), quick=True)
    return str(out), names


def test_manifest_lists_all_artifacts(emitted):
    out, names = emitted
    with open(os.path.join(out, "manifest.txt")) as f:
        lines = [l for l in f.read().splitlines() if l.strip()]
    assert len(lines) == len(names)
    manifest_names = [re.match(r"name=(\S+)", l).group(1) for l in lines]
    assert manifest_names == names


def test_hlo_files_exist_and_are_text(emitted):
    out, names = emitted
    for name in names:
        path = os.path.join(out, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        with open(path) as f:
            text = f.read()
        # HLO text module header; the parser on the Rust side requires it.
        assert text.startswith("HloModule"), f"{name} missing HloModule header"
        assert "ENTRY" in text, f"{name} missing ENTRY computation"


def test_block_artifact_signature(emitted):
    out, _ = emitted
    with open(os.path.join(out, "block_b4.hlo.txt")) as f:
        text = f.read()
    # 4 parameters: A(4,4,4), u(4), v(4), w(4); tuple of 3 outputs.
    assert "f32[4,4,4]" in text
    entry = text[text.index("ENTRY") :]
    assert entry.count("parameter(") == 4 or text.count("parameter(") >= 4
    assert re.search(
        r"\(f32\[4\](\{0\})?, f32\[4\](\{0\})?, f32\[4\](\{0\})?\) tuple", entry
    ), "expected a 3-tuple of f32[4] outputs"


def test_quick_plan_covers_all_kinds():
    kinds = {meta["kind"] for _, _, _, meta in aot.artifact_plan(quick=True)}
    assert kinds == {
        "block",
        "block_batch",
        "block_multi",
        "block_multi_batch",
        "dense",
        "power_step",
    }


def test_multi_artifact_signature(emitted):
    out, names = emitted
    assert "block_multi_b4_r2" in names
    with open(os.path.join(out, "block_multi_b4_r2.hlo.txt")) as f:
        text = f.read()
    # 4 parameters: A(4,4,4), U(4,2), V(4,2), W(4,2); tuple of 3 (4,2) outputs.
    assert "f32[4,4,4]" in text
    assert "f32[4,2]" in text
    entry = text[text.index("ENTRY") :]
    assert re.search(
        r"\(f32\[4,2\](\{[0-9,]+\})?, f32\[4,2\](\{[0-9,]+\})?, "
        r"f32\[4,2\](\{[0-9,]+\})?\) tuple",
        entry,
    ), "expected a 3-tuple of f32[4,2] outputs"
