"""Property-test shim: re-export `hypothesis` when it is installed, else a
miniature deterministic stand-in.

This environment does not vendor the `hypothesis` package, so the kernel and
oracle sweeps fall back to a seeded, deterministic sampler with the same
decorator surface (`@settings(max_examples=...)` over `@given(...)` with
`st.integers` / `st.sampled_from`). It mirrors what `rust/src/util/proptest.rs`
does for the missing `proptest` crate: fewer shrinking smarts, same coverage
style, fully reproducible.
"""

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    import random

    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def sample(self, rng):
            return rng.randint(self.lo, self.hi)

    class _SampledFrom:
        def __init__(self, options):
            self.options = list(options)

        def sample(self, rng):
            return rng.choice(self.options)

    class st:  # noqa: N801 - mimics `hypothesis.strategies` module name
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(options):
            return _SampledFrom(options)

    def settings(max_examples=20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # NOTE: no functools.wraps here — pytest must see a zero-argument
            # function, not the strategy parameters (it would treat them as
            # fixtures).
            def wrapper():
                # Seed from the test name so every run replays identically.
                rng = random.Random(f"proptest:{fn.__name__}")
                examples = getattr(wrapper, "_max_examples", 20)
                for case in range(examples):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    try:
                        fn(**drawn)
                    except Exception as e:  # noqa: BLE001 - re-raise with case
                        raise AssertionError(
                            f"property {fn.__name__!r} failed on case {case} "
                            f"with {drawn!r}: {e}"
                        ) from e

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = getattr(fn, "_max_examples", 20)
            return wrapper

        return deco
