"""Oracle self-consistency and L2 model checks.

Validates that our transcriptions of the paper's Algorithms 3 and 4 agree on
symmetric inputs (the paper's factor-of-2 bookkeeping in Algorithm 4 is easy
to get wrong), and that the L2 model functions are faithful.
"""

import numpy as np
import pytest
from proptest_compat import given, settings, st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _sym(rng, n):
    return ref.symmetrize(rng.standard_normal((n, n, n))).astype(np.float32)


@pytest.mark.parametrize("n", [1, 2, 3, 4, 6, 9])
def test_algorithm4_equals_algorithm3_on_symmetric(n):
    """Paper Algorithm 4 (lower-tetrahedron, multiplicity-weighted) must
    reproduce Algorithm 3 (all n^3 ternary multiplications)."""
    rng = np.random.default_rng(n)
    A = _sym(rng, n)
    x = rng.standard_normal(n).astype(np.float32)
    y3 = ref.dense_sttsv_loops(A, x)
    y4 = ref.symmetric_sttsv_loops(A, x)
    np.testing.assert_allclose(y4, y3, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [2, 5, 8])
def test_einsum_oracle_equals_loops(n):
    rng = np.random.default_rng(10 + n)
    A = rng.standard_normal((n, n, n)).astype(np.float32)  # need not be sym
    x = rng.standard_normal(n).astype(np.float32)
    np.testing.assert_allclose(
        ref.dense_sttsv_ref(A, x), ref.dense_sttsv_loops(A, x), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=20, deadline=None)
@given(n=st.integers(min_value=1, max_value=7), seed=st.integers(0, 2**31 - 1))
def test_algorithm4_hypothesis(n, seed):
    rng = np.random.default_rng(seed)
    A = _sym(rng, n)
    x = rng.standard_normal(n).astype(np.float32)
    np.testing.assert_allclose(
        ref.symmetric_sttsv_loops(A, x),
        ref.dense_sttsv_loops(A, x),
        rtol=1e-4,
        atol=1e-4,
    )


def test_model_dense_sttsv():
    rng = np.random.default_rng(0)
    n = 10
    A = _sym(rng, n)
    x = rng.standard_normal(n).astype(np.float32)
    (y,) = model.dense_sttsv_fn(A, x)
    np.testing.assert_allclose(y, ref.dense_sttsv_loops(A, x), rtol=1e-4, atol=1e-4)


def test_model_power_step_normalizes():
    rng = np.random.default_rng(1)
    n = 8
    A = _sym(rng, n)
    x = rng.standard_normal(n).astype(np.float32)
    xn, nrm = model.power_step_fn(A, x)
    assert nrm > 0
    np.testing.assert_allclose(np.linalg.norm(xn), 1.0, rtol=1e-5)


def test_model_rayleigh_on_odeco():
    """For an odeco (orthogonally decomposable) tensor A = sum lam_l e_l^3 with
    orthonormal e_l, the Rayleigh quotient at e_l is lam_l."""
    n, r = 6, 3
    rng = np.random.default_rng(2)
    Q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    lams = np.array([3.0, 2.0, 1.0])
    A = np.zeros((n, n, n), dtype=np.float64)
    for l in range(r):
        e = Q[:, l]
        A += lams[l] * np.einsum("i,j,k->ijk", e, e, e)
    A = A.astype(np.float32)
    for l in range(r):
        (lam,) = model.rayleigh_fn(A, Q[:, l].astype(np.float32))
        np.testing.assert_allclose(lam, lams[l], rtol=1e-4, atol=1e-4)


def test_power_method_converges_to_dominant_eigenpair():
    """Full HOPM (Algorithm 1) on an odeco tensor converges to the dominant
    eigenvector when started near it."""
    n, r = 8, 3
    rng = np.random.default_rng(5)
    Q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    lams = np.array([5.0, 2.0, 1.0])
    A = np.zeros((n, n, n))
    for l in range(r):
        e = Q[:, l]
        A += lams[l] * np.einsum("i,j,k->ijk", e, e, e)
    A = A.astype(np.float32)
    x = (Q[:, 0] + 0.3 * rng.standard_normal(n)).astype(np.float32)
    x = x / np.linalg.norm(x)
    for _ in range(50):
        x, _ = model.power_step_fn(A, x)
        x = np.asarray(x)
    align = abs(float(np.dot(x, Q[:, 0])))
    assert align > 1 - 1e-4, f"alignment {align}"
    (lam,) = model.rayleigh_fn(A, x)
    np.testing.assert_allclose(lam, 5.0, rtol=1e-3)
