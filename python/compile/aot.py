"""AOT bridge: lower the L2 JAX functions (with the L1 Pallas kernel inside)
to HLO *text* artifacts that the Rust L3 runtime loads via the PJRT C API.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Run once at build time (``make artifacts``). Python never runs at runtime.

Usage:
    python -m compile.aot --out-dir ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Block sizes the Rust coordinator may request (b = n / (q^2+1)).
BLOCK_SIZES = [4, 8, 16, 32]
# Batch sizes nb: per-processor block counts for the supported partitions.
#   spherical q=2: offdiag (q+1)q(q-1)/6 = 1, noncentral q = 2
#   spherical q=3: offdiag 4, noncentral 3
#   SQS(8):        offdiag C(4,3)=4, noncentral 4
#   spherical q=4: offdiag (5*4*3)/6 = 10, noncentral 4
BATCH_SIZES = [1, 2, 3, 4, 10]
# Dense-baseline sizes (Algorithm 3 executable for verification).
DENSE_SIZES = [20, 30, 40]
# Multi-RHS column counts r: the batched STTSV engine sweeps each block once
# for all r right-hand sides (CP-gradient rank / concurrent power-method
# queries). The Rust engine falls back to per-column dispatch for other r.
MULTI_R = [2, 4, 8, 16]
# The batched multi-RHS hot path covers the same r values (nb comes from
# BATCH_SIZES, the per-processor block counts of the supported partitions);
# keeping the sets equal means any r served by the single-block multi
# artifact also gets the one-dispatch-per-group batched artifact.
MULTI_BATCH_R = MULTI_R

QUICK_BLOCK_SIZES = [4, 8]
QUICK_BATCH_SIZES = [1, 2]
QUICK_DENSE_SIZES = [20]
QUICK_MULTI_R = [2]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_plan(quick: bool = False):
    """Yield (name, fn, arg_specs, meta) for every artifact to emit."""
    blocks = QUICK_BLOCK_SIZES if quick else BLOCK_SIZES
    batches = QUICK_BATCH_SIZES if quick else BATCH_SIZES
    denses = QUICK_DENSE_SIZES if quick else DENSE_SIZES
    multi_rs = QUICK_MULTI_R if quick else MULTI_R
    multi_batch_rs = QUICK_MULTI_R if quick else MULTI_BATCH_R

    for b in blocks:
        yield (
            f"block_b{b}",
            model.block_contract_fn,
            (_spec(b, b, b), _spec(b), _spec(b), _spec(b)),
            {"kind": "block", "b": b, "outputs": 3},
        )
    for b in blocks:
        for nb in batches:
            yield (
                f"block_batch_b{b}_nb{nb}",
                model.block_contract_batch_fn,
                (_spec(nb, b, b, b), _spec(nb, b), _spec(nb, b), _spec(nb, b)),
                {"kind": "block_batch", "b": b, "nb": nb, "outputs": 3},
            )
    for b in blocks:
        for r in multi_rs:
            yield (
                f"block_multi_b{b}_r{r}",
                model.block_contract_multi_fn,
                (_spec(b, b, b), _spec(b, r), _spec(b, r), _spec(b, r)),
                {"kind": "block_multi", "b": b, "r": r, "outputs": 3},
            )
    for b in blocks:
        for nb in batches:
            for r in multi_batch_rs:
                yield (
                    f"block_multi_batch_b{b}_nb{nb}_r{r}",
                    model.block_contract_multi_batch_fn,
                    (
                        _spec(nb, b, b, b),
                        _spec(nb, b, r),
                        _spec(nb, b, r),
                        _spec(nb, b, r),
                    ),
                    {"kind": "block_multi_batch", "b": b, "nb": nb, "r": r, "outputs": 3},
                )
    for n in denses:
        yield (
            f"dense_sttsv_n{n}",
            model.dense_sttsv_fn,
            (_spec(n, n, n), _spec(n)),
            {"kind": "dense", "n": n, "outputs": 1},
        )
    for n in denses:
        yield (
            f"power_step_n{n}",
            model.power_step_fn,
            (_spec(n, n, n), _spec(n)),
            {"kind": "power_step", "n": n, "outputs": 2},
        )


def emit(out_dir: str, quick: bool = False) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    names = []
    for name, fn, specs, meta in artifact_plan(quick):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        fields = " ".join(f"{k}={v}" for k, v in meta.items())
        manifest_lines.append(f"name={name} inputs={len(specs)} {fields}")
        names.append(name)
        print(f"  wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(names)} artifacts")
    return names


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument(
        "--quick", action="store_true", help="emit a reduced artifact set (tests)"
    )
    args = p.parse_args()
    emit(args.out_dir, quick=args.quick)


if __name__ == "__main__":
    main()
