"""Pure-jnp correctness oracles for the STTSV kernels.

Every Pallas kernel in this package has a reference implementation here,
written as plain einsums / loops with no Pallas involvement. pytest compares
kernel outputs against these oracles (see python/tests/).

Conventions match the paper's Algorithm 5 block computation: a block
``A in R^{b x b x b}`` of the symmetric tensor is contracted against row-block
vectors ``u`` (mode-1 / i), ``v`` (mode-2 / j), ``w`` (mode-3 / k).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def block_contract_ref(A, u, v, w):
    """The fused ternary block contraction (oracle).

    Returns the three mode contractions of one tensor block:

      ci[a] = sum_{b,c} A[a,b,c] * v[b] * w[c]   -- contribution to y_i
      cj[b] = sum_{a,c} A[a,b,c] * u[a] * w[c]   -- contribution to y_j
      ck[c] = sum_{a,b} A[a,b,c] * u[a] * v[b]   -- contribution to y_k
    """
    ci = jnp.einsum("abc,b,c->a", A, v, w)
    cj = jnp.einsum("abc,a,c->b", A, u, w)
    ck = jnp.einsum("abc,a,b->c", A, u, v)
    return ci, cj, ck


def block_contract_batch_ref(As, us, vs, ws):
    """Batched oracle: independent block contractions along axis 0."""
    ci = jnp.einsum("nabc,nb,nc->na", As, vs, ws)
    cj = jnp.einsum("nabc,na,nc->nb", As, us, ws)
    ck = jnp.einsum("nabc,na,nb->nc", As, us, vs)
    return ci, cj, ck


def dense_sttsv_ref(A, x):
    """Full STTSV y = A x2 x x3 x on a dense n^3 tensor (Algorithm 3)."""
    return jnp.einsum("ijk,j,k->i", A, x, x)


def dense_sttsv_loops(A, x):
    """Triple-loop numpy oracle for dense STTSV -- the most literal
    transcription of Algorithm 3, used to sanity-check the einsum oracle."""
    A = np.asarray(A)
    x = np.asarray(x)
    n = x.shape[0]
    y = np.zeros(n, dtype=A.dtype)
    for i in range(n):
        for j in range(n):
            for k in range(n):
                y[i] += A[i, j, k] * x[j] * x[k]
    return y


def symmetric_sttsv_loops(A, x):
    """Algorithm 4 oracle: STTSV exploiting symmetry, iterating only the
    lower tetrahedron i >= j >= k of a (dense, symmetric) tensor.

    This is the paper's Algorithm 4 verbatim; it must agree with
    dense_sttsv_loops on symmetric inputs.
    """
    A = np.asarray(A)
    x = np.asarray(x)
    n = x.shape[0]
    y = np.zeros(n, dtype=A.dtype)
    for i in range(n):
        for j in range(i + 1):
            for k in range(j + 1):
                a = A[i, j, k]
                if i != j and j != k:
                    y[i] += 2 * a * x[j] * x[k]
                    y[j] += 2 * a * x[i] * x[k]
                    y[k] += 2 * a * x[i] * x[j]
                elif i == j and j != k:
                    y[i] += 2 * a * x[j] * x[k]
                    y[k] += a * x[i] * x[j]
                elif i != j and j == k:
                    y[i] += a * x[j] * x[k]
                    y[j] += 2 * a * x[i] * x[k]
                else:  # i == j == k
                    y[i] += a * x[j] * x[k]
    return y


def symmetrize(T):
    """Symmetrize a dense cube over all 6 index permutations."""
    T = np.asarray(T)
    return (
        T
        + T.transpose(0, 2, 1)
        + T.transpose(1, 0, 2)
        + T.transpose(1, 2, 0)
        + T.transpose(2, 0, 1)
        + T.transpose(2, 1, 0)
    ) / 6.0


def block_contract_multi_ref(A, U, V, W):
    """Multi-RHS oracle: contract one block against r columns at once.

    ``U``, ``V``, ``W`` are ``(b, r)`` panels (column ``l`` of the mode-1
    vector batch lives in ``U[:, l]``); outputs are ``(b, r)`` panels with

      ci[a, l] = sum_{b,c} A[a,b,c] * V[b,l] * W[c,l]

    and cj/ck analogously -- i.e. per-column exactly block_contract_ref.
    """
    ci = jnp.einsum("abc,bl,cl->al", A, V, W)
    cj = jnp.einsum("abc,al,cl->bl", A, U, W)
    ck = jnp.einsum("abc,al,bl->cl", A, U, V)
    return ci, cj, ck


def block_contract_multi_batch_ref(As, Us, Vs, Ws):
    """Batched multi-RHS oracle: independent (block, r-panel) contractions
    along axis 0; shapes (nb, b, b, b) and (nb, b, r)."""
    ci = jnp.einsum("nabc,nbl,ncl->nal", As, Vs, Ws)
    cj = jnp.einsum("nabc,nal,ncl->nbl", As, Us, Ws)
    ck = jnp.einsum("nabc,nal,nbl->ncl", As, Us, Vs)
    return ci, cj, ck
