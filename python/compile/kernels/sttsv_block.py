"""L1 Pallas kernel: fused ternary block contraction for STTSV.

This is the compute hot spot of the paper's Algorithm 5 (lines 16-28). Each
owner-computed tensor block ``A in R^{b x b x b}`` must be contracted against
the three row-block vectors it touches, producing the three partial results

  ci[a] = sum_{b,c} A[a,b,c] * v[b] * w[c]
  cj[b] = sum_{a,c} A[a,b,c] * u[a] * w[c]
  ck[c] = sum_{a,b} A[a,b,c] * u[a] * v[b]

The kernel computes all three in a *single pass* over ``A``: every tensor
element loaded from memory is used three times. This is the node-level mirror
of the paper's Lemma 2 reuse argument (a point of the symmetric iteration
space touches all three one-dimensional projections), and it triples the
arithmetic intensity relative to three independent contractions — the same
reason the distributed algorithm wins at the network level.

Structure (designed for TPU, executed here with ``interpret=True``):

  * the grid walks the leading mode in slabs of ``t`` planes; each step holds
    one ``t x b x b`` slab in VMEM;
  * ``M = A_slab @ w`` (a ``(t*b, b) x (b,)`` matvec, MXU-friendly when
    shaped as matmul) is computed once and shared between the ``ci`` and
    ``cj`` contractions;
  * ``cj``/``ck`` accumulators live in the (revisited) output block across
    grid steps; ``ci`` is written slab-by-slab.

See DESIGN.md section "Hardware-Adaptation" for the VMEM/MXU analysis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_slab(b: int, t: int | None) -> int:
    """Largest divisor of b that is <= requested slab size (default 8)."""
    want = t if t is not None else 8
    want = max(1, min(want, b))
    while b % want != 0:
        want -= 1
    return want


def _fused_kernel(a_ref, u_ref, v_ref, w_ref, ci_ref, cj_ref, ck_ref):
    """One grid step: contract a (t, b, b) slab of A against u-slice, v, w."""
    s = pl.program_id(0)

    A = a_ref[...]  # (t, b, b) slab, resident in VMEM
    u = u_ref[...]  # (t,)   matching slice of u
    v = v_ref[...]  # (b,)
    w = w_ref[...]  # (b,)

    # Shared intermediate: M[a, p] = sum_g A[a, p, g] * w[g].
    # On TPU this is a (t*b, b) x (b,) contraction through the MXU; it is
    # reused by both the ci and cj outputs, saving a full pass over A.
    t, b, _ = A.shape
    M = jnp.dot(A.reshape(t * b, b), w).reshape(t, b)  # (t, b)

    # ci slab: ci[a] = sum_p M[a, p] * v[p]
    ci_ref[...] = jnp.dot(M, v)

    # cj partial from this slab: cj[p] = sum_a u[a] * M[a, p]
    cj_part = jnp.dot(u, M)

    # ck partial: ck[g] = sum_{a,p} A[a,p,g] * u[a] * v[p]
    #            = sum_p v[p] * (sum_a u[a] A[a,p,g])
    Au = jnp.tensordot(u, A, axes=(0, 0))  # (b, b): sum_a u[a] A[a, :, :]
    ck_part = jnp.dot(v, Au)

    # cj/ck output blocks are revisited on every grid step: zero-init on the
    # first step, then accumulate.
    @pl.when(s == 0)
    def _init():
        cj_ref[...] = jnp.zeros_like(cj_ref)
        ck_ref[...] = jnp.zeros_like(ck_ref)

    cj_ref[...] += cj_part
    ck_ref[...] += ck_part


@functools.partial(jax.jit, static_argnames=("slab",))
def block_contract(A, u, v, w, *, slab: int | None = None):
    """Fused ternary block contraction via a Pallas kernel.

    Args:
      A: (b, b, b) tensor block.
      u, v, w: (b,) row-block vectors for modes 1, 2, 3.
      slab: leading-mode slab size ``t`` (must divide b; defaults to the
        largest divisor of b that is <= 8).

    Returns:
      (ci, cj, ck): the three (b,) mode contractions.
    """
    b = A.shape[0]
    assert A.shape == (b, b, b), f"block must be cubic, got {A.shape}"
    t = _pick_slab(b, slab)
    grid = (b // t,)

    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, b, b), lambda s: (s, 0, 0)),
            pl.BlockSpec((t,), lambda s: (s,)),
            pl.BlockSpec((b,), lambda s: (0,)),
            pl.BlockSpec((b,), lambda s: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((t,), lambda s: (s,)),
            pl.BlockSpec((b,), lambda s: (0,)),
            pl.BlockSpec((b,), lambda s: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), A.dtype),
            jax.ShapeDtypeStruct((b,), A.dtype),
            jax.ShapeDtypeStruct((b,), A.dtype),
        ],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(A, u, v, w)


def _batch_kernel(a_ref, u_ref, v_ref, w_ref, ci_ref, cj_ref, ck_ref):
    """One grid step: fully contract one (1, b, b, b) block of the batch."""
    A = a_ref[0]  # (b, b, b)
    u = u_ref[0]
    v = v_ref[0]
    w = w_ref[0]

    b = A.shape[0]
    M = jnp.dot(A.reshape(b * b, b), w).reshape(b, b)
    ci_ref[0, :] = jnp.dot(M, v)
    cj_ref[0, :] = jnp.dot(u, M)
    Au = jnp.tensordot(u, A, axes=(0, 0))
    ck_ref[0, :] = jnp.dot(v, Au)


@jax.jit
def block_contract_batch(As, us, vs, ws):
    """Batched fused contraction: one grid step per block.

    Args:
      As: (nb, b, b, b) stacked blocks.
      us, vs, ws: (nb, b) stacked row-block vectors.

    Returns:
      (cis, cjs, cks): (nb, b) stacked contractions.

    This is the L3 hot-path variant: a processor stacks all owned blocks of
    one type and issues a single PJRT execution instead of ``nb`` dispatches.
    """
    nb, b = As.shape[0], As.shape[1]
    assert As.shape == (nb, b, b, b)

    return pl.pallas_call(
        _batch_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, b, b, b), lambda s: (s, 0, 0, 0)),
            pl.BlockSpec((1, b), lambda s: (s, 0)),
            pl.BlockSpec((1, b), lambda s: (s, 0)),
            pl.BlockSpec((1, b), lambda s: (s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, b), lambda s: (s, 0)),
            pl.BlockSpec((1, b), lambda s: (s, 0)),
            pl.BlockSpec((1, b), lambda s: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, b), As.dtype),
            jax.ShapeDtypeStruct((nb, b), As.dtype),
            jax.ShapeDtypeStruct((nb, b), As.dtype),
        ],
        interpret=True,
    )(As, us, vs, ws)


def _fused_multi_kernel(a_ref, u_ref, v_ref, w_ref, ci_ref, cj_ref, ck_ref):
    """One grid step: contract a (t, b, b) slab of A against r RHS columns.

    The multi-RHS panels U/V/W are (b, r): column l is one right-hand side.
    One slab of A is read once and contracted against ALL r columns -- the
    node-level amortization behind the batched STTSV engine (the same slab
    would otherwise be re-streamed r times by r single-RHS calls).
    """
    s = pl.program_id(0)

    A = a_ref[...]  # (t, b, b) slab, resident in VMEM
    U = u_ref[...]  # (t, r)   matching slice of the U panel
    V = v_ref[...]  # (b, r)
    W = w_ref[...]  # (b, r)

    t, b, _ = A.shape
    r = W.shape[1]

    # Shared intermediate: M[a, p, l] = sum_g A[a, p, g] * W[g, l]. On TPU
    # this is a (t*b, b) x (b, r) matmul through the MXU -- the r columns
    # widen the RHS, raising MXU utilization over the r = 1 matvec -- and it
    # is reused by both the ci and cj outputs.
    M = jnp.dot(A.reshape(t * b, b), W).reshape(t, b, r)  # (t, b, r)

    # ci slab: ci[a, l] = sum_p M[a, p, l] * V[p, l]
    ci_ref[...] = jnp.sum(M * V[None, :, :], axis=1)

    # cj partial from this slab: cj[p, l] = sum_a U[a, l] * M[a, p, l]
    cj_part = jnp.sum(M * U[:, None, :], axis=0)

    # ck partial: ck[g, l] = sum_{a,p} A[a,p,g] * U[a,l] * V[p,l]
    #   Au[p, g, l] = sum_a A[a, p, g] * U[a, l]   (another MXU contraction)
    Au = jnp.tensordot(A, U, axes=((0,), (0,)))  # (b, b, r)
    ck_part = jnp.sum(Au * V[:, None, :], axis=0)

    # cj/ck output blocks are revisited on every grid step: zero-init on the
    # first step, then accumulate.
    @pl.when(s == 0)
    def _init():
        cj_ref[...] = jnp.zeros_like(cj_ref)
        ck_ref[...] = jnp.zeros_like(ck_ref)

    cj_ref[...] += cj_part
    ck_ref[...] += ck_part


@functools.partial(jax.jit, static_argnames=("slab",))
def block_contract_multi(A, U, V, W, *, slab: int | None = None):
    """Multi-RHS fused ternary block contraction via a Pallas kernel.

    Args:
      A: (b, b, b) tensor block.
      U, V, W: (b, r) panels of row-block vectors -- column l is the l-th
        right-hand side for modes 1, 2, 3.
      slab: leading-mode slab size ``t`` (must divide b; defaults to the
        largest divisor of b that is <= 8).

    Returns:
      (ci, cj, ck): the three (b, r) mode-contraction panels.
    """
    b = A.shape[0]
    r = U.shape[1]
    assert A.shape == (b, b, b), f"block must be cubic, got {A.shape}"
    assert U.shape == V.shape == W.shape == (b, r), (U.shape, V.shape, W.shape)
    t = _pick_slab(b, slab)
    grid = (b // t,)

    return pl.pallas_call(
        _fused_multi_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, b, b), lambda s: (s, 0, 0)),
            pl.BlockSpec((t, r), lambda s: (s, 0)),
            pl.BlockSpec((b, r), lambda s: (0, 0)),
            pl.BlockSpec((b, r), lambda s: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t, r), lambda s: (s, 0)),
            pl.BlockSpec((b, r), lambda s: (0, 0)),
            pl.BlockSpec((b, r), lambda s: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, r), A.dtype),
            jax.ShapeDtypeStruct((b, r), A.dtype),
            jax.ShapeDtypeStruct((b, r), A.dtype),
        ],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(A, U, V, W)


def _batch_multi_kernel(a_ref, u_ref, v_ref, w_ref, ci_ref, cj_ref, ck_ref):
    """One grid step: fully contract one (1, b, b, b) block against its
    (1, b, r) RHS panels."""
    A = a_ref[0]  # (b, b, b)
    U = u_ref[0]  # (b, r)
    V = v_ref[0]
    W = w_ref[0]

    b = A.shape[0]
    M = jnp.dot(A.reshape(b * b, b), W).reshape(b, b, W.shape[1])
    ci_ref[0] = jnp.sum(M * V[None, :, :], axis=1)
    cj_ref[0] = jnp.sum(M * U[:, None, :], axis=0)
    Au = jnp.tensordot(A, U, axes=((0,), (0,)))
    ck_ref[0] = jnp.sum(Au * V[:, None, :], axis=0)


@jax.jit
def block_contract_multi_batch(As, Us, Vs, Ws):
    """Batched multi-RHS fused contraction: one grid step per block.

    Args:
      As: (nb, b, b, b) stacked blocks.
      Us, Vs, Ws: (nb, b, r) stacked RHS panels.

    Returns:
      (cis, cjs, cks): (nb, b, r) stacked contraction panels.

    This is the L3 hot-path variant behind ``SttsvPlan::run_multi``: a
    processor stacks all owned blocks of one kind and issues a single PJRT
    execution that sweeps each block once for all r columns.
    """
    nb, b = As.shape[0], As.shape[1]
    r = Us.shape[2]
    assert As.shape == (nb, b, b, b)
    assert Us.shape == Vs.shape == Ws.shape == (nb, b, r)

    return pl.pallas_call(
        _batch_multi_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, b, b, b), lambda s: (s, 0, 0, 0)),
            pl.BlockSpec((1, b, r), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, b, r), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, b, r), lambda s: (s, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, b, r), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, b, r), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, b, r), lambda s: (s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, b, r), As.dtype),
            jax.ShapeDtypeStruct((nb, b, r), As.dtype),
            jax.ShapeDtypeStruct((nb, b, r), As.dtype),
        ],
        interpret=True,
    )(As, Us, Vs, Ws)
