"""L2 JAX model: the compute graphs that get AOT-lowered for the Rust runtime.

Each public function here is a pure JAX function over statically-shaped
arguments; ``aot.py`` lowers them to HLO text artifacts that the Rust L3
coordinator loads via PJRT. The block contractions route through the L1
Pallas kernel so the kernel lowers into the same HLO module.

Functions (all return tuples — the Rust side unwraps `to_tuple`):

  block_contract_fn(b)        -> (A,u,v,w) -> (ci, cj, ck)
  block_contract_batch_fn(...)-> stacked variant
  block_contract_multi_fn     -> (A,U,V,W) -> (ci, cj, ck)  [(b, r) panels:
                                 one sweep of A serves r RHS columns]
  block_contract_multi_batch_fn -> stacked multi-RHS variant
  dense_sttsv_fn(n)           -> (A, x) -> (y,)          [Algorithm 3 baseline]
  power_step_fn(n)            -> (A, x) -> (y, norm)     [one HOPM iteration]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import sttsv_block
from .kernels import ref


def block_contract_fn(A, u, v, w):
    """Single fused block contraction (Pallas kernel inside)."""
    ci, cj, ck = sttsv_block.block_contract(A, u, v, w)
    return ci, cj, ck


def block_contract_batch_fn(As, us, vs, ws):
    """Batched fused block contraction (Pallas kernel inside)."""
    cis, cjs, cks = sttsv_block.block_contract_batch(As, us, vs, ws)
    return cis, cjs, cks


def block_contract_multi_fn(A, U, V, W):
    """Multi-RHS fused block contraction (Pallas kernel inside): (b, r)
    panels in, (b, r) panels out -- one sweep of A for all r columns."""
    ci, cj, ck = sttsv_block.block_contract_multi(A, U, V, W)
    return ci, cj, ck


def block_contract_multi_batch_fn(As, Us, Vs, Ws):
    """Batched multi-RHS fused block contraction (Pallas kernel inside)."""
    cis, cjs, cks = sttsv_block.block_contract_multi_batch(As, Us, Vs, Ws)
    return cis, cjs, cks


def dense_sttsv_fn(A, x):
    """Dense STTSV y = A x2 x x3 x (Algorithm 3): the no-symmetry baseline."""
    return (ref.dense_sttsv_ref(A, x),)


def power_step_fn(A, x):
    """One higher-order power method iteration on a dense symmetric tensor:
    y = A x2 x x3 x ; return (y / ||y||, ||y||). Used for small-n end-to-end
    checks of the distributed power method."""
    y = ref.dense_sttsv_ref(A, x)
    nrm = jnp.linalg.norm(y)
    return y / nrm, nrm


def rayleigh_fn(A, x):
    """lambda = A x1 x x2 x x3 x (the eigenvalue extraction, Algorithm 1)."""
    return (jnp.einsum("ijk,i,j,k->", A, x, x, x),)
