//! Ablation bench for the design choices DESIGN.md calls out:
//!
//! A1 — comm mode: point-to-point schedule vs All-to-All, under the α-β
//!      cost model: p2p wins on BOTH axes (fewer words and, for q ≥ 2,
//!      fewer steps than P−1).
//! A2 — batched vs per-block kernel dispatch (the L3 hot-path choice).
//! A3 — fused 3-output kernel vs computing the contractions separately
//!      (the L1 design choice; the Lemma 2 reuse at node level).
//! A4 — symmetry: Algorithm 5 vs the naive no-symmetry grid, memory and
//!      arithmetic per processor.
//!
//!     cargo bench --bench ablation

use sttsv::bench::{header, time};
use sttsv::bounds;
use sttsv::coordinator::{run_comm_only, run_sttsv_opts, CommMode, ExecOpts};
use sttsv::partition::TetraPartition;
use sttsv::runtime::Backend;
use sttsv::simulator::cost::CostModel;
use sttsv::steiner::spherical;
use sttsv::tensor::SymTensor;
use sttsv::util::rng::Rng;
use sttsv::util::table::Table;

fn main() -> anyhow::Result<()> {
    header("A1: p2p schedule vs All-to-All under the α-β model (per vector phase x2)");
    let model = CostModel::typical();
    let mut t = Table::new([
        "q", "P", "n", "mode", "steps", "max words", "α·steps (µs)", "β·bytes (µs)",
        "total (µs)",
    ]);
    for q in [2usize, 3, 4, 5] {
        let part = TetraPartition::from_steiner(&spherical(q as u64)?)?;
        let b = q * (q + 1) * 4;
        let n = b * part.m;
        for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
            let stats = run_comm_only(&part, b, mode)?;
            let max = stats
                .iter()
                .max_by_key(|s| s.sent_words.max(s.recv_words))
                .unwrap();
            let steps = 2 * match mode {
                CommMode::PointToPoint => bounds::p2p_steps(q),
                CommMode::AllToAll => part.p - 1,
            };
            t.row([
                q.to_string(),
                part.p.to_string(),
                n.to_string(),
                format!("{mode:?}"),
                steps.to_string(),
                max.sent_words.to_string(),
                format!("{:.2}", model.latency_time(steps) * 1e6),
                format!("{:.3}", model.bandwidth_time(max) * 1e6),
                format!("{:.2}", model.time(max, steps) * 1e6),
            ]);
        }
    }
    t.print();
    println!(
        "p2p uses fewer steps than P−1 for every q (q³/2+3q²/2−1 < q³+q−1) \
         AND fewer words — it dominates All-to-All on both α and β axes."
    );

    header("A2: batched vs per-block kernel dispatch (full distributed STTSV)");
    let part = TetraPartition::from_steiner(&spherical(2)?)?;
    let b = 16usize;
    let n = b * part.m;
    let tensor = SymTensor::random(n, 3);
    let mut rng = Rng::new(4);
    let x = rng.normal_vec(n);
    let mut t2 = Table::new(["backend", "batch", "median ms"]);
    for backend in [Backend::Native, Backend::Pjrt] {
        for batch in [false, true] {
            // overlap: false — the overlap pipeline always dispatches per
            // block, which would make the batched-vs-per-block comparison
            // measure identical code; pin the phased path it ablates.
            let opts = ExecOpts {
                mode: CommMode::PointToPoint,
                batch,
                overlap: false,
                ..ExecOpts::for_backend(backend)
            };
            if run_sttsv_opts(&tensor, &x, &part, opts).is_err() {
                continue; // pjrt without artifacts
            }
            let timing = time(2, 7, || {
                std::hint::black_box(run_sttsv_opts(&tensor, &x, &part, opts).unwrap());
            });
            t2.row([
                format!("{backend:?}"),
                batch.to_string(),
                format!("{:.2}", timing.median_ms()),
            ]);
        }
    }
    t2.print();

    header("A4: symmetry ablation — storage and arithmetic per processor");
    let mut t4 = Table::new([
        "n", "P", "packed words/proc (Alg5)", "dense words/proc (naive)", "ratio",
        "mults/proc (Alg5)", "mults/proc (naive n³/P)", "ratio",
    ]);
    for (q, b) in [(2usize, 12usize), (3, 12)] {
        let part = TetraPartition::from_steiner(&spherical(q as u64)?)?;
        let n = b * part.m;
        let packed: usize = (0..part.p)
            .map(|p| part.tensor_words(p, b))
            .max()
            .unwrap();
        let dense = n * n * n / part.p;
        let alg5_mults = bounds::per_proc_ternary_mults(q, b);
        let naive_mults = n * n * n / part.p;
        t4.row([
            n.to_string(),
            part.p.to_string(),
            packed.to_string(),
            dense.to_string(),
            format!("{:.2}", dense as f64 / packed as f64),
            alg5_mults.to_string(),
            naive_mults.to_string(),
            format!("{:.2}", naive_mults as f64 / alg5_mults as f64),
        ]);
    }
    t4.print();
    println!(
        "symmetry halves arithmetic (→ 2x ratio) and cuts tensor storage \
         toward n³/6P vs n³/P dense (→ 6x asymptotically; finite-b ratios \
         include the diagonal-block padding)."
    );
    Ok(())
}
