//! Bench E16: multi-tenant serving throughput — queries/sec and p50/p99
//! latency vs batch window for the `serve` coalescer, against the serial
//! per-query baseline, at P ∈ {4, 10} on both transports. Emits
//! `BENCH_serve.json`.
//!
//!     cargo bench --bench serve_throughput            # full sampling
//!     STTSV_BENCH_SMOKE=1 cargo bench ...             # CI fast path
//!
//! Protocol: ONE bursty open-loop arrival trace per (P, transport) —
//! bursts of 8 queries landing within ~0.1 ms, 0.1 ms apart — replayed
//! unchanged under a ladder of admission policies: serial (window 0,
//! max_r 1) and coalescing windows at max_r 8. Sweep service times are
//! measured wall-clock; arrivals are workload-clock (the E15 bridge:
//! declared arrival process, real service). Each policy replays the trace
//! twice on one server and reports the warm episode, so plan build and
//! pool warm-up are excluded and the plan cache's build-once behavior is
//! exercised (asserted: `plan_builds == 1` after both episodes).
//!
//! Every batch's per-processor counters are asserted inside `drain` to
//! equal exactly one r-deep STTSV — the words-r×/messages-unchanged lever
//! that makes coalescing pay — and the per-query word bill is reported.
//!
//! The acceptance line (coalesced ≥ 2× serial queries/sec at P = 4 with
//! admitted depth ≥ 4, mpsc phased) is printed honestly either way and
//! recorded in the JSON.

use std::fmt::Write as _;

use sttsv::bench::header;
use sttsv::coordinator::ExecOpts;
use sttsv::partition::TetraPartition;
use sttsv::serve::{AdmissionPolicy, ServeReport, SttsvServer};
use sttsv::simulator::TransportKind;
use sttsv::steiner::{spherical, trivial};
use sttsv::tensor::SymTensor;
use sttsv::util::rng::Rng;
use sttsv::util::table::Table;

const BURST: usize = 8;

/// Bursty open-loop trace: `queries` vectors in bursts of [`BURST`], each
/// burst spread over ~0.1 ms, bursts 0.1 ms apart — faster than serial
/// service, so the server saturates and throughput is policy-bound.
fn make_trace(n: usize, queries: usize, seed: u64) -> Vec<(Vec<f32>, f64)> {
    let mut rng = Rng::new(seed);
    (0..queries)
        .map(|k| {
            let base = (k / BURST) as f64 * 1e-4;
            let jitter = rng.below(1000) as f64 * 1e-7;
            (rng.normal_vec(n), base + jitter)
        })
        .collect()
}

/// Replay `trace` under `policy`: two episodes on one server (plan and
/// buffer pools warm by episode 2), returning the warm episode's report.
fn replay(
    tensor: &SymTensor,
    part: &TetraPartition,
    opts: ExecOpts,
    policy: AdmissionPolicy,
    trace: &[(Vec<f32>, f64)],
) -> anyhow::Result<ServeReport> {
    let server = SttsvServer::new(tensor, part, opts, policy, 2)?;
    let mut last = ServeReport::default();
    for _ in 0..2 {
        for (x, arrival) in trace {
            server.submit(x.clone(), *arrival)?;
        }
        last = server.drain()?;
    }
    let c = server.cache_counters();
    assert_eq!(c.plan_builds, 1, "plan must build once across episodes: {c:?}");
    Ok(last)
}

struct E16Row {
    p: usize,
    transport: TransportKind,
    policy: &'static str,
    window_ms: f64,
    max_r: usize,
    batches: usize,
    mean_r: f64,
    qps: f64,
    p50_ms: f64,
    p99_ms: f64,
    words_per_query: u64,
    msgs_per_query: f64,
}

fn render_json(rows: &[E16Row], queries: usize, accept: bool, speedup: f64) -> String {
    let mut s = String::new();
    let _ = write!(
        s,
        "{{\n  \"bench\": \"serve_throughput\",\n  \"queries_per_trace\": {queries},\n  \
         \"burst\": {BURST},\n  \"rows\": [\n"
    );
    for (idx, r) in rows.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"p\": {}, \"transport\": \"{}\", \"policy\": \"{}\", \
             \"window_ms\": {:.3}, \"max_r\": {}, \"batches\": {}, \
             \"mean_r\": {:.3}, \"qps\": {:.1}, \"p50_ms\": {:.4}, \
             \"p99_ms\": {:.4}, \"words_per_query\": {}, \
             \"msgs_per_query\": {:.3}}}{}\n",
            r.p,
            r.transport,
            r.policy,
            r.window_ms,
            r.max_r,
            r.batches,
            r.mean_r,
            r.qps,
            r.p50_ms,
            r.p99_ms,
            r.words_per_query,
            r.msgs_per_query,
            if idx + 1 < rows.len() { "," } else { "" }
        );
    }
    let _ = write!(
        s,
        "  ],\n  \"accept_coalesced_2x_at_p4\": {accept},\n  \
         \"p4_speedup_vs_serial\": {speedup:.3}\n}}\n"
    );
    s
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("STTSV_BENCH_SMOKE").is_ok();
    let queries = if smoke { 16 } else { 64 };
    let n = 40; // splits into m ∈ {4, 10}; comm-dominated sweeps

    header("E16: multi-tenant serving — coalesced r-deep sweeps vs serial");
    // The policy ladder: the serial baseline, then coalescing windows.
    // Windows are workload-clock; with 0.1 ms bursts of 8, 0.5 ms admits
    // full 8-deep batches and 0.05 ms catches partial bursts.
    let policies: &[(&'static str, f64, usize)] = if smoke {
        &[("serial", 0.0, 1), ("window 0.5ms", 0.5, 8)]
    } else {
        &[
            ("serial", 0.0, 1),
            ("window 0.05ms", 0.05, 8),
            ("window 0.5ms", 0.5, 8),
            ("window 2ms", 2.0, 8),
        ]
    };

    let mut rows: Vec<E16Row> = Vec::new();
    let mut t = Table::new([
        "P", "transport", "policy", "batches", "mean r", "qps", "p50 ms", "p99 ms",
        "w/query", "msg/query",
    ]);
    for (sys, p_label) in [(trivial(4)?, 4usize), (spherical(2)?, 10usize)] {
        let part = TetraPartition::from_steiner(&sys)?;
        assert_eq!(part.p, p_label);
        assert_eq!(n % part.m, 0);
        let tensor = SymTensor::random(n, 0xE16);
        for transport in [TransportKind::Mpsc, TransportKind::Spsc] {
            let opts = ExecOpts {
                transport,
                overlap: false, // phased: bitwise-deterministic serving
                ..Default::default()
            };
            let trace = make_trace(n, queries, 0xE16 ^ part.p as u64);
            for &(name, window_ms, max_r) in policies {
                let policy = AdmissionPolicy::coalescing(window_ms * 1e-3, max_r);
                let rep = replay(&tensor, &part, opts, policy, &trace)?;
                assert_eq!(rep.outcomes.len(), queries);
                let share = rep.outcomes[0].comm;
                let row = E16Row {
                    p: part.p,
                    transport,
                    policy: name,
                    window_ms,
                    max_r,
                    batches: rep.batches.len(),
                    mean_r: rep.mean_batch_depth(),
                    qps: rep.qps(),
                    p50_ms: 1e3 * rep.latency_percentile(50.0),
                    p99_ms: 1e3 * rep.latency_percentile(99.0),
                    words_per_query: share.sent_words,
                    msgs_per_query: share.sent_msgs,
                };
                t.row([
                    row.p.to_string(),
                    transport.to_string(),
                    name.to_string(),
                    row.batches.to_string(),
                    format!("{:.2}", row.mean_r),
                    format!("{:.0}", row.qps),
                    format!("{:.4}", row.p50_ms),
                    format!("{:.4}", row.p99_ms),
                    row.words_per_query.to_string(),
                    format!("{:.3}", row.msgs_per_query),
                ]);
                rows.push(row);
            }
        }
    }
    t.print();
    println!(
        "one bursty trace per (P, transport) replayed under every policy; \
         service = measured wall-clock run_multi, arrivals = workload clock. \
         Per-batch comm is asserted equal to ONE r-deep STTSV inside drain: \
         a query's word bill is depth-invariant (w/query column) and its \
         message bill falls as 1/r (msg/query column)."
    );

    // ---- acceptance (printed honestly either way) -----------------------
    let serial_p4 = rows
        .iter()
        .find(|r| r.p == 4 && r.transport == TransportKind::Mpsc && r.max_r == 1)
        .expect("P=4 mpsc serial row");
    let best_p4 = rows
        .iter()
        .filter(|r| {
            r.p == 4 && r.transport == TransportKind::Mpsc && r.max_r > 1 && r.mean_r >= 4.0
        })
        .max_by(|a, b| a.qps.partial_cmp(&b.qps).unwrap())
        .expect("P=4 mpsc coalescing row with admitted depth >= 4");
    let speedup = best_p4.qps / serial_p4.qps.max(1e-12);
    let accept = speedup >= 2.0;
    println!(
        "\nacceptance [coalesced >= 2x serial qps at P=4, admitted depth >= 4, \
         mpsc]: {} (measured {speedup:.2}x: {} at {:.0} qps, mean r {:.2}, vs \
         serial {:.0} qps)",
        if accept { "PASS" } else { "MISS" },
        best_p4.policy,
        best_p4.qps,
        best_p4.mean_r,
        serial_p4.qps
    );
    if !accept {
        println!(
            "note: the win comes from amortizing per-sweep spawn/sync and \
             per-message latency over r queries; oversubscribed or \
             smoke-sized runs understate it."
        );
    }

    let json = render_json(&rows, queries, accept, speedup);
    std::fs::write("BENCH_serve.json", &json)?;
    println!("\nwrote BENCH_serve.json ({} bytes)", json.len());
    Ok(())
}
