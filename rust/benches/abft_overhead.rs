//! Bench E19: ABFT checksum execution — what does "never silently
//! wrong" cost, and what does it actually catch? Emits `BENCH_abft.json`.
//!
//!     cargo bench --bench abft_overhead               # full sampling
//!     STTSV_BENCH_SMOKE=1 cargo bench ...             # CI fast path
//!
//! Two tables:
//!
//! **Overhead ladder** — median wall-clock of `run_multi` at P ∈ {4, 10}
//! × both transports × r ∈ {1, 4} for `abft ∈ {off, verify, scrub}`,
//! with the verify/scrub overhead printed honestly as a percentage of
//! the ABFT-off phased baseline (ABFT pins the phased sequential path,
//! so that IS its baseline). Wire overhead is exact and tiny — one
//! integrity word per sweep message (reported from the closed form) —
//! so the ladder measures the compute side: per-block `xᵀC_b x`
//! evaluation against the packed checksum coefficients. The one-time
//! n(n+1)/2-word allreduce that builds the checksums is reported
//! separately per row.
//!
//! **Detection coverage by flipped-bit position** — verify-mode runs
//! under forced single-bit flips (`FaultPlan::bit_flip` +
//! [`FaultPlan::forcing_bit`]), classified per run:
//!
//!   detected      run failed (typed `Corrupt` — P15 asserts the type)
//!   silent_wrong  run passed but some result moved > 1e-3 of its
//!                 column scale from the fault-free oracle
//!   benign        run passed within that bound (an immaterial flip —
//!                 low mantissa bits live below any fp-tolerant
//!                 detector's γ floor, and claiming otherwise would be
//!                 dishonest)
//!
//! `coverage = detected / (detected + silent_wrong)` — benign runs are
//! excluded: a flip that does not move the answer is not a miss. Wire
//! flips are measured under BOTH wire formats (the integrity word
//! covers the post-packing containers, so f32 and bf16 coverage are
//! each 100% by the Fletcher single-bit guarantee — the table proves
//! it rather than assumes it); memory flips (accumulator SDC the wire
//! word cannot see) show the honest position dependence of the γ-bound
//! check. Acceptance: exponent-bit (23..=30) coverage ≥ 99% for every
//! kind, full accounting of every trial.

use std::fmt::Write as _;
use std::time::Instant;

use sttsv::bench::header;
use sttsv::coordinator::{ExecOpts, SttsvPlan};
use sttsv::partition::TetraPartition;
use sttsv::simulator::{AbftMode, FaultPlan, TransportKind, WireFormat};
use sttsv::steiner::{spherical, trivial};
use sttsv::tensor::SymTensor;
use sttsv::util::rng::Rng;
use sttsv::util::table::Table;

fn median_us(samples: &mut [f64]) -> f64 {
    samples.sort_by(f64::total_cmp);
    samples[samples.len() / 2]
}

struct OverheadRow {
    p: usize,
    transport: TransportKind,
    r: usize,
    mode: AbftMode,
    median_us: f64,
    overhead_pct: f64,
    extra_words_per_msg: u64,
    build_words: u64,
}

struct CoverageRow {
    kind: &'static str, // "wire-f32" | "wire-bf16" | "mem"
    bit: u8,
    detected: usize,
    silent_wrong: usize,
    benign: usize,
    coverage: f64,
}

fn render_json(over: &[OverheadRow], cov: &[CoverageRow], trials: usize, accept: bool) -> String {
    let mut s = String::new();
    let _ = write!(
        s,
        "{{\n  \"bench\": \"abft_overhead\",\n  \"trials_per_bit\": {trials},\n  \
         \"overhead_rows\": [\n"
    );
    for (idx, r) in over.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"p\": {}, \"transport\": \"{:?}\", \"r\": {}, \"abft\": \"{}\", \
             \"median_us\": {:.1}, \"overhead_pct\": {:.2}, \
             \"extra_words_per_msg\": {}, \"build_allreduce_words\": {}}}{}\n",
            r.p,
            r.transport,
            r.r,
            r.mode,
            r.median_us,
            r.overhead_pct,
            r.extra_words_per_msg,
            r.build_words,
            if idx + 1 < over.len() { "," } else { "" }
        );
    }
    let _ = write!(s, "  ],\n  \"coverage_rows\": [\n");
    for (idx, r) in cov.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"kind\": \"{}\", \"bit\": {}, \"detected\": {}, \
             \"silent_wrong\": {}, \"benign\": {}, \"coverage\": {:.4}}}{}\n",
            r.kind,
            r.bit,
            r.detected,
            r.silent_wrong,
            r.benign,
            r.coverage,
            if idx + 1 < cov.len() { "," } else { "" }
        );
    }
    let _ = write!(s, "  ],\n  \"accept_exponent_coverage_99\": {accept}\n}}\n");
    s
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("STTSV_BENCH_SMOKE").is_ok();
    let reps = if smoke { 3 } else { 15 };
    let trials = if smoke { 2 } else { 8 };

    header("E19: ABFT overhead ladder + detection coverage by bit position");

    // ---- overhead ladder ------------------------------------------------
    let n = 40; // splits into m ∈ {4, 10}
    let mut over: Vec<OverheadRow> = Vec::new();
    let mut t = Table::new([
        "P", "transport", "r", "abft", "median us", "overhead", "w/msg", "build w",
    ]);
    for sys in [trivial(4)?, spherical(2)?] {
        let part = TetraPartition::from_steiner(&sys)?;
        assert_eq!(n % part.m, 0);
        let tensor = SymTensor::random(n, 0xE19);
        let mut rng = Rng::new(0xE19 ^ part.p as u64);
        let xs4: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(n)).collect();
        for transport in [TransportKind::Mpsc, TransportKind::Spsc] {
            for r in [1usize, 4] {
                let xs = &xs4[..r];
                let mut base_us = 0.0f64;
                for mode in [AbftMode::Off, AbftMode::Verify, AbftMode::Scrub] {
                    let plan = SttsvPlan::new(
                        &tensor,
                        &part,
                        ExecOpts { transport, abft: mode, overlap: false, ..Default::default() },
                    )?;
                    plan.run_multi(xs)?; // warmup: pools + payload buffers
                    let mut samples: Vec<f64> = (0..reps)
                        .map(|_| {
                            let t0 = Instant::now();
                            let rep = plan.run_multi(xs).expect("fault-free run");
                            assert_eq!(rep.ys.len(), r);
                            t0.elapsed().as_secs_f64() * 1e6
                        })
                        .collect();
                    let med = median_us(&mut samples);
                    if mode == AbftMode::Off {
                        base_us = med;
                    }
                    let extra = if mode.on() { 1 } else { 0 };
                    let build_words = plan
                        .abft_build_stats()
                        .map(|bs| bs.iter().map(|s| s.sent_words).max().unwrap_or(0))
                        .unwrap_or(0);
                    let row = OverheadRow {
                        p: part.p,
                        transport,
                        r,
                        mode,
                        median_us: med,
                        overhead_pct: 100.0 * (med / base_us - 1.0),
                        extra_words_per_msg: extra,
                        build_words,
                    };
                    t.row([
                        row.p.to_string(),
                        format!("{transport:?}"),
                        r.to_string(),
                        mode.to_string(),
                        format!("{:.1}", row.median_us),
                        format!("{:+.1}%", row.overhead_pct),
                        extra.to_string(),
                        build_words.to_string(),
                    ]);
                    over.push(row);
                }
            }
        }
    }
    t.print();
    println!(
        "verify = per-block xᵀC_b x checks + one integrity word per sweep \
         message; scrub adds recompute only on mismatch (none here, so its \
         fault-free cost should match verify). build w = the one-time \
         n(n+1)/2-word checksum allreduce, not charged to runs."
    );

    // ---- detection coverage by bit position -----------------------------
    let part = TetraPartition::from_steiner(&trivial(4)?)?;
    let n = 16;
    let tensor = SymTensor::random(n, 0xE19B);
    let mut rng = Rng::new(0xE19C);
    let xs: Vec<Vec<f32>> = (0..2).map(|_| rng.normal_vec(n)).collect();
    let bits: &[u8] = if smoke {
        &[0, 20, 23, 30]
    } else {
        &[0, 4, 8, 12, 16, 20, 22, 23, 24, 25, 26, 27, 28, 29, 30, 31]
    };

    let mk = |wire, abft| {
        SttsvPlan::new(
            &tensor,
            &part,
            ExecOpts { wire, abft, overlap: false, ..Default::default() },
        )
    };
    let oracle = mk(WireFormat::F32, AbftMode::Off)?.run_multi(&xs)?.ys;
    let vf32 = mk(WireFormat::F32, AbftMode::Verify)?;
    let vbf16 = mk(WireFormat::Bf16, AbftMode::Verify)?;
    // the bf16 "oracle" for material-drift classification is its own
    // fault-free run: wire rounding is encoding, not corruption
    let obf16 = vbf16.run_multi(&xs)?.ys;

    let mut cov: Vec<CoverageRow> = Vec::new();
    let mut t2 = Table::new(["kind", "bit", "detected", "silent wrong", "benign", "coverage"]);
    let mut accept = true;
    let kinds: [(&'static str, &SttsvPlan<'_>, &Vec<Vec<f32>>, bool); 3] = [
        ("wire-f32", &vf32, &oracle, true),
        ("wire-bf16", &vbf16, &obf16, true),
        ("mem", &vf32, &oracle, false),
    ];
    for (kind, plan, base_ys, is_wire) in kinds {
        for &bit in bits {
            let (mut detected, mut silent_wrong, mut benign) = (0usize, 0usize, 0usize);
            for trial in 0..trials {
                let seed = 0xE19D ^ ((trial as u64) << 8) ^ bit as u64;
                // ppm = 10⁶: every sweep send / every executed block flips
                let chaos = if is_wire {
                    FaultPlan::bit_flip(seed, 1_000_000, 0)
                } else {
                    FaultPlan::bit_flip(seed, 0, 1_000_000)
                }
                .forcing_bit(bit);
                match plan.run_multi_with(&xs, chaos) {
                    Err(_) => detected += 1,
                    Ok(rep) => {
                        let mut material = false;
                        for (got, want) in rep.ys.iter().zip(base_ys) {
                            let scale =
                                want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
                            material |= got
                                .iter()
                                .zip(want)
                                .any(|(g, w)| (g - w).abs() > 1e-3 * scale);
                        }
                        if material {
                            silent_wrong += 1;
                        } else {
                            benign += 1;
                        }
                    }
                }
            }
            assert_eq!(detected + silent_wrong + benign, trials, "unaccounted trial");
            let harmful = detected + silent_wrong;
            let coverage =
                if harmful == 0 { 1.0 } else { detected as f64 / harmful as f64 };
            if (23..=30).contains(&bit) {
                accept &= coverage >= 0.99;
            }
            t2.row([
                kind.to_string(),
                bit.to_string(),
                detected.to_string(),
                silent_wrong.to_string(),
                benign.to_string(),
                format!("{:.2}", coverage),
            ]);
            cov.push(CoverageRow { kind, bit, detected, silent_wrong, benign, coverage });
        }
    }
    t2.print();
    println!(
        "every trial flips (ppm = 10⁶): wire rows exercise the Fletcher \
         integrity word over the post-packing containers (f32 and bf16 \
         formats separately); mem rows flip one accumulator element per \
         executed block, which only the γ-bounded per-block checksum can \
         see. benign = the run passed AND stayed within 1e-3 of the \
         fault-free answer — excluded from coverage."
    );

    // ---- acceptance (printed honestly either way) -----------------------
    let worst_exp = cov
        .iter()
        .filter(|r| (23..=30).contains(&r.bit))
        .map(|r| r.coverage)
        .fold(1.0f64, f64::min);
    println!(
        "\nacceptance [detection coverage >= 99% for exponent-bit flips \
         (23..=30), all kinds]: {} (worst exponent-bit coverage: {:.4})",
        if accept { "PASS" } else { "MISS" },
        worst_exp
    );

    let json = render_json(&over, &cov, trials, accept);
    std::fs::write("BENCH_abft.json", &json)?;
    println!("\nwrote BENCH_abft.json ({} bytes)", json.len());
    Ok(())
}
