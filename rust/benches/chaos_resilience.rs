//! Bench E17: serving resilience under chaos — goodput, tail latency,
//! and shed/failure rates vs injected transport fault rate, at
//! P ∈ {4, 10} on the mpsc oracle transport. Emits `BENCH_chaos.json`.
//!
//!     cargo bench --bench chaos_resilience            # full sampling
//!     STTSV_BENCH_SMOKE=1 cargo bench ...             # CI fast path
//!
//! Protocol: ONE bursty open-loop trace per P (the E16 arrival process)
//! replayed under a ladder of seeded [`FaultPlan`] rates through a server
//! running the §Rob robustness policy (per-batch reseeded retries,
//! breaker to serial on sustained failure, a generous per-query
//! deadline). Every query must be accounted for at every rate:
//! `served + failed + shed == submitted` — the termination contract the
//! P13 soak proves, measured here as capacity. The zero-rate row is
//! asserted fault-free (no retries, no failures, no shedding) and doubles
//! as the transparency baseline: its goodput IS the E16 coalescing path.
//!
//! Reported per row: goodput (answered queries/sec and the answered
//! fraction), p50/p99 latency over the answers, retries, breaker trips,
//! shed and failed counts. Acceptance (printed honestly either way):
//! full accounting at every rate AND goodput at the highest rate stays
//! above zero — degraded, never wedged.

use std::fmt::Write as _;

use sttsv::bench::header;
use sttsv::coordinator::ExecOpts;
use sttsv::partition::TetraPartition;
use sttsv::serve::{AdmissionPolicy, RobustnessPolicy, ServeReport, SttsvServer};
use sttsv::simulator::FaultPlan;
use sttsv::steiner::{spherical, trivial};
use sttsv::tensor::SymTensor;
use sttsv::util::rng::Rng;
use sttsv::util::table::Table;

const BURST: usize = 8;

/// The E16 bursty open-loop trace: bursts of [`BURST`] queries spread
/// over ~0.1 ms, bursts 0.1 ms apart.
fn make_trace(n: usize, queries: usize, seed: u64) -> Vec<(Vec<f32>, f64)> {
    let mut rng = Rng::new(seed);
    (0..queries)
        .map(|k| {
            let base = (k / BURST) as f64 * 1e-4;
            let jitter = rng.below(1000) as f64 * 1e-7;
            (rng.normal_vec(n), base + jitter)
        })
        .collect()
}

/// Replay `trace` once through a robust server under `chaos`.
fn replay(
    tensor: &SymTensor,
    part: &TetraPartition,
    chaos: FaultPlan,
    trace: &[(Vec<f32>, f64)],
) -> anyhow::Result<ServeReport> {
    let opts = ExecOpts {
        chaos,
        overlap: false, // phased: deterministic fault schedules per seed
        ..Default::default()
    };
    let robust = RobustnessPolicy {
        deadline: 0.25, // generous 250 ms: sheds only pathological stalls
        max_retries: 2,
        breaker_after: 2,
        ..RobustnessPolicy::default()
    };
    let server = SttsvServer::new(tensor, part, opts, AdmissionPolicy::coalescing(5e-4, 8), 2)?
        .with_robustness(robust);
    for (x, arrival) in trace {
        server.submit(x.clone(), *arrival)?;
    }
    server.drain()
}

struct E17Row {
    p: usize,
    rate: f64,
    served: usize,
    failed: usize,
    shed: usize,
    retries: u64,
    breaker_trips: u64,
    goodput_qps: f64,
    answered_frac: f64,
    p50_ms: f64,
    p99_ms: f64,
}

fn render_json(rows: &[E17Row], queries: usize, accept: bool) -> String {
    let mut s = String::new();
    let _ = write!(
        s,
        "{{\n  \"bench\": \"chaos_resilience\",\n  \"queries_per_trace\": {queries},\n  \
         \"burst\": {BURST},\n  \"rows\": [\n"
    );
    for (idx, r) in rows.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"p\": {}, \"fault_rate\": {:.6}, \"served\": {}, \
             \"failed\": {}, \"shed\": {}, \"retries\": {}, \
             \"breaker_trips\": {}, \"goodput_qps\": {:.1}, \
             \"answered_frac\": {:.4}, \"p50_ms\": {:.4}, \"p99_ms\": {:.4}}}{}\n",
            r.p,
            r.rate,
            r.served,
            r.failed,
            r.shed,
            r.retries,
            r.breaker_trips,
            r.goodput_qps,
            r.answered_frac,
            r.p50_ms,
            r.p99_ms,
            if idx + 1 < rows.len() { "," } else { "" }
        );
    }
    let _ = write!(s, "  ],\n  \"accept_full_accounting_nonzero_goodput\": {accept}\n}}\n");
    s
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("STTSV_BENCH_SMOKE").is_ok();
    let queries = if smoke { 16 } else { 64 };
    let n = 40; // splits into m ∈ {4, 10}; comm-dominated sweeps

    header("E17: serving resilience — goodput and tails vs injected fault rate");
    let rates: &[f64] = if smoke {
        &[0.0, 1e-3]
    } else {
        &[0.0, 1e-4, 1e-3, 5e-3]
    };

    let mut rows: Vec<E17Row> = Vec::new();
    let mut accept = true;
    let mut t = Table::new([
        "P", "fault rate", "served", "failed", "shed", "retries", "trips",
        "goodput qps", "answered", "p50 ms", "p99 ms",
    ]);
    for (sys, p_label) in [(trivial(4)?, 4usize), (spherical(2)?, 10usize)] {
        let part = TetraPartition::from_steiner(&sys)?;
        assert_eq!(part.p, p_label);
        assert_eq!(n % part.m, 0);
        let tensor = SymTensor::random(n, 0xE17);
        let trace = make_trace(n, queries, 0xE17 ^ part.p as u64);
        for &rate in rates {
            let chaos = FaultPlan::rate(0xE17 ^ part.p as u64, rate);
            let rep = replay(&tensor, &part, chaos, &trace)?;
            let served = rep.outcomes.len();
            let failed = rep.failed.len();
            let shed = rep.shed.len();
            // Termination accounting: every submitted query surfaced as
            // exactly one of answered / typed-failure / deadline-shed.
            let accounted = served + failed + shed == queries;
            assert!(accounted, "P={} rate={rate}: {served}+{failed}+{shed} != {queries}", part.p);
            if rate == 0.0 {
                assert_eq!(
                    (failed, shed, rep.retries),
                    (0, 0, 0),
                    "zero-rate chaos must be transparent"
                );
            }
            accept &= accounted && (served > 0 || rate > 0.0);
            let row = E17Row {
                p: part.p,
                rate,
                served,
                failed,
                shed,
                retries: rep.retries,
                breaker_trips: rep.breaker_trips,
                goodput_qps: rep.qps(), // qps() already counts answers only
                answered_frac: served as f64 / queries.max(1) as f64,
                p50_ms: 1e3 * rep.latency_percentile(50.0),
                p99_ms: 1e3 * rep.latency_percentile(99.0),
            };
            t.row([
                row.p.to_string(),
                format!("{:.4}", row.rate),
                row.served.to_string(),
                row.failed.to_string(),
                row.shed.to_string(),
                row.retries.to_string(),
                row.breaker_trips.to_string(),
                format!("{:.0}", row.goodput_qps),
                format!("{:.2}", row.answered_frac),
                format!("{:.4}", row.p50_ms),
                format!("{:.4}", row.p99_ms),
            ]);
            rows.push(row);
        }
    }
    t.print();
    println!(
        "one bursty trace per P replayed under each seeded fault rate; the \
         server retries failed batches under reseeded plans, trips its \
         breaker to serial after 2 consecutive failures, and sheds only \
         queries that cannot start within 250 ms. served + failed + shed \
         is asserted == submitted at every rate (the P13 termination \
         contract, measured as capacity)."
    );

    // ---- acceptance (printed honestly either way) -----------------------
    let worst = rows
        .iter()
        .filter(|r| r.rate >= rates[rates.len() - 1])
        .map(|r| r.answered_frac)
        .fold(1.0f64, f64::min);
    accept &= worst > 0.0;
    println!(
        "\nacceptance [full accounting at every rate AND nonzero goodput at \
         the highest rate]: {} (worst answered fraction at rate {:.4}: {:.2})",
        if accept { "PASS" } else { "MISS" },
        rates[rates.len() - 1],
        worst
    );

    let json = render_json(&rows, queries, accept);
    std::fs::write("BENCH_chaos.json", &json)?;
    println!("\nwrote BENCH_chaos.json ({} bytes)", json.len());
    Ok(())
}
