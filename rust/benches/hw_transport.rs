//! Bench E15: hardware-speed transport — true multi-core wall-clock for
//! the lock-free SPSC transport vs the mpsc counting oracle, plus a
//! measured α (per-message latency) / β (per-word) fit against the charged
//! `CommStats`. Emits `BENCH_hw.json`.
//!
//!     cargo bench --bench hw_transport                # full sampling
//!     STTSV_BENCH_SMOKE=1 cargo bench ...             # CI fast path
//!
//! Two parts:
//!
//! 1. **α-β fit** — a P = 2 ping-pong per transport over a ladder of
//!    message widths; least-squares fit of one-way time t(w) = α + β·w.
//!    The per-transport constants turn any charged `CommStats` into a
//!    predicted communication time (`α·msgs + β·words`), which is exactly
//!    the quantity the paper's α-β-γ model prices.
//! 2. **STTSV wall-clock** — the iteration-resident power method (workers
//!    spawned once, every sweep over the counted fabric) at P ∈ {4, 10,
//!    14}, phased and overlap, on both transports, with per-processor
//!    comm parity asserted between them. The paper states its experiments
//!    for P ∈ {2, 4, 8}, but the tetrahedral construction realizes
//!    P = v(v²+1)... only at Steiner-system orders — P ∈ {4, 10, 14} are
//!    the realizable neighbors (trivial S(4,3,3), spherical q = 2,
//!    SQS(8)); the P = 2 point is covered by the ping-pong ladder.
//!
//! The acceptance line (spsc ≥ 2× mpsc wall-clock at P = 4, phased) is
//! printed honestly either way and recorded in the JSON.

use std::fmt::Write as _;
use std::time::Instant;

use sttsv::apps::power_method;
use sttsv::bench::{header, time};
use sttsv::coordinator::{CommMode, ExecOpts};
use sttsv::partition::TetraPartition;
use sttsv::simulator::{self, CommStats, RunCfg, TransportKind};
use sttsv::steiner::{spherical, sqs8, trivial, SteinerSystem};
use sttsv::tensor::SymTensor;
use sttsv::util::rng::Rng;
use sttsv::util::table::Table;

/// One-way per-message time for `words`-word messages on `transport`,
/// measured from `reps` P = 2 ping-pong round trips with the endpoints
/// already warm (pools filled, ring slots sized), so the number excludes
/// worker spawn and first-touch allocation — it prices the steady-state
/// message path alone.
fn pingpong_oneway_secs(transport: TransportKind, words: usize, reps: u64) -> f64 {
    let mut cfg = RunCfg::new(transport);
    cfg.slot_words = words;
    cfg.pin_threads = transport == TransportKind::Spsc;
    let (outs, _) = simulator::run_cfg(2, None, cfg, |comm| {
        let mut buf = vec![0.5f32; words];
        // one warm-up round trip (fills pools / sizes slots)
        if comm.rank == 0 {
            comm.isend(1, 0, &buf)?;
            comm.recv_into(1, 0, &mut buf)?;
        } else {
            comm.recv_into(0, 0, &mut buf)?;
            comm.isend(0, 0, &buf)?;
        }
        comm.barrier();
        let t0 = Instant::now();
        for it in 0..reps {
            if comm.rank == 0 {
                comm.isend(1, 1 + it, &buf)?;
                comm.recv_into(1, 1 + it, &mut buf)?;
            } else {
                comm.recv_into(0, 1 + it, &mut buf)?;
                comm.isend(0, 1 + it, &buf)?;
            }
        }
        Ok(t0.elapsed().as_secs_f64())
    })
    .unwrap();
    outs[0] / (2.0 * reps as f64)
}

/// Least-squares fit t = α + β·w over (words, seconds) points.
fn fit_alpha_beta(points: &[(usize, f64)]) -> (f64, f64) {
    let n = points.len() as f64;
    let wbar = points.iter().map(|&(w, _)| w as f64).sum::<f64>() / n;
    let tbar = points.iter().map(|&(_, t)| t).sum::<f64>() / n;
    let cov: f64 = points.iter().map(|&(w, t)| (w as f64 - wbar) * (t - tbar)).sum();
    let var: f64 = points.iter().map(|&(w, _)| (w as f64 - wbar) * (w as f64 - wbar)).sum();
    let beta = if var > 0.0 { cov / var } else { 0.0 };
    (tbar - beta * wbar, beta)
}

/// Predicted one-way communication seconds for a rank's charged counters
/// under a fitted (α, β): the α-β model priced with measured constants.
fn predict_secs(stats: &CommStats, alpha: f64, beta: f64) -> f64 {
    alpha * stats.sent_msgs as f64 + beta * stats.sent_words as f64
}

struct Fit {
    transport: TransportKind,
    alpha: f64,
    beta: f64,
    points: Vec<(usize, f64)>,
}

struct E15Row {
    p: usize,
    n: usize,
    mode: &'static str,
    iters: usize,
    mpsc_ms_per_iter: f64,
    spsc_ms_per_iter: f64,
    speedup: f64,
    max_sent_words: u64,
    max_sent_msgs: u64,
    pred_comm_ms_mpsc: f64,
    pred_comm_ms_spsc: f64,
}

fn render_json(fits: &[Fit], rows: &[E15Row], accept: bool) -> String {
    let mut s = String::new();
    s.push_str("{\n  \"bench\": \"hw_transport\",\n  \"alpha_beta_fits\": [\n");
    for (idx, f) in fits.iter().enumerate() {
        let pts: Vec<String> = f
            .points
            .iter()
            .map(|&(w, t)| format!("[{w}, {:.1}]", t * 1e9))
            .collect();
        let _ = write!(
            s,
            "    {{\"transport\": \"{}\", \"alpha_us\": {:.4}, \
             \"beta_ns_per_word\": {:.4}, \"oneway_ns_by_words\": [{}]}}{}\n",
            f.transport,
            f.alpha * 1e6,
            f.beta * 1e9,
            pts.join(", "),
            if idx + 1 < fits.len() { "," } else { "" }
        );
    }
    s.push_str("  ],\n  \"sttsv_power_method\": [\n");
    for (idx, r) in rows.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"p\": {}, \"n\": {}, \"mode\": \"{}\", \"iters\": {}, \
             \"mpsc_ms_per_iter\": {:.4}, \"spsc_ms_per_iter\": {:.4}, \
             \"speedup\": {:.3}, \"max_sent_words\": {}, \"max_sent_msgs\": {}, \
             \"pred_comm_ms_mpsc\": {:.4}, \"pred_comm_ms_spsc\": {:.4}}}{}\n",
            r.p,
            r.n,
            r.mode,
            r.iters,
            r.mpsc_ms_per_iter,
            r.spsc_ms_per_iter,
            r.speedup,
            r.max_sent_words,
            r.max_sent_msgs,
            r.pred_comm_ms_mpsc,
            r.pred_comm_ms_spsc,
            if idx + 1 < rows.len() { "," } else { "" }
        );
    }
    let _ = write!(s, "  ],\n  \"accept_spsc_2x_at_p4_phased\": {accept}\n}}\n");
    s
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("STTSV_BENCH_SMOKE").is_ok();

    // ---- part 1: α-β fit from the P = 2 ping-pong ladder ---------------
    header("E15a: transport α-β fit (P = 2 ping-pong, one-way per message)");
    let sizes: &[usize] = if smoke {
        &[1, 64, 1024]
    } else {
        &[1, 4, 16, 64, 256, 1024, 4096, 16384]
    };
    let (reps, fit_runs) = if smoke { (200u64, 1) } else { (2000u64, 3) };
    let mut fits = Vec::new();
    let mut t1 = Table::new(["transport", "α (µs/msg)", "β (ns/word)", "t(1w) ns", "t(16Kw) ns"]);
    for transport in [TransportKind::Mpsc, TransportKind::Spsc] {
        let points: Vec<(usize, f64)> = sizes
            .iter()
            .map(|&w| {
                // min over runs: latency noise is one-sided.
                let best = (0..fit_runs)
                    .map(|_| pingpong_oneway_secs(transport, w, reps))
                    .fold(f64::INFINITY, f64::min);
                (w, best)
            })
            .collect();
        let (alpha, beta) = fit_alpha_beta(&points);
        t1.row([
            transport.to_string(),
            format!("{:.3}", alpha * 1e6),
            format!("{:.3}", beta * 1e9),
            format!("{:.0}", points.first().unwrap().1 * 1e9),
            format!("{:.0}", points.last().unwrap().1 * 1e9),
        ]);
        fits.push(Fit { transport, alpha, beta, points });
    }
    t1.print();
    println!(
        "fit: one-way t(w) = α + β·w, least squares over {} widths; with \
         these constants any charged CommStats prices a predicted comm time \
         α·msgs + β·words.",
        sizes.len()
    );

    // ---- part 2: resident power-method wall-clock, both transports ------
    header("E15b: resident power method, spsc vs mpsc (phased and overlap)");
    // Steiner-realizable P near the paper's P ∈ {2, 4, 8}: trivial S(4,3,3)
    // → P=4, spherical q=2 → P=10, SQS(8) → P=14 (P=2 is the ping-pong).
    let systems: Vec<SteinerSystem> = vec![trivial(4)?, spherical(2)?, sqs8()];
    let n = 40; // lcm-friendly across m ∈ {4, 10, 8}; comm-dominated sweeps
    let iters = if smoke { 20 } else { 200 };
    let (warmup, samples) = if smoke { (0, 1) } else { (1, 3) };

    let mut rows = Vec::new();
    let mut t2 = Table::new([
        "P", "mode", "mpsc ms/it", "spsc ms/it", "speedup", "sent w/it", "sent msg/it",
        "pred mpsc ms", "pred spsc ms",
    ]);
    for sys in &systems {
        let part = TetraPartition::from_steiner(sys)?;
        assert_eq!(n % part.m, 0, "n must split into m = {} blocks", part.m);
        let (tensor, cols) = SymTensor::odeco(n, &[5.0, 2.0, 1.0], 7);
        let mut rng = Rng::new(8);
        let mut x0 = cols[0].clone();
        for v in x0.iter_mut() {
            *v += 0.25 * rng.normal_f32();
        }
        for overlap in [false, true] {
            let mode = if overlap { "overlap" } else { "phased" };
            let mut ms = [0.0f64; 2];
            let mut reports = Vec::new();
            for (ti, transport) in [TransportKind::Mpsc, TransportKind::Spsc]
                .into_iter()
                .enumerate()
            {
                let opts = ExecOpts {
                    mode: CommMode::PointToPoint,
                    overlap,
                    transport,
                    pin_threads: transport == TransportKind::Spsc,
                    ..Default::default()
                };
                // tol = 0 pins the session to exactly `iters` sweeps.
                let rep = power_method(&tensor, &part, &x0, iters, 0.0, opts)?;
                assert_eq!(rep.iters.len(), iters);
                let timing = time(warmup, samples, || {
                    let r = power_method(&tensor, &part, &x0, iters, 0.0, opts).unwrap();
                    std::hint::black_box(r);
                });
                ms[ti] = timing.median_ms() / iters as f64;
                reports.push(rep);
            }
            // P11 at the bench level: identical charged comm per processor
            // on both transports, for the whole solve.
            for (p, (m, s)) in reports[0].comm.iter().zip(&reports[1].comm).enumerate() {
                assert_eq!(m, s, "P={} proc {p} {mode}: transport comm parity", part.p);
            }
            if !overlap {
                // The phased path is the bitwise oracle on BOTH transports.
                assert_eq!(
                    reports[0].lambda, reports[1].lambda,
                    "P={} phased lambda must be bitwise transport-invariant",
                    part.p
                );
            }
            let busiest = reports[0]
                .iters
                .first()
                .map(|it| it.comm.clone())
                .unwrap_or_default()
                .into_iter()
                .max_by_key(|s| s.sent_words)
                .unwrap_or_default();
            let row = E15Row {
                p: part.p,
                n,
                mode,
                iters,
                mpsc_ms_per_iter: ms[0],
                spsc_ms_per_iter: ms[1],
                speedup: ms[0] / ms[1],
                max_sent_words: busiest.sent_words,
                max_sent_msgs: busiest.sent_msgs,
                pred_comm_ms_mpsc: predict_secs(&busiest, fits[0].alpha, fits[0].beta) * 1e3,
                pred_comm_ms_spsc: predict_secs(&busiest, fits[1].alpha, fits[1].beta) * 1e3,
            };
            t2.row([
                part.p.to_string(),
                mode.to_string(),
                format!("{:.4}", row.mpsc_ms_per_iter),
                format!("{:.4}", row.spsc_ms_per_iter),
                format!("{:.2}x", row.speedup),
                row.max_sent_words.to_string(),
                row.max_sent_msgs.to_string(),
                format!("{:.4}", row.pred_comm_ms_mpsc),
                format!("{:.4}", row.pred_comm_ms_spsc),
            ]);
            rows.push(row);
        }
    }
    t2.print();
    println!(
        "per-iteration wall-clock of the iteration-resident power method \
         (workers spawned once; n = {n} keeps sweeps communication-dominated); \
         \"pred\" columns price the busiest rank's charged per-iteration \
         CommStats with the part-1 α-β constants."
    );

    // ---- acceptance (printed honestly either way) -----------------------
    let p4 = rows
        .iter()
        .find(|r| r.p == 4 && r.mode == "phased")
        .expect("P=4 phased row");
    let accept = p4.speedup >= 2.0;
    println!(
        "\nacceptance [spsc >= 2x mpsc wall-clock at P=4 phased]: {} \
         (measured {:.2}x: mpsc {:.4} ms/it vs spsc {:.4} ms/it)",
        if accept { "PASS" } else { "MISS" },
        p4.speedup,
        p4.mpsc_ms_per_iter,
        p4.spsc_ms_per_iter
    );
    if !accept {
        println!(
            "note: spin-then-park and the spin barrier need P free cores to \
             shine; oversubscribed or smoke-sized runs understate the spsc \
             advantage. The α-β fit above is the core E15 deliverable."
        );
    }

    let json = render_json(&fits, &rows, accept);
    std::fs::write("BENCH_hw.json", &json)?;
    println!("\nwrote BENCH_hw.json ({} bytes)", json.len());
    Ok(())
}
