//! Bench: end-to-end higher-order power method (DESIGN.md E8) — wall-clock
//! and per-iteration communication through the full distributed stack, on
//! both backends when artifacts are available.
//!
//!     cargo bench --bench e2e_power_method

use sttsv::apps::power_method;
use sttsv::bench::{header, time};
use sttsv::bounds;
use sttsv::coordinator::{CommMode, ExecOpts};
use sttsv::partition::TetraPartition;
use sttsv::runtime::{artifacts_dir, Backend};
use sttsv::steiner::spherical;
use sttsv::tensor::{linalg, SymTensor};
use sttsv::util::rng::Rng;
use sttsv::util::table::Table;

fn main() -> anyhow::Result<()> {
    header("E8: end-to-end power method (odeco tensor, planted λ = 5, 2, 1)");
    let q = 2u64;
    let part = TetraPartition::from_steiner(&spherical(q)?)?;
    let mut backends = vec![Backend::Native];
    if artifacts_dir().join("manifest.txt").exists() {
        backends.push(Backend::Pjrt);
    } else {
        println!("(PJRT rows skipped: run `make artifacts`)");
    }

    let mut t = Table::new([
        "backend", "n", "iters", "lambda", "align", "words/iter/proc", "LB/iter",
        "median wall ms",
    ]);
    for &backend in &backends {
        for b in [8usize, 16, 32] {
            let n = b * part.m;
            let (tensor, cols) = SymTensor::odeco(n, &[5.0, 2.0, 1.0], 7);
            let mut rng = Rng::new(8);
            let mut x0 = cols[0].clone();
            for v in x0.iter_mut() {
                *v += 0.25 * rng.normal_f32();
            }
            let opts = ExecOpts {
                mode: CommMode::PointToPoint,
                ..ExecOpts::for_backend(backend)
            };
            let rep = power_method(&tensor, &part, &x0, 40, 1e-6, opts)?;
            let align = linalg::dot(&rep.x, &cols[0]).abs();
            let words = rep.comm.iter().map(|s| s.sent_words).max().unwrap()
                / rep.iters.len() as u64;
            let timing = time(0, 3, || {
                let r = power_method(&tensor, &part, &x0, 10, 0.0, opts).unwrap();
                std::hint::black_box(r);
            });
            t.row([
                format!("{backend:?}"),
                n.to_string(),
                rep.iters.len().to_string(),
                format!("{:.5}", rep.lambda),
                format!("{:.5}", align),
                words.to_string(),
                format!("{:.1}", bounds::lower_bound_words(n, part.p)),
                format!("{:.1}", timing.median_ms() / 10.0),
            ]);
            assert!((rep.lambda - 5.0).abs() < 5e-2);
            assert!(align > 0.999);
        }
    }
    t.print();
    println!(
        "eigenpair recovered on every row; comm per iteration equals the \
         closed form (2(n(q+1)/(q²+1) − n/P)); wall column is per power \
         iteration (10-iter run / 10)."
    );
    Ok(())
}
