//! Bench E13: end-to-end higher-order power method, **iteration-resident
//! session vs host-centric loop**, across P ∈ {4, 10, 14} at a fixed
//! problem size — wall-clock per iteration, counted comm words per
//! iteration (one STTSV + O(log P) collective words for the resident
//! path; one STTSV plus 2n *uncounted* host↔worker vector words for the
//! host loop). Emits `BENCH_e2e.json` (the tracked perf-trajectory
//! record).
//!
//!     cargo bench --bench e2e_power_method            # full sampling
//!     STTSV_BENCH_SMOKE=1 cargo bench ...             # CI fast path
//!
//! The comm identity `resident = host + collectives` is asserted
//! per-processor on every row (the session itself additionally asserts it
//! per iteration).

use std::fmt::Write as _;

use sttsv::apps::{power_method, power_method_host};
use sttsv::bench::{header, time};
use sttsv::coordinator::{CommMode, ExecOpts};
use sttsv::partition::TetraPartition;
use sttsv::simulator::allreduce_stats;
use sttsv::steiner::{spherical, sqs8, trivial, SteinerSystem};
use sttsv::tensor::{linalg, SymTensor};
use sttsv::util::rng::Rng;
use sttsv::util::table::Table;

struct E13Row {
    p: usize,
    n: usize,
    b: usize,
    iters: usize,
    resident_ms_per_iter: f64,
    host_ms_per_iter: f64,
    sttsv_words_per_iter: u64,
    collective_words_per_iter: u64,
    resident_words_per_iter: u64,
    host_vector_words_per_iter: u64,
}

fn render_json(rows: &[E13Row]) -> String {
    let mut s = String::new();
    s.push_str("{\n  \"bench\": \"e2e_power_method\",\n  \"resident_vs_host\": [\n");
    for (idx, r) in rows.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"p\": {}, \"n\": {}, \"b\": {}, \"iters\": {}, \
             \"resident_ms_per_iter\": {:.4}, \"host_ms_per_iter\": {:.4}, \
             \"sttsv_words_per_iter\": {}, \"collective_words_per_iter\": {}, \
             \"resident_words_per_iter\": {}, \
             \"host_vector_words_per_iter\": {}}}{}\n",
            r.p,
            r.n,
            r.b,
            r.iters,
            r.resident_ms_per_iter,
            r.host_ms_per_iter,
            r.sttsv_words_per_iter,
            r.collective_words_per_iter,
            r.resident_words_per_iter,
            r.host_vector_words_per_iter,
            if idx + 1 < rows.len() { "," } else { "" }
        );
    }
    s.push_str("  ]\n}\n");
    s
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("STTSV_BENCH_SMOKE").is_ok();
    header("E13: resident vs host-centric power method (odeco, planted λ = 5, 2, 1)");
    // Steiner systems giving P = 4 (trivial S(4,3,3)), 10 (spherical q=2),
    // 14 (SQS(8)); block sizes chosen so n is identical across rows.
    let systems: Vec<SteinerSystem> = vec![trivial(4)?, spherical(2)?, sqs8()];
    let n = if smoke { 40 } else { 120 };
    let iters = if smoke { 4 } else { 12 };
    let (warmup, samples) = if smoke { (0, 1) } else { (1, 3) };

    let mut rows = Vec::new();
    let mut t = Table::new([
        "P", "n", "iters", "res ms/it", "host ms/it", "sttsv w/it", "coll w/it",
        "host vec w/it",
    ]);
    for sys in &systems {
        let part = TetraPartition::from_steiner(sys)?;
        assert_eq!(n % part.m, 0, "n must split into m = {} blocks", part.m);
        let b = n / part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[5.0, 2.0, 1.0], 7);
        let mut rng = Rng::new(8);
        let mut x0 = cols[0].clone();
        for v in x0.iter_mut() {
            *v += 0.25 * rng.normal_f32();
        }
        let opts = ExecOpts { mode: CommMode::PointToPoint, ..Default::default() };

        // tol = 0 pins both drivers to exactly `iters` iterations.
        let res = power_method(&tensor, &part, &x0, iters, 0.0, opts)?;
        let host = power_method_host(&tensor, &part, &x0, iters, 0.0, opts)?;
        assert_eq!(res.iters.len(), iters);
        assert_eq!(host.iters.len(), iters);
        if !smoke {
            assert!((res.lambda - 5.0).abs() < 5e-2, "resident lambda {}", res.lambda);
            let align = linalg::dot(&res.x, &cols[0]).abs();
            assert!(align > 0.999, "resident alignment {align}");
        }

        // Per-iteration comm: resident must be exactly host + collectives,
        // processor by processor.
        let res_it = &res.iters[0].comm;
        let host_it = &host.iters[0].comm;
        for p in 0..part.p {
            let mut want = host_it[p];
            want.absorb(&allreduce_stats(part.p, p, 2));
            want.absorb(&allreduce_stats(part.p, p, 1));
            assert_eq!(res_it[p], want, "P={} proc {p}", part.p);
        }
        // Report all three comm columns at the single busiest resident
        // rank, so the emitted row satisfies the asserted identity
        // resident = sttsv + collectives exactly (per-rank maxima taken
        // independently need not sum).
        let busiest = (0..part.p)
            .max_by_key(|&p| res_it[p].sent_words)
            .unwrap();
        let resident_words = res_it[busiest].sent_words;
        let sttsv_words = host_it[busiest].sent_words;
        let coll_words = allreduce_stats(part.p, busiest, 2).sent_words
            + allreduce_stats(part.p, busiest, 1).sent_words;
        assert_eq!(resident_words, sttsv_words + coll_words);

        let res_timing = time(warmup, samples, || {
            let r = power_method(&tensor, &part, &x0, iters, 0.0, opts).unwrap();
            std::hint::black_box(r);
        });
        let host_timing = time(warmup, samples, || {
            let r = power_method_host(&tensor, &part, &x0, iters, 0.0, opts).unwrap();
            std::hint::black_box(r);
        });
        let row = E13Row {
            p: part.p,
            n,
            b,
            iters,
            resident_ms_per_iter: res_timing.median_ms() / iters as f64,
            host_ms_per_iter: host_timing.median_ms() / iters as f64,
            sttsv_words_per_iter: sttsv_words,
            collective_words_per_iter: coll_words,
            resident_words_per_iter: resident_words,
            host_vector_words_per_iter: 2 * n as u64,
        };
        t.row([
            part.p.to_string(),
            n.to_string(),
            iters.to_string(),
            format!("{:.2}", row.resident_ms_per_iter),
            format!("{:.2}", row.host_ms_per_iter),
            row.sttsv_words_per_iter.to_string(),
            row.collective_words_per_iter.to_string(),
            row.host_vector_words_per_iter.to_string(),
        ]);
        rows.push(row);
    }
    t.print();
    println!(
        "resident counted comm/iter = one STTSV + O(log P) collective words \
         (asserted per processor); the host loop additionally moves 2n \
         host↔worker vector words per iteration that the α-β-γ model never \
         sees, and re-spawns its P workers every iteration."
    );

    let json = render_json(&rows);
    std::fs::write("BENCH_e2e.json", &json)?;
    println!("\nwrote BENCH_e2e.json ({} bytes)", json.len());
    Ok(())
}
