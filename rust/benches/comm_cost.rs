//! Bench: communication costs (DESIGN.md E5, E7, E9).
//!
//! E5 — measured per-processor words (simulator) vs the §7.2.2 closed form
//!      and the Theorem 1 lower bound, for q ∈ {2,3,4,5}, point-to-point
//!      and All-to-All.
//! E7 — measured schedule step counts vs q³/2 + 3q²/2 − 1.
//! E9 — baselines: naive 3-D grid (no symmetry) and the §8 sequence
//!      approach, including the P-scaling that exposes the Θ(n) vs
//!      Θ(n/P^{1/3}) separation.
//!
//!     cargo bench --bench comm_cost

use sttsv::bench::header;
use sttsv::bounds;
use sttsv::coordinator::{baselines, run_comm_only, run_sttsv, CommMode};
use sttsv::partition::TetraPartition;
use sttsv::runtime::Backend;
use sttsv::schedule::CommSchedule;
use sttsv::steiner::spherical;
use sttsv::tensor::SymTensor;
use sttsv::util::rng::Rng;
use sttsv::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    header("E5: Algorithm 5 measured comm vs closed form vs Theorem 1 lower bound");
    let mut t = Table::new([
        "q", "P", "n", "p2p meas", "closed form", "exact?", "Thm1 LB", "p2p/LB",
        "a2a meas", "a2a formula", "a2a/LB",
    ]);
    for q in [2usize, 3, 4, 5] {
        let part = TetraPartition::from_steiner(&spherical(q as u64)?)?;
        let b = q * (q + 1) * 4;
        let n = b * part.m;
        let p2p = run_comm_only(&part, b, CommMode::PointToPoint)?;
        let a2a = run_comm_only(&part, b, CommMode::AllToAll)?;
        let meas = p2p.iter().map(|s| s.sent_words).max().unwrap();
        let meas_a2a = a2a.iter().map(|s| s.sent_words).max().unwrap();
        let closed = bounds::algorithm_words(n, q);
        let lb = bounds::lower_bound_words(n, part.p);
        t.row([
            q.to_string(),
            part.p.to_string(),
            n.to_string(),
            meas.to_string(),
            fnum(closed),
            if (meas as f64 - closed).abs() < 0.5 { "YES" } else { "no" }.to_string(),
            fnum(lb),
            format!("{:.3}", meas as f64 / lb),
            meas_a2a.to_string(),
            fnum(bounds::alltoall_words(n, q)),
            format!("{:.3}", meas_a2a as f64 / lb),
        ]);
    }
    t.print();
    println!(
        "p2p/LB → 1 as q grows (leading terms match); a2a/LB → 2 (paper §7.2.2)."
    );

    header("E7: schedule step counts vs formula q³/2 + 3q²/2 − 1");
    let mut t7 = Table::new(["system", "P", "steps measured", "formula", "match"]);
    for q in [2usize, 3, 4, 5] {
        let part = TetraPartition::from_steiner(&spherical(q as u64)?)?;
        let sched = CommSchedule::build(&part)?;
        sched.validate(&part)?;
        let f = bounds::p2p_steps(q);
        t7.row([
            format!("spherical q={q}"),
            part.p.to_string(),
            sched.num_steps().to_string(),
            f.to_string(),
            (sched.num_steps() == f).to_string(),
        ]);
        assert_eq!(sched.num_steps(), f);
    }
    {
        let part = TetraPartition::from_steiner(&sttsv::steiner::sqs8())?;
        let sched = CommSchedule::build(&part)?;
        t7.row([
            "SQS(8) [Fig 1]".to_string(),
            "14".to_string(),
            sched.num_steps().to_string(),
            "12".to_string(),
            (sched.num_steps() == 12).to_string(),
        ]);
    }
    t7.print();

    header("E9a: baselines at fixed P = 10 (measured words/proc, growing n)");
    let part = TetraPartition::from_steiner(&spherical(2)?)?;
    let mut t9 = Table::new([
        "n", "Alg5 p2p", "naive grid", "sequence", "Alg5/LB", "naive/LB", "seq/LB",
    ]);
    for b in [6usize, 12, 24, 48] {
        let n = b * part.m;
        let tensor = SymTensor::random(n, 1);
        let mut rng = Rng::new(2);
        let x = rng.normal_vec(n);
        let alg = run_sttsv(&tensor, &x, &part, CommMode::PointToPoint, Backend::Native)?;
        let naive = baselines::run_naive_grid(&tensor, &x, part.p)?;
        let seq = baselines::run_sequence(&tensor, &x, part.p)?;
        let lb = bounds::lower_bound_words(n, part.p);
        t9.row([
            n.to_string(),
            alg.max_sent_words().to_string(),
            naive.max_sent_words().to_string(),
            seq.max_sent_words().to_string(),
            format!("{:.2}", alg.max_sent_words() as f64 / lb),
            format!("{:.2}", naive.max_sent_words() as f64 / lb),
            format!("{:.2}", seq.max_sent_words() as f64 / lb),
        ]);
    }
    t9.print();

    header("E9b: P-scaling at comparable n — Θ(n/P^{1/3}) vs the sequence's Θ(n)");
    let mut t9b = Table::new([
        "q", "P", "n", "Alg5 p2p meas", "sequence (n − n/P)", "Alg5/seq",
    ]);
    for q in [2usize, 3, 4, 5] {
        let part = TetraPartition::from_steiner(&spherical(q as u64)?)?;
        let lambda1 = q * (q + 1);
        // pick b so n is as close as possible across q (n ≈ 2000)
        let b = (2000 / part.m / lambda1).max(1) * lambda1;
        let n = b * part.m;
        let p2p = run_comm_only(&part, b, CommMode::PointToPoint)?;
        let meas = p2p.iter().map(|s| s.sent_words).max().unwrap();
        let seq = (n - n / part.p) as u64; // ring allgather cost (measured in tests)
        t9b.row([
            q.to_string(),
            part.p.to_string(),
            n.to_string(),
            meas.to_string(),
            seq.to_string(),
            format!("{:.3}", meas as f64 / seq as f64),
        ]);
    }
    t9b.print();
    println!(
        "Alg5/sequence falls with P (the paper's §8 point: the sequence \
         approach cannot beat Θ(n) while Algorithm 5 scales as n/P^(1/3))."
    );
    Ok(())
}
