//! Bench: the E18 series (§Perf, PR 9) — mixed-precision wire formats and
//! arch-dispatched SIMD run-kernels.
//!
//! E18a: AVX2 vs scalar register-tiled run-kernels at the KERNEL level
//!       (one off-diagonal block's compiled descriptor stream, b = 32) for
//!       r ∈ {1, 4, 8} — bitwise equality asserted inline, GF/s from the
//!       §7.1 charged mults, and the headline AVX2/scalar ratio (target
//!       ≥ 1.5× at r = 4; reported honestly either way). r = 1 has no AVX2
//!       variant and pins ratio ≈ 1.
//! E18b: the same dispatch flip END TO END (`SttsvPlan::run_multi`,
//!       n = 120, q = 2) where transport time dilutes the kernel win.
//! E18c: bytes-vs-accuracy of the bf16 wire — per-proc payload bytes
//!       exactly halved at bitwise-identical words/messages (asserted),
//!       max relative error vs the f32-wire run reported per r.
//! E18d: the f64 conditioning study — HOPM on a planted spectrum spanning
//!       [1e8, 1] in f32 (distributed host loop) vs f64
//!       (`apps::power_method_f64` through the f64-generic kernels):
//!       |λ̂ − 1e8| per path, wall-clock per solve.
//!
//! A machine mul+add peak proxy (16 independent non-FMA chains, what the
//! no-FMA kernels could at best sustain per core) contextualizes the GF/s
//! columns. Emits machine-readable `BENCH_precision.json`.
//!
//!     cargo bench --bench precision_simd
//!
//! Set `STTSV_BENCH_SMOKE=1` (as CI does) for a quick pass: rougher
//! numbers, every code path still executes, JSON still written.

use std::fmt::Write as _;

use sttsv::apps;
use sttsv::bench::{gflops, header, time};
use sttsv::coordinator::{ExecOpts, SttsvPlan};
use sttsv::partition::TetraPartition;
use sttsv::runtime::{
    avx2_available, exec_block_runs, packed_ternary_mults, set_simd_policy, RunDesc, SimdPolicy,
};
use sttsv::simulator::WireFormat;
use sttsv::steiner::spherical;
use sttsv::tensor::{PackedBlockView, SymTensor, SymTensorG};
use sttsv::util::rng::Rng;
use sttsv::util::table::Table;

fn smoke() -> bool {
    std::env::var_os("STTSV_BENCH_SMOKE").is_some()
}

/// Smoke-aware (warmup, samples) scaling, same convention as the other
/// bench binaries.
fn btime<F: FnMut()>(warmup: usize, samples: usize, f: F) -> sttsv::bench::Timing {
    let (w, s) = if smoke() { (warmup.min(1), samples.clamp(1, 3)) } else { (warmup, samples) };
    time(w, s, f)
}

/// Single-core mul+add peak proxy: 16 independent x ← x·a + c chains, the
/// widest ILP the no-FMA kernels could sustain (vectorizes to two 8-lane
/// AVX ops per step when the target has them — deliberately NOT FMA,
/// matching the kernels' bitwise-parity discipline).
fn peak_proxy_gflops() -> f64 {
    let iters: u64 = if smoke() { 2_000_000 } else { 20_000_000 };
    let a = 1.000001f32;
    let c = 1e-7f32;
    let t = btime(1, 5, || {
        let mut y = [0.5f32; 16];
        for _ in 0..iters {
            for l in 0..16 {
                y[l] = y[l] * a + c;
            }
        }
        std::hint::black_box(y);
    });
    gflops(2.0 * 16.0 * iters as f64, &t)
}

struct KernelRow {
    r: usize,
    scalar_gflops: f64,
    auto_gflops: f64,
    /// auto / scalar throughput (>1 = AVX2 dispatch pays)
    ratio: f64,
}

struct E2eRow {
    r: usize,
    scalar_ms: f64,
    auto_ms: f64,
    ratio: f64,
}

struct WireRow {
    r: usize,
    f32_bytes: u64,
    bf16_bytes: u64,
    max_rel_err: f64,
}

struct CondRow {
    precision: &'static str,
    lambda_abs_err: f64,
    solve_ms: f64,
}

/// E18a: the register-tiled executor with dispatch forced scalar vs auto,
/// on one off-diagonal block's compiled run stream (the bulk shape at
/// large m). Bitwise equality between the two policies is asserted per r.
fn bench_kernel(avx2: bool) -> Vec<KernelRow> {
    header("E18a: AVX2 vs scalar run-kernels (off-diag block, b = 32, compiled stream)");
    let b = 32usize;
    let n = 3 * b;
    let tensor = SymTensor::random(n, 0xE18A);
    let tdata = tensor.packed_data();
    let view = PackedBlockView::new(2, 1, 0, b);
    let mut descs: Vec<RunDesc> = Vec::new();
    view.for_each_run(|run| descs.push(RunDesc::compile(&run)));
    let mults = packed_ternary_mults(&view);
    let mut rows = Vec::new();
    let mut t = Table::new(["r", "scalar GF/s", "auto GF/s", "auto/scalar"]);
    for r in [1usize, 4, 8] {
        let mut rng = Rng::new((0xE18A0 + r) as u64);
        let us = rng.normal_vec(b * r);
        let vs = rng.normal_vec(b * r);
        let ws = rng.normal_vec(b * r);
        let mut run_with = |policy: SimdPolicy| -> (Vec<f32>, sttsv::bench::Timing) {
            set_simd_policy(policy);
            let mut ci = vec![0.0f32; b * r];
            let mut cj = vec![0.0f32; b * r];
            let mut ck = vec![0.0f32; b * r];
            exec_block_runs(tdata, &descs, &us, &vs, &ws, &mut ci, &mut cj, &mut ck, r);
            let snapshot: Vec<f32> =
                ci.iter().chain(cj.iter()).chain(ck.iter()).copied().collect();
            let timing = btime(5, 30, || {
                ci.fill(0.0);
                cj.fill(0.0);
                ck.fill(0.0);
                exec_block_runs(tdata, &descs, &us, &vs, &ws, &mut ci, &mut cj, &mut ck, r);
                std::hint::black_box(&ci);
            });
            set_simd_policy(SimdPolicy::Auto);
            (snapshot, timing)
        };
        let (y_s, t_s) = run_with(SimdPolicy::Scalar);
        let (y_a, t_a) = run_with(SimdPolicy::Auto);
        assert_eq!(
            y_s.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            y_a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "r={r}: AVX2 dispatch must be bitwise-identical to scalar"
        );
        let flops = 2.0 * mults as f64 * r as f64;
        let row = KernelRow {
            r,
            scalar_gflops: gflops(flops, &t_s),
            auto_gflops: gflops(flops, &t_a),
            ratio: t_s.median.as_secs_f64() / t_a.median.as_secs_f64(),
        };
        t.row([
            r.to_string(),
            format!("{:.3}", row.scalar_gflops),
            format!("{:.3}", row.auto_gflops),
            format!("{:.2}x", row.ratio),
        ]);
        rows.push(row);
    }
    t.print();
    let r4 = rows.iter().find(|k| k.r == 4).unwrap();
    let verdict = if !avx2 {
        "N/A (no AVX2 on this machine; dispatch is scalar either way)"
    } else if r4.ratio >= 1.5 {
        "PASS"
    } else {
        "BELOW TARGET (reported honestly; machine-dependent)"
    };
    println!(
        "acceptance (r=4 kernel level): AVX2 = {:.2}x scalar (target >= 1.5x): {verdict}",
        r4.ratio
    );
    rows
}

/// E18b: the same policy flip measured end to end, where transport and
/// reduce time dilute the kernel-level win.
fn bench_e2e() -> anyhow::Result<Vec<E2eRow>> {
    header("E18b: SIMD dispatch end to end (run_multi, n = 120, q = 2, phased)");
    let part = TetraPartition::from_steiner(&spherical(2)?)?;
    let n = 120usize;
    let b = n / part.m;
    let tensor = SymTensor::random(n, 0xE18B);
    let plan = SttsvPlan::new(
        &tensor,
        &part,
        ExecOpts { overlap: false, ..Default::default() },
    )?;
    let mut rng = Rng::new(0xE18B1);
    let mut rows = Vec::new();
    let mut t = Table::new(["r", "b", "scalar ms", "auto ms", "auto speedup"]);
    for r in [1usize, 4, 8] {
        let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
        set_simd_policy(SimdPolicy::Scalar);
        let y_s = plan.run_multi(&xs)?;
        let t_s = btime(1, 7, || {
            std::hint::black_box(plan.run_multi(&xs).unwrap());
        });
        set_simd_policy(SimdPolicy::Auto);
        let y_a = plan.run_multi(&xs)?;
        let t_a = btime(1, 7, || {
            std::hint::black_box(plan.run_multi(&xs).unwrap());
        });
        assert_eq!(y_s.ys, y_a.ys, "r={r}: policy flip changed phased results");
        let row = E2eRow {
            r,
            scalar_ms: t_s.median.as_secs_f64() * 1e3,
            auto_ms: t_a.median.as_secs_f64() * 1e3,
            ratio: t_s.median.as_secs_f64() / t_a.median.as_secs_f64(),
        };
        t.row([
            r.to_string(),
            b.to_string(),
            format!("{:.2}", row.scalar_ms),
            format!("{:.2}", row.auto_ms),
            format!("{:.2}x", row.ratio),
        ]);
        rows.push(row);
    }
    t.print();
    Ok(rows)
}

/// E18c: bf16 wire bytes vs accuracy. The byte halving at bitwise words
/// and messages is asserted (the P14 invariant); the error is the number
/// this table exists to report.
fn bench_wire() -> anyhow::Result<Vec<WireRow>> {
    header("E18c: bf16 wire — payload bytes vs accuracy (n = 120, q = 2, phased)");
    let part = TetraPartition::from_steiner(&spherical(2)?)?;
    let n = 120usize;
    let tensor = SymTensor::random(n, 0xE18C);
    let plan_for = |wire| {
        SttsvPlan::new(
            &tensor,
            &part,
            ExecOpts { wire, overlap: false, ..Default::default() },
        )
    };
    let fplan = plan_for(WireFormat::F32)?;
    let hplan = plan_for(WireFormat::Bf16)?;
    let mut rng = Rng::new(0xE18C1);
    let mut rows = Vec::new();
    let mut t = Table::new([
        "r", "f32 bytes/proc", "bf16 bytes/proc", "bytes ratio", "max rel err",
    ]);
    for r in [1usize, 4] {
        let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
        let f = fplan.run_multi(&xs)?;
        let h = hplan.run_multi(&xs)?;
        let mut f32_bytes = 0u64;
        let mut bf16_bytes = 0u64;
        for p in 0..part.p {
            let (fs, hs) = (&f.per_proc[p].stats, &h.per_proc[p].stats);
            assert_eq!(
                (fs.sent_words, fs.recv_words, fs.sent_msgs, fs.recv_msgs),
                (hs.sent_words, hs.recv_words, hs.sent_msgs, hs.recv_msgs),
                "r={r} proc {p}: words/messages must be wire-invariant"
            );
            assert_eq!(
                2 * hs.sent_bytes,
                fs.sent_bytes,
                "r={r} proc {p}: bf16 bytes must be exactly half"
            );
            f32_bytes = f32_bytes.max(fs.sent_bytes);
            bf16_bytes = bf16_bytes.max(hs.sent_bytes);
        }
        let mut max_rel = 0.0f64;
        for l in 0..r {
            let scale = f.ys[l].iter().map(|v| v.abs()).fold(1.0f32, f32::max) as f64;
            for i in 0..n {
                max_rel = max_rel.max((h.ys[l][i] - f.ys[l][i]).abs() as f64 / scale);
            }
        }
        let row = WireRow { r, f32_bytes, bf16_bytes, max_rel_err: max_rel };
        t.row([
            r.to_string(),
            row.f32_bytes.to_string(),
            row.bf16_bytes.to_string(),
            format!("{:.3}", row.bf16_bytes as f64 / row.f32_bytes as f64),
            format!("{:.3e}", row.max_rel_err),
        ]);
        rows.push(row);
    }
    t.print();
    println!(
        "asserted: per-proc words AND messages bitwise wire-invariant, payload \
         bytes exactly halved; error stays within the 2^-7 P14 bound (each \
         payload word crosses the wire O(1) times at <= 2^-8 per crossing)."
    );
    Ok(rows)
}

/// E18d: the conditioning study. Planted spectrum [1e8, 2, 1]: the f32
/// pipeline carries ~1e-7 relative kernel error (~10 absolute at λ = 1e8);
/// the f64 path resolves the same eigenvalue to ~1e-6 absolute.
fn bench_conditioning() -> anyhow::Result<Vec<CondRow>> {
    header("E18d: f32 vs f64 HOPM on an ill-conditioned planted spectrum [1e8, 2, 1]");
    let part = TetraPartition::from_steiner(&spherical(2)?)?;
    let b = 4usize;
    let n = b * part.m;
    let iters = if smoke() { 12 } else { 40 };
    let seed = 0xE18Du64;

    let (t32, c32) = SymTensor::odeco(n, &[1.0e8f32, 2.0, 1.0], seed);
    let mut rng = Rng::new(seed + 1);
    let mut x0 = c32[0].clone();
    for v in x0.iter_mut() {
        *v += 0.1 * rng.normal_f32();
    }
    let opts = ExecOpts::default();
    let rep32 = apps::power_method_host(&t32, &part, &x0, iters, 0.0, opts)?;
    let t_32 = btime(0, 3, || {
        std::hint::black_box(
            apps::power_method_host(&t32, &part, &x0, iters, 0.0, opts).unwrap(),
        );
    });

    let (t64, c64) = SymTensorG::<f64>::odeco64(n, &[1.0e8f64, 2.0, 1.0], seed);
    let mut rng = Rng::new(seed + 1);
    let mut x0_64 = c64[0].clone();
    for v in x0_64.iter_mut() {
        *v += 0.1 * rng.normal_f32() as f64;
    }
    let rep64 = apps::power_method_f64(&t64, &x0_64, iters, 0.0);
    let t_64 = btime(0, 3, || {
        std::hint::black_box(apps::power_method_f64(&t64, &x0_64, iters, 0.0));
    });

    let rows = vec![
        CondRow {
            precision: "f32",
            lambda_abs_err: ((rep32.lambda as f64) - 1.0e8).abs(),
            solve_ms: t_32.median.as_secs_f64() * 1e3,
        },
        CondRow {
            precision: "f64",
            lambda_abs_err: (rep64.lambda - 1.0e8).abs(),
            solve_ms: t_64.median.as_secs_f64() * 1e3,
        },
    ];
    let mut t = Table::new(["precision", "|lambda - 1e8|", "solve ms"]);
    for row in &rows {
        t.row([
            row.precision.to_string(),
            format!("{:.3e}", row.lambda_abs_err),
            format!("{:.2}", row.solve_ms),
        ]);
    }
    t.print();
    println!(
        "note: the two instances share the planted spectrum but not the \
         random eigenvectors (f32 odeco vs f64 odeco64 draw differently); \
         the |λ̂ − 1e8| columns are each path's own accuracy, which is the \
         comparison that matters."
    );
    Ok(rows)
}

fn main() -> anyhow::Result<()> {
    let avx2 = avx2_available();
    println!(
        "AVX2: {} (dispatch policy: auto; no-FMA vector kernels, bitwise-equal \
         to scalar)",
        if avx2 { "available" } else { "NOT available" }
    );
    let peak = peak_proxy_gflops();
    println!("machine mul+add peak proxy (1 core, 16 chains): {peak:.2} GF/s");

    let kernel_rows = bench_kernel(avx2);
    let e2e_rows = bench_e2e()?;
    let wire_rows = bench_wire()?;
    let cond_rows = bench_conditioning()?;

    for k in &kernel_rows {
        println!(
            "kernel r={}: auto {:.3} GF/s = {:.0}% of the mul+add peak proxy",
            k.r,
            k.auto_gflops,
            100.0 * k.auto_gflops / peak
        );
    }

    let json = render_json(avx2, peak, &kernel_rows, &e2e_rows, &wire_rows, &cond_rows);
    std::fs::write("BENCH_precision.json", &json)?;
    println!("\nwrote BENCH_precision.json ({} bytes)", json.len());
    Ok(())
}

/// Hand-rolled JSON (no serde is vendored).
fn render_json(
    avx2: bool,
    peak: f64,
    kernel: &[KernelRow],
    e2e: &[E2eRow],
    wire: &[WireRow],
    cond: &[CondRow],
) -> String {
    let mut s = String::new();
    let _ = write!(
        s,
        "{{\n  \"bench\": \"precision_simd\",\n  \"avx2\": {avx2},\n  \
         \"peak_proxy_gflops\": {peak:.4},\n  \"simd_kernel\": [\n"
    );
    for (idx, k) in kernel.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"r\": {}, \"scalar_gflops\": {:.4}, \"auto_gflops\": {:.4}, \
             \"ratio\": {:.4}}}{}\n",
            k.r,
            k.scalar_gflops,
            k.auto_gflops,
            k.ratio,
            if idx + 1 < kernel.len() { "," } else { "" }
        );
    }
    s.push_str("  ],\n  \"simd_e2e\": [\n");
    for (idx, e) in e2e.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"r\": {}, \"scalar_ms\": {:.4}, \"auto_ms\": {:.4}, \
             \"ratio\": {:.4}}}{}\n",
            e.r,
            e.scalar_ms,
            e.auto_ms,
            e.ratio,
            if idx + 1 < e2e.len() { "," } else { "" }
        );
    }
    s.push_str("  ],\n  \"wire_accuracy\": [\n");
    for (idx, w) in wire.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"r\": {}, \"f32_bytes\": {}, \"bf16_bytes\": {}, \
             \"max_rel_err\": {:.6e}}}{}\n",
            w.r,
            w.f32_bytes,
            w.bf16_bytes,
            w.max_rel_err,
            if idx + 1 < wire.len() { "," } else { "" }
        );
    }
    s.push_str("  ],\n  \"conditioning\": [\n");
    for (idx, c) in cond.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"precision\": \"{}\", \"lambda_abs_err\": {:.6e}, \
             \"solve_ms\": {:.4}}}{}\n",
            c.precision,
            c.lambda_abs_err,
            c.solve_ms,
            if idx + 1 < cond.len() { "," } else { "" }
        );
    }
    s.push_str("  ]\n}\n");
    s
}
