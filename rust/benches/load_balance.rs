//! Bench: computational load balance (DESIGN.md E6, paper §7.1).
//!
//! Measures per-processor logical ternary multiplications on real runs and
//! compares against the paper's per-processor cost formula and the n³/2P
//! leading term; also verifies the global total equals Algorithm 4's
//! n²(n+1)/2 exactly.
//!
//!     cargo bench --bench load_balance

use sttsv::bench::header;
use sttsv::bounds;
use sttsv::coordinator::{run_sttsv, CommMode};
use sttsv::partition::TetraPartition;
use sttsv::runtime::Backend;
use sttsv::steiner::spherical;
use sttsv::tensor::SymTensor;
use sttsv::util::rng::Rng;
use sttsv::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    header("E6: ternary-multiplication load balance (paper §7.1)");
    let mut t = Table::new([
        "q", "P", "n", "max mults/proc", "formula/proc", "n³/2P", "max/mean",
        "total", "n²(n+1)/2", "exact?",
    ]);
    for (q, b) in [(2usize, 12usize), (2, 24), (3, 12), (3, 24)] {
        let part = TetraPartition::from_steiner(&spherical(q as u64)?)?;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 5);
        let mut rng = Rng::new(6);
        let x = rng.normal_vec(n);
        let rep = run_sttsv(&tensor, &x, &part, CommMode::PointToPoint, Backend::Native)?;
        let max = rep.max_ternary_mults();
        let total = rep.total_ternary_mults();
        let mean = total as f64 / part.p as f64;
        let formula = bounds::per_proc_ternary_mults(q, b);
        let leading = (n as f64).powi(3) / (2.0 * part.p as f64);
        let alg4 = (n * n * (n + 1) / 2) as u64;
        t.row([
            q.to_string(),
            part.p.to_string(),
            n.to_string(),
            max.to_string(),
            formula.to_string(),
            fnum(leading),
            format!("{:.4}", max as f64 / mean),
            total.to_string(),
            alg4.to_string(),
            (total == alg4).to_string(),
        ]);
        assert_eq!(total, alg4, "work conservation");
        assert!(max <= formula as u64, "max exceeds the paper's §7.1 bound");
    }
    t.print();
    println!(
        "max/proc ≤ the §7.1 closed form; totals equal Algorithm 4's count \
         exactly (no ternary multiplication duplicated or dropped); imbalance \
         (max/mean) stays in the diagonal-block slack the paper describes."
    );
    Ok(())
}
