//! Bench: the L1 block-kernel hot path (DESIGN.md E10).
//!
//! Measures the fused ternary block contraction on the native backend and,
//! when artifacts exist, on the PJRT backend (interpret-mode Pallas — CPU
//! numerics, not a TPU perf proxy; see DESIGN.md §Hardware-Adaptation for
//! the TPU VMEM/MXU analysis). Also measures the batched variant that
//! amortizes PJRT dispatch, and the unfused 3-pass native variant to show
//! the arithmetic-intensity win of the fused kernel.
//!
//!     cargo bench --bench kernel_throughput

use sttsv::bench::{gflops, header, time};
use sttsv::runtime::{artifacts_dir, block_contract_native, Backend, Engine};
use sttsv::util::rng::Rng;
use sttsv::util::table::Table;

/// Unfused reference: three independent passes over A (what a library would
/// do without the fused kernel) — 3× the A traffic.
fn block_contract_unfused(
    a: &[f32],
    u: &[f32],
    v: &[f32],
    w: &[f32],
    b: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut ci = vec![0.0f32; b];
    let mut cj = vec![0.0f32; b];
    let mut ck = vec![0.0f32; b];
    for x in 0..b {
        for y in 0..b {
            let row = &a[(x * b + y) * b..(x * b + y + 1) * b];
            let mut m = 0.0f32;
            for z in 0..b {
                m += row[z] * w[z];
            }
            ci[x] += m * v[y];
        }
    }
    for x in 0..b {
        for y in 0..b {
            let row = &a[(x * b + y) * b..(x * b + y + 1) * b];
            let mut m = 0.0f32;
            for z in 0..b {
                m += row[z] * w[z];
            }
            cj[y] += m * u[x];
        }
    }
    for x in 0..b {
        for y in 0..b {
            let row = &a[(x * b + y) * b..(x * b + y + 1) * b];
            let uv = u[x] * v[y];
            for z in 0..b {
                ck[z] += row[z] * uv;
            }
        }
    }
    (ci, cj, ck)
}

fn main() -> anyhow::Result<()> {
    header("E10: fused block-contraction kernel throughput");
    let have_pjrt = artifacts_dir().join("manifest.txt").exists();
    let pjrt = if have_pjrt {
        Some(Engine::new(Backend::Pjrt)?)
    } else {
        println!("(PJRT rows skipped: run `make artifacts`)");
        None
    };

    let mut t = Table::new(["b", "variant", "median µs", "GFLOP/s", "flops/byte(A)"]);
    for b in [4usize, 8, 16, 32] {
        let mut rng = Rng::new(b as u64);
        let a = rng.normal_vec(b * b * b);
        let (u, v, w) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        // fused kernel flops: ~3 contractions * 2 flops * b³ (+ lower order)
        let flops = 6.0 * (b as f64).powi(3);
        let intensity = flops / (b * b * b * 4) as f64;

        let tn = time(10, 50, || {
            std::hint::black_box(block_contract_native(&a, &u, &v, &w, b));
        });
        t.row([
            b.to_string(),
            "native fused".into(),
            format!("{:.2}", tn.median.as_secs_f64() * 1e6),
            format!("{:.3}", gflops(flops, &tn)),
            format!("{intensity:.2}"),
        ]);

        let tu = time(10, 50, || {
            std::hint::black_box(block_contract_unfused(&a, &u, &v, &w, b));
        });
        t.row([
            b.to_string(),
            "native unfused(3-pass)".into(),
            format!("{:.2}", tu.median.as_secs_f64() * 1e6),
            format!("{:.3}", gflops(flops, &tu)),
            format!("{:.2}", intensity / 3.0),
        ]);

        if let Some(eng) = &pjrt {
            if eng.has_artifact(&format!("block_b{b}")) {
                let tp = time(3, 15, || {
                    std::hint::black_box(eng.block_contract(&a, &u, &v, &w, b).unwrap());
                });
                t.row([
                    b.to_string(),
                    "pjrt pallas(interp)".into(),
                    format!("{:.2}", tp.median.as_secs_f64() * 1e6),
                    format!("{:.3}", gflops(flops, &tp)),
                    format!("{intensity:.2}"),
                ]);
            }
        }
    }
    t.print();

    header("E10b: batched dispatch amortization (nb blocks per call)");
    let mut t2 = Table::new(["b", "nb", "variant", "median µs/block"]);
    let (b, nb) = (16usize, 4usize);
    let mut rng = Rng::new(99);
    let a = rng.normal_vec(nb * b * b * b);
    let (us, vs, ws) = (
        rng.normal_vec(nb * b),
        rng.normal_vec(nb * b),
        rng.normal_vec(nb * b),
    );
    for (label, engine) in [
        ("native", Some(Engine::new(Backend::Native)?)),
        ("pjrt", pjrt.as_ref().cloned().map(Some).unwrap_or(None)),
    ] {
        let Some(eng) = engine else { continue };
        let t_loop = time(3, 15, || {
            for s in 0..nb {
                std::hint::black_box(
                    eng.block_contract(
                        &a[s * b * b * b..(s + 1) * b * b * b],
                        &us[s * b..(s + 1) * b],
                        &vs[s * b..(s + 1) * b],
                        &ws[s * b..(s + 1) * b],
                        b,
                    )
                    .unwrap(),
                );
            }
        });
        let t_batch = time(3, 15, || {
            std::hint::black_box(eng.block_contract_batch(&a, &us, &vs, &ws, b, nb).unwrap());
        });
        t2.row([
            b.to_string(),
            nb.to_string(),
            format!("{label} loop"),
            format!("{:.2}", t_loop.median.as_secs_f64() * 1e6 / nb as f64),
        ]);
        t2.row([
            b.to_string(),
            nb.to_string(),
            format!("{label} batched"),
            format!("{:.2}", t_batch.median.as_secs_f64() * 1e6 / nb as f64),
        ]);
    }
    t2.print();
    println!(
        "interpret-mode Pallas timings are CPU-only (structure check); the \
         TPU projection (VMEM footprint, MXU-shaped matmuls, 1.5 flop/B from \
         HBM, 3× reuse vs unfused) is in DESIGN.md §Hardware-Adaptation."
    );
    Ok(())
}
