//! Bench: the L1 block-kernel hot path (DESIGN.md E10) and the multi-RHS
//! amortization sweep (EXPERIMENTS.md §Perf P6).
//!
//! Measures the fused ternary block contraction on the native backend and,
//! when artifacts exist, on the PJRT backend (interpret-mode Pallas — CPU
//! numerics, not a TPU perf proxy; see DESIGN.md §Hardware-Adaptation for
//! the TPU VMEM/MXU analysis). Also measures the batched variant that
//! amortizes PJRT dispatch, the unfused 3-pass native variant to show the
//! arithmetic-intensity win of the fused kernel, and — the headline of this
//! file — the r-sweep of the multi-RHS path at both the kernel level
//! (`block_contract_multi` vs r single-RHS sweeps) and the end-to-end
//! engine level (`SttsvPlan::run_multi` vs r sequential `run` calls,
//! including the exact r×-words / constant-messages comm check).
//!
//! The E11 series (§Perf P7): plan-resident tensor words and end-to-end
//! throughput of the zero-copy packed execution path vs the dense-extract
//! path, including plan-construction time.
//!
//! The E12 series (§Perf P8): overlapped-pipeline vs phased wall-clock
//! and peak in-flight payload bytes across a P sweep at fixed n, with the
//! comm-cost invariance and the steady-state zero-allocation property
//! asserted inline.
//!
//! New in this PR, the E14 series (§Perf P10): plan-compiled branch-free
//! sweep programs (register-tiled microkernels over precompiled run
//! descriptors) vs the packed interpreter, and 1 vs 4 intra-worker
//! compute threads, at fixed n = 120 across P ∈ {4, 10, 14} — with
//! bitwise equality and exact comm/mults invariance asserted inline.
//! `STTSV_BENCH_SECTION=e14` (`make bench-compiled`) runs only this
//! series, writing BENCH_compiled.json.
//!
//! Emits a machine-readable `BENCH_kernel.json` next to the package root so
//! the perf trajectory is tracked across PRs.
//!
//!     cargo bench --bench kernel_throughput
//!
//! Set `STTSV_BENCH_SMOKE=1` (as CI does) to cut warmup/sample counts for a
//! quick smoke run: numbers are rougher but every code path still executes
//! and BENCH_kernel.json is still written. Set `STTSV_BENCH_SECTION=e12`
//! (as `make bench-overlap` does) to run only the E12 overlap series.

use std::fmt::Write as _;

use sttsv::bench::{gflops, header, time};
use sttsv::coordinator::{ExecOpts, SttsvPlan};
use sttsv::partition::TetraPartition;
use sttsv::runtime::{
    artifacts_dir, block_contract_multi, block_contract_native, Backend, Engine,
};
use sttsv::steiner::{spherical, sqs8, trivial, SteinerSystem};
use sttsv::tensor::SymTensor;
use sttsv::util::rng::Rng;
use sttsv::util::table::Table;

/// Unfused reference: three independent passes over A (what a library would
/// do without the fused kernel) — 3× the A traffic.
fn block_contract_unfused(
    a: &[f32],
    u: &[f32],
    v: &[f32],
    w: &[f32],
    b: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut ci = vec![0.0f32; b];
    let mut cj = vec![0.0f32; b];
    let mut ck = vec![0.0f32; b];
    for x in 0..b {
        for y in 0..b {
            let row = &a[(x * b + y) * b..(x * b + y + 1) * b];
            let mut m = 0.0f32;
            for z in 0..b {
                m += row[z] * w[z];
            }
            ci[x] += m * v[y];
        }
    }
    for x in 0..b {
        for y in 0..b {
            let row = &a[(x * b + y) * b..(x * b + y + 1) * b];
            let mut m = 0.0f32;
            for z in 0..b {
                m += row[z] * w[z];
            }
            cj[y] += m * u[x];
        }
    }
    for x in 0..b {
        for y in 0..b {
            let row = &a[(x * b + y) * b..(x * b + y + 1) * b];
            let uv = u[x] * v[y];
            for z in 0..b {
                ck[z] += row[z] * uv;
            }
        }
    }
    (ci, cj, ck)
}

/// One JSON record of the kernel-level r-sweep.
struct KernelRow {
    b: usize,
    r: usize,
    seq_gflops: f64,
    multi_gflops: f64,
    /// Effective A-words served per second: each of the r columns logically
    /// consumes the b³ block, so multi serves r·b³ words per physical sweep.
    seq_eff_words_per_sec: f64,
    multi_eff_words_per_sec: f64,
    speedup: f64,
}

/// One JSON record of the end-to-end engine r-sweep.
struct EngineRow {
    r: usize,
    seq_blocks_per_sec: f64,
    multi_blocks_per_sec: f64,
    speedup: f64,
    words_ratio: f64,
    msgs_ratio: f64,
}

/// One JSON record of the E11 packed-vs-dense series (§Perf P7).
struct PackedRow {
    b: usize,
    r: usize,
    tensor_packed_words: usize,
    plan_words_packed: usize,
    plan_words_dense: usize,
    construct_ms_packed: f64,
    construct_ms_dense: f64,
    run_ms_packed: f64,
    run_ms_dense: f64,
    /// packed run throughput relative to dense-extract (>1 = packed faster)
    packed_over_dense: f64,
}

/// One JSON record of the E12 overlap-vs-phased series (§Perf P8).
struct OverlapRow {
    p: usize,
    b: usize,
    r: usize,
    phased_ms: f64,
    overlap_ms: f64,
    /// phased / overlap wall-clock (>1 = overlap faster)
    overlap_speedup: f64,
    phased_peak_inflight_bytes: u64,
    overlap_peak_inflight_bytes: u64,
    /// fresh payload allocations on a warmed plan (asserted 0)
    steady_fresh_allocs: u64,
}

/// One JSON record of the E14 compiled-vs-interpreted series (§Perf P10).
/// GF/s are computed from the CHARGED ternary mults (2 flops per
/// (unique entry, contribution) pair, the §7.1 accounting both paths
/// execute exactly), so the two columns are directly comparable.
struct CompiledRow {
    p: usize,
    b: usize,
    r: usize,
    interp_ms: f64,
    compiled_ms: f64,
    pool4_ms: f64,
    interp_gflops: f64,
    compiled_gflops: f64,
    pool4_gflops: f64,
    /// interpreted / compiled wall-clock (>1 = compiled faster)
    compiled_speedup: f64,
    /// compiled single-thread / 4-thread wall-clock (>1 = pool scales)
    pool_scaling: f64,
}

/// Smoke mode (STTSV_BENCH_SMOKE=1, used by CI): scale down a
/// (warmup, samples) pair so every path runs but quickly.
fn reps(warmup: usize, samples: usize) -> (usize, usize) {
    if std::env::var_os("STTSV_BENCH_SMOKE").is_some() {
        (warmup.min(1), samples.clamp(1, 3))
    } else {
        (warmup, samples)
    }
}

/// Smoke-aware wrapper around the in-tree timing harness.
fn btime<F: FnMut()>(warmup: usize, samples: usize, f: F) -> sttsv::bench::Timing {
    let (w, s) = reps(warmup, samples);
    time(w, s, f)
}

/// E12 (§Perf P8): overlapped pipeline vs phased execution at fixed
/// n = 120 over the Steiner-realizable processor counts nearest the
/// 4/8/16 sweep targets — trivial S(4,3,3) (P = 4), spherical q = 2
/// (P = 10), SQS(8) (P = 14). Wall-clock is machine-dependent; the
/// comm-cost invariance (per-processor words AND messages exactly equal
/// between modes) and the steady-state zero-allocation property are
/// asserted inline, so a passing bench run certifies both.
fn bench_e12() -> anyhow::Result<Vec<OverlapRow>> {
    header("E12: overlapped pipeline vs phased (fixed n = 120, native packed, r = 4)");
    let r = 4usize;
    let n = 120usize;
    let mut rows = Vec::new();
    let mut t = Table::new([
        "P",
        "b",
        "phased ms",
        "overlap ms",
        "overlap speedup",
        "peak inflight KiB p/o",
        "steady allocs",
    ]);
    let systems: [(&str, SteinerSystem); 3] = [
        ("S(4,3,3)", trivial(4)?),
        ("spherical q=2", spherical(2)?),
        ("SQS(8)", sqs8()),
    ];
    for (label, sys) in systems {
        let part = TetraPartition::from_steiner(&sys)?;
        assert_eq!(n % part.m, 0, "{label}: m must divide the fixed n");
        let b = n / part.m;
        let tensor = SymTensor::random(n, 120 + part.p as u64);
        let mut rng = Rng::new(121);
        let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
        // compiled: false pins this series to the packed interpreter it
        // has measured since E12 was introduced (like E10/E11); the
        // compiled executor's delta is E14's business.
        let overlap_opts = ExecOpts { compiled: false, ..Default::default() };
        let plan_overlap = SttsvPlan::new(&tensor, &part, overlap_opts)?;
        let phased_opts = ExecOpts { overlap: false, compiled: false, ..Default::default() };
        let plan_phased = SttsvPlan::new(&tensor, &part, phased_opts)?;
        // Warm both plans' pools and grab the in-flight peaks, then assert
        // comm-cost invariance and the steady-state zero-alloc property.
        let rep_o = plan_overlap.run_multi(&xs)?;
        let rep_p = plan_phased.run_multi(&xs)?;
        for p in 0..part.p {
            assert_eq!(
                rep_o.per_proc[p].stats, rep_p.per_proc[p].stats,
                "{label} proc {p}: overlap must be comm-cost invariant"
            );
        }
        let rep_o2 = plan_overlap.run_multi(&xs)?;
        assert_eq!(
            rep_o2.fresh_payload_allocs, 0,
            "{label}: warm overlap run allocated payload buffers"
        );
        let t_p = btime(1, 7, || {
            std::hint::black_box(plan_phased.run_multi(&xs).unwrap());
        });
        let t_o = btime(1, 7, || {
            std::hint::black_box(plan_overlap.run_multi(&xs).unwrap());
        });
        let row = OverlapRow {
            p: part.p,
            b,
            r,
            phased_ms: t_p.median.as_secs_f64() * 1e3,
            overlap_ms: t_o.median.as_secs_f64() * 1e3,
            overlap_speedup: t_p.median.as_secs_f64() / t_o.median.as_secs_f64(),
            phased_peak_inflight_bytes: rep_p.peak_inflight_words * 4,
            overlap_peak_inflight_bytes: rep_o.peak_inflight_words * 4,
            steady_fresh_allocs: rep_o2.fresh_payload_allocs,
        };
        t.row([
            format!("{} ({label})", part.p),
            b.to_string(),
            format!("{:.2}", row.phased_ms),
            format!("{:.2}", row.overlap_ms),
            format!("{:.2}x", row.overlap_speedup),
            format!(
                "{:.1}/{:.1}",
                row.phased_peak_inflight_bytes as f64 / 1024.0,
                row.overlap_peak_inflight_bytes as f64 / 1024.0
            ),
            row.steady_fresh_allocs.to_string(),
        ]);
        rows.push(row);
    }
    t.print();
    println!(
        "acceptance: per-proc words AND messages asserted exactly equal \
         between modes (comm-cost invariance); warm-plan payload \
         allocations asserted 0; wall-clock target is overlap <= phased at \
         P >= 8 on multi-core (machine-dependent; recorded in \
         BENCH_kernel.json)."
    );
    Ok(rows)
}

/// E14 (§Perf P10): plan-compiled branch-free sweep programs vs the PR 2
/// packed interpreter, and the 1- vs 4-thread intra-worker compute pool,
/// at fixed n = 120 across the Steiner-realizable P ∈ {4, 10, 14}. The
/// phased path is measured (deterministic; E12 already covers overlap),
/// with bitwise equality at compute_threads = 1 and exact comm/mults
/// invariance asserted inline — a passing run certifies the §Perf P10
/// acceptance alongside the numbers.
fn bench_e14() -> anyhow::Result<Vec<CompiledRow>> {
    header("E14: compiled sweep programs vs packed interpreter (fixed n = 120, phased)");
    let n = 120usize;
    let mut rows = Vec::new();
    let mut t = Table::new([
        "P",
        "b",
        "r",
        "interp ms",
        "compiled ms",
        "pool4 ms",
        "interp GF/s",
        "compiled GF/s",
        "compiled speedup",
        "pool 1->4 scaling",
    ]);
    let systems: [(&str, SteinerSystem); 3] = [
        ("S(4,3,3)", trivial(4)?),
        ("spherical q=2", spherical(2)?),
        ("SQS(8)", sqs8()),
    ];
    for (label, sys) in systems {
        let part = TetraPartition::from_steiner(&sys)?;
        assert_eq!(n % part.m, 0, "{label}: m must divide the fixed n");
        let b = n / part.m;
        let tensor = SymTensor::random(n, 140 + part.p as u64);
        let mut rng = Rng::new(141);
        let interp_opts = ExecOpts { overlap: false, compiled: false, ..Default::default() };
        let interp_plan = SttsvPlan::new(&tensor, &part, interp_opts)?;
        let compiled_opts = ExecOpts { overlap: false, ..Default::default() };
        let compiled_plan = SttsvPlan::new(&tensor, &part, compiled_opts)?;
        let pool_opts = ExecOpts { overlap: false, compute_threads: 4, ..Default::default() };
        let pool_plan = SttsvPlan::new(&tensor, &part, pool_opts)?;
        for r in [1usize, 4] {
            let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
            // Warm the pools and certify the invariants once per config.
            let ri = interp_plan.run_multi(&xs)?;
            let rc = compiled_plan.run_multi(&xs)?;
            let rp = pool_plan.run_multi(&xs)?;
            for p in 0..part.p {
                assert_eq!(
                    ri.per_proc[p].stats, rc.per_proc[p].stats,
                    "{label} r={r} proc {p}: compiled changed comm"
                );
                assert_eq!(
                    ri.per_proc[p].stats, rp.per_proc[p].stats,
                    "{label} r={r} proc {p}: pool changed comm"
                );
                assert_eq!(
                    ri.per_proc[p].ternary_mults, rc.per_proc[p].ternary_mults,
                    "{label} r={r} proc {p}: charged mults diverged"
                );
                assert_eq!(
                    ri.per_proc[p].ternary_mults, rp.per_proc[p].ternary_mults,
                    "{label} r={r} proc {p}: pool changed charged mults"
                );
            }
            for (l, col) in ri.ys.iter().enumerate() {
                for i in 0..n {
                    assert_eq!(
                        col[i].to_bits(),
                        rc.ys[l][i].to_bits(),
                        "{label} r={r} col {l} i={i}: compiled not bitwise on phased"
                    );
                }
            }
            // 2 flops (mul + add) per charged ternary contribution.
            let flops = 2.0 * ri.per_proc.iter().map(|pr| pr.ternary_mults).sum::<u64>() as f64;
            let t_i = btime(1, 7, || {
                std::hint::black_box(interp_plan.run_multi(&xs).unwrap());
            });
            let t_c = btime(1, 7, || {
                std::hint::black_box(compiled_plan.run_multi(&xs).unwrap());
            });
            let t_p = btime(1, 7, || {
                std::hint::black_box(pool_plan.run_multi(&xs).unwrap());
            });
            let row = CompiledRow {
                p: part.p,
                b,
                r,
                interp_ms: t_i.median.as_secs_f64() * 1e3,
                compiled_ms: t_c.median.as_secs_f64() * 1e3,
                pool4_ms: t_p.median.as_secs_f64() * 1e3,
                interp_gflops: gflops(flops, &t_i),
                compiled_gflops: gflops(flops, &t_c),
                pool4_gflops: gflops(flops, &t_p),
                compiled_speedup: t_i.median.as_secs_f64() / t_c.median.as_secs_f64(),
                pool_scaling: t_c.median.as_secs_f64() / t_p.median.as_secs_f64(),
            };
            t.row([
                format!("{} ({label})", part.p),
                b.to_string(),
                r.to_string(),
                format!("{:.2}", row.interp_ms),
                format!("{:.2}", row.compiled_ms),
                format!("{:.2}", row.pool4_ms),
                format!("{:.3}", row.interp_gflops),
                format!("{:.3}", row.compiled_gflops),
                format!("{:.2}x", row.compiled_speedup),
                format!("{:.2}x", row.pool_scaling),
            ]);
            rows.push(row);
        }
    }
    t.print();
    for row in &rows {
        let verdict = if row.compiled_speedup >= 1.3 { "PASS" } else { "BELOW TARGET" };
        println!(
            "acceptance (P={}, r={}): compiled = {:.2}x interpreter (target >= 1.3x \
             single-threaded): {verdict}; pool 1->4 scaling {:.2}x",
            row.p, row.r, row.compiled_speedup, row.pool_scaling
        );
    }
    println!(
        "invariants asserted inline: bitwise-equal results at compute_threads = 1 \
         (phased), per-proc words/messages/charged mults exactly equal across \
         interpreter, compiled, and pooled runs. Wall-clock is machine-dependent \
         — recorded in the JSON either way."
    );
    Ok(rows)
}

fn main() -> anyhow::Result<()> {
    // `make bench-overlap` / `make bench-compiled` run one targeted
    // series each, writing separate files so a targeted run never
    // clobbers the full sweep's BENCH_kernel.json (the tracked
    // perf-trajectory record).
    if std::env::var("STTSV_BENCH_SECTION").as_deref() == Ok("e12") {
        let overlap_rows = bench_e12()?;
        let json = render_json(&[], &[], &[], &overlap_rows, &[]);
        std::fs::write("BENCH_overlap.json", &json)?;
        println!("\nwrote BENCH_overlap.json ({} bytes; E12 section only)", json.len());
        return Ok(());
    }
    if std::env::var("STTSV_BENCH_SECTION").as_deref() == Ok("e14") {
        let compiled_rows = bench_e14()?;
        let json = render_json(&[], &[], &[], &[], &compiled_rows);
        std::fs::write("BENCH_compiled.json", &json)?;
        println!("\nwrote BENCH_compiled.json ({} bytes; E14 section only)", json.len());
        return Ok(());
    }
    header("E10: fused block-contraction kernel throughput");
    let have_pjrt = artifacts_dir().join("manifest.txt").exists();
    let pjrt = if have_pjrt {
        Some(Engine::new(Backend::Pjrt)?)
    } else {
        println!("(PJRT rows skipped: run `make artifacts`)");
        None
    };

    let mut t = Table::new(["b", "variant", "median µs", "GFLOP/s", "flops/byte(A)"]);
    for b in [4usize, 8, 16, 32] {
        let mut rng = Rng::new(b as u64);
        let a = rng.normal_vec(b * b * b);
        let (u, v, w) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        // fused kernel flops: ~3 contractions * 2 flops * b³ (+ lower order)
        let flops = 6.0 * (b as f64).powi(3);
        let intensity = flops / (b * b * b * 4) as f64;

        let tn = btime(10, 50, || {
            std::hint::black_box(block_contract_native(&a, &u, &v, &w, b));
        });
        t.row([
            b.to_string(),
            "native fused".into(),
            format!("{:.2}", tn.median.as_secs_f64() * 1e6),
            format!("{:.3}", gflops(flops, &tn)),
            format!("{intensity:.2}"),
        ]);

        let tu = btime(10, 50, || {
            std::hint::black_box(block_contract_unfused(&a, &u, &v, &w, b));
        });
        t.row([
            b.to_string(),
            "native unfused(3-pass)".into(),
            format!("{:.2}", tu.median.as_secs_f64() * 1e6),
            format!("{:.3}", gflops(flops, &tu)),
            format!("{:.2}", intensity / 3.0),
        ]);

        if let Some(eng) = &pjrt {
            if eng.has_artifact(&format!("block_b{b}")) {
                let tp = btime(3, 15, || {
                    std::hint::black_box(eng.block_contract(&a, &u, &v, &w, b).unwrap());
                });
                t.row([
                    b.to_string(),
                    "pjrt pallas(interp)".into(),
                    format!("{:.2}", tp.median.as_secs_f64() * 1e6),
                    format!("{:.3}", gflops(flops, &tp)),
                    format!("{intensity:.2}"),
                ]);
            }
        }
    }
    t.print();

    header("E10b: batched dispatch amortization (nb blocks per call)");
    let mut t2 = Table::new(["b", "nb", "variant", "median µs/block"]);
    let (b, nb) = (16usize, 4usize);
    let mut rng = Rng::new(99);
    let a = rng.normal_vec(nb * b * b * b);
    let (us, vs, ws) = (
        rng.normal_vec(nb * b),
        rng.normal_vec(nb * b),
        rng.normal_vec(nb * b),
    );
    for (label, engine) in [
        ("native", Some(Engine::new(Backend::Native)?)),
        ("pjrt", pjrt.as_ref().cloned()),
    ] {
        let Some(eng) = engine else { continue };
        let t_loop = btime(3, 15, || {
            for s in 0..nb {
                std::hint::black_box(
                    eng.block_contract(
                        &a[s * b * b * b..(s + 1) * b * b * b],
                        &us[s * b..(s + 1) * b],
                        &vs[s * b..(s + 1) * b],
                        &ws[s * b..(s + 1) * b],
                        b,
                    )
                    .unwrap(),
                );
            }
        });
        let t_batch = btime(3, 15, || {
            std::hint::black_box(eng.block_contract_batch(&a, &us, &vs, &ws, b, nb).unwrap());
        });
        t2.row([
            b.to_string(),
            nb.to_string(),
            format!("{label} loop"),
            format!("{:.2}", t_loop.median.as_secs_f64() * 1e6 / nb as f64),
        ]);
        t2.row([
            b.to_string(),
            nb.to_string(),
            format!("{label} batched"),
            format!("{:.2}", t_batch.median.as_secs_f64() * 1e6 / nb as f64),
        ]);
    }
    t2.print();

    // ---- E10c: multi-RHS kernel r-sweep (§Perf P6) ------------------------
    header("E10c: multi-RHS kernel r-sweep — one A sweep serves r columns");
    let mut kernel_rows: Vec<KernelRow> = Vec::new();
    let mut t3 = Table::new([
        "b", "r", "seq µs", "multi µs", "seq GF/s", "multi GF/s",
        "eff Mwords/s (multi)", "speedup",
    ]);
    for b in [16usize, 32] {
        for r in [1usize, 2, 4, 8, 16] {
            let mut rng = Rng::new((b * 100 + r) as u64);
            let a = rng.normal_vec(b * b * b);
            // (b, r) interleaved panels and their per-column views
            let us = rng.normal_vec(b * r);
            let vs = rng.normal_vec(b * r);
            let ws = rng.normal_vec(b * r);
            let mut cols: Vec<[Vec<f32>; 3]> = Vec::with_capacity(r);
            for l in 0..r {
                let mut u = vec![0.0f32; b];
                let mut v = vec![0.0f32; b];
                let mut w = vec![0.0f32; b];
                for x in 0..b {
                    u[x] = us[x * r + l];
                    v[x] = vs[x * r + l];
                    w[x] = ws[x * r + l];
                }
                cols.push([u, v, w]);
            }
            let flops = 6.0 * (b as f64).powi(3) * r as f64;
            let eff_words = (b * b * b) as f64 * r as f64;

            let t_seq = btime(5, 30, || {
                for [u, v, w] in &cols {
                    std::hint::black_box(block_contract_native(&a, u, v, w, b));
                }
            });
            let t_multi = btime(5, 30, || {
                std::hint::black_box(block_contract_multi(&a, &us, &vs, &ws, b, r));
            });
            let row = KernelRow {
                b,
                r,
                seq_gflops: gflops(flops, &t_seq),
                multi_gflops: gflops(flops, &t_multi),
                seq_eff_words_per_sec: eff_words / t_seq.median.as_secs_f64(),
                multi_eff_words_per_sec: eff_words / t_multi.median.as_secs_f64(),
                speedup: t_seq.median.as_secs_f64() / t_multi.median.as_secs_f64(),
            };
            t3.row([
                b.to_string(),
                r.to_string(),
                format!("{:.2}", t_seq.median.as_secs_f64() * 1e6),
                format!("{:.2}", t_multi.median.as_secs_f64() * 1e6),
                format!("{:.3}", row.seq_gflops),
                format!("{:.3}", row.multi_gflops),
                format!("{:.1}", row.multi_eff_words_per_sec / 1e6),
                format!("{:.2}x", row.speedup),
            ]);
            kernel_rows.push(row);
        }
    }
    t3.print();

    // ---- E10d: end-to-end engine r-sweep ---------------------------------
    header("E10d: SttsvPlan::run_multi vs r sequential runs (q=2, b=32, native)");
    let part = TetraPartition::from_steiner(&spherical(2)?)?;
    let bb = 32usize;
    let n = bb * part.m;
    let tensor = SymTensor::random(n, 7);
    // Pinned to the dense-resident PHASED plan so the engine_rsweep series
    // keeps measuring the same code path as prior PRs' BENCH_kernel.json;
    // the packed path is measured in E11 and the overlap pipeline in E12.
    let plan = SttsvPlan::new(
        &tensor,
        &part,
        ExecOpts { packed: false, overlap: false, ..Default::default() },
    )?;
    // total owned lower-tetra blocks across processors: m(m+1)(m+2)/6
    let total_blocks = part.m * (part.m + 1) * (part.m + 2) / 6;
    let mut rng = Rng::new(8);
    let mut engine_rows: Vec<EngineRow> = Vec::new();
    let mut t4 = Table::new([
        "r", "seq ms", "multi ms", "blk-contr/s seq", "blk-contr/s multi",
        "words multi/seq", "msgs multi/seq", "speedup",
    ]);
    for r in [1usize, 2, 4, 8, 16] {
        let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
        let t_seq = btime(1, 7, || {
            for x in &xs {
                std::hint::black_box(plan.run(x).unwrap());
            }
        });
        let t_multi = btime(1, 7, || {
            std::hint::black_box(plan.run_multi(&xs).unwrap());
        });

        // Exact comm accounting: words must be exactly r×, messages equal.
        let single = plan.run(&xs[0])?;
        let multi = plan.run_multi(&xs)?;
        for p in 0..part.p {
            let s1 = &single.per_proc[p].stats;
            let sm = &multi.per_proc[p].stats;
            assert_eq!(sm.sent_words, r as u64 * s1.sent_words, "proc {p} words");
            assert_eq!(sm.sent_msgs, s1.sent_msgs, "proc {p} msgs");
        }
        let words_ratio = multi.max_sent_words() as f64 / single.max_sent_words() as f64;
        let msgs_ratio = multi.max_sent_msgs() as f64
            / single
                .per_proc
                .iter()
                .map(|pr| pr.stats.sent_msgs)
                .max()
                .unwrap() as f64;

        let contractions = (total_blocks * r) as f64;
        let row = EngineRow {
            r,
            seq_blocks_per_sec: contractions / t_seq.median.as_secs_f64(),
            multi_blocks_per_sec: contractions / t_multi.median.as_secs_f64(),
            speedup: t_seq.median.as_secs_f64() / t_multi.median.as_secs_f64(),
            words_ratio,
            msgs_ratio,
        };
        t4.row([
            r.to_string(),
            format!("{:.2}", t_seq.median.as_secs_f64() * 1e3),
            format!("{:.2}", t_multi.median.as_secs_f64() * 1e3),
            format!("{:.0}", row.seq_blocks_per_sec),
            format!("{:.0}", row.multi_blocks_per_sec),
            format!("{words_ratio:.2}"),
            format!("{msgs_ratio:.2}"),
            format!("{:.2}x", row.speedup),
        ]);
        engine_rows.push(row);
    }
    t4.print();
    let r8 = engine_rows.iter().find(|e| e.r == 8).unwrap();
    println!(
        "acceptance (r=8): run_multi throughput = {:.2}x of 8 sequential runs \
         (target >= 3x): {}",
        r8.speedup,
        if r8.speedup >= 3.0 { "PASS" } else { "FAIL" }
    );
    println!(
        "comm at r=8: words exactly {}x, messages {}x the r=1 counts \
         (asserted exact per processor above)",
        r8.words_ratio, r8.msgs_ratio
    );

    // ---- E11: packed-view vs dense-extract execution (§Perf P7) ----------
    header("E11: zero-copy packed execution vs dense-extract (q=2, native, r=4)");
    let mut packed_rows: Vec<PackedRow> = Vec::new();
    let mut t5 = Table::new([
        "b", "tensor words", "plan words (packed)", "plan words (dense)",
        "build ms p/d", "run ms p/d", "packed/dense",
    ]);
    let r = 4usize;
    for bb in [16usize, 32] {
        let n = bb * part.m;
        let tensor = SymTensor::random(n, 70 + bb as u64);
        // compiled: false pins this series to the PR 2 packed INTERPRETER
        // it has always measured; the compiled delta is E14's business.
        let mk = |packed: bool| {
            let opts = ExecOpts { packed, compiled: false, ..Default::default() };
            SttsvPlan::new(&tensor, &part, opts).unwrap()
        };
        let t_build_p = btime(1, 7, || {
            std::hint::black_box(mk(true));
        });
        let t_build_d = btime(1, 7, || {
            std::hint::black_box(mk(false));
        });
        let plan_p = mk(true);
        let plan_d = mk(false);
        assert_eq!(plan_p.resident_tensor_words(), 0, "packed plan must be zero-copy");
        let mut rng = Rng::new(71);
        let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
        let t_run_p = btime(1, 7, || {
            std::hint::black_box(plan_p.run_multi(&xs).unwrap());
        });
        let t_run_d = btime(1, 7, || {
            std::hint::black_box(plan_d.run_multi(&xs).unwrap());
        });
        let row = PackedRow {
            b: bb,
            r,
            tensor_packed_words: tensor.packed_len(),
            plan_words_packed: plan_p.resident_tensor_words(),
            plan_words_dense: plan_d.resident_tensor_words(),
            construct_ms_packed: t_build_p.median.as_secs_f64() * 1e3,
            construct_ms_dense: t_build_d.median.as_secs_f64() * 1e3,
            run_ms_packed: t_run_p.median.as_secs_f64() * 1e3,
            run_ms_dense: t_run_d.median.as_secs_f64() * 1e3,
            packed_over_dense: t_run_d.median.as_secs_f64() / t_run_p.median.as_secs_f64(),
        };
        t5.row([
            bb.to_string(),
            row.tensor_packed_words.to_string(),
            row.plan_words_packed.to_string(),
            row.plan_words_dense.to_string(),
            format!("{:.2}/{:.2}", row.construct_ms_packed, row.construct_ms_dense),
            format!("{:.2}/{:.2}", row.run_ms_packed, row.run_ms_dense),
            format!("{:.2}x", row.packed_over_dense),
        ]);
        packed_rows.push(row);
    }
    t5.print();
    println!(
        "plan tensor memory: packed = 0 words beyond the shared SymTensor \
         buffer (asserted); dense-extract re-materializes ~the packed \
         footprint again as b³ copies."
    );

    // ---- E12: overlapped pipeline vs phased (§Perf P8) -------------------
    let overlap_rows = bench_e12()?;

    // ---- E14: compiled sweep programs vs interpreter (§Perf P10) ---------
    let compiled_rows = bench_e14()?;

    // ---- machine-readable output -----------------------------------------
    let json = render_json(&kernel_rows, &engine_rows, &packed_rows, &overlap_rows, &compiled_rows);
    std::fs::write("BENCH_kernel.json", &json)?;
    println!("\nwrote BENCH_kernel.json ({} bytes)", json.len());

    println!(
        "interpret-mode Pallas timings are CPU-only (structure check); the \
         TPU projection (VMEM footprint, MXU-shaped matmuls, 1.5 flop/B from \
         HBM, 3× reuse vs unfused, r-wide MXU RHS for the multi kernel) is \
         in DESIGN.md §Hardware-Adaptation."
    );
    Ok(())
}

/// Hand-rolled JSON (no serde is vendored): five arrays of flat records.
fn render_json(
    kernel: &[KernelRow],
    engine: &[EngineRow],
    packed: &[PackedRow],
    overlap: &[OverlapRow],
    compiled: &[CompiledRow],
) -> String {
    let mut s = String::new();
    s.push_str("{\n  \"bench\": \"kernel_throughput\",\n  \"kernel_rsweep\": [\n");
    for (idx, k) in kernel.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"b\": {}, \"r\": {}, \"seq_gflops\": {:.4}, \
             \"multi_gflops\": {:.4}, \"seq_eff_words_per_sec\": {:.1}, \
             \"multi_eff_words_per_sec\": {:.1}, \"speedup\": {:.4}}}{}\n",
            k.b,
            k.r,
            k.seq_gflops,
            k.multi_gflops,
            k.seq_eff_words_per_sec,
            k.multi_eff_words_per_sec,
            k.speedup,
            if idx + 1 < kernel.len() { "," } else { "" }
        );
    }
    s.push_str("  ],\n  \"engine_rsweep\": [\n");
    for (idx, e) in engine.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"r\": {}, \"seq_blocks_per_sec\": {:.1}, \
             \"multi_blocks_per_sec\": {:.1}, \"speedup\": {:.4}, \
             \"words_ratio\": {:.4}, \"msgs_ratio\": {:.4}}}{}\n",
            e.r,
            e.seq_blocks_per_sec,
            e.multi_blocks_per_sec,
            e.speedup,
            e.words_ratio,
            e.msgs_ratio,
            if idx + 1 < engine.len() { "," } else { "" }
        );
    }
    s.push_str("  ],\n  \"packed_vs_dense\": [\n");
    for (idx, p) in packed.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"b\": {}, \"r\": {}, \"tensor_packed_words\": {}, \
             \"plan_words_packed\": {}, \"plan_words_dense\": {}, \
             \"construct_ms_packed\": {:.4}, \"construct_ms_dense\": {:.4}, \
             \"run_ms_packed\": {:.4}, \"run_ms_dense\": {:.4}, \
             \"packed_over_dense\": {:.4}}}{}\n",
            p.b,
            p.r,
            p.tensor_packed_words,
            p.plan_words_packed,
            p.plan_words_dense,
            p.construct_ms_packed,
            p.construct_ms_dense,
            p.run_ms_packed,
            p.run_ms_dense,
            p.packed_over_dense,
            if idx + 1 < packed.len() { "," } else { "" }
        );
    }
    s.push_str("  ],\n  \"overlap_vs_phased\": [\n");
    for (idx, o) in overlap.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"p\": {}, \"b\": {}, \"r\": {}, \"phased_ms\": {:.4}, \
             \"overlap_ms\": {:.4}, \"overlap_speedup\": {:.4}, \
             \"phased_peak_inflight_bytes\": {}, \
             \"overlap_peak_inflight_bytes\": {}, \
             \"steady_fresh_allocs\": {}}}{}\n",
            o.p,
            o.b,
            o.r,
            o.phased_ms,
            o.overlap_ms,
            o.overlap_speedup,
            o.phased_peak_inflight_bytes,
            o.overlap_peak_inflight_bytes,
            o.steady_fresh_allocs,
            if idx + 1 < overlap.len() { "," } else { "" }
        );
    }
    s.push_str("  ],\n  \"compiled_vs_interpreted\": [\n");
    for (idx, c) in compiled.iter().enumerate() {
        let _ = write!(
            s,
            "    {{\"p\": {}, \"b\": {}, \"r\": {}, \"interp_ms\": {:.4}, \
             \"compiled_ms\": {:.4}, \"pool4_ms\": {:.4}, \
             \"interp_gflops\": {:.4}, \"compiled_gflops\": {:.4}, \
             \"pool4_gflops\": {:.4}, \"compiled_speedup\": {:.4}, \
             \"pool_scaling\": {:.4}}}{}\n",
            c.p,
            c.b,
            c.r,
            c.interp_ms,
            c.compiled_ms,
            c.pool4_ms,
            c.interp_gflops,
            c.compiled_gflops,
            c.pool4_gflops,
            c.compiled_speedup,
            c.pool_scaling,
            if idx + 1 < compiled.len() { "," } else { "" }
        );
    }
    s.push_str("  ]\n}\n");
    s
}
