//! Bench: regenerate the paper's Tables 1–3 and Figure 1 (DESIGN.md E1–E4),
//! with construction timings and exhaustive invariant verification.
//!
//!     cargo bench --bench paper_tables

use sttsv::bench::{header, time};
use sttsv::partition::TetraPartition;
use sttsv::schedule::CommSchedule;
use sttsv::steiner::{fixtures, spherical, sqs8};
use sttsv::util::table::{fset, ftriples, Table};

fn main() -> anyhow::Result<()> {
    // ---- E1: Table 1 — tetrahedral block partition, m = 10, P = 30 -------
    header("E1 / Table 1: Steiner (10,4,3) tetrahedral partition (q = 3, P = 30)");
    let t_build = time(1, 5, || {
        let sys = spherical(3).unwrap();
        let part = TetraPartition::from_steiner(&sys).unwrap();
        std::hint::black_box(part);
    });
    let sys = spherical(3)?;
    sys.verify()?;
    let part = TetraPartition::from_steiner(&sys)?;
    part.verify()?;
    let mut t1 = Table::new(["p", "R_p", "N_p", "D_p"]);
    for p in 0..part.p {
        let d = match part.d_p[p] {
            Some(a) => format!("{{({},{},{})}}", a + 1, a + 1, a + 1),
            None => "{}".into(),
        };
        t1.row([
            (p + 1).to_string(),
            fset(&part.r_p[p]),
            ftriples(&part.n_p[p]),
            d,
        ]);
    }
    t1.print();
    println!("rows: {} (paper: 30) — construction+assignment: {t_build}", t1.len());
    println!(
        "invariants: |R_p|=4, |N_p|=3 ∀p, {} central blocks assigned, all 220 \
         lower-tetra blocks covered exactly once: VERIFIED",
        part.d_p.iter().flatten().count()
    );
    // paper's literal Table 1 is also a valid partition of the same system
    let paper = TetraPartition::from_rows(10, &fixtures::table1())?;
    println!("paper's literal Table 1 fixture: invariants VERIFIED (P={})", paper.p);

    // ---- E2: Table 2 — row block sets Q_i --------------------------------
    header("E2 / Table 2: row block sets Q_i (|Q_i| = q(q+1) = 12)");
    let mut t2 = Table::new(["i", "Q_i"]);
    for i in 0..part.m {
        t2.row([(i + 1).to_string(), fset(&part.q_i[i])]);
    }
    t2.print();
    assert!(part.q_i.iter().all(|q| q.len() == 12));
    println!("all |Q_i| = 12: VERIFIED (paper Table 2)");
    // and the paper fixture's Q_i match its Table 2 exactly
    assert_eq!(paper.q_i, fixtures::table2());
    println!("paper fixture Q_i == paper Table 2: EXACT MATCH");

    // ---- E3: Table 3 — SQS(8) partition, m = 8, P = 14 -------------------
    header("E3 / Table 3: Steiner (8,4,3) partition (m = 8, P = 14)");
    let s8 = sqs8();
    s8.verify()?;
    let part8 = TetraPartition::from_steiner(&s8)?;
    part8.verify()?;
    let mut t3 = Table::new(["p", "R_p", "N_p", "D_p"]);
    for p in 0..part8.p {
        let d = match part8.d_p[p] {
            Some(a) => format!("{{({},{},{})}}", a + 1, a + 1, a + 1),
            None => "{}".into(),
        };
        t3.row([
            (p + 1).to_string(),
            fset(&part8.r_p[p]),
            ftriples(&part8.n_p[p]),
            d,
        ]);
    }
    t3.print();
    println!(
        "rows: {} (paper: 14); |N_p| = 4 ∀p, 8 central blocks: VERIFIED",
        t3.len()
    );
    TetraPartition::from_rows(8, &fixtures::table3())?;
    println!("paper's literal Table 3 fixture: invariants VERIFIED");

    // ---- E4: Figure 1 — the 12-step point-to-point schedule ---------------
    header("E4 / Figure 1: point-to-point schedule for the Table 3 partition");
    let t_sched = time(1, 10, || {
        let s = CommSchedule::build(&part8).unwrap();
        std::hint::black_box(s);
    });
    let sched = CommSchedule::build(&part8)?;
    sched.validate(&part8)?;
    for (si, step) in sched.steps.iter().enumerate() {
        let moves: Vec<String> = step
            .iter()
            .map(|&xi| {
                let x = &sched.xfers[xi];
                format!("{}→{}", x.from + 1, x.to + 1)
            })
            .collect();
        println!("step {:>2}: {}", si + 1, moves.join("  "));
    }
    println!(
        "steps: {} (paper Figure 1: 12, < P−1 = 13) — schedule build: {t_sched}",
        sched.num_steps()
    );
    assert_eq!(sched.num_steps(), 12);
    println!("per-step ≤1 send and ≤1 recv per processor: VERIFIED");

    // spherical step-count formula for good measure
    for q in [2usize, 3, 4] {
        let p = TetraPartition::from_steiner(&spherical(q as u64)?)?;
        let s = CommSchedule::build(&p)?;
        let formula = q * q * (q + 3) / 2 - 1;
        println!(
            "spherical q={q}: {} steps (formula q³/2+3q²/2−1 = {formula}) {}",
            s.num_steps(),
            if s.num_steps() == formula { "MATCH" } else { "MISMATCH" }
        );
        assert_eq!(s.num_steps(), formula);
    }
    Ok(())
}
