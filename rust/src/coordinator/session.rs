//! Iteration-resident solver sessions: the drivers the STTSV kernel
//! exists to serve, run *inside* the simulated machine.
//!
//! The paper's motivating algorithms — the higher-order power method
//! (Algorithm 1) and gradient-based symmetric CP (Algorithm 2) — are
//! iterative, and an optimal per-kernel distribution only pays off when
//! the surrounding iteration keeps data in the optimal layout. A
//! [`SolverSession`] therefore spawns the P workers **once per solve**:
//! each worker owns its tensor blocks *and* its portion of the iterate
//! across iterations, and loops
//!
//! ```text
//! sweep (gather → contract → reduce)      one STTSV, phased or overlapped
//! scalar collectives                      λ = x·y, ‖y‖² — one allreduce
//! normalize / update, δ                   portion-local + one allreduce
//! converge-or-continue                    unanimous, from the δ allreduce
//! ```
//!
//! entirely on the simulator. The δ allreduce doubles as the session's
//! control channel: recursive doubling is bitwise deterministic across
//! ranks ([`simulator::allreduce_sum`](crate::simulator::Comm::allreduce_sum)),
//! so every worker observes the identical global δ and takes the identical
//! branch — no host round trip, no designated root.
//!
//! On compiled plans (§Perf P10, the default) every sweep of every
//! iteration replays the plan's precompiled [`SweepProgram`]s — the
//! packed-block geometry is flattened exactly once per solve, however
//! many iterations run ([`SttsvPlan::sweep_program_builds`] stays at P;
//! regression-tested below).
//!
//! [`SweepProgram`]: crate::coordinator::SweepProgram
//!
//! **Communication invariant** (asserted on every iteration of every
//! session): per-iteration per-processor comm equals exactly one
//! r-deep STTSV ([`SttsvPlan::expected_proc_stats`]) plus the O(log P)
//! scalar-allreduce words of [`allreduce_stats`]. Host↔worker
//! full-vector traffic after the iteration-0 seeding is **zero words**:
//! the host sees the iterate again only in the final assembled result.
//! Property P9 cross-checks a k-iteration session against k independent
//! `plan.run` calls plus host arithmetic.
//!
//! **Failure & recovery** (§Rob): with a [`RecoveryPolicy`], every k-th
//! completed iteration each worker commits a portion-local checkpoint —
//! its own O(n·r/P) iterate coordinates plus the committed iteration
//! records — charged to its `CommStats` as one message. A failed run
//! (injected crash, transient-fault storm, peer timeout) is retried
//! under a [`FaultPlan::reseeded`](crate::simulator::FaultPlan::reseeded)
//! plan with capped exponential backoff, resuming every rank from the
//! newest checkpoint generation that ALL ranks committed. The
//! per-iteration δ/gnorm allreduce keeps crash skew to one iteration, so
//! two retained generations per rank always contain that consistent cut.
//! Recovery comm therefore follows the closed form `checkpoint writes +
//! one read per resume + replayed iterations`, asserted bitwise in the
//! tests below against the zero-fault oracle solve.

use super::{assemble_columns, ProcReport, SttsvPlan};
use crate::simulator::{self, allreduce_stats, lock_clean, CommStats};
use crate::tensor::linalg;
use anyhow::{bail, ensure, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// One resident power-method iteration record.
#[derive(Debug, Clone)]
pub struct PowerIter {
    /// ‖y‖ before normalization (converges to |λ|).
    pub norm: f32,
    /// Rayleigh quotient λ = x·y at the unit iterate x (computed from the
    /// distributed owned portions — never from a dense host sweep).
    pub lambda: f32,
    /// ‖x_t − x_{t−1}‖, the convergence criterion.
    pub delta: f32,
    /// Per-processor communication of THIS iteration: one STTSV plus the
    /// two scalar allreduces. Identical on every iteration of a session.
    pub comm: Vec<CommStats>,
}

/// Raw outcome of a resident power solve ([`crate::apps::power_method`]
/// wraps this in its `PowerReport`).
#[derive(Debug, Clone)]
pub struct PowerSolve {
    /// Final unit iterate, assembled from the workers' owned portions.
    pub x: Vec<f32>,
    pub iters: Vec<PowerIter>,
    /// Whole-solve per-processor totals (STTSV + collectives).
    pub per_proc: Vec<ProcReport>,
    pub steps_per_phase: usize,
    /// Simulator worker entries observed on the final (successful)
    /// attempt: P — one spawn per attempt, however many iterations ran
    /// (asserted) — or 0 for a zero-iteration solve.
    pub worker_spawns: usize,
    /// Retry-with-restart evidence (§Rob); `attempts == 1` on a clean run.
    pub recovery: RecoveryLog,
}

/// One resident CP sweep record.
#[derive(Debug, Clone)]
pub struct CpIter {
    /// ‖∇f(X)‖ over all r columns at the sweep's pre-update X.
    pub gnorm: f32,
    /// Per-processor communication of THIS sweep: one r-deep STTSV plus an
    /// r²-word and a 1-word allreduce.
    pub comm: Vec<CommStats>,
}

/// Raw outcome of a resident CP solve.
#[derive(Debug, Clone)]
pub struct CpSolve {
    /// Final factor columns after the last executed update.
    pub x_cols: Vec<Vec<f32>>,
    /// Gradient columns at the last executed sweep's pre-update X.
    pub grad_cols: Vec<Vec<f32>>,
    pub iters: Vec<CpIter>,
    pub per_proc: Vec<ProcReport>,
    pub steps_per_phase: usize,
    /// Simulator worker entries observed on the final (successful)
    /// attempt: P — one spawn per attempt (asserted) — or 0 for a
    /// zero-sweep solve.
    pub worker_spawns: usize,
    /// Retry-with-restart evidence (§Rob); `attempts == 1` on a clean run.
    pub recovery: RecoveryLog,
}

/// Per-worker output of the resident power loop.
struct PowerWorkerOut {
    stats: CommStats,
    mults: u64,
    compute: Duration,
    /// (norm, lambda, delta) per iteration — identical across ranks (all
    /// three derive from bitwise-deterministic allreduces).
    scalars: Vec<(f32, f32, f32)>,
    per_iter: Vec<CommStats>,
    portions: Vec<(usize, std::ops::Range<usize>, Vec<f32>)>,
}

/// Per-worker output of the resident CP loop.
struct CpWorkerOut {
    stats: CommStats,
    mults: u64,
    compute: Duration,
    gnorms: Vec<f32>,
    per_iter: Vec<CommStats>,
    x_portions: Vec<(usize, std::ops::Range<usize>, Vec<f32>)>,
    grad_portions: Vec<(usize, std::ops::Range<usize>, Vec<f32>)>,
}

/// All-zero per-processor reports for the degenerate zero-iteration solve.
fn zero_proc_reports(p: usize) -> Vec<ProcReport> {
    (0..p)
        .map(|_| ProcReport {
            stats: CommStats::default(),
            ternary_mults: 0,
            compute_time: Duration::ZERO,
        })
        .collect()
}

/// Checkpoint/retry policy for resident solves (§Rob). The default is
/// OFF — no checkpoints, no retries — so sessions built with
/// [`SolverSession::new`] behave exactly as they did before this layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecoveryPolicy {
    /// Commit a portion-local checkpoint every `k` completed iterations
    /// (0 = never). Each commit moves O(n·r/P) words per rank, charged to
    /// that rank's [`CommStats`] as one message.
    pub checkpoint_every: usize,
    /// Failed runs to retry (under a reseeded fault plan) before
    /// surfacing the failure to the caller.
    pub max_retries: u32,
    /// First retry delay; doubles per retry up to `backoff_cap`.
    pub backoff: Duration,
    pub backoff_cap: Duration,
}

impl Default for RecoveryPolicy {
    fn default() -> RecoveryPolicy {
        RecoveryPolicy {
            checkpoint_every: 0,
            max_retries: 0,
            backoff: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(100),
        }
    }
}

/// What the retry-with-restart loop actually did: the evidence the
/// recovery-comm closed form (`checkpoint writes + one read per resume +
/// replayed iterations`) is checked against.
#[derive(Debug, Clone, Default)]
pub struct RecoveryLog {
    /// Run attempts made; 1 = the solve succeeded without a restart, 0 =
    /// the degenerate zero-iteration solve never entered the simulator.
    pub attempts: u32,
    /// Completed-iteration count each retry resumed from (0 = restarted
    /// from the seed), in attempt order — `attempts - 1` entries.
    pub resumed_from: Vec<usize>,
    /// Rendered failure reports of the failed attempts, in attempt order.
    pub failures: Vec<String>,
}

/// One committed checkpoint generation of one rank: the owned iterate
/// coordinates plus every record needed to resume the host-visible
/// iteration history. `R` is the per-iteration scalar record — `(norm,
/// lambda, delta)` for power, `gnorm` for CP.
struct Ckpt<R> {
    /// Completed iterations at this checkpoint (a multiple of k).
    iter: usize,
    /// Owned xbuf coordinates, concatenated in `own_ranges` order.
    own: Vec<f32>,
    recs: Vec<R>,
    per_iter: Vec<CommStats>,
    mults: u64,
    compute: Duration,
    /// Cumulative comm at commit time, INCLUDING this commit's own write
    /// charge — a resume restores a counter that already paid for the
    /// checkpoint it restores from.
    stats: CommStats,
}

/// Per-rank checkpoint slots: newest generation last, at most two retained.
type CkptSlots<R> = Vec<Mutex<Vec<Ckpt<R>>>>;

/// Total owned words across a rank's interleaved ranges — the O(n·r/P)
/// checkpoint payload size.
fn owned_words(ranges: &[std::ops::Range<usize>]) -> u64 {
    ranges.iter().map(|rg| rg.len() as u64).sum()
}

/// Copy a checkpoint's concatenated owned coordinates back into `xbuf`.
fn restore_own(ranges: &[std::ops::Range<usize>], own: &[f32], xbuf: &mut [f32]) {
    let mut off = 0;
    for rg in ranges {
        xbuf[rg.clone()].copy_from_slice(&own[off..off + rg.len()]);
        off += rg.len();
    }
}

/// Commit one checkpoint generation: charge the write (own-portion words,
/// one message) and push the snapshot, retiring all but the last two
/// generations — the per-iteration allreduce keeps ranks within one
/// iteration of each other at a crash, so the consistent resume cut is
/// always among every rank's last two commits.
#[allow(clippy::too_many_arguments)]
fn commit_ckpt<R: Clone>(
    slot: &Mutex<Vec<Ckpt<R>>>,
    ranges: &[std::ops::Range<usize>],
    xbuf: &[f32],
    iter: usize,
    recs: &[R],
    per_iter: &[CommStats],
    mults: u64,
    compute: Duration,
    stats: &mut CommStats,
) {
    let words = owned_words(ranges);
    stats.sent_words += words;
    stats.sent_bytes += 4 * words; // checkpoints snapshot f32 portions
    stats.sent_msgs += 1;
    let mut own = Vec::with_capacity(words as usize);
    for rg in ranges {
        own.extend_from_slice(&xbuf[rg.clone()]);
    }
    let mut slot = lock_clean(slot);
    slot.push(Ckpt {
        iter,
        own,
        recs: recs.to_vec(),
        per_iter: per_iter.to_vec(),
        mults,
        compute,
        stats: *stats,
    });
    if slot.len() > 2 {
        slot.remove(0);
    }
}

/// The newest checkpoint generation EVERY rank committed — the only cut a
/// restart may resume from. Entries past the cut belong to the abandoned
/// attempt and are pruned here, before any worker looks.
fn consistent_cut<R>(ckpts: &[Mutex<Vec<Ckpt<R>>>]) -> usize {
    let cut = ckpts
        .iter()
        .map(|s| lock_clean(s).last().map_or(0, |c| c.iter))
        .min()
        .unwrap_or(0);
    for slot in ckpts {
        lock_clean(slot).retain(|c| c.iter <= cut);
    }
    cut
}

/// An iteration-resident solve bound to a prepared [`SttsvPlan`]: the
/// tensor distribution, schedule, and buffer pools are the plan's; the
/// session adds the driver loops that keep the *vector* distributed too.
pub struct SolverSession<'p, 't> {
    plan: &'p SttsvPlan<'t>,
    recovery: RecoveryPolicy,
}

impl<'p, 't> SolverSession<'p, 't> {
    pub fn new(plan: &'p SttsvPlan<'t>) -> SolverSession<'p, 't> {
        SolverSession { plan, recovery: RecoveryPolicy::default() }
    }

    /// Enable checkpointed retry-with-restart (§Rob) for this session's
    /// solves.
    pub fn with_recovery(mut self, policy: RecoveryPolicy) -> SolverSession<'p, 't> {
        self.recovery = policy;
        self
    }

    /// Resident higher-order power method (Algorithm 1): iterate
    /// y = A ×₂ x ×₃ x, λ = x·y, x ← y/‖y‖ until ‖Δx‖ < tol or
    /// `max_iters`, with every per-iteration quantity — λ, ‖y‖, δ —
    /// reduced from the workers' owned portions. The input `x0` is
    /// normalized host-side and seeds the workers once; after that the
    /// full vector never crosses the host boundary until the final
    /// assembly.
    pub fn power_method(&self, x0: &[f32], max_iters: usize, tol: f32) -> Result<PowerSolve> {
        let plan = self.plan;
        let part = plan.part;
        ensure!(x0.len() == plan.n, "x0 length {} != n {}", x0.len(), plan.n);
        let mut seed_vec = x0.to_vec();
        linalg::normalize(&mut seed_vec);
        if max_iters == 0 {
            // Zero iterations: nothing to solve or communicate — return
            // the normalized seed (matching the pre-session apps API).
            return Ok(PowerSolve {
                x: seed_vec,
                iters: Vec::new(),
                per_proc: zero_proc_reports(part.p),
                steps_per_phase: plan.steps_per_phase(),
                worker_spawns: 0,
                recovery: RecoveryLog::default(),
            });
        }
        let seed = seed_vec.as_slice();
        let every = self.recovery.checkpoint_every;
        let ckpts: CkptSlots<(f32, f32, f32)> =
            (0..part.p).map(|_| Mutex::new(Vec::new())).collect();
        let mut recovery = RecoveryLog::default();
        let mut backoff = self.recovery.backoff;
        let (outs, worker_spawns) = loop {
            let attempt = recovery.attempts;
            recovery.attempts += 1;
            let cut = consistent_cut(&ckpts);
            if attempt > 0 {
                recovery.resumed_from.push(cut);
            }
            let entries = AtomicUsize::new(0);
            let chaos = plan.opts.chaos.reseeded(attempt);
            let cfg = plan.run_cfg_with(1, chaos);
            let result = simulator::run_cfg(part.p, Some(&plan.pools), cfg, |comm| {
                entries.fetch_add(1, Ordering::Relaxed);
                let me = comm.rank;
                let mut st = plan.worker_state(me, 1);
                plan.arm_chaos(&mut st, me, chaos);
                let ranges = plan.own_ranges(me, 1);
                let mut scalars = Vec::new();
                let mut per_iter = Vec::new();
                let mut mults = 0u64;
                let mut compute = Duration::ZERO;
                let mut t0 = 0usize;
                if let Some(c) = lock_clean(&ckpts[me]).last() {
                    // Resume: restore the owned coordinates and the
                    // committed history, then charge the checkpoint read
                    // (own-portion words, one message — the §Rob budget).
                    t0 = c.iter;
                    restore_own(&ranges, &c.own, &mut st.xbuf);
                    scalars = c.recs.clone();
                    per_iter = c.per_iter.clone();
                    mults = c.mults;
                    compute = c.compute;
                    comm.stats = c.stats;
                    comm.stats.recv_words += owned_words(&ranges);
                    comm.stats.recv_bytes += 4 * owned_words(&ranges);
                    comm.stats.recv_msgs += 1;
                } else {
                    plan.seed_own(me, &[seed], &mut st.xbuf);
                }
                for t in t0..max_iters {
                    let before = comm.stats;
                    let (m, ct) = plan.sweep(comm, &mut st)?;
                    mults += m;
                    compute += ct;
                    // λ = x·y and ‖y‖² from the owned portions only, fused
                    // into one 2-word allreduce.
                    let (mut lam, mut nrm2) = (0.0f64, 0.0f64);
                    for rg in &ranges {
                        for idx in rg.clone() {
                            let (xv, yv) = (st.xbuf[idx] as f64, st.ybuf[idx] as f64);
                            lam += xv * yv;
                            nrm2 += yv * yv;
                        }
                    }
                    let mut s = [lam as f32, nrm2 as f32];
                    comm.allreduce_sum(&mut s)?;
                    let (lambda, norm) = (s[0], s[1].sqrt());
                    let inv = if norm > 0.0 { 1.0 / norm } else { 0.0 };
                    // Normalize portion-locally, accumulating ‖Δx‖² on the fly.
                    let mut d2 = 0.0f64;
                    for rg in &ranges {
                        for idx in rg.clone() {
                            let xn = st.ybuf[idx] * inv;
                            let d = (xn - st.xbuf[idx]) as f64;
                            d2 += d * d;
                            st.xbuf[idx] = xn;
                        }
                    }
                    // The δ allreduce is the session's control channel: every
                    // rank receives the identical bits and branches identically.
                    let delta = comm.allreduce_scalar(d2 as f32)?.sqrt();
                    scalars.push((norm, lambda, delta));
                    per_iter.push(comm.stats.since(&before));
                    // Never checkpoint a finished solve — there is nothing
                    // left to protect (so per-iteration comm stays exactly
                    // the closed form; write charges land between records).
                    let done = delta < tol || t + 1 == max_iters;
                    if !done && every > 0 && (t + 1) % every == 0 {
                        commit_ckpt(
                            &ckpts[me],
                            &ranges,
                            &st.xbuf,
                            t + 1,
                            &scalars,
                            &per_iter,
                            mults,
                            compute,
                            &mut comm.stats,
                        );
                    }
                    if delta < tol {
                        break;
                    }
                }
                let portions = plan.owned_portions(me, &st.xbuf, 1);
                Ok(PowerWorkerOut {
                    stats: comm.stats,
                    mults,
                    compute,
                    scalars,
                    per_iter,
                    portions,
                })
            });
            match result {
                Ok((outs, _metrics)) => break (outs, entries.load(Ordering::Relaxed)),
                Err(e) if attempt < self.recovery.max_retries => {
                    recovery.failures.push(format!("{e:#}"));
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(self.recovery.backoff_cap);
                }
                Err(e) => return Err(e),
            }
        };

        ensure!(
            worker_spawns == part.p,
            "resident session must spawn each worker exactly once per solve"
        );
        let k = outs[0].scalars.len();
        for (p, o) in outs.iter().enumerate() {
            ensure!(
                o.scalars.len() == k && o.per_iter.len() == k,
                "worker {p} ran {} iterations, worker 0 ran {k} — the \
                 convergence decision was not unanimous",
                o.scalars.len()
            );
        }
        // The acceptance invariant: every iteration of every processor
        // moved exactly one phased-STTSV's words plus the collective
        // closed form — nothing else (in particular, no per-iteration
        // host gather/broadcast exists to move).
        let expected_sttsv = plan.expected_proc_stats(1);
        let mut iters = Vec::with_capacity(k);
        for t in 0..k {
            let comm: Vec<CommStats> = outs.iter().map(|o| o.per_iter[t]).collect();
            for (p, c) in comm.iter().enumerate() {
                let mut want = expected_sttsv[p];
                want.absorb(&allreduce_stats(part.p, p, 2));
                want.absorb(&allreduce_stats(part.p, p, 1));
                ensure!(
                    *c == want,
                    "iteration {t} proc {p}: comm {c:?} != one STTSV + \
                     O(log P) collectives {want:?}"
                );
            }
            let (norm, lambda, delta) = outs[0].scalars[t];
            iters.push(PowerIter { norm, lambda, delta, comm });
        }
        let per_proc: Vec<ProcReport> = outs
            .iter()
            .map(|o| ProcReport {
                stats: o.stats,
                ternary_mults: o.mults,
                compute_time: o.compute,
            })
            .collect();
        let portions = outs.into_iter().map(|o| o.portions).collect();
        let mut cols = assemble_columns(plan.n, plan.b, 1, portions)?;
        let x = match cols.pop() {
            Some(col) => col,
            // Unreachable by construction (assemble_columns returns r = 1
            // columns) but a chaos-path worker error must never become a
            // panic in the session loop — propagate typed instead.
            None => bail!("assembly returned no result column for r = 1"),
        };
        Ok(PowerSolve {
            x,
            iters,
            per_proc,
            steps_per_phase: plan.steps_per_phase(),
            worker_spawns,
            recovery,
        })
    }

    /// Resident multi-sweep symmetric CP driver (Algorithm 2 iterated):
    /// each sweep computes Y = A ×₂ x_ℓ ×₃ x_ℓ for all r columns as ONE
    /// batched STTSV, reduces the Gram matrix XᵀX by an r²-word allreduce
    /// (then squares it elementwise: G = (XᵀX) ∗ (XᵀX)), forms the
    /// gradient ∇_ℓ = X·G[:,ℓ] − y_ℓ portion-locally, and takes the step
    /// X ← X − η·∇. Stops when ‖∇‖ < tol (a 1-word allreduce — the
    /// session's control channel) or after `max_sweeps`. With
    /// `max_sweeps = 1, step = 0` this is exactly Algorithm 2: one
    /// distributed gradient evaluation.
    pub fn cp_sweeps(
        &self,
        x0_cols: &[Vec<f32>],
        max_sweeps: usize,
        step: f32,
        tol: f32,
    ) -> Result<CpSolve> {
        let plan = self.plan;
        let part = plan.part;
        let r = x0_cols.len();
        ensure!(r >= 1, "cp_sweeps needs at least one factor column");
        for (l, x) in x0_cols.iter().enumerate() {
            ensure!(x.len() == plan.n, "x0[{l}] length {} != n {}", x.len(), plan.n);
        }
        if max_sweeps == 0 {
            // Zero sweeps: the factor matrix is untouched and no gradient
            // was evaluated.
            return Ok(CpSolve {
                x_cols: x0_cols.to_vec(),
                grad_cols: Vec::new(),
                iters: Vec::new(),
                per_proc: zero_proc_reports(part.p),
                steps_per_phase: plan.steps_per_phase(),
                worker_spawns: 0,
                recovery: RecoveryLog::default(),
            });
        }
        let views: Vec<&[f32]> = x0_cols.iter().map(|x| x.as_slice()).collect();
        let every = self.recovery.checkpoint_every;
        let ckpts: CkptSlots<f32> = (0..part.p).map(|_| Mutex::new(Vec::new())).collect();
        let mut recovery = RecoveryLog::default();
        let mut backoff = self.recovery.backoff;
        let (outs, worker_spawns) = loop {
            let attempt = recovery.attempts;
            recovery.attempts += 1;
            let cut = consistent_cut(&ckpts);
            if attempt > 0 {
                recovery.resumed_from.push(cut);
            }
            let entries = AtomicUsize::new(0);
            let chaos = plan.opts.chaos.reseeded(attempt);
            let cfg = plan.run_cfg_with(r, chaos);
            let result = simulator::run_cfg(part.p, Some(&plan.pools), cfg, |comm| {
                entries.fetch_add(1, Ordering::Relaxed);
                let me = comm.rank;
                let mut st = plan.worker_state(me, r);
                plan.arm_chaos(&mut st, me, chaos);
                let ranges = plan.own_ranges(me, r);
                let mut gbuf = vec![0.0f32; st.xbuf.len()];
                let mut tmp = vec![0.0f32; r];
                let mut gnorms = Vec::new();
                let mut per_iter = Vec::new();
                let mut mults = 0u64;
                let mut compute = Duration::ZERO;
                let mut t0 = 0usize;
                if let Some(c) = lock_clean(&ckpts[me]).last() {
                    // Resume from the consistent cut. `gbuf` is NOT part of
                    // the checkpoint: a checkpoint is never the final sweep,
                    // so at least one post-resume sweep refills the gradient
                    // before `grad_portions` is read.
                    t0 = c.iter;
                    restore_own(&ranges, &c.own, &mut st.xbuf);
                    gnorms = c.recs.clone();
                    per_iter = c.per_iter.clone();
                    mults = c.mults;
                    compute = c.compute;
                    comm.stats = c.stats;
                    comm.stats.recv_words += owned_words(&ranges);
                    comm.stats.recv_bytes += 4 * owned_words(&ranges);
                    comm.stats.recv_msgs += 1;
                } else {
                    plan.seed_own(me, &views, &mut st.xbuf);
                }
                for t in t0..max_sweeps {
                    let before = comm.stats;
                    // One r-deep batched STTSV: ybuf[·, ℓ] = A ×₂ x_ℓ ×₃ x_ℓ.
                    let (m, ct) = plan.sweep(comm, &mut st)?;
                    mults += m;
                    compute += ct;
                    // Gram partials from owned coordinates, one r² allreduce,
                    // then the elementwise square: G = (XᵀX) ∗ (XᵀX).
                    let mut gram64 = vec![0.0f64; r * r];
                    for rg in &ranges {
                        let mut base = rg.start;
                        while base < rg.end {
                            for a in 0..r {
                                let xa = st.xbuf[base + a] as f64;
                                for l in 0..r {
                                    gram64[a * r + l] += xa * st.xbuf[base + l] as f64;
                                }
                            }
                            base += r;
                        }
                    }
                    let mut gram: Vec<f32> = gram64.iter().map(|&v| v as f32).collect();
                    comm.allreduce_sum(&mut gram)?;
                    for v in gram.iter_mut() {
                        *v *= *v;
                    }
                    // ∇_ℓ = Σ_a x_a·G[a][ℓ] − y_ℓ and the step, portion-local.
                    let mut gn2 = 0.0f64;
                    for rg in &ranges {
                        let mut base = rg.start;
                        while base < rg.end {
                            for (l, dst) in tmp.iter_mut().enumerate() {
                                let mut v = 0.0f32;
                                for a in 0..r {
                                    v += st.xbuf[base + a] * gram[a * r + l];
                                }
                                *dst = v - st.ybuf[base + l];
                            }
                            for (l, &g) in tmp.iter().enumerate() {
                                gbuf[base + l] = g;
                                gn2 += (g as f64) * (g as f64);
                                st.xbuf[base + l] -= step * g;
                            }
                            base += r;
                        }
                    }
                    let gnorm = comm.allreduce_scalar(gn2 as f32)?.sqrt();
                    gnorms.push(gnorm);
                    per_iter.push(comm.stats.since(&before));
                    // As in the power loop: a finished solve is never
                    // checkpointed, and write charges land between the
                    // per-sweep records.
                    let done = gnorm < tol || t + 1 == max_sweeps;
                    if !done && every > 0 && (t + 1) % every == 0 {
                        commit_ckpt(
                            &ckpts[me],
                            &ranges,
                            &st.xbuf,
                            t + 1,
                            &gnorms,
                            &per_iter,
                            mults,
                            compute,
                            &mut comm.stats,
                        );
                    }
                    if gnorm < tol {
                        break;
                    }
                }
                let x_portions = plan.owned_portions(me, &st.xbuf, r);
                let grad_portions = plan.owned_portions(me, &gbuf, r);
                Ok(CpWorkerOut {
                    stats: comm.stats,
                    mults,
                    compute,
                    gnorms,
                    per_iter,
                    x_portions,
                    grad_portions,
                })
            });
            match result {
                Ok((outs, _metrics)) => break (outs, entries.load(Ordering::Relaxed)),
                Err(e) if attempt < self.recovery.max_retries => {
                    recovery.failures.push(format!("{e:#}"));
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(self.recovery.backoff_cap);
                }
                Err(e) => return Err(e),
            }
        };

        ensure!(
            worker_spawns == part.p,
            "resident session must spawn each worker exactly once per solve"
        );
        let k = outs[0].gnorms.len();
        for (p, o) in outs.iter().enumerate() {
            ensure!(
                o.gnorms.len() == k && o.per_iter.len() == k,
                "worker {p} ran {} sweeps, worker 0 ran {k} — the \
                 convergence decision was not unanimous",
                o.gnorms.len()
            );
        }
        let expected_sttsv = plan.expected_proc_stats(r);
        let mut iters = Vec::with_capacity(k);
        for t in 0..k {
            let comm: Vec<CommStats> = outs.iter().map(|o| o.per_iter[t]).collect();
            for (p, c) in comm.iter().enumerate() {
                let mut want = expected_sttsv[p];
                want.absorb(&allreduce_stats(part.p, p, r * r));
                want.absorb(&allreduce_stats(part.p, p, 1));
                ensure!(
                    *c == want,
                    "sweep {t} proc {p}: comm {c:?} != one r-deep STTSV + \
                     O(log P) collectives {want:?}"
                );
            }
            iters.push(CpIter { gnorm: outs[0].gnorms[t], comm });
        }
        let per_proc: Vec<ProcReport> = outs
            .iter()
            .map(|o| ProcReport {
                stats: o.stats,
                ternary_mults: o.mults,
                compute_time: o.compute,
            })
            .collect();
        let mut x_all = Vec::with_capacity(part.p);
        let mut g_all = Vec::with_capacity(part.p);
        for o in outs {
            x_all.push(o.x_portions);
            g_all.push(o.grad_portions);
        }
        let x_cols = assemble_columns(plan.n, plan.b, r, x_all)?;
        let grad_cols = assemble_columns(plan.n, plan.b, r, g_all)?;
        Ok(CpSolve {
            x_cols,
            grad_cols,
            iters,
            per_proc,
            steps_per_phase: plan.steps_per_phase(),
            worker_spawns,
            recovery,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CommMode, ExecOpts};
    use crate::partition::TetraPartition;
    use crate::simulator::{FailureReport, FaultPlan, SttsvError};
    use crate::steiner::spherical;
    use crate::tensor::SymTensor;
    use crate::util::rng::Rng;

    #[test]
    fn resident_power_method_converges_and_comm_is_iteration_invariant() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 6usize;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[5.0, 2.0, 1.0], 61);
        let mut rng = Rng::new(62);
        let mut x0 = cols[0].clone();
        for v in x0.iter_mut() {
            *v += 0.2 * rng.normal_f32();
        }
        let plan = SttsvPlan::new(&tensor, &part, ExecOpts::default()).unwrap();
        let solve = SolverSession::new(&plan).power_method(&x0, 60, 1e-6).unwrap();
        assert_eq!(solve.worker_spawns, part.p);
        let last = solve.iters.last().unwrap();
        assert!((last.lambda - 5.0).abs() < 1e-2, "lambda={}", last.lambda);
        assert!(last.delta < 1e-6);
        let align = crate::tensor::linalg::dot(&solve.x, &cols[0]).abs();
        assert!(align > 0.999, "alignment={align}");
        // every iteration's per-proc comm is identical (the session already
        // asserted it equals STTSV + collectives exactly).
        for it in &solve.iters {
            assert_eq!(it.comm, solve.iters[0].comm);
        }
    }

    #[test]
    fn resident_power_method_runs_in_alltoall_mode_too() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 5usize;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[4.0, 1.0], 63);
        let mut rng = Rng::new(64);
        let mut x0 = cols[0].clone();
        for v in x0.iter_mut() {
            *v += 0.2 * rng.normal_f32();
        }
        let plan = SttsvPlan::new(
            &tensor,
            &part,
            ExecOpts { mode: CommMode::AllToAll, ..Default::default() },
        )
        .unwrap();
        let solve = SolverSession::new(&plan).power_method(&x0, 40, 1e-6).unwrap();
        assert!((solve.iters.last().unwrap().lambda - 4.0).abs() < 2e-2);
    }

    #[test]
    fn resident_session_reuses_one_compiled_program() {
        // Build-count instrumentation (mirroring the §Perf P9 dense-oracle
        // counter): a compiled plan flattens each worker's geometry ONCE;
        // k resident iterations — power and CP, phased and overlap — must
        // replay those P programs without ever rebuilding.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 4usize;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[4.0, 1.5], 71);
        let mut rng = Rng::new(72);
        let mut x0 = cols[0].clone();
        for v in x0.iter_mut() {
            *v += 0.2 * rng.normal_f32();
        }
        for overlap in [false, true] {
            let opts = ExecOpts { overlap, ..Default::default() };
            let plan = SttsvPlan::new(&tensor, &part, opts).unwrap();
            assert_eq!(plan.sweep_program_builds(), part.p as u64);
            let solve = SolverSession::new(&plan).power_method(&x0, 6, 0.0).unwrap();
            assert_eq!(solve.iters.len(), 6);
            assert_eq!(
                plan.sweep_program_builds(),
                part.p as u64,
                "overlap={overlap}: power sweeps rebuilt programs"
            );
            let x0_cols: Vec<Vec<f32>> = (0..2)
                .map(|_| rng.normal_vec(n).iter().map(|v| 0.3 * v).collect())
                .collect();
            let solve = SolverSession::new(&plan).cp_sweeps(&x0_cols, 4, 0.01, 0.0).unwrap();
            assert_eq!(solve.iters.len(), 4);
            assert_eq!(
                plan.sweep_program_builds(),
                part.p as u64,
                "overlap={overlap}: CP sweeps rebuilt programs"
            );
        }
    }

    #[test]
    fn resident_cp_sweeps_reduce_the_gradient_norm() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 3usize;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[3.0, 1.5], 65);
        let mut rng = Rng::new(66);
        // start near the planted factors so plain gradient descent descends
        let x0: Vec<Vec<f32>> = cols
            .iter()
            .take(2)
            .zip([3.0f32, 1.5])
            .map(|(c, lam)| {
                let s = lam.cbrt();
                c.iter().map(|v| s * v + 0.05 * rng.normal_f32()).collect()
            })
            .collect();
        let plan = SttsvPlan::new(&tensor, &part, ExecOpts::default()).unwrap();
        let solve = SolverSession::new(&plan).cp_sweeps(&x0, 25, 0.05, 0.0).unwrap();
        assert_eq!(solve.worker_spawns, part.p);
        let first = solve.iters.first().unwrap().gnorm;
        let last = solve.iters.last().unwrap().gnorm;
        assert!(
            last < 0.5 * first,
            "gradient norm did not descend: {first} -> {last}"
        );
    }

    #[test]
    fn power_recovery_replays_from_checkpoints_with_closed_form_comm() {
        // §Rob acceptance: a solve under an injected rank crash recovers
        // bitwise to the zero-fault oracle, and its committed comm totals
        // equal the oracle's plus EXACTLY the checkpoint writes and one
        // checkpoint read per nonzero resume — the `checkpoint + replayed
        // iterations` closed form. crash_at is swept because the op index
        // of a given iteration is schedule-dependent; every value must
        // recover bitwise, and at least one must resume from a checkpoint
        // (rather than restarting from the seed).
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 5usize;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[5.0, 2.0, 1.0], 91);
        let mut rng = Rng::new(92);
        let mut x0 = cols[0].clone();
        for v in x0.iter_mut() {
            *v += 0.2 * rng.normal_f32();
        }
        let iters = 10usize;
        let plan0 = SttsvPlan::new(&tensor, &part, ExecOpts::default()).unwrap();
        let oracle = SolverSession::new(&plan0).power_method(&x0, iters, 0.0).unwrap();
        assert_eq!(oracle.recovery.attempts, 1);
        assert!(oracle.recovery.failures.is_empty());
        let policy = RecoveryPolicy {
            checkpoint_every: 1,
            max_retries: 3,
            backoff: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(4),
        };
        let mut exercised_restore = false;
        for crash_at in [20u64, 60, 140] {
            let opts =
                ExecOpts { chaos: FaultPlan::crash(9, 1, crash_at), ..Default::default() };
            let plan = SttsvPlan::new(&tensor, &part, opts).unwrap();
            let solve = SolverSession::new(&plan)
                .with_recovery(policy)
                .power_method(&x0, iters, 0.0)
                .unwrap();
            if crash_at < 80 {
                // 10 iterations × (≥ 8 collective ops each) guarantee the
                // crash fires mid-solve for these op indices.
                assert!(solve.recovery.attempts >= 2, "crash_at={crash_at} never fired");
            }
            assert_eq!(
                solve.recovery.failures.len() as u32,
                solve.recovery.attempts - 1
            );
            // Replaying the deterministic phased schedule from a consistent
            // checkpoint cut is bitwise.
            assert_eq!(solve.x, oracle.x, "crash_at={crash_at}");
            assert_eq!(solve.iters.len(), oracle.iters.len());
            for (a, o) in solve.iters.iter().zip(&oracle.iters) {
                assert_eq!(
                    (a.norm, a.lambda, a.delta),
                    (o.norm, o.lambda, o.delta),
                    "crash_at={crash_at}"
                );
            }
            // Closed-form recovery comm. Per-iteration comm was already
            // asserted unchanged inside the session; totals add one write
            // per committed generation (1..iters-1 at k=1 — the chain
            // property makes this attempt-count invariant) plus one read
            // per resume that found a checkpoint.
            let writes = (iters - 1) as u64;
            let reads = solve.recovery.resumed_from.iter().filter(|&&c| c > 0).count() as u64;
            for (p, proc_) in solve.per_proc.iter().enumerate() {
                let words: u64 =
                    plan.own_ranges(p, 1).iter().map(|rg| rg.len() as u64).sum();
                let mut want = oracle.per_proc[p].stats;
                want.sent_words += writes * words;
                want.sent_bytes += 4 * writes * words;
                want.sent_msgs += writes;
                want.recv_words += reads * words;
                want.recv_bytes += 4 * reads * words;
                want.recv_msgs += reads;
                assert_eq!(
                    proc_.stats, want,
                    "crash_at={crash_at} proc {p}: recovery comm != \
                     checkpoint+replay closed form"
                );
            }
            if solve.recovery.resumed_from.iter().any(|&c| c > 0) {
                exercised_restore = true;
            }
        }
        assert!(exercised_restore, "no crash_at value resumed from a checkpoint");
    }

    #[test]
    fn cp_recovery_matches_the_zero_fault_oracle_bitwise() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 3usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 93);
        let mut rng = Rng::new(94);
        let x0: Vec<Vec<f32>> = (0..2)
            .map(|_| rng.normal_vec(n).iter().map(|v| 0.3 * v).collect())
            .collect();
        let sweeps = 6usize;
        let plan0 = SttsvPlan::new(&tensor, &part, ExecOpts::default()).unwrap();
        let oracle = SolverSession::new(&plan0).cp_sweeps(&x0, sweeps, 0.02, 0.0).unwrap();
        let policy = RecoveryPolicy {
            checkpoint_every: 2,
            max_retries: 3,
            backoff: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(2),
        };
        let opts = ExecOpts { chaos: FaultPlan::crash(17, 0, 30), ..Default::default() };
        let plan = SttsvPlan::new(&tensor, &part, opts).unwrap();
        let solve = SolverSession::new(&plan)
            .with_recovery(policy)
            .cp_sweeps(&x0, sweeps, 0.02, 0.0)
            .unwrap();
        assert!(solve.recovery.attempts >= 2, "the injected crash never fired");
        // Bitwise: the restart replays the same deterministic sweeps, and
        // the post-resume sweep refills the gradient buffer before it is
        // assembled (gbuf is deliberately not checkpointed).
        assert_eq!(solve.x_cols, oracle.x_cols);
        assert_eq!(solve.grad_cols, oracle.grad_cols);
        assert_eq!(solve.iters.len(), oracle.iters.len());
        for (a, o) in solve.iters.iter().zip(&oracle.iters) {
            assert_eq!(a.gnorm, o.gnorm);
        }
    }

    #[test]
    fn exhausted_retries_surface_a_typed_failure_report() {
        // Recovery OFF (the default session): the injected crash must
        // surface as a structured FailureReport naming the dead rank, not
        // as a hang or a stringly error.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 3usize;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[4.0, 1.0], 95);
        let x0 = cols[0].clone();
        let opts = ExecOpts { chaos: FaultPlan::crash(5, 0, 10), ..Default::default() };
        let plan = SttsvPlan::new(&tensor, &part, opts).unwrap();
        let err = SolverSession::new(&plan)
            .power_method(&x0, 5, 0.0)
            .expect_err("the crash must fail the unprotected solve");
        let report = err
            .downcast_ref::<FailureReport>()
            .expect("session failures carry a FailureReport");
        assert_eq!(report.failed_rank, 0);
        assert!(
            matches!(report.kind, Some(SttsvError::Crashed { rank: 0, .. })),
            "root cause should be the injected crash, got {:?}",
            report.kind
        );
    }

    #[test]
    fn resident_session_odd_r_exercises_dyn_fallback_on_both_modes() {
        // r = 3 and r = 5 have no register tile (tiles: r ∈ {1, 2, 4, 8}),
        // so compiled sweeps take the dynamic-width lane-helper fallback.
        // One cp_sweep with step = 0 is exactly Algorithm 2 — a single
        // distributed gradient evaluation — checked against host
        // arithmetic end to end, in both comm modes. (The session itself
        // asserts per-iteration comm == one r-deep STTSV + collectives.)
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 4usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 81);
        let mut rng = Rng::new(82);
        for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
            for r in [3usize, 5] {
                let x: Vec<Vec<f32>> = (0..r)
                    .map(|_| rng.normal_vec(n).iter().map(|v| 0.3 * v).collect())
                    .collect();
                let plan = SttsvPlan::new(
                    &tensor,
                    &part,
                    ExecOpts { mode, ..Default::default() },
                )
                .unwrap();
                let solve =
                    SolverSession::new(&plan).cp_sweeps(&x, 1, 0.0, 0.0).unwrap();
                assert_eq!(solve.iters.len(), 1, "{mode:?} r={r}");
                // Host replica of the gradient: ∇_ℓ = X·G[:,ℓ] − y_ℓ with
                // G = (XᵀX) ∗ (XᵀX) and y_ℓ the sequential oracle.
                let mut gram = vec![0.0f32; r * r];
                for a in 0..r {
                    for l in 0..r {
                        let d = crate::tensor::linalg::dot(&x[a], &x[l]);
                        gram[a * r + l] = d * d;
                    }
                }
                for l in 0..r {
                    let y = tensor.sttsv(&x[l]);
                    for i in 0..n {
                        let mut v = 0.0f32;
                        for a in 0..r {
                            v += x[a][i] * gram[a * r + l];
                        }
                        let want = v - y[i];
                        let got = solve.grad_cols[l][i];
                        assert!(
                            (got - want).abs() < 1e-3 * want.abs().max(1.0),
                            "{mode:?} r={r} grad[{l}][{i}]: {got} vs host {want}"
                        );
                    }
                }
            }
        }
    }
}
