//! Iteration-resident solver sessions: the drivers the STTSV kernel
//! exists to serve, run *inside* the simulated machine.
//!
//! The paper's motivating algorithms — the higher-order power method
//! (Algorithm 1) and gradient-based symmetric CP (Algorithm 2) — are
//! iterative, and an optimal per-kernel distribution only pays off when
//! the surrounding iteration keeps data in the optimal layout. A
//! [`SolverSession`] therefore spawns the P workers **once per solve**:
//! each worker owns its tensor blocks *and* its portion of the iterate
//! across iterations, and loops
//!
//! ```text
//! sweep (gather → contract → reduce)      one STTSV, phased or overlapped
//! scalar collectives                      λ = x·y, ‖y‖² — one allreduce
//! normalize / update, δ                   portion-local + one allreduce
//! converge-or-continue                    unanimous, from the δ allreduce
//! ```
//!
//! entirely on the simulator. The δ allreduce doubles as the session's
//! control channel: recursive doubling is bitwise deterministic across
//! ranks ([`simulator::allreduce_sum`](crate::simulator::Comm::allreduce_sum)),
//! so every worker observes the identical global δ and takes the identical
//! branch — no host round trip, no designated root.
//!
//! On compiled plans (§Perf P10, the default) every sweep of every
//! iteration replays the plan's precompiled [`SweepProgram`]s — the
//! packed-block geometry is flattened exactly once per solve, however
//! many iterations run ([`SttsvPlan::sweep_program_builds`] stays at P;
//! regression-tested below).
//!
//! [`SweepProgram`]: crate::coordinator::SweepProgram
//!
//! **Communication invariant** (asserted on every iteration of every
//! session): per-iteration per-processor comm equals exactly one
//! r-deep STTSV ([`SttsvPlan::expected_proc_stats`]) plus the O(log P)
//! scalar-allreduce words of [`allreduce_stats`]. Host↔worker
//! full-vector traffic after the iteration-0 seeding is **zero words**:
//! the host sees the iterate again only in the final assembled result.
//! Property P9 cross-checks a k-iteration session against k independent
//! `plan.run` calls plus host arithmetic.

use super::{assemble_columns, ProcReport, SttsvPlan};
use crate::simulator::{self, allreduce_stats, CommStats};
use crate::tensor::linalg;
use anyhow::{ensure, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

/// One resident power-method iteration record.
#[derive(Debug, Clone)]
pub struct PowerIter {
    /// ‖y‖ before normalization (converges to |λ|).
    pub norm: f32,
    /// Rayleigh quotient λ = x·y at the unit iterate x (computed from the
    /// distributed owned portions — never from a dense host sweep).
    pub lambda: f32,
    /// ‖x_t − x_{t−1}‖, the convergence criterion.
    pub delta: f32,
    /// Per-processor communication of THIS iteration: one STTSV plus the
    /// two scalar allreduces. Identical on every iteration of a session.
    pub comm: Vec<CommStats>,
}

/// Raw outcome of a resident power solve ([`crate::apps::power_method`]
/// wraps this in its `PowerReport`).
#[derive(Debug, Clone)]
pub struct PowerSolve {
    /// Final unit iterate, assembled from the workers' owned portions.
    pub x: Vec<f32>,
    pub iters: Vec<PowerIter>,
    /// Whole-solve per-processor totals (STTSV + collectives).
    pub per_proc: Vec<ProcReport>,
    pub steps_per_phase: usize,
    /// Simulator worker entries observed: P — one spawn per solve, however
    /// many iterations ran (asserted) — or 0 for a zero-iteration solve.
    pub worker_spawns: usize,
}

/// One resident CP sweep record.
#[derive(Debug, Clone)]
pub struct CpIter {
    /// ‖∇f(X)‖ over all r columns at the sweep's pre-update X.
    pub gnorm: f32,
    /// Per-processor communication of THIS sweep: one r-deep STTSV plus an
    /// r²-word and a 1-word allreduce.
    pub comm: Vec<CommStats>,
}

/// Raw outcome of a resident CP solve.
#[derive(Debug, Clone)]
pub struct CpSolve {
    /// Final factor columns after the last executed update.
    pub x_cols: Vec<Vec<f32>>,
    /// Gradient columns at the last executed sweep's pre-update X.
    pub grad_cols: Vec<Vec<f32>>,
    pub iters: Vec<CpIter>,
    pub per_proc: Vec<ProcReport>,
    pub steps_per_phase: usize,
    /// Simulator worker entries observed: P — one spawn per solve
    /// (asserted) — or 0 for a zero-sweep solve.
    pub worker_spawns: usize,
}

/// Per-worker output of the resident power loop.
struct PowerWorkerOut {
    stats: CommStats,
    mults: u64,
    compute: Duration,
    /// (norm, lambda, delta) per iteration — identical across ranks (all
    /// three derive from bitwise-deterministic allreduces).
    scalars: Vec<(f32, f32, f32)>,
    per_iter: Vec<CommStats>,
    portions: Vec<(usize, std::ops::Range<usize>, Vec<f32>)>,
}

/// Per-worker output of the resident CP loop.
struct CpWorkerOut {
    stats: CommStats,
    mults: u64,
    compute: Duration,
    gnorms: Vec<f32>,
    per_iter: Vec<CommStats>,
    x_portions: Vec<(usize, std::ops::Range<usize>, Vec<f32>)>,
    grad_portions: Vec<(usize, std::ops::Range<usize>, Vec<f32>)>,
}

/// All-zero per-processor reports for the degenerate zero-iteration solve.
fn zero_proc_reports(p: usize) -> Vec<ProcReport> {
    (0..p)
        .map(|_| ProcReport {
            stats: CommStats::default(),
            ternary_mults: 0,
            compute_time: Duration::ZERO,
        })
        .collect()
}

/// An iteration-resident solve bound to a prepared [`SttsvPlan`]: the
/// tensor distribution, schedule, and buffer pools are the plan's; the
/// session adds the driver loops that keep the *vector* distributed too.
pub struct SolverSession<'p, 't> {
    plan: &'p SttsvPlan<'t>,
}

impl<'p, 't> SolverSession<'p, 't> {
    pub fn new(plan: &'p SttsvPlan<'t>) -> SolverSession<'p, 't> {
        SolverSession { plan }
    }

    /// Resident higher-order power method (Algorithm 1): iterate
    /// y = A ×₂ x ×₃ x, λ = x·y, x ← y/‖y‖ until ‖Δx‖ < tol or
    /// `max_iters`, with every per-iteration quantity — λ, ‖y‖, δ —
    /// reduced from the workers' owned portions. The input `x0` is
    /// normalized host-side and seeds the workers once; after that the
    /// full vector never crosses the host boundary until the final
    /// assembly.
    pub fn power_method(&self, x0: &[f32], max_iters: usize, tol: f32) -> Result<PowerSolve> {
        let plan = self.plan;
        let part = plan.part;
        ensure!(x0.len() == plan.n, "x0 length {} != n {}", x0.len(), plan.n);
        let mut seed_vec = x0.to_vec();
        linalg::normalize(&mut seed_vec);
        if max_iters == 0 {
            // Zero iterations: nothing to solve or communicate — return
            // the normalized seed (matching the pre-session apps API).
            return Ok(PowerSolve {
                x: seed_vec,
                iters: Vec::new(),
                per_proc: zero_proc_reports(part.p),
                steps_per_phase: plan.steps_per_phase(),
                worker_spawns: 0,
            });
        }
        let seed = seed_vec.as_slice();
        let entries = AtomicUsize::new(0);

        let cfg = plan.run_cfg(1);
        let (outs, _metrics) = simulator::run_cfg(part.p, Some(&plan.pools), cfg, |comm| {
            entries.fetch_add(1, Ordering::Relaxed);
            let me = comm.rank;
            let mut st = plan.worker_state(me, 1);
            plan.seed_own(me, &[seed], &mut st.xbuf);
            let ranges = plan.own_ranges(me, 1);
            let mut scalars = Vec::new();
            let mut per_iter = Vec::new();
            let mut mults = 0u64;
            let mut compute = Duration::ZERO;
            for _ in 0..max_iters {
                let before = comm.stats;
                let (m, ct) = plan.sweep(comm, &mut st)?;
                mults += m;
                compute += ct;
                // λ = x·y and ‖y‖² from the owned portions only, fused
                // into one 2-word allreduce.
                let (mut lam, mut nrm2) = (0.0f64, 0.0f64);
                for rg in &ranges {
                    for idx in rg.clone() {
                        let (xv, yv) = (st.xbuf[idx] as f64, st.ybuf[idx] as f64);
                        lam += xv * yv;
                        nrm2 += yv * yv;
                    }
                }
                let mut s = [lam as f32, nrm2 as f32];
                comm.allreduce_sum(&mut s)?;
                let (lambda, norm) = (s[0], s[1].sqrt());
                let inv = if norm > 0.0 { 1.0 / norm } else { 0.0 };
                // Normalize portion-locally, accumulating ‖Δx‖² on the fly.
                let mut d2 = 0.0f64;
                for rg in &ranges {
                    for idx in rg.clone() {
                        let xn = st.ybuf[idx] * inv;
                        let d = (xn - st.xbuf[idx]) as f64;
                        d2 += d * d;
                        st.xbuf[idx] = xn;
                    }
                }
                // The δ allreduce is the session's control channel: every
                // rank receives the identical bits and branches identically.
                let delta = comm.allreduce_scalar(d2 as f32)?.sqrt();
                scalars.push((norm, lambda, delta));
                per_iter.push(comm.stats.since(&before));
                if delta < tol {
                    break;
                }
            }
            let portions = plan.owned_portions(me, &st.xbuf, 1);
            Ok(PowerWorkerOut {
                stats: comm.stats,
                mults,
                compute,
                scalars,
                per_iter,
                portions,
            })
        })?;

        let worker_spawns = entries.load(Ordering::Relaxed);
        ensure!(
            worker_spawns == part.p,
            "resident session must spawn each worker exactly once per solve"
        );
        let k = outs[0].scalars.len();
        for (p, o) in outs.iter().enumerate() {
            ensure!(
                o.scalars.len() == k && o.per_iter.len() == k,
                "worker {p} ran {} iterations, worker 0 ran {k} — the \
                 convergence decision was not unanimous",
                o.scalars.len()
            );
        }
        // The acceptance invariant: every iteration of every processor
        // moved exactly one phased-STTSV's words plus the collective
        // closed form — nothing else (in particular, no per-iteration
        // host gather/broadcast exists to move).
        let expected_sttsv = plan.expected_proc_stats(1);
        let mut iters = Vec::with_capacity(k);
        for t in 0..k {
            let comm: Vec<CommStats> = outs.iter().map(|o| o.per_iter[t]).collect();
            for (p, c) in comm.iter().enumerate() {
                let mut want = expected_sttsv[p];
                want.absorb(&allreduce_stats(part.p, p, 2));
                want.absorb(&allreduce_stats(part.p, p, 1));
                ensure!(
                    *c == want,
                    "iteration {t} proc {p}: comm {c:?} != one STTSV + \
                     O(log P) collectives {want:?}"
                );
            }
            let (norm, lambda, delta) = outs[0].scalars[t];
            iters.push(PowerIter { norm, lambda, delta, comm });
        }
        let per_proc: Vec<ProcReport> = outs
            .iter()
            .map(|o| ProcReport {
                stats: o.stats,
                ternary_mults: o.mults,
                compute_time: o.compute,
            })
            .collect();
        let portions = outs.into_iter().map(|o| o.portions).collect();
        let mut cols = assemble_columns(plan.n, plan.b, 1, portions)?;
        let x = cols.pop().expect("one result column");
        Ok(PowerSolve {
            x,
            iters,
            per_proc,
            steps_per_phase: plan.steps_per_phase(),
            worker_spawns,
        })
    }

    /// Resident multi-sweep symmetric CP driver (Algorithm 2 iterated):
    /// each sweep computes Y = A ×₂ x_ℓ ×₃ x_ℓ for all r columns as ONE
    /// batched STTSV, reduces the Gram matrix XᵀX by an r²-word allreduce
    /// (then squares it elementwise: G = (XᵀX) ∗ (XᵀX)), forms the
    /// gradient ∇_ℓ = X·G[:,ℓ] − y_ℓ portion-locally, and takes the step
    /// X ← X − η·∇. Stops when ‖∇‖ < tol (a 1-word allreduce — the
    /// session's control channel) or after `max_sweeps`. With
    /// `max_sweeps = 1, step = 0` this is exactly Algorithm 2: one
    /// distributed gradient evaluation.
    pub fn cp_sweeps(
        &self,
        x0_cols: &[Vec<f32>],
        max_sweeps: usize,
        step: f32,
        tol: f32,
    ) -> Result<CpSolve> {
        let plan = self.plan;
        let part = plan.part;
        let r = x0_cols.len();
        ensure!(r >= 1, "cp_sweeps needs at least one factor column");
        for (l, x) in x0_cols.iter().enumerate() {
            ensure!(x.len() == plan.n, "x0[{l}] length {} != n {}", x.len(), plan.n);
        }
        if max_sweeps == 0 {
            // Zero sweeps: the factor matrix is untouched and no gradient
            // was evaluated.
            return Ok(CpSolve {
                x_cols: x0_cols.to_vec(),
                grad_cols: Vec::new(),
                iters: Vec::new(),
                per_proc: zero_proc_reports(part.p),
                steps_per_phase: plan.steps_per_phase(),
                worker_spawns: 0,
            });
        }
        let views: Vec<&[f32]> = x0_cols.iter().map(|x| x.as_slice()).collect();
        let entries = AtomicUsize::new(0);

        let cfg = plan.run_cfg(r);
        let (outs, _metrics) = simulator::run_cfg(part.p, Some(&plan.pools), cfg, |comm| {
            entries.fetch_add(1, Ordering::Relaxed);
            let me = comm.rank;
            let mut st = plan.worker_state(me, r);
            plan.seed_own(me, &views, &mut st.xbuf);
            let ranges = plan.own_ranges(me, r);
            let mut gbuf = vec![0.0f32; st.xbuf.len()];
            let mut tmp = vec![0.0f32; r];
            let mut gnorms = Vec::new();
            let mut per_iter = Vec::new();
            let mut mults = 0u64;
            let mut compute = Duration::ZERO;
            for _ in 0..max_sweeps {
                let before = comm.stats;
                // One r-deep batched STTSV: ybuf[·, ℓ] = A ×₂ x_ℓ ×₃ x_ℓ.
                let (m, ct) = plan.sweep(comm, &mut st)?;
                mults += m;
                compute += ct;
                // Gram partials from owned coordinates, one r² allreduce,
                // then the elementwise square: G = (XᵀX) ∗ (XᵀX).
                let mut gram64 = vec![0.0f64; r * r];
                for rg in &ranges {
                    let mut base = rg.start;
                    while base < rg.end {
                        for a in 0..r {
                            let xa = st.xbuf[base + a] as f64;
                            for l in 0..r {
                                gram64[a * r + l] += xa * st.xbuf[base + l] as f64;
                            }
                        }
                        base += r;
                    }
                }
                let mut gram: Vec<f32> = gram64.iter().map(|&v| v as f32).collect();
                comm.allreduce_sum(&mut gram)?;
                for v in gram.iter_mut() {
                    *v *= *v;
                }
                // ∇_ℓ = Σ_a x_a·G[a][ℓ] − y_ℓ and the step, portion-local.
                let mut gn2 = 0.0f64;
                for rg in &ranges {
                    let mut base = rg.start;
                    while base < rg.end {
                        for (l, t) in tmp.iter_mut().enumerate() {
                            let mut v = 0.0f32;
                            for a in 0..r {
                                v += st.xbuf[base + a] * gram[a * r + l];
                            }
                            *t = v - st.ybuf[base + l];
                        }
                        for (l, &g) in tmp.iter().enumerate() {
                            gbuf[base + l] = g;
                            gn2 += (g as f64) * (g as f64);
                            st.xbuf[base + l] -= step * g;
                        }
                        base += r;
                    }
                }
                let gnorm = comm.allreduce_scalar(gn2 as f32)?.sqrt();
                gnorms.push(gnorm);
                per_iter.push(comm.stats.since(&before));
                if gnorm < tol {
                    break;
                }
            }
            let x_portions = plan.owned_portions(me, &st.xbuf, r);
            let grad_portions = plan.owned_portions(me, &gbuf, r);
            Ok(CpWorkerOut {
                stats: comm.stats,
                mults,
                compute,
                gnorms,
                per_iter,
                x_portions,
                grad_portions,
            })
        })?;

        let worker_spawns = entries.load(Ordering::Relaxed);
        ensure!(
            worker_spawns == part.p,
            "resident session must spawn each worker exactly once per solve"
        );
        let k = outs[0].gnorms.len();
        for (p, o) in outs.iter().enumerate() {
            ensure!(
                o.gnorms.len() == k && o.per_iter.len() == k,
                "worker {p} ran {} sweeps, worker 0 ran {k} — the \
                 convergence decision was not unanimous",
                o.gnorms.len()
            );
        }
        let expected_sttsv = plan.expected_proc_stats(r);
        let mut iters = Vec::with_capacity(k);
        for t in 0..k {
            let comm: Vec<CommStats> = outs.iter().map(|o| o.per_iter[t]).collect();
            for (p, c) in comm.iter().enumerate() {
                let mut want = expected_sttsv[p];
                want.absorb(&allreduce_stats(part.p, p, r * r));
                want.absorb(&allreduce_stats(part.p, p, 1));
                ensure!(
                    *c == want,
                    "sweep {t} proc {p}: comm {c:?} != one r-deep STTSV + \
                     O(log P) collectives {want:?}"
                );
            }
            iters.push(CpIter { gnorm: outs[0].gnorms[t], comm });
        }
        let per_proc: Vec<ProcReport> = outs
            .iter()
            .map(|o| ProcReport {
                stats: o.stats,
                ternary_mults: o.mults,
                compute_time: o.compute,
            })
            .collect();
        let mut x_all = Vec::with_capacity(part.p);
        let mut g_all = Vec::with_capacity(part.p);
        for o in outs {
            x_all.push(o.x_portions);
            g_all.push(o.grad_portions);
        }
        let x_cols = assemble_columns(plan.n, plan.b, r, x_all)?;
        let grad_cols = assemble_columns(plan.n, plan.b, r, g_all)?;
        Ok(CpSolve {
            x_cols,
            grad_cols,
            iters,
            per_proc,
            steps_per_phase: plan.steps_per_phase(),
            worker_spawns,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CommMode, ExecOpts};
    use crate::partition::TetraPartition;
    use crate::steiner::spherical;
    use crate::tensor::SymTensor;
    use crate::util::rng::Rng;

    #[test]
    fn resident_power_method_converges_and_comm_is_iteration_invariant() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 6usize;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[5.0, 2.0, 1.0], 61);
        let mut rng = Rng::new(62);
        let mut x0 = cols[0].clone();
        for v in x0.iter_mut() {
            *v += 0.2 * rng.normal_f32();
        }
        let plan = SttsvPlan::new(&tensor, &part, ExecOpts::default()).unwrap();
        let solve = SolverSession::new(&plan).power_method(&x0, 60, 1e-6).unwrap();
        assert_eq!(solve.worker_spawns, part.p);
        let last = solve.iters.last().unwrap();
        assert!((last.lambda - 5.0).abs() < 1e-2, "lambda={}", last.lambda);
        assert!(last.delta < 1e-6);
        let align = crate::tensor::linalg::dot(&solve.x, &cols[0]).abs();
        assert!(align > 0.999, "alignment={align}");
        // every iteration's per-proc comm is identical (the session already
        // asserted it equals STTSV + collectives exactly).
        for it in &solve.iters {
            assert_eq!(it.comm, solve.iters[0].comm);
        }
    }

    #[test]
    fn resident_power_method_runs_in_alltoall_mode_too() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 5usize;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[4.0, 1.0], 63);
        let mut rng = Rng::new(64);
        let mut x0 = cols[0].clone();
        for v in x0.iter_mut() {
            *v += 0.2 * rng.normal_f32();
        }
        let plan = SttsvPlan::new(
            &tensor,
            &part,
            ExecOpts { mode: CommMode::AllToAll, ..Default::default() },
        )
        .unwrap();
        let solve = SolverSession::new(&plan).power_method(&x0, 40, 1e-6).unwrap();
        assert!((solve.iters.last().unwrap().lambda - 4.0).abs() < 2e-2);
    }

    #[test]
    fn resident_session_reuses_one_compiled_program() {
        // Build-count instrumentation (mirroring the §Perf P9 dense-oracle
        // counter): a compiled plan flattens each worker's geometry ONCE;
        // k resident iterations — power and CP, phased and overlap — must
        // replay those P programs without ever rebuilding.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 4usize;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[4.0, 1.5], 71);
        let mut rng = Rng::new(72);
        let mut x0 = cols[0].clone();
        for v in x0.iter_mut() {
            *v += 0.2 * rng.normal_f32();
        }
        for overlap in [false, true] {
            let opts = ExecOpts { overlap, ..Default::default() };
            let plan = SttsvPlan::new(&tensor, &part, opts).unwrap();
            assert_eq!(plan.sweep_program_builds(), part.p as u64);
            let solve = SolverSession::new(&plan).power_method(&x0, 6, 0.0).unwrap();
            assert_eq!(solve.iters.len(), 6);
            assert_eq!(
                plan.sweep_program_builds(),
                part.p as u64,
                "overlap={overlap}: power sweeps rebuilt programs"
            );
            let x0_cols: Vec<Vec<f32>> = (0..2)
                .map(|_| rng.normal_vec(n).iter().map(|v| 0.3 * v).collect())
                .collect();
            let solve = SolverSession::new(&plan).cp_sweeps(&x0_cols, 4, 0.01, 0.0).unwrap();
            assert_eq!(solve.iters.len(), 4);
            assert_eq!(
                plan.sweep_program_builds(),
                part.p as u64,
                "overlap={overlap}: CP sweeps rebuilt programs"
            );
        }
    }

    #[test]
    fn resident_cp_sweeps_reduce_the_gradient_norm() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 3usize;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[3.0, 1.5], 65);
        let mut rng = Rng::new(66);
        // start near the planted factors so plain gradient descent descends
        let x0: Vec<Vec<f32>> = cols
            .iter()
            .take(2)
            .zip([3.0f32, 1.5])
            .map(|(c, lam)| {
                let s = lam.cbrt();
                c.iter().map(|v| s * v + 0.05 * rng.normal_f32()).collect()
            })
            .collect();
        let plan = SttsvPlan::new(&tensor, &part, ExecOpts::default()).unwrap();
        let solve = SolverSession::new(&plan).cp_sweeps(&x0, 25, 0.05, 0.0).unwrap();
        assert_eq!(solve.worker_spawns, part.p);
        let first = solve.iters.first().unwrap().gnorm;
        let last = solve.iters.last().unwrap().gnorm;
        assert!(
            last < 0.5 * first,
            "gradient norm did not descend: {first} -> {last}"
        );
    }

    #[test]
    fn resident_session_odd_r_exercises_dyn_fallback_on_both_modes() {
        // r = 3 and r = 5 have no register tile (tiles: r ∈ {1, 2, 4, 8}),
        // so compiled sweeps take the dynamic-width lane-helper fallback.
        // One cp_sweep with step = 0 is exactly Algorithm 2 — a single
        // distributed gradient evaluation — checked against host
        // arithmetic end to end, in both comm modes. (The session itself
        // asserts per-iteration comm == one r-deep STTSV + collectives.)
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 4usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 81);
        let mut rng = Rng::new(82);
        for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
            for r in [3usize, 5] {
                let x: Vec<Vec<f32>> = (0..r)
                    .map(|_| rng.normal_vec(n).iter().map(|v| 0.3 * v).collect())
                    .collect();
                let plan = SttsvPlan::new(
                    &tensor,
                    &part,
                    ExecOpts { mode, ..Default::default() },
                )
                .unwrap();
                let solve =
                    SolverSession::new(&plan).cp_sweeps(&x, 1, 0.0, 0.0).unwrap();
                assert_eq!(solve.iters.len(), 1, "{mode:?} r={r}");
                // Host replica of the gradient: ∇_ℓ = X·G[:,ℓ] − y_ℓ with
                // G = (XᵀX) ∗ (XᵀX) and y_ℓ the sequential oracle.
                let mut gram = vec![0.0f32; r * r];
                for a in 0..r {
                    for l in 0..r {
                        let d = crate::tensor::linalg::dot(&x[a], &x[l]);
                        gram[a * r + l] = d * d;
                    }
                }
                for l in 0..r {
                    let y = tensor.sttsv(&x[l]);
                    for i in 0..n {
                        let mut v = 0.0f32;
                        for a in 0..r {
                            v += x[a][i] * gram[a * r + l];
                        }
                        let want = v - y[i];
                        let got = solve.grad_cols[l][i];
                        assert!(
                            (got - want).abs() < 1e-3 * want.abs().max(1.0),
                            "{mode:?} r={r} grad[{l}][{i}]: {got} vs host {want}"
                        );
                    }
                }
            }
        }
    }
}
