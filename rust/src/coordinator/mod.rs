//! The L3 coordinator: Algorithm 5 (parallel STTSV) end to end, on the
//! instrumented simulator, with local block computations dispatched to the
//! runtime engine (AOT Pallas kernels via PJRT, or native loops).
//!
//! Phases (paper Algorithm 5):
//!   1. gather x  — each processor collects the full row blocks x[i],
//!      i ∈ R_p, from the other processors of Q_i (lines 3–14);
//!   2. local ternary multiplications over owned tensor blocks via the
//!      fused block kernel (lines 15–28);
//!   3. scatter-reduce y — partial results for row block i are exchanged
//!      and summed so each processor ends with its y[i]^(p) (lines 29–41).
//!
//! Both vector phases run either over the Theorem 6 point-to-point schedule
//! (comm cost = the lower bound's leading term, exactly) or as All-to-All
//! collectives (2× the leading term — §7.2.2).
//!
//! **Multi-RHS batching** ([`SttsvPlan::run_multi`]): the same two vector
//! phases and the same schedule serve an r-column right-hand-side batch
//! `Y = A ×₂ X ×₃ X` (column-wise) by packing every message r words deep
//! per coordinate. Communication words scale as exactly r× the r = 1
//! counts while the *message* counts (the α·S latency term) are unchanged,
//! and each owned tensor block is swept once for all r columns — the
//! amortization that makes the symmetric CP gradient / MTTKRP workload
//! (Algorithm 2, §8) r× cheaper per column than r independent STTSVs.
//! [`SttsvPlan::run`] is the r = 1 special case.
//!
//! **Packed-view execution** ([`ExecOpts::packed`], the default; §Perf P7):
//! workers contract *in place* against the shared packed `SymTensor` buffer
//! through zero-copy [`PackedBlockView`]s, so the plan stores block
//! coordinates + offsets instead of dense b³ copies — resident tensor
//! memory is exactly the n(n+1)(n+2)/6 unique words the paper counts, plan
//! construction is O(m³) view computations instead of O(n³) copies, and
//! the symmetry-aware diagonal kernels execute exactly the §7.1 ternary
//! multiplication counts. Dense-extract mode (`packed: false`) keeps the
//! previous behavior and the resident layout AOT artifacts consume.
//!
//! **Overlapped pipeline execution** ([`ExecOpts::overlap`], the default;
//! §Perf P8): the three barriered phases collapse into one event loop per
//! worker. Every phase-1 gather message leaves up front over the
//! nonblocking, buffer-reusing simulator API ([`Comm::isend`] /
//! [`Comm::recv_into`]); blocks are contracted the moment their three
//! row-block panels are complete (dependency counters precomputed in the
//! plan, so locally-complete blocks start before any message lands); and
//! each phase-3 reduce message streams out the moment the destination
//! portions it carries absorb their last local contribution. The α-β-γ
//! model cost is **invariant** — per-processor words and messages are
//! exactly those of the phased path (property P8 asserts equality) — only
//! idle time is removed. The phased path (`--no-overlap`) remains as the
//! deterministic oracle.
//!
//! **Iteration-resident solver sessions** ([`session::SolverSession`]):
//! both worker bodies are factored into per-iteration *sweeps* over
//! portion-local [`WorkerState`] panels, so iterative drivers (power
//! method, CP sweeps) keep the vector distributed across iterations —
//! workers are spawned once per solve, scalar reductions travel as
//! recursive-doubling allreduces, and `run`/`run_multi` are the thin
//! one-iteration sessions (seed → one sweep → collect), preserving the
//! oracle path bit for bit.

pub mod baselines;
pub mod session;

use crate::partition::{
    block_ternary_mults, checksum_weights, classify, factors, BlockKind, TetraPartition,
};
use crate::runtime::{
    exec_block_runs, lanes_add, lanes_axpy, panel_col_sums, Backend, Engine, RunDesc,
};
use crate::schedule::CommSchedule;
use crate::simulator::{
    self, AbftMode, BufPool, Comm, CommStats, FaultPlan, MemChaos, RunCfg, SttsvError, TagClass,
    TransportKind, WireFormat,
};
use crate::tensor::{PackedBlockView, Precision, SymTensor};
use anyhow::{bail, ensure, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// How vector data moves between processors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommMode {
    /// Theorem 6 point-to-point schedule: comm matches the lower bound's
    /// leading term exactly.
    PointToPoint,
    /// All-to-All collectives (§7.2.2): simpler, 2× the leading term.
    AllToAll,
}

impl std::str::FromStr for CommMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "p2p" | "point-to-point" => Ok(CommMode::PointToPoint),
            "a2a" | "all-to-all" => Ok(CommMode::AllToAll),
            other => bail!("unknown comm mode '{other}' (use p2p|a2a)"),
        }
    }
}

/// Execution options for [`run_sttsv_opts`].
///
/// ## Flag interactions ([`ExecOpts::normalize`])
///
/// The flags are not fully independent; [`SttsvPlan::new`] normalizes its
/// options through this single table instead of each path re-deriving the
/// rules ad hoc:
///
/// | flags                          | effect                              |
/// |--------------------------------|-------------------------------------|
/// | `overlap` (any backend)        | per-block dispatch; `batch` ignored |
/// | `Pjrt` + `packed`              | per-dispatch extraction, 0 resident |
/// | `compiled` + (`Pjrt` or dense) | `compiled` cleared (programs replay |
/// |                                | the packed Native kernels only)     |
/// | `compute_threads` w/o compiled | clamped to 1 (the pool splits       |
/// |                                | compiled descriptor streams)        |
/// | `compute_threads = 0`          | clamped to 1                        |
/// | `wire = bf16` + `precision f64`| `precision` forced to `F32` (the    |
/// |                                | wire wins: a 2-byte wire under f64  |
/// |                                | elements would be neither the f64   |
/// |                                | conditioning study nor the bf16     |
/// |                                | bandwidth point)                    |
/// | `abft` w/o `compiled`          | `abft` cleared (scrub replays the   |
/// |                                | compiled run-descriptor stream)     |
/// | `abft` (verify or scrub)       | `overlap` off, `compute_threads` 1  |
/// |                                | (block verification runs on the     |
/// |                                | bitwise-deterministic phased path)  |
///
/// Post-conditions are debug-asserted in `normalize`; downgrades (e.g.
/// requesting `compiled` on PJRT) are silent, matching how `batch` has
/// always been ignored under `overlap`.
///
/// `PartialEq`/`Eq`/`Hash` are field-wise and therefore meaningful as a
/// cache key only on **normalized** options: two raw option sets that
/// normalize identically (say `compiled: true` on PJRT vs `compiled:
/// false`) compare unequal until passed through [`ExecOpts::normalize`].
/// The serving layer's plan cache ([`crate::serve::PlanCache`]) normalizes
/// before keying, so logically identical opts can never miss.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ExecOpts {
    pub mode: CommMode,
    pub backend: Backend,
    /// Batch all owned blocks of a type into one kernel dispatch (the L3
    /// hot-path optimization; see EXPERIMENTS.md §Perf). Moot on the
    /// packed Native path, whose "batch" is a per-block kernel loop — the
    /// worker reads x panels straight from its gather buffer there instead
    /// of concatenating per-group copies.
    pub batch: bool,
    /// Contract in place against the shared packed `SymTensor` buffer
    /// (zero-copy; §Perf P7): the plan stores only O(1) block views, and
    /// the native kernels sweep the packed rows directly — resident tensor
    /// memory is the n(n+1)(n+2)/6 buffer the paper counts, and executed
    /// ternary multiplications match the §7.1 accounting exactly. When
    /// false, the plan extracts a dense b³ copy of every owned block at
    /// construction (the pre-P7 behavior, and the layout AOT artifacts
    /// consume resident). On the PJRT backend the packed path extracts the
    /// active group on the fly per dispatch instead.
    pub packed: bool,
    /// Overlapped pipeline execution (§Perf P8, the default): fire every
    /// phase-1 send up front over the nonblocking buffer-reusing comm API,
    /// contract blocks as their panels complete, and stream each phase-3
    /// reduce message as soon as its destination portions are final.
    /// Per-processor words and messages are exactly the phased path's (the
    /// model cost is invariant; asserted by property P8) and the
    /// steady-state hot path performs zero per-message payload
    /// allocations. Implies per-block dispatch (`batch` is ignored).
    /// `overlap: false` (CLI `--no-overlap`) keeps the stepped
    /// gather → compute → reduce path — the bitwise-deterministic oracle;
    /// overlap accumulates phase-3 partials in arrival order, so its
    /// results are reproducible only up to f32 summation order.
    pub overlap: bool,
    /// Compile each worker's packed-block geometry into a branch-free
    /// [`SweepProgram`] at plan build (§Perf P10, the default on the
    /// packed Native path): the per-row `tet/tri` offset arithmetic and
    /// the α≥β≥γ multiplicity branching are resolved once, and sweeps
    /// replay the descriptor stream through register-tiled multi-RHS
    /// microkernels — bitwise identical to the interpreted kernels at
    /// `compute_threads = 1`. Requires `packed` + the Native backend
    /// (cleared by [`ExecOpts::normalize`] otherwise); `--no-compiled`
    /// keeps the per-sweep interpreter.
    pub compiled: bool,
    /// Intra-worker compute pool width (CLI `--compute-threads N`): split
    /// a worker's compiled descriptor stream across N scoped threads with
    /// privatized output panels and a deterministic ordered reduction.
    /// Communication counters and charged ternary mults are invariant;
    /// results leave the bitwise oracle only through the reduction's
    /// f32 regrouping (deterministic for a fixed N on the phased path).
    /// Default 1 — every oracle stays bit-for-bit. Requires `compiled`
    /// (clamped to 1 otherwise).
    pub compute_threads: usize,
    /// Message-passing transport under the simulated processors (CLI
    /// `--backend spsc|mpsc`, orthogonal to the compute `backend`):
    /// [`TransportKind::Mpsc`] is the deterministic counting oracle,
    /// [`TransportKind::Spsc`] the lock-free shared-memory rings whose
    /// wall-clock E15 benchmarks (`make bench-hw`). Per-processor words,
    /// messages, and charged mults are identical on either (property P11);
    /// the plan sizes the spsc ring slots from its known message widths
    /// ([`SttsvPlan::max_message_words`]) so sends write in place.
    pub transport: TransportKind,
    /// Pin worker thread r to CPU r mod cores (CLI `--pin`; spsc runs
    /// only). Off by default — pinning helps dedicated benchmark boxes and
    /// hurts oversubscribed CI runners.
    pub pin_threads: bool,
    /// Seeded fault-injection plan (§Rob, CLI `--chaos seed,rate`). The
    /// default (all-zero) plan runs the plain transport with no wrapper;
    /// any other plan wraps it in the chaos decorator. Retry loops do NOT
    /// bake the per-attempt reseed into the opts — they pass
    /// [`FaultPlan::reseeded`] plans through
    /// [`SttsvPlan::run_multi_with`], so one plan (and one cache entry)
    /// serves every attempt.
    pub chaos: FaultPlan,
    /// Watchdog for blocking receives (CLI `--recv-timeout-ms`): a rank
    /// blocked longer than this surfaces a typed timeout instead of
    /// waiting forever behind a stuck-but-alive peer. `None` = no
    /// watchdog (peer death still unwinds the run via the abort
    /// protocol and the fail-fast liveness check).
    pub recv_timeout: Option<Duration>,
    /// Physical wire encoding of sweep payloads (§Perf P14, CLI
    /// `--wire f32|bf16`): [`WireFormat::Bf16`] packs gather/reduce
    /// panels to bfloat16 on the wire (accumulation stays f32), exactly
    /// halving measured payload bytes while per-proc words and messages
    /// stay the closed-form counts. Collectives always travel f32.
    pub wire: WireFormat,
    /// Element type for the *sequential* conditioning-study paths (CLI
    /// `--precision f32|f64`): [`Precision::F64`] routes host-side HOPM
    /// (`apps::power_method_f64`) through the f64-generic packed tensor +
    /// run-kernels. The distributed plan always computes in f32 — f64 is
    /// the accuracy reference the f32/bf16 runs are compared against.
    /// Forced to `F32` under a bf16 wire (see the table above).
    pub precision: Precision,
    /// Algorithm-based fault tolerance (§Rob P15, CLI `--abft
    /// off|verify|scrub`). When on, the plan derives per-owned-block
    /// checksum matrices `C_b` and the global mode-1 contraction `C` at
    /// build (the allreduce charged to [`SttsvPlan::abft_build_stats`]),
    /// every sweep payload carries one Fletcher-32 integrity word checked
    /// in `recv_into`, and each worker verifies every block contribution
    /// against `xᵀC_b x` after contraction — a detected mismatch surfaces
    /// as a typed [`SttsvError::Corrupt`] (`verify`) or triggers a
    /// recompute of just that block's run-descriptor stream (`scrub`).
    /// Requires the compiled packed Native path; forces the phased
    /// single-threaded sweep so the recompute is bitwise-deterministic
    /// (see the table above).
    pub abft: AbftMode,
}

impl Default for ExecOpts {
    fn default() -> Self {
        ExecOpts {
            mode: CommMode::PointToPoint,
            backend: Backend::Native,
            batch: true,
            packed: true,
            overlap: true,
            compiled: true,
            compute_threads: 1,
            transport: TransportKind::Mpsc,
            pin_threads: false,
            chaos: FaultPlan::default(),
            recv_timeout: None,
            wire: WireFormat::F32,
            precision: Precision::F32,
            abft: AbftMode::Off,
        }
    }
}

impl ExecOpts {
    /// Defaults appropriate for a backend: zero-copy packed execution and
    /// the overlapped pipeline on Native; resident dense-extract and the
    /// phased path on PJRT — its AOT artifacts are shaped for the batched
    /// per-kind dispatch (the packed fallback would re-extract every block
    /// per dispatch, and the overlap worker's per-block dispatch would
    /// forfeit that batching). `--overlap` still forces the pipeline on
    /// PJRT explicitly.
    pub fn for_backend(backend: Backend) -> ExecOpts {
        ExecOpts {
            backend,
            packed: backend == Backend::Native,
            overlap: backend == Backend::Native,
            compiled: backend == Backend::Native,
            ..Default::default()
        }
    }

    /// Canonicalize flag interactions (the table in the struct docs):
    /// `compiled` requires the packed Native path, the compute pool
    /// requires `compiled`, and `compute_threads` is at least 1.
    /// [`SttsvPlan::new`] normalizes its options through here so every
    /// execution path reads one consistent rule set.
    pub fn normalize(mut self) -> ExecOpts {
        if self.compute_threads == 0 {
            self.compute_threads = 1;
        }
        if self.backend != Backend::Native || !self.packed {
            // Sweep programs replay the packed Native kernels; PJRT and
            // dense-extract plans keep their interpreted dispatch.
            self.compiled = false;
        }
        if !self.compiled {
            // The pool splits compiled descriptor streams; without a
            // program there is nothing to split.
            self.compute_threads = 1;
        }
        if self.wire == WireFormat::Bf16 {
            // The wire wins: bf16 payloads carry 8 mantissa bits, so an
            // f64 element type underneath would measure neither the f64
            // conditioning reference nor the bf16 bandwidth point.
            self.precision = Precision::F32;
        }
        if self.abft.on() {
            if self.compiled {
                // Per-block verification (and the scrub recompute) runs on
                // the sequential compiled phased path — the only executor
                // whose per-block recompute is bitwise-deterministic.
                self.overlap = false;
                self.compute_threads = 1;
            } else {
                // No descriptor stream to verify or scrub against
                // (PJRT / dense-extract / interpreter plans).
                self.abft = AbftMode::Off;
            }
        }
        debug_assert!(self.compute_threads >= 1);
        debug_assert!(!self.compiled || (self.packed && self.backend == Backend::Native));
        debug_assert!(self.wire != WireFormat::Bf16 || self.precision == Precision::F32);
        debug_assert!(!self.abft.on() || (self.compiled && !self.overlap));
        self
    }
}

/// Per-processor execution report.
#[derive(Debug, Clone)]
pub struct ProcReport {
    pub stats: CommStats,
    /// Logical ternary multiplications (paper §7.1 accounting), summed over
    /// all right-hand-side columns of the run.
    pub ternary_mults: u64,
    pub compute_time: Duration,
}

/// Whole-run report for a single right-hand side.
#[derive(Debug, Clone)]
pub struct SttsvReport {
    /// The assembled result y = A ×₂ x ×₃ x.
    pub y: Vec<f32>,
    pub per_proc: Vec<ProcReport>,
    /// Communication steps per vector phase.
    pub steps_per_phase: usize,
    /// Peak payload words simultaneously in flight across all processors
    /// (overlap trades higher occupancy for the removed barriers; the
    /// word/message model cost is unchanged).
    pub peak_inflight_words: u64,
    /// Payload buffers freshly allocated during this run — 0 once the
    /// plan's per-processor pools are warm (the steady-state
    /// zero-allocation hot path; §Perf P8).
    pub fresh_payload_allocs: u64,
    pub elapsed: Duration,
}

impl SttsvReport {
    /// Max over processors of words sent (the paper's bandwidth cost).
    pub fn max_sent_words(&self) -> u64 {
        self.per_proc.iter().map(|r| r.stats.sent_words).max().unwrap_or(0)
    }

    /// Max over processors of words received.
    pub fn max_recv_words(&self) -> u64 {
        self.per_proc.iter().map(|r| r.stats.recv_words).max().unwrap_or(0)
    }

    /// Max logical ternary multiplications on any processor (§7.1).
    pub fn max_ternary_mults(&self) -> u64 {
        self.per_proc.iter().map(|r| r.ternary_mults).max().unwrap_or(0)
    }

    /// Total logical ternary multiplications across processors.
    pub fn total_ternary_mults(&self) -> u64 {
        self.per_proc.iter().map(|r| r.ternary_mults).sum()
    }
}

/// Whole-run report for an r-column batched run ([`SttsvPlan::run_multi`]).
#[derive(Debug, Clone)]
pub struct SttsvMultiReport {
    /// ys[l] = A ×₂ xs[l] ×₃ xs[l], one result column per input column.
    pub ys: Vec<Vec<f32>>,
    pub per_proc: Vec<ProcReport>,
    /// Communication steps per vector phase (independent of r).
    pub steps_per_phase: usize,
    /// Peak payload words simultaneously in flight across all processors.
    pub peak_inflight_words: u64,
    /// Payload buffers freshly allocated during this run — 0 once the
    /// plan's per-processor pools are warm (§Perf P8).
    pub fresh_payload_allocs: u64,
    pub elapsed: Duration,
}

impl SttsvMultiReport {
    /// Number of right-hand-side columns served.
    pub fn nrhs(&self) -> usize {
        self.ys.len()
    }

    /// Max over processors of words sent (scales as r× the r = 1 count).
    pub fn max_sent_words(&self) -> u64 {
        self.per_proc.iter().map(|r| r.stats.sent_words).max().unwrap_or(0)
    }

    /// Max over processors of words received.
    pub fn max_recv_words(&self) -> u64 {
        self.per_proc.iter().map(|r| r.stats.recv_words).max().unwrap_or(0)
    }

    /// Max over processors of messages sent (independent of r — the
    /// latency-side win of r-deep packing).
    pub fn max_sent_msgs(&self) -> u64 {
        self.per_proc.iter().map(|r| r.stats.sent_msgs).max().unwrap_or(0)
    }

    /// Total logical ternary multiplications across processors (all
    /// columns): r · n²(n+1)/2.
    pub fn total_ternary_mults(&self) -> u64 {
        self.per_proc.iter().map(|r| r.ternary_mults).sum()
    }
}

/// Run parallel STTSV with default options (point-to-point, native, batched).
pub fn run_sttsv(
    tensor: &SymTensor,
    x: &[f32],
    part: &TetraPartition,
    mode: CommMode,
    backend: Backend,
) -> Result<SttsvReport> {
    run_sttsv_opts(tensor, x, part, ExecOpts { mode, ..ExecOpts::for_backend(backend) })
}

/// Run parallel STTSV (Algorithm 5) on the simulated machine.
///
/// Builds a fresh [`SttsvPlan`] and runs it once; iterative callers (power
/// method, CP gradient) should build the plan themselves and reuse it — the
/// tensor-block extraction is input-independent (§Perf P5).
pub fn run_sttsv_opts(
    tensor: &SymTensor,
    x: &[f32],
    part: &TetraPartition,
    opts: ExecOpts,
) -> Result<SttsvReport> {
    SttsvPlan::new(tensor, part, opts)?.run(x)
}

/// Run an r-column batched STTSV (one tensor sweep, r-deep messages) on a
/// fresh plan. Iterative callers should build and reuse the plan.
pub fn run_sttsv_multi(
    tensor: &SymTensor,
    xs: &[Vec<f32>],
    part: &TetraPartition,
    opts: ExecOpts,
) -> Result<SttsvMultiReport> {
    SttsvPlan::new(tensor, part, opts)?.run_multi(xs)
}

/// Run parallel STTSV for an n that does NOT divide into the partition's m
/// row blocks: pads the tensor and vector to the next multiple of m with
/// zeros (paper §6.1), runs Algorithm 5, and truncates y back to length n.
/// Padding inflates the communication accounting by at most one block's
/// worth (the padded coordinates still travel) — the paper's n′ analysis.
pub fn run_sttsv_padded(
    tensor: &SymTensor,
    x: &[f32],
    part: &TetraPartition,
    opts: ExecOpts,
) -> Result<SttsvReport> {
    let n = tensor.n;
    if n % part.m == 0 {
        return run_sttsv_opts(tensor, x, part, opts);
    }
    let n2 = n.div_ceil(part.m) * part.m;
    let padded = tensor.padded(n2);
    let mut xp = x.to_vec();
    xp.resize(n2, 0.0);
    let mut rep = run_sttsv_opts(&padded, &xp, part, opts)?;
    rep.y.truncate(n);
    Ok(rep)
}

/// A same-kind batch of tensor blocks owned by one processor.
struct Group {
    /// Per-block coordinates + offsets as zero-copy views into the shared
    /// packed buffer (O(1) words per block): the packed path's only
    /// per-block state, and the single source of the (i, j, k) triples the
    /// factor/accounting loops read.
    views: Vec<PackedBlockView>,
    /// Dense-extract mode only: concatenated dense b³ copies, ready for
    /// the (batched) dense kernels and AOT artifacts. Empty on the packed
    /// path.
    a: Vec<f32>,
}

/// Build one processor's per-kind groups and its row-block slot table.
/// Independent across processors, so [`SttsvPlan::new`] fans the
/// dense-extract builds out over scoped threads.
fn build_proc_state(
    tensor: &SymTensor,
    part: &TetraPartition,
    p: usize,
    b: usize,
    packed: bool,
) -> (Vec<Group>, Vec<usize>) {
    let mut by_kind: [Vec<(usize, usize, usize)>; 3] = Default::default();
    for &(i, j, k) in &part.owned_blocks(p) {
        let slot = match classify(i, j, k) {
            BlockKind::OffDiagonal => 0,
            BlockKind::NonCentralDiagonal => 1,
            BlockKind::CentralDiagonal => 2,
        };
        by_kind[slot].push((i, j, k));
    }
    let mut proc_groups = Vec::new();
    for blocks in by_kind.into_iter().filter(|v| !v.is_empty()) {
        let views: Vec<PackedBlockView> = blocks
            .iter()
            .map(|&(i, j, k)| PackedBlockView::new(i, j, k, b))
            .collect();
        let a = if packed {
            Vec::new()
        } else {
            let mut a = Vec::with_capacity(views.len() * b * b * b);
            for &(i, j, k) in &blocks {
                a.extend(tensor.extract_block(i, j, k, b));
            }
            a
        };
        proc_groups.push(Group { views, a });
    }
    let mut map = vec![usize::MAX; part.m];
    for (s, &i) in part.r_p[p].iter().enumerate() {
        map[i] = s;
    }
    (proc_groups, map)
}

/// A prepared distributed STTSV: partition + Theorem 6 schedule + the
/// owner-compute block state, built once. `run`/`run_multi` are then
/// functions of the input vectors only — mirroring the paper's point that
/// the tensor is never communicated across repeated STTSVs.
///
/// On the packed path (the default) the plan borrows the `SymTensor` and
/// workers contract in place against its packed buffer: plan construction
/// is O(m³) view computations instead of O(n³) dense copies, and the
/// plan's resident tensor memory is zero beyond the shared buffer
/// ([`SttsvPlan::resident_tensor_words`]).
pub struct SttsvPlan<'a> {
    tensor: &'a SymTensor,
    part: &'a TetraPartition,
    sched: CommSchedule,
    b: usize,
    n: usize,
    opts: ExecOpts,
    engine: Engine,
    /// groups[p] = per-kind batches for processor p.
    groups: Vec<Vec<Group>>,
    /// slot_of[p][i] = dense slot of row block i on processor p (the index
    /// of i in the sorted R_p), or `usize::MAX` when i ∉ R_p. Workers use
    /// this to address their slot-indexed gather/accumulate buffers instead
    /// of hashing row-block ids.
    slot_of: Vec<Vec<usize>>,
    /// overlap[p]: precomputed readiness/streaming metadata for the §Perf
    /// P8 pipeline worker (panel waits, block dependencies, per-slot
    /// contribution counts, phase-3 release counters).
    overlap: Vec<OverlapMeta>,
    /// Per-processor payload-buffer pools lent to every run: message
    /// buffers recycle across runs, so repeated `run`/`run_multi` calls on
    /// one plan perform zero per-message heap allocations at steady state.
    pools: Vec<Mutex<BufPool>>,
    /// programs[p]: the §Perf P10 compiled sweep program — built once at
    /// plan construction and replayed by every sweep of every run and
    /// resident session. Empty when `opts.compiled` is off (normalized
    /// away on PJRT / dense-extract plans).
    programs: Vec<SweepProgram>,
    /// How many sweep programs were ever built for this plan — regression
    /// instrumentation mirroring `SymTensor::dense_sttsv_invocations`:
    /// stays exactly P (or 0 uncompiled) however many sweeps run.
    program_builds: AtomicU64,
    /// §Rob P15 checksum state (`Some` iff `opts.abft.on()`): per-block
    /// `C_b`, the global `C`, and the charged build communication.
    abft: Option<AbftData>,
    /// Blocks successfully repaired by scrub-mode recompute across every
    /// run of this plan (a detected-and-recovered silent corruption each).
    abft_scrubs: AtomicU64,
}

/// Overlap-mode tags: one gather and one reduce message per ordered peer
/// pair, so `(from, tag)` uniquely keys every in-flight message.
const TAG_GATHER: u64 = 0;
const TAG_REDUCE: u64 = 1;

/// One peer transfer of the overlap pipeline. The same row blocks travel
/// in both directions (sharing is symmetric), so a single link describes
/// the outgoing and the incoming message to/from `peer` in each phase.
struct PeerLink {
    peer: usize,
    /// Shared row blocks, in the phased payload order (sorted R_p order).
    row_blocks: Vec<usize>,
    /// All-to-All only: fixed message size in r = 1 words (zero-padded, the
    /// §7.2.2 uniform buffer). 0 = exact point-to-point payload.
    pad_words: usize,
}

impl PeerLink {
    /// r = 1 words of the message this link *receives* in `tag`'s phase:
    /// gather segments are sized by the sender's portions, reduce segments
    /// by the receiver's own portions.
    fn recv_words(&self, part: &TetraPartition, b: usize, me: usize, tag: u64) -> usize {
        if self.pad_words != 0 {
            return self.pad_words;
        }
        self.row_blocks
            .iter()
            .map(|&i| {
                let owner = if tag == TAG_GATHER { self.peer } else { me };
                part.portion(i, owner, b).len()
            })
            .sum()
    }
}

/// Per-processor metadata driving the overlap worker, derived once from
/// the partition + comm mode at plan construction: which arrivals complete
/// which x panels, which panels gate which blocks, how many local block
/// contributions finalize each y panel, and which finalizations release
/// which outgoing phase-3 messages. The counter vectors are templates,
/// cloned into mutable run state per execution.
struct OverlapMeta {
    links: Vec<PeerLink>,
    /// peer rank -> index into `links` (`usize::MAX` = no link).
    peer_link: Vec<usize>,
    /// panel_waits[s]: incoming phase-1 transfers covering slot s.
    panel_waits: Vec<u32>,
    /// block_deps[bid]: distinct gated slots among the block's three row
    /// blocks; 0 = locally complete, contractable before any arrival.
    block_deps: Vec<u32>,
    /// slot -> blocks gated on that panel's completion.
    slot_blocks: Vec<Vec<u32>>,
    /// slot_contribs[s]: owned blocks contributing (nonzero factor) to s.
    slot_contribs: Vec<u32>,
    /// slot -> links whose phase-3 message covers that slot.
    slot_links: Vec<Vec<u32>>,
    /// p3_waits[li]: slots of link li still awaiting local contributions
    /// (the message streams out the moment this reaches 0).
    p3_waits: Vec<u32>,
    /// Flattened owned blocks as (group, index-in-group), in group order.
    blocks: Vec<(u32, u32)>,
    /// Max r = 1 words of any single incoming message (scratch sizing).
    max_recv_words: usize,
}

/// Build one processor's overlap metadata. The link set reproduces the
/// phased message set exactly — point-to-point links are taken verbatim
/// from the `CommSchedule` transfer set (same peers, same row-block
/// order, by construction rather than by a parallel re-derivation);
/// All-to-All links exist for every peer with the fixed padded buffer —
/// so words and messages per processor are identical to the phased path.
fn build_overlap_meta(
    part: &TetraPartition,
    sched: &CommSchedule,
    me: usize,
    b: usize,
    mode: CommMode,
    groups: &[Group],
    slots: &[usize],
) -> OverlapMeta {
    let nslots = part.r_p[me].len();
    let mut links = Vec::new();
    let mut peer_link = vec![usize::MAX; part.p];
    match mode {
        CommMode::PointToPoint => {
            // Incoming transfers mirror the outgoing ones (sharing is
            // symmetric and r_p lists are sorted, so both directions carry
            // the same sorted row-block set — `CommSchedule::validate`
            // checks exactly this), so one link per outgoing transfer
            // describes both directions.
            for xf in sched.xfers.iter().filter(|xf| xf.from == me) {
                peer_link[xf.to] = links.len();
                links.push(PeerLink {
                    peer: xf.to,
                    row_blocks: xf.row_blocks.clone(),
                    pad_words: 0,
                });
            }
        }
        CommMode::AllToAll => {
            let pad = 2 * b.div_ceil(part.lambda1());
            for round in 1..part.p {
                let peer = (me + round) % part.p;
                let shared: Vec<usize> = part.r_p[me]
                    .iter()
                    .copied()
                    .filter(|i| part.r_p[peer].contains(i))
                    .collect();
                peer_link[peer] = links.len();
                links.push(PeerLink { peer, row_blocks: shared, pad_words: pad });
            }
        }
    }

    let mut panel_waits = vec![0u32; nslots];
    for link in &links {
        for &i in &link.row_blocks {
            panel_waits[slots[i]] += 1;
        }
    }

    let mut blocks = Vec::new();
    let mut block_deps = Vec::new();
    let mut slot_blocks = vec![Vec::new(); nslots];
    let mut slot_contribs = vec![0u32; nslots];
    for (g, group) in groups.iter().enumerate() {
        for (s, view) in group.views.iter().enumerate() {
            let bid = blocks.len() as u32;
            blocks.push((g as u32, s as u32));
            let (i, j, k) = (view.bi, view.bj, view.bk);
            let mut dep_slots = [slots[i], slots[j], slots[k]];
            dep_slots.sort_unstable();
            let mut deps = 0u32;
            let mut prev = usize::MAX;
            for &sl in &dep_slots {
                if sl == prev {
                    continue; // diagonal block: repeated row block
                }
                prev = sl;
                if panel_waits[sl] > 0 {
                    deps += 1;
                    slot_blocks[sl].push(bid);
                }
            }
            block_deps.push(deps);
            let (fi, fj, fk) = factors(classify(i, j, k), i, j, k);
            for (idx, f) in [(i, fi), (j, fj), (k, fk)] {
                if f != 0.0 {
                    slot_contribs[slots[idx]] += 1;
                }
            }
        }
    }

    let mut slot_links = vec![Vec::new(); nslots];
    let mut p3_waits = vec![0u32; links.len()];
    for (li, link) in links.iter().enumerate() {
        for &i in &link.row_blocks {
            let s = slots[i];
            slot_links[s].push(li as u32);
            if slot_contribs[s] > 0 {
                p3_waits[li] += 1;
            }
        }
    }

    let max_recv_words = links
        .iter()
        .flat_map(|link| {
            [TAG_GATHER, TAG_REDUCE].map(|tag| link.recv_words(part, b, me, tag))
        })
        .max()
        .unwrap_or(0);

    OverlapMeta {
        links,
        peer_link,
        panel_waits,
        block_deps,
        slot_blocks,
        slot_contribs,
        slot_links,
        p3_waits,
        blocks,
        max_recv_words,
    }
}

/// A compiled, branch-free sweep program for one processor (§Perf P10):
/// every owned block flattened at plan-build time into a stream of
/// contiguous-run descriptors ([`RunDesc`]) plus a per-block header with
/// the pre-resolved panel slots, multiplicity factors, and §7.1 charge.
/// Sweeps replay the stream through the register-tiled microkernels
/// ([`exec_block_runs`]) instead of re-deriving packed offsets and
/// multiplicity branches every iteration. Blocks appear in the same
/// group-major order as the interpreted sweep AND [`OverlapMeta::blocks`],
/// so the overlap pipeline's readiness block ids index [`Self::blocks`]
/// directly.
pub struct SweepProgram {
    blocks: Vec<BlockProg>,
    descs: Vec<RunDesc>,
    /// All block ids in execution order — the phased sweep's pool input.
    all: Vec<u32>,
}

/// One block of a [`SweepProgram`]: its descriptor range plus everything
/// the accumulation loop would otherwise recompute per sweep.
struct BlockProg {
    dstart: u32,
    dend: u32,
    si: u32,
    sj: u32,
    sk: u32,
    fi: f32,
    fj: f32,
    fk: f32,
    /// §7.1 ternary-mult charge per RHS column — equal by construction to
    /// the descriptor stream's executed count (debug-asserted below,
    /// unit-tested in `compiled_program_charges_equal_descriptor_mults`).
    mults: u64,
}

/// Flatten one processor's owned blocks into a sweep program. `builds`
/// is the plan's build-count instrumentation: resident sessions must
/// reuse one program across all iterations (asserted in session tests,
/// mirroring the dense-oracle counter of §Perf P9).
fn build_program(
    groups: &[Group],
    slots: &[usize],
    b: usize,
    builds: &AtomicU64,
) -> SweepProgram {
    let mut blocks = Vec::new();
    let mut descs: Vec<RunDesc> = Vec::new();
    for group in groups {
        for view in &group.views {
            let dstart = descs.len();
            let mut mults = 0u64;
            view.for_each_run(|run| {
                mults += run.ternary_mults();
                descs.push(RunDesc::compile(&run));
            });
            let (i, j, k) = (view.bi, view.bj, view.bk);
            let kind = classify(i, j, k);
            debug_assert_eq!(
                mults,
                block_ternary_mults(kind, b as u64),
                "descriptor stream charge diverged from the §7.1 accounting"
            );
            let (fi, fj, fk) = factors(kind, i, j, k);
            blocks.push(BlockProg {
                dstart: dstart as u32,
                dend: descs.len() as u32,
                si: slots[i] as u32,
                sj: slots[j] as u32,
                sk: slots[k] as u32,
                fi,
                fj,
                fk,
                mults,
            });
        }
    }
    builds.fetch_add(1, Ordering::Relaxed);
    let all = (0..blocks.len() as u32).collect();
    SweepProgram { blocks, descs, all }
}

/// Per-block packed checksum matrix `C_b` (§Rob P15): the coefficients of
/// the quadratic form `xᵀC_b x` that the block's weighted contribution to
/// `Σ_i y_i` must equal — `fi·Σci + fj·Σcj + fk·Σck = Σ_{u≥v} coef·x_u·x_v`
/// exactly in real arithmetic (the per-entry symmetrization weights of
/// [`checksum_weights`] restricted to this block's unique entries), so the
/// verify residual at zero faults is pure fp noise, bounded γ-style.
///
/// Coordinates are block-local: the block's 1–3 distinct row blocks become
/// `npanels` consecutive b-wide panels in ascending row-block order (which
/// makes local order agree with global order, so `u ≥ v` is preserved),
/// and `coef` is packed upper-triangular over the `npanels·b` local
/// coordinates (`coef[u(u+1)/2 + v]`, v ≤ u).
struct AbftBlock {
    /// Worker slot of each panel (ascending row-block order); the x value
    /// of local coordinate u is `xbuf[(slot[u/b]·b + u%b)·r + l]`.
    slot: [u32; 3],
    npanels: usize,
    coef: Vec<f32>,
}

/// One processor's ABFT state: per-owned-block checksum matrices, parallel
/// to the compiled program's block order.
struct AbftProc {
    blocks: Vec<AbftBlock>,
}

/// Plan-wide ABFT state: the per-processor `C_b` sets, the global mode-1
/// contraction checksum `C[j,k] = Σ_i A[i,j,k]` (packed upper-triangular,
/// n(n+1)/2 coefficients) for the host-side `Σ_i y_i = xᵀCx` backstop, and
/// the build-time communication (one width-n(n+1)/2 allreduce per rank —
/// the closed form [`crate::simulator::allreduce_stats`]`(p, rank,
/// n(n+1)/2)`, asserted in P15). Build comm is charged here once, NOT
/// folded into per-run stats: the tensor — and hence C — never moves again
/// across repeated STTSVs.
struct AbftData {
    per_proc: Vec<AbftProc>,
    c_global: Vec<f32>,
    build_stats: Vec<CommStats>,
}

/// Build one block's packed `C_b` from the shared tensor buffer.
fn build_abft_block(
    tensor: &SymTensor,
    view: &PackedBlockView,
    slots: &[usize],
    b: usize,
) -> AbftBlock {
    // Distinct row blocks, ascending: bk ≤ bj ≤ bi.
    let mut panels = [view.bk, 0, 0];
    let mut npanels = 1;
    for rb in [view.bj, view.bi] {
        if rb != panels[npanels - 1] {
            panels[npanels] = rb;
            npanels += 1;
        }
    }
    let loc = |g: usize| -> usize {
        let pi = panels[..npanels]
            .iter()
            .position(|&p| p == g / b)
            .expect("entry index outside the block's row blocks");
        pi * b + g % b
    };
    let nloc = npanels * b;
    let mut coef = vec![0.0f32; nloc * (nloc + 1) / 2];
    let data = tensor.packed_data();
    view.for_each_unique_entry(|off, i, j, k| {
        let a = data[off];
        for (u, v, w) in checksum_weights(i, j, k) {
            if w != 0.0 {
                let (lu, lv) = (loc(u), loc(v));
                debug_assert!(lu >= lv);
                coef[lu * (lu + 1) / 2 + lv] += w * a;
            }
        }
    });
    let mut slot = [0u32; 3];
    for (s, &p) in slot.iter_mut().zip(&panels[..npanels]) {
        *s = slots[p] as u32;
    }
    AbftBlock { slot, npanels, coef }
}

/// Build the plan's ABFT state with a dedicated P-rank simulator run on
/// the deterministic mpsc transport: each rank derives its owned blocks'
/// `C_b` locally (blocks in the same group-major order as
/// [`build_program`], so program block ids index [`AbftProc::blocks`]
/// directly), scatters them into a global n(n+1)/2 coefficient buffer
/// (blocks partition the unique entries, so the sum is exactly `C`), and
/// allreduce-sums it — the only ABFT build communication.
fn build_abft(
    tensor: &SymTensor,
    part: &TetraPartition,
    groups: &[Vec<Group>],
    slot_of: &[Vec<usize>],
    b: usize,
    n: usize,
) -> Result<AbftData> {
    let tri_n = n * (n + 1) / 2;
    let cfg = RunCfg {
        slot_words: tri_n.max(2),
        ..RunCfg::default()
    };
    type BuildOut = (AbftProc, Vec<f32>, CommStats);
    let (outs, _metrics): (Vec<BuildOut>, _) =
        simulator::run_cfg(part.p, None, cfg, |comm| {
            let me = comm.rank;
            comm.phase = "abft-build";
            let mut blocks = Vec::new();
            let mut c = vec![0.0f32; tri_n];
            for group in &groups[me] {
                for view in &group.views {
                    blocks.push(build_abft_block(tensor, view, &slot_of[me], b));
                    let data = tensor.packed_data();
                    view.for_each_unique_entry(|off, i, j, k| {
                        let a = data[off];
                        for (u, v, w) in checksum_weights(i, j, k) {
                            if w != 0.0 {
                                c[u * (u + 1) / 2 + v] += w * a;
                            }
                        }
                    });
                }
            }
            comm.allreduce_sum(&mut c)?;
            Ok((AbftProc { blocks }, c, comm.stats))
        })?;
    let mut per_proc = Vec::with_capacity(part.p);
    let mut build_stats = Vec::with_capacity(part.p);
    let mut c_global = Vec::new();
    for (proc, c, stats) in outs {
        per_proc.push(proc);
        c_global = c;
        build_stats.push(stats);
    }
    Ok(AbftData { per_proc, c_global, build_stats })
}

/// Split `bids` into at most `threads` contiguous chunks with balanced
/// §7.1 charge — the compute pool's deterministic work assignment (no
/// work stealing, so the ordered reduction is reproducible for a fixed
/// thread count).
fn balance_chunks(
    prog: &SweepProgram,
    bids: &[u32],
    threads: usize,
) -> Vec<std::ops::Range<usize>> {
    let total: u64 = bids.iter().map(|&b| prog.blocks[b as usize].mults).sum();
    let mut out = Vec::with_capacity(threads);
    let mut start = 0usize;
    let mut acc = 0u64;
    let mut done = 0u64;
    for (i, &bid) in bids.iter().enumerate() {
        let w = prog.blocks[bid as usize].mults;
        let chunks_left = (threads - out.len()) as u64;
        let fair = (total - done).div_ceil(chunks_left);
        // Close the current chunk BEFORE absorbing a block that would
        // push it past its fair share (never leaving a chunk empty), so
        // a dominant block late in the order still gets its own chunk
        // instead of collapsing everything into one.
        if acc + w > fair && i > start && out.len() + 1 < threads {
            out.push(start..i);
            start = i;
            done += acc;
            acc = 0;
        }
        acc += w;
    }
    out.push(start..bids.len());
    out
}

impl<'a> SttsvPlan<'a> {
    /// Prepare a plan: validate shapes, build the schedule, and build every
    /// processor's block state (grouped by kind for batched dispatch). The
    /// per-processor state is independent, so the dense-extract mode's
    /// O(n³) copying runs one scoped thread per processor.
    pub fn new(
        tensor: &'a SymTensor,
        part: &'a TetraPartition,
        opts: ExecOpts,
    ) -> Result<SttsvPlan<'a>> {
        let mut opts = opts.normalize();
        if opts.compiled && u32::try_from(tensor.packed_len()).is_err() {
            // RunDesc packs offsets as u32 (16 GiB of packed words);
            // beyond that the interpreter — which has no such bound —
            // keeps serving, instead of a panic out of a Result-returning
            // constructor.
            opts.compiled = false;
            opts.compute_threads = 1;
            // ABFT scrubs replay descriptor streams; without them it is
            // normalized away exactly as in ExecOpts::normalize.
            opts.abft = AbftMode::Off;
        }
        let n = tensor.n;
        ensure!(
            n % part.m == 0,
            "n = {n} must be a multiple of m = {} (pad the tensor; §6.1)",
            part.m
        );
        let b = n / part.m;
        let engine = Engine::shared(opts.backend)?;
        let sched = CommSchedule::build(part)?;
        // Dense-extract mode pays O(n³) block copies — fan that out across
        // processors (per-p state is independent), capped at the machine's
        // parallelism so large-P partitions don't oversubscribe a
        // bandwidth-bound task. The packed path builds only O(1) views and
        // a slot map per processor, cheaper than a thread spawn, so it
        // stays sequential.
        let (groups, slot_of): (Vec<Vec<Group>>, Vec<Vec<usize>>) = if opts.packed {
            (0..part.p)
                .map(|p| build_proc_state(tensor, part, p, b, true))
                .unzip()
        } else {
            let workers = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(part.p);
            let chunk = part.p.div_ceil(workers);
            let mut out: Vec<Option<(Vec<Group>, Vec<usize>)>> =
                (0..part.p).map(|_| None).collect();
            std::thread::scope(|scope| {
                for (w, slots) in out.chunks_mut(chunk).enumerate() {
                    let start = w * chunk;
                    scope.spawn(move || {
                        for (off, slot) in slots.iter_mut().enumerate() {
                            *slot = Some(build_proc_state(tensor, part, start + off, b, false));
                        }
                    });
                }
            });
            out.into_iter()
                .map(|s| s.expect("plan builder thread panicked"))
                .unzip()
        };
        // The readiness metadata only serves the pipeline worker; phased
        // plans skip building it. The buffer pools serve both paths.
        let overlap = if opts.overlap {
            (0..part.p)
                .map(|p| build_overlap_meta(part, &sched, p, b, opts.mode, &groups[p], &slot_of[p]))
                .collect()
        } else {
            Vec::new()
        };
        let pools = (0..part.p).map(|_| Mutex::new(BufPool::new())).collect();
        // Compile the sweep programs last: group-major block order matches
        // both the interpreted phased sweep and the overlap metadata, so
        // overlap readiness ids index program blocks directly.
        let program_builds = AtomicU64::new(0);
        let programs: Vec<SweepProgram> = if opts.compiled {
            (0..part.p)
                .map(|p| build_program(&groups[p], &slot_of[p], b, &program_builds))
                .collect()
        } else {
            Vec::new()
        };
        if opts.overlap {
            for (prog, meta) in programs.iter().zip(&overlap) {
                debug_assert_eq!(prog.blocks.len(), meta.blocks.len());
            }
        }
        // ABFT checksum derivation (§Rob P15) runs after the programs so
        // AbftProc block ids line up with program block ids by shared
        // group-major construction order.
        let abft = if opts.abft.on() {
            let data = build_abft(tensor, part, &groups, &slot_of, b, n)?;
            for (proc, prog) in data.per_proc.iter().zip(&programs) {
                debug_assert_eq!(proc.blocks.len(), prog.blocks.len());
            }
            Some(data)
        } else {
            None
        };
        Ok(SttsvPlan {
            tensor,
            part,
            sched,
            b,
            n,
            opts,
            engine,
            groups,
            slot_of,
            overlap,
            pools,
            programs,
            program_builds,
            abft,
            abft_scrubs: AtomicU64::new(0),
        })
    }

    /// Blocks repaired by scrub-mode recompute over this plan's lifetime
    /// (0 in `verify` mode or at zero injected/occurred corruption).
    pub fn abft_scrubs(&self) -> u64 {
        self.abft_scrubs.load(Ordering::Relaxed)
    }

    /// Per-rank communication charged to the ABFT checksum build (`Some`
    /// iff the plan runs with ABFT on): exactly one width-n(n+1)/2
    /// allreduce per rank — [`crate::simulator::allreduce_stats`]`(p,
    /// rank, n(n+1)/2)`, asserted in P15. Charged once at plan build, not
    /// per run, because C is as immobile as the tensor it checksums.
    pub fn abft_build_stats(&self) -> Option<&[CommStats]> {
        self.abft.as_ref().map(|a| a.build_stats.as_slice())
    }

    /// How many sweep programs this plan ever compiled: P on a compiled
    /// plan, 0 otherwise — and **invariant across sweeps**: resident
    /// sessions replay the same programs every iteration (asserted in the
    /// session tests, mirroring the §Perf P9 dense-oracle counter).
    pub fn sweep_program_builds(&self) -> u64 {
        self.program_builds.load(Ordering::Relaxed)
    }

    /// The compiled program of processor `me`, when this plan compiles.
    fn program(&self, me: usize) -> Option<&SweepProgram> {
        self.programs.get(me)
    }

    /// Execute program blocks sequentially in the given order into `out`,
    /// reusing the caller's 3·(b·r) scratch for the per-block output
    /// panels. Bitwise identical to dispatching the interpreted packed
    /// kernels block by block (same kernels' arithmetic, same per-block
    /// scale-and-accumulate). Returns the charged mults (all r columns).
    fn exec_blocks_seq(
        &self,
        prog: &SweepProgram,
        bids: impl Iterator<Item = usize>,
        xbuf: &[f32],
        out: &mut [f32],
        r: usize,
        cscr: &mut [f32],
    ) -> u64 {
        let b = self.b;
        let panel = b * r;
        let tdata = self.tensor.packed_data();
        debug_assert_eq!(cscr.len(), 3 * panel);
        let (ci, rest) = cscr.split_at_mut(panel);
        let (cj, ck) = rest.split_at_mut(panel);
        let mut mults = 0u64;
        for bid in bids {
            let blk = &prog.blocks[bid];
            let (si, sj, sk) = (blk.si as usize, blk.sj as usize, blk.sk as usize);
            ci.fill(0.0);
            cj.fill(0.0);
            ck.fill(0.0);
            exec_block_runs(
                tdata,
                &prog.descs[blk.dstart as usize..blk.dend as usize],
                &xbuf[si * panel..(si + 1) * panel],
                &xbuf[sj * panel..(sj + 1) * panel],
                &xbuf[sk * panel..(sk + 1) * panel],
                ci,
                cj,
                ck,
                r,
            );
            axpy_panel(out, si, panel, blk.fi, ci);
            axpy_panel(out, sj, panel, blk.fj, cj);
            axpy_panel(out, sk, panel, blk.fk, ck);
            mults += r as u64 * blk.mults;
        }
        mults
    }

    /// The ABFT-guarded sequential executor (§Rob P15): identical block
    /// order and arithmetic to [`Self::exec_blocks_seq`] — the verify is a
    /// read-only side computation between the kernel and the axpy, so
    /// zero-fault results are bitwise equal to ABFT-off — plus, per block:
    /// an optional injected memory bit-flip (chaos), the `xᵀC_b x` check,
    /// and in scrub mode a single recompute of the offending block's
    /// run-descriptor stream before giving up with a typed
    /// [`SttsvError::Corrupt`].
    #[allow(clippy::too_many_arguments)]
    fn exec_blocks_abft(
        &self,
        prog: &SweepProgram,
        ab: &AbftProc,
        me: usize,
        xbuf: &[f32],
        out: &mut [f32],
        r: usize,
        cscr: &mut [f32],
        vscr: &mut [f32],
        mem: &mut Option<MemChaos>,
    ) -> Result<u64> {
        let b = self.b;
        let panel = b * r;
        let tdata = self.tensor.packed_data();
        debug_assert_eq!(cscr.len(), 3 * panel);
        let (ci, rest) = cscr.split_at_mut(panel);
        let (cj, ck) = rest.split_at_mut(panel);
        let mut mults = 0u64;
        for (bid, (blk, abb)) in prog.blocks.iter().zip(&ab.blocks).enumerate() {
            let descs = &prog.descs[blk.dstart as usize..blk.dend as usize];
            let (si, sj, sk) = (blk.si as usize, blk.sj as usize, blk.sk as usize);
            let (us, vs, ws) = (
                &xbuf[si * panel..(si + 1) * panel],
                &xbuf[sj * panel..(sj + 1) * panel],
                &xbuf[sk * panel..(sk + 1) * panel],
            );
            ci.fill(0.0);
            cj.fill(0.0);
            ck.fill(0.0);
            exec_block_runs(tdata, descs, us, vs, ws, ci, cj, ck, r);
            if let Some(mc) = mem.as_mut() {
                // Corrupt the accumulator panel that is always accumulated
                // (fi ≥ 1 for every block kind), so an injected flip is
                // never masked by a zero multiplicity factor.
                mc.maybe_flip(ci);
            }
            if !self.verify_block(abb, blk, xbuf, ci, cj, ck, r, vscr) {
                let mut repaired = false;
                if self.opts.abft == AbftMode::Scrub {
                    // Recompute just this block's descriptor stream (the
                    // kernels are bitwise-deterministic, so a clean replay
                    // is the fault-free contribution) and re-verify.
                    ci.fill(0.0);
                    cj.fill(0.0);
                    ck.fill(0.0);
                    exec_block_runs(tdata, descs, us, vs, ws, ci, cj, ck, r);
                    repaired = self.verify_block(abb, blk, xbuf, ci, cj, ck, r, vscr);
                    if repaired {
                        self.abft_scrubs.fetch_add(1, Ordering::Relaxed);
                    }
                }
                if !repaired {
                    return Err(SttsvError::Corrupt {
                        rank: me,
                        tag: bid as u64,
                        phase: "abft-verify",
                    }
                    .into());
                }
            }
            axpy_panel(out, si, panel, blk.fi, ci);
            axpy_panel(out, sj, panel, blk.fj, cj);
            axpy_panel(out, sk, panel, blk.fk, ck);
            mults += r as u64 * blk.mults;
        }
        Ok(mults)
    }

    /// Check one block's contribution against its checksum matrix: for
    /// every column l, `fi·Σci + fj·Σcj + fk·Σck` must equal
    /// `Σ_{u≥v} coef·x_u·x_v` within a γ-style bound — ε·(8 + 2·mults)
    /// times the form's absolute mass Σ|coef·x_u·x_v|, covering the fp
    /// accumulation error of both sides with margin (soak-tested to never
    /// false-positive) while staying far below the relative error an
    /// exponent-bit flip inflicts on any contributing accumulator word.
    /// `vscr` is the worker's reusable 3r scratch (got/expected/mass).
    #[allow(clippy::too_many_arguments)]
    fn verify_block(
        &self,
        ab: &AbftBlock,
        blk: &BlockProg,
        xbuf: &[f32],
        ci: &[f32],
        cj: &[f32],
        ck: &[f32],
        r: usize,
        vscr: &mut [f32],
    ) -> bool {
        let b = self.b;
        debug_assert_eq!(vscr.len(), 3 * r);
        let (got, rest) = vscr.split_at_mut(r);
        let (exp, mass) = rest.split_at_mut(r);
        got.fill(0.0);
        exp.fill(0.0);
        mass.fill(0.0);
        panel_col_sums(ci, r, blk.fi, got);
        panel_col_sums(cj, r, blk.fj, got);
        panel_col_sums(ck, r, blk.fk, got);
        let xcol = |u: usize| {
            let s = ab.slot[u / b] as usize;
            &xbuf[(s * b + u % b) * r..(s * b + u % b + 1) * r]
        };
        let mut idx = 0usize;
        for u in 0..ab.npanels * b {
            let xu = xcol(u);
            for v in 0..=u {
                let c = ab.coef[idx];
                idx += 1;
                if c == 0.0 {
                    continue;
                }
                let xv = xcol(v);
                for l in 0..r {
                    let t = c * xu[l] * xv[l];
                    exp[l] += t;
                    mass[l] += t.abs();
                }
            }
        }
        let gamma = f32::EPSILON * (8.0 + 2.0 * blk.mults as f32);
        got.iter()
            .zip(exp.iter())
            .zip(mass.iter())
            .all(|((&g, &e), &m)| (g - e).abs() <= gamma * m)
    }

    /// Execute program blocks through the intra-worker compute pool:
    /// `bids` split into charge-balanced contiguous chunks, chunk 0 on the
    /// calling thread straight into `out`, the rest on scoped threads into
    /// privatized panels, then a deterministic ordered reduction
    /// (chunk-order `out += panel`). Communication counters and charged
    /// mults are untouched; only the f32 accumulation regrouping differs
    /// from the sequential oracle. The privatized panels and per-thread
    /// block scratch live in the worker's [`PoolBufs`] and are reused
    /// across batches and sweeps — after warm-up the pool allocates
    /// nothing per call (the scoped thread spawns remain, ~µs each,
    /// amortized over a chunk's contraction work).
    #[allow(clippy::too_many_arguments)]
    fn exec_blocks_pooled(
        &self,
        prog: &SweepProgram,
        bids: &[u32],
        xbuf: &[f32],
        out: &mut [f32],
        r: usize,
        cscr: &mut [f32],
        pool: &mut PoolBufs,
    ) -> u64 {
        let threads = self.opts.compute_threads.clamp(1, bids.len().max(1));
        // Fanning out pays a fixed cost — (threads−1) thread spawns plus a
        // zero + ordered-reduce pass over each privatized ybuf-length
        // panel — so small batches (common when the overlap loop drains a
        // couple of ready blocks at a time) run inline: the contraction
        // work must dominate the panel traffic by a healthy margin.
        let work: u64 = bids.iter().map(|&x| prog.blocks[x as usize].mults).sum();
        let fixed = 4 * (threads as u64) * (out.len() as u64);
        if work.saturating_mul(r as u64) < fixed {
            let seq = bids.iter().map(|&x| x as usize);
            return self.exec_blocks_seq(prog, seq, xbuf, out, r, cscr);
        }
        let mut chunks = balance_chunks(prog, bids, threads);
        chunks.retain(|c| !c.is_empty());
        if chunks.len() <= 1 {
            let seq = bids.iter().map(|&x| x as usize);
            return self.exec_blocks_seq(prog, seq, xbuf, out, r, cscr);
        }
        let extra = chunks.len() - 1;
        pool.prepare(extra, out.len(), 3 * self.b * r);
        let mut mults = 0u64;
        std::thread::scope(|scope| {
            let panels = pool.panels[..extra].iter_mut();
            let scratches = pool.scratch[..extra].iter_mut();
            let counters = pool.mults[..extra].iter_mut();
            for (((chunk, panel), scr), m) in
                chunks[1..].iter().zip(panels).zip(scratches).zip(counters)
            {
                let chunk_bids = &bids[chunk.clone()];
                scope.spawn(move || {
                    let seq = chunk_bids.iter().map(|&x| x as usize);
                    *m = self.exec_blocks_seq(prog, seq, xbuf, panel, r, scr);
                });
            }
            let seq = bids[chunks[0].clone()].iter().map(|&x| x as usize);
            mults = self.exec_blocks_seq(prog, seq, xbuf, out, r, cscr);
        });
        for (panel, m) in pool.panels[..extra].iter().zip(&pool.mults[..extra]) {
            lanes_add(out, panel);
            mults += *m;
        }
        mults
    }

    /// Tensor words copied into the plan: one dense b³ copy per owned
    /// block in dense-extract mode (≈ the packed footprint re-materialized
    /// across processors), and **zero** on the packed path — the only
    /// per-block state is an O(1) [`PackedBlockView`], so the plan's
    /// tensor memory is the shared `SymTensor` buffer alone.
    pub fn resident_tensor_words(&self) -> usize {
        self.groups
            .iter()
            .flat_map(|gs| gs.iter())
            .map(|g| g.a.len())
            .sum()
    }

    /// Execute the distributed STTSV for one input vector — the r = 1
    /// special case of [`SttsvPlan::run_multi`], preserving the paper's
    /// per-vector communication counts exactly.
    pub fn run(&self, x: &[f32]) -> Result<SttsvReport> {
        let SttsvMultiReport {
            mut ys,
            per_proc,
            steps_per_phase,
            peak_inflight_words,
            fresh_payload_allocs,
            elapsed,
        } = self.run_multi(&[x])?;
        Ok(SttsvReport {
            y: ys.pop().expect("r = 1 result column"),
            per_proc,
            steps_per_phase,
            peak_inflight_words,
            fresh_payload_allocs,
            elapsed,
        })
    }

    /// Execute the distributed STTSV for an r-column batch of input
    /// vectors: `ys[l] = A ×₂ xs[l] ×₃ xs[l]` for every column, with ONE
    /// sweep over the owned tensor blocks and r-deep packed messages over
    /// the same Theorem 6 schedule. Per-processor communication words are
    /// exactly r× the single-vector counts; message counts (latency) are
    /// unchanged.
    pub fn run_multi<X: AsRef<[f32]>>(&self, xs: &[X]) -> Result<SttsvMultiReport> {
        self.run_multi_with(xs, self.opts.chaos)
    }

    /// [`SttsvPlan::run_multi`] under an explicit chaos plan — the §Rob
    /// retry loops (serve-layer batch retry, session restart) run their
    /// [`FaultPlan::reseeded`] attempts through here, so one cached plan
    /// serves every attempt.
    pub fn run_multi_with<X: AsRef<[f32]>>(
        &self,
        xs: &[X],
        chaos: FaultPlan,
    ) -> Result<SttsvMultiReport> {
        let r = xs.len();
        ensure!(r >= 1, "run_multi needs at least one right-hand side");
        let views: Vec<&[f32]> = xs.iter().map(|x| x.as_ref()).collect();
        for (l, x) in views.iter().enumerate() {
            ensure!(x.len() == self.n, "xs[{l}] length {} != n {}", x.len(), self.n);
        }
        let part = self.part;
        let b = self.b;
        let started = Instant::now();

        type ProcOut = (
            CommStats,
            u64,
            Duration,
            Vec<(usize, std::ops::Range<usize>, Vec<f32>)>,
        );
        let (outs, metrics): (Vec<ProcOut>, simulator::RunMetrics) =
            simulator::run_cfg(part.p, Some(&self.pools), self.run_cfg_with(r, chaos), |comm| {
                self.worker(comm, &views, chaos)
            })?;

        // Assemble ys from the final portions (each (i, sub-range) once;
        // portion payloads are (len, r) interleaved panels).
        let mut per_proc = Vec::with_capacity(part.p);
        let mut portions_all = Vec::with_capacity(part.p);
        for (stats, mults, ct, portions) in outs {
            portions_all.push(portions);
            per_proc.push(ProcReport {
                stats,
                ternary_mults: mults,
                compute_time: ct,
            });
        }
        let ys = assemble_columns(self.n, b, r, portions_all)?;
        self.abft_global_check(&views, &ys)?;

        let steps_per_phase = self.steps_per_phase();
        Ok(SttsvMultiReport {
            ys,
            per_proc,
            steps_per_phase,
            peak_inflight_words: metrics.peak_inflight_words,
            fresh_payload_allocs: metrics.fresh_payload_allocs,
            elapsed: started.elapsed(),
        })
    }

    /// Host-side ABFT backstop after column assembly (§Rob P15): for every
    /// column, `Σ_i y_i` must equal the global form `xᵀCx` (with C the
    /// packed mode-1 contraction checksum built at plan construction).
    /// The per-block worker checks are the primary, tight detector — they
    /// compare against the same wire-rounded xbuf the kernels consumed —
    /// so this check's tolerance is wire-aware: under a bf16 wire both the
    /// gathered x panels and the reduced y partials carry one
    /// round-to-nearest-even bf16 rounding (relative 2⁻⁹ each), while the
    /// host x and C here are full f32. Mismatch = corruption that slipped
    /// past (or bypassed) every per-block check, attributed to no single
    /// rank (`rank = usize::MAX`, `tag` = column). No-op with ABFT off.
    fn abft_global_check(&self, xs: &[&[f32]], ys: &[Vec<f32>]) -> Result<()> {
        let Some(abft) = &self.abft else {
            return Ok(());
        };
        let wire_rel = match self.opts.wire {
            WireFormat::F32 => 0.0f64,
            // two bf16 roundings (gather + reduce), 2⁻⁹ relative each,
            // doubled again for safety against rounding interactions
            WireFormat::Bf16 => 4.0 / 512.0,
        };
        let n = self.n as f64;
        for (l, (x, y)) in xs.iter().zip(ys).enumerate() {
            let got: f64 = y.iter().map(|&v| v as f64).sum();
            let got_abs: f64 = y.iter().map(|&v| v.abs() as f64).sum();
            let mut exp = 0.0f64;
            let mut mass = 0.0f64;
            let mut idx = 0usize;
            for u in 0..self.n {
                for v in 0..=u {
                    let c = abft.c_global[idx] as f64;
                    idx += 1;
                    if c != 0.0 {
                        let t = c * x[u] as f64 * x[v] as f64;
                        exp += t;
                        mass += t.abs();
                    }
                }
            }
            let tol = wire_rel * (mass + got_abs)
                + f32::EPSILON as f64 * (16.0 + 4.0 * n * n) * mass;
            if (got - exp).abs() > tol {
                return Err(SttsvError::Corrupt {
                    rank: usize::MAX,
                    tag: l as u64,
                    phase: "abft-global",
                }
                .into());
            }
        }
        Ok(())
    }

    /// One simulated processor executing Algorithm 5 for r packed columns:
    /// a thin one-iteration session — seed the own portions from the
    /// host-resident input vectors, run exactly one sweep (phased or
    /// overlapped per the plan's options), collect the owned result
    /// portions. Resident sessions ([`session::SolverSession`]) run the
    /// same sweeps in a loop without re-seeding.
    fn worker(
        &self,
        comm: &mut Comm,
        xs: &[&[f32]],
        chaos: FaultPlan,
    ) -> Result<(
        CommStats,
        u64,
        Duration,
        Vec<(usize, std::ops::Range<usize>, Vec<f32>)>,
    )> {
        let me = comm.rank;
        let r = xs.len();
        let mut st = self.worker_state(me, r);
        self.arm_chaos(&mut st, me, chaos);
        self.seed_own(me, xs, &mut st.xbuf);
        let (mults, compute_time) = self.sweep(comm, &mut st)?;
        Ok((comm.stats, mults, compute_time, self.owned_portions(me, &st.ybuf, r)))
    }

    /// Communication steps per vector phase under this plan's comm mode.
    pub(crate) fn steps_per_phase(&self) -> usize {
        match self.opts.mode {
            CommMode::PointToPoint => self.sched.num_steps(),
            CommMode::AllToAll => self.part.p - 1,
        }
    }

    /// Fresh per-worker vector state for an r-column session on processor
    /// `me`. `run`/`run_multi` build one per call; resident sessions keep
    /// one alive across iterations.
    pub(crate) fn worker_state(&self, me: usize, r: usize) -> WorkerState {
        let panel_words = self.part.r_p[me].len() * self.b * r;
        WorkerState {
            r,
            xbuf: vec![0.0f32; panel_words],
            ybuf: vec![0.0f32; panel_words],
            bufs: ExchangeBufs::default(),
            // per-block output panels of the compiled executor, reused
            // across every sweep of a resident session
            cscr: if self.programs.is_empty() {
                Vec::new()
            } else {
                vec![0.0f32; 3 * self.b * r]
            },
            pool: PoolBufs::default(),
            vscr: if self.abft.is_some() {
                vec![0.0f32; 3 * r]
            } else {
                Vec::new()
            },
            mem: None,
        }
    }

    /// Arm a worker's memory-corruption injector from a (possibly
    /// per-attempt reseeded) chaos plan — `None`/no-op at
    /// `flip_mem_ppm = 0`, so fault-free runs carry no injector state.
    /// Flips land on accumulator panels only under ABFT's guarded
    /// executor, mirroring how the wire decorator only wraps nonzero
    /// plans.
    pub(crate) fn arm_chaos(&self, st: &mut WorkerState, rank: usize, chaos: FaultPlan) {
        st.mem = if self.abft.is_some() {
            MemChaos::new(rank, chaos)
        } else {
            None
        };
    }

    /// Write processor `me`'s own x portions (all r columns, interleaved)
    /// into `xbuf` from host-resident full vectors — the iteration-0
    /// seeding. Resident sessions never touch host vectors again: later
    /// iterates are produced portion-locally inside the simulator.
    pub(crate) fn seed_own(&self, me: usize, xs: &[&[f32]], xbuf: &mut [f32]) {
        let b = self.b;
        let r = xs.len();
        for (s, &i) in self.part.r_p[me].iter().enumerate() {
            for off in self.part.portion(i, me, b) {
                let dst = (s * b + off) * r;
                for (l, x) in xs.iter().enumerate() {
                    xbuf[dst + l] = x[i * b + off];
                }
            }
        }
    }

    /// Index ranges, in the interleaved (|R_p|, b, r) panel space, of the
    /// portions processor `me` owns — the coordinates it is canonical for
    /// (portions tile each row block across Q_i, so global ownership is
    /// exact and disjoint). Sessions reduce their scalars over these.
    pub(crate) fn own_ranges(&self, me: usize, r: usize) -> Vec<std::ops::Range<usize>> {
        let b = self.b;
        self.part.r_p[me]
            .iter()
            .enumerate()
            .map(|(s, &i)| {
                let rg = self.part.portion(i, me, b);
                (s * b + rg.start) * r..(s * b + rg.end) * r
            })
            .collect()
    }

    /// Extract processor `me`'s owned portions of a panel buffer as
    /// (row block, sub-range, interleaved values) triples — the per-worker
    /// output [`assemble_columns`] consumes.
    pub(crate) fn owned_portions(
        &self,
        me: usize,
        buf: &[f32],
        r: usize,
    ) -> Vec<(usize, std::ops::Range<usize>, Vec<f32>)> {
        let b = self.b;
        self.part.r_p[me]
            .iter()
            .enumerate()
            .map(|(s, &i)| {
                let rg = self.part.portion(i, me, b);
                let vals = buf[(s * b + rg.start) * r..(s * b + rg.end) * r].to_vec();
                (i, rg, vals)
            })
            .collect()
    }

    /// One full STTSV sweep over `st`, phased or overlapped per the plan's
    /// options: phase 1 gathers from the own portions already in `st.xbuf`
    /// (foreign panel segments are refreshed by the exchange before any
    /// use), phase 2 contracts, phase 3 leaves the fully reduced owned
    /// portions in `st.ybuf`. Returns (charged ternary mults, compute
    /// time).
    pub(crate) fn sweep(&self, comm: &mut Comm, st: &mut WorkerState) -> Result<(u64, Duration)> {
        if self.opts.overlap {
            self.sweep_overlap(comm, st)
        } else {
            self.sweep_phased(comm, st)
        }
    }

    /// The stepped gather → compute → reduce sweep (the deterministic
    /// oracle path), operating on portion-local panels in `st`.
    fn sweep_phased(&self, comm: &mut Comm, st: &mut WorkerState) -> Result<(u64, Duration)> {
        let me = comm.rank;
        let part = self.part;
        let b = self.b;
        let r = st.r;
        let opts = self.opts;
        let slots = &self.slot_of[me];
        let panel = b * r;
        debug_assert_eq!(st.xbuf.len(), part.r_p[me].len() * panel);

        // ---- phase 1: gather r-deep row-block panels x[i], i ∈ R_p --------
        comm.phase = "gather";
        exchange(
            comm,
            part,
            &self.sched,
            b,
            r,
            opts.mode,
            0,
            // pack: my own portion of each shared row block (all r columns)
            |i, _to, xbuf: &Vec<f32>, out: &mut Vec<f32>| {
                let s = slots[i];
                let rg = part.portion(i, me, b);
                out.extend_from_slice(&xbuf[(s * b + rg.start) * r..(s * b + rg.end) * r]);
            },
            // unpack: sender's portion of row block i
            |i, from, data: &[f32], xbuf: &mut Vec<f32>| {
                let s = slots[i];
                let rg = part.portion(i, from, b);
                xbuf[(s * b + rg.start) * r..(s * b + rg.end) * r].copy_from_slice(data);
            },
            &mut st.xbuf,
            &mut st.bufs,
        )?;

        // ---- phase 2: local ternary multiplications -----------------------
        // One sweep of each owned block serves all r columns (§Perf P6).
        // Packed mode (§Perf P7) contracts in place against the shared
        // packed buffer; dense-extract mode sweeps the plan's b³ copies.
        let compute_start = Instant::now();
        comm.phase = "compute";
        let tdata = self.tensor.packed_data();
        for v in st.ybuf.iter_mut() {
            *v = 0.0;
        }
        let mut mults: u64 = 0;

        // Compiled path (§Perf P10): replay the plan-built descriptor
        // stream — block order identical to the interpreted per-block loop
        // below, so `compute_threads = 1` is bitwise the interpreter.
        if let Some(prog) = self.program(me) {
            mults = if let Some(abft) = &self.abft {
                // §Rob P15: the guarded executor — same order and
                // arithmetic as the sequential path (normalize pinned
                // compute_threads to 1), plus per-block verification.
                let (xbuf, ybuf) = (&st.xbuf, &mut st.ybuf);
                self.exec_blocks_abft(
                    prog,
                    &abft.per_proc[me],
                    me,
                    xbuf,
                    ybuf,
                    r,
                    &mut st.cscr,
                    &mut st.vscr,
                    &mut st.mem,
                )?
            } else if self.opts.compute_threads > 1 {
                self.exec_blocks_pooled(
                    prog,
                    &prog.all,
                    &st.xbuf,
                    &mut st.ybuf,
                    r,
                    &mut st.cscr,
                    &mut st.pool,
                )
            } else {
                self.exec_blocks_seq(
                    prog,
                    0..prog.blocks.len(),
                    &st.xbuf,
                    &mut st.ybuf,
                    r,
                    &mut st.cscr,
                )
            };
            let compute_time = compute_start.elapsed();
            self.reduce_phase(comm, st)?;
            return Ok((mults, compute_time));
        }

        // Concatenated per-group panels only pay off when the batch is one
        // real dispatch (PJRT artifacts, dense batched kernels). The Native
        // packed "batch" is a loop over per-block kernels anyway, so it
        // reads xbuf slices directly — no copies.
        let concat_batch = opts.batch && !(opts.packed && opts.backend == Backend::Native);
        for group in &self.groups[me] {
            let nb = group.views.len();
            if concat_batch {
                let mut us = Vec::with_capacity(nb * panel);
                let mut vs = Vec::with_capacity(nb * panel);
                let mut ws = Vec::with_capacity(nb * panel);
                for view in &group.views {
                    let (i, j, k) = (view.bi, view.bj, view.bk);
                    us.extend_from_slice(&st.xbuf[slots[i] * panel..(slots[i] + 1) * panel]);
                    vs.extend_from_slice(&st.xbuf[slots[j] * panel..(slots[j] + 1) * panel]);
                    ws.extend_from_slice(&st.xbuf[slots[k] * panel..(slots[k] + 1) * panel]);
                }
                let (cis, cjs, cks) = if opts.packed {
                    self.engine
                        .block_contract_packed_batch(tdata, &group.views, &us, &vs, &ws, b, r)?
                } else {
                    self.engine
                        .block_contract_multi_batch(&group.a, &us, &vs, &ws, b, nb, r)?
                };
                for (s, view) in group.views.iter().enumerate() {
                    let (i, j, k) = (view.bi, view.bj, view.bk);
                    let kind = classify(i, j, k);
                    let (fi, fj, fk) = factors(kind, i, j, k);
                    axpy_panel(
                        &mut st.ybuf,
                        slots[i],
                        panel,
                        fi,
                        &cis[s * panel..(s + 1) * panel],
                    );
                    axpy_panel(
                        &mut st.ybuf,
                        slots[j],
                        panel,
                        fj,
                        &cjs[s * panel..(s + 1) * panel],
                    );
                    axpy_panel(
                        &mut st.ybuf,
                        slots[k],
                        panel,
                        fk,
                        &cks[s * panel..(s + 1) * panel],
                    );
                    mults += r as u64 * block_ternary_mults(kind, b as u64);
                }
            } else {
                for s in 0..group.views.len() {
                    mults += self.contract_one(me, group, s, &st.xbuf, &mut st.ybuf, r)?;
                }
            }
        }
        let compute_time = compute_start.elapsed();

        self.reduce_phase(comm, st)?;

        Ok((mults, compute_time))
    }

    /// Phase 3 of the phased sweep: scatter-reduce y over the schedule so
    /// each worker ends with its fully reduced owned portions in `ybuf`.
    /// Shared by the interpreted and compiled phase-2 paths.
    fn reduce_phase(&self, comm: &mut Comm, st: &mut WorkerState) -> Result<()> {
        let me = comm.rank;
        let part = self.part;
        let b = self.b;
        let r = st.r;
        let slots = &self.slot_of[me];
        comm.phase = "reduce";
        exchange(
            comm,
            part,
            &self.sched,
            b,
            r,
            self.opts.mode,
            1,
            // pack: MY partial of the DESTINATION's portion of row block i
            |i, to, ybuf: &Vec<f32>, out: &mut Vec<f32>| {
                let s = slots[i];
                let rg = part.portion(i, to, b);
                out.extend_from_slice(&ybuf[(s * b + rg.start) * r..(s * b + rg.end) * r]);
            },
            // unpack: add sender's partial of MY portion
            |i, _from, data: &[f32], ybuf: &mut Vec<f32>| {
                let s = slots[i];
                let rg = part.portion(i, me, b);
                let dst = &mut ybuf[(s * b + rg.start) * r..(s * b + rg.end) * r];
                for (o, v) in dst.iter_mut().zip(data) {
                    *o += v;
                }
            },
            &mut st.ybuf,
            &mut st.bufs,
        )
    }

    /// Contract one owned block (per-block dispatch) and accumulate its
    /// weighted contributions into `ybuf`. Shared by the phased
    /// (non-batched) path and the overlap pipeline; returns the charged
    /// §7.1 ternary multiplications.
    fn contract_one(
        &self,
        me: usize,
        group: &Group,
        idx: usize,
        xbuf: &[f32],
        ybuf: &mut [f32],
        r: usize,
    ) -> Result<u64> {
        let b = self.b;
        let panel = b * r;
        let slots = &self.slot_of[me];
        let view = &group.views[idx];
        let (i, j, k) = (view.bi, view.bj, view.bk);
        let kind = classify(i, j, k);
        let us = &xbuf[slots[i] * panel..(slots[i] + 1) * panel];
        let vs = &xbuf[slots[j] * panel..(slots[j] + 1) * panel];
        let ws = &xbuf[slots[k] * panel..(slots[k] + 1) * panel];
        let (ci, cj, ck) = if self.opts.packed {
            let tdata = self.tensor.packed_data();
            self.engine
                .block_contract_packed_multi(tdata, view, us, vs, ws, b, r)?
        } else {
            let a = &group.a[idx * b * b * b..(idx + 1) * b * b * b];
            self.engine.block_contract_multi(a, us, vs, ws, b, r)?
        };
        let (fi, fj, fk) = factors(kind, i, j, k);
        axpy_panel(ybuf, slots[i], panel, fi, &ci);
        axpy_panel(ybuf, slots[j], panel, fj, &cj);
        axpy_panel(ybuf, slots[k], panel, fk, &ck);
        Ok(r as u64 * block_ternary_mults(kind, b as u64))
    }

    /// The §Perf P8 overlapped pipeline sweep for r packed columns: no
    /// phase barriers, no steps. Every gather message leaves up front;
    /// arrivals are drained between per-block contractions (blocks start
    /// the moment their three panels complete, locally-complete blocks
    /// immediately); each reduce message streams out the moment the
    /// destination portions it carries absorb their last local
    /// contribution. Per-processor words and messages equal the phased
    /// path's exactly — same message set, same payload layout. The event
    /// loop polls with the sweep-tag filter, so a racing session peer's
    /// collective traffic waits in the stash untouched.
    fn sweep_overlap(&self, comm: &mut Comm, wst: &mut WorkerState) -> Result<(u64, Duration)> {
        let me = comm.rank;
        let part = self.part;
        let b = self.b;
        let r = wst.r;
        let slots = &self.slot_of[me];
        let panel = b * r;
        let meta = &self.overlap[me];
        let groups = &self.groups[me];
        comm.phase = "overlap";
        debug_assert_eq!(wst.xbuf.len(), part.r_p[me].len() * panel);

        for v in wst.ybuf.iter_mut() {
            *v = 0.0;
        }
        let ctx = PipeCtx { part, slots, b, r, me };
        let mut st = PipeState {
            meta,
            panel_waits: meta.panel_waits.clone(),
            block_deps: meta.block_deps.clone(),
            slot_contribs: meta.slot_contribs.clone(),
            p3_waits: meta.p3_waits.clone(),
            ready: (0..meta.blocks.len() as u32)
                .filter(|&bid| meta.block_deps[bid as usize] == 0)
                .collect(),
            p1_left: meta.links.len(),
            p3_left: meta.links.len(),
            blocks_left: meta.blocks.len(),
            xbuf: std::mem::take(&mut wst.xbuf),
            ybuf: std::mem::take(&mut wst.ybuf),
            scratch: vec![0.0f32; meta.max_recv_words * r],
            payload: Vec::new(),
        };

        // Phase-1 burst: every gather message is in flight before any
        // compute starts (isend never blocks; buffers come from the pool).
        for link in &meta.links {
            st.payload.clear();
            for &i in &link.row_blocks {
                let s = slots[i];
                let rg = part.portion(i, me, b);
                st.payload
                    .extend_from_slice(&st.xbuf[(s * b + rg.start) * r..(s * b + rg.end) * r]);
            }
            if link.pad_words != 0 {
                debug_assert!(st.payload.len() <= link.pad_words * r);
                st.payload.resize(link.pad_words * r, 0.0);
            }
            comm.isend(link.peer, TAG_GATHER, &st.payload)?;
        }
        // Reduce links whose every slot is contribution-free (their ybuf
        // segments are final zeros) stream immediately.
        for li in 0..meta.links.len() {
            if st.p3_waits[li] == 0 {
                st.send_reduce(comm, &ctx, li)?;
            }
        }

        let mut mults: u64 = 0;
        let mut compute_time = Duration::ZERO;
        while st.p1_left > 0 || st.p3_left > 0 || st.blocks_left > 0 {
            // A dead peer must unwind this worker even while it still has
            // local compute queued (§Rob): one atomic load per iteration.
            comm.check_abort()?;
            // Drain every sweep message that has already arrived (cheap,
            // nonblocking; collective tags stay stashed for the session).
            while let Some((from, tag)) = comm.try_recv_class(TagClass::Sweep) {
                st.recv_one(comm, &ctx, from, tag)?;
            }
            if !st.ready.is_empty() {
                let t0 = Instant::now();
                match self.program(me) {
                    Some(prog) if self.opts.compute_threads > 1 && st.ready.len() > 1 => {
                        // Compute pool: contract the whole drained ready
                        // set in parallel (program block ids == overlap
                        // block ids by construction), then stream the
                        // phase-3 releases in the drained order.
                        let batch = std::mem::take(&mut st.ready);
                        mults += self.exec_blocks_pooled(
                            prog,
                            &batch,
                            &st.xbuf,
                            &mut st.ybuf,
                            r,
                            &mut wst.cscr,
                            &mut wst.pool,
                        );
                        compute_time += t0.elapsed();
                        for &bid in &batch {
                            let (g, idx) = st.meta.blocks[bid as usize];
                            let view = &groups[g as usize].views[idx as usize];
                            st.note_block_done(comm, &ctx, view)?;
                        }
                    }
                    Some(prog) => {
                        let bid = st.ready.pop().expect("ready nonempty");
                        mults += self.exec_blocks_seq(
                            prog,
                            std::iter::once(bid as usize),
                            &st.xbuf,
                            &mut st.ybuf,
                            r,
                            &mut wst.cscr,
                        );
                        compute_time += t0.elapsed();
                        let (g, idx) = st.meta.blocks[bid as usize];
                        st.note_block_done(comm, &ctx, &groups[g as usize].views[idx as usize])?;
                    }
                    None => {
                        let bid = st.ready.pop().expect("ready nonempty");
                        let (g, idx) = st.meta.blocks[bid as usize];
                        let group = &groups[g as usize];
                        mults +=
                            self.contract_one(me, group, idx as usize, &st.xbuf, &mut st.ybuf, r)?;
                        compute_time += t0.elapsed();
                        st.note_block_done(comm, &ctx, &group.views[idx as usize])?;
                    }
                }
            } else if st.p1_left > 0 || st.p3_left > 0 {
                // Nothing contractable: block until the next sweep arrival.
                let (from, tag) = comm.recv_any_class(TagClass::Sweep)?;
                st.recv_one(comm, &ctx, from, tag)?;
            } else {
                bail!(
                    "overlap pipeline stalled on processor {me}: {} blocks \
                     gated with no pending messages",
                    st.blocks_left
                );
            }
        }
        debug_assert!(
            st.p3_waits.iter().all(|&w| w == u32::MAX),
            "phase-3 message never streamed"
        );

        let PipeState { xbuf, ybuf, .. } = st;
        wst.xbuf = xbuf;
        wst.ybuf = ybuf;
        Ok((mults, compute_time))
    }

    /// Closed-form per-processor communication of ONE r-deep STTSV under
    /// this plan's comm mode — pure accounting over the schedule's
    /// transfer set (point-to-point) or the §7.2.2 padded uniform buffers
    /// (All-to-All); no simulator run. Matches the measured per-processor
    /// `CommStats` of `run`/`run_multi` exactly in both execution modes
    /// (tested against the comm-only dry run), so resident sessions assert
    /// their per-iteration invariant against it cheaply.
    pub fn expected_proc_stats(&self, r: usize) -> Vec<CommStats> {
        let part = self.part;
        let b = self.b;
        // Sweep payloads travel at the plan wire's width (2 bytes/word
        // under bf16, 4 at f32); every sweep tag prices identically, so
        // tag 0 stands in for the class.
        let bpw = self.opts.wire.bytes_per_word(0);
        let mut out = vec![CommStats::default(); part.p];
        match self.opts.mode {
            CommMode::PointToPoint => {
                for xf in &self.sched.xfers {
                    // phase-1 payload: the sender's portions of the shared
                    // row blocks; phase-3 payload: the receiver's.
                    let w1: usize = xf
                        .row_blocks
                        .iter()
                        .map(|&i| part.portion(i, xf.from, b).len())
                        .sum();
                    let w3: usize = xf
                        .row_blocks
                        .iter()
                        .map(|&i| part.portion(i, xf.to, b).len())
                        .sum();
                    let words = ((w1 + w3) * r) as u64;
                    out[xf.from].sent_words += words;
                    out[xf.from].sent_bytes += bpw * words;
                    out[xf.from].sent_msgs += 2;
                    out[xf.to].recv_words += words;
                    out[xf.to].recv_bytes += bpw * words;
                    out[xf.to].recv_msgs += 2;
                }
            }
            CommMode::AllToAll => {
                let pad = 2 * b.div_ceil(part.lambda1());
                let words = (2 * (part.p - 1) * pad * r) as u64;
                let msgs = 2 * (part.p - 1) as u64;
                for s in out.iter_mut() {
                    *s = CommStats {
                        sent_words: words,
                        recv_words: words,
                        sent_bytes: bpw * words,
                        recv_bytes: bpw * words,
                        sent_msgs: msgs,
                        recv_msgs: msgs,
                    };
                }
            }
        }
        if self.opts.abft.on() {
            // Every sweep message carries exactly one Fletcher-32
            // integrity word, billed at the wire's sweep byte width — and
            // every message counted above IS a sweep message, so the
            // closed-form surcharge is one word per message (§Rob P15).
            for s in out.iter_mut() {
                s.sent_words += s.sent_msgs;
                s.sent_bytes += bpw * s.sent_msgs;
                s.recv_words += s.recv_msgs;
                s.recv_bytes += bpw * s.recv_msgs;
            }
        }
        out
    }

    /// Width (f32 words) of the largest single message any worker sends
    /// during an r-deep sweep under this plan — the same schedule
    /// accounting as [`SttsvPlan::expected_proc_stats`], taken per message
    /// instead of summed. Collective traffic (the resident sessions'
    /// allreduces: an r·r Gram panel at most, scalars otherwise) is
    /// covered by the r² floor. Used to size the spsc transport's ring
    /// slots so every send writes in place without growing a slot.
    pub fn max_message_words(&self, r: usize) -> usize {
        let part = self.part;
        let b = self.b;
        let widest = match self.opts.mode {
            CommMode::PointToPoint => self
                .sched
                .xfers
                .iter()
                .map(|xf| {
                    // phase-1 payload: the sender's portions of the shared
                    // row blocks; phase-3 payload: the receiver's.
                    let w1: usize = xf
                        .row_blocks
                        .iter()
                        .map(|&i| part.portion(i, xf.from, b).len())
                        .sum();
                    let w3: usize = xf
                        .row_blocks
                        .iter()
                        .map(|&i| part.portion(i, xf.to, b).len())
                        .sum();
                    w1.max(w3)
                })
                .max()
                .unwrap_or(0),
            CommMode::AllToAll => 2 * b.div_ceil(part.lambda1()),
        };
        // Under ABFT every sweep payload grows by one f32 container for
        // the integrity word (appended after bf16 packing, so one full
        // word either way); the r² collective floor is never framed.
        (widest * r + self.opts.abft.on() as usize).max(r * r).max(2)
    }

    /// The simulator run configuration for an r-deep sweep: the plan's
    /// transport/pinning options plus ring slots sized to the widest
    /// message, so spsc sends never allocate — and the plan's fault
    /// injection and recv watchdog (§Rob).
    pub(crate) fn run_cfg(&self, r: usize) -> RunCfg {
        self.run_cfg_with(r, self.opts.chaos)
    }

    /// [`SttsvPlan::run_cfg`] with the chaos plan overridden — the retry
    /// loops substitute [`FaultPlan::reseeded`] attempts here without
    /// touching the plan (or its cache key).
    pub(crate) fn run_cfg_with(&self, r: usize, chaos: FaultPlan) -> RunCfg {
        RunCfg {
            transport: self.opts.transport,
            pin_threads: self.opts.pin_threads,
            slot_words: self.max_message_words(r),
            chaos,
            recv_timeout: self.opts.recv_timeout,
            wire: self.opts.wire,
            abft: self.opts.abft,
        }
    }
}

/// Per-worker vector state that persists across the sweeps of a resident
/// session (and lives for exactly one sweep under `run`/`run_multi`): the
/// slot-indexed interleaved (|R_p|, b, r) gather panel `xbuf` — whose own
/// portions are the worker's canonical piece of the iterate, with foreign
/// segments refreshed by every sweep's phase-1 exchange — the accumulate
/// panel `ybuf`, and the phased path's reusable exchange buffers.
pub(crate) struct WorkerState {
    pub(crate) r: usize,
    pub(crate) xbuf: Vec<f32>,
    pub(crate) ybuf: Vec<f32>,
    bufs: ExchangeBufs,
    /// Compiled-path scratch: the 3·(b·r) per-block output panels
    /// ([`SttsvPlan::exec_blocks_seq`]); empty on interpreted plans.
    cscr: Vec<f32>,
    /// Compute-pool buffers, reused across batches and sweeps.
    pool: PoolBufs,
    /// ABFT verify scratch (3r: got/expected/mass); empty when ABFT off.
    vscr: Vec<f32>,
    /// Armed memory bit-flip injector (§Rob chaos, `flip_mem_ppm` > 0) —
    /// per-attempt state, re-armed by [`SttsvPlan::arm_chaos`] so retry
    /// reseeds change the fault sequence like the wire decorator's.
    mem: Option<MemChaos>,
}

/// Reusable intra-worker compute-pool buffers, one entry per extra pool
/// thread: privatized output panels, per-thread block scratch, and the
/// per-chunk mult counters. Lazily sized on the first pooled batch and
/// reused across batches and sweeps — zero steady-state allocations,
/// like the worker's exchange buffers and `cscr` (the per-batch cost
/// that remains is re-zeroing the panels, which accumulation needs
/// anyway).
#[derive(Default)]
struct PoolBufs {
    panels: Vec<Vec<f32>>,
    scratch: Vec<Vec<f32>>,
    mults: Vec<u64>,
}

impl PoolBufs {
    /// Make `extra` zeroed panels of `panel_len` words, scratches of
    /// `scr_len` words, and mult counters ready for one pooled batch.
    fn prepare(&mut self, extra: usize, panel_len: usize, scr_len: usize) {
        while self.panels.len() < extra {
            self.panels.push(Vec::new());
            self.scratch.push(Vec::new());
        }
        self.mults.clear();
        self.mults.resize(extra, 0);
        for p in &mut self.panels[..extra] {
            p.clear();
            p.resize(panel_len, 0.0);
        }
        for s in &mut self.scratch[..extra] {
            s.resize(scr_len, 0.0);
        }
    }
}

/// Assemble full result columns from per-processor owned portions: every
/// global coordinate must be produced by exactly one processor (portion
/// ownership is a partition of 0..n). Portion payloads are (len, r)
/// interleaved panels.
pub(crate) fn assemble_columns(
    n: usize,
    b: usize,
    r: usize,
    per_proc: Vec<Vec<(usize, std::ops::Range<usize>, Vec<f32>)>>,
) -> Result<Vec<Vec<f32>>> {
    let mut ys = vec![vec![0.0f32; n]; r];
    let mut covered = vec![false; n];
    for portions in per_proc {
        for (i, range, vals) in portions {
            for (t, off) in range.enumerate() {
                let g = i * b + off;
                ensure!(!covered[g], "coordinate {g} produced twice");
                covered[g] = true;
                for (l, ycol) in ys.iter_mut().enumerate() {
                    ycol[g] = vals[t * r + l];
                }
            }
        }
    }
    ensure!(covered.iter().all(|&c| c), "result vector not fully covered");
    Ok(ys)
}

/// Immutable per-worker context threaded through the pipeline state
/// methods (keeps their signatures manageable).
struct PipeCtx<'a> {
    part: &'a TetraPartition,
    slots: &'a [usize],
    b: usize,
    r: usize,
    me: usize,
}

/// Mutable state of one overlap-pipeline worker: the readiness counters
/// (cloned from the plan's [`OverlapMeta`] templates), the panel buffers,
/// and the reusable pack/receive scratch.
struct PipeState<'a> {
    meta: &'a OverlapMeta,
    panel_waits: Vec<u32>,
    block_deps: Vec<u32>,
    slot_contribs: Vec<u32>,
    /// Per link: slots still awaiting contributions; `u32::MAX` = sent.
    p3_waits: Vec<u32>,
    ready: Vec<u32>,
    p1_left: usize,
    p3_left: usize,
    blocks_left: usize,
    xbuf: Vec<f32>,
    ybuf: Vec<f32>,
    scratch: Vec<f32>,
    payload: Vec<f32>,
}

impl PipeState<'_> {
    /// Consume one arrived message: deliver into `xbuf` (gather) or
    /// accumulate into `ybuf` (reduce), then advance the readiness
    /// counters — newly complete panels release gated blocks.
    fn recv_one(&mut self, comm: &mut Comm, ctx: &PipeCtx, from: usize, tag: u64) -> Result<()> {
        let meta = self.meta;
        let li = *meta
            .peer_link
            .get(from)
            .ok_or_else(|| anyhow::anyhow!("message from out-of-range rank {from}"))?;
        ensure!(li != usize::MAX, "unexpected message from peer {from}");
        let link = &meta.links[li];
        let words = link.recv_words(ctx.part, ctx.b, ctx.me, tag) * ctx.r;
        comm.recv_into(from, tag, &mut self.scratch[..words])?;
        let mut off = 0usize;
        match tag {
            TAG_GATHER => {
                for &i in &link.row_blocks {
                    let s = ctx.slots[i];
                    let rg = ctx.part.portion(i, from, ctx.b);
                    let len = rg.len() * ctx.r;
                    self.xbuf[(s * ctx.b + rg.start) * ctx.r..(s * ctx.b + rg.end) * ctx.r]
                        .copy_from_slice(&self.scratch[off..off + len]);
                    off += len;
                    self.panel_waits[s] -= 1;
                    if self.panel_waits[s] == 0 {
                        for &bid in &meta.slot_blocks[s] {
                            self.block_deps[bid as usize] -= 1;
                            if self.block_deps[bid as usize] == 0 {
                                self.ready.push(bid);
                            }
                        }
                    }
                }
                self.p1_left -= 1;
            }
            TAG_REDUCE => {
                for &i in &link.row_blocks {
                    let s = ctx.slots[i];
                    let rg = ctx.part.portion(i, ctx.me, ctx.b);
                    let len = rg.len() * ctx.r;
                    let dst = &mut self.ybuf
                        [(s * ctx.b + rg.start) * ctx.r..(s * ctx.b + rg.end) * ctx.r];
                    for (o, v) in dst.iter_mut().zip(&self.scratch[off..off + len]) {
                        *o += v;
                    }
                    off += len;
                }
                self.p3_left -= 1;
            }
            other => bail!("unknown overlap tag {other} from {from}"),
        }
        // Payload accounting: the segments must tile the message exactly,
        // up to the All-to-All zero padding.
        debug_assert!(
            off == words || (link.pad_words != 0 && off <= words),
            "unpacked {off} of {words} words from {from} tag {tag}"
        );
        Ok(())
    }

    /// Record a finished block contraction: decrement the contribution
    /// counters of the slots it fed, and stream every phase-3 message whose
    /// last awaited slot just finalized.
    fn note_block_done(
        &mut self,
        comm: &mut Comm,
        ctx: &PipeCtx,
        view: &PackedBlockView,
    ) -> Result<()> {
        let meta = self.meta;
        let (i, j, k) = (view.bi, view.bj, view.bk);
        let (fi, fj, fk) = factors(classify(i, j, k), i, j, k);
        for (idx, f) in [(i, fi), (j, fj), (k, fk)] {
            if f == 0.0 {
                continue;
            }
            let s = ctx.slots[idx];
            self.slot_contribs[s] -= 1;
            if self.slot_contribs[s] == 0 {
                for &li in &meta.slot_links[s] {
                    let li = li as usize;
                    self.p3_waits[li] -= 1;
                    if self.p3_waits[li] == 0 {
                        self.send_reduce(comm, ctx, li)?;
                    }
                }
            }
        }
        self.blocks_left -= 1;
        Ok(())
    }

    /// Stream the phase-3 reduce message of link `li`: my partials of the
    /// destination's portions, packed in the phased segment order.
    fn send_reduce(&mut self, comm: &mut Comm, ctx: &PipeCtx, li: usize) -> Result<()> {
        let meta = self.meta;
        let link = &meta.links[li];
        debug_assert_eq!(self.p3_waits[li], 0);
        self.p3_waits[li] = u32::MAX; // sent sentinel
        self.payload.clear();
        for &i in &link.row_blocks {
            let s = ctx.slots[i];
            let rg = ctx.part.portion(i, link.peer, ctx.b);
            self.payload.extend_from_slice(
                &self.ybuf[(s * ctx.b + rg.start) * ctx.r..(s * ctx.b + rg.end) * ctx.r],
            );
        }
        if link.pad_words != 0 {
            debug_assert!(self.payload.len() <= link.pad_words * ctx.r);
            self.payload.resize(link.pad_words * ctx.r, 0.0);
        }
        comm.isend(link.peer, TAG_REDUCE, &self.payload)
    }
}

/// ybuf[slot panel] += f · c over one contiguous (b, r) panel (vectorized
/// lane helper; bitwise identical to the scalar loop it replaced).
fn axpy_panel(ybuf: &mut [f32], slot: usize, panel: usize, f: f32, c: &[f32]) {
    if f == 0.0 {
        return;
    }
    lanes_axpy(&mut ybuf[slot * panel..(slot + 1) * panel], f, c);
}

/// Reusable buffers for the phased [`exchange`] path: one payload staging
/// buffer (cleared and re-packed per message, sent through the pooled
/// [`Comm::isend`]) and one receive scratch buffer (filled by
/// [`Comm::recv_into`], unpacked from borrowed sub-slices). Hoisted to the
/// caller so both vector phases share them — after warm-up the phased path
/// performs zero per-message heap allocations, like the overlap pipeline.
#[derive(Default)]
struct ExchangeBufs {
    payload: Vec<f32>,
    scratch: Vec<f32>,
}

/// Execute one vector-exchange phase under the chosen comm mode, with
/// `r` words per vector coordinate (r-deep column packing; r = 1 is the
/// paper's single-vector accounting).
///
/// `pack(i, to, state, out)` appends the payload segment for shared row
/// block `i` destined to processor `to` onto `out`; `unpack(i, from, data,
/// state)` consumes a received segment borrowed from the receive scratch —
/// no per-segment allocation on either side. Payload layout: segments
/// concatenated in the sorted order of the transfer's shared row blocks,
/// each segment an interleaved (portion_len, r) panel.
#[allow(clippy::too_many_arguments)]
fn exchange<S>(
    comm: &mut Comm,
    part: &TetraPartition,
    sched: &CommSchedule,
    b: usize,
    r: usize,
    mode: CommMode,
    phase: u64,
    mut pack: impl FnMut(usize, usize, &S, &mut Vec<f32>),
    mut unpack: impl FnMut(usize, usize, &[f32], &mut S),
    state: &mut S,
    bufs: &mut ExchangeBufs,
) -> Result<()> {
    let me = comm.rank;
    // phase 0 payload: sender's portion; phase 1: receiver's portion
    let seg_words = |i: usize, from: usize| {
        r * if phase == 0 {
            part.portion(i, from, b).len()
        } else {
            part.portion(i, me, b).len()
        }
    };
    match mode {
        CommMode::PointToPoint => {
            for (si, step) in sched.steps.iter().enumerate() {
                let tag = phase * 1_000_000 + si as u64;
                let mut incoming = None;
                for &xi in step {
                    let xf = &sched.xfers[xi];
                    if xf.from == me {
                        bufs.payload.clear();
                        for &i in &xf.row_blocks {
                            pack(i, xf.to, state, &mut bufs.payload);
                        }
                        comm.isend(xf.to, tag, &bufs.payload)?;
                    }
                    if xf.to == me {
                        incoming = Some(xi);
                    }
                }
                if let Some(xi) = incoming {
                    let xf = &sched.xfers[xi];
                    let words: usize = xf.row_blocks.iter().map(|&i| seg_words(i, xf.from)).sum();
                    bufs.scratch.resize(words, 0.0);
                    comm.recv_into(xf.from, tag, &mut bufs.scratch[..words])?;
                    let mut off = 0usize;
                    for &i in &xf.row_blocks {
                        let len = seg_words(i, xf.from);
                        unpack(i, xf.from, &bufs.scratch[off..off + len], state);
                        off += len;
                    }
                    debug_assert_eq!(off, words);
                }
                comm.barrier();
            }
        }
        CommMode::AllToAll => {
            // Bandwidth-optimal All-to-All: P−1 rounds; uniform per-peer
            // buffer of 2 row-block portions (§7.2.2 accounting), r words
            // deep per coordinate. Pairs sharing fewer than 2 row blocks
            // pad with zeros.
            let lambda1 = part.lambda1();
            let slot = b.div_ceil(lambda1);
            let buf_words = 2 * slot * r;
            for round in 1..part.p {
                let to = (me + round) % part.p;
                let from = (me + part.p - round) % part.p;
                let tag = phase * 1_000_000 + 1000 + round as u64;
                let shared_out: Vec<usize> = part.r_p[me]
                    .iter()
                    .copied()
                    .filter(|i| part.r_p[to].contains(i))
                    .collect();
                bufs.payload.clear();
                for &i in &shared_out {
                    pack(i, to, state, &mut bufs.payload);
                }
                bufs.payload.resize(buf_words, 0.0);
                comm.isend(to, tag, &bufs.payload)?;

                let shared_in: Vec<usize> = part.r_p[me]
                    .iter()
                    .copied()
                    .filter(|i| part.r_p[from].contains(i))
                    .collect();
                bufs.scratch.resize(buf_words, 0.0);
                comm.recv_into(from, tag, &mut bufs.scratch[..buf_words])?;
                let mut off = 0usize;
                for &i in &shared_in {
                    let len = seg_words(i, from);
                    unpack(i, from, &bufs.scratch[off..off + len], state);
                    off += len;
                }
                debug_assert!(off <= buf_words);
                comm.barrier();
            }
        }
    }
    Ok(())
}

/// Communication-only dry run: executes the exchange phases with correctly
/// sized (zero) payloads and no tensor or compute, so comm costs can be
/// measured for large q/P without materializing an n³/6 tensor.
pub fn run_comm_only(part: &TetraPartition, b: usize, mode: CommMode) -> Result<Vec<CommStats>> {
    run_comm_only_multi(part, b, mode, 1)
}

/// Communication-only dry run of an r-column batched STTSV: every payload
/// is r words deep per coordinate, so per-processor words are exactly r×
/// the [`run_comm_only`] counts while message counts are identical.
pub fn run_comm_only_multi(
    part: &TetraPartition,
    b: usize,
    mode: CommMode,
    r: usize,
) -> Result<Vec<CommStats>> {
    let sched = CommSchedule::build(part)?;
    let outs = simulator::run(part.p, |comm| {
        let me = comm.rank;
        let mut state = ();
        let mut bufs = ExchangeBufs::default();
        for phase in 0..2u64 {
            exchange(
                comm,
                part,
                &sched,
                b,
                r,
                mode,
                phase,
                |i, to, _state: &(), out: &mut Vec<f32>| {
                    let rg = if phase == 0 {
                        part.portion(i, me, b)
                    } else {
                        part.portion(i, to, b)
                    };
                    out.resize(out.len() + rg.len() * r, 0.0);
                },
                |_, _, _, _| {},
                &mut state,
                &mut bufs,
            )?;
        }
        Ok(comm.stats)
    })?;
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::steiner::{spherical, sqs8};
    use crate::util::rng::Rng;

    fn check_matches_oracle(part: &TetraPartition, b: usize, opts: ExecOpts, seed: u64) {
        let n = part.m * b;
        let tensor = SymTensor::random(n, seed);
        let mut rng = Rng::new(seed + 1);
        let x = rng.normal_vec(n);
        let want = tensor.sttsv(&x);
        let rep = run_sttsv_opts(&tensor, &x, part, opts).unwrap();
        let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
        for i in 0..n {
            assert!(
                (rep.y[i] - want[i]).abs() < 2e-3 * scale,
                "i={i}: {} vs {} (scale {scale})",
                rep.y[i],
                want[i]
            );
        }
    }

    #[test]
    fn algorithm5_matches_oracle_q2_p2p() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        for overlap in [false, true] {
            for batch in [false, true] {
                for packed in [false, true] {
                    for compiled in [false, true] {
                        check_matches_oracle(
                            &part,
                            8,
                            ExecOpts {
                                mode: CommMode::PointToPoint,
                                backend: Backend::Native,
                                batch,
                                packed,
                                overlap,
                                compiled,
                                ..Default::default()
                            },
                            7,
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn algorithm5_matches_oracle_q2_a2a() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        check_matches_oracle(
            &part,
            6,
            ExecOpts { mode: CommMode::AllToAll, ..Default::default() },
            8,
        );
    }

    #[test]
    fn algorithm5_matches_oracle_sqs8() {
        let part = TetraPartition::from_steiner(&sqs8()).unwrap();
        for packed in [false, true] {
            check_matches_oracle(&part, 7, ExecOpts { packed, ..Default::default() }, 9);
        }
    }

    #[test]
    fn algorithm5_matches_oracle_q3() {
        let part = TetraPartition::from_steiner(&spherical(3).unwrap()).unwrap();
        check_matches_oracle(&part, 12, ExecOpts::default(), 10);
    }

    #[test]
    fn run_multi_matches_independent_oracles() {
        // The r-column batched path must agree column-by-column with r
        // independent sequential oracle STTSVs, in both comm modes, on a
        // partition exercising all three block kinds.
        for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
            let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
            let b = 6;
            let n = b * part.m;
            let tensor = SymTensor::random(n, 91);
            let mut rng = Rng::new(92);
            let r = 3;
            let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
            for batch in [false, true] {
                for packed in [false, true] {
                    // overlap: false pins the phased batched/unbatched
                    // dispatch paths; overlap equivalence is property P8.
                    let plan = SttsvPlan::new(
                        &tensor,
                        &part,
                        ExecOpts {
                            mode,
                            backend: Backend::Native,
                            batch,
                            packed,
                            overlap: false,
                            // pin the INTERPRETED dispatch paths; the
                            // compiled path's equivalence is property P10
                            compiled: false,
                            ..Default::default()
                        },
                    )
                    .unwrap();
                    let rep = plan.run_multi(&xs).unwrap();
                    assert_eq!(rep.nrhs(), r);
                    for (l, x) in xs.iter().enumerate() {
                        let want = tensor.sttsv(x);
                        let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
                        for i in 0..n {
                            assert!(
                                (rep.ys[l][i] - want[i]).abs() < 3e-3 * scale,
                                "mode {mode:?} batch {batch} packed {packed} col {l} \
                                 i={i}: {} vs {}",
                                rep.ys[l][i],
                                want[i]
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn run_multi_comm_is_r_deep_packed() {
        // Per-processor words scale as EXACTLY r× the r = 1 counts; message
        // counts are unchanged — in both comm modes, including uneven
        // portion splits (λ₁ ∤ b).
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 7;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 93);
        let mut rng = Rng::new(94);
        let r = 5;
        for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
            let plan = SttsvPlan::new(
                &tensor,
                &part,
                ExecOpts { mode, ..Default::default() },
            )
            .unwrap();
            let single = plan.run(&rng.normal_vec(n)).unwrap();
            let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
            let multi = plan.run_multi(&xs).unwrap();
            for p in 0..part.p {
                let s1 = &single.per_proc[p].stats;
                let sm = &multi.per_proc[p].stats;
                assert_eq!(sm.sent_words, r as u64 * s1.sent_words, "{mode:?} proc {p} sent");
                assert_eq!(sm.recv_words, r as u64 * s1.recv_words, "{mode:?} proc {p} recv");
                assert_eq!(sm.sent_msgs, s1.sent_msgs, "{mode:?} proc {p} sent msgs");
                assert_eq!(sm.recv_msgs, s1.recv_msgs, "{mode:?} proc {p} recv msgs");
            }
            // the comm-only dry run predicts the same counts
            let dry = run_comm_only_multi(&part, b, mode, r).unwrap();
            for p in 0..part.p {
                assert_eq!(multi.per_proc[p].stats.sent_words, dry[p].sent_words);
                assert_eq!(multi.per_proc[p].stats.recv_words, dry[p].recv_words);
            }
        }
    }

    #[test]
    fn run_multi_ternary_mults_scale_with_r() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 4;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 95);
        let mut rng = Rng::new(96);
        let r = 3;
        let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
        let plan = SttsvPlan::new(&tensor, &part, ExecOpts::default()).unwrap();
        let rep = plan.run_multi(&xs).unwrap();
        assert_eq!(
            rep.total_ternary_mults(),
            r as u64 * (n * n * (n + 1) / 2) as u64
        );
    }

    #[test]
    fn comm_words_match_paper_formula_exactly() {
        // §7.2.2: each processor sends and receives n(q+1)/(q²+1) − n/P
        // words per vector, so 2× that across both phases.
        for q in [2usize, 3] {
            let part =
                TetraPartition::from_steiner(&spherical(q as u64).unwrap()).unwrap();
            let lambda1 = q * (q + 1);
            let b = lambda1; // divisible ⇒ formula exact
            let n = b * part.m;
            let tensor = SymTensor::random(n, 3);
            let mut rng = Rng::new(4);
            let x = rng.normal_vec(n);
            let rep = run_sttsv(&tensor, &x, &part, CommMode::PointToPoint, Backend::Native)
                .unwrap();
            let expected = 2 * (n * (q + 1) / (q * q + 1) - n / part.p) as u64;
            for (p, r) in rep.per_proc.iter().enumerate() {
                assert_eq!(r.stats.sent_words, expected, "q={q} proc {p} sent");
                assert_eq!(r.stats.recv_words, expected, "q={q} proc {p} recv");
            }
        }
    }

    #[test]
    fn comm_only_matches_full_run_counts() {
        let q = 2usize;
        let part = TetraPartition::from_steiner(&spherical(q as u64).unwrap()).unwrap();
        let b = q * (q + 1);
        let n = b * part.m;
        let tensor = SymTensor::random(n, 5);
        let mut rng = Rng::new(6);
        let x = rng.normal_vec(n);
        let full = run_sttsv(&tensor, &x, &part, CommMode::PointToPoint, Backend::Native)
            .unwrap();
        let dry = run_comm_only(&part, b, CommMode::PointToPoint).unwrap();
        for p in 0..part.p {
            assert_eq!(full.per_proc[p].stats.sent_words, dry[p].sent_words);
            assert_eq!(full.per_proc[p].stats.recv_words, dry[p].recv_words);
        }
    }

    #[test]
    fn ternary_mult_totals_match_algorithm4() {
        // total over processors = n²(n+1)/2 (§3): every lower-tetra point
        // computed exactly once.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 6;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 11);
        let mut rng = Rng::new(12);
        let x = rng.normal_vec(n);
        let rep = run_sttsv(&tensor, &x, &part, CommMode::PointToPoint, Backend::Native)
            .unwrap();
        assert_eq!(
            rep.total_ternary_mults(),
            (n * n * (n + 1) / 2) as u64
        );
    }

    #[test]
    fn alltoall_costs_double_p2p_leading_term() {
        let q = 3usize;
        let part = TetraPartition::from_steiner(&spherical(q as u64).unwrap()).unwrap();
        let b = q * (q + 1) * 2;
        let dry_p2p = run_comm_only(&part, b, CommMode::PointToPoint).unwrap();
        let dry_a2a = run_comm_only(&part, b, CommMode::AllToAll).unwrap();
        let max_p2p = dry_p2p.iter().map(|s| s.sent_words).max().unwrap();
        let max_a2a = dry_a2a.iter().map(|s| s.sent_words).max().unwrap();
        let expected_a2a = 2 * (2 * b / (q * (q + 1))) * (part.p - 1);
        assert_eq!(max_a2a, expected_a2a as u64);
        // a2a / p2p → 2(q²+1)/(q+1)² (→ 2 as q grows); at q=3 it is 20/16.
        let ratio = max_a2a as f64 / max_p2p as f64;
        let expected = 2.0 * (q * q + 1) as f64 / ((q + 1) * (q + 1)) as f64;
        assert!(
            (ratio - expected).abs() < 0.08,
            "ratio {ratio} vs expected {expected} ({max_a2a} vs {max_p2p})"
        );
    }

    #[test]
    fn padded_run_matches_oracle_on_awkward_n() {
        // m = 5 (q = 2); n = 23 is not a multiple of 5 → pad to 25.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let n = 23;
        let tensor = SymTensor::random(n, 77);
        let mut rng = Rng::new(78);
        let x = rng.normal_vec(n);
        let want = tensor.sttsv(&x);
        let rep = run_sttsv_padded(&tensor, &x, &part, ExecOpts::default()).unwrap();
        assert_eq!(rep.y.len(), n);
        let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
        for i in 0..n {
            assert!((rep.y[i] - want[i]).abs() < 3e-3 * scale, "i={i}");
        }
    }

    #[test]
    fn padded_run_truncates_y_and_bounds_comm_overhead() {
        // Regression for the §6.1 n′ analysis: a padded run (n = 23 on the
        // m = 5 partition → b′ = 5) must (a) truncate y back to n, (b)
        // account communication exactly like a dry run at the padded block
        // size, and (c) stay within one block's worth of words per phase of
        // the exact-n closed form 2·(n(q+1)/(q²+1) − n/P).
        let q = 2usize;
        let part = TetraPartition::from_steiner(&spherical(q as u64).unwrap()).unwrap();
        let n = 23usize;
        let b2 = n.div_ceil(part.m); // 5
        let tensor = SymTensor::random(n, 79);
        let mut rng = Rng::new(80);
        let x = rng.normal_vec(n);
        let rep = run_sttsv_padded(&tensor, &x, &part, ExecOpts::default()).unwrap();
        assert_eq!(rep.y.len(), n, "y must be truncated back to n");

        let dry = run_comm_only(&part, b2, CommMode::PointToPoint).unwrap();
        for (p, pr) in rep.per_proc.iter().enumerate() {
            assert_eq!(pr.stats.sent_words, dry[p].sent_words, "proc {p} vs dry run");
        }
        // The paper's bandwidth cost is the max over processors; padding may
        // shift words between processors but the max exceeds the exact-n
        // closed form by at most one block's worth per phase.
        let ideal_max = 2.0
            * (n as f64 * (q + 1) as f64 / (q * q + 1) as f64 - n as f64 / part.p as f64);
        let max_sent = rep.max_sent_words() as f64;
        let extra = max_sent - ideal_max;
        assert!(
            (0.0..=2.0 * b2 as f64).contains(&extra),
            "padding overhead {extra} words (max {max_sent} vs ideal \
             {ideal_max}) exceeds one block ({b2}) per phase"
        );
    }

    #[test]
    fn uneven_portions_still_correct() {
        // b not divisible by λ₁ exercises the ±1 portion ranges.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        for packed in [false, true] {
            check_matches_oracle(
                &part,
                7, // λ₁ = 6 does not divide 7
                ExecOpts { packed, ..Default::default() },
                13,
            );
        }
    }

    #[test]
    fn packed_plan_is_zero_copy_and_matches_dense_extract() {
        // Acceptance for §Perf P7: the packed plan holds NO dense tensor
        // copies (O(1) views only — its tensor memory beyond the shared
        // SymTensor buffer is zero words), while the dense-extract plan
        // re-materializes every owned block (more than the whole packed
        // footprint again, b³ per block); and both paths agree within 1e-4
        // on random inputs for r ∈ {1, 4}.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 6usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 101);
        let packed_plan = SttsvPlan::new(&tensor, &part, ExecOpts::default()).unwrap();
        assert_eq!(packed_plan.resident_tensor_words(), 0);
        let dense_plan = SttsvPlan::new(
            &tensor,
            &part,
            ExecOpts { packed: false, ..Default::default() },
        )
        .unwrap();
        let total_blocks = part.m * (part.m + 1) * (part.m + 2) / 6;
        assert_eq!(dense_plan.resident_tensor_words(), total_blocks * b * b * b);
        assert!(dense_plan.resident_tensor_words() > tensor.packed_len());

        let mut rng = Rng::new(102);
        for r in [1usize, 4] {
            let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
            let yp = packed_plan.run_multi(&xs).unwrap();
            let yd = dense_plan.run_multi(&xs).unwrap();
            for l in 0..r {
                let scale = yd.ys[l].iter().map(|v| v.abs()).fold(1.0f32, f32::max);
                for i in 0..n {
                    assert!(
                        (yp.ys[l][i] - yd.ys[l][i]).abs() < 1e-4 * scale,
                        "r={r} col {l} i={i}: packed {} vs dense {}",
                        yp.ys[l][i],
                        yd.ys[l][i]
                    );
                }
            }
        }
    }

    #[test]
    fn packed_and_dense_plans_report_identical_comm_and_mults() {
        // The storage layout must not change the distributed semantics:
        // per-processor words, messages, and charged ternary mults are
        // identical between packed and dense-extract plans.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 5usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 103);
        let mut rng = Rng::new(104);
        let x = rng.normal_vec(n);
        let reps: Vec<SttsvReport> = [true, false]
            .iter()
            .map(|&packed| {
                SttsvPlan::new(&tensor, &part, ExecOpts { packed, ..Default::default() })
                    .unwrap()
                    .run(&x)
                    .unwrap()
            })
            .collect();
        for p in 0..part.p {
            let (a, d) = (&reps[0].per_proc[p], &reps[1].per_proc[p]);
            assert_eq!(a.stats.sent_words, d.stats.sent_words, "proc {p} words");
            assert_eq!(a.stats.sent_msgs, d.stats.sent_msgs, "proc {p} msgs");
            assert_eq!(a.ternary_mults, d.ternary_mults, "proc {p} mults");
        }
    }

    #[test]
    fn overlap_is_comm_cost_invariant_and_matches_phased() {
        // Acceptance for §Perf P8: the pipeline may reorder every arrival
        // and interleave compute with communication, but per-processor
        // words AND messages must equal the phased path exactly, in both
        // comm modes — the α-β-γ model cost is invariant — and the results
        // agree within f32 reassociation tolerance. b = 7 exercises uneven
        // portions.
        for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
            let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
            let b = 7usize;
            let n = b * part.m;
            let tensor = SymTensor::random(n, 301);
            let mut rng = Rng::new(302);
            let x = rng.normal_vec(n);
            let phased = SttsvPlan::new(
                &tensor,
                &part,
                ExecOpts { mode, overlap: false, ..Default::default() },
            )
            .unwrap()
            .run(&x)
            .unwrap();
            let overlap = SttsvPlan::new(
                &tensor,
                &part,
                ExecOpts { mode, overlap: true, ..Default::default() },
            )
            .unwrap()
            .run(&x)
            .unwrap();
            for p in 0..part.p {
                let (a, o) = (&phased.per_proc[p].stats, &overlap.per_proc[p].stats);
                assert_eq!(a, o, "{mode:?} proc {p} comm stats");
                assert_eq!(
                    phased.per_proc[p].ternary_mults, overlap.per_proc[p].ternary_mults,
                    "{mode:?} proc {p} mults"
                );
            }
            let scale = phased.y.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
            for i in 0..n {
                assert!(
                    (overlap.y[i] - phased.y[i]).abs() < 1e-4 * scale,
                    "{mode:?} i={i}: overlap {} vs phased {}",
                    overlap.y[i],
                    phased.y[i]
                );
            }
        }
    }

    #[test]
    fn steady_state_runs_allocate_no_payload_buffers() {
        // The plan lends per-processor buffer pools to every run: the first
        // run warms them (one allocation per simultaneously-in-flight
        // message), every later run must allocate NOTHING on the payload
        // path — overlap and phased alike (§Perf P8 acceptance).
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 6usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 303);
        let mut rng = Rng::new(304);
        let x = rng.normal_vec(n);
        for overlap in [true, false] {
            let plan = SttsvPlan::new(
                &tensor,
                &part,
                ExecOpts { overlap, ..Default::default() },
            )
            .unwrap();
            let first = plan.run(&x).unwrap();
            assert!(first.fresh_payload_allocs > 0, "cold pools must allocate");
            for round in 0..2 {
                let rep = plan.run(&x).unwrap();
                assert_eq!(
                    rep.fresh_payload_allocs, 0,
                    "overlap={overlap} round {round}: steady-state run allocated"
                );
            }
        }
    }

    #[test]
    fn compiled_phased_is_bitwise_the_interpreter() {
        // §Perf P10 acceptance (deterministic half): on the phased path at
        // compute_threads = 1, the compiled sweep program must reproduce
        // the interpreted packed plan BIT FOR BIT — same kernels'
        // arithmetic replayed from precompiled descriptors, same block
        // order, same reduce order — for r ∈ {1, 4} in both comm modes,
        // with per-processor words, messages, and charged mults exactly
        // equal. b = 7 exercises uneven portions.
        for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
            let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
            let b = 7usize;
            let n = b * part.m;
            let tensor = SymTensor::random(n, 501);
            let mut rng = Rng::new(502);
            let compiled_plan = SttsvPlan::new(
                &tensor,
                &part,
                ExecOpts { mode, overlap: false, ..Default::default() },
            )
            .unwrap();
            assert_eq!(compiled_plan.sweep_program_builds(), part.p as u64);
            assert_eq!(compiled_plan.resident_tensor_words(), 0);
            let interp_plan = SttsvPlan::new(
                &tensor,
                &part,
                ExecOpts { mode, overlap: false, compiled: false, ..Default::default() },
            )
            .unwrap();
            assert_eq!(interp_plan.sweep_program_builds(), 0);
            for r in [1usize, 4] {
                let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
                let rc = compiled_plan.run_multi(&xs).unwrap();
                let ri = interp_plan.run_multi(&xs).unwrap();
                for l in 0..r {
                    for i in 0..n {
                        assert_eq!(
                            rc.ys[l][i].to_bits(),
                            ri.ys[l][i].to_bits(),
                            "{mode:?} r={r} col {l} i={i}: compiled {} vs interpreted {}",
                            rc.ys[l][i],
                            ri.ys[l][i]
                        );
                    }
                }
                for p in 0..part.p {
                    assert_eq!(
                        rc.per_proc[p].stats, ri.per_proc[p].stats,
                        "{mode:?} r={r} proc {p} comm"
                    );
                    assert_eq!(
                        rc.per_proc[p].ternary_mults, ri.per_proc[p].ternary_mults,
                        "{mode:?} r={r} proc {p} mults"
                    );
                }
            }
        }
    }

    #[test]
    fn compute_pool_is_comm_invariant_and_matches_sequential() {
        // The intra-worker pool may regroup the f32 block accumulation
        // (privatized panels + ordered reduction) but must not move a
        // single word or message, must charge identical mults, and must
        // agree with the single-threaded oracle within reassociation
        // tolerance — phased and overlap, r ∈ {1, 4}.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 6usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 503);
        let mut rng = Rng::new(504);
        for overlap in [false, true] {
            let seq_opts = ExecOpts { overlap, ..Default::default() };
            let seq_plan = SttsvPlan::new(&tensor, &part, seq_opts).unwrap();
            let pool_opts = ExecOpts { overlap, compute_threads: 4, ..Default::default() };
            let pool_plan = SttsvPlan::new(&tensor, &part, pool_opts).unwrap();
            for r in [1usize, 4] {
                let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
                let rs = seq_plan.run_multi(&xs).unwrap();
                let rp = pool_plan.run_multi(&xs).unwrap();
                for p in 0..part.p {
                    assert_eq!(
                        rs.per_proc[p].stats, rp.per_proc[p].stats,
                        "overlap={overlap} r={r} proc {p}: pool moved comm"
                    );
                    assert_eq!(
                        rs.per_proc[p].ternary_mults, rp.per_proc[p].ternary_mults,
                        "overlap={overlap} r={r} proc {p} mults"
                    );
                }
                for l in 0..r {
                    let scale = rs.ys[l].iter().map(|v| v.abs()).fold(1.0f32, f32::max);
                    for i in 0..n {
                        assert!(
                            (rp.ys[l][i] - rs.ys[l][i]).abs() < 1e-4 * scale,
                            "overlap={overlap} r={r} col {l} i={i}: pool {} vs seq {}",
                            rp.ys[l][i],
                            rs.ys[l][i]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn compiled_program_charges_equal_descriptor_mults() {
        // Extends P7 to the compiled path: the per-block §7.1 charge the
        // program stores == the descriptor stream's executed count == the
        // kernels' own loop-bound walk, for every owned block.
        let part = TetraPartition::from_steiner(&sqs8()).unwrap();
        let b = 5usize;
        let tensor = SymTensor::random(b * part.m, 505);
        let plan = SttsvPlan::new(&tensor, &part, ExecOpts::default()).unwrap();
        for p in 0..part.p {
            let prog = &plan.programs[p];
            for blk in &prog.blocks {
                let executed: u64 = prog.descs[blk.dstart as usize..blk.dend as usize]
                    .iter()
                    .map(|d| {
                        let run = crate::tensor::PackedRun {
                            cls: d.cls,
                            base: d.base as usize,
                            len: d.len as usize,
                            alpha: d.x as usize,
                            beta: d.y as usize,
                            flush: d.flush,
                        };
                        run.ternary_mults()
                    })
                    .sum();
                assert_eq!(executed, blk.mults, "proc {p}");
            }
            // and the per-processor total matches the charged accounting
            let total: u64 = prog.blocks.iter().map(|bl| bl.mults).sum();
            let charged: u64 = part
                .owned_blocks(p)
                .iter()
                .map(|&(i, j, k)| block_ternary_mults(classify(i, j, k), b as u64))
                .sum();
            assert_eq!(total, charged, "proc {p}");
        }
    }

    #[test]
    fn normalize_canonicalizes_flag_interactions() {
        // The ExecOpts::normalize table: compiled requires packed Native;
        // the pool requires compiled; compute_threads >= 1.
        let o = ExecOpts { backend: Backend::Pjrt, ..Default::default() }.normalize();
        assert!(!o.compiled, "PJRT cannot execute sweep programs");
        assert_eq!(o.compute_threads, 1);
        let o = ExecOpts { packed: false, compute_threads: 8, ..Default::default() }.normalize();
        assert!(!o.compiled, "dense-extract plans stay interpreted");
        assert_eq!(o.compute_threads, 1, "pool requires a compiled program");
        let o = ExecOpts { compute_threads: 0, ..Default::default() }.normalize();
        assert_eq!(o.compute_threads, 1);
        let o = ExecOpts { compute_threads: 4, ..Default::default() }.normalize();
        assert!(o.compiled);
        assert_eq!(o.compute_threads, 4);
        // bf16 wire forces f32 elements (the wire wins); an f32 wire
        // leaves the requested precision alone.
        let o = ExecOpts {
            wire: WireFormat::Bf16,
            precision: Precision::F64,
            ..Default::default()
        }
        .normalize();
        assert_eq!(o.precision, Precision::F32, "bf16 wire forces f32 elements");
        let o = ExecOpts { precision: Precision::F64, ..Default::default() }.normalize();
        assert_eq!(o.precision, Precision::F64);
        // ABFT rides the compiled path: on it, verification pins the
        // bitwise-deterministic phased sequential execution; off it, the
        // request downgrades silently like the other table rules.
        let o = ExecOpts {
            abft: AbftMode::Verify,
            overlap: true,
            compute_threads: 4,
            ..Default::default()
        }
        .normalize();
        assert!(o.abft.on() && o.compiled);
        assert!(!o.overlap, "ABFT forces the phased path");
        assert_eq!(o.compute_threads, 1, "ABFT forces sequential exec");
        let o = ExecOpts {
            abft: AbftMode::Scrub,
            backend: Backend::Pjrt,
            ..Default::default()
        }
        .normalize();
        assert!(!o.abft.on(), "no compiled programs, no checksum exec");
        // plans normalize on construction: a PJRT-flagged compiled request
        // builds no programs (and still runs, via the interpreter)
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let tensor = SymTensor::random(4 * part.m, 507);
        let plan = SttsvPlan::new(
            &tensor,
            &part,
            ExecOpts { packed: false, compute_threads: 4, ..Default::default() },
        )
        .unwrap();
        assert_eq!(plan.sweep_program_builds(), 0);
        assert!(plan.programs.is_empty());
    }

    #[test]
    fn expected_proc_stats_matches_comm_only_dry_run() {
        // The pure-accounting closed form resident sessions assert against
        // must reproduce the measured dry-run counters exactly — both comm
        // modes, uneven portions (λ₁ ∤ b), r ∈ {1, 3}.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 7usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 401);
        for mode in [CommMode::PointToPoint, CommMode::AllToAll] {
            let plan =
                SttsvPlan::new(&tensor, &part, ExecOpts { mode, ..Default::default() }).unwrap();
            for r in [1usize, 3] {
                let want = run_comm_only_multi(&part, b, mode, r).unwrap();
                assert_eq!(plan.expected_proc_stats(r), want, "mode {mode:?} r={r}");
            }
        }
    }

    #[test]
    fn overlap_matches_phased_for_multi_rhs_and_matches_dry_run() {
        // run_multi through the pipeline: column-exact within tolerance,
        // comm equal to the phased dry-run prediction (words r×, messages
        // r-independent).
        let part = TetraPartition::from_steiner(&sqs8()).unwrap();
        let b = 5usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 305);
        let mut rng = Rng::new(306);
        let r = 3usize;
        let xs: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
        let plan = SttsvPlan::new(&tensor, &part, ExecOpts::default()).unwrap();
        assert!(plan.opts.overlap, "overlap must be the default");
        let rep = plan.run_multi(&xs).unwrap();
        for (l, x) in xs.iter().enumerate() {
            let want = tensor.sttsv(x);
            let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
            for i in 0..n {
                assert!(
                    (rep.ys[l][i] - want[i]).abs() < 3e-3 * scale,
                    "col {l} i={i}: {} vs {}",
                    rep.ys[l][i],
                    want[i]
                );
            }
        }
        let dry = run_comm_only(&part, b, CommMode::PointToPoint).unwrap();
        for p in 0..part.p {
            let s = &rep.per_proc[p].stats;
            assert_eq!(s.sent_words, r as u64 * dry[p].sent_words, "proc {p} words");
            assert_eq!(s.sent_msgs, dry[p].sent_msgs, "proc {p} msgs");
            assert_eq!(s.recv_words, r as u64 * dry[p].recv_words, "proc {p} recv words");
            assert_eq!(s.recv_msgs, dry[p].recv_msgs, "proc {p} recv msgs");
        }
    }
}
