//! The L3 coordinator: Algorithm 5 (parallel STTSV) end to end, on the
//! instrumented simulator, with local block computations dispatched to the
//! runtime engine (AOT Pallas kernels via PJRT, or native loops).
//!
//! Phases (paper Algorithm 5):
//!   1. gather x  — each processor collects the full row blocks x[i],
//!      i ∈ R_p, from the other processors of Q_i (lines 3–14);
//!   2. local ternary multiplications over owned tensor blocks via the
//!      fused block kernel (lines 15–28);
//!   3. scatter-reduce y — partial results for row block i are exchanged
//!      and summed so each processor ends with its y[i]^(p) (lines 29–41).
//!
//! Both vector phases run either over the Theorem 6 point-to-point schedule
//! (comm cost = the lower bound's leading term, exactly) or as All-to-All
//! collectives (2× the leading term — §7.2.2).

pub mod baselines;

use crate::partition::{classify, BlockKind, TetraPartition};
use crate::runtime::{Backend, Engine};
use crate::schedule::CommSchedule;
use crate::simulator::{self, Comm, CommStats};
use crate::tensor::SymTensor;
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// How vector data moves between processors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommMode {
    /// Theorem 6 point-to-point schedule: comm matches the lower bound's
    /// leading term exactly.
    PointToPoint,
    /// All-to-All collectives (§7.2.2): simpler, 2× the leading term.
    AllToAll,
}

impl std::str::FromStr for CommMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "p2p" | "point-to-point" => Ok(CommMode::PointToPoint),
            "a2a" | "all-to-all" => Ok(CommMode::AllToAll),
            other => bail!("unknown comm mode '{other}' (use p2p|a2a)"),
        }
    }
}

/// Execution options for [`run_sttsv_opts`].
#[derive(Debug, Clone, Copy)]
pub struct ExecOpts {
    pub mode: CommMode,
    pub backend: Backend,
    /// Batch all owned blocks of a type into one kernel dispatch (the L3
    /// hot-path optimization; see EXPERIMENTS.md §Perf).
    pub batch: bool,
}

impl Default for ExecOpts {
    fn default() -> Self {
        ExecOpts {
            mode: CommMode::PointToPoint,
            backend: Backend::Native,
            batch: true,
        }
    }
}

/// Per-processor execution report.
#[derive(Debug, Clone)]
pub struct ProcReport {
    pub stats: CommStats,
    /// Logical ternary multiplications (paper §7.1 accounting).
    pub ternary_mults: u64,
    pub compute_time: Duration,
}

/// Whole-run report.
#[derive(Debug, Clone)]
pub struct SttsvReport {
    /// The assembled result y = A ×₂ x ×₃ x.
    pub y: Vec<f32>,
    pub per_proc: Vec<ProcReport>,
    /// Communication steps per vector phase.
    pub steps_per_phase: usize,
    pub elapsed: Duration,
}

impl SttsvReport {
    /// Max over processors of words sent (the paper's bandwidth cost).
    pub fn max_sent_words(&self) -> u64 {
        self.per_proc.iter().map(|r| r.stats.sent_words).max().unwrap_or(0)
    }

    /// Max over processors of words received.
    pub fn max_recv_words(&self) -> u64 {
        self.per_proc.iter().map(|r| r.stats.recv_words).max().unwrap_or(0)
    }

    /// Max logical ternary multiplications on any processor (§7.1).
    pub fn max_ternary_mults(&self) -> u64 {
        self.per_proc.iter().map(|r| r.ternary_mults).max().unwrap_or(0)
    }

    /// Total logical ternary multiplications across processors.
    pub fn total_ternary_mults(&self) -> u64 {
        self.per_proc.iter().map(|r| r.ternary_mults).sum()
    }
}

/// Scaling factors (α, β, γ) applied to (ci, cj, ck) per block kind — the
/// multiplicity bookkeeping of Algorithm 5 lines 17–27.
fn factors(kind: BlockKind, i: usize, j: usize, k: usize) -> (f32, f32, f32) {
    match kind {
        BlockKind::OffDiagonal => (2.0, 2.0, 2.0),
        BlockKind::NonCentralDiagonal => {
            if i == j {
                // (a,a,b): y[a] += 2·ci, y[b] += 1·ck
                (2.0, 0.0, 1.0)
            } else {
                debug_assert_eq!(j, k);
                // (a,b,b): y[a] += 1·ci, y[b] += 2·cj
                (1.0, 2.0, 0.0)
            }
        }
        BlockKind::CentralDiagonal => (1.0, 0.0, 0.0),
    }
}

/// Logical ternary multiplications for a block of size b (paper §7.1).
fn block_ternary_mults(kind: BlockKind, b: u64) -> u64 {
    match kind {
        BlockKind::OffDiagonal => 3 * b * b * b,
        BlockKind::NonCentralDiagonal => 3 * b * b * (b - 1) / 2 + 2 * b * b,
        BlockKind::CentralDiagonal => b * (b - 1) * (b - 2) / 2 + 2 * b * (b - 1) + b,
    }
}

/// Run parallel STTSV with default options (point-to-point, native, batched).
pub fn run_sttsv(
    tensor: &SymTensor,
    x: &[f32],
    part: &TetraPartition,
    mode: CommMode,
    backend: Backend,
) -> Result<SttsvReport> {
    run_sttsv_opts(tensor, x, part, ExecOpts { mode, backend, ..Default::default() })
}

/// Run parallel STTSV (Algorithm 5) on the simulated machine.
///
/// Builds a fresh [`SttsvPlan`] and runs it once; iterative callers (power
/// method, CP gradient) should build the plan themselves and reuse it — the
/// tensor-block extraction is input-independent (§Perf P5).
pub fn run_sttsv_opts(
    tensor: &SymTensor,
    x: &[f32],
    part: &TetraPartition,
    opts: ExecOpts,
) -> Result<SttsvReport> {
    SttsvPlan::new(tensor, part, opts)?.run(x)
}

/// Run parallel STTSV for an n that does NOT divide into the partition's m
/// row blocks: pads the tensor and vector to the next multiple of m with
/// zeros (paper §6.1), runs Algorithm 5, and truncates y back to length n.
/// Padding inflates the communication accounting by at most one block's
/// worth (the padded coordinates still travel) — the paper's n′ analysis.
pub fn run_sttsv_padded(
    tensor: &SymTensor,
    x: &[f32],
    part: &TetraPartition,
    opts: ExecOpts,
) -> Result<SttsvReport> {
    let n = tensor.n;
    if n % part.m == 0 {
        return run_sttsv_opts(tensor, x, part, opts);
    }
    let n2 = n.div_ceil(part.m) * part.m;
    let padded = tensor.padded(n2);
    let mut xp = x.to_vec();
    xp.resize(n2, 0.0);
    let mut rep = run_sttsv_opts(&padded, &xp, part, opts)?;
    rep.y.truncate(n);
    Ok(rep)
}

/// A same-kind batch of extracted tensor blocks owned by one processor.
struct Group {
    blocks: Vec<(usize, usize, usize)>,
    /// Concatenated dense b³ blocks, ready for the (batched) kernel.
    a: Vec<f32>,
}

/// A prepared distributed STTSV: partition + Theorem 6 schedule + the
/// owner-compute block data, extracted once. `run` is then a function of
/// the input vector only — mirroring the paper's point that the tensor is
/// never communicated (here: never re-extracted) across repeated STTSVs.
pub struct SttsvPlan<'p> {
    part: &'p TetraPartition,
    sched: CommSchedule,
    b: usize,
    n: usize,
    opts: ExecOpts,
    engine: Engine,
    /// groups[p] = per-kind batches for processor p.
    groups: Vec<Vec<Group>>,
}

impl<'p> SttsvPlan<'p> {
    /// Prepare a plan: validate shapes, build the schedule, and extract
    /// every processor's blocks (grouped by kind for batched dispatch).
    pub fn new(
        tensor: &SymTensor,
        part: &'p TetraPartition,
        opts: ExecOpts,
    ) -> Result<SttsvPlan<'p>> {
        let n = tensor.n;
        ensure!(
            n % part.m == 0,
            "n = {n} must be a multiple of m = {} (pad the tensor; §6.1)",
            part.m
        );
        let b = n / part.m;
        let engine = Engine::shared(opts.backend)?;
        let sched = CommSchedule::build(part)?;
        let mut groups: Vec<Vec<Group>> = Vec::with_capacity(part.p);
        for p in 0..part.p {
            let mut by_kind: [Vec<(usize, usize, usize)>; 3] = Default::default();
            for &(i, j, k) in &part.owned_blocks(p) {
                let slot = match classify(i, j, k) {
                    BlockKind::OffDiagonal => 0,
                    BlockKind::NonCentralDiagonal => 1,
                    BlockKind::CentralDiagonal => 2,
                };
                by_kind[slot].push((i, j, k));
            }
            let mut proc_groups = Vec::new();
            for blocks in by_kind.into_iter().filter(|v| !v.is_empty()) {
                let mut a = Vec::with_capacity(blocks.len() * b * b * b);
                for &(i, j, k) in &blocks {
                    a.extend(tensor.extract_block(i, j, k, b));
                }
                proc_groups.push(Group { blocks, a });
            }
            groups.push(proc_groups);
        }
        Ok(SttsvPlan {
            part,
            sched,
            b,
            n,
            opts,
            engine,
            groups,
        })
    }

    /// Execute the distributed STTSV for one input vector.
    pub fn run(&self, x: &[f32]) -> Result<SttsvReport> {
        ensure!(x.len() == self.n, "x length {} != n {}", x.len(), self.n);
        let part = self.part;
        let b = self.b;
        let started = Instant::now();

        type ProcOut = (
            CommStats,
            u64,
            Duration,
            Vec<(usize, std::ops::Range<usize>, Vec<f32>)>,
        );
        let outs: Vec<ProcOut> =
            simulator::run(part.p, |comm| self.worker(comm, x))?;

        // Assemble y from the final portions (each (i, sub-range) once).
        let mut y = vec![0.0f32; self.n];
        let mut covered = vec![false; self.n];
        let mut per_proc = Vec::with_capacity(part.p);
        for (stats, mults, ct, portions) in outs {
            for (i, range, vals) in portions {
                for (off, v) in range.clone().zip(vals) {
                    let g = i * b + off;
                    ensure!(!covered[g], "y[{g}] produced twice");
                    covered[g] = true;
                    y[g] = v;
                }
            }
            per_proc.push(ProcReport {
                stats,
                ternary_mults: mults,
                compute_time: ct,
            });
        }
        ensure!(covered.iter().all(|&c| c), "y not fully covered");

        let steps_per_phase = match self.opts.mode {
            CommMode::PointToPoint => self.sched.num_steps(),
            CommMode::AllToAll => part.p - 1,
        };
        Ok(SttsvReport {
            y,
            per_proc,
            steps_per_phase,
            elapsed: started.elapsed(),
        })
    }

    /// One simulated processor executing Algorithm 5.
    fn worker(
        &self,
        comm: &mut Comm,
        x: &[f32],
    ) -> Result<(
        CommStats,
        u64,
        Duration,
        Vec<(usize, std::ops::Range<usize>, Vec<f32>)>,
    )> {
        let me = comm.rank;
        let part = self.part;
        let b = self.b;
        let opts = self.opts;

        // ---- phase 1: gather full row blocks x[i], i ∈ R_p ----------------
        let mut my_x: HashMap<usize, Vec<f32>> = HashMap::new();
        for &i in &part.r_p[me] {
            let mut buf = vec![0.0f32; b];
            let r = part.portion(i, me, b);
            buf[r.clone()].copy_from_slice(&x[i * b + r.start..i * b + r.end]);
            my_x.insert(i, buf);
        }
        exchange(
            comm,
            part,
            &self.sched,
            b,
            opts.mode,
            0,
            // pack: my own portion of each shared row block
            |i, _to, my_x: &HashMap<usize, Vec<f32>>| {
                let r = part.portion(i, me, b);
                my_x[&i][r].to_vec()
            },
            // unpack: sender's portion of row block i
            |i, from, data, my_x: &mut HashMap<usize, Vec<f32>>| {
                let r = part.portion(i, from, b);
                my_x.get_mut(&i).unwrap()[r].copy_from_slice(&data);
            },
            &mut my_x,
        )?;

        // ---- phase 2: local ternary multiplications -----------------------
        let compute_start = Instant::now();
        let mut my_y: HashMap<usize, Vec<f32>> = part.r_p[me]
            .iter()
            .map(|&i| (i, vec![0.0f32; b]))
            .collect();
        let mut mults: u64 = 0;

        for group in &self.groups[me] {
            let nb = group.blocks.len();
            if opts.batch {
                let mut us = Vec::with_capacity(nb * b);
                let mut vs = Vec::with_capacity(nb * b);
                let mut ws = Vec::with_capacity(nb * b);
                for &(i, j, k) in &group.blocks {
                    us.extend_from_slice(&my_x[&i]);
                    vs.extend_from_slice(&my_x[&j]);
                    ws.extend_from_slice(&my_x[&k]);
                }
                let (cis, cjs, cks) =
                    self.engine
                        .block_contract_batch(&group.a, &us, &vs, &ws, b, nb)?;
                for (s, &(i, j, k)) in group.blocks.iter().enumerate() {
                    let kind = classify(i, j, k);
                    let (fi, fj, fk) = factors(kind, i, j, k);
                    accumulate(&mut my_y, i, fi, &cis[s * b..(s + 1) * b]);
                    accumulate(&mut my_y, j, fj, &cjs[s * b..(s + 1) * b]);
                    accumulate(&mut my_y, k, fk, &cks[s * b..(s + 1) * b]);
                    mults += block_ternary_mults(kind, b as u64);
                }
            } else {
                for (s, &(i, j, k)) in group.blocks.iter().enumerate() {
                    let kind = classify(i, j, k);
                    let a = &group.a[s * b * b * b..(s + 1) * b * b * b];
                    let (ci, cj, ck) = self
                        .engine
                        .block_contract(a, &my_x[&i], &my_x[&j], &my_x[&k], b)?;
                    let (fi, fj, fk) = factors(kind, i, j, k);
                    accumulate(&mut my_y, i, fi, &ci);
                    accumulate(&mut my_y, j, fj, &cj);
                    accumulate(&mut my_y, k, fk, &ck);
                    mults += block_ternary_mults(kind, b as u64);
                }
            }
        }
        let compute_time = compute_start.elapsed();

        // ---- phase 3: scatter-reduce y ------------------------------------
        exchange(
            comm,
            part,
            &self.sched,
            b,
            opts.mode,
            1,
            // pack: MY partial of the DESTINATION's portion of row block i
            |i, to, my_y: &HashMap<usize, Vec<f32>>| {
                let r = part.portion(i, to, b);
                my_y[&i][r].to_vec()
            },
            // unpack: add sender's partial of MY portion
            |i, _from, data, my_y: &mut HashMap<usize, Vec<f32>>| {
                let r = part.portion(i, me, b);
                let buf = my_y.get_mut(&i).unwrap();
                for (off, v) in r.zip(data) {
                    buf[off] += v;
                }
            },
            &mut my_y,
        )?;

        // Final owned portions of y.
        let portions: Vec<(usize, std::ops::Range<usize>, Vec<f32>)> = part.r_p[me]
            .iter()
            .map(|&i| {
                let r = part.portion(i, me, b);
                (i, r.clone(), my_y[&i][r].to_vec())
            })
            .collect();

        Ok((comm.stats, mults, compute_time, portions))
    }
}

fn accumulate(y: &mut HashMap<usize, Vec<f32>>, i: usize, f: f32, c: &[f32]) {
    if f == 0.0 {
        return;
    }
    let buf = y.get_mut(&i).unwrap();
    for (o, v) in buf.iter_mut().zip(c) {
        *o += f * v;
    }
}

/// Execute one vector-exchange phase under the chosen comm mode.
///
/// `pack(i, to)` produces the payload segment for shared row block `i`
/// destined to processor `to`; `unpack(i, from, data, state)` consumes a
/// received segment. Payload layout: segments concatenated in the sorted
/// order of the transfer's shared row blocks.
#[allow(clippy::too_many_arguments)]
fn exchange<S>(
    comm: &mut Comm,
    part: &TetraPartition,
    sched: &CommSchedule,
    b: usize,
    mode: CommMode,
    phase: u64,
    mut pack: impl FnMut(usize, usize, &S) -> Vec<f32>,
    mut unpack: impl FnMut(usize, usize, Vec<f32>, &mut S),
    state: &mut S,
) -> Result<()> {
    let me = comm.rank;
    match mode {
        CommMode::PointToPoint => {
            for (si, step) in sched.steps.iter().enumerate() {
                let tag = phase * 1_000_000 + si as u64;
                let mut incoming = None;
                for &xi in step {
                    let xf = &sched.xfers[xi];
                    if xf.from == me {
                        let mut payload = Vec::new();
                        for &i in &xf.row_blocks {
                            payload.extend(pack(i, xf.to, state));
                        }
                        comm.send(xf.to, tag, payload)?;
                    }
                    if xf.to == me {
                        incoming = Some(xi);
                    }
                }
                if let Some(xi) = incoming {
                    let xf = &sched.xfers[xi];
                    let data = comm.recv(xf.from, tag)?;
                    let mut off = 0usize;
                    for &i in &xf.row_blocks {
                        // phase 0 payload: sender's portion; phase 1: my portion
                        let len = if phase == 0 {
                            part.portion(i, xf.from, b).len()
                        } else {
                            part.portion(i, me, b).len()
                        };
                        let seg = data[off..off + len].to_vec();
                        off += len;
                        unpack(i, xf.from, seg, state);
                    }
                    debug_assert_eq!(off, data.len());
                }
                comm.barrier();
            }
        }
        CommMode::AllToAll => {
            // Bandwidth-optimal All-to-All: P−1 rounds; uniform per-peer
            // buffer of 2 row-block portions (§7.2.2 accounting). Pairs
            // sharing fewer than 2 row blocks pad with zeros.
            let lambda1 = part.lambda1();
            let slot = b.div_ceil(lambda1);
            let buf_words = 2 * slot;
            for round in 1..part.p {
                let to = (me + round) % part.p;
                let from = (me + part.p - round) % part.p;
                let tag = phase * 1_000_000 + 1000 + round as u64;
                let shared_out: Vec<usize> = part.r_p[me]
                    .iter()
                    .copied()
                    .filter(|i| part.r_p[to].contains(i))
                    .collect();
                let mut payload = Vec::with_capacity(buf_words);
                for &i in &shared_out {
                    payload.extend(pack(i, to, state));
                }
                payload.resize(buf_words, 0.0);
                comm.send(to, tag, payload)?;

                let shared_in: Vec<usize> = part.r_p[me]
                    .iter()
                    .copied()
                    .filter(|i| part.r_p[from].contains(i))
                    .collect();
                let data = comm.recv(from, tag)?;
                let mut off = 0usize;
                for &i in &shared_in {
                    let len = if phase == 0 {
                        part.portion(i, from, b).len()
                    } else {
                        part.portion(i, me, b).len()
                    };
                    let seg = data[off..off + len].to_vec();
                    off += len;
                    unpack(i, from, seg, state);
                }
                comm.barrier();
            }
        }
    }
    Ok(())
}

/// Communication-only dry run: executes the exchange phases with correctly
/// sized (zero) payloads and no tensor or compute, so comm costs can be
/// measured for large q/P without materializing an n³/6 tensor.
pub fn run_comm_only(part: &TetraPartition, b: usize, mode: CommMode) -> Result<Vec<CommStats>> {
    let sched = CommSchedule::build(part)?;
    let outs = simulator::run(part.p, |comm| {
        let me = comm.rank;
        let mut state = ();
        for phase in 0..2u64 {
            exchange(
                comm,
                part,
                &sched,
                b,
                mode,
                phase,
                |i, to, _state| {
                    let r = if phase == 0 {
                        part.portion(i, me, b)
                    } else {
                        part.portion(i, to, b)
                    };
                    vec![0.0f32; r.len()]
                },
                |_, _, _, _| {},
                &mut state,
            )?;
        }
        Ok(comm.stats)
    })?;
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::steiner::{spherical, sqs8};
    use crate::util::rng::Rng;

    fn check_matches_oracle(part: &TetraPartition, b: usize, opts: ExecOpts, seed: u64) {
        let n = part.m * b;
        let tensor = SymTensor::random(n, seed);
        let mut rng = Rng::new(seed + 1);
        let x = rng.normal_vec(n);
        let want = tensor.sttsv(&x);
        let rep = run_sttsv_opts(&tensor, &x, part, opts).unwrap();
        let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
        for i in 0..n {
            assert!(
                (rep.y[i] - want[i]).abs() < 2e-3 * scale,
                "i={i}: {} vs {} (scale {scale})",
                rep.y[i],
                want[i]
            );
        }
    }

    #[test]
    fn algorithm5_matches_oracle_q2_p2p() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        for batch in [false, true] {
            check_matches_oracle(
                &part,
                8,
                ExecOpts { mode: CommMode::PointToPoint, backend: Backend::Native, batch },
                7,
            );
        }
    }

    #[test]
    fn algorithm5_matches_oracle_q2_a2a() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        check_matches_oracle(
            &part,
            6,
            ExecOpts { mode: CommMode::AllToAll, backend: Backend::Native, batch: true },
            8,
        );
    }

    #[test]
    fn algorithm5_matches_oracle_sqs8() {
        let part = TetraPartition::from_steiner(&sqs8()).unwrap();
        check_matches_oracle(
            &part,
            7,
            ExecOpts { mode: CommMode::PointToPoint, backend: Backend::Native, batch: true },
            9,
        );
    }

    #[test]
    fn algorithm5_matches_oracle_q3() {
        let part = TetraPartition::from_steiner(&spherical(3).unwrap()).unwrap();
        check_matches_oracle(
            &part,
            12,
            ExecOpts { mode: CommMode::PointToPoint, backend: Backend::Native, batch: true },
            10,
        );
    }

    #[test]
    fn comm_words_match_paper_formula_exactly() {
        // §7.2.2: each processor sends and receives n(q+1)/(q²+1) − n/P
        // words per vector, so 2× that across both phases.
        for q in [2usize, 3] {
            let part =
                TetraPartition::from_steiner(&spherical(q as u64).unwrap()).unwrap();
            let lambda1 = q * (q + 1);
            let b = lambda1; // divisible ⇒ formula exact
            let n = b * part.m;
            let tensor = SymTensor::random(n, 3);
            let mut rng = Rng::new(4);
            let x = rng.normal_vec(n);
            let rep = run_sttsv(&tensor, &x, &part, CommMode::PointToPoint, Backend::Native)
                .unwrap();
            let expected = 2 * (n * (q + 1) / (q * q + 1) - n / part.p) as u64;
            for (p, r) in rep.per_proc.iter().enumerate() {
                assert_eq!(r.stats.sent_words, expected, "q={q} proc {p} sent");
                assert_eq!(r.stats.recv_words, expected, "q={q} proc {p} recv");
            }
        }
    }

    #[test]
    fn comm_only_matches_full_run_counts() {
        let q = 2usize;
        let part = TetraPartition::from_steiner(&spherical(q as u64).unwrap()).unwrap();
        let b = q * (q + 1);
        let n = b * part.m;
        let tensor = SymTensor::random(n, 5);
        let mut rng = Rng::new(6);
        let x = rng.normal_vec(n);
        let full = run_sttsv(&tensor, &x, &part, CommMode::PointToPoint, Backend::Native)
            .unwrap();
        let dry = run_comm_only(&part, b, CommMode::PointToPoint).unwrap();
        for p in 0..part.p {
            assert_eq!(full.per_proc[p].stats.sent_words, dry[p].sent_words);
            assert_eq!(full.per_proc[p].stats.recv_words, dry[p].recv_words);
        }
    }

    #[test]
    fn ternary_mult_totals_match_algorithm4() {
        // total over processors = n²(n+1)/2 (§3): every lower-tetra point
        // computed exactly once.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 6;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 11);
        let mut rng = Rng::new(12);
        let x = rng.normal_vec(n);
        let rep = run_sttsv(&tensor, &x, &part, CommMode::PointToPoint, Backend::Native)
            .unwrap();
        assert_eq!(
            rep.total_ternary_mults(),
            (n * n * (n + 1) / 2) as u64
        );
    }

    #[test]
    fn alltoall_costs_double_p2p_leading_term() {
        let q = 3usize;
        let part = TetraPartition::from_steiner(&spherical(q as u64).unwrap()).unwrap();
        let b = q * (q + 1) * 2;
        let dry_p2p = run_comm_only(&part, b, CommMode::PointToPoint).unwrap();
        let dry_a2a = run_comm_only(&part, b, CommMode::AllToAll).unwrap();
        let max_p2p = dry_p2p.iter().map(|s| s.sent_words).max().unwrap();
        let max_a2a = dry_a2a.iter().map(|s| s.sent_words).max().unwrap();
        let n = b * part.m;
        let expected_a2a = 2 * (2 * b / (q * (q + 1))) * (part.p - 1);
        assert_eq!(max_a2a, expected_a2a as u64);
        // a2a / p2p → 2(q²+1)/(q+1)² (→ 2 as q grows); at q=3 it is 20/16.
        let ratio = max_a2a as f64 / max_p2p as f64;
        let expected = 2.0 * (q * q + 1) as f64 / ((q + 1) * (q + 1)) as f64;
        assert!(
            (ratio - expected).abs() < 0.08,
            "ratio {ratio} vs expected {expected} ({max_a2a} vs {max_p2p})"
        );
        let _ = n;
    }

    #[test]
    fn padded_run_matches_oracle_on_awkward_n() {
        // m = 5 (q = 2); n = 23 is not a multiple of 5 → pad to 25.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let n = 23;
        let tensor = SymTensor::random(n, 77);
        let mut rng = Rng::new(78);
        let x = rng.normal_vec(n);
        let want = tensor.sttsv(&x);
        let rep = run_sttsv_padded(&tensor, &x, &part, ExecOpts::default()).unwrap();
        assert_eq!(rep.y.len(), n);
        let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
        for i in 0..n {
            assert!((rep.y[i] - want[i]).abs() < 3e-3 * scale, "i={i}");
        }
    }

    #[test]
    fn uneven_portions_still_correct() {
        // b not divisible by λ₁ exercises the ±1 portion ranges.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        check_matches_oracle(
            &part,
            7, // λ₁ = 6 does not divide 7
            ExecOpts { mode: CommMode::PointToPoint, backend: Backend::Native, batch: true },
            13,
        );
    }
}
