//! Baseline parallel STTSV algorithms for the paper's comparisons.
//!
//! * [`run_naive_grid`] — the Algorithm 3 flavor: the **full** n³ iteration
//!   space distributed over a near-cubic 3-D processor grid, no symmetry
//!   exploitation. Its comm cost tracks the non-symmetric Loomis–Whitney
//!   bound (`bounds::nonsymmetric_lower_bound_words`) and its arithmetic is
//!   ≈ 2× Algorithm 5's.
//! * [`run_sequence`] — the §8 "sequence" approach: T = A ×₂ x as a parallel
//!   matrix-like product over plane-distributed A, then y = T x locally.
//!   Communication is Θ(n) per processor for P ≤ n (ring allgather of x) —
//!   asymptotically worse than Algorithm 5's O(n/P^{1/3}).

use crate::simulator::{self, CommStats};
use crate::tensor::SymTensor;
use anyhow::{ensure, Result};

/// Report for a baseline run.
#[derive(Debug, Clone)]
pub struct BaselineReport {
    pub y: Vec<f32>,
    pub per_proc: Vec<CommStats>,
    /// Elementary multiply-add pairs performed per processor (flop/2).
    pub flops_per_proc: Vec<u64>,
}

impl BaselineReport {
    pub fn max_sent_words(&self) -> u64 {
        self.per_proc.iter().map(|s| s.sent_words).max().unwrap_or(0)
    }
    pub fn max_recv_words(&self) -> u64 {
        self.per_proc.iter().map(|s| s.recv_words).max().unwrap_or(0)
    }
}

/// Factor P into a near-cubic grid (p1, p2, p3), p1·p2·p3 = P, minimizing
/// the spread max/min.
pub fn grid_dims(p: usize) -> (usize, usize, usize) {
    let mut best = (1, 1, p);
    let mut best_spread = p;
    for p1 in 1..=p {
        if p % p1 != 0 {
            continue;
        }
        let rest = p / p1;
        for p2 in 1..=rest {
            if rest % p2 != 0 {
                continue;
            }
            let p3 = rest / p2;
            let hi = p1.max(p2).max(p3);
            let lo = p1.min(p2).min(p3);
            if hi - lo < best_spread {
                best_spread = hi - lo;
                best = (p1, p2, p3);
            }
        }
    }
    best
}

fn split_range(n: usize, parts: usize, idx: usize) -> std::ops::Range<usize> {
    let base = n / parts;
    let extra = n % parts;
    let start = idx * base + idx.min(extra);
    start..start + base + usize::from(idx < extra)
}

/// Naive dense 3-D grid STTSV (no symmetry): processor (i1,i2,i3) owns the
/// brick I₁×I₂×I₃ of the *full* cube and computes partial
/// y[I₁] += Σ_{j∈I₂, k∈I₃} A[i,j,k]·x_j·x_k.
///
/// x starts distributed n/P per processor (rank order); the final y is
/// distributed the same way. Measured comm: gathering the needed x ranges
/// plus the all-to-all reduce-scatter of partial y over each grid row.
pub fn run_naive_grid(tensor: &SymTensor, x: &[f32], p: usize) -> Result<BaselineReport> {
    let n = tensor.n;
    ensure!(x.len() == n);
    let (p1, p2, p3) = grid_dims(p);
    let coords = |rank: usize| -> (usize, usize, usize) {
        (rank / (p2 * p3), (rank / p3) % p2, rank % p3)
    };

    type Out = (CommStats, u64, Vec<(usize, f32)>);
    let outs: Vec<Out> = simulator::run(p, |comm| {
        let me = comm.rank;
        let (c1, c2, c3) = coords(me);
        let (ri, rj, rk) = (
            split_range(n, p1, c1),
            split_range(n, p2, c2),
            split_range(n, p3, c3),
        );

        // -- gather x[rj ∪ rk] from the n/P-block owners ------------------
        let mut xe = vec![0.0f32; n];
        let mut have = vec![false; n];
        let own = split_range(n, p, me);
        for g in own.clone() {
            xe[g] = x[g];
            have[g] = true;
        }
        // Deterministic index list a requester needs from an owner: the
        // intersection of the requester's (rj ∪ rk) with the owner's n/P
        // range, sorted and deduplicated. Both sides compute this, so only
        // the *values* travel (honest word counting).
        let wanted = |req: usize, owner: usize| -> Vec<usize> {
            let (_, t2, t3) = coords(req);
            let t_rj = split_range(n, p2, t2);
            let t_rk = split_range(n, p3, t3);
            let orange = split_range(n, p, owner);
            let mut gs: Vec<usize> = orange
                .filter(|g| t_rj.contains(g) || t_rk.contains(g))
                .collect();
            gs.dedup();
            gs
        };
        // symmetric rounds: in round r exchange with me±r. One reused
        // staging buffer per direction: after the first rounds warm the
        // comm pool, the whole gather runs allocation-free.
        let mut sbuf: Vec<f32> = Vec::new();
        let mut rbuf: Vec<f32> = Vec::new();
        for round in 1..p {
            let to = (me + round) % p;
            let from = (me + p - round) % p;
            let out_idx = wanted(to, me);
            if !out_idx.is_empty() {
                sbuf.clear();
                sbuf.extend(out_idx.iter().map(|&g| x[g]));
                comm.isend(to, 100 + round as u64, &sbuf)?;
            }
            let in_idx = wanted(me, from);
            if !in_idx.is_empty() {
                rbuf.resize(in_idx.len(), 0.0);
                comm.recv_into(from, 100 + round as u64, &mut rbuf)?;
                for (g, v) in in_idx.into_iter().zip(rbuf.iter().copied()) {
                    xe[g] = v;
                    have[g] = true;
                }
            }
            comm.barrier();
        }
        for g in rj.clone().chain(rk.clone()) {
            ensure!(have[g], "missing x[{g}]");
        }

        // -- local partial y over the owned brick (full cube, no symmetry) -
        let mut part_y = vec![0.0f32; ri.len()];
        let mut flops: u64 = 0;
        for (ii, i) in ri.clone().enumerate() {
            let mut acc = 0.0f64;
            for j in rj.clone() {
                let xj = xe[j] as f64;
                let mut inner = 0.0f64;
                for k in rk.clone() {
                    inner += tensor.get(i, j, k) as f64 * xe[k] as f64;
                }
                acc += inner * xj;
                flops += rk.len() as u64 * 2;
            }
            part_y[ii] = acc as f32;
        }

        // -- reduce partial y across the p2·p3 processors sharing c1, then
        //    deliver to the final n/P owners. Reduce-scatter: the grid row's
        //    m members each accumulate one 1/m chunk of ri.
        let row: Vec<usize> = (0..p)
            .filter(|&r| coords(r).0 == c1)
            .collect();
        let mpos = row.iter().position(|&r| r == me).unwrap();
        let m = row.len();
        for (t, &peer) in row.iter().enumerate() {
            if peer == me {
                continue;
            }
            let chunk = split_range(ri.len(), m, t);
            comm.isend(peer, 200 + t as u64, &part_y[chunk])?;
        }
        let my_chunk = split_range(ri.len(), m, mpos);
        let mut reduced: Vec<f32> = part_y[my_chunk.clone()].to_vec();
        rbuf.resize(my_chunk.len(), 0.0);
        for &peer in &row {
            if peer == me {
                continue;
            }
            comm.recv_into(peer, 200 + mpos as u64, &mut rbuf)?;
            for (o, v) in reduced.iter_mut().zip(rbuf.iter().copied()) {
                *o += v;
            }
        }
        comm.barrier();

        // final y entries this proc produced (global index, value)
        let final_y: Vec<(usize, f32)> = my_chunk
            .clone()
            .zip(reduced)
            .map(|(off, v)| (ri.start + off, v))
            .collect();
        Ok((comm.stats, flops, final_y))
    })?;

    let mut y = vec![0.0f32; n];
    let mut per_proc = Vec::new();
    let mut flops_per_proc = Vec::new();
    for (stats, flops, parts) in outs {
        for (g, v) in parts {
            y[g] = v;
        }
        per_proc.push(stats);
        flops_per_proc.push(flops);
    }
    Ok(BaselineReport { y, per_proc, flops_per_proc })
}

/// The §8 sequence approach: plane-distributed T = A ×₂ x then local
/// y = T·x. A ring allgather replicates x on every processor — Θ(n) words
/// per processor, independent of P (for P ≤ n), which is the cost the paper
/// contrasts with Algorithm 5's Θ(n/P^{1/3}).
pub fn run_sequence(tensor: &SymTensor, x: &[f32], p: usize) -> Result<BaselineReport> {
    let n = tensor.n;
    ensure!(x.len() == n);

    type Out = (CommStats, u64, Vec<(usize, f32)>);
    let outs: Vec<Out> = simulator::run(p, |comm| {
        let me = comm.rank;
        let own = split_range(n, p, me);

        // ring allgather of x: P−1 rounds, forward the previously received
        // segment; each processor sends and receives n − n/P words total.
        let mut xe = vec![0.0f32; n];
        xe[own.clone()].copy_from_slice(&x[own.clone()]);
        let next = (me + 1) % p;
        let prev = (me + p - 1) % p;
        let mut cur = own.clone();
        for round in 0..p - 1 {
            comm.isend(next, 300 + round as u64, &xe[cur.clone()])?;
            let seg_owner = (me + p - 1 - round % p) % p;
            let seg = split_range(n, p, seg_owner);
            comm.recv_into(prev, 300 + round as u64, &mut xe[seg.clone()])?;
            cur = seg;
            comm.barrier();
        }

        // local: T_i,k = Σ_j A[i,j,k] x_j for owned planes; then y_i = Σ_k T_i,k x_k.
        // (2n²/P + 2n/P extra flops vs the fused form — the §8 accounting.)
        let mut flops: u64 = 0;
        let mut final_y = Vec::with_capacity(own.len());
        let mut t_row = vec![0.0f32; n];
        for i in own.clone() {
            for k in 0..n {
                let mut acc = 0.0f64;
                for j in 0..n {
                    acc += tensor.get(i, j, k) as f64 * xe[j] as f64;
                }
                t_row[k] = acc as f32;
                flops += n as u64 * 2;
            }
            let mut yi = 0.0f64;
            for k in 0..n {
                yi += t_row[k] as f64 * xe[k] as f64;
            }
            flops += n as u64 * 2;
            final_y.push((i, yi as f32));
        }
        Ok((comm.stats, flops, final_y))
    })?;

    let mut y = vec![0.0f32; n];
    let mut per_proc = Vec::new();
    let mut flops_per_proc = Vec::new();
    for (stats, flops, parts) in outs {
        for (g, v) in parts {
            y[g] = v;
        }
        per_proc.push(stats);
        flops_per_proc.push(flops);
    }
    Ok(BaselineReport { y, per_proc, flops_per_proc })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn grid_dims_factorizations() {
        assert_eq!(grid_dims(8), (2, 2, 2));
        assert_eq!(grid_dims(27), (3, 3, 3));
        let (a, b, c) = grid_dims(30);
        assert_eq!(a * b * c, 30);
        assert!(a.max(b).max(c) <= 5);
        assert_eq!(grid_dims(1), (1, 1, 1));
    }

    #[test]
    fn naive_grid_matches_oracle() {
        for p in [4usize, 8, 10] {
            let n = 24;
            let tensor = SymTensor::random(n, 21);
            let mut rng = Rng::new(22);
            let x = rng.normal_vec(n);
            let want = tensor.sttsv(&x);
            let rep = run_naive_grid(&tensor, &x, p).unwrap();
            let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
            for i in 0..n {
                assert!(
                    (rep.y[i] - want[i]).abs() < 2e-3 * scale,
                    "p={p} i={i}: {} vs {}",
                    rep.y[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn sequence_matches_oracle() {
        for p in [3usize, 6] {
            let n = 18;
            let tensor = SymTensor::random(n, 23);
            let mut rng = Rng::new(24);
            let x = rng.normal_vec(n);
            let want = tensor.sttsv(&x);
            let rep = run_sequence(&tensor, &x, p).unwrap();
            let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
            for i in 0..n {
                assert!(
                    (rep.y[i] - want[i]).abs() < 2e-3 * scale,
                    "p={p} i={i}: {} vs {}",
                    rep.y[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn sequence_comm_is_theta_n() {
        // ring allgather: every processor sends and receives n − n/P words.
        let n = 20;
        let p = 5;
        let tensor = SymTensor::random(n, 25);
        let mut rng = Rng::new(26);
        let x = rng.normal_vec(n);
        let rep = run_sequence(&tensor, &x, p).unwrap();
        for s in &rep.per_proc {
            assert_eq!(s.recv_words, (n - n / p) as u64);
            assert_eq!(s.sent_words, (n - n / p) as u64);
        }
    }
}
