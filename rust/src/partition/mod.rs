//! Tetrahedral block partitioning (§6 of the paper).
//!
//! Given a Steiner (m, r, 3) system, the strict lower tetrahedron of the
//! block-index space {(i,j,k) : i > j > k} is partitioned into tetrahedral
//! blocks TB₃(R_p): processor p owns every off-diagonal block whose three
//! distinct indices all lie in its Steiner block R_p. Diagonal blocks
//! ((a,a,b), (a,b,b) non-central; (a,a,a) central) are assigned by bipartite
//! matching so that their computations need no vector data beyond what the
//! off-diagonal assignment already requires (§6.1.3).

use crate::matching::{disjoint_matchings, hopcroft_karp};
use crate::steiner::SteinerSystem;
use anyhow::{bail, Context, Result};

/// Classification of a lower-tetrahedral block index (i >= j >= k).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockKind {
    /// i > j > k
    OffDiagonal,
    /// exactly two indices equal: (a,a,b) or (a,b,b) with a > b
    NonCentralDiagonal,
    /// i == j == k
    CentralDiagonal,
}

/// Classify a lower-tetrahedral block index triple (requires i >= j >= k).
pub fn classify(i: usize, j: usize, k: usize) -> BlockKind {
    assert!(i >= j && j >= k, "block index must satisfy i >= j >= k");
    if i > j && j > k {
        BlockKind::OffDiagonal
    } else if i == j && j == k {
        BlockKind::CentralDiagonal
    } else {
        BlockKind::NonCentralDiagonal
    }
}

/// Scaling factors (α, β, γ) applied to a block's three contraction
/// outputs (ci, cj, ck) — the multiplicity bookkeeping of Algorithm 5
/// lines 17–27. Lives next to [`classify`] so the coordinator's
/// accumulation loops, the overlap readiness metadata, and the compiled
/// sweep-program builder all read one source of truth.
pub fn factors(kind: BlockKind, i: usize, j: usize, k: usize) -> (f32, f32, f32) {
    match kind {
        BlockKind::OffDiagonal => (2.0, 2.0, 2.0),
        BlockKind::NonCentralDiagonal => {
            if i == j {
                // (a,a,b): y[a] += 2·ci, y[b] += 1·ck
                (2.0, 0.0, 1.0)
            } else {
                debug_assert_eq!(j, k);
                // (a,b,b): y[a] += 1·ci, y[b] += 2·cj
                (1.0, 2.0, 0.0)
            }
        }
        BlockKind::CentralDiagonal => (1.0, 0.0, 0.0),
    }
}

/// Logical ternary multiplications for a block of size b (paper §7.1),
/// per right-hand-side column. The same counts fall out of the packed
/// kernels' loop bounds ([`crate::runtime::packed_ternary_mults`]) and of
/// the compiled descriptor streams (`PackedRun::ternary_mults` sums —
/// unit-tested equal in the coordinator), so charged == executed.
pub fn block_ternary_mults(kind: BlockKind, b: u64) -> u64 {
    match kind {
        BlockKind::OffDiagonal => 3 * b * b * b,
        BlockKind::NonCentralDiagonal => 3 * b * b * (b - 1) / 2 + 2 * b * b,
        BlockKind::CentralDiagonal => b * (b - 1) * (b - 2) / 2 + 2 * b * (b - 1) + b,
    }
}

/// ABFT checksum pair-weights for one unique tensor entry (i ≥ j ≥ k):
/// up to three `(u, v, w)` terms (u ≥ v) such that accumulating
/// `coef{u,v} += w · A[i,j,k]` over all unique entries yields the
/// quadratic form `Σ_{u≥v} coef{u,v}·x_u·x_v = Σ_i y_i = xᵀCx` with
/// `C[j,k] = Σ_i A[i,j,k]` (the mode-1 contraction checksum, §Rob P15).
/// The weights are the symmetrization multiplicities of the entry — the
/// same accounting as [`factors`]/[`block_ternary_mults`], restricted to
/// a single entry instead of a block, so the per-block restriction `C_b`
/// verifies exactly what the packed kernels compute. Zero-weight terms
/// pad the array for case uniformity; accumulate-then-skip is fine.
pub fn checksum_weights(i: usize, j: usize, k: usize) -> [(usize, usize, f32); 3] {
    debug_assert!(i >= j && j >= k, "entry index must satisfy i >= j >= k");
    if i > j && j > k {
        // 6 permutations: each of the three unordered pairs appears twice
        [(i, j, 2.0), (i, k, 2.0), (j, k, 2.0)]
    } else if i == j && j == k {
        // 1 permutation: the diagonal pair once
        [(i, i, 1.0), (i, k, 0.0), (j, k, 0.0)]
    } else if i == j {
        // (a,a,b): 3 permutations — pair {a,b} twice, diagonal {a,a} once
        [(i, k, 2.0), (i, i, 1.0), (j, k, 0.0)]
    } else {
        // (a,b,b): 3 permutations — pair {a,b} twice, diagonal {b,b} once
        [(i, j, 2.0), (j, j, 1.0), (i, k, 0.0)]
    }
}

/// The tetrahedral block defined by an index subset R (paper §6):
/// TB₃(R) = {(i,j,k) : i,j,k ∈ R, i > j > k}, in lexicographic order.
pub fn tb3(r: &[usize]) -> Vec<(usize, usize, usize)> {
    let mut s = r.to_vec();
    s.sort_unstable();
    let mut out = Vec::new();
    for a in 0..s.len() {
        for b in 0..a {
            for c in 0..b {
                out.push((s[a], s[b], s[c]));
            }
        }
    }
    out
}

/// A complete tetrahedral block partition: the paper's Tables 1/3 object.
#[derive(Debug, Clone)]
pub struct TetraPartition {
    /// Number of row blocks m (= q²+1 for the spherical family).
    pub m: usize,
    /// Number of processors P (= number of Steiner blocks).
    pub p: usize,
    /// Steiner block size r (= q+1 for the spherical family).
    pub r: usize,
    /// R_p: the index set of processor p's tetrahedral block (sorted).
    pub r_p: Vec<Vec<usize>>,
    /// N_p: non-central diagonal blocks assigned to processor p, as
    /// lower-tetrahedral triples (i >= j >= k with exactly two equal).
    pub n_p: Vec<Vec<(usize, usize, usize)>>,
    /// D_p: the central diagonal block index assigned to p, if any.
    pub d_p: Vec<Option<usize>>,
    /// Q_i: the processors that require row block i (those with i ∈ R_p).
    pub q_i: Vec<Vec<usize>>,
}

impl TetraPartition {
    /// Build the full partition from a Steiner (m, r, 3) system, assigning
    /// diagonal blocks via the §6.1.3 matchings.
    pub fn from_steiner(sys: &SteinerSystem) -> Result<Self> {
        let m = sys.m;
        let p = sys.num_blocks();
        let r_p = sys.blocks.clone();

        // Q_i: processors whose R_p contains i.
        let mut q_i: Vec<Vec<usize>> = vec![Vec::new(); m];
        for (pi, r) in r_p.iter().enumerate() {
            for &i in r {
                q_i[i].push(pi);
            }
        }

        // --- non-central diagonal blocks ------------------------------
        // Right vertices: all (a,a,b) and (a,b,b) with a > b.
        let mut nc_blocks: Vec<(usize, usize, usize)> = Vec::new();
        for a in 0..m {
            for b in 0..a {
                nc_blocks.push((a, a, b));
                nc_blocks.push((a, b, b));
            }
        }
        let total_nc = m * (m - 1);
        debug_assert_eq!(nc_blocks.len(), total_nc);
        if total_nc % p != 0 {
            bail!(
                "non-central diagonal count {total_nc} not divisible by P={p}; \
                 this Steiner system does not admit the balanced assignment"
            );
        }
        let d = total_nc / p; // = q for the spherical family

        // Bipartite graph: processor -> compatible non-central blocks
        // ({a, b} ⊆ R_p).
        let adj: Vec<Vec<usize>> = r_p
            .iter()
            .map(|r| {
                nc_blocks
                    .iter()
                    .enumerate()
                    .filter(|(_, &(a, _, c))| r.contains(&a) && r.contains(&c))
                    .map(|(idx, _)| idx)
                    .collect()
            })
            .collect();
        let assignments = disjoint_matchings(&adj, nc_blocks.len(), d)
            .context("non-central diagonal block assignment (Corollary 5)")?;
        let mut n_p: Vec<Vec<(usize, usize, usize)>> = vec![Vec::new(); p];
        for matching in &assignments {
            for (proc, &blk) in matching.iter().enumerate() {
                n_p[proc].push(nc_blocks[blk]);
            }
        }

        // --- central diagonal blocks ----------------------------------
        // Match each of the m central blocks (a,a,a) to a processor with
        // a ∈ R_p (Hall's theorem guarantees a matching; §6.1.3).
        let central_adj: Vec<Vec<usize>> = (0..m).map(|a| q_i[a].clone()).collect();
        let (size, match_l, _) = hopcroft_karp(&central_adj, p);
        if size != m {
            bail!("central diagonal matching covered only {size}/{m} blocks");
        }
        let mut d_p: Vec<Option<usize>> = vec![None; p];
        for a in 0..m {
            let proc = match_l[a].unwrap();
            debug_assert!(d_p[proc].is_none());
            d_p[proc] = Some(a);
        }

        Ok(TetraPartition {
            m,
            p,
            r: sys.r,
            r_p,
            n_p,
            d_p,
            q_i,
        })
    }

    /// Build a partition from published (R_p, N_p, D_p) rows (the paper's
    /// Tables 1/3 fixtures) rather than re-deriving the matchings.
    pub fn from_rows(m: usize, rows: &[crate::steiner::fixtures::PaperRow]) -> Result<Self> {
        let p = rows.len();
        let r = rows[0].r_p.len();
        let r_p: Vec<Vec<usize>> = rows.iter().map(|x| x.r_p.clone()).collect();
        let n_p: Vec<Vec<(usize, usize, usize)>> = rows.iter().map(|x| x.n_p.clone()).collect();
        let d_p: Vec<Option<usize>> = rows.iter().map(|x| x.d_p).collect();
        let mut q_i: Vec<Vec<usize>> = vec![Vec::new(); m];
        for (pi, rset) in r_p.iter().enumerate() {
            for &i in rset {
                q_i[i].push(pi);
            }
        }
        let part = TetraPartition { m, p, r, r_p, n_p, d_p, q_i };
        part.verify()?;
        Ok(part)
    }

    /// Off-diagonal blocks owned by processor p: TB₃(R_p).
    pub fn offdiag_blocks(&self, p: usize) -> Vec<(usize, usize, usize)> {
        tb3(&self.r_p[p])
    }

    /// All lower-tetrahedral blocks owned by processor p (off-diagonal,
    /// then non-central diagonal, then central diagonal).
    pub fn owned_blocks(&self, p: usize) -> Vec<(usize, usize, usize)> {
        let mut out = self.offdiag_blocks(p);
        out.extend(self.n_p[p].iter().copied());
        if let Some(a) = self.d_p[p] {
            out.push((a, a, a));
        }
        out
    }

    /// Verify the partition invariants:
    /// every lower-tetrahedral block (i >= j >= k) owned by exactly one
    /// processor, and every diagonal block compatible with its owner's R_p.
    pub fn verify(&self) -> Result<()> {
        let mut owner = std::collections::HashMap::new();
        for p in 0..self.p {
            for blk in self.owned_blocks(p) {
                if let Some(prev) = owner.insert(blk, p) {
                    bail!("block {:?} owned by both {prev} and {p}", blk);
                }
            }
        }
        let expected = self.m * (self.m + 1) * (self.m + 2) / 6;
        if owner.len() != expected {
            bail!("{} blocks owned, expected {expected}", owner.len());
        }
        // compatibility: diagonal blocks only touch indices in R_p
        for p in 0..self.p {
            for &(a, b, c) in &self.n_p[p] {
                if !(a >= b && b >= c && (a == b || b == c) && a != c) {
                    bail!("{:?} is not a non-central diagonal block", (a, b, c));
                }
                if !(self.r_p[p].contains(&a) && self.r_p[p].contains(&c)) {
                    bail!("non-central block {:?} incompatible with R_{p}", (a, b, c));
                }
            }
            if let Some(a) = self.d_p[p] {
                if !self.r_p[p].contains(&a) {
                    bail!("central block ({a},{a},{a}) incompatible with R_{p}");
                }
            }
        }
        Ok(())
    }

    /// Number of row-block portions each processor holds: every p holds a
    /// 1/|Q_i| slice of row block i for each i ∈ R_p.
    pub fn lambda1(&self) -> usize {
        self.q_i[0].len()
    }

    /// The sub-range of row block i (of length b) owned by processor p,
    /// where p must be in Q_i. Slices are contiguous and near-even (sizes
    /// differ by at most 1 when |Q_i| does not divide b).
    pub fn portion(&self, i: usize, p: usize, b: usize) -> std::ops::Range<usize> {
        let qi = &self.q_i[i];
        let idx = qi
            .iter()
            .position(|&x| x == p)
            .expect("processor does not require this row block");
        let parts = qi.len();
        let base = b / parts;
        let extra = b % parts;
        let start = idx * base + idx.min(extra);
        let len = base + usize::from(idx < extra);
        start..start + len
    }

    /// Per-processor tensor storage in words for block size b (paper §6.1.3
    /// closing count): packed lower-tetrahedral element counts.
    pub fn tensor_words(&self, p: usize, b: usize) -> usize {
        let off = self.offdiag_blocks(p).len() * b * b * b;
        let nc = self.n_p[p].len() * b * b * (b + 1) / 2;
        let c = if self.d_p[p].is_some() {
            b * (b + 1) * (b + 2) / 6
        } else {
            0
        };
        off + nc + c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::steiner::{fixtures, spherical, sqs8};

    #[test]
    fn tb3_matches_paper_example() {
        // Paper §6: TB₃({1,4,6,8}) = {(6,4,1),(8,4,1),(8,6,1),(8,6,4)}
        // (1-indexed). 0-indexed: {0,3,5,7}.
        let blocks = tb3(&[0, 3, 5, 7]);
        assert_eq!(
            blocks,
            vec![(5, 3, 0), (7, 3, 0), (7, 5, 0), (7, 5, 3)]
        );
    }

    #[test]
    fn classify_kinds() {
        assert_eq!(classify(3, 2, 1), BlockKind::OffDiagonal);
        assert_eq!(classify(3, 3, 1), BlockKind::NonCentralDiagonal);
        assert_eq!(classify(3, 1, 1), BlockKind::NonCentralDiagonal);
        assert_eq!(classify(2, 2, 2), BlockKind::CentralDiagonal);
    }

    #[test]
    fn factors_and_mults_per_kind() {
        assert_eq!(factors(BlockKind::OffDiagonal, 3, 2, 1), (2.0, 2.0, 2.0));
        assert_eq!(factors(BlockKind::NonCentralDiagonal, 3, 3, 1), (2.0, 0.0, 1.0));
        assert_eq!(factors(BlockKind::NonCentralDiagonal, 3, 1, 1), (1.0, 2.0, 0.0));
        assert_eq!(factors(BlockKind::CentralDiagonal, 2, 2, 2), (1.0, 0.0, 0.0));
        // §7.1 per-block counts at b = 4: 3b³, 3b²(b−1)/2 + 2b², and
        // b(b−1)(b−2)/2 + 2b(b−1) + b.
        assert_eq!(block_ternary_mults(BlockKind::OffDiagonal, 4), 192);
        assert_eq!(block_ternary_mults(BlockKind::NonCentralDiagonal, 4), 104);
        assert_eq!(block_ternary_mults(BlockKind::CentralDiagonal, 4), 40);
    }

    #[test]
    fn checksum_weights_reproduce_sum_of_sttsv() {
        // Accumulating checksum_weights over all unique entries must build
        // the exact quadratic form for Σ_i y_i = xᵀCx (f64 oracle, fp slack
        // only for the f32 sttsv under test).
        use crate::tensor::SymTensor;
        use crate::util::rng::Rng;
        let n = 9;
        let t = SymTensor::random(n, 31);
        let mut coef = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                for k in 0..=j {
                    let a = t.get(i, j, k) as f64;
                    for (u, v, w) in checksum_weights(i, j, k) {
                        coef[u * n + v] += w as f64 * a;
                    }
                }
            }
        }
        let mut rng = Rng::new(7);
        let x = rng.normal_vec(n);
        let got: f64 = t.sttsv(&x).iter().map(|&y| y as f64).sum();
        let mut want = 0.0f64;
        for u in 0..n {
            for v in 0..=u {
                want += coef[u * n + v] * x[u] as f64 * x[v] as f64;
            }
        }
        assert!(
            (got - want).abs() < 1e-3 * want.abs().max(1.0),
            "{got} vs {want}"
        );
        // permutation count conservation: weights for an entry sum to its
        // number of distinct index permutations
        for (i, j, k, perms) in [(3, 2, 1, 6.0), (3, 3, 1, 3.0), (3, 1, 1, 3.0), (2, 2, 2, 1.0)] {
            let s: f32 = checksum_weights(i, j, k).iter().map(|&(_, _, w)| w).sum();
            assert_eq!(s, perms, "({i},{j},{k})");
        }
    }

    #[test]
    fn partition_from_spherical_q2() {
        let s = spherical(2).unwrap();
        let part = TetraPartition::from_steiner(&s).unwrap();
        assert_eq!(part.m, 5);
        assert_eq!(part.p, 10);
        part.verify().unwrap();
        // q = 2: each processor gets q = 2 non-central blocks, (q+1)q(q-1)/6
        // = 1 off-diagonal block.
        for p in 0..part.p {
            assert_eq!(part.n_p[p].len(), 2);
            assert_eq!(part.offdiag_blocks(p).len(), 1);
        }
        // 5 central blocks over 10 processors: 5 assigned
        assert_eq!(part.d_p.iter().flatten().count(), 5);
    }

    #[test]
    fn partition_from_spherical_q3_matches_table1_shape() {
        let s = spherical(3).unwrap();
        let part = TetraPartition::from_steiner(&s).unwrap();
        assert_eq!((part.m, part.p), (10, 30));
        part.verify().unwrap();
        for p in 0..part.p {
            assert_eq!(part.offdiag_blocks(p).len(), 4); // (q+1)q(q-1)/6
            assert_eq!(part.n_p[p].len(), 3); // q
        }
        assert_eq!(part.d_p.iter().flatten().count(), 10); // m central blocks
        for i in 0..part.m {
            assert_eq!(part.q_i[i].len(), 12); // q(q+1), Table 2
        }
    }

    #[test]
    fn partition_from_sqs8_matches_table3_shape() {
        let part = TetraPartition::from_steiner(&sqs8()).unwrap();
        assert_eq!((part.m, part.p), (8, 14));
        part.verify().unwrap();
        for p in 0..part.p {
            assert_eq!(part.offdiag_blocks(p).len(), 4); // C(4,3)
            assert_eq!(part.n_p[p].len(), 4); // m(m-1)/P = 56/14
        }
        assert_eq!(part.d_p.iter().flatten().count(), 8);
        for i in 0..part.m {
            assert_eq!(part.q_i[i].len(), 7); // λ₁
        }
    }

    #[test]
    fn paper_table1_rows_form_valid_partition() {
        let part = TetraPartition::from_rows(10, &fixtures::table1()).unwrap();
        assert_eq!(part.p, 30);
        // Q_i derived from rows must equal the paper's Table 2
        assert_eq!(part.q_i, fixtures::table2());
    }

    #[test]
    fn paper_table3_rows_form_valid_partition() {
        let part = TetraPartition::from_rows(8, &fixtures::table3()).unwrap();
        assert_eq!(part.p, 14);
        part.verify().unwrap();
    }

    #[test]
    fn portions_tile_each_row_block() {
        let s = spherical(2).unwrap();
        let part = TetraPartition::from_steiner(&s).unwrap();
        for b in [6usize, 7, 12, 30] {
            for i in 0..part.m {
                let mut covered = vec![false; b];
                for &p in &part.q_i[i] {
                    for x in part.portion(i, p, b) {
                        assert!(!covered[x]);
                        covered[x] = true;
                    }
                }
                assert!(covered.iter().all(|&c| c), "row block {i} b={b}");
            }
        }
    }

    #[test]
    fn tensor_words_close_to_n3_over_6p() {
        // Paper: each processor stores ≈ n³/6P tensor elements.
        let s = spherical(3).unwrap();
        let part = TetraPartition::from_steiner(&s).unwrap();
        let b = 24;
        let n = b * part.m;
        let target = (n * n * n) as f64 / (6.0 * part.p as f64);
        for p in 0..part.p {
            let w = part.tensor_words(p, b) as f64;
            assert!(
                (w - target).abs() / target < 0.25,
                "proc {p}: {w} vs {target}"
            );
        }
    }

    #[test]
    fn owned_blocks_cover_every_lower_tetra_block_exactly_once() {
        for sys in [spherical(2).unwrap(), sqs8()] {
            let part = TetraPartition::from_steiner(&sys).unwrap();
            let mut count = std::collections::HashMap::new();
            for p in 0..part.p {
                for blk in part.owned_blocks(p) {
                    *count.entry(blk).or_insert(0usize) += 1;
                }
            }
            for i in 0..part.m {
                for j in 0..=i {
                    for k in 0..=j {
                        assert_eq!(
                            count.get(&(i, j, k)).copied().unwrap_or(0),
                            1,
                            "block {:?}",
                            (i, j, k)
                        );
                    }
                }
            }
        }
    }
}
