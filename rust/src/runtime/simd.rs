//! SIMD lane helpers and arch-dispatched run-kernel variants (§Perf P14).
//!
//! Two layers live here:
//!
//! 1. **Portable lane helpers** (`lanes_*`): the elementwise inner-loop
//!    primitives every multi-RHS kernel and the coordinator's `axpy_panel`
//!    share, generic over the sealed [`Element`] scalar. Each runs over
//!    `chunks_exact(LANES)` with a scalar remainder so LLVM emits
//!    full-width SIMD regardless of how `r` aligns, while performing
//!    exactly the same per-lane arithmetic (same association, no FMA
//!    contraction) as the scalar loops they replaced — results are
//!    **bitwise identical**, pinned by the kernel tests.
//! 2. **Explicit AVX2 microkernels** for the register-tiled run executors
//!    at r ∈ {4, 8} (`core::arch::x86_64` intrinsics, runtime-detected via
//!    `is_x86_feature_detected!`). These use separate `_mm*_mul_ps` +
//!    `_mm*_add_ps` — deliberately **not** fused FMA — so every lane
//!    performs the identical correctly-rounded mul-then-add sequence as
//!    the scalar tiled executor and the outputs stay bitwise equal
//!    (asserted in this module's tests). The tiled kernels vectorize
//!    across independent r-columns, so no reduction is reassociated.
//!
//! Dispatch policy ([`SimdPolicy`], CLI `--simd auto|scalar`) is a
//! **runtime global**, not an `ExecOpts` field: because the AVX2 kernels
//! are bitwise-equal to the scalar path, results are policy-invariant —
//! the policy is a host-machine execution detail (like thread pinning),
//! and keeping it out of `ExecOpts` keeps it out of the serving layer's
//! plan-cache key, where it would only fragment the cache.
//!
//! (The accelerator guides shipped with this repo cover
//! Trainium/CUDA/Pallas/Triton only; the AVX2 variants below follow the
//! same discipline those guides prescribe — pin the contraction order,
//! prove bitwise parity against the reference kernel.)

use crate::tensor::Element;

/// The single lane-width constant for the portable helpers: 8 f32 words —
/// one AVX2 256-bit vector (or two NEON 128-bit ones). For f64 the same
/// count spans two 256-bit vectors; LLVM still emits full-width ops. The
/// remainder of every helper runs scalar, so LANES only affects codegen,
/// never results.
pub(crate) const LANES: usize = 8;

/// dst[l] += s · a[l]
#[inline]
pub(crate) fn lanes_axpy<E: Element>(dst: &mut [E], s: E, a: &[E]) {
    debug_assert_eq!(dst.len(), a.len());
    let mut dc = dst.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    for (d, a) in dc.by_ref().zip(ac.by_ref()) {
        for (o, x) in d.iter_mut().zip(a) {
            *o += s * *x;
        }
    }
    for (o, x) in dc.into_remainder().iter_mut().zip(ac.remainder()) {
        *o += s * *x;
    }
}

/// dst[l] = a[l] · b[l]
#[inline]
pub(crate) fn lanes_set_mul<E: Element>(dst: &mut [E], a: &[E], b: &[E]) {
    debug_assert!(dst.len() == a.len() && dst.len() == b.len());
    let mut dc = dst.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for ((d, a), b) in dc.by_ref().zip(ac.by_ref()).zip(bc.by_ref()) {
        for ((o, x), y) in d.iter_mut().zip(a).zip(b) {
            *o = *x * *y;
        }
    }
    for ((o, x), y) in dc
        .into_remainder()
        .iter_mut()
        .zip(ac.remainder())
        .zip(bc.remainder())
    {
        *o = *x * *y;
    }
}

/// dst[l] = (s · a[l]) · b[l]
#[inline]
pub(crate) fn lanes_set_mul_s<E: Element>(dst: &mut [E], s: E, a: &[E], b: &[E]) {
    debug_assert!(dst.len() == a.len() && dst.len() == b.len());
    let mut dc = dst.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for ((d, a), b) in dc.by_ref().zip(ac.by_ref()).zip(bc.by_ref()) {
        for ((o, x), y) in d.iter_mut().zip(a).zip(b) {
            *o = s * *x * *y;
        }
    }
    for ((o, x), y) in dc
        .into_remainder()
        .iter_mut()
        .zip(ac.remainder())
        .zip(bc.remainder())
    {
        *o = s * *x * *y;
    }
}

/// dst[l] += a[l] · b[l]
#[inline]
pub(crate) fn lanes_mul_add<E: Element>(dst: &mut [E], a: &[E], b: &[E]) {
    debug_assert!(dst.len() == a.len() && dst.len() == b.len());
    let mut dc = dst.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for ((d, a), b) in dc.by_ref().zip(ac.by_ref()).zip(bc.by_ref()) {
        for ((o, x), y) in d.iter_mut().zip(a).zip(b) {
            *o += *x * *y;
        }
    }
    for ((o, x), y) in dc
        .into_remainder()
        .iter_mut()
        .zip(ac.remainder())
        .zip(bc.remainder())
    {
        *o += *x * *y;
    }
}

/// dst[l] += (s · a[l]) · b[l]
#[inline]
pub(crate) fn lanes_mul_add_s<E: Element>(dst: &mut [E], s: E, a: &[E], b: &[E]) {
    debug_assert!(dst.len() == a.len() && dst.len() == b.len());
    let mut dc = dst.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for ((d, a), b) in dc.by_ref().zip(ac.by_ref()).zip(bc.by_ref()) {
        for ((o, x), y) in d.iter_mut().zip(a).zip(b) {
            *o += s * *x * *y;
        }
    }
    for ((o, x), y) in dc
        .into_remainder()
        .iter_mut()
        .zip(ac.remainder())
        .zip(bc.remainder())
    {
        *o += s * *x * *y;
    }
}

/// dst[l] += (s · a[l]) · b[l] + (t · c[l]) · d[l] — the fused two-term
/// update of the diagonal kernels; the single composite addition per lane
/// is preserved (splitting it would change the rounding).
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn lanes_mul_add2_s<E: Element>(
    dst: &mut [E],
    s: E,
    a: &[E],
    b: &[E],
    t: E,
    c: &[E],
    d: &[E],
) {
    debug_assert!(dst.len() == a.len() && dst.len() == b.len());
    debug_assert!(dst.len() == c.len() && dst.len() == d.len());
    let mut oc = dst.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    let mut cc = c.chunks_exact(LANES);
    let mut ec = d.chunks_exact(LANES);
    for ((((o, a), b), c), e) in oc
        .by_ref()
        .zip(ac.by_ref())
        .zip(bc.by_ref())
        .zip(cc.by_ref())
        .zip(ec.by_ref())
    {
        for ((((o, x), y), z), w) in o.iter_mut().zip(a).zip(b).zip(c).zip(e) {
            *o += s * *x * *y + t * *z * *w;
        }
    }
    for ((((o, x), y), z), w) in oc
        .into_remainder()
        .iter_mut()
        .zip(ac.remainder())
        .zip(bc.remainder())
        .zip(cc.remainder())
        .zip(ec.remainder())
    {
        *o += s * *x * *y + t * *z * *w;
    }
}

/// dst[l] += a[l] · b[l] + (t · c[l]) · d[l]
#[inline]
pub(crate) fn lanes_mul_add2<E: Element>(dst: &mut [E], a: &[E], b: &[E], t: E, c: &[E], d: &[E]) {
    debug_assert!(dst.len() == a.len() && dst.len() == b.len());
    debug_assert!(dst.len() == c.len() && dst.len() == d.len());
    let mut oc = dst.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    let mut cc = c.chunks_exact(LANES);
    let mut ec = d.chunks_exact(LANES);
    for ((((o, a), b), c), e) in oc
        .by_ref()
        .zip(ac.by_ref())
        .zip(bc.by_ref())
        .zip(cc.by_ref())
        .zip(ec.by_ref())
    {
        for ((((o, x), y), z), w) in o.iter_mut().zip(a).zip(b).zip(c).zip(e) {
            *o += *x * *y + t * *z * *w;
        }
    }
    for ((((o, x), y), z), w) in oc
        .into_remainder()
        .iter_mut()
        .zip(ac.remainder())
        .zip(bc.remainder())
        .zip(cc.remainder())
        .zip(ec.remainder())
    {
        *o += *x * *y + t * *z * *w;
    }
}

/// dst[l] += a[l]
#[inline]
pub(crate) fn lanes_add<E: Element>(dst: &mut [E], a: &[E]) {
    debug_assert_eq!(dst.len(), a.len());
    let mut dc = dst.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    for (d, a) in dc.by_ref().zip(ac.by_ref()) {
        for (o, x) in d.iter_mut().zip(a) {
            *o += *x;
        }
    }
    for (o, x) in dc.into_remainder().iter_mut().zip(ac.remainder()) {
        *o += *x;
    }
}

/// Which run-kernel variants [`crate::runtime::exec_block_runs`] may
/// dispatch (CLI `--simd auto|scalar`). Process-global — see the module
/// docs for why this is not an `ExecOpts` field.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub enum SimdPolicy {
    /// Use the explicit AVX2 microkernels when the CPU supports them
    /// (runtime-detected); fall back to the portable tiled path otherwise.
    #[default]
    Auto,
    /// Always the portable tiled path (baseline for the E18 bench and a
    /// belt-and-braces escape hatch — results are bitwise equal either
    /// way).
    Scalar,
}

impl std::str::FromStr for SimdPolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "auto" => Ok(SimdPolicy::Auto),
            "scalar" => Ok(SimdPolicy::Scalar),
            other => anyhow::bail!("unknown simd policy '{other}' (expected auto|scalar)"),
        }
    }
}

impl std::fmt::Display for SimdPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SimdPolicy::Auto => "auto",
            SimdPolicy::Scalar => "scalar",
        })
    }
}

static POLICY: std::sync::atomic::AtomicU8 = std::sync::atomic::AtomicU8::new(0);

/// Set the process-wide SIMD dispatch policy. Safe to call at any time
/// (kernel variants are bitwise-equal, so in-flight sweeps are unaffected).
pub fn set_simd_policy(p: SimdPolicy) {
    POLICY.store(p as u8, std::sync::atomic::Ordering::Relaxed);
}

/// The current process-wide SIMD dispatch policy.
pub fn simd_policy() -> SimdPolicy {
    match POLICY.load(std::sync::atomic::Ordering::Relaxed) {
        1 => SimdPolicy::Scalar,
        _ => SimdPolicy::Auto,
    }
}

/// Whether this host can run the AVX2 microkernels (one-time runtime
/// detection; always false off x86-64). Independent of the policy —
/// `avx2_available() && simd_policy() == Auto` is what dispatch checks.
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        static DETECTED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
        *DETECTED.get_or_init(|| std::is_x86_feature_detected!("avx2"))
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Dispatch predicate for the f32 run executor.
#[inline]
pub(crate) fn use_avx2() -> bool {
    simd_policy() == SimdPolicy::Auto && avx2_available()
}

#[cfg(target_arch = "x86_64")]
pub(crate) use avx2::{exec_runs_avx2_r4, exec_runs_avx2_r8};

/// Explicit AVX2 variants of the register-tiled run executors
/// (`native::exec_runs_tiled`) at r = 8 (one 256-bit vector per panel row)
/// and r = 4 (one 128-bit vector). Each lane is an independent r-column
/// accumulation chain — vectorizing across columns reassociates nothing —
/// and every update uses separate mul + add intrinsics (**no FMA**), so
/// outputs are bitwise equal to the portable path (pinned by
/// `avx2_kernels_bitwise_match_scalar_tiled` below; FMA would contract
/// `a*b + c` to a single rounding and break the pin, which is why the
/// fused intrinsics are deliberately not used).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::super::native::RunDesc;
    use crate::tensor::RunClass;
    use std::arch::x86_64::*;

    /// r = 8 run-stream executor. Safety: caller must ensure the CPU
    /// supports AVX2 (see [`super::use_avx2`]); panel/output slices must be
    /// (b, 8) row-major with every desc's x/y/base/len in range — the same
    /// contract as the portable executor, enforced here by checked slicing
    /// before each load/store.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub(crate) unsafe fn exec_runs_avx2_r8(
        t: &[f32],
        descs: &[RunDesc],
        us: &[f32],
        vs: &[f32],
        ws: &[f32],
        ci: &mut [f32],
        cj: &mut [f32],
        ck: &mut [f32],
    ) {
        const R: usize = 8;
        #[inline(always)]
        unsafe fn ld(s: &[f32], row: usize) -> __m256 {
            _mm256_loadu_ps(s[row * R..row * R + R].as_ptr())
        }
        #[inline(always)]
        unsafe fn acc_into(s: &mut [f32], row: usize, v: __m256) {
            let p = s[row * R..row * R + R].as_mut_ptr();
            _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), v));
        }
        let two = _mm256_set1_ps(2.0);
        let mut acc = _mm256_setzero_ps();
        for d in descs {
            let base = d.base as usize;
            let len = d.len as usize;
            let x = d.x as usize;
            let y = d.y as usize;
            let u = ld(us, x);
            let v = ld(vs, y);
            let row = &t[base..base + len];
            // m[l] += a · w[l], one mul + one add per lane — the scalar
            // tiled loop verbatim.
            let mut m = _mm256_setzero_ps();
            for (g, &a) in row.iter().enumerate() {
                m = _mm256_add_ps(m, _mm256_mul_ps(_mm256_set1_ps(a), ld(ws, g)));
            }
            match d.cls {
                RunClass::OffDiag => {
                    let uv = _mm256_mul_ps(u, v);
                    for (g, &a) in row.iter().enumerate() {
                        acc_into(ck, g, _mm256_mul_ps(_mm256_set1_ps(a), uv));
                    }
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(m, v));
                    acc_into(cj, y, _mm256_mul_ps(m, u));
                }
                RunClass::GghUpper => {
                    let uv = _mm256_mul_ps(_mm256_mul_ps(two, u), v);
                    for (g, &a) in row.iter().enumerate() {
                        acc_into(ck, g, _mm256_mul_ps(_mm256_set1_ps(a), uv));
                    }
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(m, v));
                    acc_into(ci, y, _mm256_mul_ps(m, u));
                }
                RunClass::GghAxis => {
                    let uv = _mm256_mul_ps(u, v);
                    for (g, &a) in row.iter().enumerate() {
                        acc_into(ck, g, _mm256_mul_ps(_mm256_set1_ps(a), uv));
                    }
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(m, u));
                }
                RunClass::Ghh => {
                    let ab = _mm256_set1_ps(t[base + len]);
                    let wy = ld(ws, y);
                    let uv = _mm256_mul_ps(u, v);
                    for (g, &a) in row.iter().enumerate() {
                        acc_into(cj, g, _mm256_mul_ps(_mm256_set1_ps(a), uv));
                    }
                    acc = _mm256_add_ps(
                        acc,
                        _mm256_add_ps(
                            _mm256_mul_ps(_mm256_mul_ps(two, m), v),
                            _mm256_mul_ps(_mm256_mul_ps(ab, v), wy),
                        ),
                    );
                    acc_into(
                        cj,
                        y,
                        _mm256_add_ps(
                            _mm256_mul_ps(m, u),
                            _mm256_mul_ps(_mm256_mul_ps(ab, u), wy),
                        ),
                    );
                }
                RunClass::CentralUpper => {
                    let ab_s = t[base + len];
                    let ab = _mm256_set1_ps(ab_s);
                    // scalar path computes t2 = 2.0 * ab once in f32
                    let t2 = _mm256_set1_ps(2.0 * ab_s);
                    let wy = ld(ws, y);
                    let uv = _mm256_mul_ps(_mm256_mul_ps(two, u), v);
                    for (g, &a) in row.iter().enumerate() {
                        acc_into(ci, g, _mm256_mul_ps(_mm256_set1_ps(a), uv));
                    }
                    acc = _mm256_add_ps(
                        acc,
                        _mm256_add_ps(
                            _mm256_mul_ps(_mm256_mul_ps(two, m), v),
                            _mm256_mul_ps(_mm256_mul_ps(ab, v), wy),
                        ),
                    );
                    acc_into(
                        ci,
                        y,
                        _mm256_add_ps(
                            _mm256_mul_ps(_mm256_mul_ps(two, m), u),
                            _mm256_mul_ps(_mm256_mul_ps(t2, u), wy),
                        ),
                    );
                }
                RunClass::CentralAxis => {
                    let aa = _mm256_set1_ps(t[base + len]);
                    let wy = ld(ws, y);
                    let uv = _mm256_mul_ps(u, v);
                    for (g, &a) in row.iter().enumerate() {
                        acc_into(ci, g, _mm256_mul_ps(_mm256_set1_ps(a), uv));
                    }
                    // two separate accumulator adds, as in the scalar path
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_mul_ps(two, m), v));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_mul_ps(aa, v), wy));
                }
            }
            if d.flush {
                acc_into(ci, x, acc);
                acc = _mm256_setzero_ps();
            }
        }
    }

    /// r = 4 run-stream executor on 128-bit lanes. Same structure and
    /// safety contract as [`exec_runs_avx2_r8`].
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub(crate) unsafe fn exec_runs_avx2_r4(
        t: &[f32],
        descs: &[RunDesc],
        us: &[f32],
        vs: &[f32],
        ws: &[f32],
        ci: &mut [f32],
        cj: &mut [f32],
        ck: &mut [f32],
    ) {
        const R: usize = 4;
        #[inline(always)]
        unsafe fn ld(s: &[f32], row: usize) -> __m128 {
            _mm_loadu_ps(s[row * R..row * R + R].as_ptr())
        }
        #[inline(always)]
        unsafe fn acc_into(s: &mut [f32], row: usize, v: __m128) {
            let p = s[row * R..row * R + R].as_mut_ptr();
            _mm_storeu_ps(p, _mm_add_ps(_mm_loadu_ps(p), v));
        }
        let two = _mm_set1_ps(2.0);
        let mut acc = _mm_setzero_ps();
        for d in descs {
            let base = d.base as usize;
            let len = d.len as usize;
            let x = d.x as usize;
            let y = d.y as usize;
            let u = ld(us, x);
            let v = ld(vs, y);
            let row = &t[base..base + len];
            let mut m = _mm_setzero_ps();
            for (g, &a) in row.iter().enumerate() {
                m = _mm_add_ps(m, _mm_mul_ps(_mm_set1_ps(a), ld(ws, g)));
            }
            match d.cls {
                RunClass::OffDiag => {
                    let uv = _mm_mul_ps(u, v);
                    for (g, &a) in row.iter().enumerate() {
                        acc_into(ck, g, _mm_mul_ps(_mm_set1_ps(a), uv));
                    }
                    acc = _mm_add_ps(acc, _mm_mul_ps(m, v));
                    acc_into(cj, y, _mm_mul_ps(m, u));
                }
                RunClass::GghUpper => {
                    let uv = _mm_mul_ps(_mm_mul_ps(two, u), v);
                    for (g, &a) in row.iter().enumerate() {
                        acc_into(ck, g, _mm_mul_ps(_mm_set1_ps(a), uv));
                    }
                    acc = _mm_add_ps(acc, _mm_mul_ps(m, v));
                    acc_into(ci, y, _mm_mul_ps(m, u));
                }
                RunClass::GghAxis => {
                    let uv = _mm_mul_ps(u, v);
                    for (g, &a) in row.iter().enumerate() {
                        acc_into(ck, g, _mm_mul_ps(_mm_set1_ps(a), uv));
                    }
                    acc = _mm_add_ps(acc, _mm_mul_ps(m, u));
                }
                RunClass::Ghh => {
                    let ab = _mm_set1_ps(t[base + len]);
                    let wy = ld(ws, y);
                    let uv = _mm_mul_ps(u, v);
                    for (g, &a) in row.iter().enumerate() {
                        acc_into(cj, g, _mm_mul_ps(_mm_set1_ps(a), uv));
                    }
                    acc = _mm_add_ps(
                        acc,
                        _mm_add_ps(
                            _mm_mul_ps(_mm_mul_ps(two, m), v),
                            _mm_mul_ps(_mm_mul_ps(ab, v), wy),
                        ),
                    );
                    acc_into(
                        cj,
                        y,
                        _mm_add_ps(_mm_mul_ps(m, u), _mm_mul_ps(_mm_mul_ps(ab, u), wy)),
                    );
                }
                RunClass::CentralUpper => {
                    let ab_s = t[base + len];
                    let ab = _mm_set1_ps(ab_s);
                    let t2 = _mm_set1_ps(2.0 * ab_s);
                    let wy = ld(ws, y);
                    let uv = _mm_mul_ps(_mm_mul_ps(two, u), v);
                    for (g, &a) in row.iter().enumerate() {
                        acc_into(ci, g, _mm_mul_ps(_mm_set1_ps(a), uv));
                    }
                    acc = _mm_add_ps(
                        acc,
                        _mm_add_ps(
                            _mm_mul_ps(_mm_mul_ps(two, m), v),
                            _mm_mul_ps(_mm_mul_ps(ab, v), wy),
                        ),
                    );
                    acc_into(
                        ci,
                        y,
                        _mm_add_ps(
                            _mm_mul_ps(_mm_mul_ps(two, m), u),
                            _mm_mul_ps(_mm_mul_ps(t2, u), wy),
                        ),
                    );
                }
                RunClass::CentralAxis => {
                    let aa = _mm_set1_ps(t[base + len]);
                    let wy = ld(ws, y);
                    let uv = _mm_mul_ps(u, v);
                    for (g, &a) in row.iter().enumerate() {
                        acc_into(ci, g, _mm_mul_ps(_mm_set1_ps(a), uv));
                    }
                    acc = _mm_add_ps(acc, _mm_mul_ps(_mm_mul_ps(two, m), v));
                    acc = _mm_add_ps(acc, _mm_mul_ps(_mm_mul_ps(aa, v), wy));
                }
            }
            if d.flush {
                acc_into(ci, x, acc);
                acc = _mm_setzero_ps();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simd_policy_parses_displays_and_defaults() {
        assert_eq!("auto".parse::<SimdPolicy>().unwrap(), SimdPolicy::Auto);
        assert_eq!("scalar".parse::<SimdPolicy>().unwrap(), SimdPolicy::Scalar);
        assert!("avx512".parse::<SimdPolicy>().is_err());
        assert_eq!(SimdPolicy::default(), SimdPolicy::Auto);
        assert_eq!(SimdPolicy::Scalar.to_string(), "scalar");
    }

    #[test]
    fn policy_roundtrips_and_gates_dispatch() {
        // (Global state: restore Auto before returning. Concurrent tests
        // are safe because both kernel variants are bitwise-equal.)
        set_simd_policy(SimdPolicy::Scalar);
        assert_eq!(simd_policy(), SimdPolicy::Scalar);
        assert!(!use_avx2(), "scalar policy must veto AVX2 dispatch");
        set_simd_policy(SimdPolicy::Auto);
        assert_eq!(simd_policy(), SimdPolicy::Auto);
        assert_eq!(use_avx2(), avx2_available());
    }

    /// The load-bearing pin for §Perf P14: the AVX2 executors reproduce the
    /// portable tiled executor BITWISE on every run class at r ∈ {4, 8}.
    /// CI runs this twice — default flags and -C target-cpu=native — so a
    /// compiler that starts contracting the portable path into FMA (which
    /// would break parity) is caught.
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_kernels_bitwise_match_scalar_tiled() {
        use super::super::native::{exec_block_runs, RunDesc};
        use crate::tensor::{PackedBlockView, SymTensor};
        use crate::util::rng::Rng;
        if !avx2_available() {
            eprintln!("skipping: host has no AVX2");
            return;
        }
        let (m, b) = (4usize, 6usize);
        let t = SymTensor::random(m * b, 77);
        let data = t.packed_data();
        let mut rng = Rng::new(78);
        for blk in [(3usize, 2usize, 0usize), (3, 3, 1), (3, 1, 1), (2, 2, 2)] {
            let view = PackedBlockView::new(blk.0, blk.1, blk.2, b);
            let mut descs = Vec::new();
            view.for_each_run(|run| descs.push(RunDesc::compile(&run)));
            for r in [4usize, 8] {
                let us = rng.normal_vec(b * r);
                let vs = if blk.0 == blk.1 { us.clone() } else { rng.normal_vec(b * r) };
                let ws = if blk.1 == blk.2 { vs.clone() } else { rng.normal_vec(b * r) };
                // portable tiled path, forced via the policy gate
                set_simd_policy(SimdPolicy::Scalar);
                let mut si = vec![0.0f32; b * r];
                let mut sj = vec![0.0f32; b * r];
                let mut sk = vec![0.0f32; b * r];
                exec_block_runs(data, &descs, &us, &vs, &ws, &mut si, &mut sj, &mut sk, r);
                set_simd_policy(SimdPolicy::Auto);
                // explicit AVX2 kernels, called directly
                let mut ai = vec![0.0f32; b * r];
                let mut aj = vec![0.0f32; b * r];
                let mut ak = vec![0.0f32; b * r];
                unsafe {
                    match r {
                        4 => exec_runs_avx2_r4(
                            data, &descs, &us, &vs, &ws, &mut ai, &mut aj, &mut ak,
                        ),
                        _ => exec_runs_avx2_r8(
                            data, &descs, &us, &vs, &ws, &mut ai, &mut aj, &mut ak,
                        ),
                    }
                }
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&si), bits(&ai), "{blk:?} r={r} ci");
                assert_eq!(bits(&sj), bits(&aj), "{blk:?} r={r} cj");
                assert_eq!(bits(&sk), bits(&ak), "{blk:?} r={r} ck");
            }
        }
    }

    /// The public dispatcher gives identical (bitwise) results under both
    /// policies — dispatch can never change answers, only speed.
    #[test]
    fn dispatcher_is_policy_invariant() {
        use super::super::native::{exec_block_runs, RunDesc};
        use crate::tensor::{PackedBlockView, SymTensor};
        use crate::util::rng::Rng;
        let b = 5usize;
        let t = SymTensor::random(4 * b, 79);
        let view = PackedBlockView::new(3, 2, 0, b);
        let mut descs = Vec::new();
        view.for_each_run(|run| descs.push(RunDesc::compile(&run)));
        let mut rng = Rng::new(80);
        for r in [1usize, 3, 4, 8] {
            let us = rng.normal_vec(b * r);
            let vs = rng.normal_vec(b * r);
            let ws = rng.normal_vec(b * r);
            let mut out = Vec::new();
            for policy in [SimdPolicy::Auto, SimdPolicy::Scalar] {
                set_simd_policy(policy);
                let mut ci = vec![0.0f32; b * r];
                let mut cj = vec![0.0f32; b * r];
                let mut ck = vec![0.0f32; b * r];
                exec_block_runs(t.packed_data(), &descs, &us, &vs, &ws, &mut ci, &mut cj, &mut ck, r);
                out.push((ci, cj, ck));
            }
            set_simd_policy(SimdPolicy::Auto);
            assert_eq!(out[0], out[1], "r={r}");
        }
    }
}
