//! Native (pure-Rust) reference implementations of the compute kernels.
//!
//! These serve three purposes: a backend that works without artifacts, a
//! numeric cross-check for the PJRT path, and the CPU roofline baseline for
//! the §Perf comparisons. The loop structure mirrors the Pallas kernel: one
//! pass over A computing all three contractions (3× arithmetic intensity),
//! with the shared intermediate M = A ×₃ w reused by ci and cj.

/// Fused ternary block contraction: A is b×b×b row-major ((a·b+β)·b+γ).
///
///   ci[a] = Σ_{β,γ} A[a,β,γ]·v[β]·w[γ]
///   cj[β] = Σ_{a,γ} A[a,β,γ]·u[a]·w[γ]
///   ck[γ] = Σ_{a,β} A[a,β,γ]·u[a]·v[β]
pub fn block_contract_native(
    a: &[f32],
    u: &[f32],
    v: &[f32],
    w: &[f32],
    b: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut ci = vec![0.0f32; b];
    let mut cj = vec![0.0f32; b];
    let mut ck = vec![0.0f32; b];
    // Single pass over A in row-major order: each b-length row A[x,y,:]
    // stays in L1 and is used twice —
    //   m = Σ_z A[x,y,z]·w[z]          (shared between ci and cj)
    //   ci[x] += m·v[y]; cj[y] += m·u[x]
    //   ck[z] += A[x,y,z]·(u[x]·v[y])
    // The dot-product and the axpy run as separate z-sweeps so each
    // autovectorizes cleanly (a combined sweep mixes a reduction with a
    // scatter and defeats SIMD — see EXPERIMENTS.md §Perf P2).
    for x in 0..b {
        let ux = u[x];
        let mut ci_x = 0.0f32;
        for y in 0..b {
            let row = &a[(x * b + y) * b..(x * b + y + 1) * b];
            let uv = ux * v[y];
            let mut m = 0.0f32;
            for z in 0..b {
                m += row[z] * w[z];
            }
            for z in 0..b {
                ck[z] += row[z] * uv;
            }
            ci_x += m * v[y];
            cj[y] += m * ux;
        }
        ci[x] += ci_x;
    }
    (ci, cj, ck)
}

/// Dense STTSV y = A ×₂ x ×₃ x on an n×n×n row-major tensor (Algorithm 3).
pub fn dense_sttsv_native(a: &[f32], x: &[f32], n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut acc = 0.0f64;
        for j in 0..n {
            let row = &a[(i * n + j) * n..(i * n + j + 1) * n];
            let mut inner = 0.0f32;
            for k in 0..n {
                inner += row[k] * x[k];
            }
            acc += inner as f64 * x[j] as f64;
        }
        y[i] = acc as f32;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dense_sttsv_small_known() {
        // n = 2, A[i][j][k] = 1 everywhere, x = (1, 2): y_i = (1+2)² = 9.
        let a = vec![1.0f32; 8];
        let y = dense_sttsv_native(&a, &[1.0, 2.0], 2);
        assert_eq!(y, vec![9.0, 9.0]);
    }

    #[test]
    fn block_contract_on_rank_one_tensor() {
        // A[x,y,z] = p[x]·q[y]·r[z] ⇒ ci = p·(q·v)(r·w), etc.
        let b = 4;
        let mut rng = Rng::new(2);
        let (p, q, r) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        let (u, v, w) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        let mut a = vec![0.0f32; b * b * b];
        for x in 0..b {
            for y in 0..b {
                for z in 0..b {
                    a[(x * b + y) * b + z] = p[x] * q[y] * r[z];
                }
            }
        }
        let dotf = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        let (ci, cj, ck) = block_contract_native(&a, &u, &v, &w, b);
        let (qv, rw, pu, uv) = (dotf(&q, &v), dotf(&r, &w), dotf(&p, &u), dotf(&q, &v));
        let _ = uv;
        for t in 0..b {
            assert!((ci[t] - p[t] * qv * rw).abs() < 1e-4);
            assert!((cj[t] - q[t] * pu * rw).abs() < 1e-4);
            assert!((ck[t] - r[t] * pu * qv).abs() < 1e-4);
        }
    }
}
