//! Native (pure-Rust) reference implementations of the compute kernels.
//!
//! These serve three purposes: a backend that works without artifacts, a
//! numeric cross-check for the PJRT path, and the CPU roofline baseline for
//! the §Perf comparisons. The loop structure mirrors the Pallas kernel: one
//! pass over A computing all three contractions (3× arithmetic intensity),
//! with the shared intermediate M = A ×₃ w reused by ci and cj.
//!
//! Multi-RHS layout convention (shared with the Pallas kernels and the
//! coordinator): an r-column panel stores coordinate `x` of column `l` at
//! offset `x*r + l` — i.e. a row-major `(b, r)` matrix. The column index
//! varies fastest so the per-coordinate inner loops over `l` touch
//! contiguous memory and autovectorize (EXPERIMENTS.md §Perf P6).

/// Fused ternary block contraction: A is b×b×b row-major ((a·b+β)·b+γ).
///
///   ci[a] = Σ_{β,γ} A[a,β,γ]·v[β]·w[γ]
///   cj[β] = Σ_{a,γ} A[a,β,γ]·u[a]·w[γ]
///   ck[γ] = Σ_{a,β} A[a,β,γ]·u[a]·v[β]
pub fn block_contract_native(
    a: &[f32],
    u: &[f32],
    v: &[f32],
    w: &[f32],
    b: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut ci = vec![0.0f32; b];
    let mut cj = vec![0.0f32; b];
    let mut ck = vec![0.0f32; b];
    // Single pass over A in row-major order: each b-length row A[x,y,:]
    // stays in L1 and is used twice —
    //   m = Σ_z A[x,y,z]·w[z]          (shared between ci and cj)
    //   ci[x] += m·v[y]; cj[y] += m·u[x]
    //   ck[z] += A[x,y,z]·(u[x]·v[y])
    // The dot-product and the axpy run as separate z-sweeps so each
    // autovectorizes cleanly (a combined sweep mixes a reduction with a
    // scatter and defeats SIMD — see EXPERIMENTS.md §Perf P2).
    for x in 0..b {
        let ux = u[x];
        let mut ci_x = 0.0f32;
        for y in 0..b {
            let row = &a[(x * b + y) * b..(x * b + y + 1) * b];
            let uv = ux * v[y];
            let mut m = 0.0f32;
            for z in 0..b {
                m += row[z] * w[z];
            }
            for z in 0..b {
                ck[z] += row[z] * uv;
            }
            ci_x += m * v[y];
            cj[y] += m * ux;
        }
        ci[x] += ci_x;
    }
    (ci, cj, ck)
}

// The elementwise `lanes_*` panel helpers (and their single documented
// lane-width constant) live in `runtime::simd` — shared, generic over the
// sealed Element scalar, and still bitwise-pinned by the kernel tests here
// (`multi_rhs_matches_column_by_column`, `multi_rhs_r1_is_the_single_kernel`,
// `packed_offdiag_is_bitwise_the_dense_kernel`).
use super::simd::{
    lanes_add, lanes_axpy, lanes_mul_add, lanes_mul_add2, lanes_mul_add2_s, lanes_mul_add_s,
    lanes_set_mul, lanes_set_mul_s,
};
use crate::tensor::Element;

/// Multi-RHS fused ternary block contraction: one sweep of the b³ block
/// serves r right-hand-side columns.
///
/// `us`, `vs`, `ws` are `(b, r)` row-major panels (`us[x*r + l]` is
/// coordinate `x` of column `l`); the returned `(ci, cj, ck)` are `(b, r)`
/// panels with the same layout, satisfying per column `l`
///
///   ci[a,l] = Σ_{β,γ} A[a,β,γ]·vs[β,l]·ws[γ,l]   (and cj/ck analogously).
///
/// The kernel is the r-tiled version of [`block_contract_native`]: each
/// A-row is loaded once and contracted against all r columns, multiplying
/// the arithmetic intensity by r (the node-level mirror of the multi-vector
/// amortization argument for MTTKRP-style workloads; EXPERIMENTS.md §Perf
/// P6). The inner `l`-loops run over contiguous r-length panel rows and
/// keep the per-row accumulators (`m`, `uv`, `ci_x`) in registers for the
/// practical r ≤ 16 range.
pub fn block_contract_multi(
    a: &[f32],
    us: &[f32],
    vs: &[f32],
    ws: &[f32],
    b: usize,
    r: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(a.len(), b * b * b);
    debug_assert_eq!(us.len(), b * r);
    debug_assert_eq!(vs.len(), b * r);
    debug_assert_eq!(ws.len(), b * r);
    let mut ci = vec![0.0f32; b * r];
    let mut cj = vec![0.0f32; b * r];
    let mut ck = vec![0.0f32; b * r];
    // Per-row accumulators, hoisted out of the loops (one allocation per
    // block, not per row).
    let mut m = vec![0.0f32; r];
    let mut uv = vec![0.0f32; r];
    let mut ci_x = vec![0.0f32; r];
    for x in 0..b {
        let ux = &us[x * r..(x + 1) * r];
        ci_x.fill(0.0);
        for y in 0..b {
            let row = &a[(x * b + y) * b..(x * b + y + 1) * b];
            let vy = &vs[y * r..(y + 1) * r];
            lanes_set_mul(&mut uv, ux, vy);
            m.fill(0.0);
            // Same two-sweep structure as the single-RHS kernel (§Perf P2),
            // with the scalar A element broadcast across the r lanes.
            for z in 0..b {
                lanes_axpy(&mut m, row[z], &ws[z * r..(z + 1) * r]);
            }
            for z in 0..b {
                lanes_axpy(&mut ck[z * r..(z + 1) * r], row[z], &uv);
            }
            lanes_mul_add(&mut ci_x, &m, vy);
            lanes_mul_add(&mut cj[y * r..(y + 1) * r], &m, ux);
        }
        lanes_add(&mut ci[x * r..(x + 1) * r], &ci_x);
    }
    (ci, cj, ck)
}

use crate::tensor::PackedBlockView;

/// Whether two panels are aliases for the diagonal-kernel precondition:
/// the same slice, or bitwise-equal contents. Bit comparison (not `==`)
/// so NaN payloads in the input vectors don't spuriously fail the check —
/// the kernels propagate NaN like the dense path does.
pub(crate) fn panels_alias(a: &[f32], b: &[f32]) -> bool {
    std::ptr::eq(a, b)
        || (a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()))
}

/// Zero-copy fused contraction of an **off-diagonal** block (bi > bj > bk)
/// straight from the packed tensor buffer `t` (EXPERIMENTS.md §Perf P7).
///
/// Same two-sweep loop structure as [`block_contract_native`]; the b-length
/// rows A[x, y, :] come from the contiguous packed γ-runs at
/// [`PackedBlockView::row_base`] instead of a dense copy, so the results
/// are bitwise identical to the dense kernel on the extracted block while
/// the block is never materialized.
pub fn block_contract_packed(
    t: &[f32],
    view: &PackedBlockView,
    u: &[f32],
    v: &[f32],
    w: &[f32],
    b: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert!(view.is_off_diagonal());
    debug_assert_eq!(view.b, b);
    let mut ci = vec![0.0f32; b];
    let mut cj = vec![0.0f32; b];
    let mut ck = vec![0.0f32; b];
    for x in 0..b {
        let ux = u[x];
        let mut ci_x = 0.0f32;
        for y in 0..b {
            let base = view.row_base(x, y);
            let row = &t[base..base + b];
            let uv = ux * v[y];
            let mut m = 0.0f32;
            for z in 0..b {
                m += row[z] * w[z];
            }
            for z in 0..b {
                ck[z] += row[z] * uv;
            }
            ci_x += m * v[y];
            cj[y] += m * ux;
        }
        ci[x] += ci_x;
    }
    (ci, cj, ck)
}

/// Multi-RHS variant of [`block_contract_packed`]: one sweep of the packed
/// off-diagonal block serves r columns. Panel layout as in
/// [`block_contract_multi`]; the loop structure mirrors it exactly, so the
/// per-column results match the dense multi kernel bitwise.
pub fn block_contract_packed_multi(
    t: &[f32],
    view: &PackedBlockView,
    us: &[f32],
    vs: &[f32],
    ws: &[f32],
    b: usize,
    r: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert!(view.is_off_diagonal());
    debug_assert_eq!(view.b, b);
    let mut ci = vec![0.0f32; b * r];
    let mut cj = vec![0.0f32; b * r];
    let mut ck = vec![0.0f32; b * r];
    let mut m = vec![0.0f32; r];
    let mut uv = vec![0.0f32; r];
    let mut ci_x = vec![0.0f32; r];
    for x in 0..b {
        let ux = &us[x * r..(x + 1) * r];
        ci_x.fill(0.0);
        for y in 0..b {
            let base = view.row_base(x, y);
            let row = &t[base..base + b];
            let vy = &vs[y * r..(y + 1) * r];
            lanes_set_mul(&mut uv, ux, vy);
            m.fill(0.0);
            for z in 0..b {
                lanes_axpy(&mut m, row[z], &ws[z * r..(z + 1) * r]);
            }
            for z in 0..b {
                lanes_axpy(&mut ck[z * r..(z + 1) * r], row[z], &uv);
            }
            lanes_mul_add(&mut ci_x, &m, vy);
            lanes_mul_add(&mut cj[y * r..(y + 1) * r], &m, ux);
        }
        lanes_add(&mut ci[x * r..(x + 1) * r], &ci_x);
    }
    (ci, cj, ck)
}

/// Zero-copy symmetry-aware contraction of a **diagonal** block (two or
/// three equal block indices), iterating only the unique packed entries
/// (α ≥ β ≥ γ as applicable) with multiplicity weights — so the executed
/// ternary multiplications equal the paper's §7.1 per-block count
/// ([`packed_ternary_mults`]) exactly, instead of the dense kernel's 3b³
/// (up to ≈6× overshoot on central blocks).
///
/// `u`, `v`, `w` are the x-panels of the block's row blocks i, j, k.
/// **Precondition:** panels of equal block indices must hold equal values —
/// u == v when bi == bj, v == w when bj == bk (the STTSV case, where every
/// panel is a slice of the same x; the coordinator passes aliased slices).
/// The symmetry trick that lets the kernel visit each unique entry once
/// folds the (α,β)↔(β,α) transpose through that equality; with distinct
/// panels the result would be neither A ×₂ v ×₃ w nor its symmetrization
/// (use the dense kernels on [`PackedBlockView::extract_dense`] for a
/// general trilinear form). Returns (ci, cj, ck) numerically equal to the
/// dense kernel's outputs on the extracted block, so the coordinator's
/// per-kind factors apply unchanged; outputs whose factor is always zero
/// for the kind stay zero.
pub fn diag_block_contract_packed(
    t: &[f32],
    view: &PackedBlockView,
    u: &[f32],
    v: &[f32],
    w: &[f32],
    b: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert!(!view.is_off_diagonal());
    debug_assert_eq!(view.b, b);
    debug_assert!(view.bi != view.bj || panels_alias(u, v), "bi == bj requires u == v");
    debug_assert!(view.bj != view.bk || panels_alias(v, w), "bj == bk requires v == w");
    let mut ci = vec![0.0f32; b];
    let mut cj = vec![0.0f32; b];
    let mut ck = vec![0.0f32; b];
    if view.bi == view.bj && view.bj > view.bk {
        // (g,g,h): unique entries have α ≥ β; full-length γ-runs.
        for a in 0..b {
            let ua = u[a];
            let mut ci_a = 0.0f32;
            for be in 0..=a {
                let base = view.row_base(a, be);
                let row = &t[base..base + b];
                let mut m = 0.0f32;
                for g in 0..b {
                    m += row[g] * w[g];
                }
                if a > be {
                    // i > j > k: 3 contributions per entry (weight 2 folded)
                    let uv = 2.0 * ua * v[be];
                    for g in 0..b {
                        ck[g] += row[g] * uv;
                    }
                    ci_a += m * v[be];
                    ci[be] += m * ua;
                } else {
                    // i == j > k: 2 contributions per entry
                    let uu = ua * v[a];
                    for g in 0..b {
                        ck[g] += row[g] * uu;
                    }
                    ci_a += m * ua;
                }
            }
            ci[a] += ci_a;
        }
    } else if view.bi > view.bj && view.bj == view.bk {
        // (g,h,h): unique entries have β ≥ γ; γ-runs of length β+1.
        for a in 0..b {
            let ua = u[a];
            let mut ci_a = 0.0f32;
            for be in 0..b {
                let base = view.row_base(a, be);
                let row = &t[base..base + be + 1];
                let abb = row[be];
                let uv = ua * v[be];
                let mut m = 0.0f32;
                for g in 0..be {
                    m += row[g] * w[g];
                }
                for g in 0..be {
                    cj[g] += row[g] * uv;
                }
                // β > γ entries: 3 contributions (i-weight 2 folded);
                // β == γ entry: 2 contributions
                ci_a += 2.0 * m * v[be] + abb * v[be] * w[be];
                cj[be] += m * ua + abb * ua * w[be];
            }
            ci[a] += ci_a;
        }
    } else {
        // central (g,g,g): unique entries have α ≥ β ≥ γ; all
        // contributions land in the single row block (ci).
        for a in 0..b {
            let ua = u[a];
            let mut ci_a = 0.0f32;
            for be in 0..=a {
                let base = view.row_base(a, be);
                let row = &t[base..base + be + 1];
                if a > be {
                    let mut m = 0.0f32;
                    for g in 0..be {
                        m += row[g] * w[g];
                    }
                    // α > β > γ: 3 contributions, all weights 2
                    let uv = 2.0 * ua * v[be];
                    for g in 0..be {
                        ci[g] += row[g] * uv;
                    }
                    ci_a += 2.0 * m * v[be];
                    ci[be] += 2.0 * m * ua;
                    // α > β == γ: 2 contributions
                    let abb = row[be];
                    ci_a += abb * v[be] * w[be];
                    ci[be] += 2.0 * abb * ua * w[be];
                } else {
                    // α == β > γ: 2 contributions per entry
                    let uu = ua * v[a];
                    let mut m = 0.0f32;
                    for g in 0..a {
                        m += row[g] * w[g];
                    }
                    for g in 0..a {
                        ci[g] += row[g] * uu;
                    }
                    ci_a += 2.0 * m * v[a];
                    // α == β == γ: 1 contribution
                    ci_a += row[a] * v[a] * w[a];
                }
            }
            ci[a] += ci_a;
        }
    }
    (ci, cj, ck)
}

/// Multi-RHS variant of [`diag_block_contract_packed`]: same unique-entry
/// iteration, r-lane inner loops over the `(b, r)` interleaved panels.
/// Same precondition: panels of equal block indices must hold equal values.
pub fn diag_block_contract_packed_multi(
    t: &[f32],
    view: &PackedBlockView,
    us: &[f32],
    vs: &[f32],
    ws: &[f32],
    b: usize,
    r: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert!(!view.is_off_diagonal());
    debug_assert_eq!(view.b, b);
    debug_assert!(view.bi != view.bj || panels_alias(us, vs), "bi == bj requires us == vs");
    debug_assert!(view.bj != view.bk || panels_alias(vs, ws), "bj == bk requires vs == ws");
    let mut ci = vec![0.0f32; b * r];
    let mut cj = vec![0.0f32; b * r];
    let mut ck = vec![0.0f32; b * r];
    let mut m = vec![0.0f32; r];
    let mut uv = vec![0.0f32; r];
    let mut ci_a = vec![0.0f32; r];
    if view.bi == view.bj && view.bj > view.bk {
        for a in 0..b {
            let ua = &us[a * r..(a + 1) * r];
            ci_a.fill(0.0);
            for be in 0..=a {
                let base = view.row_base(a, be);
                let row = &t[base..base + b];
                let vb = &vs[be * r..(be + 1) * r];
                m.fill(0.0);
                for g in 0..b {
                    lanes_axpy(&mut m, row[g], &ws[g * r..(g + 1) * r]);
                }
                if a > be {
                    lanes_set_mul_s(&mut uv, 2.0, ua, vb);
                    for g in 0..b {
                        lanes_axpy(&mut ck[g * r..(g + 1) * r], row[g], &uv);
                    }
                    lanes_mul_add(&mut ci_a, &m, vb);
                    lanes_mul_add(&mut ci[be * r..(be + 1) * r], &m, ua);
                } else {
                    lanes_set_mul(&mut uv, ua, vb);
                    for g in 0..b {
                        lanes_axpy(&mut ck[g * r..(g + 1) * r], row[g], &uv);
                    }
                    lanes_mul_add(&mut ci_a, &m, ua);
                }
            }
            lanes_add(&mut ci[a * r..(a + 1) * r], &ci_a);
        }
    } else if view.bi > view.bj && view.bj == view.bk {
        for a in 0..b {
            let ua = &us[a * r..(a + 1) * r];
            ci_a.fill(0.0);
            for be in 0..b {
                let base = view.row_base(a, be);
                let row = &t[base..base + be + 1];
                let vb = &vs[be * r..(be + 1) * r];
                let wb = &ws[be * r..(be + 1) * r];
                let abb = row[be];
                lanes_set_mul(&mut uv, ua, vb);
                m.fill(0.0);
                for g in 0..be {
                    lanes_axpy(&mut m, row[g], &ws[g * r..(g + 1) * r]);
                }
                for g in 0..be {
                    lanes_axpy(&mut cj[g * r..(g + 1) * r], row[g], &uv);
                }
                lanes_mul_add2_s(&mut ci_a, 2.0, &m, vb, abb, vb, wb);
                lanes_mul_add2(&mut cj[be * r..(be + 1) * r], &m, ua, abb, ua, wb);
            }
            lanes_add(&mut ci[a * r..(a + 1) * r], &ci_a);
        }
    } else {
        for a in 0..b {
            let ua = &us[a * r..(a + 1) * r];
            ci_a.fill(0.0);
            for be in 0..=a {
                let base = view.row_base(a, be);
                let row = &t[base..base + be + 1];
                let vb = &vs[be * r..(be + 1) * r];
                let wb = &ws[be * r..(be + 1) * r];
                if a > be {
                    m.fill(0.0);
                    for g in 0..be {
                        lanes_axpy(&mut m, row[g], &ws[g * r..(g + 1) * r]);
                    }
                    lanes_set_mul_s(&mut uv, 2.0, ua, vb);
                    for g in 0..be {
                        lanes_axpy(&mut ci[g * r..(g + 1) * r], row[g], &uv);
                    }
                    let abb = row[be];
                    lanes_mul_add2_s(&mut ci_a, 2.0, &m, vb, abb, vb, wb);
                    lanes_mul_add2_s(
                        &mut ci[be * r..(be + 1) * r],
                        2.0,
                        &m,
                        ua,
                        2.0 * abb,
                        ua,
                        wb,
                    );
                } else {
                    m.fill(0.0);
                    for g in 0..a {
                        lanes_axpy(&mut m, row[g], &ws[g * r..(g + 1) * r]);
                    }
                    lanes_set_mul(&mut uv, ua, vb);
                    for g in 0..a {
                        lanes_axpy(&mut ci[g * r..(g + 1) * r], row[g], &uv);
                    }
                    let aaa = row[a];
                    lanes_mul_add_s(&mut ci_a, 2.0, &m, vb);
                    lanes_mul_add_s(&mut ci_a, aaa, vb, wb);
                }
            }
            lanes_add(&mut ci[a * r..(a + 1) * r], &ci_a);
        }
    }
    (ci, cj, ck)
}

use crate::tensor::{PackedRun, RunClass};

/// One flattened run descriptor of a compiled sweep program (§Perf P10):
/// the branch-free record the plan compiles each [`PackedRun`] into at
/// build time. `base` is the packed offset of the γ-run, `len` the prefix
/// the m/axpy inner loops sweep (Ghh/Central classes also read the tail
/// entry at `base + len`), and (`x`, `y`) the block-local u/v panel rows.
/// 12 bytes — a worker's whole stream stays cache-resident.
#[derive(Debug, Clone, Copy)]
pub struct RunDesc {
    pub base: u32,
    pub len: u16,
    pub x: u16,
    pub y: u16,
    pub cls: RunClass,
    pub flush: bool,
}

impl RunDesc {
    /// Compile one enumerated run. Panics if the packed offset exceeds
    /// u32 (a > 16 GiB tensor — beyond the simulator's scope).
    pub fn compile(run: &PackedRun) -> RunDesc {
        RunDesc {
            base: u32::try_from(run.base).expect("packed offset exceeds u32"),
            len: u16::try_from(run.len).expect("block size exceeds u16"),
            x: u16::try_from(run.alpha).expect("block size exceeds u16"),
            y: u16::try_from(run.beta).expect("block size exceeds u16"),
            cls: run.cls,
            flush: run.flush,
        }
    }
}

/// `out[l] += f · Σ_off panel[off·r + l]` — weighted column sums of an
/// interleaved `(len, r)` panel. The ABFT verifier's reduction of a
/// block's output panel to its r checksum contributions (§Rob P15): the
/// weighted sums of the three panels equal the block's total contribution
/// to `Σ_i y_i`, compared against the quadratic form `xᵀC_b x`. Skips
/// factor-0 panels exactly like `axpy_panel` skips their accumulation.
pub fn panel_col_sums(panel: &[f32], r: usize, f: f32, out: &mut [f32]) {
    if f == 0.0 {
        return;
    }
    debug_assert_eq!(out.len(), r);
    for row in panel.chunks_exact(r) {
        for (o, v) in out.iter_mut().zip(row) {
            *o += f * v;
        }
    }
}

/// Execute one block's compiled run stream against the packed buffer `t`:
/// the branch-free replay of the packed kernels. `us`/`vs`/`ws` are the
/// block's `(b, r)` input panels (slices of the worker's gather buffer,
/// exactly as the interpreted kernels receive them) and `ci`/`cj`/`ck`
/// zeroed `(b, r)` output panels.
///
/// r ∈ {1, 2, 4, 8} dispatch to register-tiled microkernels whose r-column
/// accumulator tiles (`m`, `uv`, the per-α `acc`) are `[f32; R]` arrays
/// held in registers; other r fall back to the dynamic-width path over the
/// same `chunks_exact` lane helpers as the interpreted kernels. Both paths
/// perform the identical per-lane arithmetic in the identical order, so
/// results are **bitwise equal** to the kernels the plan would otherwise
/// dispatch: the scalar kernels at r = 1, the multi kernels at r ≥ 2
/// (pinned by `compiled_runs_bitwise_match_packed_kernels`; cross-checked
/// op-by-op in f32 in Python).
///
/// At r ∈ {4, 8} this additionally dispatches to the explicit AVX2
/// microkernels in [`super::simd`] when the host supports them and the
/// process-wide [`super::simd::SimdPolicy`] allows it (§Perf P14). Those
/// variants are bitwise-equal too (no FMA contraction, lanes are
/// independent r-columns), so the dispatch is unobservable in results.
#[allow(clippy::too_many_arguments)]
pub fn exec_block_runs(
    t: &[f32],
    descs: &[RunDesc],
    us: &[f32],
    vs: &[f32],
    ws: &[f32],
    ci: &mut [f32],
    cj: &mut [f32],
    ck: &mut [f32],
    r: usize,
) {
    #[cfg(target_arch = "x86_64")]
    if super::simd::use_avx2() {
        // SAFETY: use_avx2() verified the CPU feature at runtime; the
        // kernels bounds-check every panel access via checked slicing.
        match r {
            4 => {
                return unsafe {
                    super::simd::exec_runs_avx2_r4(t, descs, us, vs, ws, ci, cj, ck)
                }
            }
            8 => {
                return unsafe {
                    super::simd::exec_runs_avx2_r8(t, descs, us, vs, ws, ci, cj, ck)
                }
            }
            _ => {}
        }
    }
    exec_block_runs_elem::<f32>(t, descs, us, vs, ws, ci, cj, ck, r)
}

/// Element-generic run executor (no arch-specific variants): the portable
/// path [`exec_block_runs`] routes f32 through, and the entry point the
/// sequential f64 conditioning apps use (`apps::power_method_f64` replays
/// a central block's run stream at r = 1 in full f64).
#[allow(clippy::too_many_arguments)]
pub fn exec_block_runs_elem<E: Element>(
    t: &[E],
    descs: &[RunDesc],
    us: &[E],
    vs: &[E],
    ws: &[E],
    ci: &mut [E],
    cj: &mut [E],
    ck: &mut [E],
    r: usize,
) {
    match r {
        1 => exec_runs_tiled::<1, E>(t, descs, us, vs, ws, ci, cj, ck),
        2 => exec_runs_tiled::<2, E>(t, descs, us, vs, ws, ci, cj, ck),
        4 => exec_runs_tiled::<4, E>(t, descs, us, vs, ws, ci, cj, ck),
        8 => exec_runs_tiled::<8, E>(t, descs, us, vs, ws, ci, cj, ck),
        _ => exec_runs_dyn(t, descs, us, vs, ws, ci, cj, ck, r),
    }
}

/// Register-tiled executor: R is a compile-time constant, so every inner
/// `l`-loop unrolls over an `[E; R]` accumulator tile. At R = 1 the
/// CentralUpper tail updates follow the scalar kernel's two-step adds;
/// at R ≥ 2 the multi kernels' fused two-term updates — the only place
/// the two kernel families' operation order differs.
#[allow(clippy::needless_range_loop, clippy::too_many_arguments)]
fn exec_runs_tiled<const R: usize, E: Element>(
    t: &[E],
    descs: &[RunDesc],
    us: &[E],
    vs: &[E],
    ws: &[E],
    ci: &mut [E],
    cj: &mut [E],
    ck: &mut [E],
) {
    let mut acc = [E::ZERO; R];
    for d in descs {
        let base = d.base as usize;
        let len = d.len as usize;
        let x = d.x as usize;
        let y = d.y as usize;
        let u: [E; R] = us[x * R..(x + 1) * R].try_into().unwrap();
        let v: [E; R] = vs[y * R..(y + 1) * R].try_into().unwrap();
        let row = &t[base..base + len];
        let mut m = [E::ZERO; R];
        for (g, &a) in row.iter().enumerate() {
            let w = &ws[g * R..(g + 1) * R];
            for l in 0..R {
                m[l] += a * w[l];
            }
        }
        match d.cls {
            RunClass::OffDiag => {
                let mut uv = [E::ZERO; R];
                for l in 0..R {
                    uv[l] = u[l] * v[l];
                }
                for (g, &a) in row.iter().enumerate() {
                    let c = &mut ck[g * R..(g + 1) * R];
                    for l in 0..R {
                        c[l] += a * uv[l];
                    }
                }
                for l in 0..R {
                    acc[l] += m[l] * v[l];
                }
                let c = &mut cj[y * R..(y + 1) * R];
                for l in 0..R {
                    c[l] += m[l] * u[l];
                }
            }
            RunClass::GghUpper => {
                let mut uv = [E::ZERO; R];
                for l in 0..R {
                    uv[l] = E::TWO * u[l] * v[l];
                }
                for (g, &a) in row.iter().enumerate() {
                    let c = &mut ck[g * R..(g + 1) * R];
                    for l in 0..R {
                        c[l] += a * uv[l];
                    }
                }
                for l in 0..R {
                    acc[l] += m[l] * v[l];
                }
                let c = &mut ci[y * R..(y + 1) * R];
                for l in 0..R {
                    c[l] += m[l] * u[l];
                }
            }
            RunClass::GghAxis => {
                let mut uv = [E::ZERO; R];
                for l in 0..R {
                    uv[l] = u[l] * v[l];
                }
                for (g, &a) in row.iter().enumerate() {
                    let c = &mut ck[g * R..(g + 1) * R];
                    for l in 0..R {
                        c[l] += a * uv[l];
                    }
                }
                for l in 0..R {
                    acc[l] += m[l] * u[l];
                }
            }
            RunClass::Ghh => {
                let ab = t[base + len];
                let w_y: [E; R] = ws[y * R..(y + 1) * R].try_into().unwrap();
                let mut uv = [E::ZERO; R];
                for l in 0..R {
                    uv[l] = u[l] * v[l];
                }
                for (g, &a) in row.iter().enumerate() {
                    let c = &mut cj[g * R..(g + 1) * R];
                    for l in 0..R {
                        c[l] += a * uv[l];
                    }
                }
                for l in 0..R {
                    acc[l] += E::TWO * m[l] * v[l] + ab * v[l] * w_y[l];
                }
                let c = &mut cj[y * R..(y + 1) * R];
                for l in 0..R {
                    c[l] += m[l] * u[l] + ab * u[l] * w_y[l];
                }
            }
            RunClass::CentralUpper => {
                let ab = t[base + len];
                let w_y: [E; R] = ws[y * R..(y + 1) * R].try_into().unwrap();
                let mut uv = [E::ZERO; R];
                for l in 0..R {
                    uv[l] = E::TWO * u[l] * v[l];
                }
                for (g, &a) in row.iter().enumerate() {
                    let c = &mut ci[g * R..(g + 1) * R];
                    for l in 0..R {
                        c[l] += a * uv[l];
                    }
                }
                if R == 1 {
                    // scalar-kernel order: split two-step adds
                    acc[0] += E::TWO * m[0] * v[0];
                    ci[y] += E::TWO * m[0] * u[0];
                    acc[0] += ab * v[0] * w_y[0];
                    ci[y] += E::TWO * ab * u[0] * w_y[0];
                } else {
                    // multi-kernel order: fused two-term updates
                    let t2 = E::TWO * ab;
                    for l in 0..R {
                        acc[l] += E::TWO * m[l] * v[l] + ab * v[l] * w_y[l];
                    }
                    let c = &mut ci[y * R..(y + 1) * R];
                    for l in 0..R {
                        c[l] += E::TWO * m[l] * u[l] + t2 * u[l] * w_y[l];
                    }
                }
            }
            RunClass::CentralAxis => {
                let aa = t[base + len];
                let w_y: [E; R] = ws[y * R..(y + 1) * R].try_into().unwrap();
                let mut uv = [E::ZERO; R];
                for l in 0..R {
                    uv[l] = u[l] * v[l];
                }
                for (g, &a) in row.iter().enumerate() {
                    let c = &mut ci[g * R..(g + 1) * R];
                    for l in 0..R {
                        c[l] += a * uv[l];
                    }
                }
                for l in 0..R {
                    acc[l] += E::TWO * m[l] * v[l];
                }
                for l in 0..R {
                    acc[l] += aa * v[l] * w_y[l];
                }
            }
        }
        if d.flush {
            let c = &mut ci[x * R..(x + 1) * R];
            for l in 0..R {
                c[l] += acc[l];
            }
            acc = [E::ZERO; R];
        }
    }
}

/// Dynamic-width fallback for r ∉ {1, 2, 4, 8}: the same replay over the
/// `chunks_exact` lane helpers the interpreted multi kernels use, with
/// heap accumulator rows hoisted out of the stream loop. r = 1 never
/// routes here (the tiled R = 1 path carries the scalar-kernel order), so
/// this follows the multi kernels' fused updates throughout.
#[allow(clippy::too_many_arguments)]
fn exec_runs_dyn<E: Element>(
    t: &[E],
    descs: &[RunDesc],
    us: &[E],
    vs: &[E],
    ws: &[E],
    ci: &mut [E],
    cj: &mut [E],
    ck: &mut [E],
    r: usize,
) {
    let mut acc = vec![E::ZERO; r];
    let mut m = vec![E::ZERO; r];
    let mut uv = vec![E::ZERO; r];
    for d in descs {
        let base = d.base as usize;
        let len = d.len as usize;
        let x = d.x as usize;
        let y = d.y as usize;
        let u = &us[x * r..(x + 1) * r];
        let v = &vs[y * r..(y + 1) * r];
        let row = &t[base..base + len];
        m.fill(E::ZERO);
        for (g, &a) in row.iter().enumerate() {
            lanes_axpy(&mut m, a, &ws[g * r..(g + 1) * r]);
        }
        match d.cls {
            RunClass::OffDiag => {
                lanes_set_mul(&mut uv, u, v);
                for (g, &a) in row.iter().enumerate() {
                    lanes_axpy(&mut ck[g * r..(g + 1) * r], a, &uv);
                }
                lanes_mul_add(&mut acc, &m, v);
                lanes_mul_add(&mut cj[y * r..(y + 1) * r], &m, u);
            }
            RunClass::GghUpper => {
                lanes_set_mul_s(&mut uv, E::TWO, u, v);
                for (g, &a) in row.iter().enumerate() {
                    lanes_axpy(&mut ck[g * r..(g + 1) * r], a, &uv);
                }
                lanes_mul_add(&mut acc, &m, v);
                lanes_mul_add(&mut ci[y * r..(y + 1) * r], &m, u);
            }
            RunClass::GghAxis => {
                lanes_set_mul(&mut uv, u, v);
                for (g, &a) in row.iter().enumerate() {
                    lanes_axpy(&mut ck[g * r..(g + 1) * r], a, &uv);
                }
                lanes_mul_add(&mut acc, &m, u);
            }
            RunClass::Ghh => {
                let ab = t[base + len];
                let w_y = &ws[y * r..(y + 1) * r];
                lanes_set_mul(&mut uv, u, v);
                for (g, &a) in row.iter().enumerate() {
                    lanes_axpy(&mut cj[g * r..(g + 1) * r], a, &uv);
                }
                lanes_mul_add2_s(&mut acc, E::TWO, &m, v, ab, v, w_y);
                lanes_mul_add2(&mut cj[y * r..(y + 1) * r], &m, u, ab, u, w_y);
            }
            RunClass::CentralUpper => {
                let ab = t[base + len];
                let w_y = &ws[y * r..(y + 1) * r];
                lanes_set_mul_s(&mut uv, E::TWO, u, v);
                for (g, &a) in row.iter().enumerate() {
                    lanes_axpy(&mut ci[g * r..(g + 1) * r], a, &uv);
                }
                lanes_mul_add2_s(&mut acc, E::TWO, &m, v, ab, v, w_y);
                lanes_mul_add2_s(&mut ci[y * r..(y + 1) * r], E::TWO, &m, u, E::TWO * ab, u, w_y);
            }
            RunClass::CentralAxis => {
                let aa = t[base + len];
                let w_y = &ws[y * r..(y + 1) * r];
                lanes_set_mul(&mut uv, u, v);
                for (g, &a) in row.iter().enumerate() {
                    lanes_axpy(&mut ci[g * r..(g + 1) * r], a, &uv);
                }
                lanes_mul_add_s(&mut acc, E::TWO, &m, v);
                lanes_mul_add_s(&mut acc, aa, v, w_y);
            }
        }
        if d.flush {
            lanes_add(&mut ci[x * r..(x + 1) * r], &acc);
            acc.fill(E::ZERO);
        }
    }
}

/// Ternary multiplications the packed kernels execute for one block, per
/// right-hand-side column — derived by walking the kernels' own loop
/// bounds and summing one count per (unique entry, output contribution)
/// pair. Equals [`crate::coordinator::SttsvPlan`]'s §7.1 logical
/// accounting (`block_ternary_mults`) exactly: the packed path does not
/// overshoot on diagonal blocks the way the dense b³ sweep does.
pub fn packed_ternary_mults(view: &PackedBlockView) -> u64 {
    let b = view.b as u64;
    let mut count = 0u64;
    if view.is_off_diagonal() {
        for _a in 0..b {
            for _be in 0..b {
                count += 3 * b; // every dense row entry serves 3 outputs
            }
        }
    } else if view.bi == view.bj && view.bj > view.bk {
        for a in 0..b {
            for be in 0..=a {
                count += if a > be { 3 * b } else { 2 * b };
            }
        }
    } else if view.bi > view.bj && view.bj == view.bk {
        for _a in 0..b {
            for be in 0..b {
                count += 3 * be + 2;
            }
        }
    } else {
        for a in 0..b {
            for be in 0..=a {
                count += if a > be { 3 * be + 2 } else { 2 * a + 1 };
            }
        }
    }
    count
}

/// Dense STTSV y = A ×₂ x ×₃ x on an n×n×n row-major tensor (Algorithm 3).
pub fn dense_sttsv_native(a: &[f32], x: &[f32], n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut acc = 0.0f64;
        for j in 0..n {
            let row = &a[(i * n + j) * n..(i * n + j + 1) * n];
            let mut inner = 0.0f32;
            for k in 0..n {
                inner += row[k] * x[k];
            }
            acc += inner as f64 * x[j] as f64;
        }
        y[i] = acc as f32;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dense_sttsv_small_known() {
        // n = 2, A[i][j][k] = 1 everywhere, x = (1, 2): y_i = (1+2)² = 9.
        let a = vec![1.0f32; 8];
        let y = dense_sttsv_native(&a, &[1.0, 2.0], 2);
        assert_eq!(y, vec![9.0, 9.0]);
    }

    #[test]
    fn block_contract_on_rank_one_tensor() {
        // A[x,y,z] = p[x]·q[y]·r[z] ⇒ ci = p·(q·v)(r·w), cj = q·(p·u)(r·w),
        // ck = r·(p·u)(q·v).
        let b = 4;
        let mut rng = Rng::new(2);
        let (p, q, r) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        let (u, v, w) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        let mut a = vec![0.0f32; b * b * b];
        for x in 0..b {
            for y in 0..b {
                for z in 0..b {
                    a[(x * b + y) * b + z] = p[x] * q[y] * r[z];
                }
            }
        }
        let dotf = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        let (ci, cj, ck) = block_contract_native(&a, &u, &v, &w, b);
        let (qv, rw, pu) = (dotf(&q, &v), dotf(&r, &w), dotf(&p, &u));
        for t in 0..b {
            assert!((ci[t] - p[t] * qv * rw).abs() < 1e-4);
            assert!((cj[t] - q[t] * pu * rw).abs() < 1e-4);
            assert!((ck[t] - r[t] * pu * qv).abs() < 1e-4);
        }
    }

    #[test]
    fn block_contract_single_entry_pins_index_order() {
        // A zero except at one entry with three DISTINCT indices: pins down
        // the accumulation order exactly (a transposed loop nest would move
        // the nonzero to the wrong output coordinate, which the rank-one and
        // random tests — symmetric in distribution — can miss).
        let b = 5;
        let (x0, y0, z0) = (3usize, 1usize, 4usize);
        let mut a = vec![0.0f32; b * b * b];
        a[(x0 * b + y0) * b + z0] = 2.0;
        let mut rng = Rng::new(11);
        let (u, v, w) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        let (ci, cj, ck) = block_contract_native(&a, &u, &v, &w, b);
        for t in 0..b {
            let want_ci = if t == x0 { 2.0 * v[y0] * w[z0] } else { 0.0 };
            let want_cj = if t == y0 { 2.0 * u[x0] * w[z0] } else { 0.0 };
            let want_ck = if t == z0 { 2.0 * u[x0] * v[y0] } else { 0.0 };
            assert!((ci[t] - want_ci).abs() < 1e-5, "ci[{t}]");
            assert!((cj[t] - want_cj).abs() < 1e-5, "cj[{t}]");
            assert!((ck[t] - want_ck).abs() < 1e-5, "ck[{t}]");
        }
    }

    #[test]
    fn multi_rhs_matches_column_by_column() {
        // The r-column fused kernel must reproduce r independent single-RHS
        // calls exactly (same FP operation order per column).
        let (b, r) = (6usize, 5usize);
        let mut rng = Rng::new(3);
        let a = rng.normal_vec(b * b * b);
        let cols: Vec<[Vec<f32>; 3]> = (0..r)
            .map(|_| [rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b)])
            .collect();
        // interleave into (b, r) panels
        let mut us = vec![0.0f32; b * r];
        let mut vs = vec![0.0f32; b * r];
        let mut ws = vec![0.0f32; b * r];
        for (l, [u, v, w]) in cols.iter().enumerate() {
            for x in 0..b {
                us[x * r + l] = u[x];
                vs[x * r + l] = v[x];
                ws[x * r + l] = w[x];
            }
        }
        let (ci, cj, ck) = block_contract_multi(&a, &us, &vs, &ws, b, r);
        for (l, [u, v, w]) in cols.iter().enumerate() {
            let (si, sj, sk) = block_contract_native(&a, u, v, w, b);
            for t in 0..b {
                assert_eq!(ci[t * r + l], si[t], "col {l} ci[{t}]");
                assert_eq!(cj[t * r + l], sj[t], "col {l} cj[{t}]");
                assert_eq!(ck[t * r + l], sk[t], "col {l} ck[{t}]");
            }
        }
    }

    #[test]
    fn multi_rhs_r1_is_the_single_kernel() {
        let b = 7;
        let mut rng = Rng::new(4);
        let a = rng.normal_vec(b * b * b);
        let (u, v, w) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        let (ci, cj, ck) = block_contract_multi(&a, &u, &v, &w, b, 1);
        let (si, sj, sk) = block_contract_native(&a, &u, &v, &w, b);
        assert_eq!(ci, si);
        assert_eq!(cj, sj);
        assert_eq!(ck, sk);
    }

    use crate::tensor::SymTensor;

    /// (b, r) interleaved panel from per-column vectors.
    fn interleave(cols: &[Vec<f32>], b: usize) -> Vec<f32> {
        let r = cols.len();
        let mut out = vec![0.0f32; b * r];
        for (l, c) in cols.iter().enumerate() {
            for x in 0..b {
                out[x * r + l] = c[x];
            }
        }
        out
    }

    #[test]
    fn packed_offdiag_is_bitwise_the_dense_kernel() {
        // The off-diagonal packed kernel reads the same values in the same
        // order as the dense kernel on the extracted block, so single and
        // multi results must be bitwise identical — the zero-copy path is a
        // pure storage change.
        let (m, b, r) = (5usize, 6usize, 3usize);
        let t = SymTensor::random(m * b, 31);
        let view = PackedBlockView::new(4, 2, 1, b);
        let dense = t.extract_block(4, 2, 1, b);
        let mut rng = Rng::new(32);
        let (u, v, w) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        let got = block_contract_packed(t.packed_data(), &view, &u, &v, &w, b);
        let want = block_contract_native(&dense, &u, &v, &w, b);
        assert_eq!(got, want);
        let us = rng.normal_vec(b * r);
        let vs = rng.normal_vec(b * r);
        let ws = rng.normal_vec(b * r);
        let got = block_contract_packed_multi(t.packed_data(), &view, &us, &vs, &ws, b, r);
        let want = block_contract_multi(&dense, &us, &vs, &ws, b, r);
        assert_eq!(got, want);
    }

    /// Dense f64 brute-force contraction of an extracted block, for
    /// checking the symmetry-aware diagonal kernels.
    fn brute(dense: &[f32], u: &[f32], v: &[f32], w: &[f32], b: usize) -> [Vec<f64>; 3] {
        let mut ci = vec![0.0f64; b];
        let mut cj = vec![0.0f64; b];
        let mut ck = vec![0.0f64; b];
        for x in 0..b {
            for y in 0..b {
                for z in 0..b {
                    let a = dense[(x * b + y) * b + z] as f64;
                    ci[x] += a * v[y] as f64 * w[z] as f64;
                    cj[y] += a * u[x] as f64 * w[z] as f64;
                    ck[z] += a * u[x] as f64 * v[y] as f64;
                }
            }
        }
        [ci, cj, ck]
    }

    #[test]
    fn packed_diagonal_kernels_match_dense_contractions() {
        // For every diagonal shape the packed kernel iterates only unique
        // entries with multiplicity weights, yet its (ci, cj, ck) must be
        // numerically the dense block contractions (so the coordinator's
        // per-kind factors apply unchanged). The kind's never-used outputs
        // stay exactly zero.
        let (m, b) = (4usize, 7usize);
        let t = SymTensor::random(m * b, 33);
        let mut rng = Rng::new(34);
        // (g,g,h): u and v alias row block g, w is row block h
        let xg = rng.normal_vec(b);
        let xh = rng.normal_vec(b);
        let used_ik: &[usize] = &[0, 2];
        let used_ij: &[usize] = &[0, 1];
        let used_i: &[usize] = &[0];
        for (blk, u, v, w, used) in [
            ((3usize, 3usize, 1usize), &xg, &xg, &xh, used_ik), // cj unused
            ((3, 1, 1), &xg, &xh, &xh, used_ij),                // ck unused
            ((2, 2, 2), &xg, &xg, &xg, used_i),                 // only ci used
        ] {
            let view = PackedBlockView::new(blk.0, blk.1, blk.2, b);
            let dense = t.extract_block(blk.0, blk.1, blk.2, b);
            let want = brute(&dense, u, v, w, b);
            let got = diag_block_contract_packed(t.packed_data(), &view, u, v, w, b);
            let got = [&got.0, &got.1, &got.2];
            for &o in used {
                for x in 0..b {
                    assert!(
                        (got[o][x] as f64 - want[o][x]).abs() < 1e-4 * want[o][x].abs().max(1.0),
                        "block {blk:?} out {o} x {x}: {} vs {}",
                        got[o][x],
                        want[o][x]
                    );
                }
            }
            // outputs the coordinator never reads stay identically zero
            for o in 0..3 {
                if !used.contains(&o) {
                    assert!(got[o].iter().all(|&x| x == 0.0), "block {blk:?} out {o}");
                }
            }
        }
    }

    #[test]
    fn packed_diag_multi_matches_column_by_column() {
        let (m, b, r) = (4usize, 6usize, 4usize);
        let t = SymTensor::random(m * b, 35);
        let mut rng = Rng::new(36);
        for blk in [(3usize, 3usize, 0usize), (3, 0, 0), (1, 1, 1)] {
            let view = PackedBlockView::new(blk.0, blk.1, blk.2, b);
            // panels of equal block indices must alias (kernel precondition)
            let ucols: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(b)).collect();
            let vcols: Vec<Vec<f32>> = if blk.0 == blk.1 {
                ucols.clone()
            } else {
                (0..r).map(|_| rng.normal_vec(b)).collect()
            };
            let wcols: Vec<Vec<f32>> = if blk.1 == blk.2 {
                vcols.clone()
            } else {
                (0..r).map(|_| rng.normal_vec(b)).collect()
            };
            let (us, vs, ws) = (
                interleave(&ucols, b),
                interleave(&vcols, b),
                interleave(&wcols, b),
            );
            let (ci, cj, ck) =
                diag_block_contract_packed_multi(t.packed_data(), &view, &us, &vs, &ws, b, r);
            for l in 0..r {
                let (si, sj, sk) = diag_block_contract_packed(
                    t.packed_data(),
                    &view,
                    &ucols[l],
                    &vcols[l],
                    &wcols[l],
                    b,
                );
                for x in 0..b {
                    let tol = |s: f32| 1e-4 * s.abs().max(1.0);
                    assert!(
                        (ci[x * r + l] - si[x]).abs() < tol(si[x]),
                        "{blk:?} col {l} ci[{x}]"
                    );
                    assert!(
                        (cj[x * r + l] - sj[x]).abs() < tol(sj[x]),
                        "{blk:?} col {l} cj[{x}]"
                    );
                    assert!(
                        (ck[x * r + l] - sk[x]).abs() < tol(sk[x]),
                        "{blk:?} col {l} ck[{x}]"
                    );
                }
            }
        }
    }

    /// Compile one view's run stream into descriptors (what the plan
    /// builder does per block).
    fn compile_view(view: &PackedBlockView) -> Vec<RunDesc> {
        let mut descs = Vec::new();
        view.for_each_run(|run| descs.push(RunDesc::compile(&run)));
        descs
    }

    #[test]
    fn compiled_runs_bitwise_match_packed_kernels() {
        // The compiled executor must be BITWISE equal to the kernels the
        // interpreted plan dispatches: the scalar packed kernels at r = 1,
        // the multi kernels at r >= 2 — for every block shape, across the
        // tiled (r ∈ {1, 2, 4, 8}) and dynamic-width (r ∈ {3, 5}) paths.
        let (m, b) = (4usize, 6usize);
        let t = SymTensor::random(m * b, 51);
        let data = t.packed_data();
        let mut rng = Rng::new(52);
        for blk in [(3usize, 2usize, 0usize), (3, 3, 1), (3, 1, 1), (2, 2, 2)] {
            let view = PackedBlockView::new(blk.0, blk.1, blk.2, b);
            let descs = compile_view(&view);
            for r in [1usize, 2, 3, 4, 5, 8] {
                // panels of equal block indices alias (kernel precondition)
                let us = rng.normal_vec(b * r);
                let vs = if blk.0 == blk.1 { us.clone() } else { rng.normal_vec(b * r) };
                let ws = if blk.1 == blk.2 { vs.clone() } else { rng.normal_vec(b * r) };
                let mut ci = vec![0.0f32; b * r];
                let mut cj = vec![0.0f32; b * r];
                let mut ck = vec![0.0f32; b * r];
                exec_block_runs(data, &descs, &us, &vs, &ws, &mut ci, &mut cj, &mut ck, r);
                let want = match (view.is_off_diagonal(), r) {
                    (true, 1) => block_contract_packed(data, &view, &us, &vs, &ws, b),
                    (true, _) => block_contract_packed_multi(data, &view, &us, &vs, &ws, b, r),
                    (false, 1) => diag_block_contract_packed(data, &view, &us, &vs, &ws, b),
                    (false, _) => {
                        diag_block_contract_packed_multi(data, &view, &us, &vs, &ws, b, r)
                    }
                };
                assert_eq!(ci, want.0, "{blk:?} r={r} ci");
                assert_eq!(cj, want.1, "{blk:?} r={r} cj");
                assert_eq!(ck, want.2, "{blk:?} r={r} ck");
            }
        }
    }

    #[test]
    fn compiled_run_mults_equal_kernel_walk() {
        // Σ per-descriptor charge over a block's stream == the kernels'
        // own loop-bound walk (packed_ternary_mults) — one shared source
        // of truth for charged vs executed flops on the compiled path.
        for b in [1usize, 2, 5, 8] {
            for blk in [(3usize, 2usize, 1usize), (3, 3, 1), (3, 1, 1), (2, 2, 2)] {
                let view = PackedBlockView::new(blk.0, blk.1, blk.2, b);
                let mut sum = 0u64;
                view.for_each_run(|run| sum += run.ternary_mults());
                assert_eq!(sum, packed_ternary_mults(&view), "{blk:?} b={b}");
            }
        }
    }

    #[test]
    fn packed_mult_counts_match_paper_accounting() {
        // Executed (unique entry, contribution) pairs per packed kernel ==
        // the §7.1 closed forms the coordinator charges (block_ternary_mults):
        // 3b³ off-diagonal, 3b²(b−1)/2 + 2b² non-central, and
        // b(b−1)(b−2)/2 + 2b(b−1) + b central.
        // b = 1 spot checks (the closed forms below would underflow at
        // bu - 2 in debug builds): one entry per kind, 3/2/2/1 contributions.
        assert_eq!(packed_ternary_mults(&PackedBlockView::new(3, 2, 1, 1)), 3);
        assert_eq!(packed_ternary_mults(&PackedBlockView::new(3, 3, 1, 1)), 2);
        assert_eq!(packed_ternary_mults(&PackedBlockView::new(3, 1, 1, 1)), 2);
        assert_eq!(packed_ternary_mults(&PackedBlockView::new(2, 2, 2, 1)), 1);
        for b in 2..=9usize {
            let bu = b as u64;
            assert_eq!(packed_ternary_mults(&PackedBlockView::new(3, 2, 1, b)), 3 * bu * bu * bu);
            assert_eq!(
                packed_ternary_mults(&PackedBlockView::new(3, 3, 1, b)),
                3 * bu * bu * (bu - 1) / 2 + 2 * bu * bu
            );
            assert_eq!(
                packed_ternary_mults(&PackedBlockView::new(3, 1, 1, b)),
                3 * bu * bu * (bu - 1) / 2 + 2 * bu * bu
            );
            assert_eq!(
                packed_ternary_mults(&PackedBlockView::new(2, 2, 2, b)),
                bu * (bu - 1) * (bu - 2) / 2 + 2 * bu * (bu - 1) + bu
            );
        }
    }
}
