//! Native (pure-Rust) reference implementations of the compute kernels.
//!
//! These serve three purposes: a backend that works without artifacts, a
//! numeric cross-check for the PJRT path, and the CPU roofline baseline for
//! the §Perf comparisons. The loop structure mirrors the Pallas kernel: one
//! pass over A computing all three contractions (3× arithmetic intensity),
//! with the shared intermediate M = A ×₃ w reused by ci and cj.
//!
//! Multi-RHS layout convention (shared with the Pallas kernels and the
//! coordinator): an r-column panel stores coordinate `x` of column `l` at
//! offset `x*r + l` — i.e. a row-major `(b, r)` matrix. The column index
//! varies fastest so the per-coordinate inner loops over `l` touch
//! contiguous memory and autovectorize (EXPERIMENTS.md §Perf P6).

/// Fused ternary block contraction: A is b×b×b row-major ((a·b+β)·b+γ).
///
///   ci[a] = Σ_{β,γ} A[a,β,γ]·v[β]·w[γ]
///   cj[β] = Σ_{a,γ} A[a,β,γ]·u[a]·w[γ]
///   ck[γ] = Σ_{a,β} A[a,β,γ]·u[a]·v[β]
pub fn block_contract_native(
    a: &[f32],
    u: &[f32],
    v: &[f32],
    w: &[f32],
    b: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut ci = vec![0.0f32; b];
    let mut cj = vec![0.0f32; b];
    let mut ck = vec![0.0f32; b];
    // Single pass over A in row-major order: each b-length row A[x,y,:]
    // stays in L1 and is used twice —
    //   m = Σ_z A[x,y,z]·w[z]          (shared between ci and cj)
    //   ci[x] += m·v[y]; cj[y] += m·u[x]
    //   ck[z] += A[x,y,z]·(u[x]·v[y])
    // The dot-product and the axpy run as separate z-sweeps so each
    // autovectorizes cleanly (a combined sweep mixes a reduction with a
    // scatter and defeats SIMD — see EXPERIMENTS.md §Perf P2).
    for x in 0..b {
        let ux = u[x];
        let mut ci_x = 0.0f32;
        for y in 0..b {
            let row = &a[(x * b + y) * b..(x * b + y + 1) * b];
            let uv = ux * v[y];
            let mut m = 0.0f32;
            for z in 0..b {
                m += row[z] * w[z];
            }
            for z in 0..b {
                ck[z] += row[z] * uv;
            }
            ci_x += m * v[y];
            cj[y] += m * ux;
        }
        ci[x] += ci_x;
    }
    (ci, cj, ck)
}

/// Multi-RHS fused ternary block contraction: one sweep of the b³ block
/// serves r right-hand-side columns.
///
/// `us`, `vs`, `ws` are `(b, r)` row-major panels (`us[x*r + l]` is
/// coordinate `x` of column `l`); the returned `(ci, cj, ck)` are `(b, r)`
/// panels with the same layout, satisfying per column `l`
///
///   ci[a,l] = Σ_{β,γ} A[a,β,γ]·vs[β,l]·ws[γ,l]   (and cj/ck analogously).
///
/// The kernel is the r-tiled version of [`block_contract_native`]: each
/// A-row is loaded once and contracted against all r columns, multiplying
/// the arithmetic intensity by r (the node-level mirror of the multi-vector
/// amortization argument for MTTKRP-style workloads; EXPERIMENTS.md §Perf
/// P6). The inner `l`-loops run over contiguous r-length panel rows and
/// keep the per-row accumulators (`m`, `uv`, `ci_x`) in registers for the
/// practical r ≤ 16 range.
pub fn block_contract_multi(
    a: &[f32],
    us: &[f32],
    vs: &[f32],
    ws: &[f32],
    b: usize,
    r: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(a.len(), b * b * b);
    debug_assert_eq!(us.len(), b * r);
    debug_assert_eq!(vs.len(), b * r);
    debug_assert_eq!(ws.len(), b * r);
    let mut ci = vec![0.0f32; b * r];
    let mut cj = vec![0.0f32; b * r];
    let mut ck = vec![0.0f32; b * r];
    // Per-row accumulators, hoisted out of the loops (one allocation per
    // block, not per row).
    let mut m = vec![0.0f32; r];
    let mut uv = vec![0.0f32; r];
    let mut ci_x = vec![0.0f32; r];
    for x in 0..b {
        let ux = &us[x * r..(x + 1) * r];
        ci_x.fill(0.0);
        for y in 0..b {
            let row = &a[(x * b + y) * b..(x * b + y + 1) * b];
            let vy = &vs[y * r..(y + 1) * r];
            for l in 0..r {
                uv[l] = ux[l] * vy[l];
            }
            m.fill(0.0);
            // Same two-sweep structure as the single-RHS kernel (§Perf P2),
            // with the scalar A element broadcast across the r lanes.
            for z in 0..b {
                let az = row[z];
                let wz = &ws[z * r..(z + 1) * r];
                for l in 0..r {
                    m[l] += az * wz[l];
                }
            }
            for z in 0..b {
                let az = row[z];
                let cz = &mut ck[z * r..(z + 1) * r];
                for l in 0..r {
                    cz[l] += az * uv[l];
                }
            }
            let cjy = &mut cj[y * r..(y + 1) * r];
            for l in 0..r {
                ci_x[l] += m[l] * vy[l];
                cjy[l] += m[l] * ux[l];
            }
        }
        let cix = &mut ci[x * r..(x + 1) * r];
        for l in 0..r {
            cix[l] += ci_x[l];
        }
    }
    (ci, cj, ck)
}

/// Dense STTSV y = A ×₂ x ×₃ x on an n×n×n row-major tensor (Algorithm 3).
pub fn dense_sttsv_native(a: &[f32], x: &[f32], n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut acc = 0.0f64;
        for j in 0..n {
            let row = &a[(i * n + j) * n..(i * n + j + 1) * n];
            let mut inner = 0.0f32;
            for k in 0..n {
                inner += row[k] * x[k];
            }
            acc += inner as f64 * x[j] as f64;
        }
        y[i] = acc as f32;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dense_sttsv_small_known() {
        // n = 2, A[i][j][k] = 1 everywhere, x = (1, 2): y_i = (1+2)² = 9.
        let a = vec![1.0f32; 8];
        let y = dense_sttsv_native(&a, &[1.0, 2.0], 2);
        assert_eq!(y, vec![9.0, 9.0]);
    }

    #[test]
    fn block_contract_on_rank_one_tensor() {
        // A[x,y,z] = p[x]·q[y]·r[z] ⇒ ci = p·(q·v)(r·w), cj = q·(p·u)(r·w),
        // ck = r·(p·u)(q·v).
        let b = 4;
        let mut rng = Rng::new(2);
        let (p, q, r) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        let (u, v, w) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        let mut a = vec![0.0f32; b * b * b];
        for x in 0..b {
            for y in 0..b {
                for z in 0..b {
                    a[(x * b + y) * b + z] = p[x] * q[y] * r[z];
                }
            }
        }
        let dotf = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        let (ci, cj, ck) = block_contract_native(&a, &u, &v, &w, b);
        let (qv, rw, pu) = (dotf(&q, &v), dotf(&r, &w), dotf(&p, &u));
        for t in 0..b {
            assert!((ci[t] - p[t] * qv * rw).abs() < 1e-4);
            assert!((cj[t] - q[t] * pu * rw).abs() < 1e-4);
            assert!((ck[t] - r[t] * pu * qv).abs() < 1e-4);
        }
    }

    #[test]
    fn block_contract_single_entry_pins_index_order() {
        // A zero except at one entry with three DISTINCT indices: pins down
        // the accumulation order exactly (a transposed loop nest would move
        // the nonzero to the wrong output coordinate, which the rank-one and
        // random tests — symmetric in distribution — can miss).
        let b = 5;
        let (x0, y0, z0) = (3usize, 1usize, 4usize);
        let mut a = vec![0.0f32; b * b * b];
        a[(x0 * b + y0) * b + z0] = 2.0;
        let mut rng = Rng::new(11);
        let (u, v, w) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        let (ci, cj, ck) = block_contract_native(&a, &u, &v, &w, b);
        for t in 0..b {
            let want_ci = if t == x0 { 2.0 * v[y0] * w[z0] } else { 0.0 };
            let want_cj = if t == y0 { 2.0 * u[x0] * w[z0] } else { 0.0 };
            let want_ck = if t == z0 { 2.0 * u[x0] * v[y0] } else { 0.0 };
            assert!((ci[t] - want_ci).abs() < 1e-5, "ci[{t}]");
            assert!((cj[t] - want_cj).abs() < 1e-5, "cj[{t}]");
            assert!((ck[t] - want_ck).abs() < 1e-5, "ck[{t}]");
        }
    }

    #[test]
    fn multi_rhs_matches_column_by_column() {
        // The r-column fused kernel must reproduce r independent single-RHS
        // calls exactly (same FP operation order per column).
        let (b, r) = (6usize, 5usize);
        let mut rng = Rng::new(3);
        let a = rng.normal_vec(b * b * b);
        let cols: Vec<[Vec<f32>; 3]> = (0..r)
            .map(|_| [rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b)])
            .collect();
        // interleave into (b, r) panels
        let mut us = vec![0.0f32; b * r];
        let mut vs = vec![0.0f32; b * r];
        let mut ws = vec![0.0f32; b * r];
        for (l, [u, v, w]) in cols.iter().enumerate() {
            for x in 0..b {
                us[x * r + l] = u[x];
                vs[x * r + l] = v[x];
                ws[x * r + l] = w[x];
            }
        }
        let (ci, cj, ck) = block_contract_multi(&a, &us, &vs, &ws, b, r);
        for (l, [u, v, w]) in cols.iter().enumerate() {
            let (si, sj, sk) = block_contract_native(&a, u, v, w, b);
            for t in 0..b {
                assert_eq!(ci[t * r + l], si[t], "col {l} ci[{t}]");
                assert_eq!(cj[t * r + l], sj[t], "col {l} cj[{t}]");
                assert_eq!(ck[t * r + l], sk[t], "col {l} ck[{t}]");
            }
        }
    }

    #[test]
    fn multi_rhs_r1_is_the_single_kernel() {
        let b = 7;
        let mut rng = Rng::new(4);
        let a = rng.normal_vec(b * b * b);
        let (u, v, w) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        let (ci, cj, ck) = block_contract_multi(&a, &u, &v, &w, b, 1);
        let (si, sj, sk) = block_contract_native(&a, &u, &v, &w, b);
        assert_eq!(ci, si);
        assert_eq!(cj, sj);
        assert_eq!(ck, sk);
    }
}
