//! PJRT runtime: loads the AOT-compiled HLO artifacts (JAX/Pallas lowered at
//! build time by `python/compile/aot.py`) and executes them from the Rust
//! hot path. Python never runs here.
//!
//! Threading: the `xla` crate's PJRT wrappers hold raw pointers that are not
//! `Send`/`Sync`, while the simulator runs P worker threads. All PJRT
//! objects therefore live on one dedicated **engine service thread**; worker
//! threads talk to it over a channel. The native backend computes inline on
//! the calling thread (used for cross-checks and as the CPU perf baseline).
//!
//! Build-time gating: the `xla` crate is not vendored in every environment,
//! so everything that names it lives behind the off-by-default `pjrt` cargo
//! feature. Without the feature the engine still parses manifests and
//! resolves artifact names, but executing a request returns an error that
//! says how to enable the backend. See rust/Cargo.toml for the recipe.

mod native;
pub mod simd;

pub use native::{
    block_contract_multi, block_contract_native, block_contract_packed,
    block_contract_packed_multi, dense_sttsv_native, diag_block_contract_packed,
    diag_block_contract_packed_multi, exec_block_runs, exec_block_runs_elem,
    packed_ternary_mults, panel_col_sums, RunDesc,
};
pub use simd::{avx2_available, set_simd_policy, simd_policy, SimdPolicy};
pub(crate) use simd::{lanes_add, lanes_axpy};

use crate::tensor::PackedBlockView;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::mpsc;

/// Which compute backend executes block contractions. (`Hash` because the
/// backend is part of the serving layer's plan-cache key via `ExecOpts`.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Pure-Rust loops (always available; cross-check + perf baseline).
    Native,
    /// AOT JAX/Pallas kernels via the PJRT CPU client.
    Pjrt,
}

impl std::str::FromStr for Backend {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt),
            other => bail!("unknown backend '{other}' (use native|pjrt)"),
        }
    }
}

/// Resolve the artifacts directory: $STTSV_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("STTSV_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// A request to the engine service thread: execute artifact `name` on
/// f32 inputs with the given dims; reply with the output tuple.
struct Req {
    name: String,
    inputs: Vec<(Vec<f32>, Vec<i64>)>,
    reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
}

/// Handle to the engine. Cheap to clone; safe to use from many threads.
#[derive(Clone)]
pub struct Engine {
    backend: Backend,
    tx: Option<mpsc::Sender<Req>>,
    available: HashSet<String>,
}

impl Engine {
    /// Create an engine. For [`Backend::Pjrt`] this spawns the service
    /// thread, creates the PJRT CPU client there, and reads the artifact
    /// manifest; executables are compiled lazily and cached by name.
    pub fn new(backend: Backend) -> Result<Engine> {
        match backend {
            Backend::Native => Ok(Engine {
                backend,
                tx: None,
                available: HashSet::new(),
            }),
            Backend::Pjrt => {
                let dir = artifacts_dir();
                let manifest = dir.join("manifest.txt");
                let text = std::fs::read_to_string(&manifest).with_context(|| {
                    format!(
                        "reading {} — run `make artifacts` first",
                        manifest.display()
                    )
                })?;
                let mut available = HashSet::new();
                for line in text.lines() {
                    if let Some(name) = line
                        .split_whitespace()
                        .find_map(|f| f.strip_prefix("name="))
                    {
                        available.insert(name.to_string());
                    }
                }
                let (tx, rx) = mpsc::channel::<Req>();
                std::thread::Builder::new()
                    .name("pjrt-engine".into())
                    .spawn(move || service_loop(rx, dir))
                    .context("spawning engine thread")?;
                Ok(Engine {
                    backend,
                    tx: Some(tx),
                    available,
                })
            }
        }
    }

    /// Process-wide shared engine per backend. The PJRT engine owns an
    /// executable cache keyed by artifact name; sharing it across
    /// `run_sttsv` calls means each artifact is compiled once per process
    /// instead of once per call — the dominant cost of iterative apps like
    /// the power method (see EXPERIMENTS.md §Perf, P1).
    pub fn shared(backend: Backend) -> Result<Engine> {
        use std::sync::OnceLock;
        static NATIVE: OnceLock<Engine> = OnceLock::new();
        static PJRT: OnceLock<std::result::Result<Engine, String>> = OnceLock::new();
        match backend {
            Backend::Native => Ok(NATIVE
                .get_or_init(|| Engine::new(Backend::Native).expect("native engine"))
                .clone()),
            Backend::Pjrt => PJRT
                .get_or_init(|| Engine::new(Backend::Pjrt).map_err(|e| format!("{e:#}")))
                .clone()
                .map_err(|e| anyhow!("{e}")),
        }
    }

    /// The backend this engine runs.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Whether an artifact with this name exists in the manifest.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.available.contains(name)
    }

    fn call(&self, name: &str, inputs: Vec<(Vec<f32>, Vec<i64>)>) -> Result<Vec<Vec<f32>>> {
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| anyhow!("engine has no PJRT service thread"))?;
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(Req {
            name: name.to_string(),
            inputs,
            reply: reply_tx,
        })
        .map_err(|_| anyhow!("engine thread gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("engine thread dropped reply"))?
    }

    /// Fused ternary block contraction on one b×b×b block (L1 kernel):
    /// returns (ci, cj, ck). Dispatches to the `block_b{b}` artifact or the
    /// native loops.
    pub fn block_contract(
        &self,
        a: &[f32],
        u: &[f32],
        v: &[f32],
        w: &[f32],
        b: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        debug_assert_eq!(a.len(), b * b * b);
        match self.backend {
            Backend::Native => Ok(block_contract_native(a, u, v, w, b)),
            Backend::Pjrt => {
                let name = format!("block_b{b}");
                if !self.has_artifact(&name) {
                    bail!("artifact {name} not in manifest; re-run make artifacts");
                }
                let bt = b as i64;
                let out = self.call(
                    &name,
                    vec![
                        (a.to_vec(), vec![bt, bt, bt]),
                        (u.to_vec(), vec![bt]),
                        (v.to_vec(), vec![bt]),
                        (w.to_vec(), vec![bt]),
                    ],
                )?;
                let [ci, cj, ck]: [Vec<f32>; 3] = out
                    .try_into()
                    .map_err(|_| anyhow!("{name}: expected 3 outputs"))?;
                Ok((ci, cj, ck))
            }
        }
    }

    /// Batched fused contraction over `nb` stacked blocks (the hot-path
    /// variant: one PJRT dispatch per block type). Falls back to looping
    /// single-block calls when no `block_batch_b{b}_nb{nb}` artifact exists.
    #[allow(clippy::too_many_arguments)]
    pub fn block_contract_batch(
        &self,
        a: &[f32],
        u: &[f32],
        v: &[f32],
        w: &[f32],
        b: usize,
        nb: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        debug_assert_eq!(a.len(), nb * b * b * b);
        match self.backend {
            Backend::Native => {
                let mut ci = Vec::with_capacity(nb * b);
                let mut cj = Vec::with_capacity(nb * b);
                let mut ck = Vec::with_capacity(nb * b);
                for s in 0..nb {
                    let (x, y, z) = block_contract_native(
                        &a[s * b * b * b..(s + 1) * b * b * b],
                        &u[s * b..(s + 1) * b],
                        &v[s * b..(s + 1) * b],
                        &w[s * b..(s + 1) * b],
                        b,
                    );
                    ci.extend(x);
                    cj.extend(y);
                    ck.extend(z);
                }
                Ok((ci, cj, ck))
            }
            Backend::Pjrt => {
                let name = format!("block_batch_b{b}_nb{nb}");
                if !self.has_artifact(&name) {
                    // loop the single-block artifact
                    let mut ci = Vec::with_capacity(nb * b);
                    let mut cj = Vec::with_capacity(nb * b);
                    let mut ck = Vec::with_capacity(nb * b);
                    for s in 0..nb {
                        let (x, y, z) = self.block_contract(
                            &a[s * b * b * b..(s + 1) * b * b * b],
                            &u[s * b..(s + 1) * b],
                            &v[s * b..(s + 1) * b],
                            &w[s * b..(s + 1) * b],
                            b,
                        )?;
                        ci.extend(x);
                        cj.extend(y);
                        ck.extend(z);
                    }
                    return Ok((ci, cj, ck));
                }
                let (nbt, bt) = (nb as i64, b as i64);
                let out = self.call(
                    &name,
                    vec![
                        (a.to_vec(), vec![nbt, bt, bt, bt]),
                        (u.to_vec(), vec![nbt, bt]),
                        (v.to_vec(), vec![nbt, bt]),
                        (w.to_vec(), vec![nbt, bt]),
                    ],
                )?;
                let [ci, cj, ck]: [Vec<f32>; 3] = out
                    .try_into()
                    .map_err(|_| anyhow!("{name}: expected 3 outputs"))?;
                Ok((ci, cj, ck))
            }
        }
    }

    /// Multi-RHS fused contraction on one b×b×b block: `us`/`vs`/`ws` and
    /// the returned (ci, cj, ck) are `(b, r)` row-major panels (see
    /// [`block_contract_multi`]). One sweep of A serves all r columns.
    ///
    /// Dispatch: native loops, or the `block_multi_b{b}_r{r}` artifact; when
    /// the artifact is missing, falls back to de-interleaving the panels and
    /// looping the single-RHS path per column (correct, r× the A traffic).
    #[allow(clippy::too_many_arguments)]
    pub fn block_contract_multi(
        &self,
        a: &[f32],
        us: &[f32],
        vs: &[f32],
        ws: &[f32],
        b: usize,
        r: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        debug_assert_eq!(a.len(), b * b * b);
        debug_assert_eq!(us.len(), b * r);
        if r == 1 {
            // (b, 1) panels are plain vectors: reuse the single-RHS path and
            // its wider artifact coverage.
            return self.block_contract(a, us, vs, ws, b);
        }
        match self.backend {
            Backend::Native => Ok(block_contract_multi(a, us, vs, ws, b, r)),
            Backend::Pjrt => {
                let name = format!("block_multi_b{b}_r{r}");
                if !self.has_artifact(&name) {
                    return self.multi_via_columns(a, us, vs, ws, b, r);
                }
                let (bt, rt) = (b as i64, r as i64);
                let out = self.call(
                    &name,
                    vec![
                        (a.to_vec(), vec![bt, bt, bt]),
                        (us.to_vec(), vec![bt, rt]),
                        (vs.to_vec(), vec![bt, rt]),
                        (ws.to_vec(), vec![bt, rt]),
                    ],
                )?;
                let [ci, cj, ck]: [Vec<f32>; 3] = out
                    .try_into()
                    .map_err(|_| anyhow!("{name}: expected 3 outputs"))?;
                Ok((ci, cj, ck))
            }
        }
    }

    /// Batched multi-RHS contraction over `nb` stacked blocks: inputs and
    /// outputs are `(nb, b, r)` stacks of panels. The L3 hot path for
    /// [`crate::coordinator::SttsvPlan::run_multi`]: one dispatch per block
    /// kind per processor, sweeping each block once for all r columns.
    #[allow(clippy::too_many_arguments)]
    pub fn block_contract_multi_batch(
        &self,
        a: &[f32],
        us: &[f32],
        vs: &[f32],
        ws: &[f32],
        b: usize,
        nb: usize,
        r: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        debug_assert_eq!(a.len(), nb * b * b * b);
        debug_assert_eq!(us.len(), nb * b * r);
        if r == 1 {
            return self.block_contract_batch(a, us, vs, ws, b, nb);
        }
        if self.backend == Backend::Pjrt {
            let name = format!("block_multi_batch_b{b}_nb{nb}_r{r}");
            if self.has_artifact(&name) {
                let (nbt, bt, rt) = (nb as i64, b as i64, r as i64);
                let out = self.call(
                    &name,
                    vec![
                        (a.to_vec(), vec![nbt, bt, bt, bt]),
                        (us.to_vec(), vec![nbt, bt, rt]),
                        (vs.to_vec(), vec![nbt, bt, rt]),
                        (ws.to_vec(), vec![nbt, bt, rt]),
                    ],
                )?;
                let [ci, cj, ck]: [Vec<f32>; 3] = out
                    .try_into()
                    .map_err(|_| anyhow!("{name}: expected 3 outputs"))?;
                return Ok((ci, cj, ck));
            }
        }
        // PJRT without the batched-multi artifact but WITHOUT a per-block
        // multi artifact either: de-interleave once and run the single-RHS
        // batched path per column (r dispatches, keeping the nb-dispatch
        // amortization) instead of degrading to nb·r per-block round-trips.
        let have_per_block_multi = self.has_artifact(&format!("block_multi_b{b}_r{r}"));
        if self.backend == Backend::Pjrt && !have_per_block_multi {
            return per_column_fallback(us, vs, ws, nb * b, r, |u, v, w| {
                self.block_contract_batch(a, u, v, w, b, nb)
            });
        }
        // Native (no dispatch cost), or PJRT with the per-block multi
        // artifact: loop the multi kernel per block (nb dispatches).
        let mut ci = Vec::with_capacity(nb * b * r);
        let mut cj = Vec::with_capacity(nb * b * r);
        let mut ck = Vec::with_capacity(nb * b * r);
        for s in 0..nb {
            let (x, y, z) = self.block_contract_multi(
                &a[s * b * b * b..(s + 1) * b * b * b],
                &us[s * b * r..(s + 1) * b * r],
                &vs[s * b * r..(s + 1) * b * r],
                &ws[s * b * r..(s + 1) * b * r],
                b,
                r,
            )?;
            ci.extend(x);
            cj.extend(y);
            ck.extend(z);
        }
        Ok((ci, cj, ck))
    }

    /// Zero-copy fused contraction of one lower-tetrahedral block straight
    /// from the packed tensor buffer `t` (§Perf P7). Native dispatches to
    /// the strided-row kernel (off-diagonal) or the symmetry-aware diagonal
    /// kernels; PJRT has no packed artifacts, so it extracts the dense
    /// block **on the fly** (transient, freed after the dispatch) and runs
    /// the dense path — correctness identical, no resident copies.
    ///
    /// For diagonal views the panels inherit the symmetric-kernel
    /// precondition (u == v when bi == bj, v == w when bj == bk — the
    /// STTSV case; see [`diag_block_contract_packed`]); the native path
    /// returns an error when it is violated.
    pub fn block_contract_packed(
        &self,
        t: &[f32],
        view: &PackedBlockView,
        u: &[f32],
        v: &[f32],
        w: &[f32],
        b: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        match self.backend {
            Backend::Native => {
                if view.is_off_diagonal() {
                    Ok(block_contract_packed(t, view, u, v, w, b))
                } else {
                    check_diag_aliasing(view, u, v, w)?;
                    Ok(diag_block_contract_packed(t, view, u, v, w, b))
                }
            }
            Backend::Pjrt => {
                let a = view.extract_dense(t);
                self.block_contract(&a, u, v, w, b)
            }
        }
    }

    /// Multi-RHS zero-copy contraction of one packed block: the packed
    /// counterpart of [`Engine::block_contract_multi`]. See
    /// [`Engine::block_contract_packed`] for the per-backend strategy.
    #[allow(clippy::too_many_arguments)]
    pub fn block_contract_packed_multi(
        &self,
        t: &[f32],
        view: &PackedBlockView,
        us: &[f32],
        vs: &[f32],
        ws: &[f32],
        b: usize,
        r: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        debug_assert_eq!(us.len(), b * r);
        if r == 1 {
            return self.block_contract_packed(t, view, us, vs, ws, b);
        }
        match self.backend {
            Backend::Native => {
                if view.is_off_diagonal() {
                    Ok(block_contract_packed_multi(t, view, us, vs, ws, b, r))
                } else {
                    check_diag_aliasing(view, us, vs, ws)?;
                    Ok(diag_block_contract_packed_multi(t, view, us, vs, ws, b, r))
                }
            }
            Backend::Pjrt => {
                let a = view.extract_dense(t);
                self.block_contract_multi(&a, us, vs, ws, b, r)
            }
        }
    }

    /// Batched multi-RHS contraction over a same-kind group of packed
    /// blocks — the packed counterpart of
    /// [`Engine::block_contract_multi_batch`]. Native loops the per-block
    /// packed kernels (no dispatch cost to amortize); PJRT materializes
    /// the **active group only** on the fly and issues one batched dense
    /// dispatch, so peak transient memory is one group's blocks rather
    /// than the whole plan's.
    #[allow(clippy::too_many_arguments)]
    pub fn block_contract_packed_batch(
        &self,
        t: &[f32],
        views: &[PackedBlockView],
        us: &[f32],
        vs: &[f32],
        ws: &[f32],
        b: usize,
        r: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let nb = views.len();
        debug_assert_eq!(us.len(), nb * b * r);
        match self.backend {
            Backend::Native => {
                let mut ci = Vec::with_capacity(nb * b * r);
                let mut cj = Vec::with_capacity(nb * b * r);
                let mut ck = Vec::with_capacity(nb * b * r);
                for (s, view) in views.iter().enumerate() {
                    let (x, y, z) = self.block_contract_packed_multi(
                        t,
                        view,
                        &us[s * b * r..(s + 1) * b * r],
                        &vs[s * b * r..(s + 1) * b * r],
                        &ws[s * b * r..(s + 1) * b * r],
                        b,
                        r,
                    )?;
                    ci.extend(x);
                    cj.extend(y);
                    ck.extend(z);
                }
                Ok((ci, cj, ck))
            }
            Backend::Pjrt => {
                let mut a = Vec::with_capacity(nb * b * b * b);
                for view in views {
                    a.extend(view.extract_dense(t));
                }
                self.block_contract_multi_batch(&a, us, vs, ws, b, nb, r)
            }
        }
    }

    /// Column-loop fallback for the multi path: de-interleave the `(b, r)`
    /// panels, run the single-RHS kernel per column, re-interleave.
    fn multi_via_columns(
        &self,
        a: &[f32],
        us: &[f32],
        vs: &[f32],
        ws: &[f32],
        b: usize,
        r: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        per_column_fallback(us, vs, ws, b, r, |u, v, w| self.block_contract(a, u, v, w, b))
    }

    /// Dense STTSV on an n×n×n row-major tensor (Algorithm 3 baseline
    /// executable `dense_sttsv_n{n}`, or native loops).
    pub fn dense_sttsv(&self, a: &[f32], x: &[f32], n: usize) -> Result<Vec<f32>> {
        debug_assert_eq!(a.len(), n * n * n);
        match self.backend {
            Backend::Native => Ok(dense_sttsv_native(a, x, n)),
            Backend::Pjrt => {
                let name = format!("dense_sttsv_n{n}");
                if !self.has_artifact(&name) {
                    return Ok(dense_sttsv_native(a, x, n));
                }
                let nt = n as i64;
                let out = self.call(
                    &name,
                    vec![(a.to_vec(), vec![nt, nt, nt]), (x.to_vec(), vec![nt])],
                )?;
                out.into_iter()
                    .next()
                    .ok_or_else(|| anyhow!("{name}: missing output"))
            }
        }
    }
}

/// Enforce the symmetric diagonal kernels' precondition at the public
/// Engine boundary, in release builds too: panels of equal block indices
/// must hold equal values (see `diag_block_contract_packed`). Bitwise
/// comparison so NaN inputs propagate like the dense path instead of
/// tripping the check. O(b·r) — noise next to the O(b³·r) contraction.
fn check_diag_aliasing(view: &PackedBlockView, u: &[f32], v: &[f32], w: &[f32]) -> Result<()> {
    ensure!(
        view.bi != view.bj || native::panels_alias(u, v),
        "diagonal packed contraction with bi == bj requires u == v \
         (STTSV panel aliasing); use extract_dense + the dense kernels \
         for a general trilinear form"
    );
    ensure!(
        view.bj != view.bk || native::panels_alias(v, w),
        "diagonal packed contraction with bj == bk requires v == w \
         (STTSV panel aliasing); use extract_dense + the dense kernels \
         for a general trilinear form"
    );
    Ok(())
}

/// Shared column-loop fallback for the multi-RHS paths: de-interleave the
/// `(len, r)` row-major panels into per-column vectors, run `call` per
/// column, re-interleave the outputs. Used when no multi artifact covers
/// the requested r; correctness is identical to the fused path, the cost
/// is r single-RHS sweeps.
fn per_column_fallback(
    us: &[f32],
    vs: &[f32],
    ws: &[f32],
    len: usize,
    r: usize,
    mut call: impl FnMut(&[f32], &[f32], &[f32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)>,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
    let mut ci = vec![0.0f32; len * r];
    let mut cj = vec![0.0f32; len * r];
    let mut ck = vec![0.0f32; len * r];
    let mut u = vec![0.0f32; len];
    let mut v = vec![0.0f32; len];
    let mut w = vec![0.0f32; len];
    for l in 0..r {
        for x in 0..len {
            u[x] = us[x * r + l];
            v[x] = vs[x * r + l];
            w[x] = ws[x * r + l];
        }
        let (si, sj, sk) = call(&u, &v, &w)?;
        for x in 0..len {
            ci[x * r + l] = si[x];
            cj[x * r + l] = sj[x];
            ck[x * r + l] = sk[x];
        }
    }
    Ok((ci, cj, ck))
}

/// The engine service loop: owns the PJRT client and the executable cache.
#[cfg(feature = "pjrt")]
fn service_loop(rx: mpsc::Receiver<Req>, dir: PathBuf) {
    use std::collections::HashMap;
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with the client error.
            while let Ok(req) = rx.recv() {
                let _ = req
                    .reply
                    .send(Err(anyhow!("PJRT CPU client failed: {e:?}")));
            }
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    while let Ok(req) = rx.recv() {
        let result = execute(&client, &mut cache, &dir, &req);
        let _ = req.reply.send(result);
    }
}

/// Stub service loop when the crate is built without the `pjrt` feature:
/// every request fails with a pointer at the build recipe. Keeping the
/// thread + channel shape identical means `Engine::new(Backend::Pjrt)` and
/// manifest introspection behave the same either way.
#[cfg(not(feature = "pjrt"))]
fn service_loop(rx: mpsc::Receiver<Req>, _dir: PathBuf) {
    while let Ok(req) = rx.recv() {
        let _ = req.reply.send(Err(anyhow!(
            "PJRT backend unavailable: built without the `pjrt` feature \
             (add the `xla` dependency and build with --features pjrt; \
             see rust/Cargo.toml)"
        )));
    }
}

#[cfg(feature = "pjrt")]
fn execute(
    client: &xla::PjRtClient,
    cache: &mut std::collections::HashMap<String, xla::PjRtLoadedExecutable>,
    dir: &std::path::Path,
    req: &Req,
) -> Result<Vec<Vec<f32>>> {
    if !cache.contains_key(&req.name) {
        let path = dir.join(format!("{}.hlo.txt", req.name));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", req.name))?;
        cache.insert(req.name.clone(), exe);
    }
    let exe = cache.get(&req.name).unwrap();
    let literals: Vec<xla::Literal> = req
        .inputs
        .iter()
        .map(|(data, dims)| {
            xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
        })
        .collect::<Result<_>>()?;
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow!("executing {}: {e:?}", req.name))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("sync {}: {e:?}", req.name))?;
    // aot.py lowers with return_tuple=True: always a tuple.
    let parts = result
        .to_tuple()
        .map_err(|e| anyhow!("tuple {}: {e:?}", req.name))?;
    parts
        .into_iter()
        .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn native_block_contract_matches_brute_force() {
        let b = 5;
        let mut rng = Rng::new(1);
        let a = rng.normal_vec(b * b * b);
        let (u, v, w) = (rng.normal_vec(b), rng.normal_vec(b), rng.normal_vec(b));
        let (ci, cj, ck) = block_contract_native(&a, &u, &v, &w, b);
        for x in 0..b {
            let mut wi = 0.0f64;
            let mut wj = 0.0f64;
            let mut wk = 0.0f64;
            for y in 0..b {
                for z in 0..b {
                    wi += a[(x * b + y) * b + z] as f64 * v[y] as f64 * w[z] as f64;
                    wj += a[(y * b + x) * b + z] as f64 * u[y] as f64 * w[z] as f64;
                    wk += a[(y * b + z) * b + x] as f64 * u[y] as f64 * v[z] as f64;
                }
            }
            assert!((ci[x] as f64 - wi).abs() < 1e-4);
            assert!((cj[x] as f64 - wj).abs() < 1e-4);
            assert!((ck[x] as f64 - wk).abs() < 1e-4);
        }
    }

    #[test]
    fn engine_multi_batch_native_matches_per_block_multi() {
        let (b, nb, r) = (4usize, 3usize, 4usize);
        let mut rng = Rng::new(21);
        let a = rng.normal_vec(nb * b * b * b);
        let us = rng.normal_vec(nb * b * r);
        let vs = rng.normal_vec(nb * b * r);
        let ws = rng.normal_vec(nb * b * r);
        let eng = Engine::new(Backend::Native).unwrap();
        let (ci, cj, ck) = eng
            .block_contract_multi_batch(&a, &us, &vs, &ws, b, nb, r)
            .unwrap();
        for s in 0..nb {
            let (x, y, z) = block_contract_multi(
                &a[s * b * b * b..(s + 1) * b * b * b],
                &us[s * b * r..(s + 1) * b * r],
                &vs[s * b * r..(s + 1) * b * r],
                &ws[s * b * r..(s + 1) * b * r],
                b,
                r,
            );
            assert_eq!(&ci[s * b * r..(s + 1) * b * r], &x[..], "block {s} ci");
            assert_eq!(&cj[s * b * r..(s + 1) * b * r], &y[..], "block {s} cj");
            assert_eq!(&ck[s * b * r..(s + 1) * b * r], &z[..], "block {s} ck");
        }
    }

    #[test]
    fn engine_packed_batch_matches_dense_path() {
        // The zero-copy packed dispatch must agree with the dense-extract
        // dispatch on a mixed group (off-diagonal + both non-central shapes
        // + central) — bitwise on off-diagonal blocks, within fp tolerance
        // on diagonal ones.
        let (m, b, r) = (4usize, 5usize, 3usize);
        let t = crate::tensor::SymTensor::random(m * b, 41);
        let views: Vec<PackedBlockView> = [(3, 2, 0), (3, 3, 1), (3, 1, 1), (2, 2, 2)]
            .iter()
            .map(|&(i, j, k)| PackedBlockView::new(i, j, k, b))
            .collect();
        let nb = views.len();
        let mut rng = Rng::new(42);
        // Per-block panels with the diagonal-kernel aliasing precondition:
        // panels of equal block indices hold equal values (as the
        // coordinator guarantees by slicing one xbuf).
        let mut us = Vec::with_capacity(nb * b * r);
        let mut vs = Vec::with_capacity(nb * b * r);
        let mut ws = Vec::with_capacity(nb * b * r);
        for view in &views {
            let pu = rng.normal_vec(b * r);
            let pv = if view.bi == view.bj {
                pu.clone()
            } else {
                rng.normal_vec(b * r)
            };
            let pw = if view.bj == view.bk {
                pv.clone()
            } else {
                rng.normal_vec(b * r)
            };
            us.extend_from_slice(&pu);
            vs.extend_from_slice(&pv);
            ws.extend_from_slice(&pw);
        }
        let eng = Engine::new(Backend::Native).unwrap();
        let (ci, cj, ck) = eng
            .block_contract_packed_batch(t.packed_data(), &views, &us, &vs, &ws, b, r)
            .unwrap();
        let mut dense = Vec::new();
        for v in &views {
            dense.extend(v.extract_dense(t.packed_data()));
        }
        let (di, dj, dk) = eng
            .block_contract_multi_batch(&dense, &us, &vs, &ws, b, nb, r)
            .unwrap();
        // Compare only the outputs the coordinator reads for each kind
        // (packed diagonal kernels leave factor-0 outputs at zero).
        for (s, view) in views.iter().enumerate() {
            let rg = s * b * r..(s + 1) * b * r;
            let reads: [bool; 3] = if view.is_off_diagonal() {
                [true, true, true]
            } else if view.is_central() {
                [true, false, false]
            } else if view.bi == view.bj {
                [true, false, true]
            } else {
                [true, true, false]
            };
            for (o, (got, want)) in [(&ci, &di), (&cj, &dj), (&ck, &dk)].iter().enumerate() {
                if !reads[o] {
                    continue;
                }
                for (x, (g, w)) in got[rg.clone()].iter().zip(&want[rg.clone()]).enumerate() {
                    assert!(
                        (g - w).abs() < 1e-4 * w.abs().max(1.0),
                        "block {s} out {o} x {x}: {g} vs {w}"
                    );
                }
            }
        }
    }

    #[test]
    fn backend_parse() {
        assert_eq!("native".parse::<Backend>().unwrap(), Backend::Native);
        assert_eq!("pjrt".parse::<Backend>().unwrap(), Backend::Pjrt);
        assert!("cuda".parse::<Backend>().is_err());
    }

    // PJRT round-trip tests live in rust/tests/pjrt_integration.rs (they
    // need `make artifacts` to have run and a build with --features pjrt).
}
