//! Minimal benchmarking harness (no `criterion` crate is vendored).
//!
//! Measures wall-clock with warmup, reports median / min / max over N
//! samples, and prints rows suitable for the paper-table benches. `cargo
//! bench` targets are `harness = false` binaries built on this module.

use std::time::{Duration, Instant};

/// Result of timing one closure.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub samples: usize,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Timing {
    /// Median duration in milliseconds.
    pub fn median_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "median {:.3} ms (min {:.3}, max {:.3}, n={})",
            self.median.as_secs_f64() * 1e3,
            self.min.as_secs_f64() * 1e3,
            self.max.as_secs_f64() * 1e3,
            self.samples
        )
    }
}

/// Time `f` with `warmup` throwaway runs then `samples` measured runs.
pub fn time<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Timing {
    assert!(samples >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<Duration> = (0..samples)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed()
        })
        .collect();
    times.sort_unstable();
    Timing {
        samples,
        median: times[samples / 2],
        min: times[0],
        max: times[samples - 1],
    }
}

/// Standard bench header so all bench binaries look uniform.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// Simple throughput helper: GFLOP/s given flops and a timing.
pub fn gflops(flops: f64, t: &Timing) -> f64 {
    flops / t.median.as_secs_f64() / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_orders_samples() {
        let t = time(1, 5, || {
            std::thread::sleep(Duration::from_micros(100));
        });
        assert!(t.min <= t.median && t.median <= t.max);
        assert!(t.min >= Duration::from_micros(50));
        assert_eq!(t.samples, 5);
    }

    #[test]
    fn gflops_math() {
        let t = Timing {
            samples: 1,
            median: Duration::from_secs(1),
            min: Duration::from_secs(1),
            max: Duration::from_secs(1),
        };
        assert!((gflops(2e9, &t) - 2.0).abs() < 1e-9);
    }
}
