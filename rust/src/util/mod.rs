//! Cross-cutting utilities: deterministic PRNG, table rendering, CLI
//! parsing, and a seeded property-test driver (standing in for the `rand`,
//! `clap`, and `proptest` crates, which are not vendored in this
//! environment).

pub mod cli;
pub mod proptest;
pub mod rng;
pub mod table;
