//! Deterministic PRNG utilities (no `rand` crate is vendored in this
//! environment). xorshift64* for integers, Box–Muller for normals.
//!
//! All randomized tests and workload generators take explicit seeds so every
//! run is reproducible.

/// xorshift64* generator. Fast, passes BigCrush on the high bits, and more
/// than adequate for synthetic workloads and property tests.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed (0 is remapped to a fixed constant).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> double mantissa
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, bound).
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        (self.next_u64() % bound as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Standard normal as f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.normal_f32()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
