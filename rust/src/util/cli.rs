//! Tiny hand-rolled CLI argument parser (no `clap` is vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::HashMap;

/// Parsed command-line arguments: positionals plus `--key [value]` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Get an option value as a string.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Get an option parsed to any `FromStr` type, with a default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.options.get(key) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("warning: could not parse --{key} {v}; using default");
                std::process::exit(2)
            }),
            None => default,
        }
    }

    /// Whether a bare `--flag` was passed (a `--key value` also counts).
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.options.contains_key(key)
    }

    /// First positional argument (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn parses_positional_and_options() {
        // note: a bare flag directly followed by a positional would consume
        // it as a value (`--verbose extra`), so flags go last by convention.
        let a = parse(&["run", "--q", "3", "--b=8", "extra", "--verbose"]);
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(a.get("q"), Some("3"));
        assert_eq!(a.get_or("b", 0usize), 8);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["run", "extra"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["x"]);
        assert_eq!(a.get_or("q", 2usize), 2);
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--check"]);
        assert!(a.flag("check"));
        assert_eq!(a.get("check"), None);
    }
}
