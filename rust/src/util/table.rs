//! Minimal markdown/ASCII table printer used by the bench harness and CLI to
//! emit paper-style tables (Tables 1–3, cost sweeps) as aligned text.

/// A simple column-aligned table with a header row.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column headers.
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must have the same arity as the header).
    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let r: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(r.len(), self.header.len(), "row arity mismatch");
        self.rows.push(r);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let body: Vec<String> = (0..ncol)
                .map(|i| format!("{:w$}", cells[i], w = widths[i]))
                .collect();
            format!("| {} |", body.join(" | "))
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|", sep.join("-|-")));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float compactly (paper tables use 3-4 significant digits).
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 || x.abs() < 0.01 {
        format!("{x:.3e}")
    } else {
        format!("{x:.3}")
    }
}

/// Format a set of indices as `{a,b,c}` (1-based, paper convention).
pub fn fset(xs: &[usize]) -> String {
    let inner: Vec<String> = xs.iter().map(|x| (x + 1).to_string()).collect();
    format!("{{{}}}", inner.join(","))
}

/// Format a list of index triples as `{(a,b,c), ...}` (1-based).
pub fn ftriples(ts: &[(usize, usize, usize)]) -> String {
    let inner: Vec<String> = ts
        .iter()
        .map(|(a, b, c)| format!("({},{},{})", a + 1, b + 1, c + 1))
        .collect();
    format!("{{{}}}", inner.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new(["p", "R_p"]);
        t.row(["1", "{1,2,3,7}"]);
        t.row(["22", "{3,4,6,7}"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("| p "));
        assert!(lines[1].starts_with("|--"));
        // all lines same width
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn set_formatting_is_one_based() {
        assert_eq!(fset(&[0, 1, 6]), "{1,2,7}");
        assert_eq!(ftriples(&[(1, 1, 0)]), "{(2,2,1)}");
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert!(fnum(12345.0).contains('e'));
        assert_eq!(fnum(1.5), "1.500");
    }
}
