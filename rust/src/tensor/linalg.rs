//! Small dense linear-algebra helpers (no external linalg crate is
//! vendored): Gram–Schmidt orthonormalization, norms, dots.

use crate::util::rng::Rng;

/// Euclidean norm.
pub fn norm(x: &[f32]) -> f32 {
    x.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32
}

/// Dot product with f64 accumulation.
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(a, b)| (*a as f64) * (*b as f64))
        .sum::<f64>() as f32
}

/// Normalize in place; returns the original norm.
pub fn normalize(x: &mut [f32]) -> f32 {
    let n = norm(x);
    if n > 0.0 {
        for v in x.iter_mut() {
            *v /= n;
        }
    }
    n
}

/// r orthonormal random columns of length n via modified Gram–Schmidt
/// (re-orthogonalized once for numerical hygiene).
pub fn orthonormal_columns(n: usize, r: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    assert!(r <= n);
    let mut cols: Vec<Vec<f32>> = Vec::with_capacity(r);
    while cols.len() < r {
        let mut v = rng.normal_vec(n);
        for _pass in 0..2 {
            for c in &cols {
                let d = dot(&v, c);
                for (vi, ci) in v.iter_mut().zip(c) {
                    *vi -= d * ci;
                }
            }
        }
        if normalize(&mut v) > 1e-6 {
            cols.push(v);
        }
    }
    cols
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_and_dot() {
        assert!((norm(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
        assert!((dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]) - 32.0).abs() < 1e-6);
    }

    #[test]
    fn normalize_unit() {
        let mut v = vec![1.0, 1.0, 1.0, 1.0];
        let n = normalize(&mut v);
        assert!((n - 2.0).abs() < 1e-6);
        assert!((norm(&v) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gram_schmidt_orthonormal() {
        let mut rng = Rng::new(9);
        let cols = orthonormal_columns(20, 5, &mut rng);
        for a in 0..5 {
            for b in 0..5 {
                let d = dot(&cols[a], &cols[b]);
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-5, "({a},{b}): {d}");
            }
        }
    }
}
