//! Symmetric 3-D tensor storage and sequential STTSV oracles.
//!
//! A fully symmetric tensor is stored *packed*: one value per
//! lower-tetrahedral index (i ≥ j ≥ k), n(n+1)(n+2)/6 words — the unique
//! parameters the paper counts. Accessors symmetrize transparently.
//!
//! Storage and the sequential oracles are generic over a sealed
//! [`Element`] scalar (§Perf P14): [`SymTensor`] is the f32 instantiation
//! every distributed path uses, and [`SymTensorG`]`<f64>` backs the
//! conditioning studies (HOPM on ill-conditioned planted-eigenpair
//! instances) end to end in f64. [`Precision`] names the choice at the
//! options/CLI layer.

pub mod linalg;

use crate::util::rng::Rng;

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for f64 {}
}

/// The scalar type of packed tensors and the run-kernels: exactly f32 and
/// f64 (sealed — the kernels' arithmetic identities are audited per type,
/// not open for extension). Operations are the minimal set the packed
/// storage, the generic run-kernels, and the f64 HOPM driver need; all of
/// them compile to the obvious single instruction.
pub trait Element:
    sealed::Sealed
    + Copy
    + Default
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + std::fmt::Debug
    + std::fmt::Display
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
    + std::ops::SubAssign
    + std::ops::MulAssign
    + 'static
{
    const ZERO: Self;
    const ONE: Self;
    const TWO: Self;
    fn from_f32(v: f32) -> Self;
    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;
    /// Bit pattern widened to u64 (f32 bits occupy the low 32) — the
    /// fingerprint input, so −0.0 and +0.0 stay distinguishable.
    fn bits(self) -> u64;
    fn abs(self) -> Self;
    fn sqrt(self) -> Self;
}

impl Element for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const TWO: Self = 2.0;
    fn from_f32(v: f32) -> Self {
        v
    }
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
    fn bits(self) -> u64 {
        self.to_bits() as u64
    }
    fn abs(self) -> Self {
        f32::abs(self)
    }
    fn sqrt(self) -> Self {
        f32::sqrt(self)
    }
}

impl Element for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const TWO: Self = 2.0;
    fn from_f32(v: f32) -> Self {
        v as f64
    }
    fn from_f64(v: f64) -> Self {
        v
    }
    fn to_f64(self) -> f64 {
        self
    }
    fn bits(self) -> u64 {
        self.to_bits()
    }
    fn abs(self) -> Self {
        f64::abs(self)
    }
    fn sqrt(self) -> Self {
        f64::sqrt(self)
    }
}

/// Element-type selector at the options/CLI layer (`--precision f32|f64`):
/// which [`Element`] instantiation the sequential conditioning paths run.
/// The distributed plan always computes in f32; see
/// [`crate::coordinator::ExecOpts::precision`].
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    #[default]
    F32,
    F64,
}

impl std::str::FromStr for Precision {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "f32" => Ok(Precision::F32),
            "f64" => Ok(Precision::F64),
            other => anyhow::bail!("unknown precision '{other}' (expected f32|f64)"),
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Precision::F32 => "f32",
            Precision::F64 => "f64",
        })
    }
}

/// Packed fully-symmetric tensor of dimension n × n × n, generic over the
/// stored [`Element`]. [`SymTensor`] (= `SymTensorG<f32>`) is the type
/// every distributed path consumes; `SymTensorG<f64>` serves the
/// sequential f64 conditioning studies.
#[derive(Debug)]
pub struct SymTensorG<E: Element> {
    pub n: usize,
    data: Vec<E>,
    /// How many times the O(n³) sequential oracles ([`SymTensorG::sttsv`],
    /// [`SymTensorG::rayleigh`]) ran on THIS instance — regression
    /// instrumentation: the distributed apps must never fall back to a
    /// dense host sweep once their plan is built (asserted in apps tests).
    dense_sttsv_calls: std::sync::atomic::AtomicU64,
}

/// The f32 instantiation — the storage type of every distributed path.
pub type SymTensor = SymTensorG<f32>;

impl<E: Element> Clone for SymTensorG<E> {
    fn clone(&self) -> SymTensorG<E> {
        // The oracle-call counter is per-instance instrumentation, not
        // tensor state: clones start at zero.
        SymTensorG {
            n: self.n,
            data: self.data.clone(),
            dense_sttsv_calls: std::sync::atomic::AtomicU64::new(0),
        }
    }
}

/// Number of packed entries for dimension n: n(n+1)(n+2)/6.
pub fn packed_len(n: usize) -> usize {
    n * (n + 1) * (n + 2) / 6
}

#[inline]
fn tet(i: usize) -> usize {
    i * (i + 1) * (i + 2) / 6
}

#[inline]
fn tri(j: usize) -> usize {
    j * (j + 1) / 2
}

/// Sort three indices descending.
#[inline]
pub fn sort3(i: usize, j: usize, k: usize) -> (usize, usize, usize) {
    let (mut a, mut b, mut c) = (i, j, k);
    if a < b {
        std::mem::swap(&mut a, &mut b);
    }
    if b < c {
        std::mem::swap(&mut b, &mut c);
    }
    if a < b {
        std::mem::swap(&mut a, &mut b);
    }
    (a, b, c)
}

impl<E: Element> SymTensorG<E> {
    /// All-zeros tensor.
    pub fn zeros(n: usize) -> SymTensorG<E> {
        SymTensorG {
            n,
            data: vec![E::ZERO; packed_len(n)],
            dense_sttsv_calls: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// i.i.d. standard-normal unique entries (a generic symmetric tensor).
    /// The stream is drawn in f32 so `SymTensorG::<f64>::random` holds the
    /// exact same values as its f32 twin — precision comparisons see one
    /// tensor, not two samples.
    pub fn random(n: usize, seed: u64) -> SymTensorG<E> {
        let mut rng = Rng::new(seed);
        SymTensorG {
            n,
            data: (0..packed_len(n)).map(|_| E::from_f32(rng.normal_f32())).collect(),
            dense_sttsv_calls: std::sync::atomic::AtomicU64::new(0),
        }
    }

    #[inline]
    fn packed_index(i: usize, j: usize, k: usize) -> usize {
        // requires i >= j >= k
        tet(i) + tri(j) + k
    }

    /// Read entry (i, j, k) in any index order.
    #[inline]
    pub fn get(&self, i: usize, j: usize, k: usize) -> E {
        let (a, b, c) = sort3(i, j, k);
        self.data[Self::packed_index(a, b, c)]
    }

    /// Write entry (i, j, k) (any order; writes the unique representative).
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, k: usize, v: E) {
        let (a, b, c) = sort3(i, j, k);
        self.data[Self::packed_index(a, b, c)] = v;
    }

    /// Number of stored (unique) entries.
    pub fn packed_len(&self) -> usize {
        self.data.len()
    }

    /// The shared packed buffer (lower-tetrahedral order). Zero-copy
    /// consumers ([`PackedBlockView`], the packed runtime kernels) contract
    /// directly against this slice instead of materializing dense copies.
    pub fn packed_data(&self) -> &[E] {
        &self.data
    }

    /// Extract the dense b³ sub-block with block index (bi, bj, bk) and
    /// block size b, row-major ((α·b + β)·b + γ): entry (α, β, γ) holds the
    /// full-tensor value A[bi·b+α, bj·b+β, bk·b+γ]. This is the layout the
    /// AOT block kernels consume.
    ///
    /// Every sorted block index (bi ≥ bj ≥ bk — all blocks Algorithm 5
    /// touches) takes a contiguous fast path via
    /// [`PackedBlockView::extract_dense`]; unsorted indices fall back to the
    /// per-element sort3 loop.
    pub fn extract_block(&self, bi: usize, bj: usize, bk: usize, b: usize) -> Vec<E> {
        if bi >= bj && bj >= bk {
            return PackedBlockView::new(bi, bj, bk, b).extract_dense(&self.data);
        }
        let mut out = vec![E::ZERO; b * b * b];
        for a in 0..b {
            for be in 0..b {
                for g in 0..b {
                    out[(a * b + be) * b + g] = self.get(bi * b + a, bj * b + be, bk * b + g);
                }
            }
        }
        out
    }

    /// Zero-pad to dimension `n2 >= n` (paper §6.1: when q²+1 does not
    /// divide n, pad to the next multiple; padded entries are zero so the
    /// computation is unchanged on the first n coordinates).
    pub fn padded(&self, n2: usize) -> SymTensorG<E> {
        assert!(n2 >= self.n);
        let mut out = SymTensorG::<E>::zeros(n2);
        // packed layouts nest: indices with i < n keep their packed offsets
        out.data[..self.data.len()].copy_from_slice(&self.data);
        out
    }

    /// Sequential STTSV oracle: y = A ×₂ x ×₃ x via the paper's Algorithm 4
    /// (lower-tetrahedron iteration with multiplicity weights), f64
    /// accumulation for a trustworthy reference.
    pub fn sttsv(&self, x: &[E]) -> Vec<E> {
        assert_eq!(x.len(), self.n);
        self.dense_sttsv_calls
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut y = vec![0.0f64; self.n];
        let mut idx = 0usize;
        for i in 0..self.n {
            for j in 0..=i {
                for k in 0..=j {
                    let a = self.data[idx].to_f64();
                    idx += 1;
                    let (xi, xj, xk) = (x[i].to_f64(), x[j].to_f64(), x[k].to_f64());
                    if i != j && j != k {
                        y[i] += 2.0 * a * xj * xk;
                        y[j] += 2.0 * a * xi * xk;
                        y[k] += 2.0 * a * xi * xj;
                    } else if i == j && j != k {
                        y[i] += 2.0 * a * xj * xk;
                        y[k] += a * xi * xj;
                    } else if i != j && j == k {
                        y[i] += a * xj * xk;
                        y[j] += 2.0 * a * xi * xk;
                    } else {
                        y[i] += a * xj * xk;
                    }
                }
            }
        }
        y.into_iter().map(E::from_f64).collect()
    }

    /// Number of ternary multiplications Algorithm 4 performs: n²(n+1)/2.
    pub fn ternary_mult_count(&self) -> usize {
        let n = self.n;
        n * n * (n + 1) / 2
    }

    /// Rayleigh quotient λ = A ×₁ x ×₂ x ×₃ x (Algorithm 1, line 6).
    pub fn rayleigh(&self, x: &[E]) -> E {
        let y = self.sttsv(x);
        E::from_f64(y.iter().zip(x).map(|(a, b)| a.to_f64() * b.to_f64()).sum::<f64>())
    }

    /// How many times the O(n³) sequential oracles ran on this instance.
    /// The distributed iterative apps must leave this untouched after
    /// their plan is built — λ, norms, and deltas all come from the
    /// distributed owned portions (regression-tested in `apps`).
    pub fn dense_sttsv_invocations(&self) -> u64 {
        self.dense_sttsv_calls
            .load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Content fingerprint: FNV-1a (64-bit) over `n` and the bit patterns
    /// of the packed buffer. Two tensors fingerprint equal iff they have
    /// the same dimension and bitwise-identical unique entries (−0.0 and
    /// +0.0 hash differently — fine for a cache key, where a spurious miss
    /// is only a rebuild). This is the tensor component of the serving
    /// layer's plan-cache key (`crate::serve`); it walks the n(n+1)(n+2)/6
    /// packed words once and is orders of magnitude cheaper than the plan
    /// build it guards.
    pub fn fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        for byte in (self.n as u64).to_le_bytes() {
            h = (h ^ byte as u64).wrapping_mul(FNV_PRIME);
        }
        for v in &self.data {
            for byte in v.bits().to_le_bytes() {
                h = (h ^ byte as u64).wrapping_mul(FNV_PRIME);
            }
        }
        h
    }
}

impl SymTensor {
    /// Odeco (orthogonally decomposable) tensor A = Σ_l λ_l e_l ⊗ e_l ⊗ e_l
    /// with orthonormal e_l. Returns the tensor and the factors (columns),
    /// so tests can check recovered eigenpairs exactly. The dominant
    /// eigenpair of such a tensor is (λ_max, e_max) — the ground truth for
    /// the end-to-end power-method experiment.
    pub fn odeco(n: usize, lambdas: &[f32], seed: u64) -> (SymTensor, Vec<Vec<f32>>) {
        let r = lambdas.len();
        assert!(r <= n);
        let mut rng = Rng::new(seed);
        let cols: Vec<Vec<f32>> = linalg::orthonormal_columns(n, r, &mut rng);
        let mut t = SymTensor::zeros(n);
        let mut idx = 0usize;
        for i in 0..n {
            for j in 0..=i {
                for k in 0..=j {
                    let mut v = 0.0f64;
                    for (l, &lam) in lambdas.iter().enumerate() {
                        v += lam as f64
                            * cols[l][i] as f64
                            * cols[l][j] as f64
                            * cols[l][k] as f64;
                    }
                    t.data[idx] = v as f32;
                    idx += 1;
                }
            }
        }
        debug_assert_eq!(idx, packed_len(n));
        (t, cols)
    }
}

impl SymTensorG<f64> {
    /// f64 odeco constructor for the conditioning studies (§E18): same
    /// planted-eigenpair structure as [`SymTensor::odeco`] but with the
    /// factors drawn and orthonormalized entirely in f64 (local
    /// Gram–Schmidt — `linalg::orthonormal_columns` is f32-only), so
    /// ill-conditioned spectra (λ_max/λ_min ≫ 2²⁴) stay resolvable in the
    /// stored entries.
    pub fn odeco64(n: usize, lambdas: &[f64], seed: u64) -> (SymTensorG<f64>, Vec<Vec<f64>>) {
        let r = lambdas.len();
        assert!(r <= n);
        let mut rng = Rng::new(seed);
        let mut cols: Vec<Vec<f64>> = Vec::with_capacity(r);
        for _ in 0..r {
            // Draw, project out earlier columns (modified Gram–Schmidt,
            // twice for orthogonality to roundoff), normalize.
            let mut c: Vec<f64> = (0..n).map(|_| rng.normal_f32() as f64).collect();
            for _ in 0..2 {
                for prev in &cols {
                    let dot: f64 = c.iter().zip(prev).map(|(a, b)| a * b).sum();
                    for (ci, pi) in c.iter_mut().zip(prev) {
                        *ci -= dot * pi;
                    }
                }
            }
            let norm = c.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!(norm > 1e-12, "degenerate draw in odeco64 Gram-Schmidt");
            for ci in &mut c {
                *ci /= norm;
            }
            cols.push(c);
        }
        let mut t = SymTensorG::<f64>::zeros(n);
        let mut idx = 0usize;
        for i in 0..n {
            for j in 0..=i {
                for k in 0..=j {
                    let mut v = 0.0f64;
                    for (l, &lam) in lambdas.iter().enumerate() {
                        v += lam * cols[l][i] * cols[l][j] * cols[l][k];
                    }
                    t.data[idx] = v;
                    idx += 1;
                }
            }
        }
        debug_assert_eq!(idx, packed_len(n));
        (t, cols)
    }
}

/// A zero-copy view of one lower-tetrahedral sub-block (block index
/// bi ≥ bj ≥ bk, block size b) of a packed [`SymTensor`] buffer.
///
/// The packed layout nests: for global indices i ≥ j ≥ k the word lives at
/// `tet(i) + tri(j) + k`, so for any fixed (α, β) row of the block the
/// γ-run is **contiguous** starting at `tet(bi·b+α) + tri(bj·b+β) + bk·b`
/// ([`Self::row_base`]). Off-diagonal blocks (bi > bj > bk) expose all b²
/// full-length rows; rows of diagonal blocks are cut by the k ≤ j
/// constraint ([`Self::row_len`]) and, when bi == bj, exist only for
/// α ≥ β. The packed runtime kernels contract straight over these strided
/// rows — the plan never copies tensor data (EXPERIMENTS.md §Perf P7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PackedBlockView {
    pub bi: usize,
    pub bj: usize,
    pub bk: usize,
    pub b: usize,
}

impl PackedBlockView {
    /// View of block (bi, bj, bk) (must satisfy bi ≥ bj ≥ bk) at block
    /// size b. O(1): only the coordinates are stored.
    pub fn new(bi: usize, bj: usize, bk: usize, b: usize) -> PackedBlockView {
        assert!(bi >= bj && bj >= bk, "block index must satisfy bi >= bj >= bk");
        PackedBlockView { bi, bj, bk, b }
    }

    /// i > j > k strictly: all b³ entries are unique representatives.
    #[inline]
    pub fn is_off_diagonal(&self) -> bool {
        self.bi > self.bj && self.bj > self.bk
    }

    /// bi == bj == bk.
    #[inline]
    pub fn is_central(&self) -> bool {
        self.bi == self.bk
    }

    /// Base offset into the packed buffer of the contiguous γ-run holding
    /// the unique entries (α, β, γ), γ < [`Self::row_len`] — globally
    /// A[bi·b+α, bj·b+β, bk·b+γ]. Requires global i ≥ j, i.e. α ≥ β
    /// whenever bi == bj.
    #[inline]
    pub fn row_base(&self, alpha: usize, beta: usize) -> usize {
        debug_assert!(self.bi > self.bj || alpha >= beta);
        tet(self.bi * self.b + alpha) + tri(self.bj * self.b + beta) + self.bk * self.b
    }

    /// Length of the packed γ-run at row β: the full b when bj > bk, and
    /// β + 1 when bj == bk (cut by the k ≤ j constraint).
    #[inline]
    pub fn row_len(&self, beta: usize) -> usize {
        if self.bj == self.bk {
            beta + 1
        } else {
            self.b
        }
    }

    /// Number of unique packed words the view covers (the paper's per-block
    /// storage count: b³ off-diagonal, b²(b+1)/2 non-central diagonal,
    /// b(b+1)(b+2)/6 central).
    pub fn unique_len(&self) -> usize {
        let b = self.b;
        if self.is_off_diagonal() {
            b * b * b
        } else if self.is_central() {
            b * (b + 1) * (b + 2) / 6
        } else {
            b * b * (b + 1) / 2
        }
    }

    /// Materialize the dense row-major b³ block ((α·b + β)·b + γ, the layout
    /// the dense kernels and AOT artifacts consume) from the packed buffer.
    ///
    /// Used as the PJRT fallback: backends without packed kernels extract
    /// the active blocks on the fly instead of holding dense copies
    /// resident. All four block shapes take contiguous-run copies for the
    /// unique entries; duplicated entries of diagonal blocks are mirrored
    /// within `out` (local index permutation, no per-element packed-index
    /// math).
    pub fn extract_dense<E: Element>(&self, t: &[E]) -> Vec<E> {
        let b = self.b;
        let mut out = vec![E::ZERO; b * b * b];
        if self.is_off_diagonal() {
            for a in 0..b {
                for be in 0..b {
                    let base = self.row_base(a, be);
                    out[(a * b + be) * b..(a * b + be + 1) * b]
                        .copy_from_slice(&t[base..base + b]);
                }
            }
        } else if self.bi == self.bj && self.bj > self.bk {
            // (g,g,h): α ≥ β rows are contiguous; α < β mirrors (β, α).
            for a in 0..b {
                for be in 0..=a {
                    let base = self.row_base(a, be);
                    out[(a * b + be) * b..(a * b + be + 1) * b]
                        .copy_from_slice(&t[base..base + b]);
                }
            }
            for a in 0..b {
                for be in a + 1..b {
                    out.copy_within((be * b + a) * b..(be * b + a + 1) * b, (a * b + be) * b);
                }
            }
        } else if self.bi > self.bj && self.bj == self.bk {
            // (g,h,h): γ ≤ β runs are contiguous; γ > β mirrors (α, γ, β)
            // within the same α-slab.
            for a in 0..b {
                for be in 0..b {
                    let base = self.row_base(a, be);
                    out[(a * b + be) * b..(a * b + be) * b + be + 1]
                        .copy_from_slice(&t[base..base + be + 1]);
                }
                for be in 0..b {
                    for g in be + 1..b {
                        out[(a * b + be) * b + g] = out[(a * b + g) * b + be];
                    }
                }
            }
        } else {
            // central (g,g,g): canonical α ≥ β ≥ γ runs, then symmetrize
            // from the sorted local representative.
            for a in 0..b {
                for be in 0..=a {
                    let base = self.row_base(a, be);
                    out[(a * b + be) * b..(a * b + be) * b + be + 1]
                        .copy_from_slice(&t[base..base + be + 1]);
                }
            }
            for a in 0..b {
                for be in 0..b {
                    for g in 0..b {
                        let (x, y, z) = sort3(a, be, g);
                        if (x, y, z) != (a, be, g) {
                            out[(a * b + be) * b + g] = out[(x * b + y) * b + z];
                        }
                    }
                }
            }
        }
        out
    }
}

/// The weight class of one contiguous packed γ-run — which arithmetic
/// pattern the contraction kernels apply to it. One class per branch of
/// the packed kernels ([`crate::runtime::block_contract_packed`] /
/// `diag_block_contract_packed`), so a block's run stream replayed
/// class-by-class reproduces the kernel's operations exactly. The
/// ternary-multiplication charge per run is a pure function of
/// (class, len) — [`PackedRun::ternary_mults`] — and block sums equal the
/// §7.1 closed forms (`partition::block_ternary_mults`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunClass {
    /// Off-diagonal row (bi > bj > bk): every entry serves 3 outputs.
    OffDiag = 0,
    /// (g,g,h) row with α > β: 3 contributions per entry, i-weight 2.
    GghUpper = 1,
    /// (g,g,h) row with α == β: 2 contributions per entry.
    GghAxis = 2,
    /// (g,h,h) row: β > γ prefix (3 each) plus the β == γ tail entry (2).
    Ghh = 3,
    /// central row with α > β: γ < β prefix (3 each) + β == γ tail (2).
    CentralUpper = 4,
    /// central row with α == β: γ < α prefix (2 each) + the α==β==γ
    /// apex entry (1).
    CentralAxis = 5,
}

/// One contiguous γ-run of a packed block, in kernel iteration order:
/// `base` is the packed offset of the run, `len` the prefix length the
/// m/axpy inner loops sweep (the Ghh/Central classes additionally read the
/// tail entry at `base + len`), and (`alpha`, `beta`) the block-local
/// panel rows of the u/v inputs. `flush` marks the last run of its α
/// group — where the kernels flush the per-α `ci` accumulator.
#[derive(Debug, Clone, Copy)]
pub struct PackedRun {
    pub cls: RunClass,
    pub base: usize,
    pub len: usize,
    pub alpha: usize,
    pub beta: usize,
    pub flush: bool,
}

impl PackedRun {
    /// Ternary multiplications the kernels execute for this run, per
    /// right-hand-side column — one per (unique entry, output
    /// contribution) pair. Summed over a block's runs this equals
    /// [`crate::partition::block_ternary_mults`] exactly (unit-tested in
    /// the coordinator, extending the §Perf P7 invariant to the compiled
    /// path).
    pub fn ternary_mults(&self) -> u64 {
        let l = self.len as u64;
        match self.cls {
            RunClass::OffDiag | RunClass::GghUpper => 3 * l,
            RunClass::GghAxis => 2 * l,
            RunClass::Ghh | RunClass::CentralUpper => 3 * l + 2,
            RunClass::CentralAxis => 2 * l + 1,
        }
    }
}

impl PackedBlockView {
    /// Enumerate the block's unique packed entries as `(packed offset,
    /// global i, global j, global k)` with i ≥ j ≥ k — exactly
    /// [`Self::unique_len`] callbacks, in packed-buffer order. Each unique
    /// entry of the whole tensor belongs to exactly one block view, so
    /// iterating every owned block visits a processor's packed words once
    /// each — the walk the ABFT layer uses to build per-block checksum
    /// matrices `C_b` (and, summed over all owners, the global
    /// `C[j,k] = Σ_i A[i,j,k]`) at plan build (§Rob P15).
    pub fn for_each_unique_entry(&self, mut f: impl FnMut(usize, usize, usize, usize)) {
        let b = self.b;
        for a in 0..b {
            let bmax = if self.bi == self.bj { a + 1 } else { b };
            for be in 0..bmax {
                let base = self.row_base(a, be);
                let i = self.bi * b + a;
                let j = self.bj * b + be;
                for g in 0..self.row_len(be) {
                    f(base + g, i, j, self.bk * b + g);
                }
            }
        }
    }

    /// Enumerate the block's packed γ-runs in the exact iteration order of
    /// the packed contraction kernels (α outer, β inner), with per-run
    /// weight classes and flush marks. This is the geometry the compiled
    /// sweep programs flatten once at plan build — the per-row
    /// `row_base` tet/tri arithmetic and the α≥β≥γ multiplicity branching
    /// are resolved here instead of on every sweep (§Perf P10).
    pub fn for_each_run(&self, mut f: impl FnMut(PackedRun)) {
        let b = self.b;
        if self.is_off_diagonal() {
            for a in 0..b {
                for be in 0..b {
                    f(PackedRun {
                        cls: RunClass::OffDiag,
                        base: self.row_base(a, be),
                        len: b,
                        alpha: a,
                        beta: be,
                        flush: be == b - 1,
                    });
                }
            }
        } else if self.bi == self.bj && self.bj > self.bk {
            for a in 0..b {
                for be in 0..=a {
                    f(PackedRun {
                        cls: if a > be { RunClass::GghUpper } else { RunClass::GghAxis },
                        base: self.row_base(a, be),
                        len: b,
                        alpha: a,
                        beta: be,
                        flush: be == a,
                    });
                }
            }
        } else if self.bi > self.bj && self.bj == self.bk {
            for a in 0..b {
                for be in 0..b {
                    f(PackedRun {
                        cls: RunClass::Ghh,
                        base: self.row_base(a, be),
                        len: be,
                        alpha: a,
                        beta: be,
                        flush: be == b - 1,
                    });
                }
            }
        } else {
            for a in 0..b {
                for be in 0..=a {
                    f(PackedRun {
                        cls: if a > be { RunClass::CentralUpper } else { RunClass::CentralAxis },
                        base: self.row_base(a, be),
                        // CentralUpper sweeps γ < β; CentralAxis γ < α —
                        // equal here since the axis rows have β == α.
                        len: be,
                        alpha: a,
                        beta: be,
                        flush: be == a,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_len_formula() {
        assert_eq!(packed_len(1), 1);
        assert_eq!(packed_len(2), 4);
        assert_eq!(packed_len(3), 10);
        assert_eq!(packed_len(10), 220);
    }

    #[test]
    fn get_is_permutation_invariant() {
        let t = SymTensor::random(6, 1);
        for (i, j, k) in [(5, 3, 1), (4, 4, 2), (3, 3, 3), (5, 0, 0)] {
            let v = t.get(i, j, k);
            for (a, b, c) in [
                (i, j, k),
                (i, k, j),
                (j, i, k),
                (j, k, i),
                (k, i, j),
                (k, j, i),
            ] {
                assert_eq!(t.get(a, b, c), v);
            }
        }
    }

    #[test]
    fn set_then_get_roundtrip() {
        let mut t = SymTensor::zeros(5);
        t.set(1, 4, 2, 7.5);
        assert_eq!(t.get(4, 2, 1), 7.5);
        assert_eq!(t.get(2, 1, 4), 7.5);
    }

    #[test]
    fn sttsv_matches_dense_triple_loop() {
        let n = 7;
        let t = SymTensor::random(n, 3);
        let mut rng = Rng::new(4);
        let x = rng.normal_vec(n);
        let y = t.sttsv(&x);
        // dense oracle: y_i = Σ_{j,k} A[i,j,k] x_j x_k
        for i in 0..n {
            let mut want = 0.0f64;
            for j in 0..n {
                for k in 0..n {
                    want += t.get(i, j, k) as f64 * x[j] as f64 * x[k] as f64;
                }
            }
            assert!(
                (y[i] as f64 - want).abs() < 1e-3 * want.abs().max(1.0),
                "i={i}: {} vs {want}",
                y[i]
            );
        }
    }

    #[test]
    fn extract_block_values() {
        let n = 8;
        let b = 4;
        let t = SymTensor::random(n, 5);
        let blk = t.extract_block(1, 0, 1, b);
        for a in 0..b {
            for be in 0..b {
                for g in 0..b {
                    assert_eq!(blk[(a * b + be) * b + g], t.get(b + a, be, b + g));
                }
            }
        }
    }

    /// Slow per-element reference for extract_block (what the pre-fast-path
    /// code computed for every non-(bi>bj>bk) block).
    fn extract_block_slow(t: &SymTensor, bi: usize, bj: usize, bk: usize, b: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; b * b * b];
        for a in 0..b {
            for be in 0..b {
                for g in 0..b {
                    out[(a * b + be) * b + g] = t.get(bi * b + a, bj * b + be, bk * b + g);
                }
            }
        }
        out
    }

    #[test]
    fn extract_block_fast_paths_cover_all_sorted_kinds() {
        // Off-diagonal, both non-central diagonal shapes, and the central
        // block all take the contiguous-run path; values must equal the
        // per-element slow path exactly.
        let b = 5;
        let t = SymTensor::random(5 * b, 17);
        for (bi, bj, bk) in [(3, 2, 0), (4, 4, 1), (4, 2, 2), (2, 2, 2), (0, 0, 0)] {
            assert_eq!(
                t.extract_block(bi, bj, bk, b),
                extract_block_slow(&t, bi, bj, bk, b),
                "block ({bi},{bj},{bk})"
            );
        }
        // unsorted block indices still work via the slow path
        assert_eq!(
            t.extract_block(1, 0, 1, b),
            extract_block_slow(&t, 1, 0, 1, b)
        );
    }

    #[test]
    fn packed_view_rows_are_the_packed_entries() {
        let b = 4;
        let t = SymTensor::random(5 * b, 19);
        let data = t.packed_data();
        // off-diagonal: every (α, β) row is the contiguous γ-run of uniques
        let v = PackedBlockView::new(3, 1, 0, b);
        for a in 0..b {
            for be in 0..b {
                let base = v.row_base(a, be);
                assert_eq!(v.row_len(be), b);
                for g in 0..b {
                    assert_eq!(data[base + g], t.get(3 * b + a, b + be, g));
                }
            }
        }
        // (g,h,h): run length β+1, entries are the j ≥ k uniques
        let v = PackedBlockView::new(2, 1, 1, b);
        for a in 0..b {
            for be in 0..b {
                let base = v.row_base(a, be);
                assert_eq!(v.row_len(be), be + 1);
                for g in 0..=be {
                    assert_eq!(data[base + g], t.get(2 * b + a, b + be, b + g));
                }
            }
        }
        // central: rows exist for α ≥ β only
        let v = PackedBlockView::new(2, 2, 2, b);
        for a in 0..b {
            for be in 0..=a {
                let base = v.row_base(a, be);
                for g in 0..=be {
                    assert_eq!(data[base + g], t.get(2 * b + a, 2 * b + be, 2 * b + g));
                }
            }
        }
    }

    #[test]
    fn packed_view_unique_len_formulas() {
        let b = 6usize;
        assert_eq!(PackedBlockView::new(3, 2, 1, b).unique_len(), b * b * b);
        assert_eq!(PackedBlockView::new(3, 3, 1, b).unique_len(), b * b * (b + 1) / 2);
        assert_eq!(PackedBlockView::new(3, 1, 1, b).unique_len(), b * b * (b + 1) / 2);
        assert_eq!(
            PackedBlockView::new(3, 3, 3, b).unique_len(),
            b * (b + 1) * (b + 2) / 6
        );
        // unique lengths over all blocks tile the packed tensor exactly
        let m = 4;
        let total: usize = (0..m)
            .flat_map(|i| (0..=i).flat_map(move |j| (0..=j).map(move |k| (i, j, k))))
            .map(|(i, j, k)| PackedBlockView::new(i, j, k, b).unique_len())
            .sum();
        assert_eq!(total, packed_len(m * b));
    }

    #[test]
    fn unique_entry_enumeration_matches_packed_words() {
        // for_each_unique_entry must visit exactly unique_len() packed
        // offsets, each once, with sorted global indices i ≥ j ≥ k whose
        // tensor value is the packed word at that offset.
        let b = 4usize;
        let t = SymTensor::random(5 * b, 23);
        let data = t.packed_data();
        for blk in [(3usize, 2usize, 0usize), (4, 4, 1), (4, 2, 2), (3, 3, 3)] {
            let v = PackedBlockView::new(blk.0, blk.1, blk.2, b);
            let mut seen = std::collections::HashSet::new();
            let mut count = 0usize;
            v.for_each_unique_entry(|off, i, j, k| {
                assert!(seen.insert(off), "{blk:?}: offset {off} revisited");
                assert!(i >= j && j >= k, "{blk:?}: ({i},{j},{k}) not sorted");
                assert_eq!(i / b, blk.0);
                assert_eq!(j / b, blk.1);
                assert_eq!(k / b, blk.2);
                assert_eq!(data[off], t.get(i, j, k), "{blk:?}: ({i},{j},{k})");
                count += 1;
            });
            assert_eq!(count, v.unique_len(), "{blk:?}");
        }
    }

    #[test]
    fn run_enumeration_covers_unique_entries_exactly_once() {
        // Every packed run (prefix plus the Ghh/Central tail entry) must
        // tile the block's unique packed words exactly once, in order.
        let b = 5usize;
        for blk in [(3usize, 2usize, 0usize), (4, 4, 1), (4, 2, 2), (3, 3, 3)] {
            let v = PackedBlockView::new(blk.0, blk.1, blk.2, b);
            let mut seen = std::collections::HashSet::new();
            let mut count = 0usize;
            v.for_each_run(|run| {
                let tail = match run.cls {
                    RunClass::Ghh | RunClass::CentralUpper | RunClass::CentralAxis => 1,
                    _ => 0,
                };
                for off in 0..run.len + tail {
                    assert!(seen.insert(run.base + off), "{blk:?}: entry revisited");
                }
                count += run.len + tail;
                // the run is exactly the packed row at (α, β)
                assert_eq!(run.base, v.row_base(run.alpha, run.beta));
                assert_eq!(run.len + tail, v.row_len(run.beta), "{blk:?} run {run:?}");
            });
            assert_eq!(count, v.unique_len(), "{blk:?}");
        }
    }

    #[test]
    fn run_enumeration_flushes_once_per_alpha() {
        // Exactly one flush per α group, always on the group's last run —
        // the accumulator protocol the compiled executor relies on.
        let b = 6usize;
        for blk in [(3usize, 2usize, 0usize), (4, 4, 1), (4, 2, 2), (3, 3, 3)] {
            let v = PackedBlockView::new(blk.0, blk.1, blk.2, b);
            let mut cur_alpha = usize::MAX;
            let mut flushed = true;
            let mut flushes = 0usize;
            v.for_each_run(|run| {
                if run.alpha != cur_alpha {
                    assert!(flushed, "{blk:?}: α group {cur_alpha} never flushed");
                    cur_alpha = run.alpha;
                    flushed = false;
                }
                if run.flush {
                    assert!(!flushed, "{blk:?}: α group {cur_alpha} flushed twice");
                    flushed = true;
                    flushes += 1;
                }
            });
            assert!(flushed);
            assert_eq!(flushes, b, "{blk:?}: one flush per α");
        }
    }

    #[test]
    fn run_mults_match_packed_closed_forms() {
        // Σ ternary_mults over a block's runs == the per-kind closed forms
        // (the same values runtime::packed_ternary_mults walks).
        let sum_at = |blk: (usize, usize, usize), b: usize| {
            let mut s = 0u64;
            PackedBlockView::new(blk.0, blk.1, blk.2, b)
                .for_each_run(|run| s += run.ternary_mults());
            s
        };
        // b = 1 spot checks (the closed forms below would underflow at
        // bu - 2 in debug builds): 3/2/2/1 contributions per kind.
        assert_eq!(sum_at((3, 2, 1), 1), 3);
        assert_eq!(sum_at((3, 3, 1), 1), 2);
        assert_eq!(sum_at((3, 1, 1), 1), 2);
        assert_eq!(sum_at((2, 2, 2), 1), 1);
        for b in 2..=7usize {
            let bu = b as u64;
            let sum = |blk: (usize, usize, usize)| sum_at(blk, b);
            assert_eq!(sum((3, 2, 1)), 3 * bu * bu * bu);
            assert_eq!(sum((3, 3, 1)), 3 * bu * bu * (bu - 1) / 2 + 2 * bu * bu);
            assert_eq!(sum((3, 1, 1)), 3 * bu * bu * (bu - 1) / 2 + 2 * bu * bu);
            assert_eq!(
                sum((2, 2, 2)),
                bu * (bu - 1) * (bu - 2) / 2 + 2 * bu * (bu - 1) + bu
            );
        }
    }

    #[test]
    fn odeco_eigen_structure() {
        let (t, cols) = SymTensor::odeco(10, &[4.0, 2.0, 1.0], 6);
        // columns orthonormal
        for a in 0..3 {
            for b in 0..3 {
                let dot: f64 = cols[a]
                    .iter()
                    .zip(&cols[b])
                    .map(|(x, y)| *x as f64 * *y as f64)
                    .sum();
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-5, "({a},{b}) dot={dot}");
            }
        }
        // A ×₂ e_l ×₃ e_l = λ_l e_l  (Z-eigenpair definition)
        for (l, lam) in [(0usize, 4.0f32), (1, 2.0), (2, 1.0)] {
            let y = t.sttsv(&cols[l]);
            for i in 0..10 {
                assert!(
                    (y[i] - lam * cols[l][i]).abs() < 1e-3,
                    "l={l} i={i}: {} vs {}",
                    y[i],
                    lam * cols[l][i]
                );
            }
            assert!((t.rayleigh(&cols[l]) - lam).abs() < 1e-3);
        }
    }

    #[test]
    fn padded_preserves_entries_and_results() {
        let t = SymTensor::random(7, 8);
        let tp = t.padded(10);
        assert_eq!(tp.n, 10);
        for i in 0..7 {
            for j in 0..=i {
                for k in 0..=j {
                    assert_eq!(tp.get(i, j, k), t.get(i, j, k));
                }
            }
        }
        // padded region is zero
        assert_eq!(tp.get(9, 5, 2), 0.0);
        // STTSV with zero-extended x agrees on the first n coords
        let mut rng = Rng::new(9);
        let x = rng.normal_vec(7);
        let mut xp = x.clone();
        xp.resize(10, 0.0);
        let y = t.sttsv(&x);
        let yp = tp.sttsv(&xp);
        for i in 0..7 {
            assert!((y[i] - yp[i]).abs() < 1e-5);
        }
        for i in 7..10 {
            assert_eq!(yp[i], 0.0);
        }
    }

    #[test]
    fn ternary_count_formula() {
        let t = SymTensor::zeros(10);
        assert_eq!(t.ternary_mult_count(), 100 * 11 / 2);
    }

    #[test]
    fn fingerprint_is_content_addressed() {
        let a = SymTensor::random(8, 7);
        // Same content (clone, or independent build from the same seed)
        // fingerprints equal; the oracle-call instrumentation counter is
        // not content and must not perturb it.
        assert_eq!(a.fingerprint(), a.clone().fingerprint());
        assert_eq!(a.fingerprint(), SymTensor::random(8, 7).fingerprint());
        let _ = a.sttsv(&[1.0; 8]);
        assert_eq!(a.fingerprint(), SymTensor::random(8, 7).fingerprint());
        // Any single-entry perturbation, a different seed, and a different
        // dimension (even with identical packed bytes — all-zeros) miss.
        let mut b = a.clone();
        b.set(3, 2, 1, b.get(3, 2, 1) + 1.0);
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), SymTensor::random(8, 8).fingerprint());
        assert_ne!(
            SymTensor::zeros(4).fingerprint(),
            SymTensor::zeros(5).fingerprint()
        );
        // Zero-padding changes content, hence the fingerprint.
        assert_ne!(a.fingerprint(), a.padded(12).fingerprint());
    }

    #[test]
    fn precision_parses_and_displays() {
        assert_eq!("f32".parse::<Precision>().unwrap(), Precision::F32);
        assert_eq!("f64".parse::<Precision>().unwrap(), Precision::F64);
        assert!("bf16".parse::<Precision>().is_err());
        assert_eq!(Precision::F64.to_string(), "f64");
        assert_eq!(Precision::default(), Precision::F32);
    }

    #[test]
    fn f64_tensor_matches_f32_twin_on_shared_entries() {
        // random() draws the same f32 stream for both element types, so the
        // f64 instantiation is the exact promotion of the f32 one — and the
        // sequential oracles agree to f32 roundoff.
        let n = 9;
        let t32 = SymTensor::random(n, 11);
        let t64 = SymTensorG::<f64>::random(n, 11);
        for (i, j, k) in [(8, 3, 1), (5, 5, 2), (4, 4, 4), (0, 0, 0)] {
            assert_eq!(t64.get(i, j, k), t32.get(i, j, k) as f64);
        }
        let mut rng = Rng::new(12);
        let x32 = rng.normal_vec(n);
        let x64: Vec<f64> = x32.iter().map(|&v| v as f64).collect();
        let y32 = t32.sttsv(&x32);
        let y64 = t64.sttsv(&x64);
        for i in 0..n {
            assert!(
                (y32[i] as f64 - y64[i]).abs() < 1e-4 * y64[i].abs().max(1.0),
                "i={i}: {} vs {}",
                y32[i],
                y64[i]
            );
        }
        // extract_block resolves generically too
        let t64b = SymTensorG::<f64>::random(8, 5);
        let blk = t64b.extract_block(1, 0, 1, 4);
        assert_eq!(blk[(2 * 4 + 3) * 4 + 1], t64b.get(4 + 2, 3, 4 + 1));
    }

    #[test]
    fn odeco64_eigen_structure_survives_ill_conditioning() {
        // A spectrum spanning > 2²⁴ — below f32 resolution relative to
        // λ_max — still yields clean Z-eigenpairs in the f64 instantiation.
        let lambdas = [1.0e8f64, 1.0, 1.0e-1];
        let (t, cols) = SymTensorG::<f64>::odeco64(12, &lambdas, 21);
        for a in 0..3 {
            for b in 0..3 {
                let dot: f64 = cols[a].iter().zip(&cols[b]).map(|(x, y)| x * y).sum();
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-12, "({a},{b}) dot={dot}");
            }
        }
        for (l, &lam) in lambdas.iter().enumerate() {
            let y = t.sttsv(&cols[l]);
            for i in 0..12 {
                assert!(
                    (y[i] - lam * cols[l][i]).abs() < 1e-7 * lam.abs().max(1.0),
                    "l={l} i={i}: {} vs {}",
                    y[i],
                    lam * cols[l][i]
                );
            }
        }
    }
}
