//! Closed-form cost expressions from the paper, used by tests and benches to
//! compare measured quantities against the published analysis.

/// Theorem 1: memory-independent communication lower bound — at least one
/// processor communicates at least `2·(n(n-1)(n-2)/P)^{1/3} − 2n/P` words.
pub fn lower_bound_words(n: usize, p: usize) -> f64 {
    let n = n as f64;
    let p = p as f64;
    2.0 * (n * (n - 1.0) * (n - 2.0) / p).cbrt() - 2.0 * n / p
}

/// Leading term of the lower bound: `2n/P^{1/3}`.
pub fn lower_bound_leading(n: usize, p: usize) -> f64 {
    2.0 * n as f64 / (p as f64).cbrt()
}

/// §7.2.2: per-processor bandwidth cost of Algorithm 5 with the
/// point-to-point schedule, both vector phases:
/// `2·(n(q+1)/(q²+1) − n/P)` words.
pub fn algorithm_words(n: usize, q: usize) -> f64 {
    let p = (q * (q * q + 1)) as f64;
    let n = n as f64;
    let q = q as f64;
    2.0 * (n * (q + 1.0) / (q * q + 1.0) - n / p)
}

/// §7.2.2: per-processor bandwidth cost with All-to-All collectives, both
/// vector phases: `4n/(q+1) · (1 − 1/P)` — 2× the lower bound's leading term.
pub fn alltoall_words(n: usize, q: usize) -> f64 {
    let p = (q * (q * q + 1)) as f64;
    4.0 * n as f64 / (q as f64 + 1.0) * (1.0 - 1.0 / p)
}

/// §7.2: number of point-to-point steps per vector phase:
/// `q³/2 + 3q²/2 − 1` (= q²(q+3)/2 − 1, always integral).
pub fn p2p_steps(q: usize) -> usize {
    q * q * (q + 3) / 2 - 1
}

/// §7.1: ternary multiplications performed by processor p of Algorithm 5
/// (upper bound, processors with a central block):
/// `(q+1)q(q-1)/6·3b³ + q·(3b²(b−1)/2 + 2b²) + (b(b−1)(b−2)/2 + 2b(b−1) + b)`.
pub fn per_proc_ternary_mults(q: usize, b: usize) -> usize {
    let off = (q + 1) * q * (q - 1) / 6 * 3 * b * b * b;
    let nc = q * (3 * b * b * (b - 1) / 2 + 2 * b * b);
    let c = b * (b - 1) * (b - 2) / 2 + 2 * b * (b - 1) + b;
    off + nc + c
}

/// Total ternary multiplications of the sequential Algorithm 4: n²(n+1)/2.
pub fn total_ternary_mults(n: usize) -> usize {
    n * n * (n + 1) / 2
}

/// §8: the "sequence" approach (A ×₂ x by matrix multiplication, then a
/// matvec) moves at least O(n) words per processor when P ≤ n — its
/// first stage is an n² × n matmul whose memory-independent bound is
/// `Ω((n³/P)^{1/2})` limited by the largest array, ≥ n²/P words of the
/// intermediate when P ≤ n... we report the simple `n` lower bound the
/// paper cites ([3]: bandwidth of step one is at least O(n) for P ≤ n).
pub fn sequence_words_lower(n: usize, p: usize) -> f64 {
    if p <= n {
        n as f64
    } else {
        // beyond the paper's stated regime; fall back to the matmul bound
        (n as f64 * n as f64 * n as f64 / p as f64).sqrt()
    }
}

/// Elementary arithmetic ops: symmetric approach ≈ 2n³·(1/2)·2 = ~n³ FMA-ish;
/// the paper states ≈2n³ elementary ops for Algorithm 4 (2 mults + add per
/// ternary mult ≈ 4·n²(n+1)/2 ≈ 2n³) vs 2n³ + 2n² for the sequence approach
/// WITHOUT symmetry. We expose both for the §8 comparison bench.
pub fn symmetric_flops(n: usize) -> f64 {
    2.0 * (n as f64).powi(3)
}

/// Sequence-approach flops (no symmetry exploitation): 2n³ + 2n².
pub fn sequence_flops(n: usize) -> f64 {
    2.0 * (n as f64).powi(3) + 2.0 * (n as f64).powi(2)
}

/// Naive Algorithm-3 distribution (dense 3-D grid, no symmetry): each
/// processor holds an (n/p₁)³ cube... For the comparison bench we use the
/// standard memory-independent matmul-style bound for the n³ iteration
/// space with vector I/O: `3·(n³/P)^{1/3} − 3n/P ≈ 3n/P^{1/3}` (Lemma 1
/// without the symmetric factor-6 gain), i.e. the non-symmetric analogue.
pub fn nonsymmetric_lower_bound_words(n: usize, p: usize) -> f64 {
    let nf = n as f64;
    let pf = p as f64;
    3.0 * (nf * nf * nf / pf).cbrt() - 3.0 * nf / pf
}

/// §8 (future work, realized here): the d-dimensional generalization of
/// Theorem 1. The Lemma 2 argument extends verbatim — for V in the strictly
/// ordered orthant of Z^d, `d!·|V| ≤ |φ₁(V) ∪ … ∪ φ_d(V)|^d` (symmetrize V
/// over the d! permutations and apply the d-dim Loomis–Whitney/HBL bound) —
/// so a load-balanced atomic d-dimensional STTSV (one tensor, d−1 copies of
/// the same vector) has a processor communicating at least
/// `2·(n(n−1)···(n−d+1)/P)^{1/d} − 2n/P` words.
pub fn lower_bound_words_d(n: usize, p: usize, d: u32) -> f64 {
    assert!(d >= 2);
    let mut falling = 1.0f64;
    for t in 0..d as usize {
        falling *= (n - t) as f64;
    }
    2.0 * (falling / p as f64).powf(1.0 / d as f64) - 2.0 * n as f64 / p as f64
}

/// Wilson's existence conditions for Steiner (n, r, 3) systems (Theorem 2):
/// r−2 | n−2, (r−1)(r−2) | (n−1)(n−2), and r(r−1)(r−2) | n(n−1)(n−2).
/// Necessary for all n; sufficient for all large enough n (Wilson 1975).
pub fn wilson_conditions(n: usize, r: usize) -> bool {
    n > r
        && r >= 3
        && (n - 2) % (r - 2) == 0
        && ((n - 1) * (n - 2)) % ((r - 1) * (r - 2)) == 0
        && (n * (n - 1) * (n - 2)) % (r * (r - 1) * (r - 2)) == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lower_bound_positive_and_scaling() {
        let w1 = lower_bound_words(1000, 30);
        let w2 = lower_bound_words(2000, 30);
        assert!(w1 > 0.0);
        // leading term is linear in n
        assert!((w2 / w1 - 2.0).abs() < 0.01);
        // and the leading term decreases exactly with P^(1/3)
        let ratio = lower_bound_leading(1000, 30) / lower_bound_leading(1000, 240);
        assert!((ratio - 2.0).abs() < 1e-9, "ratio={ratio}");
        assert!(lower_bound_words(1000, 240) < w1);
    }

    #[test]
    fn algorithm_matches_lower_bound_leading_term() {
        // As n grows with q fixed, algorithm/lower-bound → (q+1)/(q²+1)^{2/3}
        // /q^{-1/3}... the paper's claim: leading terms match exactly since
        // (q²+1)/(q+1) ≈ P^{1/3}. Check the ratio tends to 1 for large q.
        for q in [5usize, 9, 13, 25] {
            let p = q * (q * q + 1);
            let n = 1000 * (q * q + 1);
            let ratio = algorithm_words(n, q) / lower_bound_leading(n, p);
            // ratio − 1 = (q+1)·q^{1/3}/(q²+1)^{2/3} − 1 = Θ(q^{-2/3}) → 0
            assert!(
                ratio >= 1.0 && ratio - 1.0 < 0.5 / (q as f64).powf(2.0 / 3.0),
                "q={q}: ratio={ratio}"
            );
        }
    }

    #[test]
    fn alltoall_is_twice_leading_term() {
        for q in [5usize, 9, 13] {
            let p = q * (q * q + 1);
            let n = 100 * (q * q + 1);
            let ratio = alltoall_words(n, q) / lower_bound_leading(n, p);
            assert!((ratio - 2.0).abs() < 0.4, "q={q}: ratio={ratio}");
        }
    }

    #[test]
    fn step_formula_known_values() {
        assert_eq!(p2p_steps(2), 9); // 4·5/2 − 1
        assert_eq!(p2p_steps(3), 26); // 9·6/2 − 1 = 13.5 + 13.5 − 1
        assert_eq!(p2p_steps(4), 55);
    }

    #[test]
    fn per_proc_mults_leading_order() {
        // §7.1: cost ≈ n³/2P for large b.
        let q = 3;
        let b = 64;
        let n = b * (q * q + 1);
        let p = q * (q * q + 1);
        let got = per_proc_ternary_mults(q, b) as f64;
        let want = (n as f64).powi(3) / (2.0 * p as f64);
        assert!((got / want - 1.0).abs() < 0.15, "got {got} want {want}");
    }

    #[test]
    fn total_mults_formula() {
        assert_eq!(total_ternary_mults(10), 550);
    }

    #[test]
    fn d_dimensional_bound_specializes_to_theorem1() {
        for (n, p) in [(120usize, 30usize), (1000, 130)] {
            assert!((lower_bound_words_d(n, p, 3) - lower_bound_words(n, p)).abs() < 1e-9);
        }
        // higher d: leading term 2n/P^{1/d} grows with d (less reuse per word)
        let n = 10_000;
        let p = 1000;
        assert!(lower_bound_words_d(n, p, 4) > lower_bound_words_d(n, p, 3));
        assert!(lower_bound_words_d(n, p, 5) > lower_bound_words_d(n, p, 4));
    }

    #[test]
    fn wilson_conditions_known_systems() {
        // existing systems satisfy the conditions…
        assert!(wilson_conditions(8, 4)); // SQS(8)
        assert!(wilson_conditions(10, 4)); // spherical q=3
        assert!(wilson_conditions(5, 3)); // spherical q=2
        assert!(wilson_conditions(17, 5)); // spherical q=4
        assert!(wilson_conditions(26, 6)); // spherical q=5
        // …and the spherical family does for every supported q
        for q in [2usize, 3, 4, 5, 7, 8, 9] {
            assert!(wilson_conditions(q * q + 1, q + 1), "q={q}");
        }
        // a divisibility failure
        assert!(!wilson_conditions(9, 4)); // 9−2 = 7 not divisible by 2
    }
}
