//! `sttsv` — CLI for the communication-optimal parallel STTSV system.
//!
//! Subcommands:
//!   tables        regenerate the paper's Tables 1–3 (partitions)
//!   schedule      regenerate Figure 1 (the 12-step schedule) or any q's
//!   run           one distributed STTSV; verify vs oracle; print comm
//!   power-method  Algorithm 1 end to end on an odeco tensor
//!                 (iteration-resident session by default; --no-resident
//!                 selects the host-centric per-iteration baseline)
//!   cp-gradient   Algorithm 2 end to end
//!   cp-als        resident multi-sweep CP gradient descent
//!   sweep         comm-cost sweep vs the Theorem 1 lower bound
//!   serve         multi-tenant serving: plan cache + r-deep query coalescing
//!   verify        exhaustive invariant checks for a given q
//!   bounds        print the paper's closed-form costs

use anyhow::{bail, Result};
use sttsv::apps;
use sttsv::bounds;
use sttsv::coordinator::{self, baselines, CommMode, ExecOpts};
use sttsv::partition::TetraPartition;
use sttsv::runtime::{set_simd_policy, Backend, SimdPolicy};
use sttsv::schedule::CommSchedule;
use sttsv::apps::RecoveryPolicy;
use sttsv::serve::{AdmissionPolicy, RobustnessPolicy, SttsvServer};
use sttsv::simulator::{AbftMode, FaultPlan, TransportKind, WireFormat};
use sttsv::steiner::{fixtures, spherical, sqs8, trivial};
use sttsv::tensor::{linalg, Precision, SymTensor, SymTensorG};
use sttsv::util::cli::Args;
use sttsv::util::rng::Rng;
use sttsv::util::table::{fnum, fset, ftriples, Table};

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand() {
        Some("tables") => cmd_tables(&args),
        Some("schedule") => cmd_schedule(&args),
        Some("run") => cmd_run(&args),
        Some("power-method") => cmd_power_method(&args),
        Some("cp-gradient") => cmd_cp_gradient(&args),
        Some("cp-als") => cmd_cp_als(&args),
        Some("mttkrp") => cmd_mttkrp(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("serve") => cmd_serve(&args),
        Some("verify") => cmd_verify(&args),
        Some("bounds") => cmd_bounds(&args),
        _ => {
            eprintln!(
                "usage: sttsv <tables|schedule|run|power-method|cp-gradient|cp-als\
                 |mttkrp|sweep|serve|verify|bounds> [--q N] [--b N] [--mode p2p|a2a] \
                 [--backend native|pjrt|spsc|mpsc] [--pin] [--iters N] [--sqs8] \
                 [--trivial M] [--no-batch] [--packed|--no-packed] \
                 [--overlap|--no-overlap] [--compiled|--no-compiled] \
                 [--compute-threads N] [--resident|--no-resident] \
                 [--batch-window MS] [--max-r N] [--cache N] [--queries N] \
                 [--chaos SEED,RATE] [--chaos-crash RANK@OP] \
                 [--chaos-flip WIRE,MEM[,BIT]] [--abft off|verify|scrub] \
                 [--recv-timeout-ms N] \
                 [--checkpoint-every N] [--retries N] [--deadline-ms MS] \
                 [--wire f32|bf16] [--precision f32|f64] [--simd auto|scalar]\n\
                 \n\
                 --backend        comma-separable selectors: a compute backend \
                 (native|pjrt) and/or a message transport (spsc = lock-free \
                 shared-memory rings, mpsc = the counting oracle; e.g. \
                 --backend native,spsc)\n\
                 --pin            pin worker thread r to CPU r (spsc transport \
                 benchmarking)\n\
                 --compiled       execute plan-compiled branch-free sweep programs \
                 (default on the packed native path; --no-compiled keeps the \
                 per-sweep interpreter)\n\
                 --compute-threads N  split each worker's compiled descriptor \
                 stream over N intra-worker threads (default 1 = bitwise \
                 oracle; comm counters are invariant for any N)\n\
                 --trivial M      use the trivial Steiner system on M block rows \
                 (P = C(M,3); --trivial 4 is the P=4 serving fixture)\n\
                 --batch-window MS  serve: hold a non-full batch open this many \
                 ms for stragglers (0 + --max-r 1 = serial per-query serving)\n\
                 --max-r N        serve: coalesce at most N queries into one \
                 r-deep sweep\n\
                 --cache N        serve: plan-cache capacity (plans, LRU)\n\
                 --queries N      serve: synthetic open-loop queries to replay\n\
                 --chaos SEED,RATE  inject seeded transport faults at this \
                 per-op probability (deterministic per seed; 0 = transparent)\n\
                 --chaos-crash RANK@OP  deterministically crash rank RANK at \
                 its OP-th transport operation (composes with --chaos; \
                 power-method/cp-als sessions restart from the newest \
                 checkpoint, serve retries the batch)\n\
                 --chaos-flip WIRE,MEM[,BIT]  silent-data-corruption chaos: \
                 flip one bit per sweep send with probability WIRE and one \
                 bit per executed block's accumulator with probability MEM \
                 (optional BIT pins the flipped position, 0..=31); pair \
                 with --abft — without it wire flips are caught only by \
                 the oracle check and memory flips go undetected\n\
                 --abft MODE      off (default) | verify | scrub: per-block \
                 mode-1 checksum verification of every sweep (detects \
                 in-memory SDC; a per-message integrity word covers the \
                 wire). verify fails typed on mismatch; scrub recomputes \
                 the offending block first and only fails if the error \
                 persists. Sessions (power-method/cp-als) and serve treat \
                 the failure as retryable like any transport fault. \
                 Requires --compiled (on by default); forces --no-overlap \
                 and --compute-threads 1\n\
                 --recv-timeout-ms N  recv watchdog: a rank waiting longer \
                 than this on one message fails with a typed Timeout\n\
                 --checkpoint-every N  power-method/cp-als: commit a \
                 portion-local checkpoint every N iterations and restart \
                 from the newest consistent one on failure\n\
                 --retries N      max restart attempts (sessions) or \
                 per-batch retries (serve) after a failure\n\
                 --deadline-ms MS serve: shed queries that cannot start \
                 within MS of arrival; late completions are flagged\n\
                 --wire FMT       sweep-payload wire format: f32 (default) or \
                 bf16 (half the payload bytes at identical words/messages; \
                 collectives and blocking sends stay f32)\n\
                 --precision P    f32 (default) or f64; power-method with f64 \
                 runs the host-side conditioning study through the f64 \
                 run-kernels (the distributed plan itself stays f32)\n\
                 --simd POLICY    run-kernel dispatch: auto (default; AVX2 \
                 microkernels when the CPU has them — bitwise-identical \
                 results either way) or scalar"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn partition_for(args: &Args) -> Result<(TetraPartition, String)> {
    if args.flag("sqs8") {
        let part = TetraPartition::from_steiner(&sqs8())?;
        Ok((part, "SQS(8), m=8, P=14".to_string()))
    } else if args.get("trivial").is_some() {
        let m: usize = args.get_or("trivial", 4usize);
        let part = TetraPartition::from_steiner(&trivial(m)?)?;
        let label = format!("trivial m={m}, P={}", part.p);
        Ok((part, label))
    } else {
        let q: u64 = args.get_or("q", 2u64);
        let sys = spherical(q)?;
        let part = TetraPartition::from_steiner(&sys)?;
        let label = format!("spherical q={q}, m={}, P={}", part.m, part.p);
        Ok((part, label))
    }
}

fn print_partition_table(part: &TetraPartition, title: &str) {
    println!("\n{title}");
    let mut t = Table::new(["p", "R_p", "N_p", "D_p"]);
    for p in 0..part.p {
        let d = match part.d_p[p] {
            Some(a) => format!("{{({},{},{})}}", a + 1, a + 1, a + 1),
            None => "{}".to_string(),
        };
        t.row([
            (p + 1).to_string(),
            fset(&part.r_p[p]),
            ftriples(&part.n_p[p]),
            d,
        ]);
    }
    t.print();
}

fn cmd_tables(_args: &Args) -> Result<()> {
    // Table 1 + 2 (q = 3) — our construction.
    let part3 = TetraPartition::from_steiner(&spherical(3)?)?;
    part3.verify()?;
    print_partition_table(
        &part3,
        "Table 1 (reproduced): tetrahedral block partition, m=10, P=30 \
         [our Steiner (10,4,3) construction; paper's instance is isomorphic]",
    );
    println!("\nTable 2 (reproduced): row block sets Q_i (|Q_i| = q(q+1) = 12)");
    let mut t2 = Table::new(["i", "Q_i"]);
    for i in 0..part3.m {
        t2.row([(i + 1).to_string(), fset(&part3.q_i[i])]);
    }
    t2.print();

    // Table 3 (SQS(8)).
    let part8 = TetraPartition::from_steiner(&sqs8())?;
    part8.verify()?;
    print_partition_table(
        &part8,
        "Table 3 (reproduced): tetrahedral block partition, m=8, P=14 \
         [planes of AG(3,2); paper's instance is isomorphic]",
    );

    // And validate the paper's literal fixtures.
    TetraPartition::from_rows(10, &fixtures::table1())?;
    TetraPartition::from_rows(8, &fixtures::table3())?;
    println!("\npaper fixtures (literal Tables 1/3): partition invariants OK");
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let (part, label) = partition_for(args)?;
    let sched = CommSchedule::build(&part)?;
    sched.validate(&part)?;
    println!(
        "communication schedule for {label}: {} transfers in {} steps",
        sched.xfers.len(),
        sched.num_steps()
    );
    for (si, step) in sched.steps.iter().enumerate() {
        let moves: Vec<String> = step
            .iter()
            .map(|&xi| {
                let x = &sched.xfers[xi];
                format!("{}→{}", x.from + 1, x.to + 1)
            })
            .collect();
        println!("step {:>2}: {}", si + 1, moves.join("  "));
    }
    Ok(())
}

fn exec_opts(args: &Args) -> Result<ExecOpts> {
    // --backend takes a comma list mixing two orthogonal selectors: the
    // compute backend (native|pjrt) and the message transport (spsc|mpsc).
    // Each word parses as whichever kind it names, so `--backend spsc`,
    // `--backend pjrt` and `--backend native,spsc` all do what they say.
    let mut backend = Backend::Native;
    let mut transport = TransportKind::Mpsc;
    for word in args.get("backend").unwrap_or("native").split(',') {
        if let Ok(t) = word.parse::<TransportKind>() {
            transport = t;
        } else {
            backend = word.parse::<Backend>().map_err(|_| {
                anyhow::anyhow!(
                    "unknown backend selector '{word}' (expected native|pjrt|spsc|mpsc)"
                )
            })?;
        }
    }
    let mut opts = ExecOpts::for_backend(backend);
    opts.transport = transport;
    opts.pin_threads = args.flag("pin");
    opts.mode = args.get("mode").unwrap_or("p2p").parse::<CommMode>()?;
    opts.batch = !args.flag("no-batch");
    if args.flag("packed") {
        opts.packed = true;
    }
    if args.flag("no-packed") {
        opts.packed = false;
    }
    if args.flag("overlap") {
        opts.overlap = true;
    }
    if args.flag("no-overlap") {
        opts.overlap = false;
    }
    if args.flag("compiled") {
        opts.compiled = true;
    }
    if args.flag("no-compiled") {
        opts.compiled = false;
    }
    opts.compute_threads = args.get_or("compute-threads", opts.compute_threads);
    if let Some(spec) = args.get("chaos") {
        opts.chaos = spec.parse::<FaultPlan>()?;
    }
    // --chaos-crash / --chaos-flip compose onto the same FaultPlan: each
    // sets its own fields, so `--chaos 7,0.001 --chaos-crash 2@40` keeps
    // the random-fault stream AND the deterministic kill switch.
    if let Some(spec) = args.get("chaos-crash") {
        let (rank, at) = spec.split_once('@').ok_or_else(|| {
            anyhow::anyhow!("--chaos-crash wants `RANK@OP` (e.g. 2@40)")
        })?;
        opts.chaos.crash_rank = Some(rank.trim().parse::<u32>()?);
        opts.chaos.crash_at = at.trim().parse::<u64>()?;
    }
    if let Some(spec) = args.get("chaos-flip") {
        let mut parts = spec.split(',');
        let mut rate = |name: &str| -> Result<u32> {
            let raw = parts
                .next()
                .ok_or_else(|| {
                    anyhow::anyhow!("--chaos-flip wants `WIRE,MEM[,BIT]` (e.g. 0.01,0,25)")
                })?
                .trim()
                .parse::<f64>()?;
            anyhow::ensure!(
                (0.0..=1.0).contains(&raw),
                "chaos-flip {name} probability must be in [0,1], got {raw}"
            );
            Ok((raw * 1e6).round() as u32)
        };
        opts.chaos.flip_wire_ppm = rate("WIRE")?;
        opts.chaos.flip_mem_ppm = rate("MEM")?;
        if let Some(bit) = parts.next() {
            let bit: u8 = bit.trim().parse()?;
            anyhow::ensure!(bit < 32, "chaos-flip BIT must be 0..=31, got {bit}");
            opts.chaos = opts.chaos.forcing_bit(bit);
        }
    }
    opts.abft = args.get("abft").unwrap_or("off").parse::<AbftMode>()?;
    let recv_timeout_ms: u64 = args.get_or("recv-timeout-ms", 0u64);
    if recv_timeout_ms > 0 {
        opts.recv_timeout = Some(std::time::Duration::from_millis(recv_timeout_ms));
    }
    opts.wire = args.get("wire").unwrap_or("f32").parse::<WireFormat>()?;
    opts.precision = args.get("precision").unwrap_or("f32").parse::<Precision>()?;
    // SIMD dispatch is a runtime-global policy, not a plan property:
    // the AVX2 kernels are bitwise-identical to the scalar ones, so the
    // choice never belongs in a plan-cache key.
    set_simd_policy(args.get("simd").unwrap_or("auto").parse::<SimdPolicy>()?);
    // Plans normalize flag interactions themselves; surface the silent
    // downgrades a user could plausibly trip over.
    if opts.compute_threads > 1 && opts.normalize().compute_threads == 1 {
        eprintln!(
            "warning: --compute-threads {} ignored — the compute pool \
             requires the compiled packed native path (drop --no-compiled/\
             --no-packed/--backend pjrt, or see --compiled)",
            opts.compute_threads
        );
    }
    if opts.precision == Precision::F64 && opts.normalize().precision == Precision::F32 {
        eprintln!(
            "warning: --precision f64 ignored — the bf16 wire format is \
             f32-only (drop --wire bf16)"
        );
    }
    if opts.abft.on() && !opts.normalize().abft.on() {
        eprintln!(
            "warning: --abft {} ignored — ABFT checksum verification \
             requires the compiled packed native path (drop --no-compiled/\
             --no-packed/--backend pjrt)",
            opts.abft
        );
    }
    Ok(opts)
}

/// `--checkpoint-every N [--retries R]` → a session [`RecoveryPolicy`]
/// (§Rob). Defaults stay all-off so plain runs are byte-identical to the
/// pre-recovery code path; turning on checkpoints defaults to 3 retries.
fn recovery_policy(args: &Args) -> RecoveryPolicy {
    let every: usize = args.get_or("checkpoint-every", 0usize);
    RecoveryPolicy {
        checkpoint_every: every,
        max_retries: args.get_or("retries", if every > 0 { 3u32 } else { 0u32 }),
        ..RecoveryPolicy::default()
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let (part, label) = partition_for(args)?;
    let b: usize = args.get_or("b", 8usize);
    let n = b * part.m;
    let opts = exec_opts(args)?;
    println!("STTSV on {label}: n={n} (b={b}), {opts:?}");
    let tensor = SymTensor::random(n, args.get_or("seed", 42u64));
    let mut rng = Rng::new(args.get_or("seed", 42u64) + 1);
    let x = rng.normal_vec(n);
    let rep = coordinator::run_sttsv_opts(&tensor, &x, &part, opts)?;
    let want = tensor.sttsv(&x);
    let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
    let max_err = rep
        .y
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs() / scale)
        .fold(0.0f32, f32::max);
    println!(
        "result: max rel err vs sequential oracle = {max_err:.2e} {}",
        if max_err < 5e-3 { "(OK)" } else { "(FAIL)" }
    );
    println!(
        "comm: max sent {} w, max recv {} w over {} steps/phase",
        rep.max_sent_words(),
        rep.max_recv_words(),
        rep.steps_per_phase
    );
    println!(
        "runtime: peak in-flight payload {} words, {} fresh payload allocs \
         (0 on a warm plan)",
        rep.peak_inflight_words, rep.fresh_payload_allocs
    );
    println!(
        "lower bound (Thm 1): {} w; algorithm closed form: {} w",
        fnum(bounds::lower_bound_words(n, part.p)),
        fnum(2.0 * (n as f64 * part.r as f64 / part.m as f64 - n as f64 / part.p as f64))
    );
    println!(
        "compute: max {} ternary mults/proc (n³/2P = {})",
        rep.max_ternary_mults(),
        fnum((n as f64).powi(3) / (2.0 * part.p as f64))
    );
    Ok(())
}

fn cmd_power_method(args: &Args) -> Result<()> {
    let (part, label) = partition_for(args)?;
    let b: usize = args.get_or("b", 8usize);
    let n = b * part.m;
    let iters: usize = args.get_or("iters", 50usize);
    let opts = exec_opts(args)?;
    if opts.normalize().precision == Precision::F64 {
        return cmd_power_method_f64(args, &label, n, iters);
    }
    let resident = !args.flag("no-resident");
    println!(
        "higher-order power method on {label}: n={n}, {} driver, {opts:?}",
        if resident { "iteration-resident" } else { "host-centric" }
    );
    let lambdas = [5.0f32, 2.0, 1.0];
    let (tensor, cols) = SymTensor::odeco(n, &lambdas, args.get_or("seed", 7u64));
    let mut rng = Rng::new(args.get_or("seed", 7u64) + 1);
    let mut x0 = cols[0].clone();
    for v in x0.iter_mut() {
        *v += 0.25 * rng.normal_f32();
    }
    let rep = if resident {
        let policy = recovery_policy(args);
        apps::power_method_recovering(&tensor, &part, &x0, iters, 1e-6, opts, policy)?
    } else {
        apps::power_method_host(&tensor, &part, &x0, iters, 1e-6, opts)?
    };
    for (t, it) in rep.iters.iter().enumerate() {
        let iter_sent = it.comm.iter().map(|s| s.sent_words).max().unwrap_or(0);
        println!(
            "iter {:>3}: ||y|| = {:<10.6} lambda = {:<10.6} delta = {:.3e}  \
             comm {iter_sent} w/proc",
            t + 1,
            it.norm,
            it.lambda,
            it.delta
        );
    }
    let align = linalg::dot(&rep.x, &cols[0]).abs();
    println!(
        "converged: lambda = {:.6} (planted 5.0), |<x, e1>| = {align:.6}",
        rep.lambda
    );
    let max_sent = rep.comm.iter().map(|s| s.sent_words).max().unwrap();
    println!(
        "total comm over {} iters: max sent/proc = {} words ({} per iter{})",
        rep.iters.len(),
        max_sent,
        max_sent / rep.iters.len() as u64,
        if resident {
            "; STTSV + O(log P) collective words, zero host vector traffic"
        } else {
            "; plus 2n host↔worker vector words per iteration, uncounted"
        }
    );
    if rep.recovery.attempts > 1 {
        println!(
            "recovery: {} attempts; resumed from checkpointed iterations {:?} \
             (checkpoint + replay comm charged above)",
            rep.recovery.attempts, rep.recovery.resumed_from
        );
    }
    Ok(())
}

/// `power-method --precision f64`: the host-side conditioning study. The
/// distributed plan (and its wire formats) is f32-only, so the f64 path
/// runs Algorithm 1 sequentially through the f64-generic run-kernels on
/// an ill-conditioned planted-eigenpair instance — the regime where the
/// f32 pipeline's ~1e-7 relative kernel error swamps the answer.
fn cmd_power_method_f64(args: &Args, label: &str, n: usize, iters: usize) -> Result<()> {
    let lambdas = [1.0e8f64, 2.0, 1.0];
    println!(
        "higher-order power method on {label} sized n={n}: f64 conditioning \
         study (host-side sequential; planted spectrum {lambdas:?})"
    );
    let (tensor, cols) = SymTensorG::<f64>::odeco64(n, &lambdas, args.get_or("seed", 7u64));
    let mut rng = Rng::new(args.get_or("seed", 7u64) + 1);
    let mut x0 = cols[0].clone();
    for v in x0.iter_mut() {
        *v += 0.25 * rng.normal_f32() as f64;
    }
    let rep = apps::power_method_f64(&tensor, &x0, iters, 1e-12);
    for (t, it) in rep.iters.iter().enumerate() {
        println!(
            "iter {:>3}: ||y|| = {:<14.6e} lambda = {:<14.8e} delta = {:.3e}",
            t + 1,
            it.norm,
            it.lambda,
            it.delta
        );
    }
    let align: f64 = rep.x.iter().zip(&cols[0]).map(|(a, b)| a * b).sum::<f64>().abs();
    println!(
        "converged: lambda = {:.8e} (planted 1e8, abs err {:.2e}; an f32 \
         pipeline is ~1e1 here), |<x, e1>| = {align:.12}",
        rep.lambda,
        (rep.lambda - 1.0e8).abs()
    );
    Ok(())
}

fn cmd_cp_als(args: &Args) -> Result<()> {
    let (part, label) = partition_for(args)?;
    let b: usize = args.get_or("b", 4usize);
    let n = b * part.m;
    let r: usize = args.get_or("r", 2usize);
    let sweeps: usize = args.get_or("sweeps", 25usize);
    let step: f32 = args.get_or("step", 0.05f32);
    let opts = exec_opts(args)?;
    println!(
        "resident CP gradient descent on {label}: n={n}, r={r}, {sweeps} sweeps, \
         step {step}, {opts:?}"
    );
    let lambdas: Vec<f32> = (0..r).map(|l| (r - l) as f32).collect();
    let (tensor, cols) = SymTensor::odeco(n, &lambdas, args.get_or("seed", 17u64));
    let mut rng = Rng::new(args.get_or("seed", 17u64) + 1);
    // perturbed planted factors: a basin where plain gradient descent works
    let x0: Vec<Vec<f32>> = cols
        .iter()
        .zip(&lambdas)
        .map(|(c, lam)| {
            let s = lam.cbrt();
            c.iter().map(|v| s * v + 0.05 * rng.normal_f32()).collect()
        })
        .collect();
    let f0 = apps::cp_objective(&tensor, &x0);
    let policy = recovery_policy(args);
    let rep = apps::cp_als_recovering(&tensor, &part, &x0, sweeps, step, 1e-6, opts, policy)?;
    for (t, it) in rep.iters.iter().enumerate() {
        let iter_sent = it.comm.iter().map(|s| s.sent_words).max().unwrap_or(0);
        println!("sweep {:>3}: ||grad|| = {:.3e}  comm {iter_sent} w/proc", t + 1, it.gnorm);
    }
    let f1 = apps::cp_objective(&tensor, &rep.x_cols);
    println!(
        "objective: {f0:.6} -> {f1:.6} ({:.1}% reduced) over {} resident sweeps",
        100.0 * (1.0 - f1 / f0),
        rep.iters.len()
    );
    let max_sent = rep.comm.iter().map(|s| s.sent_words).max().unwrap();
    println!("comm: max sent/proc = {max_sent} words total (vector never left the workers)");
    if rep.recovery.attempts > 1 {
        println!(
            "recovery: {} attempts; resumed from checkpointed sweeps {:?}",
            rep.recovery.attempts, rep.recovery.resumed_from
        );
    }
    Ok(())
}

fn cmd_cp_gradient(args: &Args) -> Result<()> {
    let (part, label) = partition_for(args)?;
    let b: usize = args.get_or("b", 4usize);
    let n = b * part.m;
    let r: usize = args.get_or("r", 3usize);
    let opts = exec_opts(args)?;
    println!("symmetric CP gradient on {label}: n={n}, r={r}, {opts:?}");
    let lambdas: Vec<f32> = (0..r).map(|l| (r - l) as f32).collect();
    let (tensor, _) = SymTensor::odeco(n, &lambdas, args.get_or("seed", 11u64));
    let mut rng = Rng::new(args.get_or("seed", 11u64) + 1);
    let x_cols: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
    let rep = apps::cp_gradient(&tensor, &part, &x_cols, opts)?;
    for (l, g) in rep.grad.iter().enumerate() {
        println!("||grad_{l}|| = {:.6}", linalg::norm(g));
    }
    let max_sent = rep.comm.iter().map(|s| s.sent_words).max().unwrap();
    println!("comm: max sent/proc = {max_sent} words over r = {r} STTSVs");
    Ok(())
}

fn cmd_mttkrp(args: &Args) -> Result<()> {
    let (part, label) = partition_for(args)?;
    let b: usize = args.get_or("b", 4usize);
    let n = b * part.m;
    let r: usize = args.get_or("r", 4usize);
    let opts = exec_opts(args)?;
    println!("mode-1 symmetric MTTKRP on {label}: n={n}, r={r} (paper §8 extension)");
    let tensor = SymTensor::random(n, args.get_or("seed", 21u64));
    let mut rng = Rng::new(args.get_or("seed", 21u64) + 1);
    let x_cols: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
    let (ys, comm) = apps::symmetric_mttkrp(&tensor, &part, &x_cols, opts)?;
    let mut max_err = 0.0f32;
    for (l, xl) in x_cols.iter().enumerate() {
        let want = tensor.sttsv(xl);
        let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
        for i in 0..n {
            max_err = max_err.max((ys[l][i] - want[i]).abs() / scale);
        }
    }
    println!(
        "Y: {r} columns of length {n}; max rel err vs r sequential STTSVs = {max_err:.2e} {}",
        if max_err < 5e-3 { "(OK)" } else { "(FAIL)" }
    );
    let max_sent = comm.iter().map(|s| s.sent_words).max().unwrap();
    println!(
        "comm: max sent/proc = {max_sent} words = r x {} (one STTSV)",
        max_sent / r as u64
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let scale: usize = args.get_or("scale", 2usize);
    println!("comm-cost sweep (measured on the simulator, words per processor, both phases)");
    let mut t = Table::new([
        "q", "P", "n", "measured p2p", "closed form", "lower bound", "meas/LB",
        "measured a2a", "a2a/LB",
    ]);
    for q in [2usize, 3, 4, 5] {
        let part = TetraPartition::from_steiner(&spherical(q as u64)?)?;
        let b = q * (q + 1) * scale;
        let n = b * part.m;
        let p2p = coordinator::run_comm_only(&part, b, CommMode::PointToPoint)?;
        let a2a = coordinator::run_comm_only(&part, b, CommMode::AllToAll)?;
        let meas = p2p.iter().map(|s| s.sent_words).max().unwrap() as f64;
        let meas_a2a = a2a.iter().map(|s| s.sent_words).max().unwrap() as f64;
        let lb = bounds::lower_bound_words(n, part.p);
        t.row([
            q.to_string(),
            part.p.to_string(),
            n.to_string(),
            fnum(meas),
            fnum(bounds::algorithm_words(n, q)),
            fnum(lb),
            format!("{:.3}", meas / lb),
            fnum(meas_a2a),
            format!("{:.3}", meas_a2a / lb),
        ]);
    }
    t.print();

    println!("\nbaselines at q=2 (P=10):");
    let part = TetraPartition::from_steiner(&spherical(2)?)?;
    let b: usize = args.get_or("b", 12usize);
    let n = b * part.m;
    let tensor = SymTensor::random(n, 1);
    let mut rng = Rng::new(2);
    let x = rng.normal_vec(n);
    let alg = coordinator::run_sttsv(&tensor, &x, &part, CommMode::PointToPoint, Backend::Native)?;
    let naive = baselines::run_naive_grid(&tensor, &x, part.p)?;
    let seq = baselines::run_sequence(&tensor, &x, part.p)?;
    let mut t2 = Table::new(["algorithm", "max sent words/proc", "vs Thm 1 LB"]);
    let lb = bounds::lower_bound_words(n, part.p);
    t2.row([
        "Algorithm 5 (p2p)".to_string(),
        alg.max_sent_words().to_string(),
        format!("{:.2}x", alg.max_sent_words() as f64 / lb),
    ]);
    t2.row([
        "naive 3-D grid (Alg 3)".to_string(),
        naive.max_sent_words().to_string(),
        format!("{:.2}x", naive.max_sent_words() as f64 / lb),
    ]);
    t2.row([
        "sequence (§8)".to_string(),
        seq.max_sent_words().to_string(),
        format!("{:.2}x", seq.max_sent_words() as f64 / lb),
    ]);
    t2.print();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (part, label) = partition_for(args)?;
    let b: usize = args.get_or("b", 4usize);
    let n = b * part.m;
    let opts = exec_opts(args)?;
    let window_ms: f64 = args.get_or("batch-window", 1.0f64);
    let max_r: usize = args.get_or("max-r", 8usize);
    let cache: usize = args.get_or("cache", 4usize);
    let queries: usize = args.get_or("queries", 64usize);
    let seed: u64 = args.get_or("seed", 97u64);
    let policy = AdmissionPolicy::coalescing(window_ms / 1000.0, max_r);
    let deadline_ms: f64 = args.get_or("deadline-ms", f64::INFINITY);
    let robust = RobustnessPolicy {
        deadline: deadline_ms / 1000.0,
        max_retries: args.get_or("retries", if opts.chaos.is_zero() { 0u32 } else { 2u32 }),
        ..RobustnessPolicy::default()
    };
    println!(
        "multi-tenant serving on {label}: n={n} (b={b}), window {window_ms} ms, \
         max_r {max_r}, cache {cache} plans, {queries} queries, {opts:?}"
    );
    let tensor = SymTensor::random(n, seed);

    // Synthetic bursty open-loop workload: bursts of max_r queries landing
    // within ~0.1 ms of each other, separated by 0.2 ms gaps — the arrival
    // process a coalescer exists for. The SAME trace replays under the
    // coalescing policy and the serial baseline.
    let mut rng = Rng::new(seed + 1);
    let burst = max_r.max(1);
    let mut trace: Vec<(Vec<f32>, f64)> = Vec::with_capacity(queries);
    for k in 0..queries {
        let base = (k / burst) as f64 * 2e-4;
        let jitter = rng.below(1000) as f64 * 1e-7;
        trace.push((rng.normal_vec(n), base + jitter));
    }

    let server = SttsvServer::new(&tensor, &part, opts, policy, cache)?.with_robustness(robust);
    for (x, arrival) in &trace {
        server.submit(x.clone(), *arrival)?;
    }
    let rep = server.drain()?;

    let mut max_err = 0.0f32;
    for o in &rep.outcomes {
        let want = tensor.sttsv(&trace[o.id as usize].0);
        let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
        for i in 0..n {
            max_err = max_err.max((o.y[i] - want[i]).abs() / scale);
        }
    }
    println!(
        "results: max rel err vs sequential oracle = {max_err:.2e} {}",
        if max_err < 5e-3 { "(OK)" } else { "(FAIL)" }
    );

    let serial = SttsvServer::new(&tensor, &part, opts, AdmissionPolicy::serial(), cache)?
        .with_robustness(robust);
    for (x, arrival) in &trace {
        serial.submit(x.clone(), *arrival)?;
    }
    let srep = serial.drain()?;

    let mut t = Table::new([
        "policy", "batches", "mean r", "qps", "p50 ms", "p99 ms", "words/query",
    ]);
    for (name, r) in [("coalescing", &rep), ("serial", &srep)] {
        let words = r
            .outcomes
            .iter()
            .map(|o| o.comm.sent_words)
            .max()
            .unwrap_or(0);
        t.row([
            name.to_string(),
            r.batches.len().to_string(),
            format!("{:.2}", r.mean_batch_depth()),
            format!("{:.0}", r.qps()),
            format!("{:.3}", 1e3 * r.latency_percentile(50.0)),
            format!("{:.3}", 1e3 * r.latency_percentile(99.0)),
            words.to_string(),
        ]);
    }
    t.print();
    println!(
        "throughput: {:.2}x serial ({:.0} vs {:.0} queries/s); per-batch comm \
         asserted = one r-deep STTSV (words rx, messages unchanged)",
        rep.qps() / srep.qps().max(1e-12),
        rep.qps(),
        srep.qps()
    );
    let c = server.cache_counters();
    println!(
        "plan cache: {} builds, {} hits, {} misses, {} evictions \
         (builds freeze once every (tensor, P, opts) config is seen)",
        c.plan_builds, c.hits, c.misses, c.evictions
    );
    if !rep.shed.is_empty() || !rep.failed.is_empty() || rep.retries > 0 || rep.breaker_trips > 0 {
        let late = rep.outcomes.iter().filter(|o| o.missed_deadline).count();
        println!(
            "robustness: {} shed (deadline), {} late, {} failed, {} retries, \
             {} breaker trips",
            rep.shed.len(),
            late,
            rep.failed.len(),
            rep.retries,
            rep.breaker_trips
        );
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let q: u64 = args.get_or("q", 3u64);
    println!("verifying spherical q={q} end to end...");
    let sys = spherical(q)?;
    sys.verify()?;
    println!("  Steiner ({}, {}, 3) system: OK ({} blocks)", sys.m, sys.r, sys.num_blocks());
    let part = TetraPartition::from_steiner(&sys)?;
    part.verify()?;
    println!("  tetrahedral partition: OK (P = {})", part.p);
    let sched = CommSchedule::build(&part)?;
    sched.validate(&part)?;
    let expected = q as usize * q as usize * (q as usize + 3) / 2 - 1;
    println!(
        "  schedule: OK ({} steps; formula q³/2+3q²/2−1 = {expected})",
        sched.num_steps()
    );
    if sched.num_steps() != expected {
        bail!("step count mismatch");
    }
    Ok(())
}

fn cmd_bounds(args: &Args) -> Result<()> {
    let n: usize = args.get_or("n", 1000usize);
    let mut t = Table::new([
        "q", "P", "Thm1 LB", "leading 2n/P^(1/3)", "Alg5 p2p", "Alg5 a2a", "steps/phase",
    ]);
    for q in [2usize, 3, 4, 5, 7, 8, 9] {
        let p = q * (q * q + 1);
        t.row([
            q.to_string(),
            p.to_string(),
            fnum(bounds::lower_bound_words(n, p)),
            fnum(bounds::lower_bound_leading(n, p)),
            fnum(bounds::algorithm_words(n, q)),
            fnum(bounds::alltoall_words(n, q)),
            bounds::p2p_steps(q).to_string(),
        ]);
    }
    println!("closed-form communication costs at n = {n} (words/processor):");
    t.print();
    Ok(())
}
