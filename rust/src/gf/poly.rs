//! Polynomial arithmetic over F_p, used only to bootstrap the GF(p^e)
//! exp/log tables: find an irreducible modulus and multiply polynomial
//! representatives modulo it.

use anyhow::{bail, Result};

/// A polynomial over F_p, little-endian coefficients (coeffs[i] is the x^i
/// coefficient). Normalized: no trailing zeros (zero polynomial = empty).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Poly {
    pub p: u64,
    pub coeffs: Vec<u64>,
}

impl Poly {
    /// The zero polynomial.
    pub fn zero(p: u64) -> Poly {
        Poly { p, coeffs: vec![] }
    }

    /// The constant polynomial 1.
    pub fn one(p: u64) -> Poly {
        Poly { p, coeffs: vec![1] }
    }

    /// Decode an element id (base-p digit string) into a polynomial.
    pub fn from_id(mut id: u64, p: u64) -> Poly {
        let mut coeffs = vec![];
        while id > 0 {
            coeffs.push(id % p);
            id /= p;
        }
        Poly { p, coeffs }
    }

    /// Encode back to an element id.
    pub fn to_id(&self) -> u64 {
        let mut id = 0u64;
        for &c in self.coeffs.iter().rev() {
            id = id * self.p + c;
        }
        id
    }

    /// Degree, or None for the zero polynomial.
    pub fn degree(&self) -> Option<usize> {
        if self.coeffs.is_empty() {
            None
        } else {
            Some(self.coeffs.len() - 1)
        }
    }

    /// Whether this is the constant 1.
    pub fn is_one(&self) -> bool {
        self.coeffs == [1]
    }

    fn trim(mut self) -> Poly {
        while self.coeffs.last() == Some(&0) {
            self.coeffs.pop();
        }
        self
    }
}

/// Plain polynomial product over F_p.
pub fn mul(a: &Poly, b: &Poly) -> Poly {
    assert_eq!(a.p, b.p);
    if a.coeffs.is_empty() || b.coeffs.is_empty() {
        return Poly::zero(a.p);
    }
    let mut out = vec![0u64; a.coeffs.len() + b.coeffs.len() - 1];
    for (i, &ca) in a.coeffs.iter().enumerate() {
        for (j, &cb) in b.coeffs.iter().enumerate() {
            out[i + j] = (out[i + j] + ca * cb) % a.p;
        }
    }
    Poly { p: a.p, coeffs: out }.trim()
}

/// Remainder of a modulo m (m must be nonzero).
pub fn rem(a: &Poly, m: &Poly) -> Poly {
    assert_eq!(a.p, m.p);
    let p = a.p;
    let dm = m.degree().expect("modulus must be nonzero");
    let lead_inv = inv_mod_p(m.coeffs[dm], p);
    let mut r = a.coeffs.clone();
    while r.len() > dm {
        let da = r.len() - 1;
        let factor = (r[da] * lead_inv) % p;
        if factor != 0 {
            let shift = da - dm;
            for (i, &mc) in m.coeffs.iter().enumerate() {
                let sub = (factor * mc) % p;
                r[shift + i] = (r[shift + i] + p - sub) % p;
            }
        }
        while r.last() == Some(&0) {
            r.pop();
        }
        if r.len() <= dm {
            break;
        }
    }
    Poly { p, coeffs: r }.trim()
}

/// Modular product: a*b mod m.
pub fn mul_mod(a: &Poly, b: &Poly, m: &Poly) -> Poly {
    rem(&mul(a, b), m)
}

/// Inverse of a nonzero scalar mod prime p (Fermat).
fn inv_mod_p(a: u64, p: u64) -> u64 {
    // a^(p-2) mod p
    let mut base = a % p;
    let mut exp = p - 2;
    let mut acc = 1u64;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = acc * base % p;
        }
        base = base * base % p;
        exp >>= 1;
    }
    acc
}

/// Brute-force irreducibility: f (monic, degree e >= 1) is irreducible over
/// F_p iff no monic polynomial of degree 1..=e/2 divides it. Fields here are
/// tiny, so enumeration is instant.
pub fn is_irreducible(f: &Poly) -> bool {
    let p = f.p;
    let e = match f.degree() {
        Some(d) if d >= 1 => d,
        _ => return false,
    };
    for d in 1..=e / 2 {
        // enumerate monic polys of degree d: p^d of them
        let count = p.pow(d as u32);
        for id in 0..count {
            let mut g = Poly::from_id(id, p);
            g.coeffs.resize(d + 1, 0);
            g.coeffs[d] = 1; // monic
            if rem(f, &g).coeffs.is_empty() {
                return false;
            }
        }
    }
    true
}

/// Find a monic irreducible polynomial of degree e over F_p by search.
pub fn find_irreducible(p: u64, e: u32) -> Result<Poly> {
    let e = e as usize;
    if e == 1 {
        // x itself: GF(p) with trivial modulus
        return Ok(Poly { p, coeffs: vec![0, 1] });
    }
    let count = p.pow(e as u32);
    for id in 0..count {
        let mut f = Poly::from_id(id, p);
        f.coeffs.resize(e + 1, 0);
        f.coeffs[e] = 1;
        if is_irreducible(&f) {
            return Ok(f);
        }
    }
    bail!("no irreducible polynomial of degree {e} over F_{p}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_roundtrip() {
        for p in [2u64, 3, 5] {
            for id in 0..40 {
                assert_eq!(Poly::from_id(id, p).to_id(), id);
            }
        }
    }

    #[test]
    fn mul_known() {
        // (x+1)^2 over F_2 = x^2 + 1
        let a = Poly { p: 2, coeffs: vec![1, 1] };
        let sq = mul(&a, &a);
        assert_eq!(sq.coeffs, vec![1, 0, 1]);
    }

    #[test]
    fn rem_known() {
        // x^2 mod (x^2 + x + 1) over F_2 = x + 1
        let x2 = Poly { p: 2, coeffs: vec![0, 0, 1] };
        let m = Poly { p: 2, coeffs: vec![1, 1, 1] };
        assert_eq!(rem(&x2, &m).coeffs, vec![1, 1]);
    }

    #[test]
    fn irreducibility_known_cases() {
        // x^2 + x + 1 irreducible over F_2; x^2 + 1 = (x+1)^2 is not.
        assert!(is_irreducible(&Poly { p: 2, coeffs: vec![1, 1, 1] }));
        assert!(!is_irreducible(&Poly { p: 2, coeffs: vec![1, 0, 1] }));
        // x^2 + 1 IS irreducible over F_3 (no root: 0,1,2 -> 1,2,2)
        assert!(is_irreducible(&Poly { p: 3, coeffs: vec![1, 0, 1] }));
    }

    #[test]
    fn find_irreducible_degrees() {
        for (p, e) in [(2u64, 2u32), (2, 3), (2, 4), (3, 2), (3, 4), (5, 2), (7, 2)] {
            let f = find_irreducible(p, e).unwrap();
            assert_eq!(f.degree(), Some(e as usize));
            assert!(is_irreducible(&f));
        }
    }

    #[test]
    fn scalar_inverse() {
        for p in [3u64, 5, 7, 11] {
            for a in 1..p {
                assert_eq!(inv_mod_p(a, p) * a % p, 1);
            }
        }
    }
}
