//! Finite fields GF(p^e) for the spherical Steiner-system construction.
//!
//! The paper's tetrahedral partitions come from Steiner (q²+1, q+1, 3)
//! systems built on the projective line PG(1, q²) (Theorem 3). We therefore
//! need arithmetic in GF(q²) = GF(p^{2e}) for prime powers q = p^e, together
//! with subfield detection (F_q = fixed points of the Frobenius x ↦ x^q).
//!
//! Elements are represented as integers `0..q` encoding polynomial
//! coefficient vectors over F_p in base p. Multiplication uses exp/log
//! tables of a primitive element (found by search against a searched-for
//! irreducible polynomial); addition is digit-wise mod-p. Fields here are
//! tiny (≤ GF(3^4) = 81 elements for q ≤ 9; ≤ GF(13²) for q = 13), so the
//! table-based approach is exact and effectively free.

mod poly;

pub use poly::Poly;

use anyhow::{bail, Result};

/// Factor a prime power `q = p^e`; errors if `q` is not a prime power.
pub fn prime_power(q: u64) -> Result<(u64, u32)> {
    if q < 2 {
        bail!("{q} is not a prime power");
    }
    let mut p = 0u64;
    let mut m = q;
    for d in 2..=q {
        if m % d == 0 {
            p = d;
            while m % d == 0 {
                m /= d;
            }
            break;
        }
    }
    if m != 1 {
        bail!("{q} is not a prime power");
    }
    let mut e = 0u32;
    let mut t = q;
    while t > 1 {
        if t % p != 0 {
            bail!("{q} is not a prime power");
        }
        t /= p;
        e += 1;
    }
    Ok((p, e))
}

/// The finite field GF(p^e) with exp/log multiplication tables.
#[derive(Debug, Clone)]
pub struct Gf {
    /// Characteristic.
    pub p: u64,
    /// Extension degree.
    pub e: u32,
    /// Field order q = p^e.
    pub q: u64,
    /// exp[i] = g^i for a primitive element g, i in 0..q-1.
    exp: Vec<u64>,
    /// log[x] for x in 1..q; log[0] unused.
    log: Vec<u64>,
}

impl Gf {
    /// Construct GF(q) for a prime power q.
    pub fn new(q: u64) -> Result<Gf> {
        let (p, e) = prime_power(q)?;
        let modulus = poly::find_irreducible(p, e)?;
        // Find a primitive element: try g = x (the polynomial t), then other
        // elements, checking multiplicative order == q-1.
        let order = q - 1;
        let mut exp = vec![0u64; order as usize];
        let mut log = vec![0u64; q as usize];
        let mut found = false;
        'cand: for gid in 1..q {
            let g = Poly::from_id(gid, p);
            // accumulate powers
            let mut acc = Poly::one(p);
            let mut seen_one_at = None;
            for i in 0..order {
                exp[i as usize] = acc.to_id();
                if i > 0 && acc.is_one() {
                    seen_one_at = Some(i);
                    break;
                }
                acc = poly::mul_mod(&acc, &g, &modulus);
            }
            if seen_one_at.is_some() || !acc.is_one() {
                // order < q-1 (hit 1 early) or g not invertible cycle; next.
                continue 'cand;
            }
            // fill logs
            for i in 0..order {
                log[exp[i as usize] as usize] = i;
            }
            found = true;
            break;
        }
        if !found {
            bail!("no primitive element found for GF({q})");
        }
        Ok(Gf { p, e, q, exp, log })
    }

    /// Additive identity.
    pub fn zero(&self) -> u64 {
        0
    }

    /// Multiplicative identity.
    pub fn one(&self) -> u64 {
        1
    }

    /// Addition: digit-wise mod p on the base-p encodings.
    pub fn add(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.q && b < self.q);
        let (mut a, mut b) = (a, b);
        let mut out = 0u64;
        let mut mult = 1u64;
        for _ in 0..self.e {
            let d = (a % self.p + b % self.p) % self.p;
            out += d * mult;
            mult *= self.p;
            a /= self.p;
            b /= self.p;
        }
        out
    }

    /// Additive inverse.
    pub fn neg(&self, a: u64) -> u64 {
        debug_assert!(a < self.q);
        let mut a = a;
        let mut out = 0u64;
        let mut mult = 1u64;
        for _ in 0..self.e {
            let d = (self.p - a % self.p) % self.p;
            out += d * mult;
            mult *= self.p;
            a /= self.p;
        }
        out
    }

    /// Subtraction.
    pub fn sub(&self, a: u64, b: u64) -> u64 {
        self.add(a, self.neg(b))
    }

    /// Multiplication via exp/log tables.
    pub fn mul(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.q && b < self.q);
        if a == 0 || b == 0 {
            return 0;
        }
        let la = self.log[a as usize];
        let lb = self.log[b as usize];
        self.exp[((la + lb) % (self.q - 1)) as usize]
    }

    /// Multiplicative inverse (panics on 0).
    pub fn inv(&self, a: u64) -> u64 {
        assert!(a != 0, "division by zero in GF({})", self.q);
        let la = self.log[a as usize];
        self.exp[((self.q - 1 - la) % (self.q - 1)) as usize]
    }

    /// Division a / b.
    pub fn div(&self, a: u64, b: u64) -> u64 {
        self.mul(a, self.inv(b))
    }

    /// Exponentiation a^k.
    pub fn pow(&self, a: u64, k: u64) -> u64 {
        if a == 0 {
            return if k == 0 { 1 } else { 0 };
        }
        let la = self.log[a as usize] as u128;
        let idx = (la * k as u128) % (self.q - 1) as u128;
        self.exp[idx as usize]
    }

    /// A fixed primitive element (generator of the multiplicative group).
    pub fn generator(&self) -> u64 {
        self.exp[1]
    }

    /// All field elements 0..q.
    pub fn elements(&self) -> impl Iterator<Item = u64> {
        0..self.q
    }

    /// The subfield F_{p^d} = {x : x^{p^d} = x} for d dividing e, as element ids.
    pub fn subfield(&self, d: u32) -> Vec<u64> {
        assert!(self.e % d == 0, "subfield degree must divide e");
        let sq = self.p.pow(d);
        self.elements()
            .filter(|&x| self.pow(x, sq) == x)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prime_power_factoring() {
        assert_eq!(prime_power(2).unwrap(), (2, 1));
        assert_eq!(prime_power(9).unwrap(), (3, 2));
        assert_eq!(prime_power(8).unwrap(), (2, 3));
        assert_eq!(prime_power(81).unwrap(), (3, 4));
        assert!(prime_power(6).is_err());
        assert!(prime_power(12).is_err());
        assert!(prime_power(1).is_err());
    }

    fn check_field_axioms(q: u64) {
        let f = Gf::new(q).unwrap();
        assert_eq!(f.q, q);
        // closure + associativity + commutativity + distributivity,
        // exhaustively (fields are tiny).
        let lim = q.min(32); // cap exhaustive triple loop for larger fields
        for a in 0..lim {
            for b in 0..lim {
                assert!(f.add(a, b) < q);
                assert!(f.mul(a, b) < q);
                assert_eq!(f.add(a, b), f.add(b, a));
                assert_eq!(f.mul(a, b), f.mul(b, a));
                assert_eq!(f.add(a, f.neg(a)), 0);
                if a != 0 {
                    assert_eq!(f.mul(a, f.inv(a)), 1, "a={a} q={q}");
                }
                for c in 0..lim {
                    assert_eq!(f.add(f.add(a, b), c), f.add(a, f.add(b, c)));
                    assert_eq!(f.mul(f.mul(a, b), c), f.mul(a, f.mul(b, c)));
                    assert_eq!(
                        f.mul(a, f.add(b, c)),
                        f.add(f.mul(a, b), f.mul(a, c)),
                        "distributivity failed a={a} b={b} c={c} q={q}"
                    );
                }
            }
        }
        // identity laws
        for a in 0..q {
            assert_eq!(f.add(a, 0), a);
            assert_eq!(f.mul(a, 1), a);
            assert_eq!(f.mul(a, 0), 0);
        }
    }

    #[test]
    fn field_axioms_small_fields() {
        for q in [2, 3, 4, 5, 7, 8, 9, 11, 13, 16, 25, 27] {
            check_field_axioms(q);
        }
    }

    #[test]
    fn generator_has_full_order() {
        for q in [4, 8, 9, 16, 25, 49, 64, 81] {
            let f = Gf::new(q).unwrap();
            let g = f.generator();
            let mut seen = std::collections::HashSet::new();
            let mut x = 1u64;
            for _ in 0..(q - 1) {
                assert!(seen.insert(x), "generator order < q-1 for q={q}");
                x = f.mul(x, g);
            }
            assert_eq!(x, 1);
        }
    }

    #[test]
    fn subfield_detection() {
        // GF(4) inside GF(16): 4 elements fixed by x^4.
        let f = Gf::new(16).unwrap();
        let sub = f.subfield(2);
        assert_eq!(sub.len(), 4);
        // closed under add/mul
        for &a in &sub {
            for &b in &sub {
                assert!(sub.contains(&f.add(a, b)));
                assert!(sub.contains(&f.mul(a, b)));
            }
        }
        // GF(3) inside GF(9)
        let f9 = Gf::new(9).unwrap();
        let sub3 = f9.subfield(1);
        assert_eq!(sub3.len(), 3);
        // GF(9) inside GF(81)
        let f81 = Gf::new(81).unwrap();
        assert_eq!(f81.subfield(2).len(), 9);
    }

    #[test]
    fn pow_matches_repeated_mul() {
        let f = Gf::new(27).unwrap();
        for a in 0..27 {
            let mut acc = 1u64;
            for k in 0..30u64 {
                assert_eq!(f.pow(a, k), acc, "a={a} k={k}");
                acc = f.mul(acc, a);
            }
        }
    }

    #[test]
    fn frobenius_is_additive() {
        // x -> x^p is a field automorphism: (a+b)^p = a^p + b^p.
        let f = Gf::new(8).unwrap();
        for a in 0..8 {
            for b in 0..8 {
                assert_eq!(
                    f.pow(f.add(a, b), 2),
                    f.add(f.pow(a, 2), f.pow(b, 2))
                );
            }
        }
    }
}
