//! Bipartite matching machinery used by the partition and schedule layers.
//!
//! * [`hopcroft_karp`] — maximum bipartite matching in O(E·√V); the paper
//!   cites Hopcroft–Karp / Ford–Fulkerson for exactly these constructions.
//! * [`disjoint_matchings`] — Corollary 5: `d` pairwise-disjoint matchings,
//!   each covering every left vertex, found by matching on the graph with
//!   each left vertex cloned `d` times.
//! * [`bipartite_edge_coloring`] — Theorem 6 / König: a Δ-regular bipartite
//!   multigraph decomposes into exactly Δ perfect matchings. Directed
//!   messages form a bipartite (sender × receiver) multigraph; each color
//!   class is one communication step in which every processor sends ≤ 1 and
//!   receives ≤ 1 message — precisely the paper's α-β-γ model constraint.

use anyhow::{bail, Result};
use std::collections::VecDeque;

/// Maximum matching in a bipartite graph given as left-adjacency lists.
///
/// Returns `(size, match_left, match_right)` where `match_left[u]` is the
/// right vertex matched to left vertex `u` (or None).
pub fn hopcroft_karp(
    adj: &[Vec<usize>],
    n_right: usize,
) -> (usize, Vec<Option<usize>>, Vec<Option<usize>>) {
    let n_left = adj.len();
    let mut match_l: Vec<Option<usize>> = vec![None; n_left];
    let mut match_r: Vec<Option<usize>> = vec![None; n_right];
    let mut dist: Vec<u32> = vec![0; n_left];
    let inf = u32::MAX;
    let mut size = 0usize;

    fn try_kuhn(
        u: usize,
        adj: &[Vec<usize>],
        dist: &mut [u32],
        match_l: &mut [Option<usize>],
        match_r: &mut [Option<usize>],
    ) -> bool {
        for i in 0..adj[u].len() {
            let v = adj[u][i];
            match match_r[v] {
                None => {
                    match_l[u] = Some(v);
                    match_r[v] = Some(u);
                    return true;
                }
                Some(u2) => {
                    if dist[u2] == dist[u] + 1 && try_kuhn(u2, adj, dist, match_l, match_r) {
                        match_l[u] = Some(v);
                        match_r[v] = Some(u);
                        return true;
                    }
                }
            }
        }
        dist[u] = u32::MAX; // dead end; prune
        false
    }

    loop {
        // BFS layering from free left vertices
        let mut queue = VecDeque::new();
        for u in 0..n_left {
            if match_l[u].is_none() {
                dist[u] = 0;
                queue.push_back(u);
            } else {
                dist[u] = inf;
            }
        }
        let mut found_augmenting = false;
        while let Some(u) = queue.pop_front() {
            for &v in &adj[u] {
                match match_r[v] {
                    Some(u2) => {
                        if dist[u2] == inf {
                            dist[u2] = dist[u] + 1;
                            queue.push_back(u2);
                        }
                    }
                    None => found_augmenting = true,
                }
            }
        }
        if !found_augmenting {
            break;
        }
        for u in 0..n_left {
            if match_l[u].is_none() && try_kuhn(u, adj, &mut dist, &mut match_l, &mut match_r) {
                size += 1;
            }
        }
    }
    (size, match_l, match_r)
}

/// Corollary 5: find `d` matchings, pairwise disjoint in both edges and
/// right vertices, each covering every left vertex. Implemented by cloning
/// each left vertex `d` times and finding one maximum matching of the
/// expanded graph (Hall's condition `d|W| <= |N(W)|` guarantees a perfect
/// one exists for the graphs we build; we verify success directly).
///
/// Returns `d` vectors, each mapping left vertex -> its right vertex.
pub fn disjoint_matchings(
    adj: &[Vec<usize>],
    n_right: usize,
    d: usize,
) -> Result<Vec<Vec<usize>>> {
    let n_left = adj.len();
    // expanded left vertex (u, clone) = u * d + c
    let expanded: Vec<Vec<usize>> = (0..n_left * d).map(|x| adj[x / d].clone()).collect();
    let (size, match_l, _) = hopcroft_karp(&expanded, n_right);
    if size != n_left * d {
        bail!(
            "no {d} disjoint matchings: matched {size} of {} clones",
            n_left * d
        );
    }
    let mut out = vec![vec![usize::MAX; n_left]; d];
    for u in 0..n_left {
        for c in 0..d {
            out[c][u] = match_l[u * d + c].unwrap();
        }
    }
    Ok(out)
}

/// A bipartite multigraph of directed messages: edge (sender, receiver,
/// payload-id). Senders and receivers are both indexed `0..n`.
#[derive(Debug, Clone)]
pub struct BipartiteMultiGraph {
    pub n: usize,
    pub edges: Vec<(usize, usize, usize)>,
}

/// Decompose the message multigraph into the minimum number of steps such
/// that in each step every vertex sends at most one and receives at most one
/// message (Theorem 6). Pads to a Δ-regular bipartite multigraph with dummy
/// edges (payload `usize::MAX`, dropped from the output), then peels Δ
/// perfect matchings — König's theorem guarantees each peel succeeds.
///
/// Returns, per step, the payload ids scheduled in that step. The number of
/// steps equals the maximum send- or receive-degree Δ.
pub fn bipartite_edge_coloring(graph: &BipartiteMultiGraph) -> Result<Vec<Vec<usize>>> {
    let n = graph.n;
    let mut out_deg = vec![0usize; n];
    let mut in_deg = vec![0usize; n];
    for &(u, v, _) in &graph.edges {
        out_deg[u] += 1;
        in_deg[v] += 1;
    }
    let delta = out_deg
        .iter()
        .chain(in_deg.iter())
        .copied()
        .max()
        .unwrap_or(0);
    if delta == 0 {
        return Ok(vec![]);
    }

    // Pad to Δ-regular: repeatedly connect a send-deficient vertex to a
    // receive-deficient vertex. Total send deficit == total receive deficit,
    // so this always terminates. (A dummy u->u message is harmless: sender
    // side and receiver side are different parts of the bipartition.)
    let mut edges = graph.edges.clone();
    loop {
        let u = (0..n).find(|&u| out_deg[u] < delta);
        let v = (0..n).find(|&v| in_deg[v] < delta);
        match (u, v) {
            (Some(u), Some(v)) => {
                edges.push((u, v, usize::MAX));
                out_deg[u] += 1;
                in_deg[v] += 1;
            }
            (None, None) => break,
            _ => bail!("send/receive deficit mismatch while padding"),
        }
    }

    // Peel Δ perfect matchings. Multigraph handling: deduplicate (u,v) pairs
    // for the matching step, then remove one *edge instance* per matched pair.
    let mut remaining: Vec<(usize, usize, usize)> = edges;
    let mut steps = Vec::with_capacity(delta);
    for round in 0..delta {
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for &(u, v, _) in &remaining {
            if !adj[u].contains(&v) {
                adj[u].push(v);
            }
        }
        let (size, match_l, _) = hopcroft_karp(&adj, n);
        if size != n {
            bail!(
                "König peel failed at round {round}: matched {size}/{n} \
                 (graph not regular?)"
            );
        }
        let mut step = Vec::new();
        for u in 0..n {
            let v = match_l[u].unwrap();
            // remove one instance of (u, v)
            let idx = remaining
                .iter()
                .position(|&(a, b, _)| a == u && b == v)
                .expect("matched edge must exist");
            let (_, _, payload) = remaining.swap_remove(idx);
            if payload != usize::MAX {
                step.push(payload);
            }
        }
        if !step.is_empty() {
            steps.push(step);
        }
    }
    debug_assert!(remaining.is_empty());
    Ok(steps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// brute-force maximum matching by augmenting paths (Kuhn), as oracle
    fn kuhn_oracle(adj: &[Vec<usize>], n_right: usize) -> usize {
        fn aug(u: usize, adj: &[Vec<usize>], seen: &mut [bool], mr: &mut [Option<usize>]) -> bool {
            for &v in &adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    if mr[v].is_none() || aug(mr[v].unwrap(), adj, seen, mr) {
                        mr[v] = Some(u);
                        return true;
                    }
                }
            }
            false
        }
        let mut mr = vec![None; n_right];
        let mut size = 0;
        for u in 0..adj.len() {
            let mut seen = vec![false; n_right];
            if aug(u, adj, &mut seen, &mut mr) {
                size += 1;
            }
        }
        size
    }

    #[test]
    fn hk_matches_oracle_on_random_graphs() {
        let mut rng = Rng::new(11);
        for trial in 0..60 {
            let nl = 1 + rng.below(12);
            let nr = 1 + rng.below(12);
            let adj: Vec<Vec<usize>> = (0..nl)
                .map(|_| {
                    let deg = rng.below(nr + 1);
                    let mut vs: Vec<usize> = (0..nr).collect();
                    rng.shuffle(&mut vs);
                    vs.truncate(deg);
                    vs
                })
                .collect();
            let (size, ml, mr) = hopcroft_karp(&adj, nr);
            assert_eq!(size, kuhn_oracle(&adj, nr), "trial {trial}");
            // consistency of the returned matching
            let mut used_r = vec![false; nr];
            let mut count = 0;
            for u in 0..nl {
                if let Some(v) = ml[u] {
                    assert!(adj[u].contains(&v));
                    assert!(!used_r[v]);
                    used_r[v] = true;
                    assert_eq!(mr[v], Some(u));
                    count += 1;
                }
            }
            assert_eq!(count, size);
        }
    }

    #[test]
    fn hk_perfect_on_complete_bipartite() {
        let n = 8;
        let adj: Vec<Vec<usize>> = (0..n).map(|_| (0..n).collect()).collect();
        let (size, _, _) = hopcroft_karp(&adj, n);
        assert_eq!(size, n);
    }

    #[test]
    fn disjoint_matchings_on_expandable_graph() {
        // The Corollary 5 semantics: each right vertex is used at most once
        // GLOBALLY across the d matchings (this is how non-central diagonal
        // blocks are assigned — every block to exactly one processor). So we
        // need |adj| targets ≥ d per left vertex with enough global slack:
        // left 0..4, rights 0..12, each left sees 6 rights.
        let nl = 4;
        let nr = 12;
        let d = 3;
        let adj: Vec<Vec<usize>> = (0..nl)
            .map(|u| (0..6).map(|k| (3 * u + k) % nr).collect())
            .collect();
        let ms = disjoint_matchings(&adj, nr, d).unwrap();
        assert_eq!(ms.len(), d);
        let mut used_rights = std::collections::HashSet::new();
        for m in &ms {
            assert_eq!(m.len(), nl); // covers every left vertex
            for (u, &v) in m.iter().enumerate() {
                assert!(adj[u].contains(&v));
                assert!(used_rights.insert(v), "right vertex {v} assigned twice");
            }
        }
        assert_eq!(used_rights.len(), nl * d);
    }

    #[test]
    fn disjoint_matchings_fails_when_impossible() {
        let adj = vec![vec![0], vec![0]];
        assert!(disjoint_matchings(&adj, 1, 1).is_err());
    }

    fn check_schedule(n: usize, edges: &[(usize, usize, usize)], steps: &[Vec<usize>]) {
        let mut seen = std::collections::HashSet::new();
        for step in steps {
            let mut sending = vec![false; n];
            let mut receiving = vec![false; n];
            for &payload in step {
                let (u, v, _) = edges[payload];
                assert!(!sending[u], "vertex {u} sends twice in one step");
                assert!(!receiving[v], "vertex {v} receives twice in one step");
                sending[u] = true;
                receiving[v] = true;
                assert!(seen.insert(payload));
            }
        }
        assert_eq!(seen.len(), edges.len(), "not all messages scheduled");
    }

    #[test]
    fn coloring_all_to_all() {
        // complete directed exchange among n: Δ = n-1 steps
        let n = 5;
        let mut edges = vec![];
        for u in 0..n {
            for v in 0..n {
                if u != v {
                    edges.push((u, v, edges.len()));
                }
            }
        }
        let g = BipartiteMultiGraph { n, edges: edges.clone() };
        let steps = bipartite_edge_coloring(&g).unwrap();
        assert_eq!(steps.len(), n - 1);
        check_schedule(n, &edges, &steps);
    }

    #[test]
    fn coloring_symmetric_exchanges() {
        // ring of symmetric exchanges: each vertex sends/receives 2 → 2 steps
        let n = 6;
        let mut edges = vec![];
        for u in 0..n {
            let v = (u + 1) % n;
            edges.push((u, v, edges.len()));
            edges.push((v, u, edges.len()));
        }
        let g = BipartiteMultiGraph { n, edges: edges.clone() };
        let steps = bipartite_edge_coloring(&g).unwrap();
        assert_eq!(steps.len(), 2);
        check_schedule(n, &edges, &steps);
    }

    #[test]
    fn coloring_random_irregular() {
        let mut rng = Rng::new(5);
        for _ in 0..40 {
            let n = 3 + rng.below(10);
            let mut edges = vec![];
            for u in 0..n {
                for v in 0..n {
                    if u != v && rng.next_f64() < 0.35 {
                        edges.push((u, v, edges.len()));
                    }
                }
            }
            if edges.is_empty() {
                continue;
            }
            let mut outd = vec![0usize; n];
            let mut ind = vec![0usize; n];
            for &(u, v, _) in &edges {
                outd[u] += 1;
                ind[v] += 1;
            }
            let delta = outd.iter().chain(ind.iter()).copied().max().unwrap();
            let g = BipartiteMultiGraph { n, edges: edges.clone() };
            let steps = bipartite_edge_coloring(&g).unwrap();
            check_schedule(n, &edges, &steps);
            assert!(steps.len() <= delta, "steps {} > Δ {}", steps.len(), delta);
        }
    }

    #[test]
    fn coloring_handles_parallel_edges() {
        // two parallel messages 0->1 force 2 steps
        let edges = vec![(0, 1, 0), (0, 1, 1)];
        let g = BipartiteMultiGraph { n: 2, edges: edges.clone() };
        let steps = bipartite_edge_coloring(&g).unwrap();
        assert_eq!(steps.len(), 2);
        check_schedule(2, &edges, &steps);
    }
}
