//! Lock-free shared-memory SPSC transport primitives (§Perf P11).
//!
//! One [`SpscRing`] per directed (sender, receiver) pair: a fixed-capacity
//! Lamport ring with cache-line-padded monotonic head/tail counters and
//! payload slots that own preallocated `Vec<f32>` buffers, so a send is a
//! single in-place `memcpy` into the slot — no per-message allocation, no
//! channel, no mutex, no CAS (each index has exactly one writer). The
//! consumer copies the payload out into a pool-drawn buffer and releases
//! the slot immediately, so slots recycle at ring rate and the packet then
//! flows through the same stash/pool machinery as the mpsc oracle.
//!
//! Memory ordering is the classic SPSC argument: the producer publishes a
//! filled slot with a `Release` store of `tail` and the consumer `Acquire`-
//! loads it before reading the slot (and symmetrically `head` for slot
//! reuse), so slot accesses never race — model-checked under loom
//! (`RUSTFLAGS="--cfg loom" cargo test loom`, the CI `rust-loom` job) and
//! raced for real under ThreadSanitizer (`rust-tsan`).
//!
//! Blocked receivers use a spin-then-park strategy via [`ParkCell`]: spin
//! briefly, then announce intent with a parked flag (SeqCst-fenced on both
//! sides — the Dekker handshake below can lose at most one timed park
//! interval, never a message) and `park_timeout`. Producers `unpark` after
//! publishing only when the flag is up, so the uncontended fast path costs
//! one fence + one relaxed load. [`SpinBarrier`] replaces the mutex+condvar
//! `std::sync::Barrier` on the spsc fabric, and [`pin_to_cpu`] optionally
//! pins worker threads for stable cache/NUMA placement (`--pin`).

#[cfg(loom)]
use loom::sync::atomic::AtomicUsize;
#[cfg(not(loom))]
use std::sync::atomic::AtomicUsize;

use std::sync::atomic::Ordering;

/// Slots per ring. The protocols bound simultaneously in-flight messages
/// per ordered pair to ~4 (one gather + one reduce in overlap mode, ≤2 per
/// stepped exchange round, ≤2 per collective instance); 16 leaves slack
/// for a rank racing ahead through back-to-back collectives. A full ring
/// only makes the producer spin — never deadlock, because a receiver
/// blocked in `recv` drains *every* incoming ring into its stash.
pub(crate) const RING_SLOTS: usize = 16;

/// Pad to 128 bytes (two 64-byte lines: adjacent-line prefetchers) so the
/// producer-owned `tail` and consumer-owned `head` never false-share.
#[repr(align(128))]
struct Padded<T>(T);

struct Slot {
    tag: u64,
    data: Vec<f32>,
}

/// Loom-checkable interior mutability for ring slots: the std path is a
/// plain `UnsafeCell` access, the loom path routes through loom's tracked
/// cell so the model checker sees every slot read/write.
#[cfg(not(loom))]
struct SlotCell<T>(std::cell::UnsafeCell<T>);
#[cfg(not(loom))]
impl<T> SlotCell<T> {
    fn new(v: T) -> Self {
        SlotCell(std::cell::UnsafeCell::new(v))
    }
    fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        f(self.0.get())
    }
}
#[cfg(loom)]
struct SlotCell<T>(loom::cell::UnsafeCell<T>);
#[cfg(loom)]
impl<T> SlotCell<T> {
    fn new(v: T) -> Self {
        SlotCell(loom::cell::UnsafeCell::new(v))
    }
    fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        self.0.with_mut(f)
    }
}

/// A single-producer single-consumer ring of owned payload slots.
///
/// `head`/`tail` are monotonically increasing (wrapping) counters masked
/// into the power-of-two slot array: `tail − head` is the queue length,
/// equality means empty, a difference of `slots.len()` means full. The
/// producer alone writes `tail` and slots in `[tail, head+cap)`; the
/// consumer alone writes `head` and reads the slot at `head` — so the only
/// synchronization is one Release/Acquire edge per direction.
pub(crate) struct SpscRing {
    head: Padded<AtomicUsize>,
    tail: Padded<AtomicUsize>,
    slots: Box<[SlotCell<Slot>]>,
    mask: usize,
}

// SAFETY: slots are `UnsafeCell` but every slot index is exclusively owned
// by either the producer (indices in [tail, head+capacity), about to be
// filled) or the consumer (index head, being drained) at any instant; the
// Release store of the counter that transfers a slot happens-after the
// slot write and the Acquire load on the other side happens-before the
// slot read. Exactly one producer and one consumer thread may use a ring.
unsafe impl Sync for SpscRing {}
unsafe impl Send for SpscRing {}

impl SpscRing {
    /// A ring with `slots` capacity (rounded up to a power of two), each
    /// slot's payload buffer preallocated to `slot_words` f32 words.
    /// Larger payloads grow the slot's buffer in place — the growth is
    /// reported once by [`SpscRing::try_push`] and the enlarged capacity
    /// persists, so even an undersized `slot_words` converges to
    /// allocation-free steady state after one lap of the ring.
    pub(crate) fn new(slots: usize, slot_words: usize) -> SpscRing {
        let cap = slots.next_power_of_two();
        SpscRing {
            head: Padded(AtomicUsize::new(0)),
            tail: Padded(AtomicUsize::new(0)),
            slots: (0..cap)
                .map(|_| {
                    SlotCell::new(Slot { tag: 0, data: Vec::with_capacity(slot_words) })
                })
                .collect(),
            mask: cap - 1,
        }
    }

    /// Producer: copy `data` into the next free slot and publish it.
    /// Returns `None` when the ring is full (caller backs off and retries;
    /// the consumer is guaranteed to drain — see [`RING_SLOTS`]), otherwise
    /// `Some(grew)` where `grew` reports that the payload exceeded the
    /// slot's buffer capacity and forced a (one-time) reallocation.
    pub(crate) fn try_push(&self, tag: u64, data: &[f32]) -> Option<bool> {
        let tail = self.tail.0.load(Ordering::Relaxed);
        let head = self.head.0.load(Ordering::Acquire);
        if tail.wrapping_sub(head) == self.slots.len() {
            return None;
        }
        let grew = self.slots[tail & self.mask].with_mut(|p| {
            // SAFETY: this slot is producer-owned until the tail store
            // below publishes it (see the `Sync` rationale).
            let slot = unsafe { &mut *p };
            slot.tag = tag;
            let grew = data.len() > slot.data.capacity();
            slot.data.clear();
            slot.data.extend_from_slice(data);
            grew
        });
        self.tail.0.store(tail.wrapping_add(1), Ordering::Release);
        Some(grew)
    }

    /// Consumer: copy the oldest undelivered payload out and release its
    /// slot. `alloc(len)` supplies the destination buffer (empty, capacity
    /// ≥ `len` — drawn from the receiver's `BufPool` in the simulator).
    pub(crate) fn pop<F>(&self, alloc: F) -> Option<(u64, Vec<f32>)>
    where
        F: FnOnce(usize) -> Vec<f32>,
    {
        let head = self.head.0.load(Ordering::Relaxed);
        let tail = self.tail.0.load(Ordering::Acquire);
        if head == tail {
            return None;
        }
        let out = self.slots[head & self.mask].with_mut(|p| {
            // SAFETY: this slot is consumer-owned until the head store
            // below returns it to the producer.
            let slot = unsafe { &mut *p };
            let mut out = alloc(slot.data.len());
            out.extend_from_slice(&slot.data);
            (slot.tag, out)
        });
        self.head.0.store(head.wrapping_add(1), Ordering::Release);
        Some(out)
    }
}

/// Spin-then-park state for one consumer thread, shared with its P−1
/// producers. The lost-wakeup-free handshake is Dekker-style:
///
/// * consumer: `parked := true` → SeqCst fence → re-scan all rings → park;
/// * producer: publish slot → SeqCst fence → load `parked` → unpark if set.
///
/// The two fences guarantee at least one side observes the other: either
/// the consumer's re-scan sees the published slot, or the producer sees
/// `parked = true` and unparks. `park_timeout` bounds the stall from any
/// spurious miss to one interval as defense in depth.
pub(crate) struct ParkCell {
    thread: std::sync::OnceLock<std::thread::Thread>,
    parked: std::sync::atomic::AtomicBool,
}

impl ParkCell {
    pub(crate) fn new() -> ParkCell {
        ParkCell {
            thread: std::sync::OnceLock::new(),
            parked: std::sync::atomic::AtomicBool::new(false),
        }
    }

    /// Bind this cell to the calling (consumer) thread. Called once per
    /// run before any peer can want to wake it.
    pub(crate) fn register(&self) {
        let _ = self.thread.set(std::thread::current());
    }

    /// Consumer: announce imminent parking. Must re-scan every incoming
    /// ring after this and before [`ParkCell::park`].
    pub(crate) fn announce(&self) {
        self.parked.store(true, Ordering::Relaxed);
        std::sync::atomic::fence(Ordering::SeqCst);
    }

    /// Consumer: withdraw the announcement (a message was found, or the
    /// park returned).
    pub(crate) fn retract(&self) {
        self.parked.store(false, Ordering::Relaxed);
    }

    /// Consumer: block until unparked or `timeout` elapses.
    pub(crate) fn park(timeout: std::time::Duration) {
        std::thread::park_timeout(timeout);
    }

    /// Producer: wake the consumer if (and only if) it announced parking.
    /// Call after publishing to its ring.
    pub(crate) fn wake(&self) {
        std::sync::atomic::fence(Ordering::SeqCst);
        if self.parked.load(Ordering::Relaxed) {
            if let Some(t) = self.thread.get() {
                t.unpark();
            }
        }
    }
}

/// Sense-reversing spin barrier for the spsc fabric: arrival is one
/// `fetch_add`, release is one generation-counter bump — no mutex, no
/// condvar, no syscall on the fast path. Waiters spin briefly then yield,
/// so oversubscribed machines (P threads > cores, e.g. the 2-core CI
/// runner at P = 14) degrade to cooperative scheduling instead of burning
/// full quanta.
pub(crate) struct SpinBarrier {
    count: std::sync::atomic::AtomicUsize,
    generation: std::sync::atomic::AtomicUsize,
    p: usize,
}

impl SpinBarrier {
    pub(crate) fn new(p: usize) -> SpinBarrier {
        SpinBarrier {
            count: std::sync::atomic::AtomicUsize::new(0),
            generation: std::sync::atomic::AtomicUsize::new(0),
            p,
        }
    }

    pub(crate) fn wait(&self) {
        static NEVER: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);
        self.wait_abortable(&NEVER);
    }

    /// [`SpinBarrier::wait`] that also releases once `abort` is raised —
    /// a rank that died mid-protocol will never arrive, and without this
    /// its peers would spin at the step boundary forever. An aborted
    /// exit leaves the arrival count stale; that is fine: the run is
    /// unwinding and the barrier is per-run.
    pub(crate) fn wait_abortable(&self, abort: &std::sync::atomic::AtomicBool) {
        let gen = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.p {
            // Last arriver: reset the counter BEFORE bumping the
            // generation — waiters re-enter only after they observe the
            // bump (Acquire below), which orders the reset before any
            // next-round arrival.
            self.count.store(0, Ordering::Relaxed);
            self.generation.fetch_add(1, Ordering::AcqRel);
            return;
        }
        let mut spins = 0u32;
        while self.generation.load(Ordering::Acquire) == gen {
            if abort.load(Ordering::Acquire) {
                return;
            }
            spins += 1;
            if spins < 256 {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
    }
}

/// Pin the calling thread to `cpu` (modulo the machine's CPU count) via a
/// direct `sched_setaffinity` syscall binding — no libc crate needed. A
/// best-effort no-op on failure and on non-Linux targets.
#[cfg(target_os = "linux")]
pub(crate) fn pin_to_cpu(cpu: usize) {
    // A 1024-bit cpu_set_t, the glibc default width.
    let mut mask = [0u64; 16];
    let bit = cpu % 1024;
    mask[bit / 64] |= 1u64 << (bit % 64);
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    // SAFETY: mask is a valid, live 128-byte buffer; pid 0 = this thread.
    unsafe {
        let _ = sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr());
    }
}

#[cfg(not(target_os = "linux"))]
pub(crate) fn pin_to_cpu(_cpu: usize) {}

/// Real-thread stress tests (loom models the same structures exhaustively
/// in `loom_tests` below; ThreadSanitizer races these in CI).
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn fifo_integrity_through_wraparound_under_contention() {
        // 5000 messages through a 4-slot ring: tags stay in order, every
        // payload arrives intact, and oversized payloads (len > slot_words)
        // grow slots at most once each.
        let ring = Arc::new(SpscRing::new(4, 8));
        let prod = ring.clone();
        let n = 5000u64;
        let producer = std::thread::spawn(move || {
            let mut grew = 0u64;
            for i in 0..n {
                let len = (i % 13 + 1) as usize; // up to 13 > slot_words 8
                let payload = vec![i as f32; len];
                loop {
                    match prod.try_push(i, &payload) {
                        Some(g) => {
                            grew += g as u64;
                            break;
                        }
                        None => std::thread::yield_now(),
                    }
                }
            }
            grew
        });
        let mut next = 0u64;
        while next < n {
            match ring.pop(Vec::with_capacity) {
                Some((tag, data)) => {
                    assert_eq!(tag, next, "out-of-order delivery");
                    assert_eq!(data.len(), (next % 13 + 1) as usize);
                    assert!(data.iter().all(|&v| v == next as f32));
                    next += 1;
                }
                None => std::thread::yield_now(),
            }
        }
        let grew = producer.join().unwrap();
        // 4 slots (4 rounded to a power of two), each grows at most once.
        assert!(grew <= 4, "slot growth must persist, saw {grew} growths");
        assert!(ring.pop(Vec::with_capacity).is_none());
    }

    #[test]
    fn parked_consumer_is_woken_by_publish() {
        let ring = Arc::new(SpscRing::new(4, 4));
        let park = Arc::new(ParkCell::new());
        let (r2, p2) = (ring.clone(), park.clone());
        let consumer = std::thread::spawn(move || {
            p2.register();
            loop {
                p2.announce();
                if let Some((tag, data)) = r2.pop(Vec::with_capacity) {
                    p2.retract();
                    return (tag, data);
                }
                ParkCell::park(std::time::Duration::from_millis(50));
                p2.retract();
            }
        });
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert_eq!(ring.try_push(9, &[3.5, 4.5]), Some(false));
        park.wake();
        let (tag, data) = consumer.join().unwrap();
        assert_eq!((tag, data), (9, vec![3.5, 4.5]));
    }

    #[test]
    fn spin_barrier_synchronizes_generations() {
        let p = 4;
        let rounds = 50;
        let barrier = Arc::new(SpinBarrier::new(p));
        let counter = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..p)
            .map(|_| {
                let (b, c) = (barrier.clone(), counter.clone());
                std::thread::spawn(move || {
                    for round in 0..rounds {
                        c.fetch_add(1, Ordering::SeqCst);
                        b.wait();
                        let seen = c.load(Ordering::SeqCst);
                        assert!(seen >= (round + 1) * p, "round {round}: {seen}");
                        b.wait();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), rounds * p);
    }

    #[test]
    fn pin_to_cpu_is_best_effort() {
        pin_to_cpu(0); // must never crash, even in restricted sandboxes
        pin_to_cpu(usize::MAX); // mask bit wraps into range
    }
}

/// Exhaustive interleaving checks (`RUSTFLAGS="--cfg loom" cargo test
/// loom`; the `rust-loom` CI job injects the test-only `loom` dependency).
#[cfg(all(test, loom))]
mod loom_tests {
    use super::*;

    #[test]
    fn loom_ring_publish_consume_and_wraparound() {
        // 4 messages through a 2-slot ring: every interleaving preserves
        // FIFO order and payload integrity across the wrap, including the
        // full-ring producer backoff.
        loom::model(|| {
            let ring = loom::sync::Arc::new(SpscRing::new(2, 2));
            let prod = ring.clone();
            let t = loom::thread::spawn(move || {
                for i in 0..4u64 {
                    let payload = [i as f32, (i + 1) as f32];
                    while prod.try_push(i, &payload).is_none() {
                        loom::thread::yield_now();
                    }
                }
            });
            let mut next = 0u64;
            while next < 4 {
                match ring.pop(Vec::with_capacity) {
                    Some((tag, data)) => {
                        assert_eq!(tag, next);
                        assert_eq!(data, vec![next as f32, (next + 1) as f32]);
                        next += 1;
                    }
                    None => loom::thread::yield_now(),
                }
            }
            t.join().unwrap();
        });
    }

    #[test]
    fn loom_park_handshake_never_loses_a_wakeup() {
        // The Dekker handshake of the spin-then-park protocol: in every
        // interleaving, either the consumer's post-announce re-scan sees
        // the message, or the producer's post-publish check sees the
        // parked flag (and would unpark). Both missing = a lost wakeup.
        loom::model(|| {
            let ring = loom::sync::Arc::new(SpscRing::new(2, 1));
            let parked = loom::sync::Arc::new(loom::sync::atomic::AtomicBool::new(false));
            let (r2, p2) = (ring.clone(), parked.clone());
            let producer = loom::thread::spawn(move || {
                assert!(r2.try_push(7, &[1.0]).is_some());
                loom::sync::atomic::fence(Ordering::SeqCst);
                p2.load(Ordering::Relaxed) // would this publish unpark?
            });
            parked.store(true, Ordering::Relaxed);
            loom::sync::atomic::fence(Ordering::SeqCst);
            let saw_message = ring.pop(Vec::with_capacity).is_some();
            let would_unpark = producer.join().unwrap();
            assert!(
                saw_message || would_unpark,
                "lost wakeup: consumer would park, producer would not unpark"
            );
        });
    }
}
