//! α-β cost-model evaluation (paper §3.1).
//!
//! The paper's model charges α per message (latency) and β per word
//! (bandwidth). The simulator counts both exactly; this module turns those
//! counts, plus a schedule's step structure, into modeled times so the
//! point-to-point vs All-to-All trade-off can be quantified: p2p moves
//! fewer words **and** uses fewer steps (q³/2+3q²/2−1 < P−1 for q ≥ 2),
//! so it wins on both axes — the ablation bench demonstrates this.

use super::CommStats;

/// Machine parameters for the α-β model (times in seconds).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Per-word transfer time (seconds/word).
    pub beta: f64,
}

impl CostModel {
    /// A typical HPC-interconnect operating point: ~1 µs latency,
    /// ~10 GB/s per-link bandwidth at 4-byte words.
    pub fn typical() -> CostModel {
        CostModel {
            alpha: 1e-6,
            beta: 4.0 / 10e9,
        }
    }

    /// Modeled communication time for a processor executing a stepped
    /// schedule: since sends/receives within a step overlap (the model
    /// allows one of each concurrently), the time is
    /// `steps·α + max(sent, recv)·β` — latency per step plus the
    /// bandwidth-bound word stream.
    pub fn time(&self, stats: &CommStats, steps: usize) -> f64 {
        self.alpha * steps as f64 + self.beta * stats.sent_words.max(stats.recv_words) as f64
    }

    /// Bandwidth-only component (the quantity Theorem 1 bounds).
    pub fn bandwidth_time(&self, stats: &CommStats) -> f64 {
        self.beta * stats.sent_words.max(stats.recv_words) as f64
    }

    /// Latency-only component.
    pub fn latency_time(&self, steps: usize) -> f64 {
        self.alpha * steps as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(sent: u64, recv: u64) -> CommStats {
        CommStats {
            sent_words: sent,
            recv_words: recv,
            sent_msgs: 0,
            recv_msgs: 0,
        }
    }

    #[test]
    fn time_combines_components() {
        let m = CostModel {
            alpha: 1.0,
            beta: 0.5,
        };
        let t = m.time(&stats(10, 8), 3);
        assert!((t - (3.0 + 5.0)).abs() < 1e-12);
        assert!((m.latency_time(3) - 3.0).abs() < 1e-12);
        assert!((m.bandwidth_time(&stats(10, 8)) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn typical_is_latency_dominated_for_tiny_messages() {
        let m = CostModel::typical();
        // 100 words over 10 steps: latency 10 µs >> bandwidth 40 ns
        assert!(m.latency_time(10) > 100.0 * m.beta);
    }
}
