//! α-β cost-model evaluation (paper §3.1).
//!
//! The paper's model charges α per message (latency) and β per word
//! (bandwidth). The simulator counts both exactly; this module turns those
//! counts, plus a schedule's step structure, into modeled times so the
//! point-to-point vs All-to-All trade-off can be quantified: p2p moves
//! fewer words **and** uses fewer steps (q³/2+3q²/2−1 < P−1 for q ≥ 2),
//! so it wins on both axes — the ablation bench demonstrates this.
//!
//! β is priced **per byte**, not per 4-byte word (§Perf P14): with the
//! bf16 wire format a word travels as 2 bytes, so pricing the byte
//! counters keeps predictions honest while the paper-model word counts
//! stay untouched. At the f32 wire the two accountings coincide
//! (`bytes = 4·words`).
//!
//! ABFT needs no special case here: the Fletcher-32 integrity word that
//! `--abft` appends to each sweep payload is billed through the ordinary
//! counters (+1 word, +wire-width bytes per message — §Rob P15), so the
//! same α/β evaluation prices protected and unprotected runs alike.

use super::CommStats;

/// Machine parameters for the α-β model (times in seconds).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Per-byte transfer time (seconds/byte). The simulator's
    /// [`CommStats`] byte counters already reflect the run's wire format,
    /// so this single constant prices f32 and bf16 traffic alike.
    pub beta: f64,
}

impl CostModel {
    /// A typical HPC-interconnect operating point: ~1 µs latency,
    /// ~10 GB/s per-link bandwidth (β = 0.1 ns/byte).
    pub fn typical() -> CostModel {
        CostModel {
            alpha: 1e-6,
            beta: 1.0 / 10e9,
        }
    }

    /// Modeled communication time for a processor executing a stepped
    /// schedule: since sends/receives within a step overlap (the model
    /// allows one of each concurrently), the time is
    /// `steps·α + max(sent, recv)·β` — latency per step plus the
    /// bandwidth-bound byte stream.
    pub fn time(&self, stats: &CommStats, steps: usize) -> f64 {
        self.alpha * steps as f64 + self.bandwidth_time(stats)
    }

    /// Bandwidth-only component (the quantity Theorem 1 bounds, priced at
    /// the measured wire bytes).
    pub fn bandwidth_time(&self, stats: &CommStats) -> f64 {
        self.beta * stats.sent_bytes.max(stats.recv_bytes) as f64
    }

    /// Latency-only component.
    pub fn latency_time(&self, steps: usize) -> f64 {
        self.alpha * steps as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(sent: u64, recv: u64) -> CommStats {
        CommStats {
            sent_words: sent,
            recv_words: recv,
            sent_bytes: 4 * sent,
            recv_bytes: 4 * recv,
            sent_msgs: 0,
            recv_msgs: 0,
        }
    }

    #[test]
    fn time_combines_components() {
        let m = CostModel {
            alpha: 1.0,
            beta: 0.125,
        };
        // 10 sent words = 40 bytes at the f32 wire → 40 · 0.125 = 5.0.
        let t = m.time(&stats(10, 8), 3);
        assert!((t - (3.0 + 5.0)).abs() < 1e-12);
        assert!((m.latency_time(3) - 3.0).abs() < 1e-12);
        assert!((m.bandwidth_time(&stats(10, 8)) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn bf16_bytes_halve_bandwidth_time() {
        let m = CostModel::typical();
        let f32_wire = stats(100, 100);
        let mut bf16_wire = f32_wire;
        bf16_wire.sent_bytes /= 2;
        bf16_wire.recv_bytes /= 2;
        assert!(
            (m.bandwidth_time(&f32_wire) - 2.0 * m.bandwidth_time(&bf16_wire)).abs() < 1e-18,
            "same words, half the bytes, half the modeled bandwidth time"
        );
    }

    #[test]
    fn typical_is_latency_dominated_for_tiny_messages() {
        let m = CostModel::typical();
        // 100 words (400 bytes) over 10 steps: latency 10 µs >> bandwidth 40 ns
        assert!(m.latency_time(10) > 400.0 * m.beta);
    }
}
