//! The instrumented α-β-γ machine (paper §3.1).
//!
//! P virtual processors run as OS threads with private state and
//! communicate *only* by message passing through per-processor mailboxes.
//! Every send/receive is counted in words (f32 elements) and messages —
//! exactly the quantities the paper's lower bound constrains. A shared
//! barrier lets algorithms execute stepped schedules, enforcing the model's
//! "one send and one receive per step" discipline (which the schedule
//! itself guarantees by construction; validation happens in `schedule`).
//!
//! This simulator is the faithful substitute for a physical MPI cluster:
//! the paper's claims are word counts per processor in an abstract model,
//! and the simulator measures them exactly (see DESIGN.md §5).

pub mod cost;

use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::{mpsc, Arc, Barrier, Mutex};

/// Per-processor communication counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CommStats {
    /// f32 words sent / received (payload only — the bandwidth cost β·W).
    pub sent_words: u64,
    pub recv_words: u64,
    /// messages sent / received (the latency cost α·S).
    pub sent_msgs: u64,
    pub recv_msgs: u64,
}

impl CommStats {
    /// Total words moved through this processor's NIC.
    pub fn total_words(&self) -> u64 {
        self.sent_words + self.recv_words
    }
}

struct Packet {
    from: usize,
    tag: u64,
    data: Vec<f32>,
}

/// A processor's communication endpoint inside [`run`].
pub struct Comm {
    /// This processor's rank in 0..P.
    pub rank: usize,
    /// Total number of processors.
    pub p: usize,
    senders: Vec<mpsc::Sender<Packet>>,
    inbox: mpsc::Receiver<Packet>,
    /// Out-of-order buffer: packets received while waiting for another tag.
    stash: HashMap<(usize, u64), Vec<f32>>,
    barrier: Arc<Barrier>,
    /// Word/message counters for this processor.
    pub stats: CommStats,
}

impl Comm {
    /// Send `data` to processor `to` with a matching `tag`.
    pub fn send(&mut self, to: usize, tag: u64, data: Vec<f32>) -> Result<()> {
        debug_assert_ne!(to, self.rank, "self-send is a bug in the algorithm");
        self.stats.sent_words += data.len() as u64;
        self.stats.sent_msgs += 1;
        self.senders[to]
            .send(Packet {
                from: self.rank,
                tag,
                data,
            })
            .map_err(|_| anyhow!("processor {to} hung up"))
    }

    /// Blocking receive of the message from `from` with `tag` (out-of-order
    /// deliveries are stashed).
    pub fn recv(&mut self, from: usize, tag: u64) -> Result<Vec<f32>> {
        if let Some(data) = self.stash.remove(&(from, tag)) {
            self.stats.recv_words += data.len() as u64;
            self.stats.recv_msgs += 1;
            return Ok(data);
        }
        loop {
            let pkt = self
                .inbox
                .recv()
                .map_err(|_| anyhow!("inbox closed while waiting for {from}:{tag}"))?;
            if pkt.from == from && pkt.tag == tag {
                self.stats.recv_words += pkt.data.len() as u64;
                self.stats.recv_msgs += 1;
                return Ok(pkt.data);
            }
            self.stash.insert((pkt.from, pkt.tag), pkt.data);
        }
    }

    /// Synchronize all processors (end of a schedule step).
    pub fn barrier(&self) {
        self.barrier.wait();
    }
}

/// Run `body` on P simulated processors; returns the per-rank results in
/// rank order. Any processor error aborts the run.
pub fn run<R, F>(p: usize, body: F) -> Result<Vec<R>>
where
    R: Send,
    F: Fn(&mut Comm) -> Result<R> + Send + Sync,
{
    assert!(p >= 1);
    let mut senders = Vec::with_capacity(p);
    let mut inboxes = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = mpsc::channel::<Packet>();
        senders.push(tx);
        inboxes.push(Some(rx));
    }
    let barrier = Arc::new(Barrier::new(p));
    let results: Vec<Mutex<Option<Result<R>>>> = (0..p).map(|_| Mutex::new(None)).collect();
    let body = &body;

    std::thread::scope(|scope| {
        for (rank, inbox) in inboxes.iter_mut().enumerate() {
            let senders = senders.clone();
            let barrier = barrier.clone();
            let inbox = inbox.take().unwrap();
            let slot = &results[rank];
            scope.spawn(move || {
                let mut comm = Comm {
                    rank,
                    p,
                    senders,
                    inbox,
                    stash: HashMap::new(),
                    barrier,
                    stats: CommStats::default(),
                };
                let out = body(&mut comm);
                *slot.lock().unwrap() = Some(out);
            });
        }
    });

    results
        .into_iter()
        .enumerate()
        .map(|(rank, slot)| {
            slot.into_inner()
                .unwrap()
                .ok_or_else(|| anyhow!("processor {rank} produced no result"))?
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass_counts_words() {
        // each rank sends 10 words to (rank+1) % p
        let p = 6;
        let out = run(p, |comm| {
            let to = (comm.rank + 1) % comm.p;
            let from = (comm.rank + comm.p - 1) % comm.p;
            comm.send(to, 0, vec![comm.rank as f32; 10])?;
            let got = comm.recv(from, 0)?;
            assert_eq!(got, vec![from as f32; 10]);
            Ok(comm.stats)
        })
        .unwrap();
        for s in out {
            assert_eq!(s.sent_words, 10);
            assert_eq!(s.recv_words, 10);
            assert_eq!(s.sent_msgs, 1);
            assert_eq!(s.recv_msgs, 1);
        }
    }

    #[test]
    fn out_of_order_tags_are_stashed() {
        let out = run(2, |comm| {
            if comm.rank == 0 {
                comm.send(1, 7, vec![7.0])?;
                comm.send(1, 8, vec![8.0])?;
                Ok(0.0)
            } else {
                // receive in reverse order
                let b = comm.recv(0, 8)?;
                let a = comm.recv(0, 7)?;
                Ok(a[0] * 10.0 + b[0])
            }
        })
        .unwrap();
        assert_eq!(out[1], 78.0);
    }

    #[test]
    fn barrier_synchronizes_steps() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = AtomicUsize::new(0);
        let p = 4;
        run(p, |comm| {
            for step in 0..3 {
                counter.fetch_add(1, Ordering::SeqCst);
                comm.barrier();
                // after the barrier, all p increments of this step happened
                let c = counter.load(Ordering::SeqCst);
                assert!(c >= (step + 1) * p, "step {step}: {c}");
                comm.barrier();
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 3 * p);
    }

    #[test]
    fn allreduce_sum_pattern() {
        // naive allreduce: everyone sends to 0, 0 broadcasts
        let p = 5;
        let out = run(p, |comm| {
            if comm.rank == 0 {
                let mut acc = 1.0; // own value
                for r in 1..comm.p {
                    acc += comm.recv(r, 1)?[0];
                }
                for r in 1..comm.p {
                    comm.send(r, 2, vec![acc])?;
                }
                Ok(acc)
            } else {
                comm.send(0, 1, vec![1.0])?;
                Ok(comm.recv(0, 2)?[0])
            }
        })
        .unwrap();
        assert!(out.iter().all(|&v| v == p as f32));
    }
}
