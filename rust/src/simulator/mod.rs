//! The instrumented α-β-γ machine (paper §3.1).
//!
//! P virtual processors run as OS threads with private state and
//! communicate *only* by message passing through per-processor endpoints.
//! Every send/receive is counted in words (f32 elements) and messages —
//! exactly the quantities the paper's lower bound constrains. A shared
//! barrier lets algorithms execute stepped schedules, enforcing the model's
//! "one send and one receive per step" discipline (which the schedule
//! itself guarantees by construction; validation happens in `schedule`).
//!
//! This simulator is the faithful substitute for a physical MPI cluster:
//! the paper's claims are word counts per processor in an abstract model,
//! and the simulator measures them exactly (see DESIGN.md §5).
//!
//! **Transports** (§Perf P11): the endpoint sits behind a private
//! [`Transport`] trait with two interchangeable backends selected by
//! [`RunCfg`] / [`TransportKind`]:
//!
//! * [`TransportKind::Mpsc`] — `std::sync::mpsc` channels, one mailbox per
//!   processor. Simple and deterministic: the **counting oracle** every
//!   other backend is validated against.
//! * [`TransportKind::Spsc`] — lock-free shared-memory rings (`spsc`
//!   module), one fixed-capacity ring per *directed* processor pair with
//!   cache-line-padded atomic head/tail counters. Sends copy the payload
//!   straight into a preallocated ring slot (no channel, no mutex, no
//!   per-message allocation once slots and pools are warm), receivers
//!   spin-then-park, and [`RunCfg::pin_threads`] optionally pins workers
//!   to CPUs. This is the hardware-speed path benchmarked by E15
//!   (`make bench-hw`), which fits real α/β constants against the charged
//!   [`CommStats`].
//!
//! Both backends share the counters, the stash, the [`BufPool`] machinery
//! and the collectives, so per-processor words, messages, and charged
//! mults are bitwise identical across backends (property P11). Both also
//! fail fast when every peer has exited while a receive is still blocked
//! (`SttsvError::PeersGone` — formerly spsc-only; the mpsc oracle used to
//! block forever).
//!
//! **Failure semantics** (§Rob): blocking waits are never unbounded when
//! something is wrong. A [`RunCfg::recv_timeout`] watchdog turns a
//! stuck-but-alive peer into [`SttsvError::Timeout`]; the cooperative
//! abort protocol ([`RunCtl`]) unwinds every healthy rank within one tick
//! once any rank fails; worker panics are contained and typed
//! ([`SttsvError::Panicked`]); and a failed run returns a structured
//! [`FailureReport`] (root-cause rank, phase, per-rank counters,
//! in-flight words) instead of a hang, a panic, or a bare string. The
//! seeded [`FaultPlan`] / chaos decorator (the `chaos` module) injects
//! delays, transient faults, and rank crashes underneath the trait for
//! property P13 and bench E17.
//!
//! Two communication APIs share the counters (§Perf P8):
//!
//! * **Blocking** ([`Comm::send`] / [`Comm::recv`]) — the original stepped
//!   API. `send` hands off an owned `Vec<f32>`; `recv` returns a buffer
//!   drawn from the processor's [`BufPool`] and adopts the in-flight
//!   buffer back into it, so repeated blocking receives are also
//!   allocation-free at steady state.
//! * **Nonblocking, buffer-reusing** ([`Comm::isend`], [`Comm::try_recv`],
//!   [`Comm::recv_any`], [`Comm::recv_into`]) — the MPI
//!   `Isend`/`Iprobe`/`Recv`-into-registered-buffer shape. `isend` copies
//!   the borrowed payload into a buffer drawn from a per-processor
//!   [`BufPool`] (or, on spsc, straight into the ring slot); the receiver
//!   delivers into a caller slice and adopts the in-flight buffer into its
//!   own pool (ownership migrates with the message — since every protocol
//!   here sends and receives the same number of messages per processor,
//!   pools stay balanced and the steady state performs **zero per-message
//!   heap allocations**, with no return-channel race against early worker
//!   teardown). Word/message accounting is identical to the blocking API
//!   (asserted in tests).
//!
//! **Collectives** (§Perf P9): [`Comm::allreduce_sum`] /
//! [`Comm::allreduce_scalar`] implement recursive-doubling allreduce over
//! the same counted fabric — O(log P) messages of `width` words per
//! processor, closed form in [`allreduce_stats`]. Results are *bitwise
//! identical on every rank* (each rank combines the same operand tree, and
//! f32 addition is commutative), which is what lets resident solver
//! sessions take the converge-or-continue branch unanimously with no host
//! round trip. Collective tags live above [`TAG_COLL_BASE`] and are
//! sequence-numbered per processor, so they never collide with algorithm
//! traffic; the class-filtered polling variants ([`Comm::try_recv_class`]
//! / [`Comm::recv_any_class`], keyed by [`TagClass`] ready-queues so a
//! poll is O(1) however deep the stash) let an event-loop worker drain its
//! own messages while a faster peer's collective traffic waits stashed.
//!
//! **Wire formats** (§Perf P14): [`RunCfg::wire`] selects the physical
//! encoding of sweep payloads. [`WireFormat::F32`] (default) ships words
//! verbatim; [`WireFormat::Bf16`] rounds each f32 to bfloat16
//! (round-to-nearest-even on the upper 16 bits) on `isend` and expands
//! back to f32 in `recv_into`, two halves per f32 container — accumulation
//! stays f32 everywhere. Per-proc words and messages are **unchanged**
//! (they count logical elements, the paper's model quantity); only
//! [`CommStats`] byte counters see the 2-byte width, exactly halving
//! measured payload bytes. Collective tags (≥ [`TAG_COLL_BASE`]) are
//! exempt: rank-bitwise-deterministic reductions require exact sums, so
//! collective traffic always travels f32.

mod chaos;
pub mod cost;
mod spsc;

pub use chaos::{FaultPlan, MemChaos};

use anyhow::{anyhow, ensure, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Per-processor communication counters.
///
/// Words are the paper's model quantity (one word = one logical f32
/// element, whatever its on-the-wire encoding); bytes are the measured
/// physical payload under the run's [`WireFormat`] — `4·words` at f32,
/// `2·words` for bf16-packed sweep traffic. Words and messages are
/// wire-format-invariant by construction (property P14); bytes are what
/// a per-byte β prices ([`cost::CostModel`]).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CommStats {
    /// Logical f32 words sent / received (payload only — the model's
    /// bandwidth cost β·W; independent of the wire format).
    pub sent_words: u64,
    pub recv_words: u64,
    /// Physical payload bytes sent / received under the run's
    /// [`WireFormat`] (excludes the half-container padding of an
    /// odd-length bf16 payload: bytes = words × bytes-per-word exactly).
    pub sent_bytes: u64,
    pub recv_bytes: u64,
    /// messages sent / received (the latency cost α·S).
    pub sent_msgs: u64,
    pub recv_msgs: u64,
}

impl CommStats {
    /// Total words moved through this processor's NIC.
    pub fn total_words(&self) -> u64 {
        self.sent_words + self.recv_words
    }

    /// Accumulate another counter set into this one — THE aggregation
    /// primitive (iteration totals, bench sums); replaces the hand-rolled
    /// four-field loops that used to live in `apps` and the benches.
    pub fn absorb(&mut self, other: &CommStats) {
        self.sent_words += other.sent_words;
        self.recv_words += other.recv_words;
        self.sent_bytes += other.sent_bytes;
        self.recv_bytes += other.recv_bytes;
        self.sent_msgs += other.sent_msgs;
        self.recv_msgs += other.recv_msgs;
    }

    /// Counter delta since an earlier snapshot of the same processor's
    /// stats (used for per-iteration accounting in resident sessions).
    pub fn since(&self, earlier: &CommStats) -> CommStats {
        CommStats {
            sent_words: self.sent_words - earlier.sent_words,
            recv_words: self.recv_words - earlier.recv_words,
            sent_bytes: self.sent_bytes - earlier.sent_bytes,
            recv_bytes: self.recv_bytes - earlier.recv_bytes,
            sent_msgs: self.sent_msgs - earlier.sent_msgs,
            recv_msgs: self.recv_msgs - earlier.recv_msgs,
        }
    }

    /// Attribute this counter set — one r-deep batched sweep — to a single
    /// query of the batch. Words divide **exactly** (r-deep packing scales
    /// every payload by r and nothing else; the caller's r must be the
    /// batch depth, debug-asserted); message counts are r-independent, so
    /// a query's share of the latency cost is fractional. This is the
    /// serving layer's per-query billing primitive: coalescing r queries
    /// leaves each query's word bill unchanged and cuts its message bill
    /// by r.
    pub fn per_query(&self, r: usize) -> QueryCommShare {
        let r64 = r as u64;
        debug_assert!(r >= 1);
        debug_assert_eq!(self.sent_words % r64, 0, "words not r-deep");
        debug_assert_eq!(self.recv_words % r64, 0, "words not r-deep");
        debug_assert_eq!(self.sent_bytes % r64, 0, "bytes not r-deep");
        debug_assert_eq!(self.recv_bytes % r64, 0, "bytes not r-deep");
        QueryCommShare {
            sent_words: self.sent_words / r64,
            recv_words: self.recv_words / r64,
            sent_bytes: self.sent_bytes / r64,
            recv_bytes: self.recv_bytes / r64,
            sent_msgs: self.sent_msgs as f64 / r as f64,
            recv_msgs: self.recv_msgs as f64 / r as f64,
        }
    }
}

/// One query's share of an r-deep batch's communication
/// ([`CommStats::per_query`]): exact words and bytes, amortized
/// (fractional) messages.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct QueryCommShare {
    pub sent_words: u64,
    pub recv_words: u64,
    pub sent_bytes: u64,
    pub recv_bytes: u64,
    pub sent_msgs: f64,
    pub recv_msgs: f64,
}

/// Collective tags live at and above this value; all point-to-point
/// algorithm traffic (stepped exchange tags, overlap gather/reduce tags)
/// stays below it, so the [`TagClass`] of a tag cleanly separates the two
/// streams for the class-filtered polling APIs.
pub const TAG_COLL_BASE: u64 = 1 << 32;

/// The two disjoint tag streams (plus the union), used to key the ready
/// queues that make polling O(1) — see [`Comm::try_recv_class`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TagClass {
    /// Any message at all.
    Any,
    /// Algorithm traffic: `tag < TAG_COLL_BASE` (stepped exchange tags,
    /// overlap gather/reduce tags).
    Sweep,
    /// Collective traffic: `tag >= TAG_COLL_BASE` (sequence-numbered
    /// allreduce instances).
    Collective,
}

impl TagClass {
    /// The class a concrete tag belongs to (never `Any`).
    pub fn of(tag: u64) -> TagClass {
        if tag < TAG_COLL_BASE {
            TagClass::Sweep
        } else {
            TagClass::Collective
        }
    }

    /// Whether `tag` falls in this class.
    pub fn matches(self, tag: u64) -> bool {
        match self {
            TagClass::Any => true,
            TagClass::Sweep => tag < TAG_COLL_BASE,
            TagClass::Collective => tag >= TAG_COLL_BASE,
        }
    }
}

/// On-the-wire element encoding for SWEEP payloads (§Perf P14).
///
/// The model counts **words** (logical f32 elements) — those never change.
/// `Bf16` packs sweep-class payloads ([`TagClass::Sweep`]) to 16-bit
/// brain-float halves on [`Comm::isend`] and expands them back to f32 in
/// [`Comm::recv_into`], halving the measured payload **bytes** per message
/// while leaving per-processor words and messages exactly the closed-form
/// counts. Accumulation stays f32 everywhere — only the wire narrows.
/// Collective traffic ([`TagClass::Collective`], the convergence
/// allreduces) always travels f32: its O(log P) words are latency-, not
/// bandwidth-bound, and the resident sessions' bitwise rank-determinism
/// depends on exact sums. The blocking [`Comm::send`] / [`Comm::recv`]
/// pair never packs (no protocol on the sweep path uses it; asserted in
/// debug builds).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WireFormat {
    /// 4 bytes per word — the identity encoding (and the bitwise oracle).
    #[default]
    F32,
    /// 2 bytes per word on sweep traffic: round-to-nearest-even bf16
    /// (upper 16 bits of the f32), relative error ≤ 2⁻⁸ per entry.
    Bf16,
}

impl WireFormat {
    /// Does a message with this `tag` get packed under this format?
    pub fn packs(self, tag: u64) -> bool {
        self == WireFormat::Bf16 && TagClass::of(tag) == TagClass::Sweep
    }

    /// Measured payload bytes per logical word for a message with `tag`
    /// (the half-container padding of an odd-length bf16 payload is
    /// excluded: bytes = words × this, exactly).
    pub fn bytes_per_word(self, tag: u64) -> u64 {
        if self.packs(tag) {
            2
        } else {
            4
        }
    }
}

impl std::str::FromStr for WireFormat {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(WireFormat::F32),
            "bf16" => Ok(WireFormat::Bf16),
            other => Err(anyhow!("unknown wire format '{other}' (expected f32|bf16)")),
        }
    }
}

impl std::fmt::Display for WireFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            WireFormat::F32 => "f32",
            WireFormat::Bf16 => "bf16",
        })
    }
}

/// Algorithm-based fault tolerance mode (§Rob, `ExecOpts::abft`, CLI
/// `--abft off|verify|scrub`).
///
/// When on, two independent detectors guard every sweep:
///
/// * **wire**: every sweep-class [`Comm::isend`] appends one Fletcher-32
///   integrity word over the final wire containers (after bf16 packing,
///   so it covers both formats bit for bit); [`Comm::recv_into`] verifies
///   and strips it, surfacing a mismatch as [`SttsvError::Corrupt`]. The
///   word is billed like payload: +1 word and +`bytes_per_word` bytes per
///   sweep message, a closed form the plan's expected counters carry.
/// * **compute**: after contracting a block, the worker checks the
///   block's contribution sum against the quadratic form `xᵀC_b x` of a
///   plan-built per-block checksum matrix, within a γ-style fp tolerance.
///
/// `Verify` turns a detection into a typed failure; `Scrub` first
/// recomputes the offending block's run-descriptor stream (bitwise
/// deterministic) and only fails if the mismatch persists. Collective
/// traffic is exempt (its bitwise rank-determinism is itself a guard).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AbftMode {
    /// No checksums, no integrity words — the zero-overhead baseline.
    #[default]
    Off,
    /// Detect and fail typed ([`SttsvError::Corrupt`]).
    Verify,
    /// Detect, recompute the offending block, then fail only if the
    /// corruption survives recomputation (memory, not transient).
    Scrub,
}

impl AbftMode {
    /// Is any ABFT machinery active?
    pub fn on(self) -> bool {
        self != AbftMode::Off
    }

    /// Does a message with this `tag` carry the integrity word? (Sweep
    /// class only — collectives stay exactly the [`allreduce_stats`]
    /// closed form.)
    pub fn frames(self, tag: u64) -> bool {
        self.on() && TagClass::of(tag) == TagClass::Sweep
    }
}

impl std::str::FromStr for AbftMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(AbftMode::Off),
            "verify" => Ok(AbftMode::Verify),
            "scrub" => Ok(AbftMode::Scrub),
            other => Err(anyhow!("unknown abft mode '{other}' (expected off|verify|scrub)")),
        }
    }
}

impl std::fmt::Display for AbftMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AbftMode::Off => "off",
            AbftMode::Verify => "verify",
            AbftMode::Scrub => "scrub",
        })
    }
}

/// Fletcher-32 over the raw bits of f32 wire containers (two 16-bit
/// halves per container, running sums mod 65535). Any single flipped bit
/// in the payload — or in the checksum word itself — changes one half by
/// ±2^k with 0 ≤ k < 16, which is never ≡ 0 (mod 65535), so single-bit
/// detection is exact, independent of the wire format (the containers are
/// hashed *after* bf16 packing).
pub fn fletcher32(containers: &[f32]) -> u32 {
    let (mut s1, mut s2) = (0u32, 0u32);
    for v in containers {
        let bits = v.to_bits();
        for half in [bits & 0xffff, bits >> 16] {
            s1 = (s1 + half) % 65535;
            s2 = (s2 + s1) % 65535;
        }
    }
    (s2 << 16) | s1
}

/// bf16 encoding of one f32: round-to-nearest-even into the upper 16
/// bits. NaNs keep a quiet mantissa bit so they stay NaN after the
/// round-trip.
#[inline]
pub fn bf16_bits(v: f32) -> u16 {
    let x = v.to_bits();
    if x & 0x7fff_ffff > 0x7f80_0000 {
        return ((x >> 16) | 0x0040) as u16;
    }
    let round = 0x7fff + ((x >> 16) & 1);
    ((x.wrapping_add(round)) >> 16) as u16
}

/// The f32 a bf16 half expands to (exact: bf16 ⊂ f32).
#[inline]
pub fn bf16_expand(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

/// Pack `src` into bf16 halves, two per f32 container slot (the transport
/// fabric moves `Vec<f32>`); an odd trailing element leaves the upper
/// half of the last container zero. `dst` is a pool-drawn staging buffer.
fn pack_bf16(src: &[f32], dst: &mut Vec<f32>) {
    dst.clear();
    for pair in src.chunks(2) {
        let lo = bf16_bits(pair[0]) as u32;
        let hi = pair.get(1).map_or(0, |&v| bf16_bits(v) as u32);
        dst.push(f32::from_bits(lo | (hi << 16)));
    }
}

/// Expand a bf16-packed payload back into `dst.len()` f32 words.
fn unpack_bf16(src: &[f32], dst: &mut [f32]) {
    for (i, d) in dst.iter_mut().enumerate() {
        let w = src[i / 2].to_bits();
        let half = if i % 2 == 0 { w & 0xffff } else { w >> 16 };
        *d = bf16_expand(half as u16);
    }
}

/// Typed failure taxonomy of the fault-tolerance layer (§Rob). Every
/// fault a transport, the chaos wrapper, or the abort protocol can
/// surface travels through the `anyhow` chain as one of these variants,
/// so callers (the run-level [`FailureReport`] assembly, the session
/// retry loop, the serve layer's breaker) branch on *kind* with
/// `downcast_ref` instead of string-matching rendered messages.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SttsvError {
    /// The chaos plan's crash event killed this rank at its `at_op`-th
    /// fallible transport operation; every later op fails the same way.
    Crashed { rank: usize, at_op: u64 },
    /// A transient (retryable) fault injected on one send or receive.
    Transient { op: &'static str, rank: usize },
    /// A targeted receive outwaited the watchdog
    /// ([`RunCfg::recv_timeout`]) for a specific peer message.
    Timeout { from: usize, tag: u64 },
    /// A blocking wait with no specific peer key (e.g.
    /// [`Comm::recv_any`]) outwaited the watchdog.
    RecvStalled { rank: usize, millis: u64 },
    /// Every peer exited while this rank was still blocked receiving —
    /// the fail-fast liveness check, on both backends.
    PeersGone { rank: usize },
    /// A peer failed first and the cooperative abort protocol unwound
    /// this (otherwise healthy) rank.
    Aborted { rank: usize },
    /// The worker body panicked; [`run_cfg`] contained the panic.
    Panicked { rank: usize, msg: String },
    /// Silent-data-corruption detection fired (§Rob, [`AbftMode`]): the
    /// wire integrity word mismatched in [`Comm::recv_into`] (then `tag`
    /// is the message tag and `phase` the comm phase label), or a block's
    /// contribution failed its `xᵀC_b x` checksum and — in scrub mode —
    /// failed it again after recomputation (then `tag` carries the
    /// offending block id and `phase` is `"abft-verify"`), or the host's
    /// final global-checksum identity failed (`rank == usize::MAX`,
    /// `phase == "abft-global"`).
    Corrupt { rank: usize, tag: u64, phase: &'static str },
}

impl SttsvError {
    /// Faults a retry under a reseeded [`FaultPlan`] can clear. `Corrupt`
    /// is included: injected bit flips are seeded, so a reseeded rerun
    /// clears them, and genuinely sticky corruption re-surfaces (typed,
    /// never silent) until the retry budget runs out.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            SttsvError::Transient { .. }
                | SttsvError::Timeout { .. }
                | SttsvError::RecvStalled { .. }
                | SttsvError::Corrupt { .. }
        )
    }

    /// Secondary casualties of another rank's failure — never the root
    /// cause a [`FailureReport`] should blame.
    pub fn is_secondary(&self) -> bool {
        matches!(self, SttsvError::Aborted { .. } | SttsvError::PeersGone { .. })
    }
}

impl std::fmt::Display for SttsvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SttsvError::Crashed { rank, at_op } => {
                write!(f, "rank {rank} crashed at transport op {at_op} (chaos plan)")
            }
            SttsvError::Transient { op, rank } => {
                write!(f, "transient {op} fault on rank {rank} (chaos plan)")
            }
            SttsvError::Timeout { from, tag } => {
                write!(f, "recv watchdog fired waiting for {from}:{tag}")
            }
            SttsvError::RecvStalled { rank, millis } => {
                write!(f, "rank {rank} stalled {millis} ms waiting for any message")
            }
            SttsvError::PeersGone { rank } => {
                write!(f, "all peers exited with rank {rank} still receiving")
            }
            SttsvError::Aborted { rank } => {
                write!(f, "rank {rank} unwound by cooperative abort (a peer failed first)")
            }
            SttsvError::Panicked { rank, msg } => {
                write!(f, "rank {rank} panicked: {msg}")
            }
            SttsvError::Corrupt { rank, tag, phase } => {
                if *rank == usize::MAX {
                    write!(f, "host-side global ABFT checksum failed for column {tag}")
                } else if *phase == "abft-verify" {
                    write!(f, "rank {rank} detected corruption in block {tag} (ABFT checksum)")
                } else {
                    write!(
                        f,
                        "rank {rank} received a corrupt message (tag {tag}, phase '{phase}': \
                         integrity word mismatch)"
                    )
                }
            }
        }
    }
}

impl std::error::Error for SttsvError {}

/// Structured account of a failed [`run_cfg`] execution, returned (inside
/// `anyhow`) in place of a hang, a panic, or a bare first-error string:
/// which rank failed first, what phase label it was in, the typed root
/// cause when there is one, every rank's counters at unwind time, and the
/// payload words abandoned in flight. Callers recover it with
/// `err.downcast_ref::<FailureReport>()`.
#[derive(Debug, Clone)]
pub struct FailureReport {
    /// Root-cause rank (abort-protocol winner, or the first rank whose
    /// error is not a secondary casualty).
    pub failed_rank: usize,
    /// The phase label the failed rank last set via [`Comm::phase`].
    pub phase: &'static str,
    /// The root cause, typed, when the failure was a [`SttsvError`].
    pub kind: Option<SttsvError>,
    /// Rendered root-cause chain (present even for untyped errors).
    pub cause: String,
    /// Per-rank counters at unwind (index = rank; failed/aborted ranks
    /// report whatever they had charged before unwinding).
    pub per_rank: Vec<CommStats>,
    /// Payload words still in flight when the run unwound.
    pub inflight_words: u64,
}

impl std::fmt::Display for FailureReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "rank {} failed in phase '{}': {} ({} words in flight)",
            self.failed_rank, self.phase, self.cause, self.inflight_words
        )
    }
}

impl std::error::Error for FailureReport {}

/// Poison-recovering mutex access: a lock poisoned by a panicked worker
/// yields its guard anyway. Every structure guarded this way (lent
/// [`BufPool`]s, result slots, the serve layer's caches and queues) is
/// kept consistent by whole-value writes and appends, so the data is
/// valid even when a panic interleaved — clearing the poison is what
/// keeps a cached `Arc<SttsvPlan>` usable by other serve tenants after
/// one tenant's run dies (§Rob satellite).
pub fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Largest power of two ≤ p (the recursive-doubling core size).
fn pow2_floor(p: usize) -> usize {
    let mut pp = 1usize;
    while pp * 2 <= p {
        pp *= 2;
    }
    pp
}

/// Closed-form per-rank cost of ONE [`Comm::allreduce_sum`] over `width`
/// words on `p` processors (recursive doubling with the standard
/// fold-in/fold-out for non-powers of two):
///
/// * ranks ≥ 2^⌊log₂P⌋ (the "extra" ranks): 1 message each way;
/// * ranks < P − 2^⌊log₂P⌋ (partners of an extra rank): ⌊log₂P⌋ + 1
///   messages each way;
/// * all other ranks: ⌊log₂P⌋ messages each way;
///
/// each message `width` words. Asserted equal to the measured counters in
/// the simulator tests, and the "O(log P) scalar words" term of the
/// resident-session per-iteration invariant (§Perf P9).
pub fn allreduce_stats(p: usize, rank: usize, width: usize) -> CommStats {
    if p <= 1 {
        return CommStats::default();
    }
    let pp = pow2_floor(p);
    let rem = p - pp;
    let lg = pp.trailing_zeros() as u64;
    let msgs = if rank >= pp {
        1
    } else if rank < rem {
        lg + 1
    } else {
        lg
    };
    CommStats {
        sent_words: msgs * width as u64,
        recv_words: msgs * width as u64,
        // Collective traffic always travels f32 (4 bytes/word), whatever
        // the run's sweep WireFormat — see [`WireFormat::packs`].
        sent_bytes: 4 * msgs * width as u64,
        recv_bytes: 4 * msgs * width as u64,
        sent_msgs: msgs,
        recv_msgs: msgs,
    }
}

/// A pool of reusable payload buffers (one per processor). Buffers are
/// drawn best-fit by [`Comm::isend`] and [`Comm::recv`], travel with the
/// packet (mpsc) and are adopted into the *receiver's* pool on delivery
/// (symmetric protocols keep the pools balanced); `fresh_allocs` counts
/// every buffer allocation or capacity growth the pool had to perform —
/// zero on a warmed-up pool. On the spsc transport, ring-slot capacity
/// growths count here too, so the invariant keeps its meaning: zero means
/// zero payload heap activity anywhere on the message path. Lend pools
/// across repeated [`run_ext`] calls (as `coordinator::SttsvPlan` does) to
/// make iterative workloads allocation-free on the communication hot path.
#[derive(Debug, Default)]
pub struct BufPool {
    bufs: Vec<Vec<f32>>,
    fresh_allocs: u64,
}

impl BufPool {
    pub fn new() -> Self {
        BufPool::default()
    }

    /// Buffers currently parked in the pool.
    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    /// Total buffer allocations (or capacity growths) this pool has ever
    /// had to perform.
    pub fn fresh_allocs(&self) -> u64 {
        self.fresh_allocs
    }

    fn take(&mut self, cap: usize) -> Vec<f32> {
        // Best fit: the smallest pooled buffer whose capacity already
        // covers `cap`. The full exchange protocols send and receive the
        // same multiset of message sizes per processor per run, so a warm
        // pool always holds an adequate buffer and the steady state is
        // free of allocations AND growth reallocations; a too-small pick
        // would reallocate inside the caller's extend, which is why growth
        // is counted here — `fresh_allocs == 0` means zero payload heap
        // activity, not just zero pool misses. Pools hold at most a few
        // dozen buffers, so the scan is noise.
        let mut best: Option<(usize, usize)> = None; // (index, capacity)
        for (i, b) in self.bufs.iter().enumerate() {
            let c = b.capacity();
            if c >= cap {
                match best {
                    Some((_, bc)) if bc <= c => {}
                    _ => best = Some((i, c)),
                }
            }
        }
        match best {
            Some((i, _)) => self.bufs.swap_remove(i),
            None => {
                self.fresh_allocs += 1;
                match self.bufs.pop() {
                    Some(mut b) => {
                        b.reserve(cap);
                        b
                    }
                    None => Vec::with_capacity(cap),
                }
            }
        }
    }

    fn put(&mut self, mut buf: Vec<f32>) {
        buf.clear();
        self.bufs.push(buf);
    }
}

/// Cross-processor gauge of payload words currently in flight (sent, not
/// yet delivered), with a high-water mark — the E12 "peak in-flight
/// payload" metric. Overlap trades higher in-flight occupancy for the
/// removed barriers; the model cost (words, messages) is unchanged.
#[derive(Debug, Default)]
struct InflightGauge {
    current: AtomicU64,
    peak: AtomicU64,
}

impl InflightGauge {
    fn add(&self, words: u64) {
        let now = self.current.fetch_add(words, Ordering::Relaxed) + words;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    fn sub(&self, words: u64) {
        self.current.fetch_sub(words, Ordering::Relaxed);
    }
}

/// Whole-run metrics reported by [`run_ext`].
#[derive(Debug, Default, Clone, Copy)]
pub struct RunMetrics {
    /// Max total payload words simultaneously in flight at any instant.
    pub peak_inflight_words: u64,
    /// Payload buffers freshly allocated during this run (0 when every
    /// `isend` was served from a warmed-up [`BufPool`]).
    pub fresh_payload_allocs: u64,
}

/// Message-passing backend for a simulator run — see the module docs for
/// the two backends' contracts. (`Hash` because the transport is part of
/// the serving layer's plan-cache key via `ExecOpts`.)
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TransportKind {
    /// `std::sync::mpsc` channels: the deterministic counting oracle.
    #[default]
    Mpsc,
    /// Lock-free shared-memory SPSC rings: the hardware-speed path.
    Spsc,
}

impl std::str::FromStr for TransportKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "mpsc" => Ok(TransportKind::Mpsc),
            "spsc" => Ok(TransportKind::Spsc),
            other => Err(anyhow!("unknown transport '{other}' (expected spsc|mpsc)")),
        }
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TransportKind::Mpsc => "mpsc",
            TransportKind::Spsc => "spsc",
        })
    }
}

/// Run-level configuration for [`run_cfg`].
#[derive(Debug, Clone, Copy)]
pub struct RunCfg {
    pub transport: TransportKind,
    /// Pin rank r's worker thread to CPU r mod cores (spsc runs only) for
    /// stable cache/NUMA placement while benchmarking.
    pub pin_threads: bool,
    /// Preallocated payload capacity (f32 words) of every ring slot on the
    /// spsc transport. Size it from the plan's known maximum message width
    /// (`SttsvPlan::max_message_words`) so sends never grow a slot; an
    /// undersized value still converges to allocation-free steady state
    /// because slot growth persists (each slot grows at most once per
    /// width regime).
    pub slot_words: usize,
    /// Fault-injection plan (§Rob). `FaultPlan::default()` runs the plain
    /// backend with no wrapper at all; any other plan — including a
    /// zero-rate, crash-free one — wraps the transport in the chaos
    /// decorator, which is what lets property P13 assert the wrapper
    /// itself is bitwise and counter transparent.
    pub chaos: FaultPlan,
    /// Watchdog for blocking receives: a rank blocked longer than this
    /// surfaces [`SttsvError::Timeout`] / [`SttsvError::RecvStalled`]
    /// instead of waiting forever behind a stuck-but-alive peer. `None`
    /// waits indefinitely (the abort protocol and the fail-fast liveness
    /// check still bound the wait when a peer actually dies).
    pub recv_timeout: Option<Duration>,
    /// On-the-wire encoding for sweep payloads (§Perf P14). `Bf16` halves
    /// measured payload bytes at identical words/messages; collectives
    /// stay f32 regardless.
    pub wire: WireFormat,
    /// ABFT mode (§Rob). When on, every sweep-class message carries one
    /// Fletcher-32 integrity word ([`Comm::isend`] appends, billed as one
    /// extra word; [`Comm::recv_into`] verifies and strips). Size spsc
    /// slots for the extra physical container.
    pub abft: AbftMode,
}

impl Default for RunCfg {
    fn default() -> Self {
        RunCfg {
            transport: TransportKind::Mpsc,
            pin_threads: false,
            slot_words: 64,
            chaos: FaultPlan::default(),
            recv_timeout: None,
            wire: WireFormat::F32,
            abft: AbftMode::Off,
        }
    }
}

impl RunCfg {
    /// Default configuration for the given backend.
    pub fn new(transport: TransportKind) -> RunCfg {
        RunCfg { transport, ..RunCfg::default() }
    }
}

struct Packet {
    from: usize,
    tag: u64,
    data: Vec<f32>,
}

/// Run-wide cooperative control shared by every rank (§Rob). The first
/// failing rank's teardown raises `abort`; every blocking transport wait
/// and every barrier polls it, so all peers unwind within a bounded time
/// (one watchdog tick / park interval) instead of deadlocking on a
/// message or barrier arrival that will never come. `alive` flags
/// (formerly spsc-only) give both backends the fail-fast "all peers
/// exited" liveness check.
struct RunCtl {
    abort: AtomicBool,
    /// First failing rank — the root cause [`FailureReport`] blames;
    /// `usize::MAX` until a failure wins the race.
    abort_rank: AtomicUsize,
    alive: Vec<AtomicBool>,
}

impl RunCtl {
    fn new(p: usize) -> RunCtl {
        RunCtl {
            abort: AtomicBool::new(false),
            abort_rank: AtomicUsize::new(usize::MAX),
            alive: (0..p).map(|_| AtomicBool::new(true)).collect(),
        }
    }

    /// Raise the abort flag; the first caller wins the root-cause slot.
    fn trigger(&self, rank: usize) {
        let _ = self.abort_rank.compare_exchange(
            usize::MAX,
            rank,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
        self.abort.store(true, Ordering::Release);
    }

    fn aborted(&self) -> bool {
        self.abort.load(Ordering::Acquire)
    }

    /// Have all of `rank`'s peers exited? (Acquire: pairs with the
    /// Release store in worker teardown, so a true answer happens-after
    /// every last publish the peer made — one final nonblocking drain
    /// after this is conclusive.)
    fn peers_done(&self, rank: usize) -> bool {
        self.alive
            .iter()
            .enumerate()
            .all(|(r, a)| r == rank || !a.load(Ordering::Acquire))
    }
}

/// How often a blocked mpsc receive wakes to poll the abort flag, the
/// liveness check, and its watchdog deadline. Pure overhead bound: a
/// message arrival wakes the receiver immediately regardless.
const MPSC_TICK: Duration = Duration::from_millis(1);

/// The wire under a [`Comm`] endpoint. Implementations move `Packet`s
/// between ranks; all counting, stashing, pooling and collective logic
/// lives above in [`Comm`], which is what keeps the two backends
/// observationally identical (property P11).
///
/// Buffer discipline: `send` consumes an owned payload — a backend that
/// copies onto the wire (spsc) recycles the `Vec` into `pool`, a backend
/// that forwards ownership (mpsc) does not. `try_recv`/`recv` draw the
/// delivered payload's buffer from `pool` when the wire copies out (spsc);
/// mpsc delivers the sender's buffer itself. Either way the packet the
/// caller sees owns its data and the pool accounting in `fresh_allocs`
/// covers every allocation on the path.
trait Transport: Send {
    fn send(&mut self, to: usize, tag: u64, data: Vec<f32>, pool: &mut BufPool) -> Result<()>;
    fn send_slice(&mut self, to: usize, tag: u64, data: &[f32], pool: &mut BufPool)
        -> Result<()>;
    fn try_recv(&mut self, pool: &mut BufPool) -> Option<Packet>;
    fn recv(&mut self, pool: &mut BufPool) -> Result<Packet>;
}

/// The `std::sync::mpsc` oracle backend: one channel per processor,
/// payload `Vec`s travel through the channel with ownership.
struct MpscTransport {
    rank: usize,
    senders: Vec<mpsc::Sender<Packet>>,
    inbox: mpsc::Receiver<Packet>,
    ctl: Arc<RunCtl>,
    /// Watchdog budget for one blocking receive ([`RunCfg::recv_timeout`]).
    timeout: Option<Duration>,
}

impl Transport for MpscTransport {
    fn send(&mut self, to: usize, tag: u64, data: Vec<f32>, _pool: &mut BufPool) -> Result<()> {
        self.senders[to]
            .send(Packet { from: self.rank, tag, data })
            .map_err(|_| anyhow!("processor {to} hung up"))
    }

    fn send_slice(
        &mut self,
        to: usize,
        tag: u64,
        data: &[f32],
        pool: &mut BufPool,
    ) -> Result<()> {
        let mut buf = pool.take(data.len());
        buf.extend_from_slice(data);
        self.send(to, tag, buf, pool)
    }

    fn try_recv(&mut self, _pool: &mut BufPool) -> Option<Packet> {
        self.inbox.try_recv().ok()
    }

    fn recv(&mut self, _pool: &mut BufPool) -> Result<Packet> {
        let deadline = self.timeout.map(|t| Instant::now() + t);
        loop {
            match self.inbox.recv_timeout(MPSC_TICK) {
                Ok(pkt) => return Ok(pkt),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!("inbox closed"));
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if self.ctl.aborted() {
                        return Err(SttsvError::Aborted { rank: self.rank }.into());
                    }
                    if self.ctl.peers_done(self.rank) {
                        // Peers publish (send) before the Release store on
                        // their alive flag, so this final drain after
                        // observing all of them dead is conclusive.
                        return match self.inbox.try_recv() {
                            Ok(pkt) => Ok(pkt),
                            Err(_) => {
                                Err(SttsvError::PeersGone { rank: self.rank }.into())
                            }
                        };
                    }
                    if let Some(d) = deadline {
                        if Instant::now() >= d {
                            let millis = self.timeout.unwrap_or_default().as_millis() as u64;
                            return Err(SttsvError::RecvStalled {
                                rank: self.rank,
                                millis,
                            }
                            .into());
                        }
                    }
                }
            }
        }
    }
}

/// How long a blocked spsc receiver spins before switching to the
/// announce-scan-park cycle, and how long each timed park lasts. The park
/// timeout is pure defense in depth — the SeqCst handshake in
/// [`spsc::ParkCell`] already rules out lost wakeups — so its only cost is
/// a rare 50µs hiccup if that reasoning were ever wrong.
const SPSC_RECV_SPINS: u32 = 512;
const SPSC_PARK: std::time::Duration = std::time::Duration::from_micros(50);

/// The lock-free backend: a dedicated [`spsc::SpscRing`] per directed
/// pair, so every ring has exactly one producer and one consumer and
/// needs no CAS anywhere. `alive` flags give fail-fast liveness: a
/// blocked receive errors out once every peer has exited with all rings
/// drained, instead of hanging the run.
struct SpscTransport {
    rank: usize,
    /// `outgoing[to]` / `incoming[from]`; `None` on the diagonal.
    outgoing: Vec<Option<Arc<spsc::SpscRing>>>,
    incoming: Vec<Option<Arc<spsc::SpscRing>>>,
    parks: Arc<Vec<spsc::ParkCell>>,
    ctl: Arc<RunCtl>,
    /// Watchdog budget for one blocking receive ([`RunCfg::recv_timeout`]).
    timeout: Option<Duration>,
    /// Round-robin scan start, for fairness across senders.
    cursor: usize,
}

impl SpscTransport {
    /// Copy `data` into `to`'s ring, backing off while the ring is full
    /// (the consumer always drains — see [`spsc::RING_SLOTS`] — unless it
    /// exited, which we fail fast on). A slot-capacity growth is charged
    /// to `pool.fresh_allocs`, keeping the zero-allocation invariant
    /// end-to-end.
    fn push_wire(&self, to: usize, tag: u64, data: &[f32], pool: &mut BufPool) -> Result<()> {
        let ring = self.outgoing[to].as_ref().expect("self-send has no ring");
        let mut spins = 0u32;
        let grew = loop {
            match ring.try_push(tag, data) {
                Some(grew) => break grew,
                None => {
                    if !self.ctl.alive[to].load(Ordering::Acquire) {
                        return Err(anyhow!("processor {to} hung up"));
                    }
                    if self.ctl.aborted() {
                        return Err(SttsvError::Aborted { rank: self.rank }.into());
                    }
                    spins += 1;
                    if spins < 128 {
                        std::hint::spin_loop();
                    } else {
                        std::thread::yield_now();
                    }
                }
            }
        };
        if grew {
            pool.fresh_allocs += 1;
        }
        self.parks[to].wake();
        Ok(())
    }

    /// One fair pass over all incoming rings; pops the first available
    /// packet into a pool-drawn buffer.
    fn scan(&mut self, pool: &mut BufPool) -> Option<Packet> {
        let p = self.incoming.len();
        for i in 0..p {
            let from = (self.cursor + i) % p;
            if let Some(ring) = self.incoming[from].as_ref() {
                if let Some((tag, data)) = ring.pop(|cap| pool.take(cap)) {
                    self.cursor = (from + 1) % p;
                    return Some(Packet { from, tag, data });
                }
            }
        }
        None
    }

    /// Have all peers exited? See [`RunCtl::peers_done`].
    fn peers_done(&self) -> bool {
        self.ctl.peers_done(self.rank)
    }
}

impl Transport for SpscTransport {
    fn send(&mut self, to: usize, tag: u64, data: Vec<f32>, pool: &mut BufPool) -> Result<()> {
        self.push_wire(to, tag, &data, pool)?;
        // The wire copied; recycle the caller's buffer.
        pool.put(data);
        Ok(())
    }

    fn send_slice(
        &mut self,
        to: usize,
        tag: u64,
        data: &[f32],
        pool: &mut BufPool,
    ) -> Result<()> {
        // In-place fast path: borrowed payloads go straight to the ring
        // slot with no intermediate pool buffer at all.
        self.push_wire(to, tag, data, pool)
    }

    fn try_recv(&mut self, pool: &mut BufPool) -> Option<Packet> {
        self.scan(pool)
    }

    fn recv(&mut self, pool: &mut BufPool) -> Result<Packet> {
        let deadline = self.timeout.map(|t| Instant::now() + t);
        let mut spins = 0u32;
        loop {
            if let Some(pkt) = self.scan(pool) {
                return Ok(pkt);
            }
            if spins < SPSC_RECV_SPINS {
                spins += 1;
                std::hint::spin_loop();
                continue;
            }
            // Spin budget exhausted: announce, re-scan (the Dekker
            // handshake — see spsc::ParkCell), then park with a timeout.
            // Each park interval re-checks the abort flag, the liveness
            // of the peers, and the watchdog deadline, so every way a
            // message can fail to arrive resolves in bounded time.
            let park = &self.parks[self.rank];
            park.announce();
            if let Some(pkt) = self.scan(pool) {
                park.retract();
                return Ok(pkt);
            }
            if self.ctl.aborted() {
                park.retract();
                return Err(SttsvError::Aborted { rank: self.rank }.into());
            }
            if self.peers_done() {
                park.retract();
                return match self.scan(pool) {
                    Some(pkt) => Ok(pkt),
                    None => Err(SttsvError::PeersGone { rank: self.rank }.into()),
                };
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    park.retract();
                    let millis = self.timeout.unwrap_or_default().as_millis() as u64;
                    return Err(SttsvError::RecvStalled { rank: self.rank, millis }.into());
                }
            }
            spsc::ParkCell::park(SPSC_PARK);
            park.retract();
        }
    }
}

/// Abort-aware generation barrier for the mpsc path: the same
/// mutex+condvar shape as `std::sync::Barrier`, except waits tick on a
/// short timeout and re-check the run's abort flag — a rank that died
/// mid-protocol (and will never arrive) releases its peers within one
/// tick instead of wedging the run at a step boundary. An aborted exit
/// leaves the arrival count stale; that is fine, the run is unwinding
/// and the barrier is per-run.
struct CondBarrier {
    p: usize,
    /// (arrived, generation)
    state: Mutex<(usize, u64)>,
    cv: Condvar,
}

impl CondBarrier {
    fn new(p: usize) -> CondBarrier {
        CondBarrier { p, state: Mutex::new((0, 0)), cv: Condvar::new() }
    }

    fn wait(&self, ctl: &RunCtl) {
        let mut s = lock_clean(&self.state);
        s.0 += 1;
        if s.0 >= self.p {
            s.0 = 0;
            s.1 = s.1.wrapping_add(1);
            self.cv.notify_all();
            return;
        }
        let gen = s.1;
        loop {
            if ctl.aborted() {
                return;
            }
            let (ns, _timed_out) = self
                .cv
                .wait_timeout(s, MPSC_TICK)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            s = ns;
            if s.1 != gen {
                return;
            }
        }
    }
}

/// The run-wide barrier, matched to the transport: the abort-aware
/// condvar barrier for the oracle, a spin barrier (no syscalls on the
/// fast path, abort polled in the spin loop) for spsc.
#[derive(Clone)]
enum RunBarrier {
    Std(Arc<CondBarrier>),
    Spin(Arc<spsc::SpinBarrier>),
}

impl RunBarrier {
    fn wait(&self, ctl: &RunCtl) {
        match self {
            RunBarrier::Std(b) => b.wait(ctl),
            RunBarrier::Spin(b) => b.wait_abortable(&ctl.abort),
        }
    }
}

/// A processor's communication endpoint inside [`run`].
pub struct Comm {
    /// This processor's rank in 0..P.
    pub rank: usize,
    /// Total number of processors.
    pub p: usize,
    transport: Box<dyn Transport>,
    /// Out-of-order buffer: packets received while waiting for another key.
    stash: HashMap<(usize, u64), Packet>,
    /// Arrival-ordered keys of stashed packets, one queue per [`TagClass`]
    /// (index 0 = Sweep, 1 = Collective), so class-filtered polling peeks
    /// in O(1) instead of scanning the stash. Entries whose packet has
    /// since been consumed by a targeted receive are stale; they are
    /// dropped lazily at peek time and swept when a queue outgrows the
    /// stash (see [`Comm::stash_insert`]).
    ready: [VecDeque<(usize, u64)>; 2],
    pool: BufPool,
    inflight: Arc<InflightGauge>,
    barrier: RunBarrier,
    ctl: Arc<RunCtl>,
    /// Free-form phase label the worker body keeps current ("sweep",
    /// "allreduce", …). Costs one pointer store to set; surfaces in the
    /// [`FailureReport`] so a failure names the protocol phase it hit.
    pub phase: &'static str,
    /// Sequence number for collective tags: every collective call on this
    /// processor consumes one tag above [`TAG_COLL_BASE`]. All processors
    /// issue collectives in the same program order, so the tags agree
    /// across ranks and every collective instance keys its messages
    /// uniquely — back-to-back allreduces between the same pair can never
    /// collide, however far one rank races ahead.
    coll_seq: u64,
    /// Sweep-payload wire encoding for this run ([`RunCfg::wire`]).
    wire: WireFormat,
    /// ABFT mode for this run ([`RunCfg::abft`]): when on, sweep-class
    /// `isend`/`recv_into` traffic carries the Fletcher-32 integrity word.
    abft: AbftMode,
    /// Word/byte/message counters for this processor.
    pub stats: CommStats,
}

impl Comm {
    /// Send `data` to processor `to` with a matching `tag` (owned-payload
    /// variant: the caller-built `Vec` becomes the in-flight buffer on
    /// mpsc, or is recycled into this processor's pool after the spsc wire
    /// copies it in place).
    pub fn send(&mut self, to: usize, tag: u64, data: Vec<f32>) -> Result<()> {
        debug_assert_ne!(to, self.rank, "self-send is a bug in the algorithm");
        // The blocking pair never packs: the receiver of an owned-Vec
        // `recv` has no length expectation to recover an odd logical
        // length from. No sweep-path protocol uses it; keep bf16 runs off
        // this API.
        debug_assert!(
            !self.wire.packs(tag),
            "blocking send on a bf16-packed tag class (use isend)"
        );
        debug_assert!(
            !self.abft.frames(tag),
            "blocking send on an ABFT-framed tag class (use isend)"
        );
        self.stats.sent_words += data.len() as u64;
        self.stats.sent_bytes += 4 * data.len() as u64;
        self.stats.sent_msgs += 1;
        self.inflight.add(data.len() as u64);
        self.transport.send(to, tag, data, &mut self.pool)
    }

    /// Nonblocking send from a borrowed slice: the payload is copied into a
    /// reusable buffer from this processor's pool (zero allocations once
    /// the pool is warm) — or, on the spsc transport, directly into the
    /// destination ring slot — and handed to `to`'s endpoint. Never blocks
    /// under the protocols' in-flight bounds; identical word/message
    /// accounting to [`Comm::send`].
    pub fn isend(&mut self, to: usize, tag: u64, data: &[f32]) -> Result<()> {
        debug_assert_ne!(to, self.rank, "self-send is a bug in the algorithm");
        let framed = self.abft.frames(tag);
        let billed = data.len() as u64 + framed as u64;
        self.stats.sent_words += billed;
        self.stats.sent_bytes += self.wire.bytes_per_word(tag) * billed;
        self.stats.sent_msgs += 1;
        self.inflight.add(billed);
        if self.wire.packs(tag) {
            // bf16: round into a pool-drawn staging buffer, two halves
            // per f32 container (zero allocations once the pool is warm;
            // the spsc in-place fast path is traded for the pack pass).
            let mut buf = self.pool.take(data.len().div_ceil(2) + framed as usize);
            pack_bf16(data, &mut buf);
            if framed {
                // The integrity word hashes the FINAL wire containers —
                // after packing — so it covers exactly the bits that
                // travel, in either format.
                let ck = fletcher32(&buf);
                buf.push(f32::from_bits(ck));
            }
            self.transport.send(to, tag, buf, &mut self.pool)
        } else if framed {
            let mut buf = self.pool.take(data.len() + 1);
            buf.extend_from_slice(data);
            buf.push(f32::from_bits(fletcher32(data)));
            self.transport.send(to, tag, buf, &mut self.pool)
        } else {
            self.transport.send_slice(to, tag, data, &mut self.pool)
        }
    }

    /// Blocking receive of the message from `from` with `tag` (out-of-order
    /// deliveries are stashed). The returned buffer is drawn from this
    /// processor's [`BufPool`] and the in-flight buffer is adopted into the
    /// pool in its place, so ownership stays inside the pool system and
    /// repeated blocking receives allocate nothing once the pool is warm.
    pub fn recv(&mut self, from: usize, tag: u64) -> Result<Vec<f32>> {
        debug_assert!(
            !self.wire.packs(tag),
            "blocking recv on a bf16-packed tag class (use recv_into)"
        );
        debug_assert!(
            !self.abft.frames(tag),
            "blocking recv on an ABFT-framed tag class (use recv_into)"
        );
        let pkt = self.wait_for(from, tag)?;
        self.stats.recv_words += pkt.data.len() as u64;
        self.stats.recv_bytes += 4 * pkt.data.len() as u64;
        self.stats.recv_msgs += 1;
        self.inflight.sub(pkt.data.len() as u64);
        let mut out = self.pool.take(pkt.data.len());
        out.extend_from_slice(&pkt.data);
        self.pool.put(pkt.data);
        Ok(out)
    }

    /// Blocking receive delivered straight into `dst`, which must be
    /// exactly the logical message length; the in-flight buffer is adopted
    /// into this processor's pool for reuse by later `isend`s. Word/message
    /// accounting identical to [`Comm::recv`]. Under a bf16 wire format
    /// the physical payload is `dst.len().div_ceil(2)` f32 containers and
    /// each half-word is expanded back to f32 here; words and messages are
    /// still counted at the logical (f32-word) granularity, only the byte
    /// counter sees the 2-byte wire width.
    pub fn recv_into(&mut self, from: usize, tag: u64, dst: &mut [f32]) -> Result<()> {
        let pkt = self.wait_for(from, tag)?;
        let framed = self.abft.frames(tag);
        let containers = if self.wire.packs(tag) { dst.len().div_ceil(2) } else { dst.len() };
        ensure!(
            pkt.data.len() == containers + framed as usize,
            "recv_into from {from} tag {tag}: payload {} containers, caller expected {} \
             ({} logical words{})",
            pkt.data.len(),
            containers + framed as usize,
            dst.len(),
            if framed { " + integrity word" } else { "" }
        );
        if framed {
            // Verify the Fletcher-32 integrity word over the payload
            // containers BEFORE unpacking: a flipped wire bit must never
            // reach an accumulator. The caller propagates the typed error
            // through the §Rob machinery (abort protocol, FailureReport).
            let want = pkt.data[containers].to_bits();
            let got = fletcher32(&pkt.data[..containers]);
            if got != want {
                let err = SttsvError::Corrupt { rank: self.rank, tag, phase: self.phase };
                self.pool.put(pkt.data);
                return Err(err.into());
            }
        }
        if self.wire.packs(tag) {
            unpack_bf16(&pkt.data[..containers], dst);
        } else {
            dst.copy_from_slice(&pkt.data[..containers]);
        }
        let billed = dst.len() as u64 + framed as u64;
        self.stats.recv_bytes += self.wire.bytes_per_word(tag) * billed;
        self.stats.recv_words += billed;
        self.stats.recv_msgs += 1;
        self.inflight.sub(billed);
        self.pool.put(pkt.data);
        Ok(())
    }

    /// Nonblocking poll: drains every packet currently on the wire into
    /// the stash and reports the `(from, tag)` of one available message, or
    /// `None` when nothing has arrived. Consume the reported message with
    /// [`Comm::recv_into`] (or [`Comm::recv`]) before polling again.
    pub fn try_recv(&mut self) -> Option<(usize, u64)> {
        self.try_recv_class(TagClass::Any)
    }

    /// [`Comm::try_recv`] restricted to one [`TagClass`]: non-matching
    /// arrivals are stashed (not lost) but never reported. Event-loop
    /// workers poll with [`TagClass::Sweep`] so a faster peer's collective
    /// traffic waits in the stash instead of derailing the sweep protocol.
    /// The peek is O(1) via the per-class ready queue (arrival order), so
    /// polling cost is independent of stash depth.
    pub fn try_recv_class(&mut self, class: TagClass) -> Option<(usize, u64)> {
        while let Some(pkt) = self.transport.try_recv(&mut self.pool) {
            self.stash_insert(pkt);
        }
        self.ready_peek(class)
    }

    /// Blocking wait for *any* message: returns the `(from, tag)` of an
    /// available packet (stashed first, then the wire). Like
    /// [`Comm::try_recv`], does not consume the message.
    pub fn recv_any(&mut self) -> Result<(usize, u64)> {
        self.recv_any_class(TagClass::Any)
    }

    /// [`Comm::recv_any`] restricted to one [`TagClass`]: blocks until a
    /// matching message is available, stashing (never dropping)
    /// non-matching arrivals along the way.
    pub fn recv_any_class(&mut self, class: TagClass) -> Result<(usize, u64)> {
        if let Some(key) = self.try_recv_class(class) {
            return Ok(key);
        }
        loop {
            let pkt = match self.transport.recv(&mut self.pool) {
                Ok(pkt) => pkt,
                Err(e) => return Err(annotate(e, "while waiting for any message")),
            };
            let key = (pkt.from, pkt.tag);
            self.stash_insert(pkt);
            if class.matches(key.1) {
                return Ok(key);
            }
        }
    }

    /// Recursive-doubling allreduce: every processor ends with the
    /// element-wise sum of all P `buf` contributions, **bitwise identical
    /// on every rank** (each rank combines the same operand tree; f32
    /// addition is commutative). Non-powers of two use the standard
    /// fold-in/fold-out. Cost per rank is [`allreduce_stats`] exactly:
    /// O(log P) messages of `buf.len()` words, fully counted in
    /// [`Comm::stats`]. All processors must call collectives in the same
    /// program order (tags are sequence-numbered per processor).
    pub fn allreduce_sum(&mut self, buf: &mut [f32]) -> Result<()> {
        let tag = TAG_COLL_BASE + self.coll_seq;
        self.coll_seq += 1;
        if self.p == 1 {
            return Ok(());
        }
        let me = self.rank;
        let pp = pow2_floor(self.p);
        let rem = self.p - pp;
        if me >= pp {
            // Extra rank: fold into the partner, receive the final sum —
            // no combine scratch needed on this branch.
            self.isend(me - pp, tag, buf)?;
            self.recv_into(me - pp, tag, buf)?;
            return Ok(());
        }
        let mut scratch = vec![0.0f32; buf.len()];
        if me < rem {
            self.recv_into(me + pp, tag, &mut scratch)?;
            for (b, s) in buf.iter_mut().zip(&scratch) {
                *b += s;
            }
        }
        let mut mask = 1usize;
        while mask < pp {
            let partner = me ^ mask;
            self.isend(partner, tag, buf)?;
            self.recv_into(partner, tag, &mut scratch)?;
            for (b, s) in buf.iter_mut().zip(&scratch) {
                *b += s;
            }
            mask <<= 1;
        }
        if me < rem {
            self.isend(me + pp, tag, buf)?;
        }
        Ok(())
    }

    /// One-word [`Comm::allreduce_sum`]: the global sum of `v`.
    pub fn allreduce_scalar(&mut self, v: f32) -> Result<f32> {
        let mut buf = [v];
        self.allreduce_sum(&mut buf)?;
        Ok(buf[0])
    }

    /// Oldest stashed key in `class`, dropping stale ready entries (whose
    /// packet a targeted receive already consumed) along the way.
    fn ready_peek(&mut self, class: TagClass) -> Option<(usize, u64)> {
        let order: &[usize] = match class {
            TagClass::Any => &[0, 1],
            TagClass::Sweep => &[0],
            TagClass::Collective => &[1],
        };
        for &q in order {
            while let Some(&key) = self.ready[q].front() {
                if self.stash.contains_key(&key) {
                    return Some(key);
                }
                self.ready[q].pop_front();
            }
        }
        None
    }

    /// Stash an out-of-order packet and enqueue its key on the class ready
    /// queue. A `(from, tag)` key must identify at most one in-flight
    /// message at a time (true for every protocol here: the stepped
    /// exchanges use per-step tags, the overlap pipeline one gather + one
    /// reduce per ordered pair); a duplicate would silently replace the
    /// first payload, so it trips a debug assertion (running in CI's
    /// release-with-debug-assertions job too).
    fn stash_insert(&mut self, pkt: Packet) {
        let key = (pkt.from, pkt.tag);
        let q = if key.1 < TAG_COLL_BASE { 0 } else { 1 };
        let prev = self.stash.insert(key, pkt);
        debug_assert!(
            prev.is_none(),
            "duplicate in-flight message key (from {}, tag {})",
            key.0,
            key.1
        );
        self.ready[q].push_back(key);
        // Purely-phased protocols consume the stash through targeted
        // `wait_for` and never peek a ready queue, so stale entries would
        // otherwise accumulate unboundedly; this amortized sweep keeps
        // every queue O(|stash|) with O(1) amortized cost per insert.
        if self.ready[q].len() >= 2 * self.stash.len() + 8 {
            let stash = &self.stash;
            self.ready[q].retain(|k| stash.contains_key(k));
        }
    }

    fn wait_for(&mut self, from: usize, tag: u64) -> Result<Packet> {
        if let Some(pkt) = self.stash.remove(&(from, tag)) {
            // The matching ready entry (if any) goes stale and is dropped
            // lazily at the next peek.
            return Ok(pkt);
        }
        loop {
            let pkt = match self.transport.recv(&mut self.pool) {
                Ok(pkt) => pkt,
                // A generic watchdog stall upgrades to the concrete key
                // this receive was blocked on — the caller learns *which*
                // message never came.
                Err(e) => {
                    return Err(match e.downcast::<SttsvError>() {
                        Ok(SttsvError::RecvStalled { .. }) => {
                            SttsvError::Timeout { from, tag }.into()
                        }
                        Ok(kind) => annotate(
                            anyhow::Error::new(kind),
                            &format!("while waiting for {from}:{tag}"),
                        ),
                        Err(e) => annotate(e, &format!("while waiting for {from}:{tag}")),
                    });
                }
            };
            if pkt.from == from && pkt.tag == tag {
                return Ok(pkt);
            }
            self.stash_insert(pkt);
        }
    }

    /// Surface a peer-initiated abort as a typed error — event-loop
    /// workers that make progress through nonblocking polls (which cannot
    /// fail) call this once per loop iteration so a dead peer unwinds
    /// them within one iteration instead of leaving them spinning.
    pub fn check_abort(&self) -> Result<()> {
        if self.ctl.aborted() {
            return Err(SttsvError::Aborted { rank: self.rank }.into());
        }
        Ok(())
    }

    /// Synchronize all processors (end of a schedule step).
    pub fn barrier(&self) {
        self.barrier.wait(&self.ctl);
    }
}

/// Wrap a transport error with its waiting context while keeping any
/// typed [`SttsvError`] downcastable through the chain (the old
/// `anyhow!("{e} …")` rewrap erased the type). The context line repeats
/// the cause, so a bare `to_string()` stays self-contained.
fn annotate(e: anyhow::Error, what: &str) -> anyhow::Error {
    let msg = format!("{e} {what}");
    e.context(msg)
}

/// Per-rank endpoint halves built by [`run_cfg`] and moved into the worker
/// threads.
enum Endpoint {
    Mpsc {
        senders: Vec<mpsc::Sender<Packet>>,
        inbox: mpsc::Receiver<Packet>,
    },
    Spsc {
        outgoing: Vec<Option<Arc<spsc::SpscRing>>>,
        incoming: Vec<Option<Arc<spsc::SpscRing>>>,
        parks: Arc<Vec<spsc::ParkCell>>,
    },
}

/// Run `body` on P simulated processors; returns the per-rank results in
/// rank order. Any processor error aborts the run.
pub fn run<R, F>(p: usize, body: F) -> Result<Vec<R>>
where
    R: Send,
    F: Fn(&mut Comm) -> Result<R> + Send + Sync,
{
    run_ext(p, None, body).map(|(out, _)| out)
}

/// [`run`] with run-level metrics, optionally lending per-processor
/// [`BufPool`]s so payload buffers survive across runs (the steady-state
/// zero-allocation path for iterative callers). Uses the default (mpsc)
/// transport; see [`run_cfg`] for backend selection.
pub fn run_ext<R, F>(
    p: usize,
    pools: Option<&[Mutex<BufPool>]>,
    body: F,
) -> Result<(Vec<R>, RunMetrics)>
where
    R: Send,
    F: Fn(&mut Comm) -> Result<R> + Send + Sync,
{
    run_cfg(p, pools, RunCfg::default(), body)
}

/// [`run_ext`] with full run configuration: transport backend, CPU
/// pinning, and spsc ring-slot sizing. `pools`, when provided, must have
/// exactly `p` entries; each worker locks only its own slot, at entry and
/// exit.
pub fn run_cfg<R, F>(
    p: usize,
    pools: Option<&[Mutex<BufPool>]>,
    cfg: RunCfg,
    body: F,
) -> Result<(Vec<R>, RunMetrics)>
where
    R: Send,
    F: Fn(&mut Comm) -> Result<R> + Send + Sync,
{
    assert!(p >= 1);
    if let Some(ps) = pools {
        assert_eq!(ps.len(), p, "one BufPool per processor");
    }
    let mut endpoints: Vec<Option<Endpoint>> = Vec::with_capacity(p);
    let barrier = match cfg.transport {
        TransportKind::Mpsc => {
            let mut senders = Vec::with_capacity(p);
            let mut inboxes = Vec::with_capacity(p);
            for _ in 0..p {
                let (tx, rx) = mpsc::channel::<Packet>();
                senders.push(tx);
                inboxes.push(rx);
            }
            for inbox in inboxes {
                endpoints.push(Some(Endpoint::Mpsc { senders: senders.clone(), inbox }));
            }
            RunBarrier::Std(Arc::new(CondBarrier::new(p)))
        }
        TransportKind::Spsc => {
            // rings[from * p + to]: one SPSC ring per directed pair.
            let rings: Vec<Option<Arc<spsc::SpscRing>>> = (0..p * p)
                .map(|i| {
                    (i / p != i % p)
                        .then(|| Arc::new(spsc::SpscRing::new(spsc::RING_SLOTS, cfg.slot_words)))
                })
                .collect();
            let parks = Arc::new((0..p).map(|_| spsc::ParkCell::new()).collect::<Vec<_>>());
            for rank in 0..p {
                endpoints.push(Some(Endpoint::Spsc {
                    outgoing: (0..p).map(|to| rings[rank * p + to].clone()).collect(),
                    incoming: (0..p).map(|from| rings[from * p + rank].clone()).collect(),
                    parks: parks.clone(),
                }));
            }
            RunBarrier::Spin(Arc::new(spsc::SpinBarrier::new(p)))
        }
    };
    let ctl = Arc::new(RunCtl::new(p));
    let inflight = Arc::new(InflightGauge::default());
    let fresh = AtomicU64::new(0);
    let results: Vec<Mutex<Option<Result<R>>>> = (0..p).map(|_| Mutex::new(None)).collect();
    // Per-rank (stats, phase) observations written at teardown — the raw
    // material of a [`FailureReport`] when the run fails.
    let obs: Vec<Mutex<(CommStats, &'static str)>> =
        (0..p).map(|_| Mutex::new((CommStats::default(), "run"))).collect();
    let body = &body;
    let fresh_ref = &fresh;
    let obs_ref = &obs;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    std::thread::scope(|scope| {
        for (rank, ep) in endpoints.iter_mut().enumerate() {
            let ep = ep.take().unwrap();
            let barrier = barrier.clone();
            let inflight = inflight.clone();
            let ctl = ctl.clone();
            let slot = &results[rank];
            scope.spawn(move || {
                if cfg.pin_threads {
                    spsc::pin_to_cpu(rank % cores);
                }
                let pool = match pools {
                    Some(ps) => std::mem::take(&mut *lock_clean(&ps[rank])),
                    None => BufPool::new(),
                };
                let fresh_before = pool.fresh_allocs;
                let (transport, park_cells): (Box<dyn Transport>, Option<_>) = match ep {
                    Endpoint::Mpsc { senders, inbox } => {
                        let t = MpscTransport {
                            rank,
                            senders,
                            inbox,
                            ctl: ctl.clone(),
                            timeout: cfg.recv_timeout,
                        };
                        (Box::new(t), None)
                    }
                    Endpoint::Spsc { outgoing, incoming, parks } => {
                        parks[rank].register();
                        let t = SpscTransport {
                            rank,
                            outgoing,
                            incoming,
                            parks: parks.clone(),
                            ctl: ctl.clone(),
                            timeout: cfg.recv_timeout,
                            cursor: 0,
                        };
                        (Box::new(t), Some(parks))
                    }
                };
                // The chaos decorator goes on only under a non-default
                // plan; the default plan means the plain backend, no
                // wrapper — so the zero-cost status quo is the default
                // and a zero-RATE plan still exercises the wrapper
                // (the P13 transparency leg).
                let transport: Box<dyn Transport> = if cfg.chaos == FaultPlan::default() {
                    transport
                } else {
                    Box::new(chaos::ChaosTransport::new(rank, cfg.chaos, transport))
                };
                let mut comm = Comm {
                    rank,
                    p,
                    transport,
                    stash: HashMap::new(),
                    ready: [VecDeque::new(), VecDeque::new()],
                    pool,
                    inflight,
                    barrier,
                    ctl: ctl.clone(),
                    phase: "run",
                    coll_seq: 0,
                    wire: cfg.wire,
                    abft: cfg.abft,
                    stats: CommStats::default(),
                };
                // Contain panics: an assert in a worker body becomes a
                // typed error and the cooperative abort below, not a
                // poisoned-lock cascade through the whole plan.
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    body(&mut comm)
                }))
                .unwrap_or_else(|payload| {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "non-string panic payload".to_string());
                    Err(SttsvError::Panicked { rank, msg }.into())
                });
                if out.is_err() {
                    // First failure wins the root-cause slot; every peer
                    // blocked on a receive, a full ring, or a barrier
                    // polls the flag and unwinds within one tick.
                    ctl.trigger(rank);
                }
                // Teardown: publish the per-run allocation delta, then MERGE
                // the pool back into the lent slot (append, don't overwrite:
                // if a second run on the same plan raced us and took an
                // empty pool, overwriting would drop its buffers — merging
                // keeps every buffer and the cumulative counter correct).
                fresh_ref.fetch_add(comm.pool.fresh_allocs - fresh_before, Ordering::Relaxed);
                if let Some(ps) = pools {
                    let mut lent = lock_clean(&ps[rank]);
                    lent.fresh_allocs += comm.pool.fresh_allocs;
                    lent.bufs.append(&mut comm.pool.bufs);
                }
                *lock_clean(&obs_ref[rank]) = (comm.stats, comm.phase);
                // Release: everything this rank published on any wire
                // happens-before a peer observing it dead.
                ctl.alive[rank].store(false, Ordering::Release);
                if let Some(parks) = park_cells {
                    // Wake all parked peers so they re-check liveness and
                    // the abort flag promptly.
                    for (r, park) in parks.iter().enumerate() {
                        if r != rank {
                            park.wake();
                        }
                    }
                }
                *lock_clean(slot) = Some(out);
            });
        }
    });

    let mut vals: Vec<Option<R>> = Vec::with_capacity(p);
    let mut errs: Vec<(usize, anyhow::Error)> = Vec::new();
    for (rank, slot) in results.into_iter().enumerate() {
        let cell = slot.into_inner().unwrap_or_else(|poisoned| poisoned.into_inner());
        match cell {
            Some(Ok(v)) => vals.push(Some(v)),
            Some(Err(e)) => {
                vals.push(None);
                errs.push((rank, e));
            }
            None => {
                vals.push(None);
                errs.push((rank, anyhow!("processor {rank} produced no result")));
            }
        }
    }
    let metrics = RunMetrics {
        peak_inflight_words: inflight.peak.load(Ordering::Relaxed),
        fresh_payload_allocs: fresh.into_inner(),
    };
    if errs.is_empty() {
        let out = vals.into_iter().map(|v| v.expect("checked")).collect();
        return Ok((out, metrics));
    }
    // Root-cause selection: the abort-protocol winner if its error is a
    // genuine failure, else the first rank whose error is not a secondary
    // casualty (Aborted / PeersGone), else the first error.
    let is_primary = |e: &anyhow::Error| match e.downcast_ref::<SttsvError>() {
        Some(kind) => !kind.is_secondary(),
        None => true,
    };
    let winner = ctl.abort_rank.load(Ordering::Acquire);
    let idx = errs
        .iter()
        .position(|(r, e)| *r == winner && is_primary(e))
        .or_else(|| errs.iter().position(|(_, e)| is_primary(e)))
        .unwrap_or(0);
    let (failed_rank, cause) = &errs[idx];
    let report = FailureReport {
        failed_rank: *failed_rank,
        phase: lock_clean(&obs[*failed_rank]).1,
        kind: cause.downcast_ref::<SttsvError>().cloned(),
        cause: cause.to_string(),
        per_rank: obs.iter().map(|o| lock_clean(o).0).collect(),
        inflight_words: inflight.current.load(Ordering::Relaxed),
    };
    Err(anyhow::Error::new(report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass_counts_words() {
        // each rank sends 10 words to (rank+1) % p
        let p = 6;
        let out = run(p, |comm| {
            let to = (comm.rank + 1) % comm.p;
            let from = (comm.rank + comm.p - 1) % comm.p;
            comm.send(to, 0, vec![comm.rank as f32; 10])?;
            let got = comm.recv(from, 0)?;
            assert_eq!(got, vec![from as f32; 10]);
            Ok(comm.stats)
        })
        .unwrap();
        for s in out {
            assert_eq!(s.sent_words, 10);
            assert_eq!(s.recv_words, 10);
            assert_eq!(s.sent_bytes, 40);
            assert_eq!(s.recv_bytes, 40);
            assert_eq!(s.sent_msgs, 1);
            assert_eq!(s.recv_msgs, 1);
        }
    }

    #[test]
    fn out_of_order_tags_are_stashed() {
        for transport in [TransportKind::Mpsc, TransportKind::Spsc] {
            let (out, _) = run_cfg(2, None, RunCfg::new(transport), |comm| {
                if comm.rank == 0 {
                    comm.send(1, 7, vec![7.0])?;
                    comm.send(1, 8, vec![8.0])?;
                    Ok(0.0)
                } else {
                    // receive in reverse order
                    let b = comm.recv(0, 8)?;
                    let a = comm.recv(0, 7)?;
                    Ok(a[0] * 10.0 + b[0])
                }
            })
            .unwrap();
            assert_eq!(out[1], 78.0, "{transport}");
        }
    }

    #[test]
    fn barrier_synchronizes_steps() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for transport in [TransportKind::Mpsc, TransportKind::Spsc] {
            let counter = AtomicUsize::new(0);
            let p = 4;
            run_cfg(p, None, RunCfg::new(transport), |comm| {
                for step in 0..3 {
                    counter.fetch_add(1, Ordering::SeqCst);
                    comm.barrier();
                    // after the barrier, all p increments of this step happened
                    let c = counter.load(Ordering::SeqCst);
                    assert!(c >= (step + 1) * p, "step {step}: {c}");
                    comm.barrier();
                }
                Ok(())
            })
            .unwrap();
            assert_eq!(counter.load(Ordering::SeqCst), 3 * p);
        }
    }

    #[test]
    fn allreduce_sum_pattern() {
        // naive allreduce: everyone sends to 0, 0 broadcasts
        let p = 5;
        let out = run(p, |comm| {
            if comm.rank == 0 {
                let mut acc = 1.0; // own value
                for r in 1..comm.p {
                    acc += comm.recv(r, 1)?[0];
                }
                for r in 1..comm.p {
                    comm.send(r, 2, vec![acc])?;
                }
                Ok(acc)
            } else {
                comm.send(0, 1, vec![1.0])?;
                Ok(comm.recv(0, 2)?[0])
            }
        })
        .unwrap();
        assert!(out.iter().all(|&v| v == p as f32));
    }

    /// Comm-only ring exchange over the nonblocking API (no tensor, no
    /// compute): every rank isends to both neighbors, then drains arrivals
    /// with try_recv/recv_any + recv_into. Used to pin (a) stats parity
    /// with the blocking API and (b) steady-state buffer reuse, on both
    /// transports.
    fn nonblocking_ring(
        p: usize,
        words: usize,
        pools: &[Mutex<BufPool>],
        cfg: RunCfg,
    ) -> Vec<CommStats> {
        let (out, _) = run_cfg(p, Some(pools), cfg, |comm| {
            let me = comm.rank;
            let next = (me + 1) % comm.p;
            let prev = (me + comm.p - 1) % comm.p;
            let payload = vec![me as f32; words];
            comm.isend(next, 1, &payload)?;
            comm.isend(prev, 2, &payload)?;
            let mut pending = 2;
            let mut buf = vec![0.0f32; words];
            while pending > 0 {
                let (from, tag) = match comm.try_recv() {
                    Some(key) => key,
                    None => comm.recv_any()?,
                };
                comm.recv_into(from, tag, &mut buf)?;
                assert!(buf.iter().all(|&v| v == from as f32));
                pending -= 1;
            }
            Ok(comm.stats)
        })
        .unwrap();
        out
    }

    #[test]
    fn nonblocking_api_matches_blocking_stats() {
        // Identical exchange pattern through both APIs: per-rank CommStats
        // must be exactly equal (the §Perf P8 accounting invariant).
        let (p, words) = (5usize, 17usize);
        let blocking = run(p, |comm| {
            let me = comm.rank;
            let next = (me + 1) % comm.p;
            let prev = (me + comm.p - 1) % comm.p;
            comm.send(next, 1, vec![me as f32; words])?;
            comm.send(prev, 2, vec![me as f32; words])?;
            comm.recv(prev, 1)?;
            comm.recv(next, 2)?;
            Ok(comm.stats)
        })
        .unwrap();
        let pools: Vec<Mutex<BufPool>> = (0..p).map(|_| Mutex::new(BufPool::new())).collect();
        let nonblocking = nonblocking_ring(p, words, &pools, RunCfg::default());
        assert_eq!(blocking, nonblocking);
    }

    #[test]
    fn spsc_transport_matches_mpsc_stats_exactly() {
        // The same exchange (neighbor isends + recursive-doubling
        // allreduce on awkward odd P) on both backends: per-rank counters
        // and allreduce results must be identical — the simulator-level
        // core of property P11.
        let (p, words) = (5usize, 23usize);
        let run_one = |transport| {
            run_cfg(p, None, RunCfg::new(transport), |comm| {
                let me = comm.rank;
                let next = (me + 1) % comm.p;
                let prev = (me + comm.p - 1) % comm.p;
                let payload = vec![me as f32 + 0.5; words];
                comm.isend(next, 1, &payload)?;
                comm.isend(prev, 2, &payload)?;
                let mut buf = vec![0.0f32; words];
                comm.recv_into(prev, 1, &mut buf)?;
                comm.recv_into(next, 2, &mut buf)?;
                let total = comm.allreduce_scalar(buf[0])?;
                Ok((total, comm.stats))
            })
            .unwrap()
            .0
        };
        let mpsc_out = run_one(TransportKind::Mpsc);
        let spsc_out = run_one(TransportKind::Spsc);
        assert_eq!(mpsc_out, spsc_out);
        for (rank, (_, stats)) in mpsc_out.iter().enumerate() {
            let mut want = CommStats {
                sent_words: 2 * words as u64,
                recv_words: 2 * words as u64,
                sent_bytes: 8 * words as u64,
                recv_bytes: 8 * words as u64,
                sent_msgs: 2,
                recv_msgs: 2,
            };
            want.absorb(&allreduce_stats(p, rank, 1));
            assert_eq!(*stats, want, "rank {rank}");
        }
    }

    #[test]
    fn spsc_warm_pools_and_sized_slots_are_allocation_free() {
        // With ring slots sized to the message width, a warmed-up spsc run
        // performs zero payload heap activity: isends write in place and
        // recv_into draws from the adopted-buffer pool.
        let (p, words) = (4usize, 33usize);
        let mut cfg = RunCfg::new(TransportKind::Spsc);
        cfg.slot_words = words;
        let pools: Vec<Mutex<BufPool>> = (0..p).map(|_| Mutex::new(BufPool::new())).collect();
        nonblocking_ring(p, words, &pools, cfg);
        let (_, metrics) = run_cfg(p, Some(&pools), cfg, |comm| {
            let me = comm.rank;
            let next = (me + 1) % comm.p;
            let prev = (me + comm.p - 1) % comm.p;
            let payload = vec![me as f32; words];
            comm.isend(next, 1, &payload)?;
            comm.isend(prev, 2, &payload)?;
            let mut buf = vec![0.0f32; words];
            comm.recv_into(prev, 1, &mut buf)?;
            comm.recv_into(next, 2, &mut buf)?;
            Ok(())
        })
        .unwrap();
        assert_eq!(
            metrics.fresh_payload_allocs, 0,
            "warm spsc run must not touch the heap for payloads"
        );
    }

    #[test]
    fn spsc_blocked_recv_fails_fast_when_all_peers_exit() {
        // Rank 1 waits for a message rank 0 never sends; once rank 0
        // exits, the blocked receive must error out (typed PeersGone)
        // instead of hanging the run.
        let out = run_cfg(2, None, RunCfg::new(TransportKind::Spsc), |comm| {
            if comm.rank == 0 {
                Ok(String::new())
            } else {
                match comm.recv(0, 42) {
                    Ok(_) => panic!("received a message nobody sent"),
                    Err(e) => Ok(e.to_string()),
                }
            }
        })
        .unwrap();
        assert!(
            out[1].contains("all peers exited"),
            "unexpected error text: {}",
            out[1]
        );
    }

    #[test]
    fn mpsc_blocked_recv_fails_fast_when_all_peers_exit() {
        // The oracle backend gained the same fail-fast liveness check the
        // spsc rings always had (§Rob satellite): no more indefinite
        // block on a message nobody will ever send.
        let out = run_cfg(2, None, RunCfg::default(), |comm| {
            if comm.rank == 0 {
                Ok(String::new())
            } else {
                match comm.recv(0, 42) {
                    Ok(_) => panic!("received a message nobody sent"),
                    Err(e) => Ok(e.to_string()),
                }
            }
        })
        .unwrap();
        assert!(
            out[1].contains("all peers exited"),
            "unexpected error text: {}",
            out[1]
        );
    }

    #[test]
    fn recv_watchdog_surfaces_typed_timeout_on_both_backends() {
        // Rank 1 blocks on a message a stuck-but-ALIVE rank 0 never
        // sends; the watchdog must fire with the concrete awaited key
        // (SttsvError::Timeout, upgraded from the generic stall), and the
        // run must report a structured FailureReport blaming rank 1.
        for kind in [TransportKind::Mpsc, TransportKind::Spsc] {
            let mut cfg = RunCfg::new(kind);
            cfg.recv_timeout = Some(Duration::from_millis(50));
            let hold = AtomicBool::new(false);
            let err = run_cfg(2, None, cfg, |comm| {
                if comm.rank == 0 {
                    // Stay alive (poll the flag) until rank 1 has failed,
                    // so liveness fail-fast cannot mask the watchdog.
                    while !hold.load(Ordering::Acquire) {
                        std::thread::sleep(Duration::from_millis(1));
                        if comm.check_abort().is_err() {
                            break;
                        }
                    }
                    Ok(())
                } else {
                    let res = comm.recv(0, 42).map(|_| ());
                    hold.store(true, Ordering::Release);
                    res
                }
            })
            .unwrap_err();
            let report = err
                .downcast_ref::<FailureReport>()
                .unwrap_or_else(|| panic!("[{kind}] expected FailureReport, got: {err}"));
            assert_eq!(report.failed_rank, 1, "[{kind}]");
            assert_eq!(
                report.kind,
                Some(SttsvError::Timeout { from: 0, tag: 42 }),
                "[{kind}] cause: {}",
                report.cause
            );
        }
    }

    #[test]
    fn dead_rank_aborts_peers_within_bounded_time() {
        // Rank 0 fails immediately; every other rank is blocked on a
        // receive (no watchdog configured). The cooperative abort must
        // unwind them all and the report must blame rank 0's typed
        // crash, not the secondary Aborted casualties.
        for kind in [TransportKind::Mpsc, TransportKind::Spsc] {
            let cfg = RunCfg::new(kind);
            let started = Instant::now();
            let err = run_cfg(4, None, cfg, |comm| {
                if comm.rank == 0 {
                    Err(SttsvError::Crashed { rank: 0, at_op: 0 }.into())
                } else {
                    comm.recv(0, 7).map(|_| ())
                }
            })
            .unwrap_err();
            assert!(
                started.elapsed() < Duration::from_secs(10),
                "[{kind}] abort unwind took too long"
            );
            let report = err
                .downcast_ref::<FailureReport>()
                .unwrap_or_else(|| panic!("[{kind}] expected FailureReport, got: {err}"));
            assert_eq!(report.failed_rank, 0, "[{kind}] cause: {}", report.cause);
            assert_eq!(report.kind, Some(SttsvError::Crashed { rank: 0, at_op: 0 }));
        }
    }

    #[test]
    fn dead_rank_releases_peers_blocked_on_a_barrier() {
        // Same, but the healthy ranks are parked at a BARRIER the dead
        // rank will never arrive at — the abort-aware barriers must
        // release them (they then unwind at their next fallible op or
        // complete; either way the run terminates and blames rank 0).
        for kind in [TransportKind::Mpsc, TransportKind::Spsc] {
            let err = run_cfg(4, None, RunCfg::new(kind), |comm| {
                if comm.rank == 0 {
                    Err(SttsvError::Crashed { rank: 0, at_op: 0 }.into())
                } else {
                    comm.barrier();
                    comm.check_abort()
                }
            })
            .unwrap_err();
            let report = err
                .downcast_ref::<FailureReport>()
                .unwrap_or_else(|| panic!("[{kind}] expected FailureReport, got: {err}"));
            assert_eq!(report.failed_rank, 0, "[{kind}] cause: {}", report.cause);
        }
    }

    #[test]
    fn worker_panic_is_contained_and_typed() {
        // A panicking body must become SttsvError::Panicked in a
        // FailureReport — not a process abort, not a poisoned-lock
        // cascade — and lent pools must stay usable afterwards.
        let pools: Vec<Mutex<BufPool>> = (0..2).map(|_| Mutex::new(BufPool::new())).collect();
        let err = run_cfg(2, Some(&pools), RunCfg::default(), |comm| -> Result<()> {
            if comm.rank == 0 {
                panic!("worker body exploded");
            }
            comm.barrier();
            Ok(())
        })
        .unwrap_err();
        let report = err.downcast_ref::<FailureReport>().expect("FailureReport");
        assert_eq!(report.failed_rank, 0);
        match &report.kind {
            Some(SttsvError::Panicked { rank: 0, msg }) => {
                assert!(msg.contains("exploded"), "panic message lost: {msg}");
            }
            other => panic!("expected Panicked, got {other:?}"),
        }
        // The pools survived the panic (poison-recovering access).
        let (_, metrics) = run_cfg(2, Some(&pools), RunCfg::default(), |comm| {
            let peer = 1 - comm.rank;
            comm.isend(peer, 1, &[1.0, 2.0])?;
            let mut buf = [0.0f32; 2];
            comm.recv_into(peer, 1, &mut buf)?;
            Ok(())
        })
        .unwrap();
        let _ = metrics;
    }

    #[test]
    fn zero_fault_chaos_wrapper_is_transparent() {
        // A nonzero-seed, zero-rate, crash-free plan wraps the transport
        // in the chaos decorator but must be observationally invisible:
        // bitwise-identical results and identical CommStats.
        let body = |comm: &mut Comm| {
            let to = (comm.rank + 1) % comm.p;
            let from = (comm.rank + comm.p - 1) % comm.p;
            comm.send(to, 0, vec![comm.rank as f32 + 0.25; 9])?;
            let got = comm.recv(from, 0)?;
            let s = comm.allreduce_scalar(got.iter().sum())?;
            Ok((s, comm.stats))
        };
        for kind in [TransportKind::Mpsc, TransportKind::Spsc] {
            let plain = run_cfg(5, None, RunCfg::new(kind), body).unwrap().0;
            let mut cfg = RunCfg::new(kind);
            cfg.chaos = FaultPlan::rate(12345, 0.0);
            assert!(cfg.chaos.is_zero() && cfg.chaos != FaultPlan::default());
            let wrapped = run_cfg(5, None, cfg, body).unwrap().0;
            assert_eq!(plain, wrapped, "[{kind}] zero-fault chaos must be invisible");
        }
    }

    #[test]
    fn chaos_crash_yields_failure_report_not_hang() {
        // A deterministic crash of rank 2 early in its op stream: the
        // run must terminate on both backends with a report blaming rank
        // 2's Crashed error (never a deadlock, never a panic).
        for kind in [TransportKind::Mpsc, TransportKind::Spsc] {
            let mut cfg = RunCfg::new(kind);
            cfg.chaos = FaultPlan::crash(9, 2, 0);
            let err = run_cfg(4, None, cfg, |comm| {
                let to = (comm.rank + 1) % comm.p;
                let from = (comm.rank + comm.p - 1) % comm.p;
                comm.phase = "ring";
                comm.send(to, 0, vec![1.0; 8])?;
                let _ = comm.recv(from, 0)?;
                Ok(())
            })
            .unwrap_err();
            let report = err
                .downcast_ref::<FailureReport>()
                .unwrap_or_else(|| panic!("[{kind}] expected FailureReport, got: {err}"));
            assert_eq!(report.failed_rank, 2, "[{kind}] cause: {}", report.cause);
            assert_eq!(report.kind, Some(SttsvError::Crashed { rank: 2, at_op: 0 }));
            assert_eq!(report.phase, "ring", "[{kind}]");
            assert_eq!(report.per_rank.len(), 4);
        }
    }

    #[test]
    fn fault_plan_parses_and_reseeds() {
        let plan: FaultPlan = "7,0.001".parse().unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.rate_ppm, 1000);
        assert!("7".parse::<FaultPlan>().is_err());
        assert!("7,1.5".parse::<FaultPlan>().is_err());
        // Attempt 0 is the plan itself; retries remix the stream and drop
        // the one-shot crash event.
        let crash = FaultPlan::crash(3, 1, 10);
        assert_eq!(crash.reseeded(0), crash);
        let retry = crash.reseeded(1);
        assert_eq!(retry.crash_rank, None);
        assert_ne!(retry.seed, crash.seed);
        assert_ne!(crash.reseeded(1), crash.reseeded(2));
    }

    #[test]
    fn warm_pools_make_isend_allocation_free() {
        // First run allocates one buffer per in-flight message; with the
        // pools lent across runs, the second run allocates nothing.
        let (p, words) = (4usize, 33usize);
        let pools: Vec<Mutex<BufPool>> = (0..p).map(|_| Mutex::new(BufPool::new())).collect();
        nonblocking_ring(p, words, &pools, RunCfg::default());
        let before: u64 = pools.iter().map(|pl| pl.lock().unwrap().fresh_allocs()).sum();
        assert!(before > 0, "cold run must have allocated buffers");
        let (_, metrics) = run_ext(p, Some(&pools), |comm| {
            let me = comm.rank;
            let next = (me + 1) % comm.p;
            let prev = (me + comm.p - 1) % comm.p;
            let payload = vec![me as f32; words];
            comm.isend(next, 1, &payload)?;
            comm.isend(prev, 2, &payload)?;
            let mut buf = vec![0.0f32; words];
            comm.recv_into(prev, 1, &mut buf)?;
            comm.recv_into(next, 2, &mut buf)?;
            Ok(())
        })
        .unwrap();
        assert_eq!(
            metrics.fresh_payload_allocs, 0,
            "warmed pools must serve every isend without allocating"
        );
    }

    #[test]
    fn blocking_recv_adopts_buffer_into_pool() {
        // The satellite fix for the allocating receive: `recv` now returns
        // a pool-drawn buffer and adopts the in-flight buffer, so a second
        // run over warm pools performs zero receive-side allocations.
        let pools: Vec<Mutex<BufPool>> = (0..2).map(|_| Mutex::new(BufPool::new())).collect();
        let exchange = |pools: &[Mutex<BufPool>]| {
            run_ext(2, Some(pools), |comm| {
                if comm.rank == 0 {
                    comm.send(1, 3, vec![1.0, 2.0, 3.0])?;
                    Ok(0.0)
                } else {
                    let got = comm.recv(0, 3)?;
                    Ok(got.iter().sum())
                }
            })
            .unwrap()
        };
        let (out, first) = exchange(&pools);
        assert_eq!(out[1], 6.0);
        assert!(first.fresh_payload_allocs > 0, "cold pool must allocate once");
        assert!(
            !pools[1].lock().unwrap().is_empty(),
            "receiver must have adopted the in-flight buffer"
        );
        let (out, second) = exchange(&pools);
        assert_eq!(out[1], 6.0);
        assert_eq!(
            second.fresh_payload_allocs, 0,
            "warm blocking recv must not allocate"
        );
    }

    #[test]
    fn recv_into_rejects_wrong_length() {
        let err = run(2, |comm| {
            if comm.rank == 0 {
                comm.isend(1, 0, &[1.0, 2.0, 3.0])?;
                Ok(())
            } else {
                let mut buf = vec![0.0f32; 2]; // wrong: message has 3 words
                comm.recv_into(0, 0, &mut buf)
            }
        });
        assert!(err.is_err());
    }

    #[test]
    fn allreduce_matches_closed_form_and_is_rank_deterministic() {
        // Recursive-doubling allreduce on powers of two and awkward P
        // alike: (a) every rank ends with the same bits, (b) the value is
        // the true sum, (c) per-rank CommStats equal the allreduce_stats
        // closed form — the collective side of the §Perf P9 invariant.
        for p in [2usize, 3, 4, 5, 7, 10, 14, 16] {
            for width in [1usize, 3] {
                let out = run(p, |comm| {
                    let mut buf: Vec<f32> = (0..width)
                        .map(|w| 1.0 + 0.25 * (comm.rank * width + w) as f32)
                        .collect();
                    comm.allreduce_sum(&mut buf)?;
                    Ok((buf, comm.stats))
                })
                .unwrap();
                for w in 0..width {
                    let want: f32 =
                        (0..p).map(|r| 1.0 + 0.25 * (r * width + w) as f32).sum();
                    assert!(
                        (out[0].0[w] - want).abs() < 1e-3 * want.abs().max(1.0),
                        "p={p} width={width} w={w}: {} vs {want}",
                        out[0].0[w]
                    );
                }
                for (rank, (buf, stats)) in out.iter().enumerate() {
                    assert_eq!(
                        buf, &out[0].0,
                        "p={p} width={width}: rank {rank} result differs bitwise"
                    );
                    assert_eq!(
                        *stats,
                        allreduce_stats(p, rank, width),
                        "p={p} width={width} rank {rank} stats"
                    );
                }
            }
        }
    }

    #[test]
    fn back_to_back_allreduces_use_distinct_tags() {
        // Two immediately successive collectives between the same partner
        // pairs must not collide even when one rank races ahead: the
        // per-processor tag sequence keys every instance uniquely.
        let p = 6;
        for transport in [TransportKind::Mpsc, TransportKind::Spsc] {
            let (out, _) = run_cfg(p, None, RunCfg::new(transport), |comm| {
                let a = comm.allreduce_scalar(1.0)?;
                let b = comm.allreduce_scalar(comm.rank as f32)?;
                Ok((a, b))
            })
            .unwrap();
            let rank_sum = (p * (p - 1) / 2) as f32;
            for (a, b) in out {
                assert_eq!(a, p as f32);
                assert_eq!(b, rank_sum);
            }
        }
    }

    #[test]
    fn tag_class_partitions_the_tag_space() {
        assert_eq!(TagClass::of(0), TagClass::Sweep);
        assert_eq!(TagClass::of(TAG_COLL_BASE - 1), TagClass::Sweep);
        assert_eq!(TagClass::of(TAG_COLL_BASE), TagClass::Collective);
        for tag in [0, TAG_COLL_BASE - 1, TAG_COLL_BASE, TAG_COLL_BASE + 9] {
            assert!(TagClass::Any.matches(tag));
            assert_eq!(TagClass::Sweep.matches(tag), tag < TAG_COLL_BASE);
            assert_eq!(TagClass::Collective.matches(tag), tag >= TAG_COLL_BASE);
        }
    }

    #[test]
    fn tag_filtered_polling_leaves_collective_traffic_stashed() {
        // A collective-tagged message from a racing peer must be invisible
        // to a sweep's class-filtered drain, yet stay available for a later
        // targeted receive — and the ready queues must survive the stash
        // mutation in between.
        run(2, |comm| {
            if comm.rank == 0 {
                comm.isend(1, TAG_COLL_BASE + 7, &[1.0, 2.0])?;
                comm.isend(1, 5, &[9.0])?;
                comm.barrier();
            } else {
                comm.barrier(); // sender's isends happen-before its barrier
                // Unfiltered poll sees something (draining both into the
                // stash); the sweep filter reports only the sweep tag...
                assert!(comm.try_recv().is_some());
                assert_eq!(comm.try_recv_class(TagClass::Sweep), Some((0, 5)));
                assert_eq!(
                    comm.try_recv_class(TagClass::Collective),
                    Some((0, TAG_COLL_BASE + 7))
                );
                // ...consuming the sweep message leaves a stale ready entry
                // that the next peek silently skips...
                let mut one = [0.0f32; 1];
                comm.recv_into(0, 5, &mut one)?;
                assert_eq!(one, [9.0]);
                assert!(comm.try_recv_class(TagClass::Sweep).is_none());
                // ...and the targeted receive still consumes the stashed
                // collective payload.
                let mut buf = [0.0f32; 2];
                comm.recv_into(0, TAG_COLL_BASE + 7, &mut buf)?;
                assert_eq!(buf, [1.0, 2.0]);
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn ready_queue_reports_arrival_order_within_class() {
        // Three sweep messages stashed out of order by a targeted wait:
        // class polling then reports the remaining keys oldest-first.
        run(2, |comm| {
            if comm.rank == 0 {
                comm.isend(1, 11, &[1.0])?;
                comm.isend(1, 12, &[2.0])?;
                comm.isend(1, 13, &[3.0])?;
            } else {
                // Waiting for tag 13 stashes 11 and 12 in arrival order.
                let mut buf = [0.0f32; 1];
                comm.recv_into(0, 13, &mut buf)?;
                assert_eq!(buf, [3.0]);
                assert_eq!(comm.recv_any_class(TagClass::Sweep)?, (0, 11));
                comm.recv_into(0, 11, &mut buf)?;
                assert_eq!(comm.recv_any_class(TagClass::Sweep)?, (0, 12));
                comm.recv_into(0, 12, &mut buf)?;
                assert!(comm.try_recv().is_none());
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn transport_kind_parses_and_displays() {
        assert_eq!("mpsc".parse::<TransportKind>().unwrap(), TransportKind::Mpsc);
        assert_eq!("spsc".parse::<TransportKind>().unwrap(), TransportKind::Spsc);
        assert!("tcp".parse::<TransportKind>().is_err());
        assert_eq!(TransportKind::Spsc.to_string(), "spsc");
        assert_eq!(TransportKind::default(), TransportKind::Mpsc);
    }

    #[test]
    fn commstats_absorb_and_since_are_inverse() {
        let a = CommStats {
            sent_words: 5,
            recv_words: 7,
            sent_bytes: 20,
            recv_bytes: 28,
            sent_msgs: 2,
            recv_msgs: 3,
        };
        let b = CommStats {
            sent_words: 11,
            recv_words: 13,
            sent_bytes: 22,
            recv_bytes: 26,
            sent_msgs: 4,
            recv_msgs: 5,
        };
        let mut acc = a;
        acc.absorb(&b);
        assert_eq!(acc.since(&a), b);
        assert_eq!(acc.since(&b), a);
        assert_eq!(acc.total_words(), a.total_words() + b.total_words());
    }

    #[test]
    fn inflight_peak_tracks_unconsumed_payloads() {
        // Rank 0 sends 3 messages of 10 words before rank 1 consumes any:
        // the peak in-flight gauge must reach at least 30 words.
        let pools: Vec<Mutex<BufPool>> = (0..2).map(|_| Mutex::new(BufPool::new())).collect();
        let (_, metrics) = run_ext(2, Some(&pools), |comm| {
            if comm.rank == 0 {
                for tag in 0..3u64 {
                    comm.isend(1, tag, &[0.5f32; 10])?;
                }
                comm.barrier();
            } else {
                comm.barrier(); // all three are in flight now
                let mut buf = vec![0.0f32; 10];
                for tag in 0..3u64 {
                    comm.recv_into(0, tag, &mut buf)?;
                }
            }
            Ok(())
        })
        .unwrap();
        assert!(
            metrics.peak_inflight_words >= 30,
            "peak {} < 30",
            metrics.peak_inflight_words
        );
    }

    #[test]
    fn per_query_attribution_divides_words_exactly_and_amortizes_msgs() {
        // An r-deep batch's stats are (r × words, same msgs) of the
        // single-query sweep; attribution must invert that exactly.
        let single = CommStats {
            sent_words: 12,
            recv_words: 20,
            sent_bytes: 48,
            recv_bytes: 80,
            sent_msgs: 6,
            recv_msgs: 6,
        };
        for r in [1usize, 2, 4, 8] {
            let batch = CommStats {
                sent_words: single.sent_words * r as u64,
                recv_words: single.recv_words * r as u64,
                sent_bytes: single.sent_bytes * r as u64,
                recv_bytes: single.recv_bytes * r as u64,
                sent_msgs: single.sent_msgs,
                recv_msgs: single.recv_msgs,
            };
            let share = batch.per_query(r);
            assert_eq!(share.sent_words, single.sent_words, "r={r}");
            assert_eq!(share.recv_words, single.recv_words, "r={r}");
            assert_eq!(share.sent_bytes, single.sent_bytes, "r={r}");
            assert_eq!(share.recv_bytes, single.recv_bytes, "r={r}");
            assert_eq!(share.sent_msgs, single.sent_msgs as f64 / r as f64, "r={r}");
            assert_eq!(share.recv_msgs, single.recv_msgs as f64 / r as f64, "r={r}");
        }
    }

    #[test]
    fn wire_format_parses_and_displays() {
        assert_eq!("f32".parse::<WireFormat>().unwrap(), WireFormat::F32);
        assert_eq!("bf16".parse::<WireFormat>().unwrap(), WireFormat::Bf16);
        assert!("f16".parse::<WireFormat>().is_err());
        assert_eq!(WireFormat::Bf16.to_string(), "bf16");
        assert_eq!(WireFormat::default(), WireFormat::F32);
        // bf16 packs only the sweep tag class; collectives stay 4-byte.
        assert!(WireFormat::Bf16.packs(0));
        assert!(!WireFormat::Bf16.packs(TAG_COLL_BASE));
        assert!(!WireFormat::F32.packs(0));
        assert_eq!(WireFormat::Bf16.bytes_per_word(0), 2);
        assert_eq!(WireFormat::Bf16.bytes_per_word(TAG_COLL_BASE), 4);
        assert_eq!(WireFormat::F32.bytes_per_word(0), 4);
    }

    #[test]
    fn bf16_roundtrip_is_within_relative_bound() {
        // Round-to-nearest-even truncation keeps 8 mantissa bits: the
        // relative error of a pack/expand round trip is ≤ 2⁻⁸ ≤ 2⁻⁷ per
        // entry (the P14 bound), and specials survive.
        let mut x = 0.7f32;
        for _ in 0..200 {
            x = (x * 1.7 + 0.13).fract() * 1e3 - 500.0;
            let back = bf16_expand(bf16_bits(x));
            assert!(
                (back - x).abs() <= x.abs() * (1.0 / 128.0),
                "{x} -> {back}"
            );
        }
        assert_eq!(bf16_expand(bf16_bits(0.0)), 0.0);
        assert_eq!(bf16_expand(bf16_bits(-1.0)), -1.0);
        assert_eq!(bf16_expand(bf16_bits(f32::INFINITY)), f32::INFINITY);
        assert!(bf16_expand(bf16_bits(f32::NAN)).is_nan());
        // Exactly representable values (8-bit mantissa) are bit-preserved.
        for v in [1.0f32, -2.5, 0.15625, 384.0] {
            assert_eq!(bf16_expand(bf16_bits(v)), v);
        }
    }

    #[test]
    fn bf16_pack_unpack_handles_odd_lengths() {
        for len in [1usize, 2, 5, 8, 33] {
            let src: Vec<f32> = (0..len).map(|i| 1.0 + i as f32 * 0.25).collect();
            let mut packed = Vec::new();
            pack_bf16(&src, &mut packed);
            assert_eq!(packed.len(), len.div_ceil(2));
            let mut out = vec![0.0f32; len];
            unpack_bf16(&packed, &mut out);
            // Quarters below 4096 are exactly representable in bf16's
            // 8-bit mantissa only up to 2^8/4; just check the bound.
            for (a, b) in src.iter().zip(&out) {
                assert!((a - b).abs() <= a.abs() / 128.0, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn bf16_wire_halves_bytes_at_identical_words() {
        // The tentpole invariant at simulator level: a bf16 ring exchange
        // charges exactly the f32 words/messages but half the bytes, on
        // both transports, including an odd payload length (whose final
        // half-container is padding excluded from the byte count... the
        // count is 2·words exactly, not 4·ceil(words/2)).
        for transport in [TransportKind::Mpsc, TransportKind::Spsc] {
            for words in [10usize, 17] {
                let run_one = |wire| {
                    let mut cfg = RunCfg::new(transport);
                    cfg.wire = wire;
                    let (out, _) = run_cfg(4, None, cfg, |comm| {
                        let me = comm.rank;
                        let next = (me + 1) % comm.p;
                        let prev = (me + comm.p - 1) % comm.p;
                        let payload: Vec<f32> =
                            (0..words).map(|i| (me * words + i) as f32 * 0.5).collect();
                        comm.isend(next, 1, &payload)?;
                        let mut buf = vec![0.0f32; words];
                        comm.recv_into(prev, 1, &mut buf)?;
                        Ok((buf, comm.stats))
                    })
                    .unwrap();
                    out
                };
                let f32_out = run_one(WireFormat::F32);
                let bf16_out = run_one(WireFormat::Bf16);
                for ((fbuf, fs), (bbuf, bs)) in f32_out.iter().zip(&bf16_out) {
                    assert_eq!(fs.sent_words, bs.sent_words, "{transport} {words}");
                    assert_eq!(fs.recv_words, bs.recv_words, "{transport} {words}");
                    assert_eq!(fs.sent_msgs, bs.sent_msgs, "{transport} {words}");
                    assert_eq!(fs.recv_msgs, bs.recv_msgs, "{transport} {words}");
                    assert_eq!(fs.sent_bytes, 4 * words as u64);
                    assert_eq!(bs.sent_bytes, 2 * words as u64);
                    assert_eq!(bs.recv_bytes, 2 * words as u64);
                    for (a, b) in fbuf.iter().zip(bbuf) {
                        assert!((a - b).abs() <= a.abs() / 128.0, "{a} vs {b}");
                    }
                }
            }
        }
    }

    #[test]
    fn bf16_wire_leaves_collectives_exact() {
        // Collectives must be byte-exact f32 under a bf16 wire: the sums
        // stay bitwise rank-deterministic and their stats charge 4
        // bytes/word (allreduce_stats closed form already does).
        let mut cfg = RunCfg::default();
        cfg.wire = WireFormat::Bf16;
        let (out, _) = run_cfg(5, None, cfg, |comm| {
            // 1/3 is inexact in bf16; a packed collective would perturb it.
            let s = comm.allreduce_scalar((1.0f32 / 3.0) * (comm.rank as f32 + 1.0))?;
            Ok((s, comm.stats))
        })
        .unwrap();
        let want: f32 = (0..5).map(|r| (1.0f32 / 3.0) * (r as f32 + 1.0)).sum::<f32>();
        for (rank, (s, stats)) in out.iter().enumerate() {
            assert_eq!(s.to_bits(), out[0].0.to_bits(), "rank {rank} not bitwise");
            assert!((s - want).abs() < 1e-5);
            assert_eq!(*stats, allreduce_stats(5, rank, 1), "rank {rank}");
        }
    }

    #[test]
    fn bf16_roundtrip_edge_cases() {
        // ±inf survive exactly (the 8-bit exponent is kept whole).
        assert_eq!(bf16_bits(f32::INFINITY), 0x7f80);
        assert_eq!(bf16_bits(f32::NEG_INFINITY), 0xff80);
        assert_eq!(bf16_expand(bf16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_expand(bf16_bits(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        // NaN quieting: every NaN stays NaN after the round trip — in
        // particular a signaling NaN whose surviving mantissa bits would
        // all round away must pick up the quiet bit instead of decaying
        // to ±inf.
        for bits in [0x7fc0_0001u32, 0x7f80_0001, 0xffbf_ffff, 0x7f8f_0000] {
            let v = f32::from_bits(bits);
            assert!(v.is_nan());
            let half = bf16_bits(v);
            assert!(half & 0x0040 != 0, "quiet bit set for {bits:#010x}");
            assert!(bf16_expand(half).is_nan(), "{bits:#010x} decayed to non-NaN");
            assert_eq!(half >> 15, (bits >> 31) as u16, "sign preserved");
        }
        // Subnormals: bf16 shares f32's exponent range, so small f32
        // subnormals round to (signed) zero and the largest ones round up
        // into bf16's subnormal/normal boundary — monotonically.
        assert_eq!(bf16_bits(f32::from_bits(0x0000_0001)), 0x0000);
        assert_eq!(bf16_bits(f32::from_bits(0x8000_0001)), 0x8000);
        // Largest f32 subnormal 0x007fffff rounds up to the smallest
        // normal bf16 0x0080 (RNE carries across the exponent boundary).
        assert_eq!(bf16_bits(f32::from_bits(0x007f_ffff)), 0x0080);
        assert_eq!(bf16_expand(0x0080), f32::from_bits(0x0080_0000));
        // RNE ties-to-even at the half-ULP boundary: lower half exactly
        // 0x8000 rounds to the EVEN upper half — down when already even,
        // up when odd.
        assert_eq!(bf16_bits(f32::from_bits(0x3f80_8000)), 0x3f80); // even: down
        assert_eq!(bf16_bits(f32::from_bits(0x3f81_8000)), 0x3f82); // odd: up
        // Just past the tie always rounds up; just under always down.
        assert_eq!(bf16_bits(f32::from_bits(0x3f80_8001)), 0x3f81);
        assert_eq!(bf16_bits(f32::from_bits(0x3f80_7fff)), 0x3f80);
        // Random roundtrip: |x − expand(pack(x))| ≤ 2⁻⁸·|x| for normals.
        let mut rng = crate::util::rng::Rng::new(0xb16e);
        for _ in 0..4096 {
            let v = rng.normal_f32() * 1e3;
            let back = bf16_expand(bf16_bits(v));
            assert!((v - back).abs() <= v.abs() / 256.0, "{v} -> {back}");
        }
    }

    #[test]
    fn fletcher32_detects_every_single_bit_flip() {
        // Exhaustive: over a payload of mixed magnitudes (including 0.0,
        // whose containers are all-zero), flipping ANY single bit of any
        // container — or of the checksum word itself — is detected.
        let payload: Vec<f32> = vec![0.0, 1.0, -2.5e-3, 3.4e38, 1.17e-38, -0.0, 7.0];
        let ck = fletcher32(&payload);
        for i in 0..payload.len() {
            for bit in 0..32 {
                let mut flipped = payload.clone();
                flipped[i] = f32::from_bits(flipped[i].to_bits() ^ (1u32 << bit));
                assert_ne!(fletcher32(&flipped), ck, "missed flip word {i} bit {bit}");
            }
        }
        for bit in 0..32 {
            assert_ne!(ck ^ (1u32 << bit), ck);
        }
    }

    #[test]
    fn abft_integrity_word_bills_one_word_and_detects_wire_flips() {
        // Zero faults: the framed ring pass succeeds bitwise and each
        // rank's counters carry exactly +1 word (+bytes_per_word bytes)
        // per sweep message, on both transports and both wire formats.
        for transport in [TransportKind::Mpsc, TransportKind::Spsc] {
            for wire in [WireFormat::F32, WireFormat::Bf16] {
                let mut cfg = RunCfg::new(transport);
                cfg.wire = wire;
                cfg.abft = AbftMode::Verify;
                cfg.slot_words = 32;
                let words = 9usize;
                let (out, _) = run_cfg(4, None, cfg, |comm| {
                    let me = comm.rank;
                    let next = (me + 1) % comm.p;
                    let prev = (me + comm.p - 1) % comm.p;
                    let payload: Vec<f32> =
                        (0..words).map(|i| (me * words + i) as f32 * 0.25).collect();
                    comm.isend(next, 1, &payload)?;
                    let mut buf = vec![0.0f32; words];
                    comm.recv_into(prev, 1, &mut buf)?;
                    // Collectives stay exempt — and exact.
                    let s = comm.allreduce_scalar(1.0)?;
                    Ok((buf, s, comm.stats))
                })
                .unwrap();
                let bpw = wire.bytes_per_word(1);
                for (rank, (buf, s, stats)) in out.iter().enumerate() {
                    let prev = (rank + 4 - 1) % 4;
                    for (i, v) in buf.iter().enumerate() {
                        let want = (prev * words + i) as f32 * 0.25;
                        if wire == WireFormat::F32 {
                            assert_eq!(v.to_bits(), want.to_bits());
                        } else {
                            assert!((v - want).abs() <= want.abs() / 128.0);
                        }
                    }
                    assert_eq!(*s, 4.0);
                    let coll = allreduce_stats(4, rank, 1);
                    assert_eq!(stats.sent_words - coll.sent_words, words as u64 + 1);
                    assert_eq!(stats.sent_bytes - coll.sent_bytes, bpw * (words as u64 + 1));
                    assert_eq!(stats.recv_words - coll.recv_words, words as u64 + 1);
                    assert_eq!(stats.sent_msgs - coll.sent_msgs, 1);
                }
            }
        }
        // Every injected wire flip (rate 1.0 ⇒ every sweep send) is
        // caught by recv_into and surfaces as a typed Corrupt — including
        // under bf16 packing, and wherever in the message the bit lands.
        for wire in [WireFormat::F32, WireFormat::Bf16] {
            for seed in 1..=8u64 {
                let mut cfg = RunCfg::default();
                cfg.wire = wire;
                cfg.abft = AbftMode::Verify;
                cfg.chaos = FaultPlan::bit_flip(seed, 1_000_000, 0);
                let err = run_cfg(3, None, cfg, |comm| {
                    let me = comm.rank;
                    let next = (me + 1) % comm.p;
                    let prev = (me + comm.p - 1) % comm.p;
                    comm.phase = "sweep";
                    comm.isend(next, 1, &[1.0, 2.0, 3.0, 4.0, 5.0])?;
                    let mut buf = vec![0.0f32; 5];
                    comm.recv_into(prev, 1, &mut buf)?;
                    Ok(())
                })
                .unwrap_err();
                let report = err.downcast_ref::<FailureReport>().expect("typed report");
                assert!(
                    matches!(report.kind, Some(SttsvError::Corrupt { .. })),
                    "{wire} seed {seed}: root cause {:?} not Corrupt",
                    report.kind
                );
            }
        }
    }
}
