//! The instrumented α-β-γ machine (paper §3.1).
//!
//! P virtual processors run as OS threads with private state and
//! communicate *only* by message passing through per-processor mailboxes.
//! Every send/receive is counted in words (f32 elements) and messages —
//! exactly the quantities the paper's lower bound constrains. A shared
//! barrier lets algorithms execute stepped schedules, enforcing the model's
//! "one send and one receive per step" discipline (which the schedule
//! itself guarantees by construction; validation happens in `schedule`).
//!
//! This simulator is the faithful substitute for a physical MPI cluster:
//! the paper's claims are word counts per processor in an abstract model,
//! and the simulator measures them exactly (see DESIGN.md §5).
//!
//! Two communication APIs share the counters (§Perf P8):
//!
//! * **Blocking** ([`Comm::send`] / [`Comm::recv`]) — the original stepped
//!   API. Each message owns a freshly allocated `Vec<f32>`.
//! * **Nonblocking, buffer-reusing** ([`Comm::isend`], [`Comm::try_recv`],
//!   [`Comm::recv_any`], [`Comm::recv_into`]) — the MPI
//!   `Isend`/`Iprobe`/`Recv`-into-registered-buffer shape. `isend` copies
//!   the borrowed payload into a buffer drawn from a per-processor
//!   [`BufPool`]; the receiver delivers straight into a caller slice and
//!   adopts the in-flight buffer into its own pool (ownership migrates
//!   with the message — since every protocol here sends and receives the
//!   same number of messages per processor, pools stay balanced and the
//!   steady state performs **zero per-message heap allocations**, with no
//!   return-channel race against early worker teardown). Word/message
//!   accounting is identical to the blocking API (asserted in tests).
//!
//! **Collectives** (§Perf P9): [`Comm::allreduce_sum`] /
//! [`Comm::allreduce_scalar`] implement recursive-doubling allreduce over
//! the same counted fabric — O(log P) messages of `width` words per
//! processor, closed form in [`allreduce_stats`]. Results are *bitwise
//! identical on every rank* (each rank combines the same operand tree, and
//! f32 addition is commutative), which is what lets resident solver
//! sessions take the converge-or-continue branch unanimously with no host
//! round trip. Collective tags live above [`TAG_COLL_BASE`] and are
//! sequence-numbered per processor, so they never collide with algorithm
//! traffic; the tag-filtered polling variants
//! ([`Comm::try_recv_matching`] / [`Comm::recv_any_matching`]) let an
//! event-loop worker drain its own messages while a faster peer's
//! collective traffic waits in the stash.

pub mod cost;

use anyhow::{anyhow, ensure, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Barrier, Mutex};

/// Per-processor communication counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CommStats {
    /// f32 words sent / received (payload only — the bandwidth cost β·W).
    pub sent_words: u64,
    pub recv_words: u64,
    /// messages sent / received (the latency cost α·S).
    pub sent_msgs: u64,
    pub recv_msgs: u64,
}

impl CommStats {
    /// Total words moved through this processor's NIC.
    pub fn total_words(&self) -> u64 {
        self.sent_words + self.recv_words
    }

    /// Accumulate another counter set into this one — THE aggregation
    /// primitive (iteration totals, bench sums); replaces the hand-rolled
    /// four-field loops that used to live in `apps` and the benches.
    pub fn absorb(&mut self, other: &CommStats) {
        self.sent_words += other.sent_words;
        self.recv_words += other.recv_words;
        self.sent_msgs += other.sent_msgs;
        self.recv_msgs += other.recv_msgs;
    }

    /// Counter delta since an earlier snapshot of the same processor's
    /// stats (used for per-iteration accounting in resident sessions).
    pub fn since(&self, earlier: &CommStats) -> CommStats {
        CommStats {
            sent_words: self.sent_words - earlier.sent_words,
            recv_words: self.recv_words - earlier.recv_words,
            sent_msgs: self.sent_msgs - earlier.sent_msgs,
            recv_msgs: self.recv_msgs - earlier.recv_msgs,
        }
    }
}

/// Collective tags live at and above this value; all point-to-point
/// algorithm traffic (stepped exchange tags, overlap gather/reduce tags)
/// stays below it, so `tag < TAG_COLL_BASE` cleanly separates the two
/// streams for the tag-filtered polling APIs.
pub const TAG_COLL_BASE: u64 = 1 << 32;

/// Largest power of two ≤ p (the recursive-doubling core size).
fn pow2_floor(p: usize) -> usize {
    let mut pp = 1usize;
    while pp * 2 <= p {
        pp *= 2;
    }
    pp
}

/// Closed-form per-rank cost of ONE [`Comm::allreduce_sum`] over `width`
/// words on `p` processors (recursive doubling with the standard
/// fold-in/fold-out for non-powers of two):
///
/// * ranks ≥ 2^⌊log₂P⌋ (the "extra" ranks): 1 message each way;
/// * ranks < P − 2^⌊log₂P⌋ (partners of an extra rank): ⌊log₂P⌋ + 1
///   messages each way;
/// * all other ranks: ⌊log₂P⌋ messages each way;
///
/// each message `width` words. Asserted equal to the measured counters in
/// the simulator tests, and the "O(log P) scalar words" term of the
/// resident-session per-iteration invariant (§Perf P9).
pub fn allreduce_stats(p: usize, rank: usize, width: usize) -> CommStats {
    if p <= 1 {
        return CommStats::default();
    }
    let pp = pow2_floor(p);
    let rem = p - pp;
    let lg = pp.trailing_zeros() as u64;
    let msgs = if rank >= pp {
        1
    } else if rank < rem {
        lg + 1
    } else {
        lg
    };
    CommStats {
        sent_words: msgs * width as u64,
        recv_words: msgs * width as u64,
        sent_msgs: msgs,
        recv_msgs: msgs,
    }
}

/// A pool of reusable payload buffers (one per processor). Buffers are
/// drawn best-fit by [`Comm::isend`], travel with the packet, and are
/// adopted into the *receiver's* pool on delivery (symmetric protocols
/// keep the pools balanced); `fresh_allocs` counts every buffer
/// allocation or capacity growth the pool had to perform — zero on a
/// warmed-up pool. Lend pools across repeated [`run_ext`] calls (as
/// `coordinator::SttsvPlan` does) to make iterative workloads
/// allocation-free on the communication hot path.
#[derive(Debug, Default)]
pub struct BufPool {
    bufs: Vec<Vec<f32>>,
    fresh_allocs: u64,
}

impl BufPool {
    pub fn new() -> Self {
        BufPool::default()
    }

    /// Buffers currently parked in the pool.
    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    /// Total buffer allocations (or capacity growths) this pool has ever
    /// had to perform.
    pub fn fresh_allocs(&self) -> u64 {
        self.fresh_allocs
    }

    fn take(&mut self, cap: usize) -> Vec<f32> {
        // Best fit: the smallest pooled buffer whose capacity already
        // covers `cap`. The full exchange protocols send and receive the
        // same multiset of message sizes per processor per run, so a warm
        // pool always holds an adequate buffer and the steady state is
        // free of allocations AND growth reallocations; a too-small pick
        // would reallocate inside the caller's extend, which is why growth
        // is counted here — `fresh_allocs == 0` means zero payload heap
        // activity, not just zero pool misses. Pools hold at most a few
        // dozen buffers, so the scan is noise.
        let mut best: Option<(usize, usize)> = None; // (index, capacity)
        for (i, b) in self.bufs.iter().enumerate() {
            let c = b.capacity();
            if c >= cap {
                match best {
                    Some((_, bc)) if bc <= c => {}
                    _ => best = Some((i, c)),
                }
            }
        }
        match best {
            Some((i, _)) => self.bufs.swap_remove(i),
            None => {
                self.fresh_allocs += 1;
                match self.bufs.pop() {
                    Some(mut b) => {
                        b.reserve(cap);
                        b
                    }
                    None => Vec::with_capacity(cap),
                }
            }
        }
    }

    fn put(&mut self, mut buf: Vec<f32>) {
        buf.clear();
        self.bufs.push(buf);
    }
}

/// Cross-processor gauge of payload words currently in flight (sent, not
/// yet delivered), with a high-water mark — the E12 "peak in-flight
/// payload" metric. Overlap trades higher in-flight occupancy for the
/// removed barriers; the model cost (words, messages) is unchanged.
#[derive(Debug, Default)]
struct InflightGauge {
    current: AtomicU64,
    peak: AtomicU64,
}

impl InflightGauge {
    fn add(&self, words: u64) {
        let now = self.current.fetch_add(words, Ordering::Relaxed) + words;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    fn sub(&self, words: u64) {
        self.current.fetch_sub(words, Ordering::Relaxed);
    }
}

/// Whole-run metrics reported by [`run_ext`].
#[derive(Debug, Default, Clone, Copy)]
pub struct RunMetrics {
    /// Max total payload words simultaneously in flight at any instant.
    pub peak_inflight_words: u64,
    /// Payload buffers freshly allocated during this run (0 when every
    /// `isend` was served from a warmed-up [`BufPool`]).
    pub fresh_payload_allocs: u64,
}

struct Packet {
    from: usize,
    tag: u64,
    data: Vec<f32>,
}

/// A processor's communication endpoint inside [`run`].
pub struct Comm {
    /// This processor's rank in 0..P.
    pub rank: usize,
    /// Total number of processors.
    pub p: usize,
    senders: Vec<mpsc::Sender<Packet>>,
    inbox: mpsc::Receiver<Packet>,
    /// Out-of-order buffer: packets received while waiting for another key.
    stash: HashMap<(usize, u64), Packet>,
    pool: BufPool,
    inflight: Arc<InflightGauge>,
    barrier: Arc<Barrier>,
    /// Sequence number for collective tags: every collective call on this
    /// processor consumes one tag above [`TAG_COLL_BASE`]. All processors
    /// issue collectives in the same program order, so the tags agree
    /// across ranks and every collective instance keys its messages
    /// uniquely — back-to-back allreduces between the same pair can never
    /// collide, however far one rank races ahead.
    coll_seq: u64,
    /// Word/message counters for this processor.
    pub stats: CommStats,
}

impl Comm {
    /// Send `data` to processor `to` with a matching `tag` (allocating
    /// variant: the caller-built `Vec` becomes the in-flight buffer).
    pub fn send(&mut self, to: usize, tag: u64, data: Vec<f32>) -> Result<()> {
        debug_assert_ne!(to, self.rank, "self-send is a bug in the algorithm");
        self.stats.sent_words += data.len() as u64;
        self.stats.sent_msgs += 1;
        self.inflight.add(data.len() as u64);
        self.senders[to]
            .send(Packet { from: self.rank, tag, data })
            .map_err(|_| anyhow!("processor {to} hung up"))
    }

    /// Nonblocking send from a borrowed slice: the payload is copied into a
    /// reusable buffer from this processor's pool (zero allocations once
    /// the pool is warm) and handed to `to`'s mailbox. Never blocks;
    /// identical word/message accounting to [`Comm::send`].
    pub fn isend(&mut self, to: usize, tag: u64, data: &[f32]) -> Result<()> {
        debug_assert_ne!(to, self.rank, "self-send is a bug in the algorithm");
        let mut buf = self.pool.take(data.len());
        buf.extend_from_slice(data);
        self.stats.sent_words += data.len() as u64;
        self.stats.sent_msgs += 1;
        self.inflight.add(data.len() as u64);
        self.senders[to]
            .send(Packet { from: self.rank, tag, data: buf })
            .map_err(|_| anyhow!("processor {to} hung up"))
    }

    /// Blocking receive of the message from `from` with `tag` (out-of-order
    /// deliveries are stashed). Allocating variant: ownership of the
    /// payload moves to the caller, so the buffer leaves the pool system.
    pub fn recv(&mut self, from: usize, tag: u64) -> Result<Vec<f32>> {
        let pkt = self.wait_for(from, tag)?;
        self.stats.recv_words += pkt.data.len() as u64;
        self.stats.recv_msgs += 1;
        self.inflight.sub(pkt.data.len() as u64);
        Ok(pkt.data)
    }

    /// Blocking receive delivered straight into `dst`, which must be
    /// exactly the message length; the in-flight buffer is adopted into
    /// this processor's pool for reuse by later `isend`s. Word/message
    /// accounting identical to [`Comm::recv`].
    pub fn recv_into(&mut self, from: usize, tag: u64, dst: &mut [f32]) -> Result<()> {
        let pkt = self.wait_for(from, tag)?;
        ensure!(
            pkt.data.len() == dst.len(),
            "recv_into from {from} tag {tag}: payload {} words, caller expected {}",
            pkt.data.len(),
            dst.len()
        );
        dst.copy_from_slice(&pkt.data);
        self.stats.recv_words += pkt.data.len() as u64;
        self.stats.recv_msgs += 1;
        self.inflight.sub(pkt.data.len() as u64);
        self.pool.put(pkt.data);
        Ok(())
    }

    /// Nonblocking poll: drains every packet currently in the mailbox into
    /// the stash and reports the `(from, tag)` of one available message, or
    /// `None` when nothing has arrived. Consume the reported message with
    /// [`Comm::recv_into`] (or [`Comm::recv`]) before polling again.
    pub fn try_recv(&mut self) -> Option<(usize, u64)> {
        self.try_recv_matching(|_| true)
    }

    /// [`Comm::try_recv`] restricted to tags satisfying `pred`:
    /// non-matching arrivals are stashed (not lost) but never reported.
    /// Event-loop workers poll with `|t| t < TAG_COLL_BASE` so a faster
    /// peer's collective traffic waits in the stash instead of derailing
    /// the sweep protocol.
    pub fn try_recv_matching(&mut self, pred: impl Fn(u64) -> bool) -> Option<(usize, u64)> {
        while let Ok(pkt) = self.inbox.try_recv() {
            self.stash_insert(pkt);
        }
        self.stash.keys().find(|&&(_, t)| pred(t)).copied()
    }

    /// Blocking wait for *any* message: returns the `(from, tag)` of an
    /// available packet (stashed first, then the mailbox). Like
    /// [`Comm::try_recv`], does not consume the message.
    pub fn recv_any(&mut self) -> Result<(usize, u64)> {
        self.recv_any_matching(|_| true)
    }

    /// [`Comm::recv_any`] restricted to tags satisfying `pred`: blocks
    /// until a matching message is available, stashing (never dropping)
    /// non-matching arrivals along the way.
    pub fn recv_any_matching(&mut self, pred: impl Fn(u64) -> bool) -> Result<(usize, u64)> {
        if let Some(key) = self.stash.keys().find(|&&(_, t)| pred(t)).copied() {
            return Ok(key);
        }
        loop {
            let pkt = self
                .inbox
                .recv()
                .map_err(|_| anyhow!("inbox closed while waiting for any message"))?;
            let key = (pkt.from, pkt.tag);
            self.stash_insert(pkt);
            if pred(key.1) {
                return Ok(key);
            }
        }
    }

    /// Recursive-doubling allreduce: every processor ends with the
    /// element-wise sum of all P `buf` contributions, **bitwise identical
    /// on every rank** (each rank combines the same operand tree; f32
    /// addition is commutative). Non-powers of two use the standard
    /// fold-in/fold-out. Cost per rank is [`allreduce_stats`] exactly:
    /// O(log P) messages of `buf.len()` words, fully counted in
    /// [`Comm::stats`]. All processors must call collectives in the same
    /// program order (tags are sequence-numbered per processor).
    pub fn allreduce_sum(&mut self, buf: &mut [f32]) -> Result<()> {
        let tag = TAG_COLL_BASE + self.coll_seq;
        self.coll_seq += 1;
        if self.p == 1 {
            return Ok(());
        }
        let me = self.rank;
        let pp = pow2_floor(self.p);
        let rem = self.p - pp;
        if me >= pp {
            // Extra rank: fold into the partner, receive the final sum —
            // no combine scratch needed on this branch.
            self.isend(me - pp, tag, buf)?;
            self.recv_into(me - pp, tag, buf)?;
            return Ok(());
        }
        let mut scratch = vec![0.0f32; buf.len()];
        if me < rem {
            self.recv_into(me + pp, tag, &mut scratch)?;
            for (b, s) in buf.iter_mut().zip(&scratch) {
                *b += s;
            }
        }
        let mut mask = 1usize;
        while mask < pp {
            let partner = me ^ mask;
            self.isend(partner, tag, buf)?;
            self.recv_into(partner, tag, &mut scratch)?;
            for (b, s) in buf.iter_mut().zip(&scratch) {
                *b += s;
            }
            mask <<= 1;
        }
        if me < rem {
            self.isend(me + pp, tag, buf)?;
        }
        Ok(())
    }

    /// One-word [`Comm::allreduce_sum`]: the global sum of `v`.
    pub fn allreduce_scalar(&mut self, v: f32) -> Result<f32> {
        let mut buf = [v];
        self.allreduce_sum(&mut buf)?;
        Ok(buf[0])
    }

    /// Stash an out-of-order packet. A `(from, tag)` key must identify at
    /// most one in-flight message at a time (true for every protocol here:
    /// the stepped exchanges use per-step tags, the overlap pipeline one
    /// gather + one reduce per ordered pair); a duplicate would silently
    /// replace the first payload, so it trips a debug assertion (running
    /// in CI's release-with-debug-assertions job too).
    fn stash_insert(&mut self, pkt: Packet) {
        let key = (pkt.from, pkt.tag);
        let prev = self.stash.insert(key, pkt);
        debug_assert!(
            prev.is_none(),
            "duplicate in-flight message key (from {}, tag {})",
            key.0,
            key.1
        );
    }

    fn wait_for(&mut self, from: usize, tag: u64) -> Result<Packet> {
        if let Some(pkt) = self.stash.remove(&(from, tag)) {
            return Ok(pkt);
        }
        loop {
            let pkt = self
                .inbox
                .recv()
                .map_err(|_| anyhow!("inbox closed while waiting for {from}:{tag}"))?;
            if pkt.from == from && pkt.tag == tag {
                return Ok(pkt);
            }
            self.stash_insert(pkt);
        }
    }

    /// Synchronize all processors (end of a schedule step).
    pub fn barrier(&self) {
        self.barrier.wait();
    }
}

/// Run `body` on P simulated processors; returns the per-rank results in
/// rank order. Any processor error aborts the run.
pub fn run<R, F>(p: usize, body: F) -> Result<Vec<R>>
where
    R: Send,
    F: Fn(&mut Comm) -> Result<R> + Send + Sync,
{
    run_ext(p, None, body).map(|(out, _)| out)
}

/// [`run`] with run-level metrics, optionally lending per-processor
/// [`BufPool`]s so payload buffers survive across runs (the steady-state
/// zero-allocation path for iterative callers). `pools`, when provided,
/// must have exactly `p` entries; each worker locks only its own slot, at
/// entry and exit.
pub fn run_ext<R, F>(
    p: usize,
    pools: Option<&[Mutex<BufPool>]>,
    body: F,
) -> Result<(Vec<R>, RunMetrics)>
where
    R: Send,
    F: Fn(&mut Comm) -> Result<R> + Send + Sync,
{
    assert!(p >= 1);
    if let Some(ps) = pools {
        assert_eq!(ps.len(), p, "one BufPool per processor");
    }
    let mut senders = Vec::with_capacity(p);
    let mut inboxes = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = mpsc::channel::<Packet>();
        senders.push(tx);
        inboxes.push(Some(rx));
    }
    let barrier = Arc::new(Barrier::new(p));
    let inflight = Arc::new(InflightGauge::default());
    let fresh = AtomicU64::new(0);
    let results: Vec<Mutex<Option<Result<R>>>> = (0..p).map(|_| Mutex::new(None)).collect();
    let body = &body;
    let fresh_ref = &fresh;

    std::thread::scope(|scope| {
        for (rank, inbox) in inboxes.iter_mut().enumerate() {
            let senders = senders.clone();
            let barrier = barrier.clone();
            let inflight = inflight.clone();
            let inbox = inbox.take().unwrap();
            let slot = &results[rank];
            scope.spawn(move || {
                let pool = match pools {
                    Some(ps) => std::mem::take(&mut *ps[rank].lock().unwrap()),
                    None => BufPool::new(),
                };
                let fresh_before = pool.fresh_allocs;
                let mut comm = Comm {
                    rank,
                    p,
                    senders,
                    inbox,
                    stash: HashMap::new(),
                    pool,
                    inflight,
                    barrier,
                    coll_seq: 0,
                    stats: CommStats::default(),
                };
                let out = body(&mut comm);
                // Teardown: publish the per-run allocation delta, then MERGE
                // the pool back into the lent slot (append, don't overwrite:
                // if a second run on the same plan raced us and took an
                // empty pool, overwriting would drop its buffers — merging
                // keeps every buffer and the cumulative counter correct).
                fresh_ref.fetch_add(comm.pool.fresh_allocs - fresh_before, Ordering::Relaxed);
                if let Some(ps) = pools {
                    let mut lent = ps[rank].lock().unwrap();
                    lent.fresh_allocs += comm.pool.fresh_allocs;
                    lent.bufs.append(&mut comm.pool.bufs);
                }
                *slot.lock().unwrap() = Some(out);
            });
        }
    });

    let out: Result<Vec<R>> = results
        .into_iter()
        .enumerate()
        .map(|(rank, slot)| {
            slot.into_inner()
                .unwrap()
                .ok_or_else(|| anyhow!("processor {rank} produced no result"))?
        })
        .collect();
    let metrics = RunMetrics {
        peak_inflight_words: inflight.peak.load(Ordering::Relaxed),
        fresh_payload_allocs: fresh.into_inner(),
    };
    Ok((out?, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_pass_counts_words() {
        // each rank sends 10 words to (rank+1) % p
        let p = 6;
        let out = run(p, |comm| {
            let to = (comm.rank + 1) % comm.p;
            let from = (comm.rank + comm.p - 1) % comm.p;
            comm.send(to, 0, vec![comm.rank as f32; 10])?;
            let got = comm.recv(from, 0)?;
            assert_eq!(got, vec![from as f32; 10]);
            Ok(comm.stats)
        })
        .unwrap();
        for s in out {
            assert_eq!(s.sent_words, 10);
            assert_eq!(s.recv_words, 10);
            assert_eq!(s.sent_msgs, 1);
            assert_eq!(s.recv_msgs, 1);
        }
    }

    #[test]
    fn out_of_order_tags_are_stashed() {
        let out = run(2, |comm| {
            if comm.rank == 0 {
                comm.send(1, 7, vec![7.0])?;
                comm.send(1, 8, vec![8.0])?;
                Ok(0.0)
            } else {
                // receive in reverse order
                let b = comm.recv(0, 8)?;
                let a = comm.recv(0, 7)?;
                Ok(a[0] * 10.0 + b[0])
            }
        })
        .unwrap();
        assert_eq!(out[1], 78.0);
    }

    #[test]
    fn barrier_synchronizes_steps() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let counter = AtomicUsize::new(0);
        let p = 4;
        run(p, |comm| {
            for step in 0..3 {
                counter.fetch_add(1, Ordering::SeqCst);
                comm.barrier();
                // after the barrier, all p increments of this step happened
                let c = counter.load(Ordering::SeqCst);
                assert!(c >= (step + 1) * p, "step {step}: {c}");
                comm.barrier();
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 3 * p);
    }

    #[test]
    fn allreduce_sum_pattern() {
        // naive allreduce: everyone sends to 0, 0 broadcasts
        let p = 5;
        let out = run(p, |comm| {
            if comm.rank == 0 {
                let mut acc = 1.0; // own value
                for r in 1..comm.p {
                    acc += comm.recv(r, 1)?[0];
                }
                for r in 1..comm.p {
                    comm.send(r, 2, vec![acc])?;
                }
                Ok(acc)
            } else {
                comm.send(0, 1, vec![1.0])?;
                Ok(comm.recv(0, 2)?[0])
            }
        })
        .unwrap();
        assert!(out.iter().all(|&v| v == p as f32));
    }

    /// Comm-only ring exchange over the nonblocking API (no tensor, no
    /// compute): every rank isends to both neighbors, then drains arrivals
    /// with try_recv/recv_any + recv_into. Used to pin (a) stats parity
    /// with the blocking API and (b) steady-state buffer reuse.
    fn nonblocking_ring(p: usize, words: usize, pools: &[Mutex<BufPool>]) -> Vec<CommStats> {
        let (out, _) = run_ext(p, Some(pools), |comm| {
            let me = comm.rank;
            let next = (me + 1) % comm.p;
            let prev = (me + comm.p - 1) % comm.p;
            let payload = vec![me as f32; words];
            comm.isend(next, 1, &payload)?;
            comm.isend(prev, 2, &payload)?;
            let mut pending = 2;
            let mut buf = vec![0.0f32; words];
            while pending > 0 {
                let (from, tag) = match comm.try_recv() {
                    Some(key) => key,
                    None => comm.recv_any()?,
                };
                comm.recv_into(from, tag, &mut buf)?;
                assert!(buf.iter().all(|&v| v == from as f32));
                pending -= 1;
            }
            Ok(comm.stats)
        })
        .unwrap();
        out
    }

    #[test]
    fn nonblocking_api_matches_blocking_stats() {
        // Identical exchange pattern through both APIs: per-rank CommStats
        // must be exactly equal (the §Perf P8 accounting invariant).
        let (p, words) = (5usize, 17usize);
        let blocking = run(p, |comm| {
            let me = comm.rank;
            let next = (me + 1) % comm.p;
            let prev = (me + comm.p - 1) % comm.p;
            comm.send(next, 1, vec![me as f32; words])?;
            comm.send(prev, 2, vec![me as f32; words])?;
            comm.recv(prev, 1)?;
            comm.recv(next, 2)?;
            Ok(comm.stats)
        })
        .unwrap();
        let pools: Vec<Mutex<BufPool>> = (0..p).map(|_| Mutex::new(BufPool::new())).collect();
        let nonblocking = nonblocking_ring(p, words, &pools);
        assert_eq!(blocking, nonblocking);
    }

    #[test]
    fn warm_pools_make_isend_allocation_free() {
        // First run allocates one buffer per in-flight message; with the
        // pools lent across runs, the second run allocates nothing.
        let (p, words) = (4usize, 33usize);
        let pools: Vec<Mutex<BufPool>> = (0..p).map(|_| Mutex::new(BufPool::new())).collect();
        nonblocking_ring(p, words, &pools);
        let before: u64 = pools.iter().map(|pl| pl.lock().unwrap().fresh_allocs()).sum();
        assert!(before > 0, "cold run must have allocated buffers");
        let (_, metrics) = run_ext(p, Some(&pools), |comm| {
            let me = comm.rank;
            let next = (me + 1) % comm.p;
            let prev = (me + comm.p - 1) % comm.p;
            let payload = vec![me as f32; words];
            comm.isend(next, 1, &payload)?;
            comm.isend(prev, 2, &payload)?;
            let mut buf = vec![0.0f32; words];
            comm.recv_into(prev, 1, &mut buf)?;
            comm.recv_into(next, 2, &mut buf)?;
            Ok(())
        })
        .unwrap();
        assert_eq!(
            metrics.fresh_payload_allocs, 0,
            "warmed pools must serve every isend without allocating"
        );
    }

    #[test]
    fn recv_into_rejects_wrong_length() {
        let err = run(2, |comm| {
            if comm.rank == 0 {
                comm.isend(1, 0, &[1.0, 2.0, 3.0])?;
                Ok(())
            } else {
                let mut buf = vec![0.0f32; 2]; // wrong: message has 3 words
                comm.recv_into(0, 0, &mut buf)
            }
        });
        assert!(err.is_err());
    }

    #[test]
    fn allreduce_matches_closed_form_and_is_rank_deterministic() {
        // Recursive-doubling allreduce on powers of two and awkward P
        // alike: (a) every rank ends with the same bits, (b) the value is
        // the true sum, (c) per-rank CommStats equal the allreduce_stats
        // closed form — the collective side of the §Perf P9 invariant.
        for p in [2usize, 3, 4, 5, 7, 10, 14, 16] {
            for width in [1usize, 3] {
                let out = run(p, |comm| {
                    let mut buf: Vec<f32> = (0..width)
                        .map(|w| 1.0 + 0.25 * (comm.rank * width + w) as f32)
                        .collect();
                    comm.allreduce_sum(&mut buf)?;
                    Ok((buf, comm.stats))
                })
                .unwrap();
                for w in 0..width {
                    let want: f32 =
                        (0..p).map(|r| 1.0 + 0.25 * (r * width + w) as f32).sum();
                    assert!(
                        (out[0].0[w] - want).abs() < 1e-3 * want.abs().max(1.0),
                        "p={p} width={width} w={w}: {} vs {want}",
                        out[0].0[w]
                    );
                }
                for (rank, (buf, stats)) in out.iter().enumerate() {
                    assert_eq!(
                        buf, &out[0].0,
                        "p={p} width={width}: rank {rank} result differs bitwise"
                    );
                    assert_eq!(
                        *stats,
                        allreduce_stats(p, rank, width),
                        "p={p} width={width} rank {rank} stats"
                    );
                }
            }
        }
    }

    #[test]
    fn back_to_back_allreduces_use_distinct_tags() {
        // Two immediately successive collectives between the same partner
        // pairs must not collide even when one rank races ahead: the
        // per-processor tag sequence keys every instance uniquely.
        let p = 6;
        let out = run(p, |comm| {
            let a = comm.allreduce_scalar(1.0)?;
            let b = comm.allreduce_scalar(comm.rank as f32)?;
            Ok((a, b))
        })
        .unwrap();
        let rank_sum = (p * (p - 1) / 2) as f32;
        for (a, b) in out {
            assert_eq!(a, p as f32);
            assert_eq!(b, rank_sum);
        }
    }

    #[test]
    fn tag_filtered_polling_leaves_collective_traffic_stashed() {
        // A collective-tagged message from a racing peer must be invisible
        // to a sweep's tag-filtered drain, yet stay available for a later
        // targeted receive.
        run(2, |comm| {
            if comm.rank == 0 {
                comm.isend(1, TAG_COLL_BASE + 7, &[1.0, 2.0])?;
                comm.barrier();
            } else {
                comm.barrier(); // sender's isend happens-before its barrier
                // Unfiltered poll sees it (draining it into the stash)...
                let key = comm.try_recv();
                assert_eq!(key, Some((0, TAG_COLL_BASE + 7)));
                // ...the sweep-tag filter does not...
                assert!(comm.try_recv_matching(|t| t < TAG_COLL_BASE).is_none());
                // ...and the targeted receive still consumes it.
                let mut buf = [0.0f32; 2];
                comm.recv_into(0, TAG_COLL_BASE + 7, &mut buf)?;
                assert_eq!(buf, [1.0, 2.0]);
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn commstats_absorb_and_since_are_inverse() {
        let a = CommStats { sent_words: 5, recv_words: 7, sent_msgs: 2, recv_msgs: 3 };
        let b = CommStats { sent_words: 11, recv_words: 13, sent_msgs: 4, recv_msgs: 5 };
        let mut acc = a;
        acc.absorb(&b);
        assert_eq!(acc.since(&a), b);
        assert_eq!(acc.since(&b), a);
        assert_eq!(acc.total_words(), a.total_words() + b.total_words());
    }

    #[test]
    fn inflight_peak_tracks_unconsumed_payloads() {
        // Rank 0 sends 3 messages of 10 words before rank 1 consumes any:
        // the peak in-flight gauge must reach at least 30 words.
        let pools: Vec<Mutex<BufPool>> = (0..2).map(|_| Mutex::new(BufPool::new())).collect();
        let (_, metrics) = run_ext(2, Some(&pools), |comm| {
            if comm.rank == 0 {
                for tag in 0..3u64 {
                    comm.isend(1, tag, &[0.5f32; 10])?;
                }
                comm.barrier();
            } else {
                comm.barrier(); // all three are in flight now
                let mut buf = vec![0.0f32; 10];
                for tag in 0..3u64 {
                    comm.recv_into(0, tag, &mut buf)?;
                }
            }
            Ok(())
        })
        .unwrap();
        assert!(
            metrics.peak_inflight_words >= 30,
            "peak {} < 30",
            metrics.peak_inflight_words
        );
    }
}
