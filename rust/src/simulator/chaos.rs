//! Deterministic, seeded fault injection at the [`Transport`] seam
//! (§Rob: property P13, bench E17).
//!
//! [`ChaosTransport`] wraps either backend (the mpsc counting oracle or
//! the lock-free spsc rings) behind the same private [`Transport`] trait
//! and injects faults according to a [`FaultPlan`]: message delays that
//! reorder arrivals, transient send/recv failures, a deterministic
//! rank-crash-at-op event, and — for the ABFT layer (§Rob P15, E19) —
//! silent single-bit flips on outgoing sweep wire containers
//! (`flip_wire_ppm`) and, via the separate [`MemChaos`] injector the
//! compute path arms, in freshly contracted accumulator panels
//! (`flip_mem_ppm`). Because every counter, stash, pool, and
//! collective lives in `Comm` ABOVE the trait, a zero-fault plan is
//! observationally invisible — bitwise-identical results and identical
//! `CommStats` (the P13 transparency leg).
//!
//! Determinism: each rank draws fault decisions from its own xorshift64*
//! stream seeded from `(plan.seed, rank)`, and the decision index is the
//! count of *fallible* operations (send / send_slice / blocking recv)
//! that rank has issued — a schedule-determined quantity on the phased
//! path, so a given `(seed, rate)` replays the same fault sequence every
//! run. Polling (`try_recv`) draws from the same stream but its call
//! count follows real arrival timing, so overlap-mode delays are seeded
//!-reproducible in distribution rather than bitwise.
//!
//! Recovery interplay: retrying a failed run under the SAME plan would
//! deterministically re-inject the same crash, so restart loops
//! (`SolverSession` retry-with-restart, the serve layer's batch retry)
//! call [`FaultPlan::reseeded`] — the transient-fault stream is remixed
//! per attempt and the one-shot `crash_rank` event is dropped after the
//! first attempt, modeling a crashed-and-replaced worker.

use super::{BufPool, Packet, SttsvError, TagClass, Transport};
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::VecDeque;

/// Most packets a rank will hold back (delay) at once. Small, so chaos
/// perturbs ordering without unboundedly deferring progress; a blocking
/// recv always drains the holdback before it can park (liveness).
const HOLDBACK_CAP: usize = 4;

/// A deterministic fault-injection plan for one run (§Rob).
///
/// `Copy + Hash` so it can ride inside `ExecOpts` (the plan-cache key).
/// `Default` is the all-zero plan: no faults, no crash — and the
/// `ChaosTransport` wrapper under it is bitwise transparent.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct FaultPlan {
    /// Seed of the per-rank fault-decision streams (rank-mixed, so ranks
    /// draw independent faults from one plan).
    pub seed: u64,
    /// Per-fallible-operation fault probability in parts per million
    /// (`rate_ppm = 1_000` ≈ one fault per thousand transport ops). Each
    /// firing is a transient send/recv failure or a delivery delay.
    pub rate_ppm: u32,
    /// Deterministic kill switch: crash this rank (every subsequent
    /// transport op returns [`SttsvError::Crashed`]) once it has issued
    /// [`FaultPlan::crash_at`] fallible operations. `None` = no crash.
    pub crash_rank: Option<u32>,
    /// The fallible-op index at which `crash_rank` dies.
    pub crash_at: u64,
    /// Per-sweep-send probability (ppm) of flipping one bit somewhere in
    /// the outgoing wire containers — AFTER bf16 packing and the ABFT
    /// integrity word, so a firing corrupts exactly the bits that travel.
    /// Collective/control tags are never flipped: their bitwise
    /// rank-determinism is a correctness guard, and "never silently
    /// wrong" is about sweep data (§Rob, `FaultKind::BitFlip{wire}`).
    pub flip_wire_ppm: u32,
    /// Per-executed-block probability (ppm) of flipping one bit in that
    /// block's accumulator panels after contraction, before the ABFT
    /// check reads them — modeling in-memory SDC the wire word cannot see
    /// (`FaultKind::BitFlip{memory}`; injected via [`MemChaos`] on the
    /// compiled sequential exec path).
    pub flip_mem_ppm: u32,
    /// Forced bit position for both flip kinds, stored as `bit + 1`
    /// (0 = uniform over all 32 bits). The E19 coverage table sweeps this
    /// to attribute detection by bit position (exponent vs mantissa).
    pub flip_bit: u8,
}

impl FaultPlan {
    /// Random-fault plan from a CLI-style `(seed, rate)` pair: `rate` is
    /// a probability in `[0, 1]`, stored as parts per million.
    pub fn rate(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan {
            seed,
            rate_ppm: (rate.clamp(0.0, 1.0) * 1e6).round() as u32,
            ..FaultPlan::default()
        }
    }

    /// Deterministic crash plan: `rank` dies at its `at`-th transport op.
    pub fn crash(seed: u64, rank: usize, at: u64) -> FaultPlan {
        FaultPlan {
            seed,
            crash_rank: Some(rank as u32),
            crash_at: at,
            ..FaultPlan::default()
        }
    }

    /// Bit-flip plan (§Rob ABFT): wire flips at `wire_ppm` per sweep
    /// send, accumulator-panel flips at `mem_ppm` per executed block,
    /// bit position uniform. Compose with [`FaultPlan::forcing_bit`] for
    /// the E19 coverage-by-position table.
    pub fn bit_flip(seed: u64, wire_ppm: u32, mem_ppm: u32) -> FaultPlan {
        FaultPlan {
            seed,
            flip_wire_ppm: wire_ppm.min(1_000_000),
            flip_mem_ppm: mem_ppm.min(1_000_000),
            ..FaultPlan::default()
        }
    }

    /// Pin every flip of this plan to bit `bit` (0..32) of its f32
    /// container instead of a uniform draw.
    pub fn forcing_bit(mut self, bit: u8) -> FaultPlan {
        debug_assert!(bit < 32, "f32 containers have 32 bits");
        self.flip_bit = bit + 1;
        self
    }

    /// The plan a restart should run under. Attempt 0 is the plan itself;
    /// later attempts remix the transient-fault stream (same rates — the
    /// environment is still hostile, bit flips included) and drop the
    /// one-shot crash event (the crashed worker was replaced).
    pub fn reseeded(self, attempt: u32) -> FaultPlan {
        if attempt == 0 {
            return self;
        }
        FaultPlan {
            seed: self.seed ^ (attempt as u64).wrapping_mul(0xA076_1D64_78BD_642F),
            crash_rank: None,
            crash_at: 0,
            ..self
        }
    }

    /// True when the plan can inject nothing (the transparency case).
    pub fn is_zero(&self) -> bool {
        self.rate_ppm == 0
            && self.crash_rank.is_none()
            && self.flip_wire_ppm == 0
            && self.flip_mem_ppm == 0
    }
}

/// `--chaos seed,rate` CLI form, e.g. `--chaos 7,0.001`.
impl std::str::FromStr for FaultPlan {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<FaultPlan> {
        let (seed, rate) = s
            .split_once(',')
            .ok_or_else(|| anyhow::anyhow!("--chaos wants `seed,rate` (e.g. 7,0.001)"))?;
        let seed: u64 = seed.trim().parse()?;
        let rate: f64 = rate.trim().parse()?;
        anyhow::ensure!((0.0..=1.0).contains(&rate), "chaos rate must be in [0,1], got {rate}");
        Ok(FaultPlan::rate(seed, rate))
    }
}

/// The fault-injecting [`Transport`] decorator. Constructed by `run_cfg`
/// around whichever backend the run selected; never visible above the
/// trait object.
pub(super) struct ChaosTransport {
    inner: Box<dyn Transport>,
    plan: FaultPlan,
    rank: usize,
    rng: Rng,
    /// Count of fallible ops issued — the deterministic decision index.
    ops: u64,
    /// Set once the crash event fires; every later op fails immediately.
    crashed: bool,
    /// Delayed packets awaiting re-delivery (source of reordering).
    holdback: VecDeque<Packet>,
}

impl ChaosTransport {
    pub(super) fn new(rank: usize, plan: FaultPlan, inner: Box<dyn Transport>) -> ChaosTransport {
        ChaosTransport {
            inner,
            plan,
            rank,
            rng: Rng::new(plan.seed ^ (rank as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            ops: 0,
            crashed: false,
            holdback: VecDeque::new(),
        }
    }

    /// One biased coin at the plan's rate. Zero-rate plans never touch
    /// the RNG, so the wrapper stays bit-transparent.
    fn flip(&mut self) -> bool {
        self.plan.rate_ppm > 0 && self.rng.next_u64() % 1_000_000 < self.plan.rate_ppm as u64
    }

    /// Maybe corrupt one bit of an outgoing sweep payload (§Rob ABFT).
    /// Zero-rate plans never touch the RNG; collective tags are exempt
    /// (see [`FaultPlan::flip_wire_ppm`]).
    fn maybe_flip_wire(&mut self, tag: u64, data: &mut [f32]) {
        if self.plan.flip_wire_ppm == 0
            || data.is_empty()
            || TagClass::of(tag) != TagClass::Sweep
            || self.rng.next_u64() % 1_000_000 >= self.plan.flip_wire_ppm as u64
        {
            return;
        }
        let idx = (self.rng.next_u64() % data.len() as u64) as usize;
        let bit = forced_or_random_bit(self.plan.flip_bit, &mut self.rng);
        data[idx] = f32::from_bits(data[idx].to_bits() ^ (1u32 << bit));
    }

    /// Advance the fallible-op counter; `Err` when this op crashes the
    /// rank or draws a transient fault.
    fn step(&mut self, op: &'static str) -> Result<()> {
        if self.crashed {
            return Err(SttsvError::Crashed { rank: self.rank, at_op: self.ops }.into());
        }
        let at = self.ops;
        self.ops += 1;
        if self.plan.crash_rank == Some(self.rank as u32) && at >= self.plan.crash_at {
            self.crashed = true;
            return Err(SttsvError::Crashed { rank: self.rank, at_op: at }.into());
        }
        if self.flip() {
            return Err(SttsvError::Transient { op, rank: self.rank }.into());
        }
        Ok(())
    }
}

impl Transport for ChaosTransport {
    fn send(&mut self, to: usize, tag: u64, mut data: Vec<f32>, pool: &mut BufPool) -> Result<()> {
        self.step("send")?;
        self.maybe_flip_wire(tag, &mut data);
        self.inner.send(to, tag, data, pool)
    }

    fn send_slice(&mut self, to: usize, tag: u64, data: &[f32], pool: &mut BufPool) -> Result<()> {
        self.step("send")?;
        if self.plan.flip_wire_ppm > 0 && TagClass::of(tag) == TagClass::Sweep {
            // The borrowed fast path cannot be mutated in place: stage a
            // pool copy, flip (maybe), and hand that off as owned.
            let mut buf = pool.take(data.len());
            buf.extend_from_slice(data);
            self.maybe_flip_wire(tag, &mut buf);
            return self.inner.send(to, tag, buf, pool);
        }
        self.inner.send_slice(to, tag, data, pool)
    }

    fn try_recv(&mut self, pool: &mut BufPool) -> Option<Packet> {
        if self.crashed {
            return None;
        }
        // A held-back packet may re-enter the stream ahead of this poll's
        // wire arrival — that (plus the holdback push below) is where
        // reordering comes from.
        if !self.holdback.is_empty() && self.flip() {
            return self.holdback.pop_front();
        }
        match self.inner.try_recv(pool) {
            Some(pkt) => {
                if self.holdback.len() < HOLDBACK_CAP && self.flip() {
                    // Delay: the caller sees nothing this poll; the packet
                    // re-emerges on a later poll or before any blocking recv.
                    self.holdback.push_back(pkt);
                    None
                } else {
                    Some(pkt)
                }
            }
            // Empty wire: release the oldest delayed packet, preserving
            // progress (a delay is never an indefinite withhold).
            None => self.holdback.pop_front(),
        }
    }

    fn recv(&mut self, pool: &mut BufPool) -> Result<Packet> {
        self.step("recv")?;
        // Never block while holding delayed packets: poll the wire once
        // (possibly delaying the fresh arrival), then drain the holdback,
        // and only park in the inner transport when both are empty.
        if let Some(pkt) = self.inner.try_recv(pool) {
            if self.holdback.len() < HOLDBACK_CAP && self.flip() {
                self.holdback.push_back(pkt);
            } else {
                return Ok(pkt);
            }
        }
        if let Some(pkt) = self.holdback.pop_front() {
            return Ok(pkt);
        }
        self.inner.recv(pool)
    }
}

/// The plan's forced bit position, or a uniform draw over all 32.
fn forced_or_random_bit(flip_bit: u8, rng: &mut Rng) -> u32 {
    match flip_bit {
        0 => (rng.next_u64() % 32) as u32,
        b => (b - 1) as u32,
    }
}

/// In-memory SDC injector for the compute path (§Rob ABFT,
/// [`FaultPlan::flip_mem_ppm`]): one decision per executed block, seeded
/// per rank like the transport wrapper but from an independent stream
/// (mixing constant differs), so wire and memory fault sequences do not
/// alias. The coordinator arms one per worker and offers every block's
/// freshly contracted accumulator panels to [`MemChaos::maybe_flip`]
/// BEFORE the ABFT check reads them — a firing is exactly the corruption
/// the `xᵀC_b x` verify must catch, and a scrub's recomputation heals it
/// (the decision stream has moved on).
#[derive(Debug)]
pub struct MemChaos {
    plan: FaultPlan,
    rng: Rng,
}

impl MemChaos {
    /// `None` when the plan injects no memory flips — the zero-cost (and
    /// zero-RNG) default path.
    pub fn new(rank: usize, plan: FaultPlan) -> Option<MemChaos> {
        (plan.flip_mem_ppm > 0).then(|| MemChaos {
            plan,
            rng: Rng::new(plan.seed ^ (rank as u64 + 1).wrapping_mul(0xD6E8_FEB8_6659_FD93)),
        })
    }

    /// Flip one bit of one element of `buf` at the plan's per-block rate.
    /// Returns the flipped (index, bit) for test/bench attribution.
    pub fn maybe_flip(&mut self, buf: &mut [f32]) -> Option<(usize, u32)> {
        if buf.is_empty() || self.rng.next_u64() % 1_000_000 >= self.plan.flip_mem_ppm as u64 {
            return None;
        }
        let idx = (self.rng.next_u64() % buf.len() as u64) as usize;
        let bit = forced_or_random_bit(self.plan.flip_bit, &mut self.rng);
        buf[idx] = f32::from_bits(buf[idx].to_bits() ^ (1u32 << bit));
        Some((idx, bit))
    }
}
