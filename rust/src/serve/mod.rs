//! Multi-tenant STTSV serving: plan/program cache + request coalescing
//! into r-deep sweeps (§Perf P12, bench E16, the `serve` subcommand).
//!
//! Production shape (ROADMAP item 2): ONE large resident symmetric tensor
//! — a dataset moment tensor — serving many independent single-vector
//! queries `y = A ×₂ x ×₃ x` plus resident HOPM/CP solves. Three serving
//! mechanics make heavy traffic cheap, each grounded in an invariant an
//! earlier PR proved:
//!
//! * **Plan/program cache** ([`PlanCache`]). An [`SttsvPlan`] (schedule,
//!   owner-compute block state, compiled sweep programs, buffer pools) is
//!   expensive to build and provably reusable — `sweep_program_builds`
//!   stays at P across arbitrarily many sweeps (§Perf P9/P10). The cache
//!   keys plans by [`PlanKey`] = `(SymTensor::fingerprint(), P,
//!   normalized ExecOpts)` with LRU eviction and hit/miss/build/eviction
//!   counters, so construction happens once per distinct configuration
//!   regardless of query volume.
//! * **Request coalescing** ([`SttsvServer::drain`]). Pending
//!   single-vector queries are admitted into one r-deep
//!   [`SttsvPlan::run_multi`] sweep under an [`AdmissionPolicy`] (batch
//!   window + max-r cap — the continuous-batching shape from inference
//!   serving). The paper's cost model makes coalescing the dominant
//!   serving lever: r queries cost ONE tensor stream, words exactly r×,
//!   messages unchanged (§Perf P6) — so a query's word bill is unchanged
//!   and its message (latency-cost) bill drops by r. Every batch's
//!   per-processor counters are asserted equal to exactly one r-deep
//!   STTSV ([`SttsvPlan::expected_proc_stats`]), and each query gets its
//!   attributed share back ([`CommStats::per_query`]: words / r exact,
//!   messages amortized).
//! * **Concurrent sessions over one shared packed tensor**. Plans borrow
//!   the packed n(n+1)(n+2)/6 buffer zero-copy (§Perf P7) and are `Sync`,
//!   so resident solver sessions ([`SttsvServer::power_method`],
//!   [`SttsvServer::cp_sweeps`]) and coalesced query batches interleave
//!   against the same buffer from plain `std::thread::scope` threads —
//!   all through one cached plan (concurrent runs on one plan are
//!   supported; its per-processor buffer pools merge on teardown).
//!
//! ## The workload clock
//!
//! Arrival times are caller-supplied seconds on an **open-loop workload
//! clock** ([`SttsvServer::submit`]); sweep service times are **measured
//! wall-clock seconds**. [`SttsvServer::drain`] replays the admission
//! policy over that merged timeline: a batch opens when the server frees
//! up and a query is waiting, fills within the window, and completes
//! after its measured `run_multi` service time. Per-query latency =
//! completion − arrival. This keeps the latency/throughput trade-off
//! honest (real service times, declared arrival process) while staying
//! deterministic enough to property-test — the same shape E15 uses to
//! bridge charged counters and measured seconds.
//!
//! ## Failure semantics (§Rob)
//!
//! Under a [`RobustnessPolicy`] the server degrades instead of hanging or
//! panicking: `submit` sheds beyond the pending-queue cap; a query whose
//! deadline already passed when its batch opens is shed for free; a batch
//! whose sweep fails (e.g. an injected [`FaultPlan`] fault) is retried
//! under a reseeded plan up to `max_retries` times, then its queries are
//! reported failed — never silently dropped; and `breaker_after`
//! consecutive batch failures trip a breaker that degrades coalescing to
//! serial (depth 1) until a batch succeeds, bounding the blast radius of
//! a poisoned batch member. All of it is recorded on the [`ServeReport`]
//! (shed ids, failed ids with causes, retry and trip counters), and the
//! per-batch closed-form comm assertion still holds for every batch that
//! completes.
//!
//! [`FaultPlan`]: crate::simulator::FaultPlan

use crate::apps::{self, PowerReport};
use crate::coordinator::session::{CpSolve, SolverSession};
use crate::coordinator::{ExecOpts, SttsvPlan};
use crate::partition::TetraPartition;
use crate::simulator::{lock_clean, CommStats, QueryCommShare};
use crate::tensor::SymTensor;
use anyhow::{ensure, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Plan-cache key: tensor content hash, processor count, and the
/// **normalized** execution options ([`ExecOpts::normalize`] is applied
/// before keying, so raw option sets that resolve to the same execution
/// configuration — e.g. `compiled: true` on a dense plan vs `compiled:
/// false` — share one plan and can never miss behind each other).
///
/// P stands in for the partition: every tetrahedral construction in this
/// repo (trivial, spherical, SQS(8)) realizes a distinct P, so (tensor,
/// P) determines the block partition a plan was built for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub fingerprint: u64,
    pub p: usize,
    pub opts: ExecOpts,
}

/// Cache effectiveness counters. `plan_builds` is the number the
/// acceptance invariant watches: once every distinct (fingerprint, P,
/// opts) configuration has been seen, it freezes — millions of further
/// queries hit.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheCounters {
    pub hits: u64,
    pub misses: u64,
    pub plan_builds: u64,
    pub evictions: u64,
}

struct CacheEntry<'t> {
    plan: Arc<SttsvPlan<'t>>,
    last_used: u64,
}

/// LRU cache of built [`SttsvPlan`]s, keyed by [`PlanKey`]. Plans are
/// handed out as `Arc`s, so an eviction never invalidates a plan a
/// session is still running on — the Arc keeps it alive until the last
/// user drops it.
///
/// Lifetimes: the cache stores plans borrowing `'t` tensors/partitions,
/// so the caller owns those for the cache's lifetime (the server borrows
/// one of each; multi-tensor tenants hold a cache over their pool).
pub struct PlanCache<'t> {
    cap: usize,
    clock: u64,
    entries: HashMap<PlanKey, CacheEntry<'t>>,
    counters: CacheCounters,
}

impl<'t> PlanCache<'t> {
    /// Cache holding at most `capacity` plans (clamped to ≥ 1).
    pub fn new(capacity: usize) -> PlanCache<'t> {
        PlanCache {
            cap: capacity.max(1),
            clock: 0,
            entries: HashMap::new(),
            counters: CacheCounters::default(),
        }
    }

    /// Plans currently resident.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn counters(&self) -> CacheCounters {
        self.counters
    }

    /// Return the cached plan for `(tensor, part, opts)`, building and
    /// inserting it (evicting the least-recently-used entry at capacity)
    /// on a miss. The fingerprint walk is O(packed words); the build it
    /// guards is the expensive part (schedule + per-worker geometry
    /// flattening into compiled programs).
    pub fn get_or_build(
        &mut self,
        tensor: &'t SymTensor,
        part: &'t TetraPartition,
        opts: ExecOpts,
    ) -> Result<Arc<SttsvPlan<'t>>> {
        let key = PlanKey {
            fingerprint: tensor.fingerprint(),
            p: part.p,
            opts: opts.normalize(),
        };
        self.clock += 1;
        let clock = self.clock;
        if let Some(e) = self.entries.get_mut(&key) {
            e.last_used = clock;
            self.counters.hits += 1;
            return Ok(Arc::clone(&e.plan));
        }
        self.counters.misses += 1;
        let plan = Arc::new(SttsvPlan::new(tensor, part, opts)?);
        self.counters.plan_builds += 1;
        if self.entries.len() == self.cap {
            // cap ≥ 1 so the map is nonempty here; if-let instead of an
            // expect so a future cap-0 misconfiguration degrades to a
            // cache that never evicts rather than a serving-loop panic.
            if let Some(lru) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
            {
                self.entries.remove(&lru);
                self.counters.evictions += 1;
            }
        }
        self.entries.insert(
            key,
            CacheEntry {
                plan: Arc::clone(&plan),
                last_used: clock,
            },
        );
        Ok(plan)
    }
}

/// Latency/throughput admission policy for the coalescer — the
/// continuous-batching shape: a batch opens when the server is free and a
/// query is waiting, admits queries arriving within `batch_window`
/// seconds of the open up to `max_r`, dispatches the moment it fills, and
/// otherwise waits out the window for stragglers (it cannot know none are
/// coming).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionPolicy {
    /// Seconds a non-full batch holds its slot open. 0.0 never waits —
    /// combined with `max_r: 1` that is per-query serial serving.
    pub batch_window: f64,
    /// Depth cap: at most this many queries coalesce into one r-deep
    /// sweep (0 is treated as 1). Powers of two hit the register-tiled
    /// microkernels (r ∈ {1, 2, 4, 8}); other depths take the
    /// dynamic-width fallback — same results, same counters.
    pub max_r: usize,
}

impl AdmissionPolicy {
    /// Per-query serial serving: no window, batches of one. The E16
    /// baseline the coalescing speedup is measured against.
    pub fn serial() -> AdmissionPolicy {
        AdmissionPolicy {
            batch_window: 0.0,
            max_r: 1,
        }
    }

    /// Coalesce up to `max_r` queries arriving within `batch_window`
    /// seconds.
    pub fn coalescing(batch_window: f64, max_r: usize) -> AdmissionPolicy {
        AdmissionPolicy {
            batch_window: batch_window.max(0.0),
            max_r: max_r.max(1),
        }
    }
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy::serial()
    }
}

/// Serve-layer failure handling (§Rob): deadlines, load shedding, batch
/// retries, and the coalescing→serial breaker. The default turns all of
/// it off — infinite deadline, unbounded queue, no retries, no breaker —
/// so servers built without [`SttsvServer::with_robustness`] behave
/// exactly as before this layer existed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RobustnessPolicy {
    /// Seconds from arrival a query's answer is still useful
    /// (`f64::INFINITY` = no deadline). A query whose deadline has
    /// already passed when its batch opens is shed without running; one
    /// that completes late is flagged [`QueryOutcome::missed_deadline`].
    pub deadline: f64,
    /// Pending-queue cap (0 = unbounded): [`SttsvServer::submit`] sheds —
    /// returns an error and counts it — once this many queries wait.
    pub max_queue: usize,
    /// Failed sweeps to retry per batch, each under a
    /// [`FaultPlan::reseeded`](crate::simulator::FaultPlan::reseeded)
    /// plan, before the batch's queries are reported failed.
    pub max_retries: u32,
    /// Consecutive batch failures that trip the breaker, degrading
    /// coalescing to serial batches until one succeeds (0 = never trip).
    pub breaker_after: u32,
}

impl Default for RobustnessPolicy {
    fn default() -> RobustnessPolicy {
        RobustnessPolicy {
            deadline: f64::INFINITY,
            max_queue: 0,
            max_retries: 0,
            breaker_after: 0,
        }
    }
}

struct Pending {
    id: u64,
    x: Vec<f32>,
    arrival: f64,
}

/// One answered query, demultiplexed from its batch.
#[derive(Debug, Clone)]
pub struct QueryOutcome {
    /// Submission id ([`SttsvServer::submit`]'s return value).
    pub id: u64,
    /// y = A ×₂ x ×₃ x for this query's x.
    pub y: Vec<f32>,
    /// Index into [`ServeReport::batches`] of the sweep that served it.
    pub batch: usize,
    /// Depth of that sweep (how many queries shared the tensor stream).
    pub batch_r: usize,
    /// Arrival time on the workload clock (seconds).
    pub arrival: f64,
    /// Completion − arrival: queueing + window wait + measured service.
    pub latency: f64,
    /// This query's attributed share of the busiest processor's batch
    /// comm: words / r (exact — r-deep packing scales words and nothing
    /// else), messages amortized fractionally.
    pub comm: QueryCommShare,
    /// The answer arrived after `arrival + deadline` (§Rob): it was
    /// computed and returned, but too late to be useful.
    pub missed_deadline: bool,
}

/// One executed r-deep sweep.
#[derive(Debug, Clone)]
pub struct BatchRecord {
    /// Queries served by this single tensor sweep.
    pub r: usize,
    /// Dispatch time on the workload clock.
    pub dispatched: f64,
    /// Completion time: `dispatched` + measured service.
    pub completed: f64,
    /// Measured wall-clock seconds of the `run_multi` sweep.
    pub service_secs: f64,
    /// Measured per-processor comm — asserted equal to exactly one
    /// r-deep STTSV before the batch is recorded.
    pub per_proc: Vec<CommStats>,
}

/// Everything one [`SttsvServer::drain`] episode produced.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    /// Per-query outcomes, in submission-id order.
    pub outcomes: Vec<QueryOutcome>,
    /// Per-batch records of SUCCESSFUL sweeps, in dispatch order.
    pub batches: Vec<BatchRecord>,
    /// Ids shed before execution: their deadline had already passed when
    /// their batch opened (§Rob) — no sweep slot was spent on them.
    pub shed: Vec<u64>,
    /// Ids whose batch exhausted its retries, with the rendered cause.
    pub failed: Vec<(u64, String)>,
    /// Depths of the batches that failed, in dispatch order (the breaker
    /// test reads the degradation to serial off this).
    pub failed_batches: Vec<usize>,
    /// Sweep re-executions beyond each batch's first attempt.
    pub retries: u64,
    /// Times the breaker newly tripped coalescing down to serial.
    pub breaker_trips: u64,
}

impl ServeReport {
    /// Workload-clock span from the first arrival to the last completion.
    pub fn makespan(&self) -> f64 {
        let first = self
            .outcomes
            .iter()
            .map(|o| o.arrival)
            .fold(f64::INFINITY, f64::min);
        let last = self
            .batches
            .iter()
            .map(|b| b.completed)
            .fold(f64::NEG_INFINITY, f64::max);
        (last - first).max(0.0)
    }

    /// Sustained queries per second over the episode.
    pub fn qps(&self) -> f64 {
        self.outcomes.len() as f64 / self.makespan().max(1e-12)
    }

    /// Nearest-rank latency percentile, `pct` in [0, 100].
    pub fn latency_percentile(&self, pct: f64) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        let mut lats: Vec<f64> = self.outcomes.iter().map(|o| o.latency).collect();
        // total_cmp: NaN-tolerant total order — a corrupted latency sample
        // must never panic a metrics call on a live server.
        lats.sort_by(f64::total_cmp);
        let rank = ((pct / 100.0) * lats.len() as f64).ceil() as usize;
        lats[rank.clamp(1, lats.len()) - 1]
    }

    /// Mean batch depth — how much tensor-stream amortization the policy
    /// actually achieved.
    pub fn mean_batch_depth(&self) -> f64 {
        if self.batches.is_empty() {
            return 0.0;
        }
        self.outcomes.len() as f64 / self.batches.len() as f64
    }
}

/// A multi-tenant serving endpoint over one shared packed tensor and one
/// partition: plan cache + query coalescer + resident-session entry
/// points. `&self` everywhere — submit queries, drain batches, and run
/// solver sessions concurrently from scoped threads.
pub struct SttsvServer<'t> {
    tensor: &'t SymTensor,
    part: &'t TetraPartition,
    opts: ExecOpts,
    policy: AdmissionPolicy,
    robust: RobustnessPolicy,
    cache: Mutex<PlanCache<'t>>,
    pending: Mutex<Vec<Pending>>,
    next_id: AtomicU64,
    shed_submits: AtomicU64,
}

impl<'t> SttsvServer<'t> {
    /// A server answering queries against `tensor` under `part`, running
    /// sweeps with `opts` (normalized at the cache), coalescing per
    /// `policy`, caching at most `cache_capacity` plans.
    pub fn new(
        tensor: &'t SymTensor,
        part: &'t TetraPartition,
        opts: ExecOpts,
        policy: AdmissionPolicy,
        cache_capacity: usize,
    ) -> Result<SttsvServer<'t>> {
        ensure!(
            tensor.n % part.m == 0,
            "tensor dim {} not divisible into {} block rows (pad first)",
            tensor.n,
            part.m
        );
        Ok(SttsvServer {
            tensor,
            part,
            opts,
            policy,
            robust: RobustnessPolicy::default(),
            cache: Mutex::new(PlanCache::new(cache_capacity)),
            pending: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(0),
            shed_submits: AtomicU64::new(0),
        })
    }

    /// Enable failure handling (§Rob) — deadlines, shedding, retries, the
    /// coalescing breaker — for this server's submit/drain traffic.
    pub fn with_robustness(mut self, robust: RobustnessPolicy) -> SttsvServer<'t> {
        self.robust = robust;
        self
    }

    pub fn robustness(&self) -> RobustnessPolicy {
        self.robust
    }

    /// Submissions refused by the queue-depth cap so far.
    pub fn shed_submits(&self) -> u64 {
        self.shed_submits.load(Ordering::Relaxed)
    }

    /// The execution options sweeps run with (as supplied; the cache keys
    /// their normalized form).
    pub fn opts(&self) -> ExecOpts {
        self.opts
    }

    pub fn policy(&self) -> AdmissionPolicy {
        self.policy
    }

    /// Queries submitted but not yet drained.
    pub fn pending_len(&self) -> usize {
        lock_clean(&self.pending).len()
    }

    pub fn cache_counters(&self) -> CacheCounters {
        lock_clean(&self.cache).counters()
    }

    /// The (cached) plan this server sweeps with — also the entry point
    /// for callers that want to run their own sessions against the shared
    /// tensor.
    pub fn plan(&self) -> Result<Arc<SttsvPlan<'t>>> {
        lock_clean(&self.cache).get_or_build(self.tensor, self.part, self.opts)
    }

    /// Enqueue one query `y = A x x` arriving at `arrival` seconds on the
    /// workload clock. Returns the query id its [`QueryOutcome`] will
    /// carry. Sheds (errors and counts) when the pending queue is at the
    /// robustness policy's cap — backpressure instead of unbounded growth.
    pub fn submit(&self, x: Vec<f32>, arrival: f64) -> Result<u64> {
        ensure!(
            x.len() == self.tensor.n,
            "query length {} != n {}",
            x.len(),
            self.tensor.n
        );
        ensure!(arrival.is_finite(), "non-finite arrival time");
        let mut pending = lock_clean(&self.pending);
        if self.robust.max_queue > 0 && pending.len() >= self.robust.max_queue {
            drop(pending);
            self.shed_submits.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!(
                "shed: pending queue at its cap of {}",
                self.robust.max_queue
            );
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        pending.push(Pending { id, x, arrival });
        Ok(id)
    }

    /// Serve every pending query: replay the admission policy over the
    /// arrival timeline (module docs), executing each admitted batch as
    /// one r-deep `run_multi` sweep and demultiplexing results and comm
    /// attribution per query.
    ///
    /// Asserts, per batch, that every processor's counters equal exactly
    /// one r-deep STTSV — coalescing must never move a word or message
    /// off the closed form the plan promises.
    pub fn drain(&self) -> Result<ServeReport> {
        let mut queries = {
            let mut pending = lock_clean(&self.pending);
            std::mem::take(&mut *pending)
        };
        if queries.is_empty() {
            return Ok(ServeReport::default());
        }
        // Stable by arrival: simultaneous arrivals keep submission order.
        // total_cmp: a NaN arrival (corrupted timeline) sorts last instead
        // of panicking the drain loop.
        queries.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        let plan = self.plan()?;
        let max_r = self.policy.max_r.max(1);
        let window = self.policy.batch_window.max(0.0);
        let robust = self.robust;
        // Closed-form per-proc comm of one r-deep sweep, per depth seen.
        let mut expected: HashMap<usize, Vec<CommStats>> = HashMap::new();

        let mut report = ServeReport::default();
        let mut server_free = f64::NEG_INFINITY;
        // Breaker state: `fails` consecutive batch failures; at the
        // threshold coalescing degrades to serial until a batch succeeds.
        let mut fails = 0u32;
        let mut tripped = false;
        let mut i = 0usize;
        while i < queries.len() {
            let open = queries[i].arrival.max(server_free);
            // Admission-time shedding: a query whose deadline passed
            // before the server could even open its batch is dropped for
            // free instead of spending a sweep slot on a stale answer.
            if open > queries[i].arrival + robust.deadline {
                report.shed.push(queries[i].id);
                i += 1;
                continue;
            }
            let eff_max_r = if tripped { 1 } else { max_r };
            let close = open + window;
            let mut j = i + 1;
            while j < queries.len() && j - i < eff_max_r && queries[j].arrival <= close {
                j += 1;
            }
            let r = j - i;
            // A full batch goes the moment its last member arrives; a
            // non-full one waits out the window for stragglers.
            let dispatched = if r == eff_max_r {
                open.max(queries[j - 1].arrival)
            } else {
                close
            };
            let batch = &queries[i..j];
            let xs: Vec<&[f32]> = batch.iter().map(|q| q.x.as_slice()).collect();
            let t0 = Instant::now();
            // Retry-on-fault: attempt 0 runs the plan's own fault plan;
            // each retry remixes it (and drops a one-shot crash), modeling
            // a replaced worker re-running the sweep.
            let mut attempt = 0u32;
            let run = loop {
                match plan.run_multi_with(&xs, self.opts.chaos.reseeded(attempt)) {
                    Ok(rep) => break Ok(rep),
                    Err(_) if attempt < robust.max_retries => {
                        attempt += 1;
                        report.retries += 1;
                    }
                    Err(e) => break Err(e),
                }
            };
            let service_secs = t0.elapsed().as_secs_f64();
            let completed = dispatched + service_secs;
            let mut rep = match run {
                Ok(rep) => {
                    fails = 0;
                    tripped = false;
                    rep
                }
                Err(e) => {
                    // The batch is lost, not the server: report every
                    // member failed, advance the clock, maybe trip the
                    // breaker, and keep draining.
                    fails += 1;
                    if robust.breaker_after > 0 && fails == robust.breaker_after {
                        tripped = true;
                        report.breaker_trips += 1;
                    }
                    let cause = format!("{e:#}");
                    for q in batch {
                        report.failed.push((q.id, cause.clone()));
                    }
                    report.failed_batches.push(r);
                    server_free = completed;
                    i = j;
                    continue;
                }
            };

            let want = expected
                .entry(r)
                .or_insert_with(|| plan.expected_proc_stats(r));
            let per_proc: Vec<CommStats> = rep.per_proc.iter().map(|p| p.stats).collect();
            for (p, (got, exp)) in per_proc.iter().zip(want.iter()).enumerate() {
                ensure!(
                    got == exp,
                    "batch {} proc {p}: comm {:?} != one {r}-deep STTSV {:?}",
                    report.batches.len(),
                    got,
                    exp
                );
            }
            let busiest = per_proc
                .iter()
                .copied()
                .max_by_key(|s| s.total_words())
                .unwrap_or_default();
            let share = busiest.per_query(r);

            let batch_idx = report.batches.len();
            for (q, y) in batch.iter().zip(rep.ys.drain(..)) {
                report.outcomes.push(QueryOutcome {
                    id: q.id,
                    y,
                    batch: batch_idx,
                    batch_r: r,
                    arrival: q.arrival,
                    latency: completed - q.arrival,
                    comm: share,
                    missed_deadline: completed > q.arrival + robust.deadline,
                });
            }
            report.batches.push(BatchRecord {
                r,
                dispatched,
                completed,
                service_secs,
                per_proc,
            });
            server_free = completed;
            i = j;
        }
        report.outcomes.sort_by_key(|o| o.id);
        Ok(report)
    }

    /// Resident HOPM solve through the shared cached plan — one tenant's
    /// session, safe to run concurrently with `drain` and other sessions
    /// against the same tensor.
    pub fn power_method(&self, x0: &[f32], max_iters: usize, tol: f32) -> Result<PowerReport> {
        let plan = self.plan()?;
        apps::power_method_on(&plan, x0, max_iters, tol)
    }

    /// Resident multi-sweep CP gradient descent through the shared cached
    /// plan (its r STTSVs per sweep already run as one batched pass).
    pub fn cp_sweeps(
        &self,
        x0_cols: &[Vec<f32>],
        max_sweeps: usize,
        step: f32,
        tol: f32,
    ) -> Result<CpSolve> {
        let plan = self.plan()?;
        SolverSession::new(&plan).cp_sweeps(x0_cols, max_sweeps, step, tol)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CommMode;
    use crate::runtime::Backend;
    use crate::steiner::trivial;
    use crate::tensor::linalg;
    use crate::util::rng::Rng;

    fn p4() -> TetraPartition {
        TetraPartition::from_steiner(&trivial(4).unwrap()).unwrap()
    }

    #[test]
    fn cache_counts_hits_misses_builds_and_evicts_lru() {
        let part = p4();
        let b = 3usize;
        let tensor = SymTensor::random(b * part.m, 0xCAFE);
        let mut cache = PlanCache::new(2);
        assert!(cache.is_empty());

        let a = cache.get_or_build(&tensor, &part, ExecOpts::default()).unwrap();
        assert_eq!(a.sweep_program_builds(), part.p as u64);
        let a2 = cache.get_or_build(&tensor, &part, ExecOpts::default()).unwrap();
        assert!(Arc::ptr_eq(&a, &a2), "hit must return the cached plan");
        // Raw opts that NORMALIZE to the default key must hit, not miss:
        // compute_threads 0 clamps to 1, and `compiled` is meaningless on
        // a dense plan (cleared) so dense±compiled share one entry later.
        let a3 = cache
            .get_or_build(&tensor, &part, ExecOpts { compute_threads: 0, ..Default::default() })
            .unwrap();
        assert!(Arc::ptr_eq(&a, &a3));
        let c = cache.counters();
        assert_eq!((c.hits, c.misses, c.plan_builds, c.evictions), (2, 1, 1, 0));

        // Distinct normalized keys build; at capacity the LRU entry goes.
        cache
            .get_or_build(
                &tensor,
                &part,
                ExecOpts { mode: CommMode::AllToAll, ..Default::default() },
            )
            .unwrap();
        assert_eq!(cache.len(), 2);
        let dense = ExecOpts { packed: false, compiled: false, ..Default::default() };
        cache.get_or_build(&tensor, &part, dense).unwrap();
        let c = cache.counters();
        assert_eq!((c.misses, c.plan_builds, c.evictions), (3, 3, 1));
        assert_eq!(cache.len(), 2);
        // dense + compiled normalizes onto the dense entry: a hit.
        cache
            .get_or_build(
                &tensor,
                &part,
                ExecOpts { packed: false, compiled: true, ..Default::default() },
            )
            .unwrap();
        assert_eq!(cache.counters().hits, 3);
        // The evicted default entry rebuilds on re-request — counted.
        cache.get_or_build(&tensor, &part, ExecOpts::default()).unwrap();
        let c = cache.counters();
        assert_eq!(c.plan_builds, 4);
        assert_eq!(c.evictions, 2);
        // A different tensor is a different key even with equal opts.
        let other = SymTensor::random(b * part.m, 0xBEEF);
        cache.get_or_build(&other, &part, ExecOpts::default()).unwrap();
        assert_eq!(cache.counters().plan_builds, 5);
    }

    #[test]
    fn coalesced_queries_match_the_batched_oracle_and_serial_runs() {
        // Eight queries through the coalescer (max_r = 4 → two 4-deep
        // sweeps): bitwise equal to the same-depth run_multi oracle in
        // phased mode (demux is bit-transparent), within 1e-4 of serial
        // per-query plan.run (the r = 1 scalar kernels and the r ≥ 2
        // fused multi kernels group central-block tail adds differently —
        // the documented P10 boundary), and per-batch comm exactly one
        // 4-deep STTSV with word attribution exactly the single-query
        // bill.
        let part = p4();
        let b = 3usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 0x516);
        let opts = ExecOpts { overlap: false, ..Default::default() };
        let server = SttsvServer::new(
            &tensor,
            &part,
            opts,
            AdmissionPolicy::coalescing(1.0, 4),
            4,
        )
        .unwrap();
        let mut rng = Rng::new(0x517);
        let xs: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(n)).collect();
        for (k, x) in xs.iter().enumerate() {
            server.submit(x.clone(), 0.001 * k as f64).unwrap();
        }
        let rep = server.drain().unwrap();
        assert_eq!(rep.outcomes.len(), 8);
        assert_eq!(rep.batches.len(), 2);
        assert!(rep.batches.iter().all(|bt| bt.r == 4));
        assert_eq!(rep.mean_batch_depth(), 4.0);

        let plan = server.plan().unwrap();
        for (g, group) in xs.chunks(4).enumerate() {
            let oracle = plan.run_multi(group).unwrap();
            for (l, want) in oracle.ys.iter().enumerate() {
                let got = &rep.outcomes[4 * g + l];
                assert_eq!(got.batch, g);
                assert_eq!(
                    got.y, *want,
                    "batch {g} col {l}: coalesced result not bitwise the batched oracle"
                );
            }
        }
        let single = plan.expected_proc_stats(1);
        let busiest_single = single.iter().copied().max_by_key(|s| s.total_words()).unwrap();
        for o in &rep.outcomes {
            let serial = plan.run(&xs[o.id as usize]).unwrap();
            let scale = serial.y.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
            for i in 0..n {
                assert!(
                    (o.y[i] - serial.y[i]).abs() < 1e-4 * scale,
                    "query {} i={i}: coalesced {} vs serial {}",
                    o.id,
                    o.y[i],
                    serial.y[i]
                );
            }
            // words / r of the 4-deep batch == the single-query word bill
            assert_eq!(o.comm.sent_words, busiest_single.sent_words, "query {}", o.id);
            assert_eq!(o.comm.recv_words, busiest_single.recv_words, "query {}", o.id);
            assert_eq!(o.comm.sent_msgs, busiest_single.sent_msgs as f64 / 4.0);
        }
    }

    #[test]
    fn serial_policy_is_bitwise_per_query_run() {
        // With the serial policy every "batch" is one r = 1 sweep — the
        // identical code path plan.run takes — so serving adds nothing:
        // results are bitwise equal in phased mode.
        let part = p4();
        let b = 3usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 0x518);
        let opts = ExecOpts { overlap: false, ..Default::default() };
        let server =
            SttsvServer::new(&tensor, &part, opts, AdmissionPolicy::serial(), 2).unwrap();
        let mut rng = Rng::new(0x519);
        let xs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(n)).collect();
        for (k, x) in xs.iter().enumerate() {
            server.submit(x.clone(), k as f64).unwrap();
        }
        let rep = server.drain().unwrap();
        assert_eq!(rep.batches.len(), 3);
        let plan = server.plan().unwrap();
        for o in &rep.outcomes {
            assert_eq!(o.batch_r, 1);
            let serial = plan.run(&xs[o.id as usize]).unwrap();
            assert_eq!(o.y, serial.y, "query {}: serial serving must be bitwise", o.id);
        }
        // One plan served the submit/drain/oracle traffic: built once.
        assert_eq!(server.cache_counters().plan_builds, 1);
    }

    #[test]
    fn admission_replay_batches_dispatches_and_bills_latency_correctly() {
        let part = p4();
        let b = 2usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 0x51A);
        let server = SttsvServer::new(
            &tensor,
            &part,
            ExecOpts { overlap: false, ..Default::default() },
            AdmissionPolicy::coalescing(0.5, 4),
            2,
        )
        .unwrap();
        let mut rng = Rng::new(0x51B);
        // Burst of four within the window, then a straggler far away.
        for arrival in [0.0, 0.1, 0.1, 0.1, 100.0] {
            server.submit(rng.normal_vec(n), arrival).unwrap();
        }
        assert_eq!(server.pending_len(), 5);
        let rep = server.drain().unwrap();
        assert_eq!(server.pending_len(), 0);
        assert_eq!(rep.batches.len(), 2);
        // The burst fills max_r and dispatches at its last arrival, not
        // at the window close.
        assert_eq!(rep.batches[0].r, 4);
        assert_eq!(rep.batches[0].dispatched, 0.1);
        // The lone straggler cannot fill: it waits out the full window.
        assert_eq!(rep.batches[1].r, 1);
        assert_eq!(rep.batches[1].dispatched, 100.5);
        for o in &rep.outcomes {
            let bt = &rep.batches[o.batch];
            assert_eq!(o.latency, bt.completed - o.arrival);
            assert!(o.latency >= bt.service_secs);
        }
        // Query 0 waited for the batch to fill; query 4 for the window.
        assert!(rep.outcomes[0].latency >= 0.1);
        assert!(rep.outcomes[4].latency >= 0.5);
        assert!(rep.makespan() >= 100.5);
    }

    #[test]
    fn queue_cap_sheds_submits_with_backpressure() {
        let part = p4();
        let b = 2usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 0x520);
        let server = SttsvServer::new(
            &tensor,
            &part,
            ExecOpts::default(),
            AdmissionPolicy::serial(),
            1,
        )
        .unwrap()
        .with_robustness(RobustnessPolicy { max_queue: 2, ..Default::default() });
        let mut rng = Rng::new(0x521);
        server.submit(rng.normal_vec(n), 0.0).unwrap();
        server.submit(rng.normal_vec(n), 0.0).unwrap();
        let err = server.submit(rng.normal_vec(n), 0.0).expect_err("cap of 2");
        assert!(err.to_string().contains("shed"), "{err}");
        assert_eq!(server.pending_len(), 2);
        assert_eq!(server.shed_submits(), 1);
        // Draining frees the queue; submits flow again.
        let rep = server.drain().unwrap();
        assert_eq!(rep.outcomes.len(), 2);
        server.submit(rng.normal_vec(n), 1.0).unwrap();
        assert_eq!(server.pending_len(), 1);
    }

    #[test]
    fn deadlines_shed_stale_queries_and_flag_late_answers() {
        // Zero-second deadline: the first query (open == arrival) runs but
        // completes after its instant deadline — flagged missed; the
        // second opens only once the server frees up, strictly after its
        // arrival — shed without spending a sweep on it.
        let part = p4();
        let b = 2usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 0x522);
        let server = SttsvServer::new(
            &tensor,
            &part,
            ExecOpts { overlap: false, ..Default::default() },
            AdmissionPolicy::serial(),
            1,
        )
        .unwrap()
        .with_robustness(RobustnessPolicy { deadline: 0.0, ..Default::default() });
        let mut rng = Rng::new(0x523);
        let id0 = server.submit(rng.normal_vec(n), 0.0).unwrap();
        let id1 = server.submit(rng.normal_vec(n), 0.0).unwrap();
        let rep = server.drain().unwrap();
        assert_eq!(rep.outcomes.len(), 1);
        assert_eq!(rep.outcomes[0].id, id0);
        assert!(rep.outcomes[0].missed_deadline);
        assert_eq!(rep.shed, vec![id1]);
        assert!(rep.failed.is_empty());
    }

    #[test]
    fn transient_batch_failures_retry_under_reseeded_plans() {
        use crate::simulator::FaultPlan;
        // Every batch's first attempt runs the plan's own fault plan — a
        // deterministic rank crash — and must fail; the retry drops the
        // one-shot crash and succeeds. Results are bitwise the zero-fault
        // sweep on the same plan.
        let part = p4();
        let b = 3usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 0x524);
        let opts = ExecOpts {
            overlap: false,
            chaos: FaultPlan::crash(21, 1, 1),
            ..Default::default()
        };
        let server = SttsvServer::new(
            &tensor,
            &part,
            opts,
            AdmissionPolicy::coalescing(1.0, 4),
            2,
        )
        .unwrap()
        .with_robustness(RobustnessPolicy { max_retries: 2, ..Default::default() });
        let mut rng = Rng::new(0x525);
        let xs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(n)).collect();
        for (k, x) in xs.iter().enumerate() {
            server.submit(x.clone(), 0.001 * k as f64).unwrap();
        }
        let rep = server.drain().unwrap();
        assert_eq!(rep.outcomes.len(), 4);
        assert!(rep.failed.is_empty());
        assert_eq!(rep.batches.len(), 1);
        assert_eq!(rep.retries, 1, "one crash, one reseeded re-run");
        let plan = server.plan().unwrap();
        let views: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
        let oracle = plan.run_multi_with(&views, FaultPlan::default()).unwrap();
        for (o, want) in rep.outcomes.iter().zip(&oracle.ys) {
            assert_eq!(o.y, *want, "query {}: retried batch must be bitwise", o.id);
        }
    }

    #[test]
    fn sustained_failures_trip_the_breaker_down_to_serial() {
        use crate::simulator::FaultPlan;
        // No retries: with a crash plan every batch fails. The first
        // 4-deep failure trips the breaker (threshold 1), so the
        // remaining queries are attempted serially — visible as failed
        // batch depths [4, 1, 1]. Nothing hangs, nothing panics, every
        // query is accounted for.
        let part = p4();
        let b = 2usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 0x526);
        let opts = ExecOpts {
            overlap: false,
            chaos: FaultPlan::crash(23, 0, 1),
            ..Default::default()
        };
        let server = SttsvServer::new(
            &tensor,
            &part,
            opts,
            AdmissionPolicy::coalescing(1.0, 4),
            2,
        )
        .unwrap()
        .with_robustness(RobustnessPolicy { breaker_after: 1, ..Default::default() });
        let mut rng = Rng::new(0x527);
        for k in 0..6 {
            server.submit(rng.normal_vec(n), 0.001 * k as f64).unwrap();
        }
        let rep = server.drain().unwrap();
        assert!(rep.outcomes.is_empty());
        assert_eq!(rep.failed.len(), 6);
        assert_eq!(rep.failed_batches, vec![4, 1, 1]);
        assert_eq!(rep.breaker_trips, 1);
        for (_, cause) in &rep.failed {
            assert!(cause.contains("crash"), "cause should name the fault: {cause}");
        }
    }

    #[test]
    fn concurrent_sessions_and_queries_share_one_cached_plan() {
        // The tentpole's part (c): a resident HOPM solve and a coalesced
        // query drain run CONCURRENTLY against one shared packed tensor
        // through one cached plan — zero tensor copies, one plan build,
        // both workloads correct.
        let part = p4();
        let b = 4usize;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[5.0, 2.0], 0x51C);
        let mut rng = Rng::new(0x51D);
        let mut x0 = cols[0].clone();
        for v in x0.iter_mut() {
            *v += 0.2 * rng.normal_f32();
        }
        let server = SttsvServer::new(
            &tensor,
            &part,
            ExecOpts::default(),
            AdmissionPolicy::coalescing(1.0, 8),
            2,
        )
        .unwrap();
        let xs: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(n)).collect();
        for (k, x) in xs.iter().enumerate() {
            server.submit(x.clone(), 0.0001 * k as f64).unwrap();
        }
        let (power, drained) = std::thread::scope(|s| {
            let ph = s.spawn(|| server.power_method(&x0, 40, 1e-6));
            let dh = s.spawn(|| server.drain());
            (ph.join().expect("power thread"), dh.join().expect("drain thread"))
        });
        let power = power.unwrap();
        let drained = drained.unwrap();
        assert!((power.lambda - 5.0).abs() < 1e-2, "lambda={}", power.lambda);
        assert!(linalg::dot(&power.x, &cols[0]).abs() > 0.999);
        assert_eq!(drained.outcomes.len(), 8);
        assert_eq!(drained.batches.len(), 1);
        assert_eq!(drained.batches[0].r, 8);
        for o in &drained.outcomes {
            let want = tensor.sttsv(&xs[o.id as usize]);
            let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
            for i in 0..n {
                assert!(
                    (o.y[i] - want[i]).abs() < 3e-3 * scale,
                    "query {} i={i}",
                    o.id
                );
            }
        }
        // Both tenants went through ONE plan: a single build, the rest
        // hits; the shared plan holds no tensor copy and its P compiled
        // programs were built exactly once.
        let c = server.cache_counters();
        assert_eq!(c.plan_builds, 1, "counters: {c:?}");
        assert!(c.hits >= 1);
        let plan = server.plan().unwrap();
        assert_eq!(plan.resident_tensor_words(), 0);
        assert_eq!(plan.sweep_program_builds(), part.p as u64);
    }

    #[test]
    fn serve_works_on_both_transports() {
        // The transport is part of the cache key and orthogonal to
        // coalescing: identical per-batch counters on mpsc and spsc.
        use crate::simulator::TransportKind;
        let part = p4();
        let b = 3usize;
        let n = b * part.m;
        let tensor = SymTensor::random(n, 0x51E);
        let mut rng = Rng::new(0x51F);
        let xs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(n)).collect();
        let mut reps = Vec::new();
        for transport in [TransportKind::Mpsc, TransportKind::Spsc] {
            let opts = ExecOpts { transport, overlap: false, ..Default::default() };
            let server = SttsvServer::new(
                &tensor,
                &part,
                opts,
                AdmissionPolicy::coalescing(1.0, 4),
                2,
            )
            .unwrap();
            for (k, x) in xs.iter().enumerate() {
                server.submit(x.clone(), 0.001 * k as f64).unwrap();
            }
            reps.push(server.drain().unwrap());
        }
        let (mp, sp) = (&reps[0], &reps[1]);
        assert_eq!(mp.batches[0].per_proc, sp.batches[0].per_proc);
        for (a, o) in mp.outcomes.iter().zip(&sp.outcomes) {
            assert_eq!(a.y, o.y, "phased results must be transport-invariant");
        }
    }

    #[test]
    fn backend_enum_hashes_consistently_with_eq() {
        // The Hash derives backing PlanKey: equal values hash equal.
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        fn h<T: Hash>(v: &T) -> u64 {
            let mut s = DefaultHasher::new();
            v.hash(&mut s);
            s.finish()
        }
        let a = ExecOpts { compute_threads: 0, ..Default::default() }.normalize();
        let b = ExecOpts::default().normalize();
        assert_eq!(a, b);
        assert_eq!(h(&a), h(&b));
        assert_ne!(
            ExecOpts { backend: Backend::Pjrt, ..Default::default() }.normalize(),
            b
        );
    }
}
