//! The paper's motivating applications, built on the distributed STTSV
//! coordinator: the higher-order power method (Algorithm 1) for tensor
//! Z-eigenpairs, and the symmetric CP gradient (Algorithm 2).
//!
//! Both iterative drivers run as **iteration-resident solver sessions**
//! ([`SolverSession`]): the P workers are spawned once per solve, keep
//! their portion of the iterate across iterations, and reduce every
//! per-iteration scalar (λ = x·y, ‖y‖, δ, ‖∇‖, the Gram matrix) by
//! recursive-doubling allreduce — the full vector never returns to the
//! host between iterations, and there is **no dense O(n³) host work per
//! iteration** (the old Rayleigh-quotient fallback is deleted; a
//! regression test counts dense-oracle invocations). Per-iteration comm
//! is exactly one STTSV plus O(log P) scalar-allreduce words, asserted by
//! the session and recorded per iteration in the reports.
//!
//! Multi-column workloads (CP gradient/sweeps, symmetric MTTKRP) run
//! their r STTSVs through the batched pass: one sweep of the distributed
//! tensor serves all r columns, with messages packed r words deep — words
//! scale as r× one STTSV but message counts (latency) do not grow with r.
//!
//! On default options every sweep executes the plan's **compiled sweep
//! programs** (§Perf P10) through the register-tiled microkernels, with
//! `ExecOpts::compute_threads` optionally fanning each worker's stream
//! over an intra-worker compute pool — neither changes a word, message,
//! or charged ternary mult of the accounting above.
//!
//! [`power_method_host`] keeps the pre-session host-centric loop (one
//! `plan.run` per iteration, scalars on the host) as the baseline the E13
//! bench compares against; it computes λ = x·y from the vectors it
//! already holds, never from a dense tensor sweep.

use crate::coordinator::session::SolverSession;
use crate::coordinator::{ExecOpts, SttsvPlan};
use crate::partition::TetraPartition;
use crate::runtime::{exec_block_runs_elem, RunDesc};
use crate::simulator::CommStats;
use crate::tensor::{linalg, PackedBlockView, SymTensor, SymTensorG};
use anyhow::Result;

pub use crate::coordinator::session::{CpIter, PowerIter, RecoveryLog, RecoveryPolicy};

/// Full power-method report.
#[derive(Debug, Clone)]
pub struct PowerReport {
    /// Final eigenvalue estimate (λ = x·y of the last iteration).
    pub lambda: f32,
    /// Final unit eigenvector estimate.
    pub x: Vec<f32>,
    /// Per-iteration convergence log, each entry carrying its own
    /// per-processor communication record.
    pub iters: Vec<PowerIter>,
    /// Aggregated per-processor comm over the whole solve (STTSV +
    /// collectives for the resident path; STTSV only for the host loop).
    pub comm: Vec<CommStats>,
    /// Communication steps per STTSV vector phase.
    pub steps_per_phase: usize,
    /// Attempt/restart record of the solve (§Rob). `attempts == 1` on a
    /// fault-free run; the host loop never retries and reports defaults.
    pub recovery: RecoveryLog,
}

/// Sum per-iteration per-processor records into whole-solve totals.
fn total_comm<'a>(p: usize, iters: impl Iterator<Item = &'a [CommStats]>) -> Vec<CommStats> {
    let mut acc = vec![CommStats::default(); p];
    for iter_comm in iters {
        for (a, s) in acc.iter_mut().zip(iter_comm) {
            a.absorb(s);
        }
    }
    acc
}

/// Higher-order power method (Algorithm 1), iteration-resident: ONE
/// simulator session runs the whole solve — workers keep their iterate
/// portions across iterations, λ = x·y and ‖y‖ travel as a fused 2-word
/// allreduce, δ as a 1-word allreduce that doubles as the unanimous
/// convergence decision. Per-iteration comm = one STTSV + O(log P) scalar
/// words (asserted inside the session).
pub fn power_method(
    tensor: &SymTensor,
    part: &TetraPartition,
    x0: &[f32],
    max_iters: usize,
    tol: f32,
    opts: ExecOpts,
) -> Result<PowerReport> {
    // The plan (schedule + owner-compute block state) is built once; the
    // session then never touches host-resident vectors again (§Perf P9).
    let plan = SttsvPlan::new(tensor, part, opts)?;
    power_method_on(&plan, x0, max_iters, tol)
}

/// Resident power method over an EXTERNALLY built plan — the multi-tenant
/// serving path (`crate::serve`): independent solves against one resident
/// tensor share a cached plan's schedule, buffer pools, and compiled
/// sweep programs instead of paying a fresh build per solve. Identical to
/// [`power_method`] once the plan exists (which builds one and delegates
/// here).
pub fn power_method_on(
    plan: &SttsvPlan,
    x0: &[f32],
    max_iters: usize,
    tol: f32,
) -> Result<PowerReport> {
    let solve = SolverSession::new(plan).power_method(x0, max_iters, tol)?;
    let p = solve.per_proc.len();
    let comm = total_comm(p, solve.iters.iter().map(|it| it.comm.as_slice()));
    let lambda = solve.iters.last().map(|i| i.lambda).unwrap_or(0.0);
    Ok(PowerReport {
        lambda,
        x: solve.x,
        iters: solve.iters,
        comm,
        steps_per_phase: solve.steps_per_phase,
        recovery: solve.recovery,
    })
}

/// Resident power method with checkpointed recovery (§Rob): identical to
/// [`power_method`] on a fault-free run, but the session commits
/// portion-local checkpoints every `recovery.checkpoint_every` iterations
/// and retries a failed run from the newest globally consistent one (with
/// capped exponential backoff) up to `recovery.max_retries` times. The
/// extra checkpoint/restore traffic is charged to [`CommStats`] and the
/// restart history lands in [`PowerReport::recovery`].
pub fn power_method_recovering(
    tensor: &SymTensor,
    part: &TetraPartition,
    x0: &[f32],
    max_iters: usize,
    tol: f32,
    opts: ExecOpts,
    recovery: RecoveryPolicy,
) -> Result<PowerReport> {
    let plan = SttsvPlan::new(tensor, part, opts)?;
    let solve = SolverSession::new(&plan)
        .with_recovery(recovery)
        .power_method(x0, max_iters, tol)?;
    let p = solve.per_proc.len();
    let comm = total_comm(p, solve.iters.iter().map(|it| it.comm.as_slice()));
    let lambda = solve.iters.last().map(|i| i.lambda).unwrap_or(0.0);
    Ok(PowerReport {
        lambda,
        x: solve.x,
        iters: solve.iters,
        comm,
        steps_per_phase: solve.steps_per_phase,
        recovery: solve.recovery,
    })
}

/// Host-centric power method baseline: one `plan.run` per iteration, all
/// scalar arithmetic on the host-resident full vectors. λ = x·y before
/// normalization — O(n) from data the iteration already produced; the
/// dense O(n³) `tensor.sttsv` Rayleigh re-evaluation this loop used to
/// perform is gone (regression-tested). This is the E13 comparison
/// baseline: identical per-iteration STTSV comm, but the full vector
/// crosses the host boundary twice per iteration.
pub fn power_method_host(
    tensor: &SymTensor,
    part: &TetraPartition,
    x0: &[f32],
    max_iters: usize,
    tol: f32,
    opts: ExecOpts,
) -> Result<PowerReport> {
    let mut x = x0.to_vec();
    linalg::normalize(&mut x);
    let mut iters: Vec<PowerIter> = Vec::new();
    let mut steps_per_phase = 0;

    let plan = SttsvPlan::new(tensor, part, opts)?;
    for _ in 0..max_iters {
        let rep = plan.run(&x)?;
        steps_per_phase = rep.steps_per_phase;
        let iter_comm: Vec<CommStats> = rep.per_proc.iter().map(|r| r.stats).collect();
        let mut y = rep.y;
        let lambda = linalg::dot(&x, &y);
        let norm = linalg::normalize(&mut y);
        let delta = x
            .iter()
            .zip(&y)
            .map(|(a, b)| {
                let d = a - b;
                (d * d) as f64
            })
            .sum::<f64>()
            .sqrt() as f32;
        x = y;
        iters.push(PowerIter { norm, lambda, delta, comm: iter_comm });
        if delta < tol {
            break;
        }
    }
    let lambda = iters.last().map(|i| i.lambda).unwrap_or(0.0);
    let comm = total_comm(part.p, iters.iter().map(|it| it.comm.as_slice()));
    Ok(PowerReport {
        lambda,
        x,
        iters,
        comm,
        steps_per_phase,
        recovery: RecoveryLog::default(),
    })
}

/// Per-iteration record of the f64 conditioning-study power method.
#[derive(Debug, Clone)]
pub struct PowerF64Iter {
    /// ‖y‖ before normalization.
    pub norm: f64,
    /// λ = x·y of this iteration.
    pub lambda: f64,
    /// ‖x_{t+1} − x_t‖ convergence measure.
    pub delta: f64,
}

/// Report of [`power_method_f64`].
#[derive(Debug, Clone)]
pub struct PowerF64Report {
    /// Final eigenvalue estimate.
    pub lambda: f64,
    /// Final unit eigenvector estimate.
    pub x: Vec<f64>,
    /// Per-iteration convergence log.
    pub iters: Vec<PowerF64Iter>,
}

/// Double-precision STTSV y = A ×₂ x ×₃ x by replaying the whole packed
/// tensor as ONE central block's compiled run stream through the
/// f64-generic register-tiled executor ([`exec_block_runs_elem`]) at
/// r = 1. A central block's run classes (CentralUpper/CentralAxis)
/// accumulate every contribution into the `ci` panel with unit factor, so
/// `y = ci` directly — the same §Perf P10 descriptor machinery the
/// distributed plan compiles per owned block, exercised end-to-end in
/// f64.
fn sttsv_f64(tensor: &SymTensorG<f64>, descs: &[RunDesc], x: &[f64]) -> Vec<f64> {
    let n = tensor.n;
    let mut ci = vec![0.0f64; n];
    let mut cj = vec![0.0f64; n];
    let mut ck = vec![0.0f64; n];
    exec_block_runs_elem::<f64>(tensor.packed_data(), descs, x, x, x, &mut ci, &mut cj, &mut ck, 1);
    // Central-class runs never touch the cj/ck panels.
    debug_assert!(cj.iter().chain(ck.iter()).all(|&v| v == 0.0));
    ci
}

/// Host-side higher-order power method in **f64** end-to-end (§Perf, PR 9
/// precision path): packed tensor storage, run-kernel arithmetic, and all
/// iteration scalars in double precision. This is the conditioning-study
/// companion to [`power_method`] — on ill-conditioned planted-eigenpair
/// instances (`SymTensorG::<f64>::odeco64` with λ spreads of 1e8 or more)
/// the f32 pipeline's ~1e-7 relative kernel error swamps the small
/// eigenvalues, while this path resolves them to f64 accuracy. Sequential
/// by construction: the distributed plan and its wire formats stay
/// f32-only (`ExecOpts::precision` routes the CLI here instead).
pub fn power_method_f64(
    tensor: &SymTensorG<f64>,
    x0: &[f64],
    max_iters: usize,
    tol: f64,
) -> PowerF64Report {
    let n = tensor.n;
    assert_eq!(x0.len(), n, "x0 length must equal tensor dimension");
    // Compile the run stream once (the whole tensor is the single central
    // block of a 1-block partition); every iteration replays it.
    let view = PackedBlockView::new(0, 0, 0, n);
    let mut descs = Vec::new();
    view.for_each_run(|run| descs.push(RunDesc::compile(&run)));

    let mut x = x0.to_vec();
    let nrm = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!(nrm > 0.0, "x0 must be nonzero");
    x.iter_mut().for_each(|v| *v /= nrm);

    let mut iters: Vec<PowerF64Iter> = Vec::new();
    for _ in 0..max_iters {
        let mut y = sttsv_f64(tensor, &descs, &x);
        let lambda = x.iter().zip(&y).map(|(a, b)| a * b).sum::<f64>();
        let norm = y.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm > 0.0 {
            y.iter_mut().for_each(|v| *v /= norm);
        }
        let delta = x.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        x = y;
        iters.push(PowerF64Iter { norm, lambda, delta });
        if delta < tol {
            break;
        }
    }
    let lambda = iters.last().map(|i| i.lambda).unwrap_or(0.0);
    PowerF64Report { lambda, x, iters }
}

/// Symmetric CP gradient report (Algorithm 2).
#[derive(Debug, Clone)]
pub struct CpGradReport {
    /// The gradient matrix Y ∈ R^{n×r}, column-major (columns = y_ℓ).
    pub grad: Vec<Vec<f32>>,
    /// Per-processor comm of the solve: ONE batched r-column distributed
    /// STTSV plus the r²-word Gram and 1-word ‖∇‖ allreduces.
    pub comm: Vec<CommStats>,
}

/// Symmetric CP gradient (Algorithm 2): for factor matrix X (columns x_ℓ),
///   G = (XᵀX) ∗ (XᵀX);  y_ℓ = A ×₂ x_ℓ ×₃ x_ℓ;  ∇ = X·G − Y.
/// Runs as a one-sweep resident session: the r STTSVs (the bottleneck) are
/// ONE batched multi-RHS pass, the Gram matrix is an r²-word allreduce of
/// portion-local partial dots, and the gradient is assembled from the
/// workers' owned portions — the factor matrix crosses the host boundary
/// once in, once out.
pub fn cp_gradient(
    tensor: &SymTensor,
    part: &TetraPartition,
    x_cols: &[Vec<f32>],
    opts: ExecOpts,
) -> Result<CpGradReport> {
    if x_cols.is_empty() {
        // Empty factor matrix: nothing to compute or communicate.
        return Ok(CpGradReport { grad: Vec::new(), comm: vec![CommStats::default(); part.p] });
    }
    let plan = SttsvPlan::new(tensor, part, opts)?;
    // max_sweeps = 1, step = 0: exactly one distributed gradient evaluation.
    let solve = SolverSession::new(&plan).cp_sweeps(x_cols, 1, 0.0, 0.0)?;
    let comm = solve.per_proc.iter().map(|pr| pr.stats).collect();
    Ok(CpGradReport { grad: solve.grad_cols, comm })
}

/// Resident multi-sweep CP report.
#[derive(Debug, Clone)]
pub struct CpAlsReport {
    /// Factor columns after the last executed sweep.
    pub x_cols: Vec<Vec<f32>>,
    /// Per-sweep gradient norms + per-processor comm.
    pub iters: Vec<CpIter>,
    /// Aggregated per-processor comm over the whole solve.
    pub comm: Vec<CommStats>,
    pub steps_per_phase: usize,
    /// Attempt/restart record of the solve (§Rob).
    pub recovery: RecoveryLog,
}

/// Multi-sweep resident symmetric CP driver (the Algorithm 2 workload
/// made iterative): inside ONE simulator session, repeat — batched
/// r-column STTSV, Gram allreduce (r² words), portion-local gradient step
/// X ← X − η·∇ — until ‖∇‖ < tol or `sweeps` exhausted. Per-sweep comm is
/// one r-deep STTSV plus O(log P) scalar words (asserted in the session);
/// the factor matrix stays distributed for the whole descent.
pub fn cp_als_sweep(
    tensor: &SymTensor,
    part: &TetraPartition,
    x0_cols: &[Vec<f32>],
    sweeps: usize,
    step: f32,
    tol: f32,
    opts: ExecOpts,
) -> Result<CpAlsReport> {
    if x0_cols.is_empty() {
        return Ok(CpAlsReport {
            x_cols: Vec::new(),
            iters: Vec::new(),
            comm: vec![CommStats::default(); part.p],
            steps_per_phase: 0,
            recovery: RecoveryLog::default(),
        });
    }
    let plan = SttsvPlan::new(tensor, part, opts)?;
    let solve = SolverSession::new(&plan).cp_sweeps(x0_cols, sweeps, step, tol)?;
    let comm = solve.per_proc.iter().map(|pr| pr.stats).collect();
    Ok(CpAlsReport {
        x_cols: solve.x_cols,
        iters: solve.iters,
        comm,
        steps_per_phase: solve.steps_per_phase,
        recovery: solve.recovery,
    })
}

/// Resident CP descent with checkpointed recovery (§Rob): the CP analogue
/// of [`power_method_recovering`] — factor-portion checkpoints every
/// `recovery.checkpoint_every` sweeps, reseeded retry-with-restart on
/// failure, all extra traffic charged to [`CommStats`].
#[allow(clippy::too_many_arguments)]
pub fn cp_als_recovering(
    tensor: &SymTensor,
    part: &TetraPartition,
    x0_cols: &[Vec<f32>],
    sweeps: usize,
    step: f32,
    tol: f32,
    opts: ExecOpts,
    recovery: RecoveryPolicy,
) -> Result<CpAlsReport> {
    if x0_cols.is_empty() {
        return Ok(CpAlsReport {
            x_cols: Vec::new(),
            iters: Vec::new(),
            comm: vec![CommStats::default(); part.p],
            steps_per_phase: 0,
            recovery: RecoveryLog::default(),
        });
    }
    let plan = SttsvPlan::new(tensor, part, opts)?;
    let solve = SolverSession::new(&plan)
        .with_recovery(recovery)
        .cp_sweeps(x0_cols, sweeps, step, tol)?;
    let comm = solve.per_proc.iter().map(|pr| pr.stats).collect();
    Ok(CpAlsReport {
        x_cols: solve.x_cols,
        iters: solve.iters,
        comm,
        steps_per_phase: solve.steps_per_phase,
        recovery: solve.recovery,
    })
}

/// Mode-1 symmetric MTTKRP (paper §8, future work realized here):
/// `Y[:, ℓ] = A ×₂ x_ℓ ×₃ x_ℓ` for each column of X — exactly r STTSVs, the
/// bottleneck of CP decomposition algorithms, served by ONE batched
/// multi-RHS pass: the tensor distribution is column-independent, so a
/// single sweep of the owned blocks computes every column while the
/// messages of the Theorem 6 schedule carry all r columns at once.
///
/// Returns (Y columns, per-processor comm of the batched pass).
pub fn symmetric_mttkrp(
    tensor: &SymTensor,
    part: &TetraPartition,
    x_cols: &[Vec<f32>],
    opts: ExecOpts,
) -> Result<(Vec<Vec<f32>>, Vec<CommStats>)> {
    if x_cols.is_empty() {
        // Zero columns: nothing to compute or communicate.
        return Ok((Vec::new(), vec![CommStats::default(); part.p]));
    }
    let plan = SttsvPlan::new(tensor, part, opts)?;
    let rep = plan.run_multi(x_cols)?;
    let comm = rep.per_proc.iter().map(|pr| pr.stats).collect();
    Ok((rep.ys, comm))
}

/// The CP objective f(X) = ||A − Σ_ℓ x_ℓ⊗x_ℓ⊗x_ℓ||² / 6, evaluated over
/// the packed unique entries only: each lower-tetrahedral (i ≥ j ≥ k)
/// residual is weighted by its orbit size (6 for i > j > k, 3 on
/// non-central diagonals, 1 at i = j = k), walking the `SymTensor` packed
/// buffer in layout order — n(n+1)(n+2)/6·r work instead of the dense
/// n³·r triple loop (whose `#[cfg(test)]` twin remains as the oracle).
pub fn cp_objective(tensor: &SymTensor, x_cols: &[Vec<f32>]) -> f64 {
    let n = tensor.n;
    let data = tensor.packed_data();
    let mut err = 0.0f64;
    let mut idx = 0usize;
    for i in 0..n {
        for j in 0..=i {
            for k in 0..=j {
                let mut model = 0.0f64;
                for xl in x_cols {
                    model += xl[i] as f64 * xl[j] as f64 * xl[k] as f64;
                }
                let d = data[idx] as f64 - model;
                idx += 1;
                let w = if i == j && j == k {
                    1.0
                } else if i == j || j == k {
                    3.0
                } else {
                    6.0
                };
                err += w * d * d;
            }
        }
    }
    debug_assert_eq!(idx, data.len());
    err / 6.0
}

/// Dense n³ twin of [`cp_objective`] — the finite-difference oracle the
/// packed sweep is checked against.
#[cfg(test)]
fn cp_objective_dense(tensor: &SymTensor, x_cols: &[Vec<f32>]) -> f64 {
    let n = tensor.n;
    let mut err = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                let mut model = 0.0f64;
                for xl in x_cols {
                    model += xl[i] as f64 * xl[j] as f64 * xl[k] as f64;
                }
                let d = tensor.get(i, j, k) as f64 - model;
                err += d * d;
            }
        }
    }
    err / 6.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CommMode;
    use crate::runtime::Backend;
    use crate::steiner::spherical;
    use crate::util::rng::Rng;

    fn opts() -> ExecOpts {
        ExecOpts {
            mode: CommMode::PointToPoint,
            backend: Backend::Native,
            batch: true,
            packed: true,
            overlap: true,
            ..Default::default()
        }
    }

    #[test]
    fn power_method_recovers_dominant_eigenpair() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 6;
        let n = b * part.m; // 30
        let (tensor, cols) = SymTensor::odeco(n, &[5.0, 2.0, 1.0], 31);
        let mut rng = Rng::new(32);
        // start near the dominant eigenvector to ensure its basin
        let mut x0: Vec<f32> = cols[0].clone();
        for v in x0.iter_mut() {
            *v += 0.2 * rng.normal_f32();
        }
        let rep = power_method(&tensor, &part, &x0, 60, 1e-6, opts()).unwrap();
        assert!((rep.lambda - 5.0).abs() < 1e-2, "lambda={}", rep.lambda);
        let align = linalg::dot(&rep.x, &cols[0]).abs();
        assert!(align > 0.999, "alignment={align}");
        // convergence log is monotone-ish and ends small
        assert!(rep.iters.last().unwrap().delta < 1e-6);
        // comm happened on every processor, and per-iteration records sum
        // to the whole-solve totals
        assert!(rep.comm.iter().all(|s| s.sent_words > 0));
        for p in 0..part.p {
            let per_iter_sum: u64 = rep.iters.iter().map(|it| it.comm[p].sent_words).sum();
            assert_eq!(per_iter_sum, rep.comm[p].sent_words, "proc {p}");
        }
    }

    #[test]
    fn resident_and_host_power_methods_agree() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 5;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[5.0, 2.0, 1.0], 33);
        let mut rng = Rng::new(34);
        let mut x0: Vec<f32> = cols[0].clone();
        for v in x0.iter_mut() {
            *v += 0.2 * rng.normal_f32();
        }
        // tol = 0 pins the iteration count to exactly k on both paths
        let k = 8;
        let res = power_method(&tensor, &part, &x0, k, 0.0, opts()).unwrap();
        let host = power_method_host(&tensor, &part, &x0, k, 0.0, opts()).unwrap();
        assert_eq!(res.iters.len(), k);
        assert_eq!(host.iters.len(), k);
        for (t, (a, b)) in res.iters.iter().zip(&host.iters).enumerate() {
            assert!((a.lambda - b.lambda).abs() < 1e-4, "iter {t} lambda");
            assert!((a.norm - b.norm).abs() < 1e-4 * b.norm.abs().max(1.0), "iter {t} norm");
            assert!((a.delta - b.delta).abs() < 1e-4, "iter {t} delta");
        }
        for i in 0..n {
            assert!((res.x[i] - host.x[i]).abs() < 1e-4, "x[{i}]");
        }
    }

    #[test]
    fn iterative_apps_never_invoke_the_dense_oracle() {
        // Regression for the O(n³)-per-iteration host Rayleigh quotient:
        // after the plan is built, neither the resident session nor the
        // host-centric baseline may fall back to tensor.sttsv.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 4;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[3.0, 1.0], 35);
        let x0 = cols[0].clone();
        let before = tensor.dense_sttsv_invocations();
        power_method(&tensor, &part, &x0, 6, 0.0, opts()).unwrap();
        power_method_host(&tensor, &part, &x0, 6, 0.0, opts()).unwrap();
        let mut rng = Rng::new(36);
        // small columns keep the fixed-step descent numerically tame
        let x_cols: Vec<Vec<f32>> = (0..2)
            .map(|_| rng.normal_vec(n).iter().map(|v| 0.3 * v).collect())
            .collect();
        cp_gradient(&tensor, &part, &x_cols, opts()).unwrap();
        cp_als_sweep(&tensor, &part, &x_cols, 3, 0.01, 0.0, opts()).unwrap();
        assert_eq!(
            tensor.dense_sttsv_invocations(),
            before,
            "an iterative app fell back to the dense O(n³) host oracle"
        );
    }

    #[test]
    fn f64_power_method_matches_the_f32_twin_on_tame_spectra() {
        // SymTensorG::random draws the same f32 variate stream for every
        // element type, so the f64 tensor is the exact promotion of the
        // f32 one — the two power methods walk the same instance and must
        // agree to f32 kernel accuracy on a well-conditioned problem.
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 4;
        let n = b * part.m;
        let t32 = SymTensor::random(n, 91);
        let t64 = SymTensorG::<f64>::random(n, 91);
        let mut rng = Rng::new(92);
        let x0: Vec<f32> = rng.normal_vec(n);
        let x0_64: Vec<f64> = x0.iter().map(|&v| v as f64).collect();
        let k = 6;
        let host = power_method_host(&t32, &part, &x0, k, 0.0, opts()).unwrap();
        let dbl = power_method_f64(&t64, &x0_64, k, 0.0);
        assert_eq!(dbl.iters.len(), k);
        for (t, (a, b)) in host.iters.iter().zip(&dbl.iters).enumerate() {
            let scale = b.lambda.abs().max(1.0);
            assert!(((a.lambda as f64) - b.lambda).abs() < 1e-3 * scale, "iter {t} lambda");
            let nscale = b.norm.abs().max(1.0);
            assert!(((a.norm as f64) - b.norm).abs() < 1e-3 * nscale, "iter {t} norm");
        }
        for i in 0..n {
            assert!(((host.x[i] as f64) - dbl.x[i]).abs() < 1e-3, "x[{i}]");
        }
    }

    #[test]
    fn f64_power_method_resolves_ill_conditioned_dominant_pair() {
        // Conditioning study (the reason the f64 path exists): with a
        // planted spectrum spanning 9 decades, the f32 pipeline's ~1e-7
        // relative kernel error is ~10 absolute at λ = 1e8 — the f64 path
        // must land within 1e-2 absolute (1e-10 relative).
        let n = 12;
        let (t, cols) = SymTensorG::<f64>::odeco64(n, &[1.0e8, 1.0, 1.0e-1], 77);
        let mut x0 = cols[0].clone();
        let mut rng = Rng::new(78);
        for v in x0.iter_mut() {
            *v += 0.1 * rng.normal_f32() as f64;
        }
        let rep = power_method_f64(&t, &x0, 60, 1e-12);
        assert!((rep.lambda - 1.0e8).abs() < 1e-2, "lambda={}", rep.lambda);
        let align: f64 = rep.x.iter().zip(&cols[0]).map(|(a, b)| a * b).sum::<f64>().abs();
        assert!(align > 1.0 - 1e-10, "alignment={align}");
        assert!(rep.iters.last().unwrap().delta < 1e-12);
    }

    #[test]
    fn mttkrp_columns_are_sttsvs() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let n = 4 * part.m;
        let tensor = SymTensor::random(n, 51);
        let mut rng = Rng::new(52);
        let x_cols: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(n)).collect();
        let (ys, comm) = symmetric_mttkrp(&tensor, &part, &x_cols, opts()).unwrap();
        assert_eq!(ys.len(), 3);
        for (l, xl) in x_cols.iter().enumerate() {
            let want = tensor.sttsv(xl);
            let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
            for i in 0..n {
                assert!((ys[l][i] - want[i]).abs() < 3e-3 * scale, "l={l} i={i}");
            }
        }
        // words = r × one-STTSV cost on every processor (r-deep packing) ...
        let single = crate::coordinator::run_comm_only(
            &part,
            4,
            crate::coordinator::CommMode::PointToPoint,
        )
        .unwrap();
        for (p, s) in comm.iter().enumerate() {
            assert_eq!(s.sent_words, 3 * single[p].sent_words, "proc {p} words");
            // ... while message counts stay those of ONE STTSV: the batched
            // pass amortizes the per-message latency across the r columns.
            assert_eq!(s.sent_msgs, single[p].sent_msgs, "proc {p} msgs");
        }
    }

    #[test]
    fn cp_gradient_matches_finite_differences() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 3;
        let n = b * part.m; // 15
        let (tensor, _) = SymTensor::odeco(n, &[3.0, 1.5], 41);
        let mut rng = Rng::new(42);
        let r = 2;
        let x_cols: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
        let rep = cp_gradient(&tensor, &part, &x_cols, opts()).unwrap();

        let h = 1e-3f32;
        for l in 0..r {
            for i in [0usize, n / 2, n - 1] {
                let mut plus = x_cols.clone();
                plus[l][i] += h;
                let mut minus = x_cols.clone();
                minus[l][i] -= h;
                let fd = (cp_objective(&tensor, &plus) - cp_objective(&tensor, &minus))
                    / (2.0 * h as f64);
                let got = rep.grad[l][i] as f64;
                assert!(
                    (fd - got).abs() < 2e-2 * fd.abs().max(1.0),
                    "l={l} i={i}: fd={fd} grad={got}"
                );
            }
        }
    }

    #[test]
    fn packed_cp_objective_equals_dense_oracle() {
        let n = 9;
        let tensor = SymTensor::random(n, 71);
        let mut rng = Rng::new(72);
        let x_cols: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(n)).collect();
        let packed = cp_objective(&tensor, &x_cols);
        let dense = cp_objective_dense(&tensor, &x_cols);
        assert!(
            (packed - dense).abs() < 1e-9 * dense.abs().max(1.0),
            "packed {packed} vs dense {dense}"
        );
    }

    #[test]
    fn cp_als_sweep_descends_the_objective() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 3;
        let n = b * part.m;
        let (tensor, cols) = SymTensor::odeco(n, &[3.0, 1.5], 43);
        let mut rng = Rng::new(44);
        // start near the planted factors so plain gradient descent descends
        let x0: Vec<Vec<f32>> = cols
            .iter()
            .take(2)
            .zip([3.0f32, 1.5])
            .map(|(c, lam)| {
                let s = lam.cbrt();
                c.iter().map(|v| s * v + 0.05 * rng.normal_f32()).collect()
            })
            .collect();
        let f0 = cp_objective(&tensor, &x0);
        let rep = cp_als_sweep(&tensor, &part, &x0, 25, 0.05, 0.0, opts()).unwrap();
        assert_eq!(rep.iters.len(), 25);
        let f1 = cp_objective(&tensor, &rep.x_cols);
        assert!(f1 < 0.25 * f0, "objective did not descend: {f0} -> {f1}");
        // gradient norms descend too
        assert!(rep.iters.last().unwrap().gnorm < rep.iters[0].gnorm);
    }
}
