//! The paper's motivating applications, built on the distributed STTSV
//! coordinator: the higher-order power method (Algorithm 1) for tensor
//! Z-eigenpairs, and the symmetric CP gradient (Algorithm 2).
//!
//! Both multi-column workloads (CP gradient, symmetric MTTKRP) run their r
//! STTSVs through [`SttsvPlan::run_multi`]: one sweep of the distributed
//! tensor serves all r columns, with messages packed r words deep — words
//! scale as r× one STTSV but message counts (latency) do not grow with r.

use crate::coordinator::{ExecOpts, SttsvPlan};
use crate::partition::TetraPartition;
use crate::simulator::CommStats;
use crate::tensor::{linalg, SymTensor};
use anyhow::Result;

/// One power-method iteration record.
#[derive(Debug, Clone)]
pub struct PowerIter {
    /// ||y|| before normalization (converges to |λ|).
    pub norm: f32,
    /// Rayleigh quotient estimate λ = A ×₁ x ×₂ x ×₃ x.
    pub lambda: f32,
    /// ||x_{t} − x_{t−1}||, the convergence criterion.
    pub delta: f32,
}

/// Full power-method report.
#[derive(Debug, Clone)]
pub struct PowerReport {
    /// Final eigenvalue estimate.
    pub lambda: f32,
    /// Final unit eigenvector estimate.
    pub x: Vec<f32>,
    /// Per-iteration convergence log.
    pub iters: Vec<PowerIter>,
    /// Aggregated per-processor comm over all distributed STTSV calls.
    pub comm: Vec<CommStats>,
    /// Communication steps per STTSV vector phase.
    pub steps_per_phase: usize,
}

fn add_stats(acc: &mut [CommStats], per_proc: &[crate::coordinator::ProcReport]) {
    for (a, r) in acc.iter_mut().zip(per_proc) {
        a.sent_words += r.stats.sent_words;
        a.recv_words += r.stats.recv_words;
        a.sent_msgs += r.stats.sent_msgs;
        a.recv_msgs += r.stats.recv_msgs;
    }
}

/// Higher-order power method (Algorithm 1): iterate y = A ×₂ x ×₃ x,
/// x = y/||y||, until ||Δx|| < tol or `max_iters`. Every iteration's STTSV
/// runs through the full distributed stack (partition → schedule →
/// simulator → block kernels).
pub fn power_method(
    tensor: &SymTensor,
    part: &TetraPartition,
    x0: &[f32],
    max_iters: usize,
    tol: f32,
    opts: ExecOpts,
) -> Result<PowerReport> {
    let mut x = x0.to_vec();
    linalg::normalize(&mut x);
    let mut iters = Vec::new();
    let mut comm: Vec<CommStats> = vec![CommStats::default(); part.p];
    let mut steps_per_phase = 0;

    // The plan (schedule + extracted owner-compute blocks) is built once;
    // each iteration only moves vector data (§Perf P5).
    let plan = SttsvPlan::new(tensor, part, opts)?;
    for _ in 0..max_iters {
        let rep = plan.run(&x)?;
        steps_per_phase = rep.steps_per_phase;
        add_stats(&mut comm, &rep.per_proc);
        let mut y = rep.y;
        let norm = linalg::normalize(&mut y);
        let delta = x
            .iter()
            .zip(&y)
            .map(|(a, b)| {
                let d = a - b;
                (d * d) as f64
            })
            .sum::<f64>()
            .sqrt() as f32;
        let lambda = linalg::dot(&tensor.sttsv(&y), &y);
        x = y;
        iters.push(PowerIter { norm, lambda, delta });
        if delta < tol {
            break;
        }
    }
    let lambda = iters.last().map(|i| i.lambda).unwrap_or(0.0);
    Ok(PowerReport {
        lambda,
        x,
        iters,
        comm,
        steps_per_phase,
    })
}

/// Symmetric CP gradient report (Algorithm 2).
#[derive(Debug, Clone)]
pub struct CpGradReport {
    /// The gradient matrix Y ∈ R^{n×r}, column-major (columns = y_ℓ).
    pub grad: Vec<Vec<f32>>,
    /// Per-processor comm of the ONE batched r-column distributed STTSV.
    pub comm: Vec<CommStats>,
}

/// Symmetric CP gradient (Algorithm 2): for factor matrix X (columns x_ℓ),
///   G = (XᵀX) ∗ (XᵀX);  y_ℓ = A ×₂ x_ℓ ×₃ x_ℓ;  ∇ = X·G − Y.
/// The r STTSVs (the bottleneck) run as ONE batched multi-RHS pass through
/// the distributed stack — each owned tensor block is swept once for all r
/// columns and every message carries all r columns' coordinates; the r×r
/// Gram arithmetic is O(nr²) local work (as in the paper, where only STTSV
/// is analyzed).
pub fn cp_gradient(
    tensor: &SymTensor,
    part: &TetraPartition,
    x_cols: &[Vec<f32>],
    opts: ExecOpts,
) -> Result<CpGradReport> {
    let n = tensor.n;
    let r = x_cols.len();
    if r == 0 {
        // Empty factor matrix: nothing to compute or communicate.
        return Ok(CpGradReport { grad: Vec::new(), comm: vec![CommStats::default(); part.p] });
    }
    // G = (XᵀX) ∗ (XᵀX) elementwise
    let mut g = vec![vec![0.0f32; r]; r];
    for a in 0..r {
        for bb in 0..r {
            let d = linalg::dot(&x_cols[a], &x_cols[bb]);
            g[a][bb] = d * d;
        }
    }
    // Y via ONE batched distributed STTSV over all r columns
    let plan = SttsvPlan::new(tensor, part, opts)?;
    let rep = plan.run_multi(x_cols)?;
    let mut comm: Vec<CommStats> = vec![CommStats::default(); part.p];
    add_stats(&mut comm, &rep.per_proc);
    let ys = rep.ys;
    // ∇_ℓ = Σ_a x_a·G[a][ℓ] − y_ℓ
    let mut grad = vec![vec![0.0f32; n]; r];
    for l in 0..r {
        for i in 0..n {
            let mut v = 0.0f32;
            for a in 0..r {
                v += x_cols[a][i] * g[a][l];
            }
            grad[l][i] = v - ys[l][i];
        }
    }
    Ok(CpGradReport { grad, comm })
}

/// Mode-1 symmetric MTTKRP (paper §8, future work realized here):
/// `Y[:, ℓ] = A ×₂ x_ℓ ×₃ x_ℓ` for each column of X — exactly r STTSVs, the
/// bottleneck of CP decomposition algorithms, served by ONE batched
/// multi-RHS pass: the tensor distribution is column-independent, so a
/// single sweep of the owned blocks computes every column while the
/// messages of the Theorem 6 schedule carry all r columns at once.
///
/// Returns (Y columns, per-processor comm of the batched pass).
pub fn symmetric_mttkrp(
    tensor: &SymTensor,
    part: &TetraPartition,
    x_cols: &[Vec<f32>],
    opts: ExecOpts,
) -> Result<(Vec<Vec<f32>>, Vec<CommStats>)> {
    if x_cols.is_empty() {
        // Zero columns: nothing to compute or communicate.
        return Ok((Vec::new(), vec![CommStats::default(); part.p]));
    }
    let plan = SttsvPlan::new(tensor, part, opts)?;
    let rep = plan.run_multi(x_cols)?;
    let mut comm: Vec<CommStats> = vec![CommStats::default(); part.p];
    add_stats(&mut comm, &rep.per_proc);
    Ok((rep.ys, comm))
}

/// The CP objective f(X) = ||A − Σ_ℓ x_ℓ⊗x_ℓ⊗x_ℓ||² / 6 evaluated densely
/// (test helper for finite-difference gradient checks).
pub fn cp_objective(tensor: &SymTensor, x_cols: &[Vec<f32>]) -> f64 {
    let n = tensor.n;
    let mut err = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            for k in 0..n {
                let mut model = 0.0f64;
                for xl in x_cols {
                    model += xl[i] as f64 * xl[j] as f64 * xl[k] as f64;
                }
                let d = tensor.get(i, j, k) as f64 - model;
                err += d * d;
            }
        }
    }
    err / 6.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CommMode;
    use crate::runtime::Backend;
    use crate::steiner::spherical;
    use crate::util::rng::Rng;

    fn opts() -> ExecOpts {
        ExecOpts {
            mode: CommMode::PointToPoint,
            backend: Backend::Native,
            batch: true,
            packed: true,
            overlap: true,
        }
    }

    #[test]
    fn power_method_recovers_dominant_eigenpair() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 6;
        let n = b * part.m; // 30
        let (tensor, cols) = SymTensor::odeco(n, &[5.0, 2.0, 1.0], 31);
        let mut rng = Rng::new(32);
        // start near the dominant eigenvector to ensure its basin
        let mut x0: Vec<f32> = cols[0].clone();
        for v in x0.iter_mut() {
            *v += 0.2 * rng.normal_f32();
        }
        let rep = power_method(&tensor, &part, &x0, 60, 1e-6, opts()).unwrap();
        assert!((rep.lambda - 5.0).abs() < 1e-2, "lambda={}", rep.lambda);
        let align = linalg::dot(&rep.x, &cols[0]).abs();
        assert!(align > 0.999, "alignment={align}");
        // convergence log is monotone-ish and ends small
        assert!(rep.iters.last().unwrap().delta < 1e-6);
        // comm happened on every processor
        assert!(rep.comm.iter().all(|s| s.sent_words > 0));
    }

    #[test]
    fn mttkrp_columns_are_sttsvs() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let n = 4 * part.m;
        let tensor = SymTensor::random(n, 51);
        let mut rng = Rng::new(52);
        let x_cols: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(n)).collect();
        let (ys, comm) = symmetric_mttkrp(&tensor, &part, &x_cols, opts()).unwrap();
        assert_eq!(ys.len(), 3);
        for (l, xl) in x_cols.iter().enumerate() {
            let want = tensor.sttsv(xl);
            let scale = want.iter().map(|v| v.abs()).fold(1.0f32, f32::max);
            for i in 0..n {
                assert!((ys[l][i] - want[i]).abs() < 3e-3 * scale, "l={l} i={i}");
            }
        }
        // words = r × one-STTSV cost on every processor (r-deep packing) ...
        let single = crate::coordinator::run_comm_only(
            &part,
            4,
            crate::coordinator::CommMode::PointToPoint,
        )
        .unwrap();
        for (p, s) in comm.iter().enumerate() {
            assert_eq!(s.sent_words, 3 * single[p].sent_words, "proc {p} words");
            // ... while message counts stay those of ONE STTSV: the batched
            // pass amortizes the per-message latency across the r columns.
            assert_eq!(s.sent_msgs, single[p].sent_msgs, "proc {p} msgs");
        }
    }

    #[test]
    fn cp_gradient_matches_finite_differences() {
        let part = TetraPartition::from_steiner(&spherical(2).unwrap()).unwrap();
        let b = 3;
        let n = b * part.m; // 15
        let (tensor, _) = SymTensor::odeco(n, &[3.0, 1.5], 41);
        let mut rng = Rng::new(42);
        let r = 2;
        let x_cols: Vec<Vec<f32>> = (0..r).map(|_| rng.normal_vec(n)).collect();
        let rep = cp_gradient(&tensor, &part, &x_cols, opts()).unwrap();

        let h = 1e-3f32;
        for l in 0..r {
            for i in [0usize, n / 2, n - 1] {
                let mut plus = x_cols.clone();
                plus[l][i] += h;
                let mut minus = x_cols.clone();
                minus[l][i] -= h;
                let fd = (cp_objective(&tensor, &plus) - cp_objective(&tensor, &minus))
                    / (2.0 * h as f64);
                let got = rep.grad[l][i] as f64;
                assert!(
                    (fd - got).abs() < 2e-2 * fd.abs().max(1.0),
                    "l={l} i={i}: fd={fd} grad={got}"
                );
            }
        }
    }
}
